//! Table 2 — per-optimization speedups, one axis toggled at a time.
//!
//! For each (pipeline, axis) cell of the paper's Table 2: run the pipeline
//! fully optimized, then with exactly that axis set back to baseline; the
//! ratio is the axis's contribution. Absolute factors differ from the
//! paper's (different substrate, single core — see DESIGN.md §2); the
//! *shape* to hold is which cells are large vs small.
//!
//! Each (pipeline, toggles) cell opens one warm `Session` and executes
//! its pre-generated payload per iteration, so the medians measure the
//! pipeline, not repeated data generation or model compiles.
//!
//! ```sh
//! cargo bench --bench table2_optimizations
//! ```

use repro::pipelines::{RunConfig, Toggles};
use repro::service::Session;
use repro::util::fmt::{self, Table};
use repro::OptLevel;

#[derive(Clone, Copy)]
enum Axis {
    Dataframe,
    Ml,
    Dl,
    Quant,
}

impl Axis {
    fn label(self) -> &'static str {
        match self {
            Axis::Dataframe => "dataframe (Modin)",
            Axis::Ml => "ml (sklearnex/XGB)",
            Axis::Dl => "dl graph (IPEX/TF)",
            Axis::Quant => "int8 (INC)",
        }
    }

    fn degrade(self, t: &mut Toggles) {
        match self {
            Axis::Dataframe => t.dataframe = OptLevel::Baseline,
            Axis::Ml => t.ml = OptLevel::Baseline,
            Axis::Dl => {
                t.dl = OptLevel::Baseline;
                t.quant = false;
            }
            Axis::Quant => t.quant = false,
        }
    }
}

/// The Table 2 cells: (pipeline, axis, paper speedup).
fn cells() -> Vec<(&'static str, Axis, &'static str)> {
    vec![
        ("census", Axis::Dataframe, "6x"),
        ("census", Axis::Ml, "59x"),
        ("plasticc", Axis::Dataframe, "30x"),
        ("plasticc", Axis::Ml, "8x (sklearnex) / 1x (XGB)"),
        ("iiot", Axis::Dataframe, "4.8x"),
        ("iiot", Axis::Ml, "113x"),
        ("dlsa", Axis::Dl, "4.15x (IPEX)"),
        ("dlsa", Axis::Quant, "3.90x"),
        ("dien", Axis::Dataframe, "23.2x"),
        ("dien", Axis::Dl, "9.82x (TF)"),
        ("video_streamer", Axis::Dl, "1.36x (TF)"),
        ("video_streamer", Axis::Quant, "3.64x"),
        ("anomaly", Axis::Ml, "3.4x (sklearnex)"),
        ("anomaly", Axis::Dl, "1.8x (IPEX)"),
        ("face", Axis::Dl, "1.7x (TF)"),
    ]
}

fn median_total(name: &str, cfg: &RunConfig, iters: usize) -> f64 {
    let Ok(session) = Session::open(name, *cfg) else {
        return f64::NAN;
    };
    let payload = session.payload();
    let mut samples: Vec<f64> = (0..iters.max(1))
        .map(|_| {
            session
                .execute(payload.clone())
                .map(|(res, _)| res.report.total().as_secs_f64())
                .unwrap_or(f64::NAN)
        })
        .collect();
    samples.sort_by(|a, b| a.total_cmp(b));
    samples[samples.len() / 2]
}

fn main() {
    let scale: f64 = std::env::var("REPRO_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0);
    let iters: usize = std::env::var("REPRO_BENCH_ITERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(3);

    println!("\n=== Table 2: per-optimization speedups (scale {scale}, median of {iters}) ===");
    let mut t = Table::new(&["pipeline", "axis", "measured", "paper"]);
    let mut last_pipeline = "";
    let mut opt_time = 0.0;
    for (pipeline, axis, paper) in cells() {
        if pipeline != last_pipeline {
            let cfg = RunConfig { toggles: Toggles::optimized(), scale, seed: 0x7AB, ..Default::default() };
            opt_time = median_total(pipeline, &cfg, iters);
            last_pipeline = pipeline;
        }
        let measured = if matches!(axis, Axis::Quant) {
            // INT8 axis: fp32-optimized vs int8-optimized. On a substrate
            // without INT8 dot-product hardware this comes out <= 1x — the
            // honest result; the paper's 3.6–3.9x needs VNNI
            // (EXPERIMENTS.md §INT8).
            let mut toggles = Toggles::optimized();
            toggles.quant = true;
            let cfg = RunConfig { toggles, scale, seed: 0x7AB, ..Default::default() };
            let int8 = median_total(pipeline, &cfg, iters);
            opt_time / int8
        } else {
            let mut toggles = Toggles::optimized();
            axis.degrade(&mut toggles);
            let cfg = RunConfig { toggles, scale, seed: 0x7AB, ..Default::default() };
            let degraded = median_total(pipeline, &cfg, iters);
            degraded / opt_time
        };
        t.row(&[
            pipeline.to_string(),
            axis.label().to_string(),
            fmt::speedup(measured),
            paper.to_string(),
        ]);
    }
    t.print();
    println!(
        "shape check: dataframe cells are large for census/plasticc/dien,\n\
         ml cells large for census/iiot, dl+int8 matter for the DL pipelines."
    );
}
