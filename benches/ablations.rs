//! Ablations over the design choices DESIGN.md calls out:
//!
//! * GEMM block size (linalg §Perf knob)
//! * GBT histogram bins vs exact splits (XGBoost `hist` axis)
//! * CSV reader engines at growing row counts (Modin axis, isolated)
//! * groupby engines at growing group cardinality (PLAsTiCC's hot stage)
//! * NMS naive vs sorted at growing detection density
//! * tokenizer baseline vs trie at growing document counts
//! * dynamic batcher policy (batch size × wait) at a fixed arrival rate
//!
//! ```sh
//! cargo bench --bench ablations
//! ```

use repro::coordinator::{BatcherConfig, DynamicBatcher};
use repro::dataframe::{self as df, groupby::Agg, Column, DataFrame, Engine};
use repro::linalg::{matmul_blocked, matmul_naive, Matrix};
use repro::ml::gbt::{synthetic_classification, Gbt, GbtParams, TreeMethod};
use repro::parallel::channel::bounded;
use repro::text::{ReviewGenerator, TokenizerKind, Vocab, WordPiece};
use repro::util::fmt::{dur, speedup, Table};
use repro::util::timer::bench_median;
use repro::util::Rng;
use repro::vision::{nms, Detection, NmsKind};
use std::time::Duration;

fn gemm_blocks() {
    println!("\n--- GEMM: naive vs blocked (256³) ---");
    let mut rng = Rng::new(1);
    let a = Matrix::randn(256, 256, &mut rng);
    let b = Matrix::randn(256, 256, &mut rng);
    let t_naive = bench_median(1, 3, || {
        std::hint::black_box(matmul_naive(&a, &b));
    });
    let t_blocked = bench_median(1, 3, || {
        std::hint::black_box(matmul_blocked(&a, &b));
    });
    let mut t = Table::new(&["kernel", "median", "speedup"]);
    t.row(&["naive (ijk, strided)".into(), dur(t_naive), "1.00x".into()]);
    t.row(&[
        "blocked (ikj, 64³ tiles, unrolled)".into(),
        dur(t_blocked),
        speedup(t_naive.as_secs_f64() / t_blocked.as_secs_f64()),
    ]);
    t.print();
}

fn gbt_bins() {
    println!("\n--- GBT: exact vs histogram bins (1500×10) ---");
    let mut rng = Rng::new(2);
    let (x, y) = synthetic_classification(1500, 10, &mut rng);
    let mut t = Table::new(&["method", "median fit", "speedup vs exact"]);
    let t_exact = bench_median(0, 3, || {
        std::hint::black_box(Gbt::fit(
            &x,
            &y,
            GbtParams { method: TreeMethod::Exact, n_trees: 10, ..Default::default() },
        ));
    });
    t.row(&["exact".into(), dur(t_exact), "1.00x".into()]);
    for bins in [16usize, 64, 256] {
        let t_hist = bench_median(0, 3, || {
            std::hint::black_box(Gbt::fit(
                &x,
                &y,
                GbtParams {
                    method: TreeMethod::Hist,
                    max_bins: bins,
                    n_trees: 10,
                    ..Default::default()
                },
            ));
        });
        t.row(&[
            format!("hist({bins})"),
            dur(t_hist),
            speedup(t_exact.as_secs_f64() / t_hist.as_secs_f64()),
        ]);
    }
    t.print();
}

fn csv_engines() {
    println!("\n--- CSV reader: baseline vs optimized vs parallel engine ---");
    let threads = repro::parallel::default_threads();
    let mut t = Table::new(&["rows", "baseline", "optimized", "speedup", &format!("parallel({threads})")]);
    for rows in [2_000usize, 10_000, 40_000] {
        let text = repro::pipelines::census::generate_csv(rows, 3);
        let t_base = bench_median(0, 3, || {
            std::hint::black_box(df::csv::read_str(&text, Engine::Baseline).unwrap());
        });
        let t_opt = bench_median(0, 3, || {
            std::hint::black_box(df::csv::read_str(&text, Engine::Optimized).unwrap());
        });
        let t_par = bench_median(0, 3, || {
            std::hint::black_box(df::csv::read_str_parallel(&text, threads).unwrap());
        });
        t.row(&[
            rows.to_string(),
            dur(t_base),
            dur(t_opt),
            speedup(t_base.as_secs_f64() / t_opt.as_secs_f64()),
            dur(t_par),
        ]);
    }
    t.print();
}

fn groupby_engines() {
    println!("\n--- groupby-agg: baseline vs optimized engine ---");
    let mut t = Table::new(&["rows x groups", "baseline", "optimized", "speedup"]);
    for (rows, groups) in [(5_000usize, 50usize), (20_000, 500), (50_000, 5_000)] {
        let mut rng = Rng::new(4);
        let frame = DataFrame::from_cols(vec![
            ("k", Column::i64((0..rows).map(|_| rng.below(groups) as i64).collect())),
            ("x", Column::f64((0..rows).map(|_| rng.normal()).collect())),
        ]);
        let aggs = [("x", Agg::Mean), ("x", Agg::Std), ("x", Agg::Max)];
        let t_base = bench_median(0, 3, || {
            std::hint::black_box(
                df::groupby::groupby_agg(&frame, &["k"], &aggs, Engine::Baseline).unwrap(),
            );
        });
        let t_opt = bench_median(0, 3, || {
            std::hint::black_box(
                df::groupby::groupby_agg(&frame, &["k"], &aggs, Engine::Optimized).unwrap(),
            );
        });
        t.row(&[
            format!("{rows}x{groups}"),
            dur(t_base),
            dur(t_opt),
            speedup(t_base.as_secs_f64() / t_opt.as_secs_f64()),
        ]);
    }
    t.print();
}

fn nms_density() {
    println!("\n--- NMS: naive vs sorted at growing density ---");
    let mut t = Table::new(&["detections", "naive", "sorted", "speedup"]);
    for n in [64usize, 256, 1024] {
        let mut rng = Rng::new(5);
        let dets: Vec<Detection> = (0..n)
            .map(|_| {
                let y = rng.range_f64(0.0, 100.0) as f32;
                let x = rng.range_f64(0.0, 100.0) as f32;
                Detection {
                    bbox: [y, x, y + 8.0, x + 8.0],
                    class: 1 + rng.below(2),
                    score: rng.f32(),
                }
            })
            .collect();
        let t_naive = bench_median(0, 5, || {
            std::hint::black_box(nms(&dets, 0.4, NmsKind::Naive));
        });
        let t_sorted = bench_median(0, 5, || {
            std::hint::black_box(nms(&dets, 0.4, NmsKind::Sorted));
        });
        t.row(&[
            n.to_string(),
            dur(t_naive),
            dur(t_sorted),
            speedup(t_naive.as_secs_f64() / t_sorted.as_secs_f64()),
        ]);
    }
    t.print();
}

fn tokenizer_paths() {
    println!("\n--- tokenizer: substring-probe vs trie ---");
    let vocab = Vocab::build_from_corpus(&ReviewGenerator::lexicon(), 64);
    let tok = WordPiece::new(vocab, 64);
    let mut t = Table::new(&["docs", "baseline", "optimized", "speedup"]);
    for n in [200usize, 1000] {
        let mut gen = ReviewGenerator::new(6, 30);
        let texts: Vec<String> = gen.batch(n).into_iter().map(|r| r.text).collect();
        let t_base = bench_median(0, 3, || {
            std::hint::black_box(tok.encode_batch(&texts, TokenizerKind::Baseline));
        });
        let t_opt = bench_median(0, 3, || {
            std::hint::black_box(tok.encode_batch(&texts, TokenizerKind::Optimized));
        });
        t.row(&[
            n.to_string(),
            dur(t_base),
            dur(t_opt),
            speedup(t_base.as_secs_f64() / t_opt.as_secs_f64()),
        ]);
    }
    t.print();
}

fn batcher_policies() {
    println!("\n--- dynamic batcher: policy vs batch-size distribution ---");
    let mut t = Table::new(&["max_batch", "max_wait", "batches", "size flushes", "timeout flushes"]);
    for (max_batch, wait_ms) in [(4usize, 1u64), (8, 1), (8, 10)] {
        let (tx, rx) = bounded(64);
        let producer = std::thread::spawn(move || {
            let mut rng = Rng::new(7);
            for i in 0..200 {
                tx.send(i).unwrap();
                if rng.chance(0.3) {
                    std::thread::sleep(Duration::from_micros(300));
                }
            }
        });
        let mut b = DynamicBatcher::new(
            rx,
            BatcherConfig { max_batch, max_wait: Duration::from_millis(wait_ms) },
        );
        let batches = b.drain();
        producer.join().unwrap();
        t.row(&[
            max_batch.to_string(),
            format!("{wait_ms}ms"),
            batches.len().to_string(),
            b.size_flushes.to_string(),
            b.timeout_flushes.to_string(),
        ]);
    }
    t.print();
}

fn main() {
    println!("=== ablations over DESIGN.md design choices ===");
    gemm_blocks();
    gbt_bins();
    csv_engines();
    groupby_engines();
    nms_density();
    tokenizer_paths();
    batcher_policies();
}
