//! §3.4 workload scaling — multi-instance sweep for the two workloads the
//! paper scales: anomaly-detection camera streams and DLSA inference
//! streams — plus the data-parallel comparison: `shard:N` (one dataset
//! partitioned across N workers, merge-aware sink) vs `multi:N`
//! (N replicated streams) on the same census payload. Multi-instance
//! scales *compute*; sharding is what makes a *fixed dataset* finish
//! faster, so the two are printed side by side as dataset throughput
//! (payload items per second of wall time until the dataset is done).
//!
//! Single-core sandbox: the deliverables are (a) aggregate throughput
//! stays flat as instances time-slice (no coordination collapse),
//! (b) fairness stays near 1.0, and (c) per-batch latency p50/p95 —
//! fairness by item count can hide one instance's requests all landing in
//! the tail, so the percentiles make the §3.4 fairness claim measurable.
//! On a many-core Xeon the same harness shows the paper's linear scaling
//! (DESIGN.md §2). For the sharded comparison even one core shows the
//! gap: multi:N redoes the dataset N times, sharding does it once.
//!
//! Besides the printed tables, the run persists its trajectory to
//! `BENCH_scaling.json` (see `util::bench` for the schema): for each
//! measured pipeline, every exec mode's dataset throughput and
//! p50/p95 latency, merged across the sharded-vs-multi sweep and the
//! executor ladder. The §3.4 stream sweep and the DL pipelines join
//! when model artifacts are present; without them the bench still
//! completes (and still writes census's trajectory) instead of
//! panicking.
//!
//! ```sh
//! cargo bench --bench scaling_instances
//! ```

use repro::coordinator::{run_instances_timed, ExecMode, LatencyRecorder};
use repro::media::{normalize, resize, ResizeFilter};
use repro::pipelines::{self, run_plan_with, RunConfig, Toggles};
use repro::runtime::{ModelServer, Tensor};
use repro::text::{ReviewGenerator, TokenizerKind, Vocab, WordPiece};
use repro::util::bench::{mode_entry, write_trajectory};
use repro::util::fmt::{dur, Table};
use repro::util::json::Json;
use repro::util::Rng;
use std::collections::BTreeMap;
use std::time::Instant;

/// Pipeline name → exec-mode display string → measurement, merged
/// across the bench's sections and persisted at exit.
type Trajectory = BTreeMap<String, BTreeMap<String, Json>>;

/// Sharded vs multi-instance on one pre-generated payload: dataset
/// throughput (payload items / wall until that dataset is fully
/// processed). Census (tabular, single-state plan — the degenerate
/// sharding shape where shard 0 does all the work and the comparison
/// measures only that sharding avoids multi's n× replication) runs on
/// any checkout; the per-item pipelines (dlsa documents,
/// video_streamer frames — where shards genuinely split the transform
/// work) join when model artifacts are present and skip with a note
/// otherwise.
fn sharded_vs_multi(scale: f64, traj: &mut Trajectory) {
    println!("\n=== sharded (one dataset, partitioned) vs multi (n replicated streams) ===");
    let mut census_check: Option<(f64, f64)> = None;
    for name in ["census", "dlsa", "video_streamer"] {
        let entry = pipelines::find(name).expect("registry names");
        let cfg =
            RunConfig { toggles: Toggles::optimized(), scale, seed: 0x5CA1E, ..Default::default() };
        let payload = (entry.payload)(&cfg);
        println!("\n{name}:");
        let mut t = Table::new(&[
            "n",
            "shard:N wall",
            "shard:N items/s",
            "multi:N wall",
            "multi:N items/s",
            "shard/multi",
        ]);
        let mut last: Option<(f64, f64)> = None;
        let mut unavailable = false;
        for n in [1usize, 2, 4] {
            let shard_cfg = RunConfig { exec: ExecMode::Sharded(n), ..cfg };
            let t0 = Instant::now();
            let sharded = match run_plan_with(entry.plan_with, payload.clone(), &shard_cfg) {
                Ok(res) => res,
                Err(e) => {
                    println!("  skipped (no artifacts): {e:#}");
                    unavailable = true;
                    break;
                }
            };
            let shard_wall = t0.elapsed();
            // Sharded runs process the payload once: items == payload size.
            let shard_tput = sharded.items as f64 / shard_wall.as_secs_f64().max(1e-12);
            traj.entry(name.to_string())
                .or_default()
                .insert(ExecMode::Sharded(n).to_string(), mode_entry(&sharded, shard_wall));

            let multi_cfg = RunConfig { exec: ExecMode::MultiInstance(n), ..cfg };
            let t0 = Instant::now();
            let multi = match run_plan_with(entry.plan_with, payload.clone(), &multi_cfg) {
                Ok(res) => res,
                Err(e) => {
                    println!("  skipped (no artifacts): {e:#}");
                    unavailable = true;
                    break;
                }
            };
            let multi_wall = t0.elapsed();
            // Multi-instance processes n copies; the one dataset is done
            // when the run is, so dataset throughput divides items by n.
            let dataset_items = multi.items / n.max(1);
            let multi_tput = dataset_items as f64 / multi_wall.as_secs_f64().max(1e-12);
            traj.entry(name.to_string())
                .or_default()
                .insert(ExecMode::MultiInstance(n).to_string(), mode_entry(&multi, multi_wall));

            t.row(&[
                n.to_string(),
                dur(shard_wall),
                format!("{shard_tput:.1}"),
                dur(multi_wall),
                format!("{multi_tput:.1}"),
                format!("{:.2}x", shard_tput / multi_tput.max(1e-12)),
            ]);
            last = Some((shard_tput, multi_tput));
        }
        if !unavailable {
            t.print();
        }
        if name == "census" {
            census_check = last;
        }
    }
    if let Some((shard_tput, multi_tput)) = census_check {
        println!(
            "\ncheck: census shard:4 dataset throughput {} multi:4 ({shard_tput:.1} vs {multi_tput:.1} items/s)",
            if shard_tput >= multi_tput { "≥" } else { "< (UNEXPECTED)" },
        );
    }
}

/// One payload, every single-request executor side by side: sequential
/// vs streaming vs async:T vs shard:N wall time over the same
/// pre-generated dataset. Async rows print the pool's task counters and
/// sharded rows the streamed-fold count, so the table shows not just
/// "how fast" but "how it ran" (tasks multiplexed, folds overlapped).
/// Census always runs; the per-item DL pipelines (dlsa documents,
/// video_streamer frames) join when model artifacts are present.
fn executor_ladder(scale: f64, traj: &mut Trajectory) {
    println!("\n=== executor ladder: sequential vs streaming vs async:T vs shard:N (one payload) ===");
    for name in ["census", "dlsa", "video_streamer"] {
        let entry = pipelines::find(name).expect("registry names");
        let cfg =
            RunConfig { toggles: Toggles::optimized(), scale, seed: 0xA51C, ..Default::default() };
        let payload = (entry.payload)(&cfg);
        let mut t = Table::new(&["executor", "wall", "items/s", "notes"]);
        let mut unavailable = false;
        let modes = [
            ExecMode::Sequential,
            ExecMode::Streaming,
            ExecMode::Async(2),
            ExecMode::Async(4),
            ExecMode::Sharded(2),
            ExecMode::Sharded(4),
        ];
        for exec in modes {
            let run_cfg = RunConfig { exec, ..cfg };
            let t0 = Instant::now();
            let res = match run_plan_with(entry.plan_with, payload.clone(), &run_cfg) {
                Ok(res) => res,
                Err(e) => {
                    println!("  {name} skipped (no artifacts): {e:#}");
                    unavailable = true;
                    break;
                }
            };
            let wall = t0.elapsed();
            traj.entry(name.to_string()).or_default().insert(exec.to_string(), mode_entry(&res, wall));
            let notes = match (&res.sched, &res.sharding) {
                (Some(s), Some(sh)) => {
                    format!("{} tasks, {} folds streamed", s.tasks_run, sh.streamed_folds)
                }
                (Some(s), None) => {
                    format!("{} tasks, max in-flight {}", s.tasks_run, s.max_in_flight)
                }
                (None, Some(sh)) => format!("balance {:.2}", sh.balance()),
                (None, None) => String::new(),
            };
            t.row(&[
                exec.to_string(),
                dur(wall),
                format!("{:.1}", res.items as f64 / wall.as_secs_f64().max(1e-12)),
                notes,
            ]);
        }
        if !unavailable {
            println!("\n{name}:");
            t.print();
        }
    }
}

/// Compile-once amortization, from BindReport counters (never
/// wall-clock-only): each pipeline opens ONE warm session (graph
/// compiled + models warmed once) and serves N requests against it;
/// the table reports the per-request bind time, requests served per
/// graph build, and the estimated setup time the reuse saved vs a
/// build-per-request loop. Census always runs; the DL pipelines join
/// when artifacts are present.
fn bind_amortization(scale: f64) {
    use repro::service::Session;
    println!("\n=== plan reuse: compile once, bind per request ===");
    let requests = 12usize;
    let mut t = Table::new(&[
        "pipeline",
        "graph builds",
        "binds",
        "mean bind",
        "binds/build",
        "est. setup saved",
        "wall (N requests)",
    ]);
    for name in ["census", "dlsa", "video_streamer"] {
        let cfg =
            RunConfig { toggles: Toggles::optimized(), scale, seed: 0xB17D, ..Default::default() };
        let session = match Session::open(name, cfg) {
            Ok(s) => s,
            Err(e) => {
                println!("  {name} skipped (no artifacts): {e:#}");
                continue;
            }
        };
        let payload = session.payload();
        let t0 = Instant::now();
        for _ in 0..requests {
            session.execute(payload.clone()).expect("warm session serves");
        }
        let wall = t0.elapsed();
        let br = session.bind_report();
        t.row(&[
            name.to_string(),
            br.compiles.to_string(),
            br.binds.to_string(),
            dur(br.mean_bind_time()),
            format!("{:.1}", br.binds_per_compile()),
            dur(br.amortized_saving()),
            dur(wall),
        ]);
    }
    t.print();
}

const IMG: usize = 32;

fn anomaly_stream(
    client: &repro::runtime::ModelClient,
    lat: &mut LatencyRecorder,
    seed: u64,
    images: usize,
) -> usize {
    let mut rng = Rng::new(seed);
    let mut done = 0usize;
    while done < images {
        let mut data = Vec::with_capacity(4 * IMG * IMG * 3);
        for _ in 0..4 {
            let part = {
                let defective = rng.chance(0.2);
                repro::pipelines::anomaly::generate_part(&mut rng, defective)
            };
            let mut small = resize(&part.img, IMG, IMG, ResizeFilter::Bilinear);
            normalize(&mut small, [0.45; 3], [0.25; 3]);
            data.extend_from_slice(&small.data);
        }
        let ok = lat.time(|| {
            client
                .run("resnet_features_fused_b4", vec![Tensor::f32(&[4, IMG, IMG, 3], data)])
                .is_ok()
        });
        if !ok {
            break;
        }
        done += 4;
    }
    done
}

fn dlsa_stream(
    client: &repro::runtime::ModelClient,
    lat: &mut LatencyRecorder,
    tok: &WordPiece,
    seed: u64,
    docs: usize,
) -> usize {
    let mut gen = ReviewGenerator::new(seed, 30);
    let mut done = 0usize;
    while done < docs {
        let batch = gen.batch(8);
        let texts: Vec<String> = batch.into_iter().map(|r| r.text).collect();
        let enc = tok.encode_batch(&texts, TokenizerKind::Optimized);
        let mut ids: Vec<i32> = Vec::with_capacity(8 * 64);
        for doc in &enc {
            ids.extend(doc.iter().map(|&t| t as i32));
        }
        let ok = lat
            .time(|| client.run("bert_fused_b8", vec![Tensor::i32(&[8, 64], ids)]).is_ok());
        if !ok {
            break;
        }
        done += 8;
    }
    done
}

fn main() {
    let images: usize = std::env::var("REPRO_BENCH_ITEMS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(24);
    let scale: f64 = std::env::var("REPRO_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0);
    // Tabular: runs on any checkout, before the artifact-gated streams.
    let mut traj = Trajectory::new();
    sharded_vs_multi(scale, &mut traj);
    executor_ladder(scale, &mut traj);
    bind_amortization(scale);

    let pipelines: BTreeMap<String, Json> = traj
        .into_iter()
        .map(|(name, modes)| {
            let mut p = BTreeMap::new();
            p.insert("exec_modes".to_string(), Json::Obj(modes));
            (name, Json::Obj(p))
        })
        .collect();
    match write_trajectory("BENCH_scaling.json", "scaling_instances", scale, pipelines) {
        Ok(_) => println!("\ntrajectory written to BENCH_scaling.json"),
        Err(e) => eprintln!("could not write BENCH_scaling.json: {e}"),
    }

    // The §3.4 stream sweep executes model artifacts; skip gracefully
    // (the trajectory above is already on disk) when `make artifacts`
    // has not run.
    if !repro::runtime::default_artifacts_dir().join("manifest.json").exists() {
        println!("\n=== §3.4 multi-instance scaling: skipped (no model artifacts) ===");
        return;
    }
    let server =
        ModelServer::spawn(repro::runtime::default_artifacts_dir(), 64).expect("server");
    server
        .client()
        .warmup(&["resnet_features_fused_b4", "bert_fused_b8"])
        .expect("warmup");
    let tok = WordPiece::new(Vocab::build_from_corpus(&ReviewGenerator::lexicon(), 64), 64);

    println!("\n=== §3.4 multi-instance scaling ({images} items/instance) ===");
    for (workload, is_dlsa) in [("anomaly camera streams", false), ("dlsa inference streams", true)]
    {
        println!("\n{workload}:");
        let mut t = Table::new(&[
            "instances",
            "aggregate items/s",
            "fairness",
            "batch p50",
            "batch p95",
        ]);
        for n in [1usize, 2, 4, 8] {
            let client = server.client();
            let tok = &tok;
            let report = run_instances_timed(n, |i, lat| {
                if is_dlsa {
                    dlsa_stream(&client, lat, tok, 0xD15A + i as u64, images)
                } else {
                    anomaly_stream(&client, lat, 0xA770 + i as u64, images)
                }
            });
            let pct = |p: Option<std::time::Duration>| match p {
                Some(d) => dur(d),
                None => "-".to_string(),
            };
            let mut pcts = report.latency_percentiles(&[0.50, 0.95]).into_iter();
            t.row(&[
                n.to_string(),
                format!("{:.1}", report.aggregate_throughput()),
                format!("{:.2}", report.fairness()),
                pct(pcts.next().flatten()),
                pct(pcts.next().flatten()),
            ]);
        }
        t.print();
    }
    println!(
        "\nshape check: aggregate ~flat on one core; fairness ≥ 0.5 and p95/p50\n\
         within a small factor throughout (no starved instance)."
    );
}
