//! Figure 11 — end-to-end speedup per pipeline: fully-baseline stack vs
//! fully-optimized stack.
//!
//! Paper reference: 1.8×–81.7× across the eight pipelines (abstract +
//! Figure 11). The shape to reproduce: the biggest wins come where
//! preprocessing dominates (Figure 1's high-pre pipelines — census,
//! plasticc, iiot, dien), the smallest where the pipeline is already
//! AI-dominated with modest DL headroom (face, video streamer).
//!
//! ```sh
//! cargo bench --bench fig11_e2e
//! REPRO_BENCH_SCALE=2 REPRO_BENCH_ITERS=5 cargo bench --bench fig11_e2e
//! ```

use repro::pipelines::{registry, RunConfig, Toggles};
use repro::util::fmt::{self, Table};

fn median_total(run: fn(&RunConfig) -> anyhow::Result<repro::pipelines::PipelineResult>, cfg: &RunConfig, iters: usize) -> f64 {
    let mut samples: Vec<f64> = (0..iters)
        .map(|_| {
            run(cfg)
                .map(|r| r.report.total().as_secs_f64())
                .unwrap_or(f64::NAN)
        })
        .collect();
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    samples[samples.len() / 2]
}

fn main() {
    let scale: f64 = std::env::var("REPRO_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0);
    let iters: usize = std::env::var("REPRO_BENCH_ITERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(3);

    println!("\n=== Figure 11: E2E speedup, baseline vs optimized (scale {scale}, median of {iters}) ===");
    let mut t = Table::new(&["pipeline", "baseline", "optimized", "speedup"]);
    let mut speedups: Vec<(String, f64)> = Vec::new();
    for e in registry() {
        let base_cfg =
            RunConfig { toggles: Toggles::baseline(), scale, seed: 0xF11, ..Default::default() };
        let opt_cfg =
            RunConfig { toggles: Toggles::optimized(), scale, seed: 0xF11, ..Default::default() };
        let base = median_total(e.run, &base_cfg, iters);
        let opt = median_total(e.run, &opt_cfg, iters);
        let s = base / opt;
        speedups.push((e.name.to_string(), s));
        t.row(&[
            e.name.to_string(),
            fmt::dur(std::time::Duration::from_secs_f64(base)),
            fmt::dur(std::time::Duration::from_secs_f64(opt)),
            fmt::speedup(s),
        ]);
    }
    t.print();
    let min = speedups.iter().map(|(_, s)| *s).fold(f64::INFINITY, f64::min);
    let max = speedups.iter().map(|(_, s)| *s).fold(0.0, f64::max);
    println!(
        "spread: {} – {}   (paper: 1.8x – 81.7x on dual-socket Xeon 8380)",
        fmt::speedup(min),
        fmt::speedup(max)
    );
}
