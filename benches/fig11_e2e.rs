//! Figure 11 — end-to-end speedup per pipeline: fully-baseline stack vs
//! fully-optimized stack.
//!
//! Paper reference: 1.8×–81.7× across the eight pipelines (abstract +
//! Figure 11). The shape to reproduce: the biggest wins come where
//! preprocessing dominates (Figure 1's high-pre pipelines — census,
//! plasticc, iiot, dien), the smallest where the pipeline is already
//! AI-dominated with modest DL headroom (face, video streamer).
//!
//! Serving-path measurement: each (pipeline, opt level) opens one warm
//! `Session` and synthesizes its payload once; the timed iterations
//! execute that payload repeatedly, so repeated runs no longer pay data
//! generation or model-compile cost (the paper's Fig 11 measures the
//! pipelines, not their setup).
//!
//! Besides the printed tables, the run persists its trajectory to
//! `BENCH_fig11.json` (see `util::bench` for the schema): per-pipeline
//! baseline/optimized medians and speedup, a per-exec-mode throughput +
//! p50/p95 ladder for the always-runnable tabular pipelines, and the
//! per-item vs columnar-batched comparison at `batch_rows = 256` —
//! so later changes diff measured numbers instead of re-asserting them.
//!
//! ```sh
//! cargo bench --bench fig11_e2e
//! REPRO_BENCH_SCALE=2 REPRO_BENCH_ITERS=5 cargo bench --bench fig11_e2e
//! ```

use repro::coordinator::ExecMode;
use repro::pipelines::{registry, run_by_name, RunConfig, Toggles};
use repro::service::Session;
use repro::util::bench::{mode_entry, write_trajectory};
use repro::util::fmt::{self, Table};
use repro::util::json::Json;
use std::collections::BTreeMap;
use std::time::Instant;

/// Median plan-execution time over `iters` runs of one warm session
/// serving a pre-generated payload; NaN when the pipeline cannot run
/// (missing artifacts).
fn median_total(name: &str, cfg: &RunConfig, iters: usize) -> f64 {
    let Ok(session) = Session::open(name, *cfg) else {
        return f64::NAN;
    };
    let payload = session.payload();
    let mut samples: Vec<f64> = (0..iters.max(1))
        .map(|_| {
            session
                .execute(payload.clone())
                .map(|(res, _)| res.report.total().as_secs_f64())
                .unwrap_or(f64::NAN)
        })
        .collect();
    samples.sort_by(|a, b| a.total_cmp(b));
    samples[samples.len() / 2]
}

fn main() {
    let scale: f64 = std::env::var("REPRO_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0);
    let iters: usize = std::env::var("REPRO_BENCH_ITERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(3);
    println!("\n=== Figure 11: E2E speedup, baseline vs optimized (scale {scale}, median of {iters}) ===");
    let mut t = Table::new(&["pipeline", "baseline", "optimized", "speedup"]);
    let mut speedups: Vec<(String, f64)> = Vec::new();
    // Per-pipeline JSON fragments for the persisted trajectory.
    let mut trajectory: BTreeMap<String, BTreeMap<String, Json>> = BTreeMap::new();
    for e in registry() {
        let base_cfg =
            RunConfig { toggles: Toggles::baseline(), scale, seed: 0xF11, ..Default::default() };
        let opt_cfg =
            RunConfig { toggles: Toggles::optimized(), scale, seed: 0xF11, ..Default::default() };
        let base = median_total(e.name, &base_cfg, iters);
        let opt = median_total(e.name, &opt_cfg, iters);
        let s = base / opt;
        speedups.push((e.name.to_string(), s));
        let maybe = |v: f64| if v.is_finite() { Json::Num(v) } else { Json::Null };
        let frag = trajectory.entry(e.name.to_string()).or_default();
        frag.insert("baseline_s".to_string(), maybe(base));
        frag.insert("optimized_s".to_string(), maybe(opt));
        frag.insert("speedup".to_string(), maybe(s));
        // Pipelines that cannot open (no artifacts) show as unavailable,
        // not as an impossibly fast 0ns measurement.
        let cell = |secs: f64| {
            if secs.is_finite() {
                fmt::dur(std::time::Duration::from_secs_f64(secs))
            } else {
                "-".to_string()
            }
        };
        t.row(&[
            e.name.to_string(),
            cell(base),
            cell(opt),
            if s.is_finite() { fmt::speedup(s) } else { "-".to_string() },
        ]);
    }
    t.print();
    let finite: Vec<f64> =
        speedups.iter().map(|(_, s)| *s).filter(|s| s.is_finite()).collect();
    let min = finite.iter().copied().fold(f64::INFINITY, f64::min);
    let max = finite.iter().copied().fold(0.0, f64::max);
    println!(
        "spread: {} – {}   (paper: 1.8x – 81.7x on dual-socket Xeon 8380)",
        fmt::speedup(min),
        fmt::speedup(max)
    );

    // Executor footnote: the optimized census payload executed data-
    // parallel (shard:4, one dataset partitioned) vs replicated
    // (multi:4, four copies) vs thread-per-stage (streaming) vs
    // cooperative tasks (async:2) — the wall-clock difference between
    // "finish the dataset faster", "run more copies", and the two
    // overlap shapes. Census is the degenerate single-state shape
    // (shard 0 does the whole pass), so this footnote measures only
    // replication avoidance; the scaling bench's executor ladder adds
    // the per-item pipelines where shards and tasks split real work.
    let mut t = Table::new(&["executor", "wall", "dataset items/s"]);
    for exec in [
        ExecMode::Sharded(4),
        ExecMode::MultiInstance(4),
        ExecMode::Streaming,
        ExecMode::Async(2),
    ] {
        let cfg =
            RunConfig { toggles: Toggles::optimized(), scale, seed: 0xF11, exec, ..Default::default() };
        let Ok(session) = Session::open("census", cfg) else {
            continue;
        };
        let payload = session.payload();
        let t0 = std::time::Instant::now();
        let Ok((res, _)) = session.execute(payload) else {
            continue;
        };
        let wall = t0.elapsed();
        // A sharded run's items are the one dataset; multi:N's are N
        // copies of it, which the dataset view divides back out.
        let copies = match exec {
            ExecMode::MultiInstance(n) => n.max(1),
            _ => 1,
        };
        let dataset_items = res.items / copies;
        t.row(&[
            exec.to_string(),
            fmt::dur(wall),
            format!("{:.1}", dataset_items as f64 / wall.as_secs_f64().max(1e-12)),
        ]);
    }
    println!("\nsharded vs multi vs streaming vs async on one census dataset (scale {scale}):");
    t.print();

    // Build-once vs build-per-request ladder: the same census payload
    // served N times through (a) a warm session binding its one
    // compiled graph per request and (b) the one-shot path recompiling
    // the graph every request. The amortization column comes from
    // BindReport counters (binds per graph build, mean bind time, and
    // the estimated setup time the reuse saved) — never wall clock
    // alone.
    let n_requests = 8usize;
    let cfg = RunConfig {
        toggles: Toggles::optimized(),
        scale,
        seed: 0xF11,
        ..Default::default()
    };
    let mut t = Table::new(&["strategy", "wall", "graph builds", "binds", "mean bind"]);
    if let Ok(session) = Session::open("census", cfg) {
        let payload = session.payload();
        let t0 = std::time::Instant::now();
        for _ in 0..n_requests {
            session.execute(payload.clone()).expect("census serves");
        }
        let reuse_wall = t0.elapsed();
        let br = session.bind_report();
        t.row(&[
            "build-once (session)".to_string(),
            fmt::dur(reuse_wall),
            br.compiles.to_string(),
            br.binds.to_string(),
            fmt::dur(br.mean_bind_time()),
        ]);

        let t0 = std::time::Instant::now();
        let mut rebuild_binds = 0usize;
        for _ in 0..n_requests {
            let compiled = repro::pipelines::compile_by_name("census", &cfg).expect("compiles");
            let entry = repro::pipelines::find("census").unwrap();
            repro::pipelines::run_compiled(entry, &compiled, payload.clone(), &cfg)
                .expect("census runs");
            rebuild_binds += compiled.bind_report().binds;
        }
        let rebuild_wall = t0.elapsed();
        t.row(&[
            "build-per-request".to_string(),
            fmt::dur(rebuild_wall),
            n_requests.to_string(),
            rebuild_binds.to_string(),
            "-".to_string(),
        ]);
        println!(
            "\nbuild-once vs build-per-request, census × {n_requests} requests (scale {scale}):"
        );
        t.print();
        println!(
            "amortization: {:.1} requests served per graph build; ~{} setup time saved vs rebuilding",
            br.binds_per_compile(),
            fmt::dur(br.amortized_saving()),
        );
    }

    // Per-exec-mode trajectory for the always-runnable tabular
    // pipelines: one run per mode, recorded as dataset throughput +
    // latency percentiles so the next change can diff the ladder.
    let ladder = [
        ExecMode::Sequential,
        ExecMode::Streaming,
        ExecMode::MultiInstance(2),
        ExecMode::Sharded(2),
        ExecMode::Async(2),
    ];
    for name in ["census", "plasticc", "iiot"] {
        let mut modes: BTreeMap<String, Json> = BTreeMap::new();
        for exec in ladder {
            let cfg = RunConfig {
                toggles: Toggles::optimized(),
                scale,
                seed: 0xF11,
                exec,
                ..Default::default()
            };
            let t0 = Instant::now();
            let Ok(res) = run_by_name(name, &cfg) else { continue };
            modes.insert(exec.to_string(), mode_entry(&res, t0.elapsed()));
        }
        trajectory.entry(name.to_string()).or_default().insert(
            "exec_modes".to_string(),
            Json::Obj(modes),
        );
    }

    // Columnar data plane: per-item vs batched (batch_rows = 256) on
    // the same payload, sequential executor. Throughput from wall
    // time; the amortization evidence (rows, clone-avoided bytes)
    // from the run's BatchReport counters.
    println!("\n=== columnar batch plane: per-item vs batch_rows=256 (sequential) ===");
    let mut t = Table::new(&["pipeline", "per-item items/s", "batched items/s", "ratio", "zero-copy"]);
    for name in ["census", "plasticc", "iiot"] {
        let cfg = RunConfig {
            toggles: Toggles::optimized(),
            scale,
            seed: 0xF11,
            ..Default::default()
        };
        let t0 = Instant::now();
        let Ok(per_item) = run_by_name(name, &cfg) else { continue };
        let per_item_wall = t0.elapsed();
        let batched_cfg = RunConfig { batch_rows: 256, ..cfg };
        let t0 = Instant::now();
        let Ok(batched) = run_by_name(name, &batched_cfg) else { continue };
        let batched_wall = t0.elapsed();
        let per_tput = per_item.items as f64 / per_item_wall.as_secs_f64().max(1e-12);
        let bat_tput = batched.items as f64 / batched_wall.as_secs_f64().max(1e-12);
        let zero_copy = batched
            .batching
            .map_or(0.0, |b| b.zero_copy_fraction() * 100.0);
        t.row(&[
            name.to_string(),
            format!("{per_tput:.1}"),
            format!("{bat_tput:.1}"),
            format!("{:.2}x", bat_tput / per_tput.max(1e-12)),
            format!("{zero_copy:.1}%"),
        ]);
        let mut b = BTreeMap::new();
        b.insert("batch_rows".to_string(), Json::Num(256.0));
        b.insert("per_item".to_string(), mode_entry(&per_item, per_item_wall));
        b.insert("batched".to_string(), mode_entry(&batched, batched_wall));
        trajectory
            .entry(name.to_string())
            .or_default()
            .insert("batched_vs_per_item".to_string(), Json::Obj(b));
    }
    t.print();

    let pipelines: BTreeMap<String, Json> =
        trajectory.into_iter().map(|(k, v)| (k, Json::Obj(v))).collect();
    match write_trajectory("BENCH_fig11.json", "fig11_e2e", scale, pipelines) {
        Ok(_) => println!("\ntrajectory written to BENCH_fig11.json"),
        Err(e) => eprintln!("could not write BENCH_fig11.json: {e}"),
    }
}
