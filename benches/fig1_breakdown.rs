//! Figure 1 — "Percent time in pre/postprocessing vs AI" for all eight
//! pipelines, regenerated on this substrate.
//!
//! Paper reference: Figure 1 reports a 4%–98% pre/post share across the
//! eight applications (§2). The "paper ≈" column holds approximate
//! readings off the published figure; the *shape* to reproduce is the
//! spread — tabular pipelines are preprocessing-dominated, DL-heavy
//! pipelines are AI-dominated.
//!
//! ```sh
//! cargo bench --bench fig1_breakdown            # default scale
//! REPRO_BENCH_SCALE=2 cargo bench --bench fig1_breakdown
//! ```

use repro::pipelines::{registry, RunConfig, Toggles};
use repro::util::fmt::{self, Table};

/// Approximate pre/post share (%) read off the paper's Figure 1 bars.
fn paper_pre_pct(name: &str) -> &'static str {
    match name {
        "census" => "~90",
        "plasticc" => "~85",
        "iiot" => "~60",
        "dlsa" => "~20",
        "dien" => "~75",
        "video_streamer" => "~25",
        "anomaly" => "~30",
        "face" => "~4",
        _ => "?",
    }
}

fn main() {
    let scale: f64 = std::env::var("REPRO_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0);
    let cfg = RunConfig { toggles: Toggles::optimized(), scale, seed: 0xF16, ..Default::default() };

    println!("\n=== Figure 1: percent time in pre/postprocessing vs AI (scale {scale}) ===");
    let mut t = Table::new(&[
        "pipeline",
        "pre/post %",
        "ai %",
        "paper ≈ pre/post %",
        "total",
        "items/s",
    ]);
    for e in registry() {
        match (e.run)(&cfg) {
            Ok(res) => {
                let (pre, ai) = res.report.fig1_split();
                t.row(&[
                    e.name.to_string(),
                    format!("{pre:.1}"),
                    format!("{ai:.1}"),
                    paper_pre_pct(e.name).to_string(),
                    fmt::dur(res.report.total()),
                    format!("{:.1}", res.throughput()),
                ]);
            }
            Err(err) => t.row(&[e.name.to_string(), format!("error: {err}")]),
        }
    }
    t.print();
    println!(
        "shape check: the spread must run from preprocessing-dominated (census,\n\
         plasticc, dien) to AI-dominated (dlsa, anomaly, face), as in the paper."
    );
}
