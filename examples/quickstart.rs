//! Quickstart: the smallest end-to-end tour of the public API.
//!
//! Runs one tabular pipeline (census) and one DL pipeline (video streamer)
//! at baseline and optimized levels, prints the paper-style speedups and
//! the Figure 1 breakdowns.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use repro::pipelines::{run_by_name, RunConfig, Toggles};
use repro::util::fmt;
use repro::OptLevel;

fn main() -> anyhow::Result<()> {
    for name in ["census", "video_streamer"] {
        println!("=== {name} ===");
        let mut totals = Vec::new();
        for opt in OptLevel::ALL {
            let cfg = RunConfig { toggles: Toggles::all(opt), scale: 0.5, seed: 1, ..Default::default() };
            let res = run_by_name(name, &cfg)?;
            let (pre, ai) = res.report.fig1_split();
            println!(
                "  {opt:<9}  total {:>8}  ({pre:.0}% pre/post, {ai:.0}% ai)  \
                 {:.1} items/s",
                fmt::dur(res.report.total()),
                res.throughput(),
            );
            for (k, v) in &res.metrics {
                println!("             {k} = {v:.4}");
            }
            totals.push(res.report.total().as_secs_f64());
        }
        println!("  E2E speedup: {}\n", fmt::speedup(totals[0] / totals[1]));
    }
    Ok(())
}
