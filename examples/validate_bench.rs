//! Validate persisted benchmark trajectories — the CI smoke gate for
//! `BENCH_fig11.json` / `BENCH_scaling.json` / `BENCH_serve.json` /
//! `BENCH_kernels.json`.
//!
//! For each file passed on the command line (both files by default),
//! checks that it parses, that the document header is well-formed
//! (`bench`, `schema_version`, `scale`, `pipelines`), that the
//! always-runnable `census` pipeline is present with an `exec_modes`
//! map containing every mode its bench measures, and that every
//! recorded mode entry carries finite `wall_s` / `items_per_s`
//! numbers. Serving trajectories (`bench_serve`) must additionally
//! break sheds out per wire-level `ShedCause` (`shed_by_cause` with
//! every cause label, summing to `shed`) and carry a top-level `net`
//! connection ledger whose counters balance (`accepted == drained +
//! reaped_idle + reaped_handshake` after the bench's drain). Kernel
//! trajectories (`bench_kernels`) must carry a top-level `kernels`
//! per-verb section with finite throughput and a row ledger that
//! balances (vector + scalar rows cover the rows processed). Exits
//! non-zero with a message naming the first violation.
//!
//! ```sh
//! cargo run --release --example validate_bench
//! cargo run --release --example validate_bench -- BENCH_fig11.json
//! ```

use repro::util::json::Json;
use std::process::ExitCode;

/// Exec modes each bench must record for census (always runnable, no
/// artifacts needed). Mode keys are `ExecMode` display strings.
fn required_modes(bench: &str) -> &'static [&'static str] {
    match bench {
        "fig11_e2e" => &["sequential", "streaming", "multi:2", "shard:2", "async:2"],
        "scaling_instances" => &[
            "sequential",
            "streaming",
            "async:2",
            "async:4",
            "shard:1",
            "shard:2",
            "shard:4",
            "multi:1",
            "multi:2",
            "multi:4",
        ],
        // `repro bench-serve` records one pseudo-mode per tenant: the
        // closed-loop serving trajectory over the TCP edge.
        "bench_serve" => &["serve"],
        // `repro bench-kernels` is a per-verb microbench; it still runs
        // one tiny sequential census pass so every trajectory carries a
        // comparable E2E anchor.
        "bench_kernels" => &["sequential"],
        other => panic!("unknown bench name in trajectory: {other}"),
    }
}

/// Dataframe verbs the kernel microbench must record.
const KERNEL_VERBS: &[&str] = &["filter", "with_column", "astype", "dropna", "fillna"];

fn check(path: &str) -> Result<(), String> {
    let text =
        std::fs::read_to_string(path).map_err(|e| format!("{path}: cannot read: {e}"))?;
    let doc = Json::parse(&text).map_err(|e| format!("{path}: {e}"))?;
    let bench = doc
        .get("bench")
        .and_then(Json::as_str)
        .ok_or_else(|| format!("{path}: missing `bench` name"))?;
    let version = doc
        .get("schema_version")
        .and_then(Json::as_f64)
        .ok_or_else(|| format!("{path}: missing `schema_version`"))?;
    if version != repro::util::bench::SCHEMA_VERSION {
        return Err(format!(
            "{path}: schema_version {version} != expected {}",
            repro::util::bench::SCHEMA_VERSION
        ));
    }
    doc.get("scale")
        .and_then(Json::as_f64)
        .ok_or_else(|| format!("{path}: missing `scale`"))?;
    let pipelines =
        doc.get("pipelines").ok_or_else(|| format!("{path}: missing `pipelines`"))?;
    let census = pipelines
        .get("census")
        .ok_or_else(|| format!("{path}: census trajectory missing"))?;
    let modes = census
        .get("exec_modes")
        .ok_or_else(|| format!("{path}: census has no `exec_modes`"))?;
    for required in required_modes(bench) {
        let entry = modes
            .get(required)
            .ok_or_else(|| format!("{path}: census missing exec mode `{required}`"))?;
        for field in ["wall_s", "items_per_s"] {
            let v = entry.get(field).and_then(Json::as_f64).ok_or_else(|| {
                format!("{path}: census {required}: missing `{field}`")
            })?;
            if !v.is_finite() || v < 0.0 {
                return Err(format!("{path}: census {required}: bad {field} = {v}"));
            }
        }
        // Serving trajectories must attribute every shed to a wire-level
        // ShedCause: all four cause labels present, finite and
        // non-negative, summing exactly to the `shed` total.
        if bench == "bench_serve" {
            let shed = entry.get("shed").and_then(Json::as_f64).ok_or_else(|| {
                format!("{path}: census {required}: missing `shed`")
            })?;
            let by_cause = entry.get("shed_by_cause").ok_or_else(|| {
                format!("{path}: census {required}: missing `shed_by_cause`")
            })?;
            let mut total = 0.0;
            for cause in repro::net::ShedCause::ALL {
                let label = cause.label();
                let v = by_cause.get(label).and_then(Json::as_f64).ok_or_else(|| {
                    format!("{path}: census {required}: shed_by_cause missing `{label}`")
                })?;
                if !v.is_finite() || v < 0.0 {
                    return Err(format!(
                        "{path}: census {required}: bad shed_by_cause.{label} = {v}"
                    ));
                }
                total += v;
            }
            if total != shed {
                return Err(format!(
                    "{path}: census {required}: shed_by_cause sums to {total}, shed = {shed}"
                ));
            }
        }
    }
    // Serving trajectories carry the server-side connection ledger at
    // the document root; a drained server's counters must balance.
    if bench == "bench_serve" {
        let net = doc.get("net").ok_or_else(|| format!("{path}: missing `net` ledger"))?;
        let counter = |field: &str| -> Result<f64, String> {
            let v = net.get(field).and_then(Json::as_f64).ok_or_else(|| {
                format!("{path}: net ledger missing `{field}`")
            })?;
            if !v.is_finite() || v < 0.0 {
                return Err(format!("{path}: net ledger: bad {field} = {v}"));
            }
            Ok(v)
        };
        let accepted = counter("accepted")?;
        let drained = counter("drained")?;
        let reaped_idle = counter("reaped_idle")?;
        let reaped_handshake = counter("reaped_handshake")?;
        counter("rejected")?;
        counter("frames_in")?;
        counter("frames_out")?;
        if accepted != drained + reaped_idle + reaped_handshake {
            return Err(format!(
                "{path}: net ledger does not balance: accepted {accepted} != \
                 drained {drained} + reaped {}",
                reaped_idle + reaped_handshake
            ));
        }
    }
    // Kernel-microbench trajectories carry a per-verb section at the
    // document root: every verb present, finite throughput, and a
    // counter ledger that balances (rows attributed to the vector and
    // scalar paths sum to the rows the verb processed).
    if bench == "bench_kernels" {
        let kernels = doc
            .get("kernels")
            .ok_or_else(|| format!("{path}: missing `kernels` section"))?;
        for verb in KERNEL_VERBS {
            let entry = kernels
                .get(verb)
                .ok_or_else(|| format!("{path}: kernels missing verb `{verb}`"))?;
            let field = |name: &str| -> Result<f64, String> {
                let v = entry.get(name).and_then(Json::as_f64).ok_or_else(|| {
                    format!("{path}: kernels.{verb}: missing `{name}`")
                })?;
                if !v.is_finite() || v < 0.0 {
                    return Err(format!("{path}: kernels.{verb}: bad {name} = {v}"));
                }
                Ok(v)
            };
            let rows = field("rows")?;
            field("rows_per_s")?;
            let vector = field("vector_rows")?;
            let scalar = field("scalar_rows")?;
            let frac = field("vector_fraction")?;
            if vector + scalar < rows {
                return Err(format!(
                    "{path}: kernels.{verb}: ledger undercounts: \
                     vector {vector} + scalar {scalar} < rows {rows}"
                ));
            }
            if frac > 1.0 {
                return Err(format!("{path}: kernels.{verb}: vector_fraction {frac} > 1"));
            }
        }
    }
    println!(
        "{path}: ok ({bench}, {} exec modes recorded for census)",
        required_modes(bench).len()
    );
    Ok(())
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let paths: Vec<String> = if args.is_empty() {
        vec!["BENCH_fig11.json".to_string(), "BENCH_scaling.json".to_string()]
    } else {
        args
    };
    let mut failed = false;
    for path in &paths {
        if let Err(msg) = check(path) {
            eprintln!("FAIL {msg}");
            failed = true;
        }
    }
    if failed {
        ExitCode::FAILURE
    } else {
        ExitCode::SUCCESS
    }
}
