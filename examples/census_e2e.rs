//! Census end-to-end walkthrough: the paper's §2.1 workload, stage by
//! stage, showing how each Table 2 optimization axis contributes.
//!
//! Sweeps (dataframe, ml) toggles independently — the decomposition behind
//! Table 2's "Modin 6×" and "scikit-learn 59×" columns for Census.
//!
//! ```sh
//! cargo run --release --example census_e2e [-- --scale 2.0]
//! ```

use repro::pipelines::{census, RunConfig, Toggles};
use repro::util::cli::Args;
use repro::util::fmt::{self, Table};
use repro::OptLevel;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let scale = args.get_parse("scale", 1.0f64);

    println!("census E2E — toggle decomposition (scale {scale})\n");
    let mut table = Table::new(&["dataframe", "ml", "total", "pre/post %", "r2"]);
    let mut baseline_total = None;
    for df_opt in OptLevel::ALL {
        for ml_opt in OptLevel::ALL {
            let mut toggles = Toggles::baseline();
            toggles.dataframe = df_opt;
            toggles.ml = ml_opt;
            let cfg = RunConfig { toggles, scale, seed: 42, ..Default::default() };
            let res = census::run(&cfg)?;
            let total = res.report.total();
            if df_opt == OptLevel::Baseline && ml_opt == OptLevel::Baseline {
                baseline_total = Some(total.as_secs_f64());
            }
            let (pre, _) = res.report.fig1_split();
            table.row(&[
                df_opt.label().to_string(),
                ml_opt.label().to_string(),
                format!(
                    "{} ({})",
                    fmt::dur(total),
                    fmt::speedup(baseline_total.unwrap() / total.as_secs_f64())
                ),
                format!("{pre:.1}%"),
                format!("{:.4}", res.metric("r2").unwrap_or(f64::NAN)),
            ]);
        }
    }
    table.print();

    // Full stage table for the optimized run (Figure 1 view).
    let res = census::run(&RunConfig {
        toggles: Toggles::optimized(),
        scale,
        seed: 42,
        ..Default::default()
    })?;
    println!("\noptimized stage breakdown:");
    res.report.table().print();
    Ok(())
}
