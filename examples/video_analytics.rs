//! Video-analytics walkthrough: the §2.6 streaming pipeline with the
//! Intel-TF (fused) and INT8 axes toggled, plus the NMS ablation —
//! demonstrating the streaming coordinator (bounded queues, model server)
//! on a real frame stream.
//!
//! ```sh
//! cargo run --release --example video_analytics [-- --frames 96]
//! ```

use repro::pipelines::{video_streamer, RunConfig, Toggles};
use repro::util::cli::Args;
use repro::util::fmt::{self, Table};
use repro::OptLevel;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let frames = args.get_parse("frames", 48usize);
    let scale = frames as f64 / 48.0;

    println!("video streamer — {frames} frames per configuration\n");
    let configs: &[(&str, Toggles)] = &[
        ("unfused fp32 (stock TF)", {
            let mut t = Toggles::baseline();
            t.nms = OptLevel::Optimized;
            t
        }),
        ("fused fp32 (Intel TF)", {
            let mut t = Toggles::optimized();
            t.quant = false;
            t
        }),
        ("fused int8 (Intel TF + INC)", Toggles::optimized()),
    ];

    let mut table = Table::new(&["configuration", "fps", "ai %", "recall", "db bytes"]);
    let mut first_fps = None;
    for (label, toggles) in configs {
        let cfg = RunConfig { toggles: *toggles, scale, seed: 3, ..Default::default() };
        let res = video_streamer::run(&cfg)?;
        let fps = res.metric("fps").unwrap();
        first_fps.get_or_insert(fps);
        let (_, ai) = res.report.fig1_split();
        table.row(&[
            format!("{label} ({})", fmt::speedup(fps / first_fps.unwrap())),
            format!("{fps:.1}"),
            format!("{ai:.1}%"),
            format!("{:.2}", res.metric("truth_recall").unwrap_or(f64::NAN)),
            fmt::count(res.metric("db_bytes").unwrap_or(0.0)),
        ]);
    }
    table.print();

    println!("\nstage breakdown (fused int8):");
    let res = video_streamer::run(&RunConfig {
        toggles: Toggles::optimized(),
        scale,
        seed: 3,
        ..Default::default()
    })?;
    res.report.table().print();
    Ok(())
}
