//! TCP serving quickstart: start a `PipelineServer` on a loopback
//! ephemeral port, talk to it over a real socket with a `ServeClient`
//! (handshake → typed request → stats → graceful drain), then push a
//! small closed-loop fleet through `run_load` and print both sides of
//! the ledger.
//!
//! ```sh
//! cargo run --example tcp_serving
//! ```

use repro::net::wire::WirePayload;
use repro::net::{run_load, LoadSpec, PipelineServer, ServeClient, ServerConfig};
use repro::net::{Frame, ShedCause};
use repro::pipelines::{RunConfig, Toggles};
use repro::service::{PipelineService, Priority, ServiceConfig};
use std::sync::Arc;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let defaults = RunConfig {
        toggles: Toggles::optimized(),
        scale: 0.1,
        seed: 0x5EED,
        ..Default::default()
    };
    let svc = Arc::new(PipelineService::open(
        &["census", "iiot"],
        ServiceConfig { defaults, queue_depth: 16, workers: 2, ..Default::default() },
    )?);
    // A tight tenant lane (depth 2) so the burst below shows first-class
    // shedding on the wire.
    let server = PipelineServer::start(
        Arc::clone(&svc),
        "127.0.0.1:0",
        ServerConfig { per_tenant_depth: 2, ..Default::default() },
    )?;
    println!("serving census, iiot at {}", server.local_addr());

    // --- One hand-rolled conversation -----------------------------------
    let mut client = ServeClient::connect(server.local_addr(), "demo")?;
    println!("handshake ok; server advertises {:?}", client.pipelines());
    match client.call("census", Priority::Normal, Some(Duration::from_secs(30)),
        WirePayload::Synthetic)?
    {
        Frame::Completed(c) => println!(
            "census completed: {} ({} items, queued {}us, ran {}us)",
            c.summary, c.items, c.queue_wait_us, c.service_us
        ),
        Frame::Shed { cause, .. } => println!("census shed: {cause}"),
        Frame::Failed { error, .. } => println!("census failed: {error}"),
        other => anyhow::bail!("unexpected {}", other.kind()),
    }
    // Burst past the lane depth: whatever overruns the depth-2 lane
    // sheds with a first-class TenantLaneFull frame — never a dropped
    // connection. Every request resolves exactly once.
    let burst = 5;
    for _ in 0..burst {
        client.send("iiot", Priority::Low, None, WirePayload::Synthetic)?;
    }
    for _ in 0..burst {
        match client.recv()? {
            Frame::Completed(c) => println!("iiot completed: {}", c.summary),
            Frame::Shed { cause, .. } => {
                debug_assert_eq!(cause, ShedCause::TenantLaneFull);
                println!("iiot shed: {cause}");
            }
            Frame::Failed { error, .. } => println!("iiot failed: {error}"),
            other => anyhow::bail!("unexpected {}", other.kind()),
        }
    }
    let (completed, shed, failed, by_cause) = client.drain()?;
    println!("goodbye ledger: completed {completed} shed {shed} failed {failed}");
    for cause in ShedCause::ALL {
        if by_cause[cause.index()] > 0 {
            println!("  shed[{cause}] = {}", by_cause[cause.index()]);
        }
    }

    // --- A closed-loop fleet --------------------------------------------
    let spec = LoadSpec {
        clients: 2,
        requests: 6,
        mix: vec![("census".to_string(), 2), ("iiot".to_string(), 1)],
    };
    let load = run_load(server.local_addr(), &spec)?;
    for (tenant, t) in &load.per_tenant {
        println!(
            "{tenant:<8} {} requests, {} completed, {} shed (client side)",
            t.requests, t.completed, t.shed
        );
    }

    let report = server.drain();
    println!(
        "server drained: {} connections accepted == {} drained; ledger balanced: {}",
        report.accepted,
        report.drained,
        report.balanced()
    );
    Ok(())
}
