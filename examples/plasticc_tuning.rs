//! §3.3 parameter optimization on PLAsTiCC — the SigOpt experiment.
//!
//! The paper: "In the case of PLAsTiCC, 'accuracy' and 'timing' metrics
//! were optimized while the model hyperparameters (number of parallel
//! threads for XGBoost, number of trees, learning rate, max depth, L1/L2
//! normalization, etc.) were computed in order to achieve the objective."
//!
//! This example runs both searchers from `tune::` over the GBT
//! hyperparameters on the real PLAsTiCC-like workload: maximize training
//! throughput subject to AUC ≥ 0.95, then prints the trade-off frontier.
//!
//! ```sh
//! cargo run --release --example plasticc_tuning
//! ```

use repro::linalg::Matrix;
use repro::ml::{metrics, Gbt, GbtParams, TreeMethod};
use repro::pipelines::plasticc;
use repro::tune::{coordinate_descent, random_search, Eval, SearchSpace};
use repro::util::fmt::Table;
use repro::util::Rng;
use std::time::Instant;

/// Build the PLAsTiCC feature matrix once (preprocessing is not what we
/// are tuning here).
fn features() -> (Matrix, Vec<f64>, Matrix, Vec<f64>) {
    let (csv, labels) = plasticc::generate_csv(400, 40, 0x516);
    // Reuse the pipeline's own preprocessing via the dataframe engine.
    use repro::dataframe::{self as df, groupby::Agg, Engine, Expr};
    let frame = df::csv::read_str(&csv, Engine::Optimized).unwrap();
    let frame = df::ops::with_column(
        &frame,
        "snr",
        &Expr::col("flux").div(Expr::col("flux_err")),
        Engine::Optimized,
    )
    .unwrap();
    let g = df::groupby::groupby_agg(
        &frame,
        &["object_id"],
        &[
            ("flux", Agg::Mean),
            ("flux", Agg::Std),
            ("flux", Agg::Min),
            ("flux", Agg::Max),
            ("snr", Agg::Mean),
            ("snr", Agg::Std),
        ],
        Engine::Optimized,
    )
    .unwrap();
    let cols = ["flux_mean", "flux_std", "flux_min", "flux_max", "snr_mean", "snr_std"];
    let n = g.nrows();
    let mut x = Matrix::zeros(n, cols.len());
    for (j, c) in cols.iter().enumerate() {
        let v = g.f64s(c).unwrap();
        for i in 0..n {
            x.set(i, j, v[i]);
        }
    }
    let ids = g.i64s("object_id").unwrap();
    let y: Vec<f64> = ids.iter().map(|&i| labels[i as usize]).collect();
    // 75/25 split.
    let mut idx: Vec<usize> = (0..n).collect();
    Rng::new(9).shuffle(&mut idx);
    let (test_i, train_i) = idx.split_at(n / 4);
    let take = |rows: &[usize]| {
        let mut xm = Matrix::zeros(rows.len(), cols.len());
        let mut ym = Vec::new();
        for (r, &i) in rows.iter().enumerate() {
            for j in 0..cols.len() {
                xm.set(r, j, x.get(i, j));
            }
            ym.push(y[i]);
        }
        (xm, ym)
    };
    let (xt, yt) = take(train_i);
    let (xs, ys) = take(test_i);
    (xt, yt, xs, ys)
}

fn main() {
    let (x_train, y_train, x_test, y_test) = features();
    let space = SearchSpace::new()
        .param("n_trees", &[5.0, 10.0, 20.0, 40.0])
        .param("max_depth", &[2.0, 3.0, 4.0, 6.0])
        .param("learning_rate", &[0.1, 0.3, 0.5])
        .param("lambda", &[0.5, 1.0, 4.0])
        .param("max_bins", &[16.0, 64.0, 256.0]);
    println!(
        "PLAsTiCC hyperparameter tuning — {} configurations in the space\n",
        space.cardinality()
    );

    let evaluate = |cfg: &std::collections::HashMap<String, f64>| {
        let params = GbtParams {
            n_trees: cfg["n_trees"] as usize,
            max_depth: cfg["max_depth"] as usize,
            learning_rate: cfg["learning_rate"],
            lambda: cfg["lambda"],
            max_bins: cfg["max_bins"] as usize,
            method: TreeMethod::Hist,
            ..Default::default()
        };
        let t0 = Instant::now();
        let gbt = Gbt::fit(&x_train, &y_train, params);
        let fit_s = t0.elapsed().as_secs_f64();
        let auc = metrics::auc(&y_test, &gbt.predict_proba(&x_test));
        Eval { objective: x_train.rows as f64 / fit_s, constraint: auc }
    };

    let mut table = Table::new(&["searcher", "trials", "best config", "rows/s", "AUC"]);
    let rs = random_search(&space, 40, 0.95, 0x51607, evaluate);
    table.row(&[
        "random(40)".into(),
        rs.history.len().to_string(),
        format!(
            "trees={} depth={} lr={} λ={} bins={}",
            rs.best["n_trees"], rs.best["max_depth"], rs.best["learning_rate"],
            rs.best["lambda"], rs.best["max_bins"],
        ),
        format!("{:.0}", rs.best_eval.objective),
        format!("{:.3}", rs.best_eval.constraint),
    ]);
    let cd = coordinate_descent(&space, 2, 0.95, evaluate);
    table.row(&[
        "coord-descent(2 sweeps)".into(),
        cd.history.len().to_string(),
        format!(
            "trees={} depth={} lr={} λ={} bins={}",
            cd.best["n_trees"], cd.best["max_depth"], cd.best["learning_rate"],
            cd.best["lambda"], cd.best["max_bins"],
        ),
        format!("{:.0}", cd.best_eval.objective),
        format!("{:.3}", cd.best_eval.constraint),
    ]);
    table.print();

    // Trade-off frontier from the random-search history.
    println!("\naccuracy/throughput frontier (random-search samples):");
    let mut pts: Vec<(f64, f64)> = rs
        .history
        .iter()
        .map(|(_, e)| (e.constraint, e.objective))
        .collect();
    pts.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    let mut best_thr = 0.0;
    let mut frontier = Table::new(&["AUC ≥", "best rows/s"]);
    for (auc, thr) in pts {
        if thr > best_thr {
            best_thr = thr;
            frontier.row(&[format!("{auc:.3}"), format!("{thr:.0}")]);
        }
    }
    frontier.print();
}
