//! Multi-instance workload scaling (§3.4): N parallel anomaly-detection
//! camera streams on one box — the paper's "10 streams over 30 FPS on one
//! socket" experiment, scaled to this sandbox.
//!
//! Each instance runs its own stream of synthetic part images through a
//! shared [`ModelServer`]; the report shows aggregate throughput and
//! fairness across instances as the count sweeps 1→8.
//!
//! ```sh
//! cargo run --release --example multi_instance [-- --images 24]
//! ```

use repro::coordinator::run_instances;
use repro::media::{normalize, resize, ResizeFilter};
use repro::runtime::{ModelServer, Tensor};
use repro::util::cli::Args;
use repro::util::fmt::Table;
use repro::util::Rng;

const IMG: usize = 32;
const BATCH: usize = 4;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let images = args.get_parse("images", 24usize);

    let server = ModelServer::spawn(repro::runtime::default_artifacts_dir(), 64)?;
    server.client().warmup(&["resnet_features_fused_b4"])?;

    println!("multi-instance anomaly streams — {images} images per instance\n");
    let mut table =
        Table::new(&["instances", "aggregate img/s", "per-instance img/s", "fairness"]);
    for n in [1usize, 2, 4, 8] {
        let client = server.client();
        let report = run_instances(n, |instance| {
            let client = client.clone();
            let mut rng = Rng::new(0xCAFE + instance as u64);
            let mut done = 0usize;
            while done < images {
                // One batch of camera frames: generate → resize/normalize
                // → feature extraction (the per-stream serving loop).
                let mut data = Vec::with_capacity(BATCH * IMG * IMG * 3);
                for _ in 0..BATCH {
                    let part =
                        {
                    let defective = rng.chance(0.2);
                    repro::pipelines::anomaly::generate_part(&mut rng, defective)
                };
                    let mut small = resize(&part.img, IMG, IMG, ResizeFilter::Bilinear);
                    normalize(&mut small, [0.45; 3], [0.25; 3]);
                    data.extend_from_slice(&small.data);
                }
                let t = Tensor::f32(&[BATCH, IMG, IMG, 3], data);
                if client.run("resnet_features_fused_b4", vec![t]).is_err() {
                    break;
                }
                done += BATCH;
            }
            done
        });
        let agg = report.aggregate_throughput();
        table.row(&[
            n.to_string(),
            format!("{agg:.1}"),
            format!("{:.1}", agg / n as f64),
            format!("{:.2}", report.fairness()),
        ]);
    }
    table.print();
    println!(
        "\nnote: single-core sandbox — aggregate stays ~flat and fairness ~1.0;\n\
         on a 40-core Xeon the same harness scales instances linearly (§3.4)."
    );
    Ok(())
}
