//! DLSA serving walkthrough (§2.4 + §3.3): dynamic batching and the
//! (batch size × max wait) tuning the paper does with SigOpt.
//!
//! Documents arrive on a bounded queue; the [`DynamicBatcher`] groups them
//! and a BERT artifact scores each batch through the [`ModelServer`]. The
//! `tune::coordinate_descent` pass then searches the batching policy for
//! max throughput at a latency constraint — the paper's multi-objective
//! tuning story on real measurements.
//!
//! ```sh
//! cargo run --release --example dlsa_serving
//! ```

use repro::coordinator::{BatcherConfig, DynamicBatcher};
use repro::parallel::channel::bounded;
use repro::runtime::{ModelServer, Tensor};
use repro::text::{ReviewGenerator, TokenizerKind, Vocab, WordPiece};
use repro::tune::{coordinate_descent, Eval, SearchSpace};
use repro::util::fmt::Table;
use std::time::{Duration, Instant};

const SEQ: usize = 64;

/// Serve `n_docs` through a batcher with the given policy; returns
/// (throughput docs/s, p95 latency ms).
fn serve(
    client: &repro::runtime::ModelClient,
    tok: &WordPiece,
    n_docs: usize,
    cfg: BatcherConfig,
) -> anyhow::Result<(f64, f64)> {
    let mut gen = ReviewGenerator::new(99, 30);
    let docs = gen.batch(n_docs);
    let (tx, rx) = bounded::<(Vec<i64>, Instant)>(64);
    let mut batcher = DynamicBatcher::new(rx, cfg);

    // Producer: tokenize and enqueue (arrival process).
    let texts: Vec<String> = docs.into_iter().map(|r| r.text).collect();
    let encoded = tok.encode_batch(&texts, TokenizerKind::Optimized);
    let producer = std::thread::spawn(move || {
        for ids in encoded {
            if tx.send((ids, Instant::now())).is_err() {
                break;
            }
        }
    });

    // Consumer: batch → pad to the artifact batch (8) → infer.
    let t0 = Instant::now();
    let mut latencies: Vec<f64> = Vec::with_capacity(n_docs);
    while let Some(batch) = batcher.next_batch() {
        let mut ids: Vec<i32> = Vec::with_capacity(8 * SEQ);
        for (doc, _) in &batch {
            ids.extend(doc.iter().map(|&t| t as i32));
        }
        while ids.len() < 8 * SEQ {
            let start = ids.len() - SEQ;
            let last: Vec<i32> = ids[start..].to_vec();
            ids.extend(last);
        }
        client.run("bert_fused_b8", vec![Tensor::i32(&[8, SEQ], ids)])?;
        let done = Instant::now();
        for (_, arrived) in &batch {
            latencies.push((done - *arrived).as_secs_f64() * 1e3);
        }
    }
    producer.join().unwrap();
    let wall = t0.elapsed().as_secs_f64();
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p95 = latencies[(latencies.len() as f64 * 0.95) as usize % latencies.len()];
    Ok((n_docs as f64 / wall, p95))
}

fn main() -> anyhow::Result<()> {
    let server = ModelServer::spawn(repro::runtime::default_artifacts_dir(), 32)?;
    server.client().warmup(&["bert_fused_b8"])?;
    let tok = WordPiece::new(Vocab::build_from_corpus(&ReviewGenerator::lexicon(), 64), SEQ);
    let n_docs = 64;

    println!("dlsa serving — batching policy sweep ({n_docs} docs each)\n");
    let mut table = Table::new(&["max_batch", "max_wait", "docs/s", "p95 ms"]);
    for max_batch in [1usize, 4, 8] {
        for wait_ms in [1u64, 10] {
            let cfg = BatcherConfig {
                max_batch,
                max_wait: Duration::from_millis(wait_ms),
            };
            let (thr, p95) = serve(&server.client(), &tok, n_docs, cfg)?;
            table.row(&[
                max_batch.to_string(),
                format!("{wait_ms}ms"),
                format!("{thr:.1}"),
                format!("{p95:.1}"),
            ]);
        }
    }
    table.print();

    // SigOpt-style auto-tuning: maximize throughput s.t. p95 <= budget.
    println!("\nauto-tuning (coordinate descent, p95 <= 400ms):");
    let space = SearchSpace::new()
        .param("max_batch", &[1.0, 2.0, 4.0, 8.0])
        .param("max_wait_ms", &[1.0, 5.0, 10.0, 20.0]);
    let client = server.client();
    let result = coordinate_descent(&space, 1, -400.0, |cfg| {
        let bc = BatcherConfig {
            max_batch: cfg["max_batch"] as usize,
            max_wait: Duration::from_millis(cfg["max_wait_ms"] as u64),
        };
        match serve(&client, &tok, n_docs, bc) {
            Ok((thr, p95)) => Eval { objective: thr, constraint: -p95 },
            Err(_) => Eval { objective: 0.0, constraint: f64::NEG_INFINITY },
        }
    });
    println!(
        "best: max_batch={} max_wait={}ms → {:.1} docs/s (p95 {:.1}ms) over {} trials",
        result.best["max_batch"],
        result.best["max_wait_ms"],
        result.best_eval.objective,
        -result.best_eval.constraint,
        result.history.len()
    );
    Ok(())
}
