//! PipelineService quickstart: open warm sessions for three pipelines,
//! push a mixed-priority burst through a small admission queue, and read
//! back typed responses plus the service's latency percentiles.
//!
//! ```sh
//! cargo run --example pipeline_service
//! ```

use repro::pipelines::{RunConfig, Toggles};
use repro::service::{PipelineService, Priority, Request, Response, ServiceConfig};

fn main() -> anyhow::Result<()> {
    let defaults = RunConfig {
        toggles: Toggles::optimized(),
        scale: 0.1,
        seed: 0x5EED,
        ..Default::default()
    };
    // A deliberately tight queue so the burst below exercises shedding.
    let svc = PipelineService::open(
        &["census", "plasticc", "iiot"],
        ServiceConfig { defaults, queue_depth: 4, workers: 2, ..Default::default() },
    )?;

    let names = ["census", "plasticc", "iiot"];
    let priorities = [Priority::Normal, Priority::High, Priority::Low];
    let tickets: Vec<_> = (0..9)
        .map(|i| {
            svc.submit(
                Request::synthetic(names[i % names.len()])
                    .with_priority(priorities[i % priorities.len()]),
            )
        })
        .collect::<anyhow::Result<_>>()?;

    for ticket in tickets {
        match ticket.wait() {
            Response::Completed(c) => println!(
                "{:<9} {:<6} {}  (queued {:?}, ran {:?})",
                c.pipeline,
                c.priority.label(),
                c.output.summary(),
                c.queue_wait,
                c.service_time
            ),
            Response::Shed { pipeline, priority, reason, .. } => {
                println!("{pipeline:<9} {priority:<6} shed ({})", reason.label())
            }
            Response::Failed { pipeline, error } => {
                println!("{pipeline:<9} FAILED: {error}")
            }
        }
    }

    let stats = svc.stats();
    let report = svc.scaling_report();
    println!(
        "\ncompleted {} shed {} failed {};  request latency p50 {:?} p95 {:?}",
        stats.completed,
        stats.shed,
        stats.failed,
        report.latency_p50(),
        report.latency_p95()
    );
    Ok(())
}
