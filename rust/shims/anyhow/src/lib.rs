//! Offline shim of the `anyhow` crate: the subset this repository uses
//! (`anyhow::Result`, `anyhow::Error`, `anyhow!`, `bail!`, `ensure!`,
//! blanket `From<E: std::error::Error>`), implemented from `std` so the
//! default build needs no crates.io access. The API mirrors the real
//! crate, so swapping the path dependency for upstream `anyhow` is a
//! one-line `Cargo.toml` change.

use std::error::Error as StdError;
use std::fmt;

/// A type-erased, send-able error, like `anyhow::Error`.
pub struct Error {
    inner: Box<dyn StdError + Send + Sync + 'static>,
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

struct MessageError(String);

impl fmt::Debug for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Display for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl StdError for MessageError {}

impl Error {
    /// Create an error from a display-able message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { inner: Box::new(MessageError(message.to_string())) }
    }

    /// Wrap a concrete error value.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Error {
        Error { inner: Box::new(error) }
    }

    /// The lowest-level source in the chain.
    pub fn root_cause(&self) -> &(dyn StdError + 'static) {
        let mut cur: &(dyn StdError + 'static) = self.inner.as_ref();
        while let Some(next) = cur.source() {
            cur = next;
        }
        cur
    }

    /// Downcast reference, mirroring `anyhow::Error::downcast_ref`.
    pub fn downcast_ref<E: StdError + 'static>(&self) -> Option<&E> {
        self.inner.downcast_ref::<E>()
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.inner)?;
        if f.alternate() {
            let mut cur: &(dyn StdError + 'static) = self.inner.as_ref();
            while let Some(next) = cur.source() {
                write!(f, ": {next}")?;
                cur = next;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.inner)?;
        let mut cur: &(dyn StdError + 'static) = self.inner.as_ref();
        while let Some(next) = cur.source() {
            write!(f, "\n\nCaused by:\n    {next}")?;
            cur = next;
        }
        Ok(())
    }
}

// Like upstream anyhow, `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket impl coherent.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Error {
        Error { inner: Box::new(error) }
    }
}

/// Construct an [`Error`] from a format string or error value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails(flag: bool) -> Result<u32> {
        ensure!(flag, "flag was {flag}");
        Ok(7)
    }

    #[test]
    fn macros_build_errors() {
        let e = anyhow!("x = {}", 3);
        assert_eq!(e.to_string(), "x = 3");
        assert_eq!(fails(true).unwrap(), 7);
        assert_eq!(fails(false).unwrap_err().to_string(), "flag was false");
    }

    #[test]
    fn from_std_error_via_question_mark() {
        fn parse(s: &str) -> Result<i32> {
            let v: i32 = s.parse()?;
            Ok(v)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
    }

    #[test]
    fn alternate_display_prints_chain() {
        let io = std::io::Error::new(std::io::ErrorKind::Other, "inner");
        let e = Error::new(io);
        assert!(format!("{e:#}").contains("inner"));
        assert_eq!(e.root_cause().to_string(), "inner");
    }

    #[test]
    fn error_is_send_and_sync() {
        fn check<T: Send + Sync>() {}
        check::<Error>();
    }
}
