//! Offline stub of the `xla` crate (PJRT bindings), covering exactly the
//! API surface `repro::runtime` touches.
//!
//! Host-side plumbing — [`Literal`] construction, reshape, dtype/shape
//! inspection, typed extraction — is fully implemented, so tensor
//! round-trips work without any native library. The device path
//! ([`PjRtClient::compile`] / [`PjRtLoadedExecutable::execute`]) returns
//! [`Error::Unavailable`]: executing AOT artifacts requires swapping this
//! path dependency for the real `xla` crate (0.1.6, xla_extension 0.5.1),
//! which is API-compatible with everything stubbed here. Callers already
//! gate on the artifacts manifest being present, so the default offline
//! build and test run never reach the stubbed entry points.

use std::fmt;
use std::path::Path;

/// Stub error type mirroring the variants the runtime matches on.
#[derive(Debug)]
pub enum Error {
    /// A literal held a dtype outside the supported set.
    UnexpectedElementType(i32),
    /// Shape/element-count mismatch in host-side literal plumbing.
    Shape(String),
    /// Artifact file problems.
    Io(String),
    /// The PJRT device path, which the offline stub does not provide.
    Unavailable(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::UnexpectedElementType(t) => write!(f, "unexpected element type {t}"),
            Error::Shape(m) => write!(f, "shape error: {m}"),
            Error::Io(m) => write!(f, "io error: {m}"),
            Error::Unavailable(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for Error {}

fn unavailable<T>() -> Result<T, Error> {
    Err(Error::Unavailable(
        "PJRT execution is unavailable in the offline xla stub; build with the real \
         `xla` crate to compile and run AOT artifacts"
            .to_string(),
    ))
}

/// Element dtypes the runtime exchanges at model boundaries.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S8,
    S32,
    S64,
    F32,
    F64,
}

#[derive(Debug, Clone, PartialEq)]
enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Data {
    fn len(&self) -> usize {
        match self {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
        }
    }

    fn ty(&self) -> ElementType {
        match self {
            Data::F32(_) => ElementType::F32,
            Data::I32(_) => ElementType::S32,
        }
    }
}

/// Dtypes that can cross the host boundary (`f32`, `i32`).
pub trait NativeType: Copy {
    #[doc(hidden)]
    fn wrap(v: Vec<Self>) -> Data;
    #[doc(hidden)]
    fn unwrap(d: &Data) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(v: Vec<f32>) -> Data {
        Data::F32(v)
    }
    fn unwrap(d: &Data) -> Option<Vec<f32>> {
        match d {
            Data::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(v: Vec<i32>) -> Data {
        Data::I32(v)
    }
    fn unwrap(d: &Data) -> Option<Vec<i32>> {
        match d {
            Data::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// Dimensions of an array-shaped literal.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    /// Dimension extents.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// A host tensor literal (the real crate's device-transferable value).
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    dims: Vec<i64>,
    data: Data,
}

impl Literal {
    /// Rank-1 literal from a typed slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal { dims: vec![v.len() as i64], data: T::wrap(v.to_vec()) }
    }

    /// Same data, new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, Error> {
        let want: i64 = dims.iter().product();
        if want as usize != self.data.len() {
            return Err(Error::Shape(format!(
                "cannot reshape {} elements to {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal { dims: dims.to_vec(), data: self.data.clone() })
    }

    /// Array shape (all stub literals are arrays, never tuples).
    pub fn array_shape(&self) -> Result<ArrayShape, Error> {
        Ok(ArrayShape { dims: self.dims.clone() })
    }

    /// Element dtype.
    pub fn ty(&self) -> Result<ElementType, Error> {
        Ok(self.data.ty())
    }

    /// Typed copy of the elements.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        T::unwrap(&self.data).ok_or(Error::UnexpectedElementType(self.data.ty() as i32))
    }

    /// Destructure a tuple literal. Stub literals are always arrays, and
    /// tuple outputs only arise from device execution — unreachable here.
    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        unavailable()
    }
}

/// Stub PJRT client: constructible, but cannot compile.
pub struct PjRtClient;

impl PjRtClient {
    /// CPU client handle (host-only in the stub).
    pub fn cpu() -> Result<PjRtClient, Error> {
        Ok(PjRtClient)
    }

    /// Compilation needs the native PJRT runtime → [`Error::Unavailable`].
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unavailable()
    }
}

/// Parsed HLO module handle.
pub struct HloModuleProto;

impl HloModuleProto {
    /// Validate the artifact exists; parsing happens at compile time in
    /// the real crate, which the stub cannot reach anyway.
    pub fn from_text_file(path: &str) -> Result<HloModuleProto, Error> {
        if Path::new(path).is_file() {
            Ok(HloModuleProto)
        } else {
            Err(Error::Io(format!("no such HLO artifact: {path}")))
        }
    }
}

/// Computation wrapper.
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Compiled executable (never actually constructed by the stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Device execution → [`Error::Unavailable`].
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable()
    }
}

/// Device buffer (never actually constructed by the stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Host transfer → [`Error::Unavailable`].
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_reshape_round_trip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        assert_eq!(l.array_shape().unwrap().dims(), &[2, 2]);
        assert_eq!(l.ty().unwrap(), ElementType::F32);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn reshape_checks_element_count() {
        assert!(Literal::vec1(&[1i32, 2, 3]).reshape(&[2, 2]).is_err());
    }

    #[test]
    fn device_path_reports_unavailable() {
        let client = PjRtClient::cpu().unwrap();
        let err = client.compile(&XlaComputation).unwrap_err();
        assert!(err.to_string().contains("offline xla stub"), "{err}");
    }

    #[test]
    fn missing_artifact_is_io_error() {
        assert!(HloModuleProto::from_text_file("/nonexistent/model.hlo.txt").is_err());
    }
}
