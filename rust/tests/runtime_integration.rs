//! Integration: the AOT runtime end-to-end — every artifact in the
//! manifest compiles and executes with manifest-conforming inputs, the
//! fused/unfused/int8 variants agree numerically, and the model server
//! survives concurrent load and failure injection.

use repro::runtime::{Engine, ModelServer, Tensor, TensorSpec};
use repro::util::Rng;

fn artifacts_ready() -> bool {
    repro::runtime::default_artifacts_dir().join("manifest.json").exists()
}

fn make_input(spec: &TensorSpec, rng: &mut Rng) -> Tensor {
    match spec.dtype.as_str() {
        "float32" => Tensor::f32(
            &spec.shape,
            (0..spec.numel()).map(|_| rng.normal() as f32 * 0.5).collect(),
        ),
        "int32" => Tensor::i32(
            &spec.shape,
            (0..spec.numel()).map(|_| rng.below(512) as i32).collect(),
        ),
        other => panic!("unexpected input dtype {other}"),
    }
}

#[test]
fn every_artifact_compiles_and_runs() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let engine = Engine::local().unwrap();
    let mut rng = Rng::new(0xA11);
    let names = engine.model_names();
    assert!(names.len() >= 20, "expected the full artifact set, got {}", names.len());
    for name in names {
        let spec = engine.manifest().model(&name).unwrap().clone();
        let inputs: Vec<Tensor> =
            spec.inputs.iter().map(|s| make_input(s, &mut rng)).collect();
        let out = engine
            .run(&name, &inputs)
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(out.len(), spec.outputs.len(), "{name}: output arity");
        for (t, s) in out.iter().zip(&spec.outputs) {
            assert_eq!(t.shape(), s.shape.as_slice(), "{name}: output shape");
            if let Some(v) = t.as_f32() {
                assert!(v.iter().all(|x| x.is_finite()), "{name}: non-finite output");
                assert!(v.iter().any(|x| *x != 0.0), "{name}: all-zero output (elided constants?)");
            }
        }
    }
}

#[test]
fn all_stage_chains_execute() {
    if !artifacts_ready() {
        return;
    }
    let engine = Engine::local().unwrap();
    let mut rng = Rng::new(0xC4A);
    let chains: Vec<String> = engine.manifest().stage_chains.keys().cloned().collect();
    assert!(!chains.is_empty());
    for chain in chains {
        let first = engine.manifest().stage_chains[&chain][0].clone();
        let spec = engine.manifest().model(&first).unwrap().clone();
        let inputs: Vec<Tensor> =
            spec.inputs.iter().map(|s| make_input(s, &mut rng)).collect();
        let out = engine.run_chain(&chain, &inputs).unwrap();
        assert!(!out.is_empty(), "{chain}");
    }
}

#[test]
fn int8_tracks_fp32_within_tolerance() {
    if !artifacts_ready() {
        return;
    }
    let engine = Engine::local().unwrap();
    let mut rng = Rng::new(0x117);
    let spec = engine.manifest().model("bert_fused_b8").unwrap().clone();
    let ids = make_input(&spec.inputs[0], &mut rng);
    let fp32 = engine.run("bert_fused_b8", &[ids.clone()]).unwrap();
    let int8 = engine.run("bert_int8_b8", &[ids]).unwrap();
    let a = fp32[0].as_f32().unwrap();
    let b = int8[0].as_f32().unwrap();
    // Logits track within a coarse absolute band (int8 epilogues).
    let max_diff = a
        .iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1.0, "int8 drift {max_diff}");
}

#[test]
fn server_handles_concurrent_mixed_workloads() {
    if !artifacts_ready() {
        return;
    }
    let server = ModelServer::spawn(repro::runtime::default_artifacts_dir(), 8).unwrap();
    server.client().warmup(&["ssd_fused_b1", "dien_fused_b16"]).unwrap();
    let mut handles = Vec::new();
    for i in 0..4 {
        let client = server.client();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(0x5E2 + i);
            for _ in 0..5 {
                if i % 2 == 0 {
                    let img = Tensor::f32(
                        &[1, 32, 32, 3],
                        (0..32 * 32 * 3).map(|_| rng.f32()).collect(),
                    );
                    client.run("ssd_fused_b1", vec![img]).unwrap();
                } else {
                    let hist = Tensor::i32(
                        &[16, 10],
                        (0..160).map(|_| rng.below(1024) as i32).collect(),
                    );
                    let cand =
                        Tensor::i32(&[16], (0..16).map(|_| rng.below(1024) as i32).collect());
                    client.run("dien_fused_b16", vec![hist, cand]).unwrap();
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
}

#[test]
fn shape_validation_rejects_before_pjrt() {
    if !artifacts_ready() {
        return;
    }
    let engine = Engine::local().unwrap();
    // Wrong rank.
    let bad = Tensor::f32(&[32, 32, 3], vec![0.0; 32 * 32 * 3]);
    assert!(engine.run("ssd_fused_b1", &[bad]).is_err());
    // Wrong dtype.
    let bad = Tensor::i32(&[1, 32, 32, 3], vec![0; 32 * 32 * 3]);
    assert!(engine.run("ssd_fused_b1", &[bad]).is_err());
    // Wrong arity.
    assert!(engine.run("ssd_fused_b1", &[]).is_err());
}
