//! Executor-equivalence suite: the plan layer's core guarantee is that a
//! pipeline's *results* are a property of its plan, not of the executor
//! that ran it. For a fixed seed, every registry pipeline must produce
//! identical deterministic metrics under Sequential, Streaming, and
//! MultiInstance(n=1) execution — batch boundaries, thread scheduling,
//! and queue sizes may differ; answers may not.
//!
//! Pipelines that execute model artifacts are skipped when `make
//! artifacts` has not produced a manifest (the tabular three always run).

use repro::coordinator::ExecMode;
use repro::pipelines::{registry, run_by_name, RunConfig, Toggles};

fn artifacts_ready() -> bool {
    repro::runtime::default_artifacts_dir().join("manifest.json").exists()
}

fn needs_artifacts(name: &str) -> bool {
    !matches!(name, "census" | "plasticc" | "iiot")
}

/// Wall-clock-valued metrics, excluded from cross-executor equality.
const TIMING_METRICS: &[&str] = &["fps"];

fn base_cfg() -> RunConfig {
    RunConfig { toggles: Toggles::optimized(), scale: 0.1, seed: 0xE9, ..Default::default() }
}

#[test]
fn all_executors_produce_identical_metrics() {
    for e in registry() {
        if needs_artifacts(e.name) && !artifacts_ready() {
            eprintln!("skipping {} (no artifacts)", e.name);
            continue;
        }
        let mut cfg = base_cfg();
        cfg.exec = ExecMode::Sequential;
        let seq = (e.run)(&cfg).unwrap_or_else(|err| panic!("{} sequential: {err:#}", e.name));
        cfg.exec = ExecMode::Streaming;
        let stream = (e.run)(&cfg).unwrap_or_else(|err| panic!("{} streaming: {err:#}", e.name));
        cfg.exec = ExecMode::MultiInstance(1);
        let multi = (e.run)(&cfg).unwrap_or_else(|err| panic!("{} multi(1): {err:#}", e.name));

        for (mode, other) in [("streaming", &stream), ("multi:1", &multi)] {
            assert_eq!(seq.items, other.items, "{} items differ under {mode}", e.name);
            let keys: Vec<&String> = seq.metrics.keys().collect();
            let other_keys: Vec<&String> = other.metrics.keys().collect();
            assert_eq!(keys, other_keys, "{} metric keys differ under {mode}", e.name);
            for (k, v) in &seq.metrics {
                if TIMING_METRICS.contains(&k.as_str()) {
                    continue;
                }
                let w = other.metric(k).unwrap();
                assert!(
                    (v - w).abs() < 1e-12,
                    "{}.{k} differs under {mode}: {v} vs {w}",
                    e.name
                );
            }
        }
    }
}

#[test]
fn all_executors_visit_the_same_stages() {
    for e in registry() {
        if needs_artifacts(e.name) && !artifacts_ready() {
            continue;
        }
        let mut cfg = base_cfg();
        let stage_names = |res: &repro::pipelines::PipelineResult| -> Vec<String> {
            res.report.stages.iter().map(|s| s.name.clone()).collect()
        };
        cfg.exec = ExecMode::Sequential;
        let seq = stage_names(&(e.run)(&cfg).unwrap());
        cfg.exec = ExecMode::Streaming;
        let stream_res = (e.run)(&cfg).unwrap();
        let stream = stage_names(&stream_res);
        cfg.exec = ExecMode::MultiInstance(1);
        let multi = stage_names(&(e.run)(&cfg).unwrap());
        assert_eq!(seq, stream, "{}", e.name);
        assert_eq!(seq, multi, "{}", e.name);
        // Every stage was visited under the streaming executor too.
        for s in &stream_res.report.stages {
            assert!(s.items > 0, "{}: stage {} idle under streaming", e.name, s.name);
        }
    }
}

#[test]
fn multi_instance_scales_items_and_reports_scaling_metrics() {
    // Tabular pipelines need no artifacts; each replica processes its own
    // stream, so items sum across instances.
    for name in ["census", "plasticc", "iiot"] {
        let mut cfg = base_cfg();
        cfg.exec = ExecMode::Sequential;
        let seq = run_by_name(name, &cfg).unwrap();
        cfg.exec = ExecMode::MultiInstance(2);
        let multi = run_by_name(name, &cfg).unwrap();
        assert_eq!(multi.items, 2 * seq.items, "{name}");
        assert_eq!(multi.metric("scaling_instances"), Some(2.0), "{name}");
        let fairness = multi.metric("scaling_fairness").unwrap();
        assert!((0.0..=1.0).contains(&fairness), "{name}: fairness {fairness}");
        assert!(multi.metric("scaling_throughput").unwrap() > 0.0, "{name}");
        let p50 = multi.metric("scaling_latency_p50_ms").unwrap();
        let p95 = multi.metric("scaling_latency_p95_ms").unwrap();
        assert!(p95 >= p50, "{name}: p95 {p95} < p50 {p50}");
        // Single-instance runs must NOT carry scaling metrics (so n=1 is
        // bit-identical to sequential).
        assert!(seq.metric("scaling_instances").is_none(), "{name}");
    }
}

#[test]
fn multi_instance_replicas_get_distinct_seeds() {
    // Instance i runs seed+i: census R² is seed-dependent noise-wise but
    // metrics come from instance 0, which must match the sequential run
    // at the same seed.
    let mut cfg = base_cfg();
    cfg.exec = ExecMode::Sequential;
    let seq = run_by_name("census", &cfg).unwrap();
    cfg.exec = ExecMode::MultiInstance(3);
    let multi = run_by_name("census", &cfg).unwrap();
    assert!(
        (seq.metric("r2").unwrap() - multi.metric("r2").unwrap()).abs() < 1e-12,
        "instance 0 must use the base seed"
    );
}

#[test]
fn streaming_is_deterministic_across_repeats() {
    for name in ["census", "iiot"] {
        let mut cfg = base_cfg();
        cfg.exec = ExecMode::Streaming;
        let a = run_by_name(name, &cfg).unwrap();
        let b = run_by_name(name, &cfg).unwrap();
        for (k, v) in &a.metrics {
            if TIMING_METRICS.contains(&k.as_str()) {
                continue;
            }
            let w = b.metric(k).unwrap();
            assert!((v - w).abs() < 1e-12, "{name}.{k}: {v} vs {w}");
        }
    }
}
