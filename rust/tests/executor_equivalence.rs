//! Executor-conformance suite: the plan layer's core guarantee is that a
//! pipeline's *results* are a property of its plan, not of the executor
//! that ran it. For a fixed seed, every registry pipeline must produce
//! identical deterministic metrics under Sequential, Streaming,
//! MultiInstance(n=1), Sharded(1..=4), and Async(1..=3) execution —
//! batch boundaries, thread scheduling, queue sizes, task interleavings,
//! and shard partitions may differ; answers may not. Sharded runs
//! additionally pin the merge-aware sink contract: one latency sample
//! per item completing the sink, pooled across shards, with p50 ≤ p95
//! and partitions that exactly cover the source stream. The async ×
//! sharded composition (shard passes + streaming merge as cooperative
//! tasks) is pinned both threaded (`run_sharded_async`) and under
//! seeded single-threaded interleavings (`run_sharded_seeded`), where
//! the merge-streaming counter is asserted deterministically — via
//! scheduler counters, never timing.
//!
//! Since the compile/bind split, the matrix runs twice: once through
//! the one-shot plan builders (every pipeline's `e.run` now compiles +
//! binds per call, with sharded runs binding pre-sliced payloads) and
//! once through an explicitly REUSED `CompiledPlan` — one graph build
//! serving the whole executor ladder plus repeat binds, pinned
//! metric-identical to the seed Sequential run with `BindReport`
//! counting exactly one compile. Payload-aware sliced sharding is
//! additionally pinned bit-identical to the clone-based
//! `Plan::shard` path for all eight pipelines.
//!
//! The columnar batch data plane adds a third pass for the tabular
//! three: every executor in the ladder re-runs with `batch_rows = 64`,
//! pinned metric-identical to the per-item Sequential run with a
//! balanced `BatchReport` ledger (amortization asserted from counters,
//! never wall-clock).
//!
//! Pipelines that execute model artifacts are skipped when `make
//! artifacts` has not produced a manifest (the tabular three always run).

use repro::coordinator::{exec, ExecMode, Sharder};
use repro::pipelines::{
    compile_entry, registry, run_by_name, run_compiled, run_plan_with, PipelineResult,
    RunConfig, Toggles,
};

fn artifacts_ready() -> bool {
    repro::runtime::default_artifacts_dir().join("manifest.json").exists()
}

fn needs_artifacts(name: &str) -> bool {
    !matches!(name, "census" | "plasticc" | "iiot")
}

/// Wall-clock-valued metrics, excluded from cross-executor equality.
const TIMING_METRICS: &[&str] = &["fps"];

fn base_cfg() -> RunConfig {
    RunConfig { toggles: Toggles::optimized(), scale: 0.1, seed: 0xE9, ..Default::default() }
}

/// Every non-sequential mode whose answers must equal Sequential's:
/// Streaming, MultiInstance(1), the full Sharded(1..=4) ladder, and the
/// Async(1..=3) pool ladder.
fn conformance_modes() -> Vec<ExecMode> {
    let mut modes = vec![ExecMode::Streaming, ExecMode::MultiInstance(1)];
    modes.extend((1..=4).map(ExecMode::Sharded));
    modes.extend((1..=3).map(ExecMode::Async));
    modes
}

fn assert_metrics_match(name: &str, mode: ExecMode, seq: &PipelineResult, other: &PipelineResult) {
    assert_eq!(seq.items, other.items, "{name} items differ under {mode}");
    let keys: Vec<&String> = seq.metrics.keys().collect();
    let other_keys: Vec<&String> = other.metrics.keys().collect();
    assert_eq!(keys, other_keys, "{name} metric keys differ under {mode}");
    for (k, v) in &seq.metrics {
        if TIMING_METRICS.contains(&k.as_str()) {
            continue;
        }
        let w = other.metric(k).unwrap();
        assert!((v - w).abs() < 1e-12, "{name}.{k} differs under {mode}: {v} vs {w}");
    }
}

#[test]
fn all_executors_produce_identical_metrics() {
    for e in registry() {
        if needs_artifacts(e.name) && !artifacts_ready() {
            eprintln!("skipping {} (no artifacts)", e.name);
            continue;
        }
        let mut cfg = base_cfg();
        cfg.exec = ExecMode::Sequential;
        let seq = (e.run)(&cfg).unwrap_or_else(|err| panic!("{} sequential: {err:#}", e.name));
        for mode in conformance_modes() {
            cfg.exec = mode;
            let other =
                (e.run)(&cfg).unwrap_or_else(|err| panic!("{} {mode}: {err:#}", e.name));
            assert_metrics_match(e.name, mode, &seq, &other);
        }
    }
}

#[test]
fn batched_data_plane_is_executor_invariant_for_tabular_pipelines() {
    // The columnar data plane's acceptance matrix: for the tabular
    // three, a batched run (batch_rows = 64) answers exactly like the
    // per-item Sequential run under EVERY executor in the conformance
    // ladder, and each batched run's ledger balances (rows in == rows
    // out + rows filtered) with at least one byte shared zero-copy —
    // amortization asserted from counters, never wall-clock.
    for name in ["census", "plasticc", "iiot"] {
        let mut cfg = base_cfg();
        cfg.exec = ExecMode::Sequential;
        let per_item = run_by_name(name, &cfg).unwrap();
        assert!(per_item.batching.is_none(), "{name}: per-item run must not report batches");
        cfg.batch_rows = 64;
        let mut modes = vec![ExecMode::Sequential];
        modes.extend(conformance_modes());
        for mode in modes {
            cfg.exec = mode;
            let batched = run_by_name(name, &cfg)
                .unwrap_or_else(|err| panic!("{name} batched {mode}: {err:#}"));
            assert_metrics_match(name, mode, &per_item, &batched);
            let b = batched
                .batching
                .unwrap_or_else(|| panic!("{name} {mode}: batched run must report counters"));
            assert!(b.batches > 1, "{name} {mode}: {b:?}");
            assert!(b.balanced(), "{name} {mode}: rows unbalanced: {b:?}");
            assert!(b.clone_avoided_bytes > 0, "{name} {mode}: {b:?}");
            assert!(b.mean_rows() <= 64.0 + 1e-9, "{name} {mode}: {b:?}");
        }
    }
}

#[test]
fn compiled_plan_conformance_matrix_and_reuse() {
    // The tentpole acceptance matrix: for every runnable pipeline, ONE
    // CompiledPlan serves the full conformance ladder — Sequential /
    // Streaming / MultiInstance(1) / Sharded(1..=4, payload-aware
    // slicing) / Async(1..=3) — all through CompiledPlan::bind, with
    // metrics identical to the seed Sequential run; and binding the
    // same graph repeatedly (3× sequential) never moves a metric while
    // the BindReport counts exactly one compile.
    for e in registry() {
        if needs_artifacts(e.name) && !artifacts_ready() {
            eprintln!("skipping {} (no artifacts)", e.name);
            continue;
        }
        let mut cfg = base_cfg();
        cfg.exec = ExecMode::Sequential;
        let compiled = compile_entry(e, &cfg).unwrap();
        let seq = run_compiled(e, &compiled, repro::pipelines::Workload::Synthetic, &cfg)
            .unwrap_or_else(|err| panic!("{} compiled sequential: {err:#}", e.name));
        // Reuse pin: the same compiled graph bound and executed twice
        // more answers identically.
        for round in 0..2 {
            let again =
                run_compiled(e, &compiled, repro::pipelines::Workload::Synthetic, &cfg)
                    .unwrap();
            assert_eq!(again.items, seq.items, "{} reuse round {round}", e.name);
            for (k, v) in &seq.metrics {
                if TIMING_METRICS.contains(&k.as_str()) {
                    continue;
                }
                let w = again.metric(k).unwrap();
                assert!(
                    (v - w).abs() < 1e-12,
                    "{}.{k} drifted on reuse round {round}: {v} vs {w}",
                    e.name
                );
            }
        }
        for mode in conformance_modes() {
            cfg.exec = mode;
            let other =
                run_compiled(e, &compiled, repro::pipelines::Workload::Synthetic, &cfg)
                    .unwrap_or_else(|err| panic!("{} compiled {mode}: {err:#}", e.name));
            assert_metrics_match(e.name, mode, &seq, &other);
        }
        let br = compiled.bind_report();
        assert_eq!(br.compiles, 1, "{}: one graph build for the whole matrix", e.name);
        assert!(br.binds >= 3 + conformance_modes().len(), "{}: {br:?}", e.name);
    }
}

/// Bit-identical equality for same-mode optimized-vs-unoptimized pairs:
/// unlike the cross-executor tolerance above, rewritten plans replay the
/// exact same arithmetic in the exact same order, so every non-timing
/// metric must match to the last bit (`f64::to_bits`), not within 1e-12.
fn assert_bit_identical(
    name: &str,
    mode: ExecMode,
    batch_rows: usize,
    base: &PipelineResult,
    opt: &PipelineResult,
) {
    assert_eq!(
        base.items, opt.items,
        "{name} items differ optimized vs not under {mode} (batch_rows={batch_rows})"
    );
    let keys: Vec<&String> = base.metrics.keys().collect();
    let opt_keys: Vec<&String> = opt.metrics.keys().collect();
    assert_eq!(
        keys, opt_keys,
        "{name} metric keys differ optimized vs not under {mode} (batch_rows={batch_rows})"
    );
    for (k, v) in &base.metrics {
        if TIMING_METRICS.contains(&k.as_str()) {
            continue;
        }
        let w = opt.metric(k).unwrap();
        assert_eq!(
            v.to_bits(),
            w.to_bits(),
            "{name}.{k} not bit-identical under {mode} (batch_rows={batch_rows}): {v} vs {w}"
        );
    }
}

#[test]
fn optimized_plans_are_bit_identical_to_unoptimized_across_the_ladder() {
    // The optimizer's acceptance matrix: for every runnable pipeline,
    // the rewritten CompiledPlan answers BIT-identically to the
    // untouched one under the entire executor ladder — Sequential /
    // Streaming / MultiInstance(1) / Sharded(1..=4) / Async(1..=3) —
    // on the per-item plane, and additionally on the batched plane
    // (batch_rows = 64) for the tabular three. The OptReport must
    // account for every removed stage, ride the optimized results (and
    // only those), and prove at least one fusion fired on at least
    // three pipelines.
    use repro::coordinator::optimize;
    let mut fused: Vec<String> = Vec::new();
    for e in registry() {
        if needs_artifacts(e.name) && !artifacts_ready() {
            eprintln!("skipping {} (no artifacts)", e.name);
            continue;
        }
        let planes: &[usize] =
            if matches!(e.name, "census" | "plasticc" | "iiot") { &[0, 64] } else { &[0] };
        for &batch_rows in planes {
            let mut cfg = base_cfg();
            cfg.batch_rows = batch_rows;
            let baseline = compile_entry(e, &cfg).unwrap();
            let mut optimized = compile_entry(e, &cfg).unwrap();
            let report = optimize(&mut optimized);
            assert_eq!(
                report.stages_before,
                report.stages_after + report.stages_removed(),
                "{}: OptReport must account for every removed stage",
                e.name
            );
            assert_eq!(optimized.opt_report(), Some(&report), "{}", e.name);
            if batch_rows == 0 && report.fused > 0 {
                fused.push(e.name.to_string());
            }
            let mut modes = vec![ExecMode::Sequential];
            modes.extend(conformance_modes());
            for mode in modes {
                cfg.exec = mode;
                let base =
                    run_compiled(e, &baseline, repro::pipelines::Workload::Synthetic, &cfg)
                        .unwrap_or_else(|err| panic!("{} baseline {mode}: {err:#}", e.name));
                let opt =
                    run_compiled(e, &optimized, repro::pipelines::Workload::Synthetic, &cfg)
                        .unwrap_or_else(|err| panic!("{} optimized {mode}: {err:#}", e.name));
                assert!(
                    base.opt.is_none(),
                    "{} {mode}: unoptimized runs must not carry an OptReport",
                    e.name
                );
                assert_eq!(
                    opt.opt.as_ref(),
                    Some(&report),
                    "{} {mode}: optimized runs carry the plan's OptReport",
                    e.name
                );
                assert_bit_identical(e.name, mode, batch_rows, &base, &opt);
            }
        }
    }
    assert!(
        fused.len() >= 3,
        "fusion must fire on at least three pipelines, got {fused:?}"
    );
}

#[test]
fn sliced_sharding_matches_clone_based_sharding_for_every_pipeline() {
    // Payload-aware slicing (CompiledPlan::bind_shard over
    // Workload::slice) must reproduce the clone-based path
    // (plan_with + Plan::shard) exactly: metrics, items, and per-shard
    // ownership, for all eight pipelines and shard counts 1..=4.
    for e in registry() {
        if needs_artifacts(e.name) && !artifacts_ready() {
            continue;
        }
        let cfg = base_cfg();
        let payload = (e.payload)(&cfg);
        let compiled = compile_entry(e, &cfg).unwrap();
        for n in 1..=4usize {
            let mut shard_cfg = cfg;
            shard_cfg.exec = ExecMode::Sharded(n);
            let cloned = run_plan_with(e.plan_with, payload.clone(), &shard_cfg)
                .unwrap_or_else(|err| panic!("{} cloned shard:{n}: {err:#}", e.name));
            let sliced = run_compiled(e, &compiled, payload.clone(), &shard_cfg)
                .unwrap_or_else(|err| panic!("{} sliced shard:{n}: {err:#}", e.name));
            assert_eq!(sliced.items, cloned.items, "{} shard:{n}", e.name);
            let keys: Vec<&String> = cloned.metrics.keys().collect();
            let sliced_keys: Vec<&String> = sliced.metrics.keys().collect();
            assert_eq!(keys, sliced_keys, "{} shard:{n}", e.name);
            for (k, v) in &cloned.metrics {
                if TIMING_METRICS.contains(&k.as_str()) {
                    continue;
                }
                let w = sliced.metric(k).unwrap();
                assert!(
                    (v - w).abs() < 1e-12,
                    "{}.{k} differs sliced vs cloned at shard:{n}: {v} vs {w}",
                    e.name
                );
            }
            let a = sliced.sharding.as_ref().expect("sliced run reports partitions");
            let b = cloned.sharding.as_ref().expect("cloned run reports partitions");
            assert_eq!(a.shard_count(), n, "{}", e.name);
            assert_eq!(a.total_owned(), b.total_owned(), "{} shard:{n}", e.name);
            for (x, y) in a.shards.iter().zip(&b.shards) {
                assert_eq!(x.shard, y.shard, "{}", e.name);
                assert_eq!(x.owned, y.owned, "{} shard:{n} shard {}", e.name, x.shard);
                assert_eq!(
                    x.completed, y.completed,
                    "{} shard:{n} shard {}",
                    e.name, x.shard
                );
            }
        }
    }
}

#[test]
fn compiled_async_sharded_composition_binds_pre_sliced_shards() {
    // The async × sharded composition through CompiledPlan::bind_shard:
    // shard passes over pre-sliced payloads plus the streaming merge on
    // a 2-worker pool, answering exactly like the seed Sequential run.
    use repro::coordinator::Slicing;
    for e in registry() {
        if needs_artifacts(e.name) && !artifacts_ready() {
            continue;
        }
        let cfg = base_cfg();
        let seq = (e.run)(&cfg).unwrap();
        let compiled = compile_entry(e, &cfg).unwrap();
        let payload = (e.payload)(&cfg);
        for n in [2usize, 3] {
            let res = exec::run_sharded_async(n, 2, |s| {
                let sharder = Sharder::new(s, n);
                let slice = match compiled.slicing() {
                    Slicing::PerItem => payload.slice(s, n),
                    Slicing::SingleState => {
                        if s == 0 {
                            payload.clone()
                        } else {
                            payload.empty_like()
                        }
                    }
                };
                compiled.bind_shard(slice, sharder, &payload, cfg.seed)
            })
            .unwrap_or_else(|err| panic!("{} compiled async+shard:{n}: {err:#}", e.name));
            assert_eq!(res.output.items, seq.items, "{} async+shard:{n}", e.name);
            for (k, v) in &seq.metrics {
                if TIMING_METRICS.contains(&k.as_str()) {
                    continue;
                }
                let w = res.output.metrics[k];
                assert!(
                    (v - w).abs() < 1e-12,
                    "{}.{k} differs under compiled async+shard:{n}: {v} vs {w}",
                    e.name
                );
            }
            assert!(res.sched.expect("counters").balanced(), "{} shard:{n}", e.name);
        }
    }
}

#[test]
fn sharded_runs_pool_latencies_and_cover_the_source() {
    // The merge-aware sink contract, for every runnable pipeline and
    // every shard count: pooled latency samples == items completed at
    // the sink, p50 ≤ p95, and the round-robin partition exactly covers
    // the source stream (disjoint shards summing to the sequential
    // source count).
    for e in registry() {
        if needs_artifacts(e.name) && !artifacts_ready() {
            continue;
        }
        let mut cfg = base_cfg();
        cfg.exec = ExecMode::Sequential;
        let seq = (e.run)(&cfg).unwrap();
        let source_items = seq.report.stages.first().map_or(0, |s| s.items);
        for n in 1..=4usize {
            cfg.exec = ExecMode::Sharded(n);
            let res = (e.run)(&cfg).unwrap_or_else(|err| panic!("{} shard:{n}: {err:#}", e.name));
            let sharding = res
                .sharding
                .as_ref()
                .unwrap_or_else(|| panic!("{} shard:{n}: missing sharding report", e.name));
            assert_eq!(sharding.shard_count(), n, "{}", e.name);
            assert_eq!(sharding.total_owned(), source_items, "{} shard:{n}", e.name);
            let completed_at_sink =
                res.report.stages.last().map_or(0, |s| s.items);
            assert_eq!(
                sharding.pooled_latencies().len(),
                completed_at_sink,
                "{} shard:{n}: one pooled sample per sink completion",
                e.name
            );
            assert_eq!(res.report.latencies.len(), completed_at_sink, "{} shard:{n}", e.name);
            if completed_at_sink > 0 {
                let p50 = sharding.latency_percentile(0.50).unwrap();
                let p95 = sharding.latency_percentile(0.95).unwrap();
                assert!(p95 >= p50, "{} shard:{n}: p95 {p95:?} < p50 {p50:?}", e.name);
            }
            // Shard reports are indexed by shard (merge order) and each
            // carries its own samples.
            for (i, s) in sharding.shards.iter().enumerate() {
                assert_eq!(s.shard, i, "{}", e.name);
                assert_eq!(s.latencies.len(), s.completed, "{}", e.name);
            }
        }
    }
}

#[test]
fn all_executors_visit_the_same_stages() {
    for e in registry() {
        if needs_artifacts(e.name) && !artifacts_ready() {
            continue;
        }
        let mut cfg = base_cfg();
        let stage_names = |res: &repro::pipelines::PipelineResult| -> Vec<String> {
            res.report.stages.iter().map(|s| s.name.clone()).collect()
        };
        cfg.exec = ExecMode::Sequential;
        let seq = stage_names(&(e.run)(&cfg).unwrap());
        cfg.exec = ExecMode::Streaming;
        let stream_res = (e.run)(&cfg).unwrap();
        let stream = stage_names(&stream_res);
        cfg.exec = ExecMode::MultiInstance(1);
        let multi = stage_names(&(e.run)(&cfg).unwrap());
        cfg.exec = ExecMode::Sharded(2);
        let sharded = stage_names(&(e.run)(&cfg).unwrap());
        cfg.exec = ExecMode::Async(2);
        let async_names = stage_names(&(e.run)(&cfg).unwrap());
        assert_eq!(seq, stream, "{}", e.name);
        assert_eq!(seq, multi, "{}", e.name);
        assert_eq!(seq, sharded, "{}", e.name);
        assert_eq!(seq, async_names, "{}", e.name);
        // Every stage was visited under the streaming executor too.
        for s in &stream_res.report.stages {
            assert!(s.items > 0, "{}: stage {} idle under streaming", e.name, s.name);
        }
    }
}

#[test]
fn multi_instance_scales_items_and_reports_scaling_metrics() {
    // Tabular pipelines need no artifacts; each replica processes its own
    // stream, so items sum across instances.
    for name in ["census", "plasticc", "iiot"] {
        let mut cfg = base_cfg();
        cfg.exec = ExecMode::Sequential;
        let seq = run_by_name(name, &cfg).unwrap();
        cfg.exec = ExecMode::MultiInstance(2);
        let multi = run_by_name(name, &cfg).unwrap();
        assert_eq!(multi.items, 2 * seq.items, "{name}");
        assert_eq!(multi.metric("scaling_instances"), Some(2.0), "{name}");
        let fairness = multi.metric("scaling_fairness").unwrap();
        assert!((0.0..=1.0).contains(&fairness), "{name}: fairness {fairness}");
        assert!(multi.metric("scaling_throughput").unwrap() > 0.0, "{name}");
        let p50 = multi.metric("scaling_latency_p50_ms").unwrap();
        let p95 = multi.metric("scaling_latency_p95_ms").unwrap();
        assert!(p95 >= p50, "{name}: p95 {p95} < p50 {p50}");
        // Single-instance runs must NOT carry scaling metrics (so n=1 is
        // bit-identical to sequential).
        assert!(seq.metric("scaling_instances").is_none(), "{name}");
    }
}

#[test]
fn multi_instance_replicas_get_distinct_seeds() {
    // Instance i runs seed+i: census R² is seed-dependent noise-wise but
    // metrics come from instance 0, which must match the sequential run
    // at the same seed.
    let mut cfg = base_cfg();
    cfg.exec = ExecMode::Sequential;
    let seq = run_by_name("census", &cfg).unwrap();
    cfg.exec = ExecMode::MultiInstance(3);
    let multi = run_by_name("census", &cfg).unwrap();
    assert!(
        (seq.metric("r2").unwrap() - multi.metric("r2").unwrap()).abs() < 1e-12,
        "instance 0 must use the base seed"
    );
}

#[test]
fn streaming_is_deterministic_across_repeats() {
    for name in ["census", "iiot"] {
        let mut cfg = base_cfg();
        cfg.exec = ExecMode::Streaming;
        let a = run_by_name(name, &cfg).unwrap();
        let b = run_by_name(name, &cfg).unwrap();
        for (k, v) in &a.metrics {
            if TIMING_METRICS.contains(&k.as_str()) {
                continue;
            }
            let w = b.metric(k).unwrap();
            assert!((v - w).abs() < 1e-12, "{name}.{k}: {v} vs {w}");
        }
    }
}

#[test]
fn async_is_deterministic_across_repeats() {
    // Task interleaving varies run to run on a real pool; metrics may
    // not. Repeats must agree bit-for-bit on every non-timing metric,
    // and every repeat's scheduler ledger must balance.
    for name in ["census", "iiot"] {
        let mut cfg = base_cfg();
        cfg.exec = ExecMode::Async(3);
        let a = run_by_name(name, &cfg).unwrap();
        let b = run_by_name(name, &cfg).unwrap();
        for (k, v) in &a.metrics {
            if TIMING_METRICS.contains(&k.as_str()) {
                continue;
            }
            let w = b.metric(k).unwrap();
            assert!((v - w).abs() < 1e-12, "{name}.{k}: {v} vs {w}");
        }
        for res in [&a, &b] {
            let sched = res.sched.as_ref().expect("async runs carry scheduler counters");
            assert!(sched.balanced(), "{name}: {sched:?}");
            assert!(sched.max_in_flight <= 3, "{name}: {sched:?}");
        }
    }
}

#[test]
fn async_composes_with_sharding_identically() {
    // The composed executor — shard passes plus the streaming merge as
    // cooperative tasks on a 2-worker pool — answers exactly like
    // Sequential for every runnable pipeline and every shard count.
    for e in registry() {
        if needs_artifacts(e.name) && !artifacts_ready() {
            continue;
        }
        let cfg = base_cfg();
        let seq = (e.run)(&cfg).unwrap();
        for n in 1..=4usize {
            let res = exec::run_sharded_async(n, 2, |s| {
                (e.plan)(&cfg).map(|p| p.shard(Sharder::new(s, n)))
            })
            .unwrap_or_else(|err| panic!("{} async+shard:{n}: {err:#}", e.name));
            assert_eq!(res.output.items, seq.items, "{} async+shard:{n}", e.name);
            let keys: Vec<&String> = seq.metrics.keys().collect();
            let res_keys: Vec<&String> = res.output.metrics.keys().collect();
            assert_eq!(keys, res_keys, "{} async+shard:{n}: metric keys differ", e.name);
            for (k, v) in &seq.metrics {
                if TIMING_METRICS.contains(&k.as_str()) {
                    continue;
                }
                let w = res.output.metrics[k];
                assert!(
                    (v - w).abs() < 1e-12,
                    "{}.{k} differs under async+shard:{n}: {v} vs {w}",
                    e.name
                );
            }
            let sharding = res.sharding.as_ref().expect("composed run reports partitions");
            assert_eq!(sharding.shard_count(), n, "{}", e.name);
            let sched = res.sched.as_ref().expect("composed run reports counters");
            assert!(sched.balanced(), "{} async+shard:{n}: {sched:?}", e.name);
            // n pass tasks + 1 merge task on the pool.
            assert_eq!(sched.tasks_spawned, n + 1, "{} async+shard:{n}", e.name);
        }
    }
}

#[test]
fn seeded_interleavings_stream_the_sharded_merge_for_registry_plans() {
    // The acceptance pin for the streaming merge on a REAL pipeline,
    // asserted via scheduler/shard counters under deterministic seeds —
    // never timing: across 20 seeded interleavings of census's shard
    // passes and merge task, metrics never move, and at least one
    // interleaving begins folding before the last pass has run. (The
    // exhaustive 32-seed version over a synthetic multi-item plan lives
    // in the exec unit suite; this one pins the registry path.)
    let e = repro::pipelines::find("census").expect("census is registered");
    let cfg =
        RunConfig { toggles: Toggles::optimized(), scale: 0.05, seed: 0xE9, ..Default::default() };
    let mut seq_cfg = cfg;
    seq_cfg.exec = ExecMode::Sequential;
    let seq = (e.run)(&seq_cfg).unwrap();
    let mut streamed_any = false;
    for seed in 0..20u64 {
        let res = exec::run_sharded_seeded(3, seed, |s| {
            (e.plan)(&cfg).map(|p| p.shard(Sharder::new(s, 3)))
        })
        .unwrap_or_else(|err| panic!("seed {seed}: {err:#}"));
        assert_eq!(res.output.items, seq.items, "seed {seed}");
        for (k, v) in &seq.metrics {
            if TIMING_METRICS.contains(&k.as_str()) {
                continue;
            }
            let w = res.output.metrics[k];
            assert!((v - w).abs() < 1e-12, "seed {seed}: census.{k}: {v} vs {w}");
        }
        let sharding = res.sharding.expect("seeded sharded run reports partitions");
        assert!(sharding.streamed_folds <= sharding.shard_count(), "seed {seed}");
        streamed_any |= sharding.merge_streamed();
        assert!(res.sched.expect("counters").balanced(), "seed {seed}");
    }
    assert!(
        streamed_any,
        "no seed in 0..20 overlapped a fold with a pending pass — the merge is not streaming"
    );
}
