//! Integration property tests: the baseline and optimized execution
//! engines must be *observationally identical* across the dataframe, NMS,
//! tokenizer and recsys substrates — broader random sweeps than the unit
//! tests, exercising whole operation chains.

use repro::dataframe::{self as df, groupby::Agg, Column, DataFrame, DType, Engine, Expr};
use repro::util::{prop, Rng};

/// Random frame with mixed dtypes and nulls.
fn random_frame(rng: &mut Rng, n: usize) -> DataFrame {
    let mask: Option<Vec<bool>> = if rng.chance(0.5) {
        Some((0..n).map(|_| rng.chance(0.85)).collect())
    } else {
        None
    };
    DataFrame::from_cols(vec![
        ("f", Column::F64((0..n).map(|_| rng.normal()).collect(), mask)),
        ("i", Column::i64((0..n).map(|_| rng.range_i64(-20, 20)).collect())),
        ("g", Column::str((0..n).map(|_| rng.ascii_lower(1)).collect())),
        ("b", Column::bool((0..n).map(|_| rng.chance(0.5)).collect())),
    ])
}

#[test]
fn whole_chain_equivalence() {
    prop::check("df chain: filter→with_column→astype→groupby", 12, |rng| {
        let n = 1 + rng.below(300);
        let frame = random_frame(rng, n);
        let run = |engine: Engine| -> Result<DataFrame, String> {
            let pred = Expr::col("f")
                .gt(Expr::lit(-0.5))
                .and(Expr::col("i").ne(Expr::lit_i64(0)));
            let x = df::ops::filter(&frame, &pred, engine).map_err(|e| e.to_string())?;
            let x = df::ops::with_column(
                &x,
                "fi",
                &Expr::col("f").mul(Expr::col("i")),
                engine,
            )
            .map_err(|e| e.to_string())?;
            let x = df::ops::astype(&x, "i", DType::F64, engine).map_err(|e| e.to_string())?;
            df::groupby::groupby_agg(
                &x,
                &["g"],
                &[("fi", Agg::Sum), ("fi", Agg::Mean), ("i", Agg::Count)],
                engine,
            )
            .map_err(|e| e.to_string())
        };
        let a = run(Engine::Baseline)?;
        let b = run(Engine::Optimized)?;
        if a.nrows() != b.nrows() {
            return Err(format!("group counts {} vs {}", a.nrows(), b.nrows()));
        }
        if a.strs("g").map_err(|e| e.to_string())? != b.strs("g").map_err(|e| e.to_string())? {
            return Err("group keys differ".into());
        }
        for col in ["fi_sum", "fi_mean", "i_count"] {
            prop::assert_close(
                a.f64s(col).map_err(|e| e.to_string())?,
                b.f64s(col).map_err(|e| e.to_string())?,
                1e-9,
            )?;
        }
        Ok(())
    });
}

#[test]
fn csv_round_trip_equivalence() {
    prop::check("csv write→read equivalence across engines", 8, |rng| {
        let n = 1 + rng.below(200);
        let frame = random_frame(rng, n);
        let text = df::csv::write_str(&frame);
        let a = df::csv::read_str(&text, Engine::Baseline).map_err(|e| e.to_string())?;
        let b = df::csv::read_str(&text, Engine::Optimized).map_err(|e| e.to_string())?;
        if a.nrows() != b.nrows() || a.ncols() != b.ncols() {
            return Err("shape mismatch".into());
        }
        for i in 0..a.nrows() {
            if a.row_values(i) != b.row_values(i) {
                return Err(format!("row {i} differs"));
            }
        }
        Ok(())
    });
}

#[test]
fn sort_then_split_is_engine_independent() {
    prop::check("sort+split determinism", 8, |rng| {
        let n = 2 + rng.below(150);
        let frame = random_frame(rng, n);
        let sorted = df::ops::sort_by(&frame, "f", true).map_err(|e| e.to_string())?;
        let (tr1, te1) = df::ops::train_test_split(&sorted, 0.3, 9);
        let (tr2, te2) = df::ops::train_test_split(&sorted, 0.3, 9);
        if tr1 != tr2 || te1 != te2 {
            return Err("split not deterministic".into());
        }
        if tr1.nrows() + te1.nrows() != n {
            return Err("split loses rows".into());
        }
        Ok(())
    });
}

#[test]
fn recsys_feature_engineering_equivalence() {
    use repro::recsys::{build_examples, generate_log, parse_log};
    use repro::OptLevel;
    prop::check("recsys baseline == optimized", 6, |rng| {
        let n = 50 + rng.below(400);
        let (events, _) = parse_log(&generate_log(n, 10 + rng.below(20), 60, rng.next_u64()));
        let (a, _, _) = build_examples(&events, 8, 64, 5, OptLevel::Baseline);
        let (b, _, _) = build_examples(&events, 8, 64, 5, OptLevel::Optimized);
        let key = |e: &repro::recsys::DienExample| (e.history.clone(), e.candidate, e.label);
        let mut ka: Vec<_> = a.iter().map(key).collect();
        let mut kb: Vec<_> = b.iter().map(key).collect();
        ka.sort();
        kb.sort();
        if ka != kb {
            return Err(format!("{} vs {} examples differ", a.len(), b.len()));
        }
        Ok(())
    });
}

#[test]
fn nms_equivalence_dense_scenes() {
    use repro::vision::{nms, Detection, NmsKind};
    prop::check("nms dense-scene equivalence", 10, |rng| {
        let n = 200 + rng.below(400);
        let dets: Vec<Detection> = (0..n)
            .map(|_| {
                let y = rng.range_f64(0.0, 50.0) as f32;
                let x = rng.range_f64(0.0, 50.0) as f32;
                Detection {
                    bbox: [y, x, y + 6.0, x + 6.0],
                    class: 1 + rng.below(3),
                    score: (rng.f32() * 100.0).round() / 100.0,
                }
            })
            .collect();
        let a = nms(&dets, 0.3, NmsKind::Naive);
        let b = nms(&dets, 0.3, NmsKind::Sorted);
        if a.len() != b.len() {
            return Err(format!("{} vs {}", a.len(), b.len()));
        }
        for (x, y) in a.iter().zip(&b) {
            if x.bbox != y.bbox {
                return Err("survivor sets differ".into());
            }
        }
        Ok(())
    });
}

#[test]
fn tokenizer_equivalence_wide_sweep() {
    use repro::text::{ReviewGenerator, TokenizerKind, Vocab, WordPiece};
    prop::check("tokenizer equivalence", 8, |rng| {
        let vocab = Vocab::build_from_corpus(&ReviewGenerator::lexicon(), 40);
        let tok = WordPiece::new(vocab, 48);
        let mut gen = ReviewGenerator::new(rng.next_u64(), 20);
        for r in gen.batch(30) {
            let a = tok.encode(&r.text, TokenizerKind::Baseline);
            let b = tok.encode(&r.text, TokenizerKind::Optimized);
            if a != b {
                return Err(format!("{:?}", r.text));
            }
        }
        Ok(())
    });
}
