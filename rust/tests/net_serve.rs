//! Loopback soak suite for the TCP serving edge (`repro::net`): a real
//! `PipelineServer` on an ephemeral port, driven over real sockets by
//! wire clients. Every contract is pinned from **counters** — the
//! `NetReport` ledger, `Goodbye` frames, and client-side tallies —
//! never from wall-clock:
//!
//! * per-tenant admission lanes shed **deterministically** at a fixed
//!   `per_tenant_depth`: a paused service makes admission synchronous,
//!   so K requests against a depth-D lane yield exactly K−D first-class
//!   `Shed(TenantLaneFull)` frames — per tenant, never per connection;
//! * graceful drain loses **zero** responses: every in-flight ticket at
//!   drain time resolves, is written, and lands in a `Goodbye` whose
//!   counters agree with the client's own ledger;
//! * the closed-loop load generator (`run_load`, the engine behind
//!   `repro bench-serve`) balances end-to-end: the server's per-tenant
//!   ledger equals the fleet's client-side outcome record exactly;
//! * the hardened edge holds its limits: connections past `max_conns`
//!   get a first-class `Shed(ServerFull)` frame (counted `rejected`,
//!   never `accepted`), the idle reaper retires idle and half-open
//!   connections (`accepted == drained + reaped`, split by cause) while
//!   sparing anything with work in flight, connection tasks multiplex
//!   on the service's shared scheduler pool (no per-connection handler
//!   threads; `SchedReport.parked == woken` at quiescence), tenant
//!   admission lanes release to zero, and a protocol-violating first
//!   frame is counted and answered, never silently dropped.
//!
//! Spins (`spin_until`) are liveness bounds only — every assertion
//! reads a counter.

use repro::net::wire::{self, Frame};
use repro::net::{run_load, LoadSpec, PipelineServer, ServeClient, ServerConfig};
use repro::pipelines::{RunConfig, Toggles};
use repro::service::{PipelineService, Priority, ServiceConfig};
use std::net::TcpStream;
use std::sync::Arc;

fn tiny() -> RunConfig {
    RunConfig { toggles: Toggles::optimized(), scale: 0.05, seed: 0x51, ..Default::default() }
}

fn open(names: &[&str], paused: bool) -> Arc<PipelineService> {
    Arc::new(
        PipelineService::open(
            names,
            ServiceConfig {
                defaults: tiny(),
                queue_depth: 32,
                workers: 2,
                start_paused: paused,
                skip_unavailable: false,
            },
        )
        .expect("tabular pipelines always open"),
    )
}

/// Bounded liveness spin: wait for a counter condition, panic after a
/// generous cap so a hang fails loudly instead of wedging the suite.
/// Assertions always come from counters AFTER the condition holds.
fn spin_until(what: &str, mut cond: impl FnMut() -> bool) {
    for _ in 0..10_000 {
        if cond() {
            return;
        }
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
    panic!("timed out waiting for {what}");
}

#[test]
fn tenant_lanes_shed_deterministically_at_fixed_depth() {
    // Paused service: admitted requests pend (nothing resolves), so the
    // lane occupancy is exact — tenant t's requests 1..=D occupy the
    // lane and D+1..=K shed with TenantLaneFull, deterministically.
    let depth = 3u64;
    let per_tenant = 8u64;
    let svc = open(&["census"], true);
    let server = PipelineServer::start(
        Arc::clone(&svc),
        "127.0.0.1:0",
        ServerConfig { per_tenant_depth: depth as usize, ..Default::default() },
    )
    .unwrap();

    let mut clients: Vec<ServeClient> = ["alpha", "beta"]
        .iter()
        .map(|tenant| ServeClient::connect(server.local_addr(), tenant).unwrap())
        .collect();
    for client in &mut clients {
        for _ in 0..per_tenant {
            client
                .send("census", Priority::Normal, None, wire::WirePayload::Synthetic)
                .unwrap();
        }
        // With the service paused the ONLY response frames are the lane
        // sheds — exactly K − D of them, ids D+1..=K, all TenantLaneFull.
        for expect_id in (depth + 1)..=per_tenant {
            match client.recv().unwrap() {
                Frame::Shed { id, cause, waited_us, .. } => {
                    assert_eq!(id, expect_id, "sheds arrive in request order");
                    assert_eq!(cause, wire::ShedCause::TenantLaneFull);
                    assert_eq!(waited_us, 0, "lane sheds never enter the queue");
                }
                other => panic!("expected Shed, got {}", other.kind()),
            }
        }
    }

    // The server-side ledger agrees per tenant BEFORE anything resolves:
    // every request frame admitted, K − D shed, zero completed.
    let report = clients[0].stats().unwrap();
    for tenant in ["alpha", "beta"] {
        let t = report.tenants.get(tenant).unwrap_or_else(|| panic!("{tenant} ledger"));
        assert_eq!(t.admitted, per_tenant, "{tenant}");
        assert_eq!(t.shed, per_tenant - depth, "{tenant}");
        assert_eq!(t.completed, 0, "{tenant}: nothing resolves while paused");
    }

    // Resume and drain each connection: the Goodbye counters pin the
    // outcome split (D completed, K − D shed) per tenant — with every
    // shed attributed to the tenant lane, none to the queue or deadline.
    svc.resume();
    for client in clients {
        let (completed, shed, failed, by_cause) = client.drain().unwrap();
        assert_eq!((completed, shed, failed), (depth, per_tenant - depth, 0));
        assert_eq!(
            by_cause[wire::ShedCause::TenantLaneFull.index()],
            per_tenant - depth,
            "every shed is a lane shed"
        );
        assert_eq!(by_cause.iter().sum::<u64>(), shed);
    }
    let report = server.drain();
    assert!(report.balanced(), "{report:?}");
    assert_eq!(report.accepted, 2);
    assert_eq!(report.drained, 2);
}

#[test]
fn server_drain_flushes_every_in_flight_response() {
    // Requests are in flight (paused service) when the server starts
    // draining: the handler must flush ALL of them — written to the
    // socket, counted in Goodbye — and the final ledger must balance.
    let svc = open(&["census"], true);
    let server =
        PipelineServer::start(Arc::clone(&svc), "127.0.0.1:0", ServerConfig::default())
            .unwrap();
    let mut client = ServeClient::connect(server.local_addr(), "t-drain").unwrap();
    let in_flight = 3u64;
    for _ in 0..in_flight {
        client.send("census", Priority::Normal, None, wire::WirePayload::Synthetic).unwrap();
    }
    // Counter sync (no sleeps): the stats reply is written after every
    // request frame before it was handled, so admitted == 3 here.
    let report = client.stats().unwrap();
    assert_eq!(report.tenants["t-drain"].admitted, in_flight);
    assert_eq!(report.tenants["t-drain"].completed, 0);

    // Server-initiated drain races nothing: the drain thread blocks
    // until handlers flush, which requires the resumed service.
    let drainer = std::thread::spawn(move || server.drain());
    svc.resume();

    // The client reads every in-flight response, then the Goodbye.
    let mut completed = 0u64;
    loop {
        match client.recv().unwrap() {
            Frame::Completed(c) => {
                assert!(!c.summary.is_empty());
                completed += 1;
            }
            Frame::Goodbye { completed: done, shed, failed, shed_by_cause } => {
                assert_eq!((done, shed, failed), (in_flight, 0, 0));
                assert_eq!(shed_by_cause, [0; wire::SHED_CAUSE_COUNT]);
                break;
            }
            other => panic!("unexpected {} during drain", other.kind()),
        }
    }
    assert_eq!(completed, in_flight, "zero responses lost to the drain");

    let report = drainer.join().expect("drain thread");
    assert!(report.balanced(), "{report:?}");
    assert_eq!(report.accepted, report.drained);
    let t = &report.tenants["t-drain"];
    assert_eq!((t.admitted, t.completed, t.shed, t.failed), (in_flight, in_flight, 0, 0));
}

#[test]
fn queue_expiry_sheds_are_deterministic_and_attributed_per_cause() {
    // Deterministic DeadlineExpired sheds, no timing assertions: the
    // service starts PAUSED, so submitted requests sit in the queue.
    // Each request carries Some(Duration::ZERO) — which the wire codec
    // saturates to a 1 ms deadline instead of aliasing the "no
    // deadline" sentinel — so by the time the service resumes (after a
    // queue wait of at least one stats round trip plus a guard sleep),
    // the dispatcher finds every deadline long expired and sheds each
    // request with ShedReason::DeadlineExpired. A deadline-less control
    // request on the same connection completes normally.
    use std::time::Duration;
    let svc = open(&["census"], true);
    let server =
        PipelineServer::start(Arc::clone(&svc), "127.0.0.1:0", ServerConfig::default())
            .unwrap();
    let mut client = ServeClient::connect(server.local_addr(), "t-deadline").unwrap();
    let expire = 3u64;
    for _ in 0..expire {
        client
            .send("census", Priority::Normal, Some(Duration::ZERO), wire::WirePayload::Synthetic)
            .unwrap();
    }
    client.send("census", Priority::Normal, None, wire::WirePayload::Synthetic).unwrap();
    // Counter sync: the stats reply proves all four requests were
    // admitted to the (paused) queue before the resume below.
    let report = client.stats().unwrap();
    assert_eq!(report.tenants["t-deadline"].admitted, expire + 1);
    assert_eq!(report.tenants["t-deadline"].completed, 0);
    // Guard: even a 1 ms deadline is comfortably expired at dispatch.
    // (Determinism guard on queue wait, not a timing assertion.)
    std::thread::sleep(Duration::from_millis(10));
    svc.resume();
    let (completed, shed, failed, by_cause) = client.drain().unwrap();
    assert_eq!((completed, shed, failed), (1, expire, 0));
    assert_eq!(
        by_cause[wire::ShedCause::DeadlineExpired.index()],
        expire,
        "every expired request is attributed to DeadlineExpired: {by_cause:?}"
    );
    assert_eq!(by_cause[wire::ShedCause::TenantLaneFull.index()], 0);
    assert_eq!(by_cause[wire::ShedCause::QueueFull.index()], 0);
    assert_eq!(by_cause.iter().sum::<u64>(), shed);
    let net = server.drain();
    assert!(net.balanced(), "{net:?}");
    let t = &net.tenants["t-deadline"];
    assert_eq!((t.admitted, t.completed, t.shed, t.failed), (expire + 1, 1, expire, 0));
}

#[test]
fn closed_loop_load_generator_balances_server_and_client_ledgers() {
    // The bench-serve engine end-to-end: 2 generator threads, 2 tenants
    // (tenant == pipeline), weighted census:2,plasticc:1 mix. Closed
    // loop means at most `clients` requests in flight per tenant — well
    // under the lane depth — so the outcome is fully deterministic:
    // everything completes, and the server's per-tenant ledger equals
    // the fleet's client-side record.
    let svc = open(&["census", "plasticc"], false);
    let server =
        PipelineServer::start(Arc::clone(&svc), "127.0.0.1:0", ServerConfig::default())
            .unwrap();
    let spec = LoadSpec {
        clients: 2,
        requests: 6,
        mix: vec![("census".to_string(), 2), ("plasticc".to_string(), 1)],
    };
    let load = run_load(server.local_addr(), &spec).unwrap();
    let net = server.drain();

    assert!(load.balances(), "{load:?}");
    assert!(net.balanced(), "{net:?}");
    // 2 clients x 2 mix entries = 4 connections, all drained.
    assert_eq!(net.accepted, 4);
    assert_eq!(net.drained, 4);
    // Weighted round-robin over 6 requests: census gets slots {0,1,3,4},
    // plasticc slots {2,5} — per client.
    let total: u64 = load.per_tenant.values().map(|t| t.requests).sum();
    assert_eq!(total, (spec.clients * spec.requests) as u64);
    assert_eq!(load.per_tenant["census"].requests, 8);
    assert_eq!(load.per_tenant["plasticc"].requests, 4);
    for (tenant, client_side) in &load.per_tenant {
        assert_eq!(client_side.completed, client_side.requests, "{tenant}: nothing sheds");
        assert_eq!(client_side.failed, 0, "{tenant}");
        let server_side = net.tenants.get(tenant).unwrap_or_else(|| panic!("{tenant}"));
        assert_eq!(server_side.admitted, client_side.requests, "{tenant}");
        assert_eq!(server_side.completed, client_side.completed, "{tenant}");
        assert_eq!(server_side.shed, 0, "{tenant}");
    }
    // The trajectory rendering carries every tenant with latency samples.
    let pipelines = load.trajectory_pipelines();
    for tenant in ["census", "plasticc"] {
        let entry = pipelines
            .get(tenant)
            .and_then(|p| p.get("exec_modes"))
            .and_then(|m| m.get("serve"))
            .unwrap_or_else(|| panic!("{tenant} serve entry"));
        assert!(entry.get("p50_ms").is_some());
        assert!(entry.get("items_per_s").is_some());
    }
    // The service underneath saw exactly the offered load.
    let stats = svc.stats();
    assert_eq!(stats.completed, total);
    assert!(stats.balances(), "{stats:?}");
}

#[test]
fn connections_past_max_conns_get_a_first_class_server_full_shed() {
    // Two live connections fill a max_conns=2 server. The third connect
    // is answered with Shed(ServerFull) — a parseable frame, never a
    // silent RST — and counted `rejected`, never `accepted`. Draining
    // one connection frees the slot and the next connect is admitted.
    let svc = open(&["census"], false);
    let server = PipelineServer::start(
        Arc::clone(&svc),
        "127.0.0.1:0",
        ServerConfig { max_conns: 2, ..Default::default() },
    )
    .unwrap();
    let a = ServeClient::connect(server.local_addr(), "t-full-a").unwrap();
    let mut b = ServeClient::connect(server.local_addr(), "t-full-b").unwrap();

    // Raw socket, no Hello: the refusal frame arrives, then a clean EOF.
    let mut raw = TcpStream::connect(server.local_addr()).unwrap();
    match wire::read_frame(&mut raw).unwrap().unwrap() {
        Frame::Shed { id, cause, waited_us, .. } => {
            assert_eq!(id, 0, "a connection-level shed correlates to no request");
            assert_eq!(cause, wire::ShedCause::ServerFull);
            assert_eq!(waited_us, 0);
        }
        other => panic!("expected Shed(ServerFull), got {}", other.kind()),
    }
    assert!(wire::read_frame(&mut raw).unwrap().is_none(), "closed after the refusal");
    drop(raw);

    // The typed client surfaces the same refusal as a typed error.
    match ServeClient::connect(server.local_addr(), "t-full-c") {
        Ok(_) => panic!("connect past max_conns must be rejected"),
        Err(wire::WireError::Rejected(cause)) => {
            assert_eq!(cause, wire::ShedCause::ServerFull)
        }
        Err(other) => panic!("expected Rejected(ServerFull), got {other}"),
    }

    // Retiring a connection frees its slot.
    let (done, shed, failed, _) = a.drain().unwrap();
    assert_eq!((done, shed, failed), (0, 0, 0));
    spin_until("drained connection frees its slot", || server.report().drained == 1);
    let c = ServeClient::connect(server.local_addr(), "t-full-c")
        .expect("slot freed by the drain");
    let (done, _, _, _) = c.drain().unwrap();
    assert_eq!(done, 0);
    b.send("census", Priority::Normal, None, wire::WirePayload::Synthetic).unwrap();
    match b.recv().unwrap() {
        Frame::Completed(_) => {}
        other => panic!("expected Completed, got {}", other.kind()),
    }
    b.drain().unwrap();

    let net = server.drain();
    assert_eq!(net.accepted, 3, "rejected connections never count as accepted");
    assert_eq!(net.rejected, 2);
    assert_eq!(net.drained, 3);
    assert!(net.balanced(), "{net:?}");
}

#[test]
fn idle_and_half_open_connections_are_reaped_but_busy_ones_survive() {
    // idle_after=2 ticks. Three connections: one with a request pinned
    // in flight by the paused service (must survive), one established
    // but idle (reaped_idle), one that never says Hello — the
    // half-open handshake that used to spin a thread forever
    // (reaped_handshake). Every assertion is a ledger counter; the spin
    // only bounds liveness.
    let svc = open(&["census"], true);
    let server = PipelineServer::start(
        Arc::clone(&svc),
        "127.0.0.1:0",
        ServerConfig { idle_after: 2, ..Default::default() },
    )
    .unwrap();
    let mut busy = ServeClient::connect(server.local_addr(), "t-busy").unwrap();
    busy.send("census", Priority::Normal, None, wire::WirePayload::Synthetic).unwrap();
    spin_until("busy request admitted", || {
        server.report().tenants.get("t-busy").is_some_and(|t| t.admitted == 1)
    });
    let mut idle = ServeClient::connect(server.local_addr(), "t-idle").unwrap();
    let half_open = TcpStream::connect(server.local_addr()).unwrap();
    spin_until("reaper retires the idle and half-open connections", || {
        let r = server.report();
        r.reaped_idle == 1 && r.reaped_handshake == 1
    });
    let report = server.report();
    assert_eq!(report.accepted, 3);
    assert_eq!(report.drained, 0);
    assert_eq!(report.active(), 1, "the connection with work in flight survives the reaper");
    // The reaped established connection was closed with a Goodbye, not
    // a silent disconnect.
    match idle.recv().unwrap() {
        Frame::Goodbye { completed, shed, failed, .. } => {
            assert_eq!((completed, shed, failed), (0, 0, 0));
        }
        other => panic!("expected Goodbye from the reaper, got {}", other.kind()),
    }
    drop(idle);
    drop(half_open);

    // Drain the busy connection BEFORE resuming: the conn task enters
    // its flush state (where the reaper never applies) while the ticket
    // is still pending, so the post-completion outcome is deterministic.
    // frames_in so far: busy Hello + Request, idle Hello = 3; the Drain
    // frame makes 4.
    let drainer = std::thread::spawn(move || busy.drain().unwrap());
    spin_until("drain frame read", || server.report().frames_in == 4);
    svc.resume();
    let (done, shed, failed, _) = drainer.join().expect("drain thread");
    assert_eq!((done, shed, failed), (1, 0, 0), "the pinned request resolved and flushed");

    let net = server.drain();
    assert_eq!(net.accepted, 3);
    assert_eq!(net.drained, 1);
    assert_eq!((net.reaped_idle, net.reaped_handshake), (1, 1));
    assert_eq!(net.accepted, net.drained + net.reaped(), "reaps extend the drain balance");
    assert!(net.balanced(), "{net:?}");
    let t = &net.tenants["t-busy"];
    assert_eq!((t.admitted, t.completed), (1, 1));
}

#[test]
fn tenant_stats_returns_only_the_callers_ledger() {
    // A tenant polls ITS OWN server-side ledger over its connection and
    // gets exactly what the server's full report holds for it — scoped:
    // the other tenant's counters never ride the reply.
    let svc = open(&["census"], false);
    let server =
        PipelineServer::start(Arc::clone(&svc), "127.0.0.1:0", ServerConfig::default())
            .unwrap();
    let mut a = ServeClient::connect(server.local_addr(), "t-a").unwrap();
    let mut b = ServeClient::connect(server.local_addr(), "t-b").unwrap();
    for (client, calls) in [(&mut a, 1), (&mut b, 2)] {
        for _ in 0..calls {
            match client
                .call("census", Priority::Normal, None, wire::WirePayload::Synthetic)
                .unwrap()
            {
                Frame::Completed(_) => {}
                other => panic!("expected Completed, got {}", other.kind()),
            }
        }
    }
    let la = a.tenant_stats().unwrap();
    assert_eq!((la.admitted, la.completed, la.shed, la.failed), (1, 1, 0, 0));
    let lb = b.tenant_stats().unwrap();
    assert_eq!((lb.admitted, lb.completed, lb.shed, lb.failed), (2, 2, 0, 0));
    // Scoped view == the server's own ledger for that tenant, exactly.
    let report = server.report();
    assert_eq!(la, report.tenants["t-a"]);
    assert_eq!(lb, report.tenants["t-b"]);
    a.drain().unwrap();
    b.drain().unwrap();
    let net = server.drain();
    assert!(net.balanced(), "{net:?}");
}

#[test]
fn connection_tasks_multiplex_on_the_services_shared_pool() {
    // An ExecMode::Async service owns the shared cooperative pool;
    // socket tasks ride the SAME pool as plan stages. Pinned from
    // counters: the pool spawned at least one task per connection, every
    // park was woken (sockets parked instead of spinning threads), and
    // there is no per-connection handler thread anywhere in the process.
    use repro::coordinator::ExecMode;
    let svc = Arc::new(
        PipelineService::open(
            &["census", "plasticc"],
            ServiceConfig {
                defaults: RunConfig { exec: ExecMode::Async(2), ..tiny() },
                queue_depth: 32,
                workers: 2,
                start_paused: false,
                skip_unavailable: false,
            },
        )
        .unwrap(),
    );
    assert!(svc.scheduler_counters().is_some(), "async service owns a shared pool");
    let server =
        PipelineServer::start(Arc::clone(&svc), "127.0.0.1:0", ServerConfig::default())
            .unwrap();
    let spec = LoadSpec {
        clients: 3,
        requests: 6,
        mix: vec![("census".to_string(), 2), ("plasticc".to_string(), 1)],
    };
    let load = run_load(server.local_addr(), &spec).unwrap();
    assert!(load.balances(), "{load:?}");

    // With a live connection open, the process still has no
    // per-connection handler thread — the connection is a pool task.
    let live = ServeClient::connect(server.local_addr(), "t-live").unwrap();
    #[cfg(target_os = "linux")]
    {
        let mut names = Vec::new();
        for entry in std::fs::read_dir("/proc/self/task").unwrap() {
            let comm = entry.unwrap().path().join("comm");
            if let Ok(name) = std::fs::read_to_string(comm) {
                names.push(name.trim().to_string());
            }
        }
        assert!(
            names.iter().all(|n| !n.starts_with("pipeline-server-conn")),
            "per-connection handler threads found: {names:?}"
        );
    }
    live.drain().unwrap();
    assert_eq!(server.lane_count(), 0, "no lanes held once nothing is in flight");

    let net = server.drain();
    // 3 clients x 2 mix entries + the liveness probe = 7 connections.
    assert_eq!(net.accepted, 7);
    assert_eq!(net.drained, 7);
    assert!(net.balanced(), "{net:?}");
    let sr = svc.scheduler_counters().unwrap();
    assert!(sr.tasks_spawned >= 7, "one pool task per connection (plus plan tasks): {sr:?}");
    assert!(sr.parked > 0, "socket tasks parked on the shared pool: {sr:?}");
    assert_eq!(sr.parked, sr.woken, "every park was woken: {sr:?}");
    assert!(sr.balanced(), "{sr:?}");
}

#[test]
fn one_shot_tenant_churn_leaves_no_lane_entries_behind() {
    // Twelve tenants connect, run one request each, and leave. The lane
    // map must return to EMPTY after every release-to-zero (the old map
    // kept a dead entry per tenant forever); the ledger — whose job IS
    // history — keeps all twelve.
    let svc = open(&["census"], false);
    let server =
        PipelineServer::start(Arc::clone(&svc), "127.0.0.1:0", ServerConfig::default())
            .unwrap();
    for i in 0..12 {
        let tenant = format!("t-churn-{i:02}");
        let mut c = ServeClient::connect(server.local_addr(), &tenant).unwrap();
        match c.call("census", Priority::Normal, None, wire::WirePayload::Synthetic).unwrap() {
            Frame::Completed(_) => {}
            other => panic!("expected Completed, got {}", other.kind()),
        }
        // The lane released BEFORE the response frame was written, so
        // having read the response proves the entry is already gone.
        assert_eq!(server.lane_count(), 0, "lane entry leaked after {tenant}");
        let (done, shed, failed, _) = c.drain().unwrap();
        assert_eq!((done, shed, failed), (1, 0, 0));
    }
    let net = server.drain();
    assert_eq!(net.accepted, 12);
    assert_eq!(net.drained, 12);
    assert_eq!(net.tenants.len(), 12, "the ledger keeps per-tenant history");
    assert!(net.tenants.values().all(|t| t.admitted == 1 && t.completed == 1), "{net:?}");
    assert!(net.balanced(), "{net:?}");
}

#[test]
fn protocol_violating_first_frame_is_counted_and_answered() {
    // A valid frame that is not Hello arrives first. The server READ
    // it, so the ledger must count it (the old path dropped it from
    // frames_in), and the peer gets a zero-counter Goodbye, not a
    // silent close.
    let svc = open(&["census"], false);
    let server =
        PipelineServer::start(Arc::clone(&svc), "127.0.0.1:0", ServerConfig::default())
            .unwrap();
    let mut raw = TcpStream::connect(server.local_addr()).unwrap();
    wire::write_frame(&mut raw, &Frame::Drain).unwrap();
    match wire::read_frame(&mut raw).unwrap().unwrap() {
        Frame::Goodbye { completed, shed, failed, shed_by_cause } => {
            assert_eq!((completed, shed, failed), (0, 0, 0));
            assert_eq!(shed_by_cause, [0; wire::SHED_CAUSE_COUNT]);
        }
        other => panic!("expected Goodbye, got {}", other.kind()),
    }
    assert!(wire::read_frame(&mut raw).unwrap().is_none(), "closed after the refusal");
    drop(raw);
    let net = server.drain();
    assert_eq!(net.accepted, 1);
    assert_eq!(net.drained, 1);
    assert_eq!(net.frames_in, 1, "the violating frame IS counted");
    assert_eq!(net.frames_out, 1, "exactly the Goodbye went out");
    assert!(net.balanced(), "{net:?}");
}

#[test]
fn server_drain_completes_while_connections_park_at_the_inflight_cap() {
    // A connection parked AT conn_inflight (pending full, service
    // paused, reading nothing) must still complete a server drain: the
    // timer wakes the parked task, it observes the drain flag, flushes
    // both tickets once the service resumes, and closes with an honest
    // Goodbye — zero lost responses.
    let cap = 2u64;
    let svc = open(&["census"], true);
    let server = PipelineServer::start(
        Arc::clone(&svc),
        "127.0.0.1:0",
        ServerConfig { conn_inflight: cap as usize, ..Default::default() },
    )
    .unwrap();
    let mut client = ServeClient::connect(server.local_addr(), "t-cap").unwrap();
    for _ in 0..4 {
        client.send("census", Priority::Normal, None, wire::WirePayload::Synthetic).unwrap();
    }
    // The task admits exactly `cap` requests then parks; with the
    // service paused nothing can resolve, so admitted can never exceed
    // the cap — the spin bounds liveness, the counter is the assertion.
    spin_until("connection parked at its in-flight cap", || {
        server.report().tenants.get("t-cap").is_some_and(|t| t.admitted == cap)
    });
    assert_eq!(server.report().tenants["t-cap"].admitted, cap);

    let addr = server.local_addr();
    let drainer = std::thread::spawn(move || server.drain());
    // Order matters: the drain flag must be visibly set before the
    // service resumes, or the waking task could admit the two unread
    // requests. The accept loop retires (and new connects are refused)
    // only AFTER the flag is stored, so this spin is the barrier.
    spin_until("accept loop retired", || TcpStream::connect(addr).is_err());
    svc.resume();
    let mut completed = 0u64;
    loop {
        match client.recv().unwrap() {
            Frame::Completed(_) => completed += 1,
            Frame::Goodbye { completed: done, shed, failed, .. } => {
                assert_eq!((done, shed, failed), (cap, 0, 0));
                break;
            }
            other => panic!("unexpected {} during drain", other.kind()),
        }
    }
    assert_eq!(completed, cap, "every parked ticket flushed, zero lost");
    let net = drainer.join().expect("drain thread");
    assert_eq!(net.accepted, 1);
    assert_eq!(net.drained, 1);
    assert!(net.balanced(), "{net:?}");
    let t = &net.tenants["t-cap"];
    assert_eq!((t.admitted, t.completed), (cap, cap), "unread requests were never admitted");
}

#[test]
fn long_lived_server_drains_connections_as_it_runs() {
    // Regression for the JoinHandle hoard: connection state is fully
    // retired WHILE the server keeps running — the drained counter grows
    // live and active() returns to zero after every departure, without
    // a server shutdown to sweep up.
    let svc = open(&["census"], false);
    let server =
        PipelineServer::start(Arc::clone(&svc), "127.0.0.1:0", ServerConfig::default())
            .unwrap();
    for i in 0..5u64 {
        let mut c = ServeClient::connect(server.local_addr(), "t-seq").unwrap();
        match c.call("census", Priority::Normal, None, wire::WirePayload::Synthetic).unwrap() {
            Frame::Completed(_) => {}
            other => panic!("expected Completed, got {}", other.kind()),
        }
        let (done, shed, failed, _) = c.drain().unwrap();
        assert_eq!((done, shed, failed), (1, 0, 0));
        spin_until("connection retired while the server runs", || {
            server.report().drained as u64 == i + 1
        });
        assert_eq!(server.report().active(), 0, "no lingering per-connection state");
    }
    let net = server.drain();
    assert_eq!(net.accepted, 5);
    assert_eq!(net.drained, 5);
    assert_eq!(net.tenants["t-seq"].completed, 5);
    assert!(net.balanced(), "{net:?}");
}
