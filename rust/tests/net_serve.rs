//! Loopback soak suite for the TCP serving edge (`repro::net`): a real
//! `PipelineServer` on an ephemeral port, driven over real sockets by
//! wire clients. Every contract is pinned from **counters** — the
//! `NetReport` ledger, `Goodbye` frames, and client-side tallies —
//! never from wall-clock:
//!
//! * per-tenant admission lanes shed **deterministically** at a fixed
//!   `per_tenant_depth`: a paused service makes admission synchronous,
//!   so K requests against a depth-D lane yield exactly K−D first-class
//!   `Shed(TenantLaneFull)` frames — per tenant, never per connection;
//! * graceful drain loses **zero** responses: every in-flight ticket at
//!   drain time resolves, is written, and lands in a `Goodbye` whose
//!   counters agree with the client's own ledger;
//! * the closed-loop load generator (`run_load`, the engine behind
//!   `repro bench-serve`) balances end-to-end: the server's per-tenant
//!   ledger equals the fleet's client-side outcome record exactly.

use repro::net::wire::{self, Frame};
use repro::net::{run_load, LoadSpec, PipelineServer, ServeClient, ServerConfig};
use repro::pipelines::{RunConfig, Toggles};
use repro::service::{PipelineService, Priority, ServiceConfig};
use std::sync::Arc;

fn tiny() -> RunConfig {
    RunConfig { toggles: Toggles::optimized(), scale: 0.05, seed: 0x51, ..Default::default() }
}

fn open(names: &[&str], paused: bool) -> Arc<PipelineService> {
    Arc::new(
        PipelineService::open(
            names,
            ServiceConfig {
                defaults: tiny(),
                queue_depth: 32,
                workers: 2,
                start_paused: paused,
                skip_unavailable: false,
            },
        )
        .expect("tabular pipelines always open"),
    )
}

#[test]
fn tenant_lanes_shed_deterministically_at_fixed_depth() {
    // Paused service: admitted requests pend (nothing resolves), so the
    // lane occupancy is exact — tenant t's requests 1..=D occupy the
    // lane and D+1..=K shed with TenantLaneFull, deterministically.
    let depth = 3u64;
    let per_tenant = 8u64;
    let svc = open(&["census"], true);
    let server = PipelineServer::start(
        Arc::clone(&svc),
        "127.0.0.1:0",
        ServerConfig { per_tenant_depth: depth as usize, ..Default::default() },
    )
    .unwrap();

    let mut clients: Vec<ServeClient> = ["alpha", "beta"]
        .iter()
        .map(|tenant| ServeClient::connect(server.local_addr(), tenant).unwrap())
        .collect();
    for client in &mut clients {
        for _ in 0..per_tenant {
            client
                .send("census", Priority::Normal, None, wire::WirePayload::Synthetic)
                .unwrap();
        }
        // With the service paused the ONLY response frames are the lane
        // sheds — exactly K − D of them, ids D+1..=K, all TenantLaneFull.
        for expect_id in (depth + 1)..=per_tenant {
            match client.recv().unwrap() {
                Frame::Shed { id, cause, waited_us, .. } => {
                    assert_eq!(id, expect_id, "sheds arrive in request order");
                    assert_eq!(cause, wire::ShedCause::TenantLaneFull);
                    assert_eq!(waited_us, 0, "lane sheds never enter the queue");
                }
                other => panic!("expected Shed, got {}", other.kind()),
            }
        }
    }

    // The server-side ledger agrees per tenant BEFORE anything resolves:
    // every request frame admitted, K − D shed, zero completed.
    let report = clients[0].stats().unwrap();
    for tenant in ["alpha", "beta"] {
        let t = report.tenants.get(tenant).unwrap_or_else(|| panic!("{tenant} ledger"));
        assert_eq!(t.admitted, per_tenant, "{tenant}");
        assert_eq!(t.shed, per_tenant - depth, "{tenant}");
        assert_eq!(t.completed, 0, "{tenant}: nothing resolves while paused");
    }

    // Resume and drain each connection: the Goodbye counters pin the
    // outcome split (D completed, K − D shed) per tenant — with every
    // shed attributed to the tenant lane, none to the queue or deadline.
    svc.resume();
    for client in clients {
        let (completed, shed, failed, by_cause) = client.drain().unwrap();
        assert_eq!((completed, shed, failed), (depth, per_tenant - depth, 0));
        assert_eq!(
            by_cause[wire::ShedCause::TenantLaneFull.index()],
            per_tenant - depth,
            "every shed is a lane shed"
        );
        assert_eq!(by_cause.iter().sum::<u64>(), shed);
    }
    let report = server.drain();
    assert!(report.balanced(), "{report:?}");
    assert_eq!(report.accepted, 2);
    assert_eq!(report.drained, 2);
}

#[test]
fn server_drain_flushes_every_in_flight_response() {
    // Requests are in flight (paused service) when the server starts
    // draining: the handler must flush ALL of them — written to the
    // socket, counted in Goodbye — and the final ledger must balance.
    let svc = open(&["census"], true);
    let server =
        PipelineServer::start(Arc::clone(&svc), "127.0.0.1:0", ServerConfig::default())
            .unwrap();
    let mut client = ServeClient::connect(server.local_addr(), "t-drain").unwrap();
    let in_flight = 3u64;
    for _ in 0..in_flight {
        client.send("census", Priority::Normal, None, wire::WirePayload::Synthetic).unwrap();
    }
    // Counter sync (no sleeps): the stats reply is written after every
    // request frame before it was handled, so admitted == 3 here.
    let report = client.stats().unwrap();
    assert_eq!(report.tenants["t-drain"].admitted, in_flight);
    assert_eq!(report.tenants["t-drain"].completed, 0);

    // Server-initiated drain races nothing: the drain thread blocks
    // until handlers flush, which requires the resumed service.
    let drainer = std::thread::spawn(move || server.drain());
    svc.resume();

    // The client reads every in-flight response, then the Goodbye.
    let mut completed = 0u64;
    loop {
        match client.recv().unwrap() {
            Frame::Completed(c) => {
                assert!(!c.summary.is_empty());
                completed += 1;
            }
            Frame::Goodbye { completed: done, shed, failed, shed_by_cause } => {
                assert_eq!((done, shed, failed), (in_flight, 0, 0));
                assert_eq!(shed_by_cause, [0; wire::SHED_CAUSE_COUNT]);
                break;
            }
            other => panic!("unexpected {} during drain", other.kind()),
        }
    }
    assert_eq!(completed, in_flight, "zero responses lost to the drain");

    let report = drainer.join().expect("drain thread");
    assert!(report.balanced(), "{report:?}");
    assert_eq!(report.accepted, report.drained);
    let t = &report.tenants["t-drain"];
    assert_eq!((t.admitted, t.completed, t.shed, t.failed), (in_flight, in_flight, 0, 0));
}

#[test]
fn queue_expiry_sheds_are_deterministic_and_attributed_per_cause() {
    // Deterministic DeadlineExpired sheds, no timing assertions: the
    // service starts PAUSED, so submitted requests sit in the queue.
    // Each request carries Some(Duration::ZERO) — which the wire codec
    // saturates to a 1 ms deadline instead of aliasing the "no
    // deadline" sentinel — so by the time the service resumes (after a
    // queue wait of at least one stats round trip plus a guard sleep),
    // the dispatcher finds every deadline long expired and sheds each
    // request with ShedReason::DeadlineExpired. A deadline-less control
    // request on the same connection completes normally.
    use std::time::Duration;
    let svc = open(&["census"], true);
    let server =
        PipelineServer::start(Arc::clone(&svc), "127.0.0.1:0", ServerConfig::default())
            .unwrap();
    let mut client = ServeClient::connect(server.local_addr(), "t-deadline").unwrap();
    let expire = 3u64;
    for _ in 0..expire {
        client
            .send("census", Priority::Normal, Some(Duration::ZERO), wire::WirePayload::Synthetic)
            .unwrap();
    }
    client.send("census", Priority::Normal, None, wire::WirePayload::Synthetic).unwrap();
    // Counter sync: the stats reply proves all four requests were
    // admitted to the (paused) queue before the resume below.
    let report = client.stats().unwrap();
    assert_eq!(report.tenants["t-deadline"].admitted, expire + 1);
    assert_eq!(report.tenants["t-deadline"].completed, 0);
    // Guard: even a 1 ms deadline is comfortably expired at dispatch.
    // (Determinism guard on queue wait, not a timing assertion.)
    std::thread::sleep(Duration::from_millis(10));
    svc.resume();
    let (completed, shed, failed, by_cause) = client.drain().unwrap();
    assert_eq!((completed, shed, failed), (1, expire, 0));
    assert_eq!(
        by_cause[wire::ShedCause::DeadlineExpired.index()],
        expire,
        "every expired request is attributed to DeadlineExpired: {by_cause:?}"
    );
    assert_eq!(by_cause[wire::ShedCause::TenantLaneFull.index()], 0);
    assert_eq!(by_cause[wire::ShedCause::QueueFull.index()], 0);
    assert_eq!(by_cause.iter().sum::<u64>(), shed);
    let net = server.drain();
    assert!(net.balanced(), "{net:?}");
    let t = &net.tenants["t-deadline"];
    assert_eq!((t.admitted, t.completed, t.shed, t.failed), (expire + 1, 1, expire, 0));
}

#[test]
fn closed_loop_load_generator_balances_server_and_client_ledgers() {
    // The bench-serve engine end-to-end: 2 generator threads, 2 tenants
    // (tenant == pipeline), weighted census:2,plasticc:1 mix. Closed
    // loop means at most `clients` requests in flight per tenant — well
    // under the lane depth — so the outcome is fully deterministic:
    // everything completes, and the server's per-tenant ledger equals
    // the fleet's client-side record.
    let svc = open(&["census", "plasticc"], false);
    let server =
        PipelineServer::start(Arc::clone(&svc), "127.0.0.1:0", ServerConfig::default())
            .unwrap();
    let spec = LoadSpec {
        clients: 2,
        requests: 6,
        mix: vec![("census".to_string(), 2), ("plasticc".to_string(), 1)],
    };
    let load = run_load(server.local_addr(), &spec).unwrap();
    let net = server.drain();

    assert!(load.balances(), "{load:?}");
    assert!(net.balanced(), "{net:?}");
    // 2 clients x 2 mix entries = 4 connections, all drained.
    assert_eq!(net.accepted, 4);
    assert_eq!(net.drained, 4);
    // Weighted round-robin over 6 requests: census gets slots {0,1,3,4},
    // plasticc slots {2,5} — per client.
    let total: u64 = load.per_tenant.values().map(|t| t.requests).sum();
    assert_eq!(total, (spec.clients * spec.requests) as u64);
    assert_eq!(load.per_tenant["census"].requests, 8);
    assert_eq!(load.per_tenant["plasticc"].requests, 4);
    for (tenant, client_side) in &load.per_tenant {
        assert_eq!(client_side.completed, client_side.requests, "{tenant}: nothing sheds");
        assert_eq!(client_side.failed, 0, "{tenant}");
        let server_side = net.tenants.get(tenant).unwrap_or_else(|| panic!("{tenant}"));
        assert_eq!(server_side.admitted, client_side.requests, "{tenant}");
        assert_eq!(server_side.completed, client_side.completed, "{tenant}");
        assert_eq!(server_side.shed, 0, "{tenant}");
    }
    // The trajectory rendering carries every tenant with latency samples.
    let pipelines = load.trajectory_pipelines();
    for tenant in ["census", "plasticc"] {
        let entry = pipelines
            .get(tenant)
            .and_then(|p| p.get("exec_modes"))
            .and_then(|m| m.get("serve"))
            .unwrap_or_else(|| panic!("{tenant} serve entry"));
        assert!(entry.get("p50_ms").is_some());
        assert!(entry.get("items_per_s").is_some());
    }
    // The service underneath saw exactly the offered load.
    let stats = svc.stats();
    assert_eq!(stats.completed, total);
    assert!(stats.balances(), "{stats:?}");
}
