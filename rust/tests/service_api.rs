//! Service-API acceptance suite: a deterministic soak drives several
//! pipelines through one `PipelineService` with mixed priorities and a
//! bounded admission queue. The contracts pinned here:
//!
//! * unshedded responses carry metrics **identical** to a direct
//!   `run_plan` at the same seed — serving never changes answers;
//! * once the queue depth is exceeded, low-priority requests resolve as
//!   first-class `Response::Shed` values — never a panic, an error, or
//!   partial metrics — and high-priority requests displace queued
//!   low-priority ones;
//! * per-request latency lands in the `ScalingReport` machinery so the
//!   soak reports the same p50/p95 quantities as the §3.4 bench;
//! * steady state is compile-once: after a session opens, requests bind
//!   the cached `CompiledPipeline` — zero plan-graph rebuilds and zero
//!   warm round-trips, asserted from `BindReport` and the warm-RPC
//!   counter (never timing).
//!
//! The tabular three need no artifacts, so the soak always runs; the
//! DL session test degrades to a skip without `make artifacts`.

use repro::pipelines::{self, RunConfig, Toggles, Workload};
use repro::service::{
    PipelineService, Priority, Request, Response, ServiceConfig, Session, ShedReason,
};
use std::sync::Mutex;
use std::time::Duration;

const TABULAR: [&str; 3] = ["census", "plasticc", "iiot"];

/// Serializes the tests that either assert on the process-wide warm-RPC
/// counter or issue warm round-trips (opening DL sessions), so the
/// zero-warm steady-state window is never polluted by a concurrent
/// session open in this binary.
static WARM_WINDOW: Mutex<()> = Mutex::new(());

fn warm_window_guard() -> std::sync::MutexGuard<'static, ()> {
    WARM_WINDOW.lock().unwrap_or_else(|e| e.into_inner())
}

fn cfg() -> RunConfig {
    RunConfig { toggles: Toggles::optimized(), scale: 0.1, seed: 0xE9, ..Default::default() }
}

fn service(depth: usize, workers: usize, paused: bool) -> PipelineService {
    PipelineService::open(
        &TABULAR,
        ServiceConfig {
            defaults: cfg(),
            queue_depth: depth,
            workers,
            start_paused: paused,
            skip_unavailable: false,
        },
    )
    .expect("tabular pipelines always open")
}

#[test]
fn service_metrics_match_direct_run_plan() {
    let svc = service(16, 2, false);
    for name in TABULAR {
        let entry = pipelines::find(name).unwrap();
        let direct = pipelines::run_plan(entry.plan, &cfg()).unwrap();
        let resp = svc.call(Request::synthetic(name)).unwrap();
        let c = resp.completion().unwrap_or_else(|| panic!("{name}: {resp:?}"));
        assert_eq!(c.result.metrics, direct.metrics, "{name} metrics drifted under serving");
        assert_eq!(c.result.items, direct.items, "{name}");
        assert_eq!(c.pipeline, name);
        // The typed output is a projection of the same metrics (compare
        // rendered form: uncomputed fields are NaN, and NaN != NaN).
        assert_eq!((entry.output)(&direct).summary(), c.output.summary(), "{name}");
    }
}

#[test]
fn soak_mixed_priorities_sheds_low_beyond_depth() {
    // Paused service: admission is deterministic because nothing drains
    // until resume().
    let depth = 4;
    let svc = service(depth, 2, true);

    // Fill the queue with normal-priority requests round-robin over the
    // three pipelines.
    let fill: Vec<_> = (0..depth)
        .map(|i| svc.submit(Request::synthetic(TABULAR[i % TABULAR.len()])).unwrap())
        .collect();

    // A low-priority request beyond the bound is shed immediately …
    let low = svc.submit(Request::synthetic("census").with_priority(Priority::Low)).unwrap();
    match low.wait() {
        Response::Shed { pipeline, priority, reason, .. } => {
            assert_eq!(pipeline, "census");
            assert_eq!(priority, Priority::Low);
            assert_eq!(reason, ShedReason::QueueFull);
        }
        other => panic!("low-priority overflow must shed, got {other:?}"),
    }

    // … while a high-priority request displaces the newest queued
    // normal-priority entry (the last fill ticket).
    let high = svc.submit(Request::synthetic("iiot").with_priority(Priority::High)).unwrap();
    let mut fill = fill;
    let displaced = fill.pop().unwrap();
    match displaced.wait() {
        Response::Shed { priority, reason, .. } => {
            assert_eq!(priority, Priority::Normal);
            assert_eq!(reason, ShedReason::QueueFull);
        }
        other => panic!("displaced normal request must shed, got {other:?}"),
    }

    // Drain: every surviving request completes with full metrics equal to
    // a direct run at the same seed.
    svc.resume();
    for (i, ticket) in fill.into_iter().enumerate() {
        let name = TABULAR[i % TABULAR.len()];
        let resp = ticket.wait();
        let c = resp.completion().unwrap_or_else(|| panic!("{name}: {resp:?}"));
        let entry = pipelines::find(name).unwrap();
        let direct = pipelines::run_plan(entry.plan, &cfg()).unwrap();
        assert_eq!(c.result.metrics, direct.metrics, "{name} after soak");
        assert!(!c.result.report.stages.is_empty(), "{name} report missing");
    }
    let c = high.wait();
    let c = c.completion().expect("high-priority request completes");
    assert_eq!(c.pipeline, "iiot");
    assert_eq!(c.priority, Priority::High);

    // Counters: depth + 1 admitted (fill + high), 2 shed (low + displaced).
    let qs = svc.queue_stats();
    assert_eq!(qs.admitted, depth as u64 + 1);
    assert_eq!(qs.shed, 2);
    assert_eq!(qs.peak_depth, depth);
    let stats = svc.stats();
    assert_eq!(stats.completed, depth as u64);
    assert_eq!(stats.shed, 2);
    assert_eq!(stats.failed, 0);

    // Per-request latency flows into the scaling machinery.
    let report = svc.scaling_report();
    let served: usize = report.instances.iter().map(|i| i.items).sum();
    assert_eq!(served, depth);
    let samples: usize = report.instances.iter().map(|i| i.latencies.len()).sum();
    assert_eq!(samples, depth);
    let p50 = report.latency_p50().expect("latency samples recorded");
    let p95 = report.latency_p95().unwrap();
    assert!(p95 >= p50);
}

#[test]
fn external_payload_matches_synthetic_payload() {
    // A session serving an externally supplied payload (here: the same
    // bytes the generator would produce) reports identical metrics.
    let svc = service(8, 1, false);
    for name in TABULAR {
        let payload = svc.session(name).unwrap().payload();
        let external = svc
            .call(Request::synthetic(name).with_payload(payload))
            .unwrap();
        let synthetic = svc.call(Request::synthetic(name)).unwrap();
        assert_eq!(
            external.completion().unwrap().result.metrics,
            synthetic.completion().unwrap().result.metrics,
            "{name}"
        );
    }
}

#[test]
fn mismatched_payload_is_a_failed_response_not_a_panic() {
    let svc = service(8, 1, false);
    let resp = svc
        .call(Request::synthetic("census").with_payload(Workload::ReviewLog {
            json: String::new(),
        }))
        .unwrap();
    match resp {
        Response::Failed { pipeline, error } => {
            assert_eq!(pipeline, "census");
            assert!(error.contains("review_log"), "{error}");
        }
        other => panic!("expected Failed, got {other:?}"),
    }
    assert_eq!(svc.stats().failed, 1);
}

#[test]
fn expired_deadline_sheds_at_dispatch() {
    let svc = service(8, 1, true);
    let ticket = svc
        .submit(Request::synthetic("census").with_deadline(Duration::ZERO))
        .unwrap();
    // Give the queued request a measurable wait before workers start.
    std::thread::sleep(Duration::from_millis(5));
    svc.resume();
    match ticket.wait() {
        Response::Shed { reason, waited, .. } => {
            assert_eq!(reason, ShedReason::DeadlineExpired);
            assert!(waited > Duration::ZERO);
        }
        other => panic!("expected deadline shed, got {other:?}"),
    }
}

#[test]
fn service_runs_under_every_executor() {
    // The session executor is part of the config: the same service soak
    // under streaming and multi:2 still matches direct runs on every
    // deterministic metric (scaling_* carry wall-clock throughput).
    use repro::coordinator::ExecMode;
    use std::collections::BTreeMap;
    let deterministic = |m: &BTreeMap<String, f64>| -> BTreeMap<String, f64> {
        m.iter()
            .filter(|(k, _)| !k.starts_with("scaling_"))
            .map(|(k, v)| (k.clone(), *v))
            .collect()
    };
    for exec in [
        ExecMode::Streaming,
        ExecMode::MultiInstance(2),
        ExecMode::Sharded(3),
        ExecMode::Async(2),
    ] {
        let defaults = RunConfig { exec, ..cfg() };
        let svc = PipelineService::open(
            &["census"],
            ServiceConfig { defaults, queue_depth: 4, workers: 1, ..Default::default() },
        )
        .unwrap();
        let resp = svc.call(Request::synthetic("census")).unwrap();
        let direct = pipelines::run_by_name("census", &defaults).unwrap();
        let c = resp.completion().unwrap_or_else(|| panic!("{exec}: {resp:?}"));
        assert_eq!(
            deterministic(&c.result.metrics),
            deterministic(&direct.metrics),
            "{exec}"
        );
        assert_eq!(c.result.items, direct.items, "{exec}");
    }
}

#[test]
fn sharded_session_answers_equal_sequential_session_answers() {
    // One dataset, partitioned: a sharded session's Response carries the
    // exact metric map a sequential session produces (no scaling_* or
    // shard_* keys sneak in), and the partition report tags the result.
    use repro::coordinator::ExecMode;
    let seq_svc = service(4, 1, false);
    for n in [1usize, 2, 4] {
        let defaults = RunConfig { exec: ExecMode::Sharded(n), ..cfg() };
        let svc = PipelineService::open(
            &TABULAR,
            ServiceConfig { defaults, queue_depth: 4, workers: 1, ..Default::default() },
        )
        .unwrap();
        for name in TABULAR {
            let sharded = svc.call(Request::synthetic(name)).unwrap();
            let sequential = seq_svc.call(Request::synthetic(name)).unwrap();
            let s = sharded.completion().unwrap_or_else(|| panic!("{name} shard:{n}"));
            let q = sequential.completion().unwrap();
            assert_eq!(s.result.metrics, q.result.metrics, "{name} shard:{n}");
            assert_eq!(s.result.items, q.result.items, "{name} shard:{n}");
            let sharding =
                s.result.sharding.as_ref().unwrap_or_else(|| panic!("{name} shard:{n}"));
            assert_eq!(sharding.shard_count(), n, "{name}");
            assert!(q.result.sharding.is_none(), "{name}: sequential runs carry no shards");
        }
    }
}

#[test]
fn async_service_soak_completes_every_ticket_and_balances_stats() {
    // The async-session soak: a census:4,dlsa:1-style weighted mix on
    // ONE dispatcher over a two-worker shared pool (dlsa degrades to a
    // skip on checkouts without artifacts). Every non-shed ticket
    // completes with metrics identical to a direct async run at the
    // same seed, the ServiceStats ledger balances exactly
    // (submitted == completed + shed + failed), per-request p50 ≤ p95
    // through the ScalingReport machinery, and the shared pool's
    // scheduler counters balance once nothing is in flight.
    use repro::coordinator::ExecMode;
    use std::collections::BTreeMap;
    let _guard = warm_window_guard();
    let defaults = RunConfig { exec: ExecMode::Async(2), ..cfg() };
    let svc = PipelineService::open(
        &["census", "dlsa"],
        ServiceConfig {
            defaults,
            queue_depth: 64,
            workers: 1,
            start_paused: false,
            skip_unavailable: true,
        },
    )
    .expect("census always opens; dlsa skips without artifacts");

    let mut schedule: Vec<&str> = Vec::new();
    for (name, weight) in [("census", 4usize), ("dlsa", 1)] {
        if svc.session(name).is_some() {
            schedule.extend(std::iter::repeat(name).take(weight));
        }
    }
    assert!(!schedule.is_empty());

    let requests = 15usize;
    let tickets: Vec<_> = (0..requests)
        .map(|i| svc.submit(Request::synthetic(schedule[i % schedule.len()])).unwrap())
        .collect();

    // Direct async-run reference per pipeline, computed once.
    let mut direct: BTreeMap<&str, repro::pipelines::PipelineResult> = BTreeMap::new();
    for &name in &schedule {
        if !direct.contains_key(name) {
            direct.insert(name, pipelines::run_by_name(name, &defaults).unwrap());
        }
    }

    for (i, ticket) in tickets.into_iter().enumerate() {
        let name = schedule[i % schedule.len()];
        let resp = ticket.wait();
        let c = resp.completion().unwrap_or_else(|| panic!("{name}: {resp:?}"));
        assert_eq!(c.pipeline, name);
        // Census metrics are fully deterministic; compare the whole map.
        if name == "census" {
            assert_eq!(c.result.metrics, direct[name].metrics, "{name} drifted under serving");
        }
        assert_eq!(c.result.items, direct[name].items, "{name}");
    }

    let stats = svc.stats();
    assert_eq!(stats.submitted, requests as u64);
    assert_eq!(stats.completed, requests as u64);
    assert_eq!(stats.shed, 0);
    assert_eq!(stats.failed, 0);
    assert!(stats.balances(), "{stats:?}");

    // Per-request latency flows into the scaling machinery: one sample
    // per completion, p50 ≤ p95.
    let report = svc.scaling_report();
    let served: usize = report.instances.iter().map(|i| i.items).sum();
    assert_eq!(served, requests);
    let samples: usize = report.instances.iter().map(|i| i.latencies.len()).sum();
    assert_eq!(samples, requests);
    let p50 = report.latency_p50().expect("latency samples recorded");
    let p95 = report.latency_p95().unwrap();
    assert!(p95 >= p50);

    // The shared pool's ledger balances with nothing in flight.
    let sc = svc.scheduler_counters().expect("async service exposes pool counters");
    assert!(sc.balanced(), "{sc:?}");
    assert_eq!(sc.workers, 2);
    assert!(sc.max_in_flight <= sc.workers, "{sc:?}");
}

#[test]
fn async_service_sheds_deterministically_at_fixed_depth() {
    // Admission is synchronous and executor-independent: a paused async
    // service at depth 2 sheds the low-priority overflow immediately,
    // completes everything else after resume, and the ledger balances.
    use repro::coordinator::ExecMode;
    let defaults = RunConfig { exec: ExecMode::Async(2), ..cfg() };
    let svc = PipelineService::open(
        &["census"],
        ServiceConfig {
            defaults,
            queue_depth: 2,
            workers: 1,
            start_paused: true,
            skip_unavailable: false,
        },
    )
    .unwrap();
    let fill: Vec<_> =
        (0..2).map(|_| svc.submit(Request::synthetic("census")).unwrap()).collect();
    let low = svc.submit(Request::synthetic("census").with_priority(Priority::Low)).unwrap();
    match low.poll() {
        Some(Response::Shed { priority, reason, .. }) => {
            assert_eq!(priority, Priority::Low);
            assert_eq!(reason, ShedReason::QueueFull);
        }
        other => panic!("low overflow must shed before resume, got {other:?}"),
    }
    svc.resume();
    for t in fill {
        assert!(t.wait().completion().is_some(), "queued async request must complete");
    }
    let stats = svc.stats();
    assert_eq!(stats.submitted, 3);
    assert_eq!(stats.completed, 2);
    assert_eq!(stats.shed, 1);
    assert_eq!(stats.failed, 0);
    assert!(stats.balances(), "{stats:?}");
}

#[test]
fn steady_state_requests_never_rebuild_graphs_or_rewarm_models() {
    // The acceptance pin for compile-once serving, from counters and
    // never timing: after open, N requests (sequential AND sharded
    // sessions, DL included when artifacts exist) perform ZERO plan
    // graph rebuilds (BindReport.compiles frozen at one per session,
    // binds growing with requests) and ZERO warm round-trips (the
    // process-wide warm-RPC counter does not move across the window).
    use repro::coordinator::ExecMode;
    let _guard = warm_window_guard();
    let names: Vec<&str> = if Session::open("dlsa", cfg()).is_ok() {
        vec!["census", "dlsa"]
    } else {
        vec!["census", "plasticc"]
    };
    let svc = PipelineService::open(
        &names,
        ServiceConfig { defaults: cfg(), queue_depth: 32, workers: 2, ..Default::default() },
    )
    .unwrap();
    // Steady-state window starts AFTER open (open is allowed to warm).
    let warm_before = repro::runtime::warm_rpc_count();
    let requests = 8usize;
    let tickets: Vec<_> = (0..requests)
        .map(|i| svc.submit(Request::synthetic(names[i % names.len()])).unwrap())
        .collect();
    for t in tickets {
        assert!(t.wait().completion().is_some(), "steady-state request must complete");
    }
    assert_eq!(
        repro::runtime::warm_rpc_count(),
        warm_before,
        "steady-state requests must not issue warm round-trips"
    );
    let total = svc.bind_report_total();
    assert_eq!(total.compiles, names.len(), "one graph build per session, ever");
    assert_eq!(total.binds as usize, requests, "one bind per served request");
    for (name, br) in svc.bind_reports() {
        assert_eq!(br.compiles, 1, "{name}");
    }

    // Sharded sessions bind pre-sliced shard plans from the same cached
    // graph — several binds per request, still zero rebuilds and zero
    // warm round-trips.
    let shards = 3usize;
    let sharded_cfg = RunConfig { exec: ExecMode::Sharded(shards), ..cfg() };
    let sharded = PipelineService::open(
        &["census"],
        ServiceConfig { defaults: sharded_cfg, queue_depth: 8, workers: 1, ..Default::default() },
    )
    .unwrap();
    let warm_before = repro::runtime::warm_rpc_count();
    for _ in 0..3 {
        assert!(sharded
            .call(Request::synthetic("census"))
            .unwrap()
            .completion()
            .is_some());
    }
    assert_eq!(repro::runtime::warm_rpc_count(), warm_before);
    let br = sharded.bind_report_total();
    assert_eq!(br.compiles, 1);
    assert_eq!(br.binds, 3 * shards, "one shard bind per shard per request");
}

#[test]
fn dl_session_opens_warm_or_skips_cleanly() {
    // With artifacts, a DLSA session opens warm (holding a model client)
    // and serves documents; without them it fails with the artifact error
    // the tests key on.
    let _guard = warm_window_guard();
    match Session::open("dlsa", cfg()) {
        Ok(session) => {
            assert!(session.client().is_some(), "dlsa session must hold a warm client");
            let (result, _) = session.execute(Workload::Synthetic).unwrap();
            assert!(result.items > 0);
        }
        Err(e) => {
            let msg = format!("{e:#}").to_lowercase();
            assert!(
                msg.contains("manifest") || msg.contains("artifact"),
                "unexpected dlsa open error: {e:#}"
            );
        }
    }
}
