//! Integration: every pipeline runs end-to-end at both optimization
//! levels, produces sane metrics, and the cross-level quality invariants
//! hold (optimizations must not change answers beyond tolerance).
//!
//! Pipelines that execute model artifacts skip cleanly when `make
//! artifacts` has not been run; the tabular three (census, plasticc,
//! iiot) are exercised unconditionally.

use repro::pipelines::{registry, run_by_name, RunConfig, Toggles};
use repro::OptLevel;

fn artifacts_ready() -> bool {
    repro::runtime::default_artifacts_dir().join("manifest.json").exists()
}

fn needs_artifacts(name: &str) -> bool {
    !matches!(name, "census" | "plasticc" | "iiot")
}

fn tiny(opt: OptLevel) -> RunConfig {
    RunConfig { toggles: Toggles::all(opt), scale: 0.1, seed: 0x1E57, ..Default::default() }
}

#[test]
fn every_pipeline_runs_at_both_levels() {
    for e in registry() {
        if needs_artifacts(e.name) && !artifacts_ready() {
            eprintln!("skipping {} (run `make artifacts` first)", e.name);
            continue;
        }
        for opt in OptLevel::ALL {
            let res = (e.run)(&tiny(opt))
                .unwrap_or_else(|err| panic!("{} @ {opt}: {err:#}", e.name));
            assert!(res.items > 0, "{} @ {opt}", e.name);
            assert!(!res.metrics.is_empty(), "{} @ {opt}", e.name);
            assert!(!res.report.stages.is_empty(), "{} @ {opt}", e.name);
            assert!(
                res.report.total().as_nanos() > 0,
                "{} @ {opt}: empty telemetry",
                e.name
            );
            // Every stage must have been visited.
            for s in &res.report.stages {
                assert!(s.items > 0, "{} @ {opt}: stage {} idle", e.name, s.name);
            }
        }
    }
}

#[test]
fn quality_metrics_meet_floors_when_optimized() {
    let floors: &[(&str, &str, f64)] = &[
        ("census", "r2", 0.85),
        ("plasticc", "auc", 0.8),
        ("iiot", "auc", 0.75),
        ("dlsa", "agreement_vs_fp32", 0.85),
        ("anomaly", "auc", 0.7),
        ("face", "match_rate", 0.6),
    ];
    for (name, metric, floor) in floors {
        if needs_artifacts(name) && !artifacts_ready() {
            continue;
        }
        let cfg = RunConfig {
            toggles: Toggles::optimized(),
            scale: 0.4,
            seed: 0xF100,
            ..Default::default()
        };
        let res = run_by_name(name, &cfg).unwrap();
        let v = res.metric(metric).unwrap_or(f64::NAN);
        assert!(v >= *floor, "{name}.{metric} = {v} < {floor}");
    }
}

#[test]
fn figure1_shape_holds() {
    // The paper's Figure 1 spread: tabular pipelines preprocessing-heavy,
    // DL pipelines AI-heavy. Check the ordering at a mid scale.
    let cfg = RunConfig {
        toggles: Toggles::optimized(),
        scale: 0.4,
        seed: 0xF1,
        ..Default::default()
    };
    let pre_pct = |name: &str| {
        let res = run_by_name(name, &cfg).unwrap();
        res.report.fig1_split().0
    };
    let census = pre_pct("census");
    let plasticc = pre_pct("plasticc");
    assert!(census > 50.0, "census pre={census}");
    assert!(plasticc > 50.0, "plasticc pre={plasticc}");
    if artifacts_ready() {
        let dlsa = pre_pct("dlsa");
        let anomaly = pre_pct("anomaly");
        assert!(dlsa < 50.0, "dlsa pre={dlsa}");
        assert!(anomaly < 50.0, "anomaly pre={anomaly}");
    }
}

#[test]
fn seeds_are_deterministic() {
    for name in ["census", "plasticc", "iiot"] {
        let cfg = RunConfig {
            toggles: Toggles::optimized(),
            scale: 0.1,
            seed: 77,
            ..Default::default()
        };
        let a = run_by_name(name, &cfg).unwrap();
        let b = run_by_name(name, &cfg).unwrap();
        for (k, v) in &a.metrics {
            let w = b.metric(k).unwrap();
            assert!((v - w).abs() < 1e-9, "{name}.{k}: {v} vs {w}");
        }
    }
}

#[test]
fn e2e_speedup_spread_direction() {
    // Figure 11's direction on a preprocessing-bound pipeline: optimized
    // beats baseline end-to-end at moderate scale.
    for name in ["census", "plasticc"] {
        let base = run_by_name(name, &tiny_scaled(OptLevel::Baseline)).unwrap();
        let opt = run_by_name(name, &tiny_scaled(OptLevel::Optimized)).unwrap();
        let speedup =
            base.report.total().as_secs_f64() / opt.report.total().as_secs_f64();
        assert!(speedup > 1.1, "{name}: E2E speedup {speedup}");
    }
}

fn tiny_scaled(opt: OptLevel) -> RunConfig {
    RunConfig { toggles: Toggles::all(opt), scale: 0.5, seed: 0x5EED, ..Default::default() }
}
