//! Hyperparameter / runtime-parameter tuning — the SigOpt stand-in (§3.3).
//!
//! The paper tunes PLAsTiCC's XGBoost hyperparameters and DLSA's
//! (instances × batch size) for multi-objective goals ("maximum throughput
//! at threshold accuracy"). This module implements the open equivalent:
//! a discrete search space, random search, and greedy coordinate descent,
//! optimizing a user-supplied objective under an accuracy constraint.

use crate::util::Rng;
use std::collections::HashMap;

/// A discrete search space: named parameters, each with candidate values.
#[derive(Debug, Clone, Default)]
pub struct SearchSpace {
    params: Vec<(String, Vec<f64>)>,
}

/// One configuration: parameter name → chosen value.
pub type Config = HashMap<String, f64>;

/// Result of evaluating one configuration.
#[derive(Debug, Clone, Copy)]
pub struct Eval {
    /// The quantity to maximize (e.g. throughput).
    pub objective: f64,
    /// The constrained metric (e.g. accuracy); must stay ≥ threshold.
    pub constraint: f64,
}

impl SearchSpace {
    /// Empty space.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add a parameter with candidate values.
    pub fn param(mut self, name: &str, values: &[f64]) -> Self {
        assert!(!values.is_empty());
        self.params.push((name.to_string(), values.to_vec()));
        self
    }

    /// Total number of configurations.
    pub fn cardinality(&self) -> usize {
        self.params.iter().map(|(_, v)| v.len()).product()
    }

    fn sample(&self, rng: &mut Rng) -> Config {
        self.params
            .iter()
            .map(|(name, vals)| (name.clone(), *rng.choice(vals)))
            .collect()
    }
}

/// Outcome of a tuning run.
#[derive(Debug, Clone)]
pub struct TuneResult {
    pub best: Config,
    pub best_eval: Eval,
    /// Every (config, eval) tried, in order.
    pub history: Vec<(Config, Eval)>,
}

/// Random search for `budget` evaluations; maximizes `objective` subject
/// to `constraint >= threshold`. Configurations violating the constraint
/// are recorded but never become `best` unless nothing satisfies it.
pub fn random_search(
    space: &SearchSpace,
    budget: usize,
    threshold: f64,
    seed: u64,
    mut evaluate: impl FnMut(&Config) -> Eval,
) -> TuneResult {
    let mut rng = Rng::new(seed);
    let mut history = Vec::with_capacity(budget);
    for _ in 0..budget {
        let cfg = space.sample(&mut rng);
        let ev = evaluate(&cfg);
        history.push((cfg, ev));
    }
    pick_best(history, threshold)
}

/// Greedy coordinate descent: start from the first value of every
/// parameter, then sweep parameters cyclically, keeping the best value per
/// coordinate. `sweeps` full cycles.
pub fn coordinate_descent(
    space: &SearchSpace,
    sweeps: usize,
    threshold: f64,
    mut evaluate: impl FnMut(&Config) -> Eval,
) -> TuneResult {
    let mut current: Config = space
        .params
        .iter()
        .map(|(n, v)| (n.clone(), v[0]))
        .collect();
    let mut history = Vec::new();
    let mut current_eval = evaluate(&current);
    history.push((current.clone(), current_eval));
    for _ in 0..sweeps {
        for (name, values) in &space.params {
            for &v in values {
                if current[name] == v {
                    continue;
                }
                let mut cand = current.clone();
                cand.insert(name.clone(), v);
                let ev = evaluate(&cand);
                history.push((cand.clone(), ev));
                if better(ev, current_eval, threshold) {
                    current = cand;
                    current_eval = ev;
                }
            }
        }
    }
    pick_best(history, threshold)
}

fn better(a: Eval, b: Eval, threshold: f64) -> bool {
    match (a.constraint >= threshold, b.constraint >= threshold) {
        (true, false) => true,
        (false, true) => false,
        (true, true) => a.objective > b.objective,
        // Both infeasible: prefer closer to feasibility.
        (false, false) => a.constraint > b.constraint,
    }
}

fn pick_best(history: Vec<(Config, Eval)>, threshold: f64) -> TuneResult {
    let mut best_i = 0;
    for i in 1..history.len() {
        if better(history[i].1, history[best_i].1, threshold) {
            best_i = i;
        }
    }
    TuneResult {
        best: history[best_i].0.clone(),
        best_eval: history[best_i].1,
        history,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn space() -> SearchSpace {
        SearchSpace::new()
            .param("n_trees", &[10.0, 20.0, 40.0, 80.0])
            .param("depth", &[2.0, 4.0, 6.0])
            .param("lr", &[0.1, 0.3])
    }

    /// Toy objective: throughput falls with trees*depth; accuracy rises.
    fn toy_eval(cfg: &Config) -> Eval {
        let work = cfg["n_trees"] * cfg["depth"];
        Eval {
            objective: 1000.0 / work,
            constraint: 1.0 - (-work / 60.0).exp(), // saturating accuracy
        }
    }

    #[test]
    fn cardinality() {
        assert_eq!(space().cardinality(), 24);
    }

    #[test]
    fn random_search_respects_constraint() {
        let res = random_search(&space(), 50, 0.8, 1, toy_eval);
        assert!(res.best_eval.constraint >= 0.8, "{:?}", res.best_eval);
        assert_eq!(res.history.len(), 50);
        // Best objective among feasible must not be beaten by any feasible
        // config in history.
        for (_, ev) in &res.history {
            if ev.constraint >= 0.8 {
                assert!(ev.objective <= res.best_eval.objective + 1e-12);
            }
        }
    }

    #[test]
    fn coordinate_descent_improves_over_start() {
        let res = coordinate_descent(&space(), 2, 0.8, toy_eval);
        let start = res.history[0].1;
        assert!(
            better(res.best_eval, start, 0.8) || res.best_eval.objective >= start.objective
        );
        assert!(res.best_eval.constraint >= 0.8);
    }

    #[test]
    fn coordinate_descent_reaches_its_fixed_point() {
        // Greedy CD is locally, not globally, optimal: from the (10, 2)
        // start the reachable fixed point on this toy is work = 160
        // (80 trees × depth 2) — feasible, and no single-coordinate move
        // from it is both feasible and better. Verify exactly that.
        let res = coordinate_descent(&space(), 3, 0.8, toy_eval);
        assert!(res.best_eval.constraint >= 0.8);
        let best_work = res.best["n_trees"] * res.best["depth"];
        assert_eq!(best_work, 160.0, "{:?}", res.best);
        // …and random search with enough budget finds the global optimum
        // (work = 120), beating CD — documenting why the paper pairs
        // SigOpt-style global search with manual tuning.
        let rs = random_search(&space(), 200, 0.8, 7, toy_eval);
        assert!(rs.best_eval.objective >= res.best_eval.objective);
        assert_eq!(rs.best["n_trees"] * rs.best["depth"], 120.0);
    }

    #[test]
    fn infeasible_everywhere_prefers_closest() {
        let res = random_search(&space(), 30, 2.0, 3, toy_eval); // impossible
        // Best must be the max-constraint config seen.
        let max_c = res
            .history
            .iter()
            .map(|(_, e)| e.constraint)
            .fold(f64::NEG_INFINITY, f64::max);
        assert!((res.best_eval.constraint - max_c).abs() < 1e-12);
    }
}
