//! # repro — End-to-End AI Pipeline Optimization on CPU
//!
//! Reproduction of *"Strategies for Optimizing End-to-End AI Pipelines on
//! Intel® Xeon® Processors"* (Arunachalam et al., 2022) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — a plan-based pipeline orchestrator
//!   ([`coordinator`]): every workload is declared once as a **plan** (a
//!   typed graph of categorized stage nodes) and executed by pluggable
//!   **executors** — sequential, thread-per-stage streaming with
//!   backpressure, multi-instance replication (§3.4), data-parallel
//!   sharding (one dataset partitioned round-robin across workers with a
//!   merge-aware sink whose fold streams ahead of the last shard), or
//!   cooperative task-based async execution (resumable stage tasks on a
//!   fixed worker pool — one pool multiplexes many in-flight plans when
//!   serving). On top sits the
//!   serving layer ([`service`]): a [`service::PipelineService`] opens
//!   warm per-pipeline [`service::Session`]s once and answers typed
//!   `Request { pipeline, payload, priority, deadline }` values through
//!   a bounded priority [`coordinator::AdmissionQueue`] with load
//!   shedding — the §3.4 many-streams deployment as an API instead of a
//!   bench loop — and the network edge ([`net`]): a TCP front-end
//!   speaking a length-prefixed wire protocol with per-tenant admission
//!   lanes, write backpressure, and counter-pinned graceful drain.
//!   Below both sits every substrate the paper's eight
//!   pipelines depend on: a columnar dataframe engine ([`dataframe`]),
//!   classical ML ([`ml`]), media/vision/text processing ([`media`],
//!   [`vision`], [`text`]), recommendation preprocessing ([`recsys`]),
//!   INT8 quantization ([`quant`]) and hyperparameter tuning ([`tune`]).
//! * **Layer 2** — JAX models (`python/compile/model.py`) AOT-lowered to
//!   HLO text artifacts.
//! * **Layer 1** — Pallas kernels (`python/compile/kernels/`) called by the
//!   L2 models.
//!
//! The [`runtime`] module loads the AOT artifacts through the PJRT C API
//! (`xla` crate; an offline stub under `rust/shims/` by default) so
//! Python never runs on the request path; cross-thread model access goes
//! through the [`runtime::ModelServer`], which is how streaming and
//! multi-instance executors share one compiled engine.
//!
//! Every pipeline stage exists in a **baseline** and an **optimized**
//! variant (see [`OptLevel`]); benchmarks toggle them to regenerate the
//! paper's Figure 1, Table 2 and Figure 11. See `DESIGN.md` for the full
//! experiment index.

pub mod util;
pub mod parallel;
pub mod dataframe;
pub mod linalg;
pub mod ml;
pub mod media;
pub mod vision;
pub mod text;
pub mod recsys;
pub mod quant;
pub mod tune;
pub mod runtime;
pub mod coordinator;
pub mod pipelines;
pub mod service;
pub mod net;

/// Which implementation variant of a pipeline stage to use.
///
/// `Baseline` reproduces the *algorithmic* behaviour of the unoptimized
/// stack the paper starts from (row-at-a-time pandas-like dataframe
/// interpretation, exact tree splits, unfused op-by-op DL graphs, FP32
/// inference). `Optimized` is the paper's tuned stack (columnar vectorized
/// dataframes, histogram trees, fused graphs, INT8).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OptLevel {
    /// Stock/unoptimized software stack (pandas, sklearn, op-by-op FP32 DL).
    Baseline,
    /// Fully optimized stack (Modin/sklearnex/XGBoost-hist analogues,
    /// fused graphs, INT8 where the paper quantizes).
    Optimized,
}

impl OptLevel {
    /// All variants, in bench order.
    pub const ALL: [OptLevel; 2] = [OptLevel::Baseline, OptLevel::Optimized];

    /// Short human-readable label used in reports.
    pub fn label(self) -> &'static str {
        match self {
            OptLevel::Baseline => "baseline",
            OptLevel::Optimized => "optimized",
        }
    }
}

impl std::fmt::Display for OptLevel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}
