//! Recommendation preprocessing substrate — the DIEN pipeline's front end.
//!
//! The paper (§2.5): "json input is parsed into dataframes, and feature
//! engineering tasks are further optimized to reduce serial code and
//! intermediate data" — then history sequences and negative samples are
//! built for the model. This module provides the synthetic Amazon-Books
//! stand-in (a JSON review log with Zipf-distributed item popularity) and
//! the feature-engineering steps in baseline/optimized variants.

pub mod log;
pub mod features;

pub use features::{build_examples, DienExample};
pub use log::{generate_log, parse_log, parse_log_via_dataframe, ReviewEvent};
