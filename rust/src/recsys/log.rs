//! Synthetic JSON review log (Amazon Books stand-in) and its parser.
//!
//! Schema per line: `{"user": "u123", "item": "b456", "ts": 1234, "rating": 5}`
//! — the JSON-lines layout the paper's DIEN preprocessing ingests. Item
//! popularity is Zipf-distributed, users have geometric activity levels.

use crate::util::json::Json;
use crate::util::Rng;

/// One parsed review event.
#[derive(Debug, Clone, PartialEq)]
pub struct ReviewEvent {
    pub user: String,
    pub item: String,
    pub ts: i64,
    pub rating: i64,
}

/// Generate a JSON-lines review log with `n_events` events over
/// `n_users`/`n_items`, deterministic in `seed`.
pub fn generate_log(n_events: usize, n_users: usize, n_items: usize, seed: u64) -> String {
    let mut rng = Rng::new(seed);
    let mut out = String::with_capacity(n_events * 64);
    for ts in 0..n_events {
        let user = rng.below(n_users);
        let item = rng.zipf(n_items, 1.1);
        let rating = 1 + rng.below(5);
        out.push_str(&format!(
            "{{\"user\": \"u{user}\", \"item\": \"b{item}\", \"ts\": {ts}, \"rating\": {rating}}}\n"
        ));
    }
    out
}

/// Baseline ingestion: the paper's "json input is parsed into dataframes"
/// done the object-path way — every line becomes boxed [`Value`]s, rows
/// are accumulated, a [`DataFrame`] is materialized column-by-column, and
/// the events are read *back out* of the frame. Twice the boxing and a
/// full intermediate dataframe, which is exactly the "serial code and
/// intermediate data" the paper says its optimized DIEN removed (§2.5).
pub fn parse_log_via_dataframe(text: &str) -> (Vec<ReviewEvent>, usize) {
    use crate::dataframe::{Column, DataFrame, Value};
    let mut rows: Vec<Vec<Value>> = Vec::new();
    let mut skipped = 0usize;
    for line in text.lines() {
        if line.trim().is_empty() {
            continue;
        }
        match Json::parse(line) {
            Ok(v) => {
                let row = (|| {
                    Some(vec![
                        Value::Str(v.get("user")?.as_str()?.to_string()),
                        Value::Str(v.get("item")?.as_str()?.to_string()),
                        Value::I64(v.get("ts")?.as_i64()?),
                        Value::I64(v.get("rating")?.as_i64()?),
                    ])
                })();
                match row {
                    Some(r) => rows.push(r),
                    None => skipped += 1,
                }
            }
            Err(_) => skipped += 1,
        }
    }
    // Materialize the intermediate dataframe (column-by-column boxing).
    let mut df = DataFrame::new();
    for (c, name) in ["user", "item", "ts", "rating"].iter().enumerate() {
        let vals: Vec<Value> = rows.iter().map(|r| r[c].clone()).collect();
        df.push(name, Column::from_values(&vals)).expect("log frame");
    }
    // ...and read the events back out of it, row by boxed row.
    let events = (0..df.nrows())
        .filter_map(|i| {
            let vals = df.row_values(i);
            match (&vals[0], &vals[1], &vals[2], &vals[3]) {
                (Value::Str(u), Value::Str(it), Value::I64(ts), Value::I64(r)) => {
                    Some(ReviewEvent {
                        user: u.clone(),
                        item: it.clone(),
                        ts: *ts,
                        rating: *r,
                    })
                }
                _ => None,
            }
        })
        .collect();
    (events, skipped)
}

/// Parse a JSON-lines log into events; malformed lines are skipped with a
/// count returned (real ingestion never assumes clean data).
pub fn parse_log(text: &str) -> (Vec<ReviewEvent>, usize) {
    let mut events = Vec::new();
    let mut skipped = 0usize;
    for line in text.lines() {
        if line.trim().is_empty() {
            continue;
        }
        match Json::parse(line) {
            Ok(v) => {
                let parsed = (|| {
                    Some(ReviewEvent {
                        user: v.get("user")?.as_str()?.to_string(),
                        item: v.get("item")?.as_str()?.to_string(),
                        ts: v.get("ts")?.as_i64()?,
                        rating: v.get("rating")?.as_i64()?,
                    })
                })();
                match parsed {
                    Some(e) => events.push(e),
                    None => skipped += 1,
                }
            }
            Err(_) => skipped += 1,
        }
    }
    (events, skipped)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generate_parse_round_trip() {
        let text = generate_log(500, 20, 100, 7);
        let (events, skipped) = parse_log(&text);
        assert_eq!(events.len(), 500);
        assert_eq!(skipped, 0);
        assert!(events.iter().all(|e| e.user.starts_with('u')));
        assert!(events.iter().all(|e| (1..=5).contains(&e.rating)));
        // Timestamps are the generation order.
        assert_eq!(events[10].ts, 10);
    }

    #[test]
    fn deterministic() {
        assert_eq!(generate_log(50, 5, 10, 3), generate_log(50, 5, 10, 3));
        assert_ne!(generate_log(50, 5, 10, 3), generate_log(50, 5, 10, 4));
    }

    #[test]
    fn popularity_is_skewed() {
        let (events, _) = parse_log(&generate_log(5000, 50, 200, 9));
        let mut counts = std::collections::HashMap::new();
        for e in &events {
            *counts.entry(e.item.clone()).or_insert(0usize) += 1;
        }
        let max = counts.values().max().copied().unwrap();
        let distinct = counts.len();
        // Zipf: the head item dominates; far fewer distinct than events.
        assert!(max > 5000 / distinct * 5, "max={max} distinct={distinct}");
    }

    #[test]
    fn dataframe_path_matches_direct_parse() {
        let text = generate_log(300, 15, 80, 11);
        let (a, sa) = parse_log(&text);
        let (b, sb) = parse_log_via_dataframe(&text);
        assert_eq!(a, b);
        assert_eq!(sa, sb);
    }

    #[test]
    fn malformed_lines_skipped() {
        let text = "{\"user\": \"u1\", \"item\": \"b2\", \"ts\": 0, \"rating\": 5}\nnot json\n{\"user\": 7}\n";
        let (events, skipped) = parse_log(text);
        assert_eq!(events.len(), 1);
        assert_eq!(skipped, 2);
    }
}
