//! DIEN feature engineering: label-encode, build per-user history
//! sequences, negative-sample candidates (Table 1's "get history sequence,
//! native sampling, data split").
//!
//! Baseline: the row-by-row shape — group events by re-scanning the whole
//! event list per user (quadratic, lots of intermediate allocation, the
//! "serial code and intermediate data" the paper says it optimized away).
//! Optimized: single-pass grouping into per-user vectors, then one pass
//! emitting examples.

use super::log::ReviewEvent;
use crate::ml::LabelEncoder;
use crate::util::Rng;
use crate::OptLevel;

/// One training/inference example for `dien_tiny`.
#[derive(Debug, Clone, PartialEq)]
pub struct DienExample {
    /// Last `hist_len` item ids (padded with 0 at the front).
    pub history: Vec<i64>,
    /// Candidate item id.
    pub candidate: i64,
    /// 1 = the user really interacted with the candidate next, 0 = negative
    /// sample.
    pub label: i64,
}

/// Build DIEN examples from an event log.
///
/// For every user with ≥ 2 events: the last event's item becomes the
/// positive candidate with the preceding items as history; one negative
/// candidate is sampled uniformly from the catalog (the paper's "native
/// sampling").
pub fn build_examples(
    events: &[ReviewEvent],
    hist_len: usize,
    catalog: usize,
    seed: u64,
    opt: OptLevel,
) -> (Vec<DienExample>, LabelEncoder, LabelEncoder) {
    let mut user_enc = LabelEncoder::new();
    let mut item_enc = LabelEncoder::new();
    // Encode ids (shared by both variants; itself a Table 1 stage).
    let users: Vec<i64> = {
        let names: Vec<&str> = events.iter().map(|e| e.user.as_str()).collect();
        user_enc.fit_transform(&names)
    };
    let items: Vec<i64> = {
        let names: Vec<&str> = events.iter().map(|e| e.item.as_str()).collect();
        item_enc.fit_transform(&names)
    };
    let n_users = user_enc.len();
    let mut rng = Rng::new(seed);
    let mut examples = Vec::new();

    // Item ids are offset by 1 so 0 can be the history padding id.
    let item_at = |i: usize| items[i] + 1;

    match opt {
        OptLevel::Baseline => {
            // Re-scan all events per user, materializing a fresh Vec of
            // (ts, item) pairs, then sort it — the quadratic object path.
            for u in 0..n_users {
                let mut mine: Vec<(i64, i64)> = Vec::new();
                for (i, e) in events.iter().enumerate() {
                    if users[i] == u as i64 {
                        mine.push((e.ts, item_at(i)));
                    }
                }
                mine.sort_by_key(|(ts, _)| *ts);
                push_user_examples(&mine, hist_len, catalog, &mut rng, &mut examples);
            }
        }
        OptLevel::Optimized => {
            // Single pass: bucket event indices per user (events are
            // already ts-ordered in the log; verified by a debug assert).
            let mut buckets: Vec<Vec<(i64, i64)>> = vec![Vec::new(); n_users];
            for (i, e) in events.iter().enumerate() {
                buckets[users[i] as usize].push((e.ts, item_at(i)));
            }
            for mine in buckets.iter_mut() {
                if !mine.is_sorted_by_key(|(ts, _)| *ts) {
                    mine.sort_by_key(|(ts, _)| *ts);
                }
                push_user_examples(mine, hist_len, catalog, &mut rng, &mut examples);
            }
        }
    }
    (examples, user_enc, item_enc)
}

fn push_user_examples(
    mine: &[(i64, i64)],
    hist_len: usize,
    catalog: usize,
    rng: &mut Rng,
    out: &mut Vec<DienExample>,
) {
    if mine.len() < 2 {
        return;
    }
    let (_, pos_item) = mine[mine.len() - 1];
    let hist_src: Vec<i64> = mine[..mine.len() - 1].iter().map(|(_, it)| *it).collect();
    let mut history = vec![0i64; hist_len];
    let take = hist_src.len().min(hist_len);
    history[hist_len - take..].copy_from_slice(&hist_src[hist_src.len() - take..]);
    out.push(DienExample { history: history.clone(), candidate: pos_item, label: 1 });
    // Negative sample: uniform over the catalog, excluding the positive.
    let mut neg = 1 + rng.below(catalog) as i64;
    if neg == pos_item {
        neg = 1 + (neg as usize % catalog) as i64;
        if neg == pos_item {
            neg = if pos_item == 1 { 2 } else { 1 };
        }
    }
    out.push(DienExample { history, candidate: neg, label: 0 });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::recsys::log::{generate_log, parse_log};

    fn events(n: usize, seed: u64) -> Vec<ReviewEvent> {
        parse_log(&generate_log(n, 20, 50, seed)).0
    }

    #[test]
    fn variants_agree() {
        let ev = events(400, 1);
        let (a, _, _) = build_examples(&ev, 10, 64, 5, OptLevel::Baseline);
        let (b, _, _) = build_examples(&ev, 10, 64, 5, OptLevel::Optimized);
        // Same examples; order may group differently, so compare sorted.
        let key = |e: &DienExample| (e.candidate, e.label, e.history.clone());
        let mut ka: Vec<_> = a.iter().map(key).collect();
        let mut kb: Vec<_> = b.iter().map(key).collect();
        ka.sort();
        kb.sort();
        assert_eq!(ka, kb);
    }

    #[test]
    fn one_pos_one_neg_per_active_user() {
        let ev = events(500, 2);
        let (ex, users, _) = build_examples(&ev, 10, 64, 3, OptLevel::Optimized);
        let pos = ex.iter().filter(|e| e.label == 1).count();
        let neg = ex.iter().filter(|e| e.label == 0).count();
        assert_eq!(pos, neg);
        assert!(pos <= users.len());
        assert!(pos > 0);
    }

    #[test]
    fn history_padding_and_order() {
        let ev = vec![
            ReviewEvent { user: "u".into(), item: "a".into(), ts: 0, rating: 5 },
            ReviewEvent { user: "u".into(), item: "b".into(), ts: 1, rating: 4 },
            ReviewEvent { user: "u".into(), item: "c".into(), ts: 2, rating: 3 },
        ];
        let (ex, _, items) = build_examples(&ev, 4, 8, 1, OptLevel::Optimized);
        let pos = ex.iter().find(|e| e.label == 1).unwrap();
        // ids: a=0,b=1,c=2 → +1 offset → history [pad pad a b] = [0,0,1,2]
        assert_eq!(pos.history, vec![0, 0, 1, 2]);
        assert_eq!(pos.candidate, 3); // c
        assert_eq!(items.len(), 3);
    }

    #[test]
    fn negative_never_equals_positive() {
        let ev = events(600, 4);
        let (ex, _, _) = build_examples(&ev, 10, 64, 9, OptLevel::Optimized);
        for pair in ex.chunks(2) {
            if pair.len() == 2 {
                assert_ne!(pair[0].candidate, pair[1].candidate);
            }
        }
    }

    #[test]
    fn single_event_users_skipped() {
        let ev = vec![ReviewEvent { user: "solo".into(), item: "x".into(), ts: 0, rating: 1 }];
        let (ex, _, _) = build_examples(&ev, 4, 8, 1, OptLevel::Optimized);
        assert!(ex.is_empty());
    }

    #[test]
    fn history_ids_within_catalog_bounds() {
        let ev = events(300, 6);
        let (ex, _, items) = build_examples(&ev, 10, 64, 2, OptLevel::Optimized);
        let max_id = items.len() as i64 + 1;
        for e in &ex {
            assert!(e.history.iter().all(|&h| h >= 0 && h <= max_id));
        }
    }
}
