//! Text substrate: WordPiece-style tokenization + synthetic reviews.
//!
//! DLSA's preprocessing is "load data, initialize tokenizer, data
//! encoding" (Table 1) — tokenization is most of the non-model time at
//! small batch sizes. Two tokenizer paths mirror the optimization axis:
//! a per-call scanning baseline and a trie-based longest-match optimized
//! path (what HF "fast" tokenizers do in Rust).

pub mod tokenizer;
pub mod reviews;

pub use reviews::ReviewGenerator;
pub use tokenizer::{TokenizerKind, Vocab, WordPiece};
