//! WordPiece tokenizer: greedy longest-match subword segmentation.
//!
//! Baseline: for each word position, linearly probe progressively shorter
//! substrings against a `HashMap` (each probe hashes a fresh `String`) —
//! the "slow" Python-tokenizer shape.
//! Optimized: walk a prefix trie over bytes once per match — the HF
//! fast-tokenizer shape. Both produce identical ids (property-tested).

use std::collections::HashMap;

/// Tokenizer implementation choice (DLSA preprocessing axis).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokenizerKind {
    /// Substring-probing baseline.
    Baseline,
    /// Trie longest-match.
    Optimized,
}

/// Special token ids (fixed positions at the front of the vocab).
pub const PAD: i64 = 0;
pub const UNK: i64 = 1;
pub const CLS: i64 = 2;
pub const SEP: i64 = 3;

/// A WordPiece vocabulary: full words plus `##`-prefixed continuations.
#[derive(Debug, Clone)]
pub struct Vocab {
    map: HashMap<String, i64>,
    trie: Trie,
    size: usize,
}

/// Byte-trie for longest-match lookup.
#[derive(Debug, Clone, Default)]
struct Trie {
    /// Node storage; node 0 is the root. Each node: child edges + optional
    /// token id terminating here.
    nodes: Vec<TrieNode>,
}

#[derive(Debug, Clone, Default)]
struct TrieNode {
    children: Vec<(u8, u32)>,
    id: Option<i64>,
}

impl Trie {
    fn new() -> Trie {
        Trie { nodes: vec![TrieNode::default()] }
    }

    fn insert(&mut self, key: &str, id: i64) {
        let mut cur = 0usize;
        for &b in key.as_bytes() {
            let next = match self.nodes[cur].children.iter().find(|(c, _)| *c == b) {
                Some((_, n)) => *n as usize,
                None => {
                    let n = self.nodes.len() as u32;
                    self.nodes.push(TrieNode::default());
                    self.nodes[cur].children.push((b, n));
                    n as usize
                }
            };
            cur = next;
        }
        self.nodes[cur].id = Some(id);
    }

    /// Longest prefix of `s` that is a token; returns (byte_len, id).
    fn longest_match(&self, s: &[u8]) -> Option<(usize, i64)> {
        let mut cur = 0usize;
        let mut best: Option<(usize, i64)> = None;
        for (i, &b) in s.iter().enumerate() {
            match self.nodes[cur].children.iter().find(|(c, _)| *c == b) {
                Some((_, n)) => cur = *n as usize,
                None => break,
            }
            if let Some(id) = self.nodes[cur].id {
                best = Some((i + 1, id));
            }
        }
        best
    }
}

impl Vocab {
    /// Build from word and subword pieces. Pieces beginning with `##` are
    /// continuations. Specials occupy ids 0..4.
    pub fn new(pieces: &[&str]) -> Vocab {
        let mut map = HashMap::new();
        let mut trie = Trie::new();
        for (i, s) in ["[PAD]", "[UNK]", "[CLS]", "[SEP]"].iter().enumerate() {
            map.insert(s.to_string(), i as i64);
        }
        let mut next = 4i64;
        for &p in pieces {
            if map.contains_key(p) {
                continue;
            }
            map.insert(p.to_string(), next);
            trie.insert(p, next);
            next += 1;
        }
        Vocab { map, trie, size: next as usize }
    }

    /// Derive a character-complete vocab from a corpus: all single chars
    /// and their `##` continuations plus the `max_words` most frequent
    /// whole words. Guarantees no word ever maps to UNK unless it contains
    /// an unseen character.
    pub fn build_from_corpus(texts: &[String], max_words: usize) -> Vocab {
        let mut freq: HashMap<String, usize> = HashMap::new();
        let mut chars: Vec<String> = Vec::new();
        let mut seen_chars = std::collections::HashSet::new();
        for t in texts {
            for w in split_words(t) {
                *freq.entry(w.to_string()).or_insert(0) += 1;
                for c in w.chars() {
                    if seen_chars.insert(c) {
                        chars.push(c.to_string());
                        chars.push(format!("##{c}"));
                    }
                }
            }
        }
        let mut words: Vec<(String, usize)> = freq.into_iter().collect();
        words.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        let mut pieces: Vec<String> = chars;
        pieces.extend(words.into_iter().take(max_words).map(|(w, _)| w));
        let refs: Vec<&str> = pieces.iter().map(|s| s.as_str()).collect();
        Vocab::new(&refs)
    }

    /// Vocabulary size including specials.
    pub fn len(&self) -> usize {
        self.size
    }

    /// True if only specials are present.
    pub fn is_empty(&self) -> bool {
        self.size <= 4
    }

    /// Exact-piece lookup.
    pub fn id(&self, piece: &str) -> Option<i64> {
        self.map.get(piece).copied()
    }
}

fn split_words(text: &str) -> impl Iterator<Item = &str> {
    text.split(|c: char| !c.is_ascii_alphanumeric()).filter(|w| !w.is_empty())
}

/// The tokenizer: lowercase → whitespace/punct split → WordPiece pieces →
/// `[CLS] … [SEP]` → pad/truncate to `max_len`.
#[derive(Debug, Clone)]
pub struct WordPiece {
    vocab: Vocab,
    pub max_len: usize,
}

impl WordPiece {
    /// New tokenizer over `vocab` emitting sequences of `max_len`.
    pub fn new(vocab: Vocab, max_len: usize) -> WordPiece {
        WordPiece { vocab, max_len }
    }

    /// Encode one text to `max_len` ids.
    pub fn encode(&self, text: &str, kind: TokenizerKind) -> Vec<i64> {
        let lower = text.to_ascii_lowercase();
        let mut ids = vec![CLS];
        'words: for word in split_words(&lower) {
            if ids.len() >= self.max_len - 1 {
                break;
            }
            let bytes = word.as_bytes();
            let mut pos = 0usize;
            let mut word_ids = Vec::new();
            while pos < bytes.len() {
                let (m, id) = match kind {
                    TokenizerKind::Optimized => {
                        let probe: Option<(usize, i64)> = if pos == 0 {
                            self.vocab.trie.longest_match(&bytes[pos..])
                        } else {
                            // Continuation: probe with the ## prefix.
                            let mut buf = Vec::with_capacity(bytes.len() - pos + 2);
                            buf.extend_from_slice(b"##");
                            buf.extend_from_slice(&bytes[pos..]);
                            self.vocab
                                .trie
                                .longest_match(&buf)
                                .and_then(|(l, id)| l.checked_sub(2).map(|l| (l, id)))
                        };
                        match probe {
                            Some(x) if x.0 > 0 => x,
                            _ => {
                                ids.push(UNK);
                                continue 'words;
                            }
                        }
                    }
                    TokenizerKind::Baseline => {
                        // Probe progressively shorter substrings, each
                        // allocating a lookup key (the slow path).
                        let mut found = None;
                        for end in (pos + 1..=bytes.len()).rev() {
                            let cand = if pos == 0 {
                                String::from_utf8_lossy(&bytes[pos..end]).into_owned()
                            } else {
                                format!("##{}", String::from_utf8_lossy(&bytes[pos..end]))
                            };
                            if let Some(&id) = self.vocab.map.get(&cand) {
                                found = Some((end - pos, id));
                                break;
                            }
                        }
                        match found {
                            Some(x) => x,
                            None => {
                                ids.push(UNK);
                                continue 'words;
                            }
                        }
                    }
                };
                word_ids.push(id);
                pos += m;
            }
            for id in word_ids {
                if ids.len() >= self.max_len - 1 {
                    break;
                }
                ids.push(id);
            }
        }
        ids.push(SEP);
        ids.resize(self.max_len, PAD);
        ids
    }

    /// Encode a batch.
    pub fn encode_batch(&self, texts: &[String], kind: TokenizerKind) -> Vec<Vec<i64>> {
        texts.iter().map(|t| self.encode(t, kind)).collect()
    }

    /// Vocabulary accessor.
    pub fn vocab(&self) -> &Vocab {
        &self.vocab
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn vocab() -> Vocab {
        Vocab::new(&[
            "the", "movie", "was", "great", "bad", "act", "##ing", "##or", "un",
            "##great", "a", "##c", "##t", "g", "##r", "b", "##a", "##d",
        ])
    }

    #[test]
    fn encodes_known_words() {
        let tok = WordPiece::new(vocab(), 12);
        let ids = tok.encode("The movie was great", TokenizerKind::Optimized);
        assert_eq!(ids.len(), 12);
        assert_eq!(ids[0], CLS);
        let the = tok.vocab().id("the").unwrap();
        assert_eq!(ids[1], the);
        assert!(ids.contains(&SEP));
        assert_eq!(*ids.last().unwrap(), PAD);
    }

    #[test]
    fn subword_split() {
        let tok = WordPiece::new(vocab(), 12);
        let ids = tok.encode("acting", TokenizerKind::Optimized);
        let act = tok.vocab().id("act").unwrap();
        let ing = tok.vocab().id("##ing").unwrap();
        assert_eq!(&ids[1..3], &[act, ing]);
    }

    #[test]
    fn unknown_word_is_unk() {
        let tok = WordPiece::new(vocab(), 8);
        let ids = tok.encode("xyzzy", TokenizerKind::Optimized);
        assert_eq!(ids[1], UNK);
        let ids_b = tok.encode("xyzzy", TokenizerKind::Baseline);
        assert_eq!(ids, ids_b);
    }

    #[test]
    fn baseline_and_optimized_agree() {
        let tok = WordPiece::new(vocab(), 16);
        for text in [
            "the movie was great",
            "acting actor",
            "ungreat bad acting",
            "THE MOVIE!!! was... bad?",
            "",
            "a b g",
        ] {
            let a = tok.encode(text, TokenizerKind::Baseline);
            let b = tok.encode(text, TokenizerKind::Optimized);
            assert_eq!(a, b, "{text:?}");
        }
    }

    #[test]
    fn agree_on_random_corpus_property() {
        prop::check("tokenizer paths agree", 15, |rng| {
            // Build a random corpus + char-complete vocab from it.
            let texts: Vec<String> = (0..10)
                .map(|_| {
                    (0..1 + rng.below(8))
                        .map(|_| {
                            let len = 1 + rng.below(7);
                            rng.ascii_lower(len)
                        })
                        .collect::<Vec<_>>()
                        .join(" ")
                })
                .collect();
            let vocab = Vocab::build_from_corpus(&texts, 30);
            let tok = WordPiece::new(vocab, 24);
            for t in &texts {
                let a = tok.encode(t, TokenizerKind::Baseline);
                let b = tok.encode(t, TokenizerKind::Optimized);
                if a != b {
                    return Err(format!("{t:?}: {a:?} vs {b:?}"));
                }
                if a.len() != 24 {
                    return Err("bad length".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn char_complete_vocab_never_unks() {
        let texts = vec!["hello world".to_string(), "held low".to_string()];
        let vocab = Vocab::build_from_corpus(&texts, 2);
        let tok = WordPiece::new(vocab, 32);
        let ids = tok.encode("hollow dell", TokenizerKind::Optimized);
        assert!(!ids.contains(&UNK), "{ids:?}");
    }

    #[test]
    fn truncates_long_inputs() {
        let tok = WordPiece::new(vocab(), 6);
        let ids = tok.encode("the movie was great bad acting actor", TokenizerKind::Optimized);
        assert_eq!(ids.len(), 6);
        assert_eq!(ids[0], CLS);
        assert_eq!(ids[5], SEP);
    }

    #[test]
    fn batch_matches_singles() {
        let tok = WordPiece::new(vocab(), 10);
        let texts = vec!["the movie".to_string(), "bad acting".to_string()];
        let batch = tok.encode_batch(&texts, TokenizerKind::Optimized);
        assert_eq!(batch[0], tok.encode(&texts[0], TokenizerKind::Optimized));
        assert_eq!(batch[1], tok.encode(&texts[1], TokenizerKind::Optimized));
    }
}
