//! Synthetic movie-review generator — the IMDb/SST-2 stand-in for DLSA.
//!
//! Reviews are built from sentiment-bearing word pools with a planted
//! label, so the pipeline has real documents to tokenize and a ground
//! truth to report accuracy against. Lengths follow a clipped exponential
//! like real review corpora (many short, few very long).

use crate::util::Rng;

const POSITIVE: &[&str] = &[
    "great", "wonderful", "superb", "delightful", "masterpiece", "moving",
    "brilliant", "captivating", "excellent", "charming",
];
const NEGATIVE: &[&str] = &[
    "terrible", "boring", "awful", "dreadful", "disaster", "bland",
    "tedious", "clumsy", "forgettable", "painful",
];
const NEUTRAL: &[&str] = &[
    "the", "movie", "film", "plot", "was", "acting", "scene", "director",
    "story", "character", "and", "with", "watch", "screen", "ending",
    "a", "of", "in", "it", "very",
];

/// A labeled synthetic review.
#[derive(Debug, Clone)]
pub struct Review {
    pub text: String,
    /// 1 = positive, 0 = negative.
    pub label: i64,
}

/// Deterministic review stream.
pub struct ReviewGenerator {
    rng: Rng,
    mean_len: usize,
}

impl ReviewGenerator {
    /// New generator; `mean_len` is the average word count.
    pub fn new(seed: u64, mean_len: usize) -> ReviewGenerator {
        ReviewGenerator { rng: Rng::new(seed), mean_len: mean_len.max(4) }
    }

    /// Generate one review.
    pub fn next_review(&mut self) -> Review {
        let label = self.rng.chance(0.5) as i64;
        let pool = if label == 1 { POSITIVE } else { NEGATIVE };
        let len = (self.rng.exp(1.0 / self.mean_len as f64) as usize).clamp(4, 6 * self.mean_len);
        let mut words = Vec::with_capacity(len);
        for _ in 0..len {
            // ~30% sentiment words, rest neutral filler.
            if self.rng.chance(0.3) {
                words.push(*self.rng.choice(pool));
            } else {
                words.push(*self.rng.choice(NEUTRAL));
            }
        }
        Review { text: words.join(" "), label }
    }

    /// Generate a batch.
    pub fn batch(&mut self, n: usize) -> Vec<Review> {
        (0..n).map(|_| self.next_review()).collect()
    }

    /// All corpus words (for vocabulary construction).
    pub fn lexicon() -> Vec<String> {
        POSITIVE
            .iter()
            .chain(NEGATIVE)
            .chain(NEUTRAL)
            .map(|s| s.to_string())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = ReviewGenerator::new(1, 20);
        let mut b = ReviewGenerator::new(1, 20);
        for _ in 0..10 {
            let (ra, rb) = (a.next_review(), b.next_review());
            assert_eq!(ra.text, rb.text);
            assert_eq!(ra.label, rb.label);
        }
    }

    #[test]
    fn labels_roughly_balanced() {
        let mut g = ReviewGenerator::new(2, 15);
        let pos: i64 = g.batch(1000).iter().map(|r| r.label).sum();
        assert!((350..=650).contains(&pos), "{pos}");
    }

    #[test]
    fn sentiment_words_match_label() {
        let mut g = ReviewGenerator::new(3, 40);
        for r in g.batch(50) {
            let has_wrong = if r.label == 1 {
                NEGATIVE.iter().any(|w| r.text.contains(w))
            } else {
                POSITIVE.iter().any(|w| r.text.contains(w))
            };
            assert!(!has_wrong, "{r:?}");
        }
    }

    #[test]
    fn lengths_vary_but_bounded() {
        let mut g = ReviewGenerator::new(4, 10);
        let lens: Vec<usize> =
            g.batch(200).iter().map(|r| r.text.split(' ').count()).collect();
        assert!(lens.iter().all(|&l| (4..=60).contains(&l)));
        let distinct: std::collections::HashSet<usize> = lens.iter().copied().collect();
        assert!(distinct.len() > 5, "lengths should vary");
    }
}
