//! `ServeClient` + the closed-loop load generator behind
//! `repro bench-serve`.
//!
//! A [`ServeClient`] is one tenant's connection: handshake at connect,
//! then typed frame traffic — the closed-loop [`ServeClient::call`]
//! sends one request and blocks for *its* resolution, so a generator
//! thread's offered load is gated by service latency (closed loop),
//! exactly the arrival model the admission/backpressure machinery is
//! designed against.
//!
//! [`run_load`] drives a whole fleet: `clients` generator threads, each
//! opening ONE connection per mix entry (tenant = pipeline name, so the
//! server's per-tenant ledger maps straight onto the bench's per-
//! pipeline trajectory), issuing a deterministic weighted round-robin
//! schedule with cycling priorities, then draining every connection —
//! real connection churn, overload → first-class shed, and a
//! per-tenant latency record. [`LoadReport::trajectory_pipelines`]
//! renders the result in the `util/bench.rs` schema for
//! `BENCH_serve.json`.

use super::wire::{self, Frame, ShedCause, WireError, WirePayload, WireRequest, SHED_CAUSE_COUNT};
use crate::coordinator::telemetry::{NetReport, TenantLedger};
use crate::service::Priority;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

/// One tenant's connection to a [`PipelineServer`].
///
/// [`PipelineServer`]: super::PipelineServer
pub struct ServeClient {
    stream: TcpStream,
    tenant: String,
    pipelines: Vec<String>,
    next_id: u64,
}

impl ServeClient {
    /// Connect and handshake: `Hello{tenant}` → `HelloAck`.
    pub fn connect(addr: SocketAddr, tenant: &str) -> Result<ServeClient, WireError> {
        let mut stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        wire::write_frame(&mut stream, &Frame::Hello { tenant: tenant.to_string() })?;
        let pipelines = match wire::read_frame(&mut stream)? {
            Some(Frame::HelloAck { pipelines }) => pipelines,
            // The admission gate answers over-limit connections with a
            // first-class Shed(ServerFull) instead of a silent close —
            // surface it as a typed, retryable rejection.
            Some(Frame::Shed { cause, .. }) => return Err(WireError::Rejected(cause)),
            Some(other) => {
                return Err(WireError::Malformed(format!(
                    "expected hello_ack, got {}",
                    other.kind()
                )))
            }
            None => return Err(WireError::Truncated { context: "handshake" }),
        };
        Ok(ServeClient { stream, tenant: tenant.to_string(), pipelines, next_id: 0 })
    }

    /// The tenant this connection declared.
    pub fn tenant(&self) -> &str {
        &self.tenant
    }

    /// Pipelines the server reported open at handshake.
    pub fn pipelines(&self) -> &[String] {
        &self.pipelines
    }

    /// Fire one request without waiting; returns its correlation id.
    pub fn send(
        &mut self,
        pipeline: &str,
        priority: Priority,
        deadline: Option<Duration>,
        payload: WirePayload,
    ) -> Result<u64, WireError> {
        self.next_id += 1;
        let id = self.next_id;
        wire::write_frame(
            &mut self.stream,
            &Frame::Request(WireRequest {
                id,
                pipeline: pipeline.to_string(),
                priority,
                // Shared codec helper: Some(Duration::ZERO) saturates
                // to 1 ms instead of aliasing the "no deadline"
                // sentinel (0).
                deadline_ms: wire::encode_deadline_ms(deadline),
                payload,
            }),
        )?;
        Ok(id)
    }

    /// Read the next frame; a close mid-conversation is an error.
    pub fn recv(&mut self) -> Result<Frame, WireError> {
        match wire::read_frame(&mut self.stream)? {
            Some(frame) => Ok(frame),
            None => Err(WireError::Truncated { context: "connection closed mid-conversation" }),
        }
    }

    /// Closed-loop call: send one request and block until ITS
    /// resolution frame (`Completed`/`Shed`/`Failed`) arrives.
    pub fn call(
        &mut self,
        pipeline: &str,
        priority: Priority,
        deadline: Option<Duration>,
        payload: WirePayload,
    ) -> Result<Frame, WireError> {
        let id = self.send(pipeline, priority, deadline, payload)?;
        loop {
            let frame = self.recv()?;
            match &frame {
                Frame::Completed(c) if c.id == id => return Ok(frame),
                Frame::Shed { id: rid, .. } | Frame::Failed { id: rid, .. } if *rid == id => {
                    return Ok(frame)
                }
                // Stale frames from earlier fire-and-forget sends (or a
                // stats reply) are skipped; anything else is protocol.
                Frame::Completed(_) | Frame::Shed { .. } | Frame::Failed { .. }
                | Frame::Stats(_) | Frame::TenantStats { .. } => continue,
                other => {
                    return Err(WireError::Malformed(format!(
                        "unexpected {} while awaiting request {id}",
                        other.kind()
                    )))
                }
            }
        }
    }

    /// Fetch the server's serving ledger.
    pub fn stats(&mut self) -> Result<NetReport, WireError> {
        wire::write_frame(&mut self.stream, &Frame::StatsReq)?;
        loop {
            match self.recv()? {
                Frame::Stats(report) => return Ok(report),
                // In-flight resolutions may interleave before the reply.
                Frame::Completed(_) | Frame::Shed { .. } | Frame::Failed { .. }
                | Frame::TenantStats { .. } => continue,
                other => {
                    return Err(WireError::Malformed(format!(
                        "unexpected {} while awaiting stats",
                        other.kind()
                    )))
                }
            }
        }
    }

    /// Fetch THIS tenant's server-side ledger — the scoped counterpart
    /// of [`Self::stats`]: a tenant polls its own admission/outcome
    /// counters without seeing the whole fleet's report.
    pub fn tenant_stats(&mut self) -> Result<TenantLedger, WireError> {
        wire::write_frame(&mut self.stream, &Frame::TenantStatsReq)?;
        loop {
            match self.recv()? {
                Frame::TenantStats { tenant, ledger } => {
                    if tenant != self.tenant {
                        return Err(WireError::Malformed(format!(
                            "tenant_stats for {tenant} on a {} connection",
                            self.tenant
                        )));
                    }
                    return Ok(ledger);
                }
                // In-flight resolutions may interleave before the reply.
                Frame::Completed(_) | Frame::Shed { .. } | Frame::Failed { .. }
                | Frame::Stats(_) => continue,
                other => {
                    return Err(WireError::Malformed(format!(
                        "unexpected {} while awaiting tenant stats",
                        other.kind()
                    )))
                }
            }
        }
    }

    /// Graceful close: send `Drain`, read out every remaining
    /// resolution, and return the `Goodbye` counters
    /// `(completed, shed, failed, shed_by_cause)` — the last broken out
    /// per [`ShedCause`] in `ShedCause::ALL` order.
    #[allow(clippy::type_complexity)]
    pub fn drain(mut self) -> Result<(u64, u64, u64, [u64; SHED_CAUSE_COUNT]), WireError> {
        wire::write_frame(&mut self.stream, &Frame::Drain)?;
        loop {
            match self.recv()? {
                Frame::Goodbye { completed, shed, failed, shed_by_cause } => {
                    return Ok((completed, shed, failed, shed_by_cause))
                }
                Frame::Completed(_) | Frame::Shed { .. } | Frame::Failed { .. }
                | Frame::Stats(_) | Frame::TenantStats { .. } => continue,
                other => {
                    return Err(WireError::Malformed(format!(
                        "unexpected {} while draining",
                        other.kind()
                    )))
                }
            }
        }
    }
}

/// How [`run_load`] offers load.
#[derive(Debug, Clone)]
pub struct LoadSpec {
    /// Generator threads; each opens one connection per mix entry.
    pub clients: usize,
    /// Closed-loop requests per client (spread over the mix by weight).
    pub requests: usize,
    /// Weighted pipeline mix; each entry is also its tenant id.
    pub mix: Vec<(String, usize)>,
}

/// One tenant's client-side outcome record.
#[derive(Debug, Clone, Default)]
pub struct TenantLoad {
    pub requests: u64,
    pub completed: u64,
    pub shed: u64,
    /// Sheds broken out per [`ShedCause`] (in `ShedCause::ALL` order);
    /// always sums to `shed`.
    pub shed_by_cause: [u64; SHED_CAUSE_COUNT],
    pub failed: u64,
    /// Client-observed latency of each COMPLETED request, milliseconds.
    pub latencies_ms: Vec<f64>,
}

impl TenantLoad {
    /// Every issued request resolved exactly once, and the per-cause
    /// shed breakdown accounts for every shed.
    pub fn balances(&self) -> bool {
        self.requests == self.completed + self.shed + self.failed
            && self.shed_by_cause.iter().sum::<u64>() == self.shed
    }

    /// Fraction of issued requests the serving edge shed.
    pub fn shed_fraction(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.shed as f64 / self.requests as f64
        }
    }
}

/// The whole fleet's outcome, per tenant.
#[derive(Debug, Clone, Default)]
pub struct LoadReport {
    pub per_tenant: BTreeMap<String, TenantLoad>,
    pub wall: Duration,
}

/// Latency percentile over an unsorted sample set (same nearest-rank
/// convention as the telemetry reports); `None` on no samples. Delegates
/// to the crate-wide [`crate::util::stats`] helper, which orders with
/// `f64::total_cmp` — a NaN latency sample degrades deterministically
/// instead of panicking the load generator.
pub fn percentile_ms(samples: &[f64], q: f64) -> Option<f64> {
    crate::util::stats::percentile_f64(samples, q)
}

impl LoadReport {
    /// Sum of completed requests across tenants.
    pub fn total_completed(&self) -> u64 {
        self.per_tenant.values().map(|t| t.completed).sum()
    }

    /// Every tenant's ledger balances client-side.
    pub fn balances(&self) -> bool {
        self.per_tenant.values().all(TenantLoad::balances)
    }

    /// Render per-tenant trajectories in the `util/bench.rs` schema:
    /// each tenant (== pipeline) gets an `exec_modes.serve` entry with
    /// the standard `wall_s`/`items`/`items_per_s`/`p50_ms`/`p95_ms`
    /// fields plus the serving-specific outcome counters.
    pub fn trajectory_pipelines(&self) -> BTreeMap<String, Json> {
        let secs = self.wall.as_secs_f64();
        let mut pipelines = BTreeMap::new();
        for (tenant, t) in &self.per_tenant {
            let mut entry = BTreeMap::new();
            entry.insert("wall_s".to_string(), Json::Num(secs));
            entry.insert("items".to_string(), Json::Num(t.completed as f64));
            entry.insert(
                "items_per_s".to_string(),
                Json::Num(t.completed as f64 / secs.max(1e-12)),
            );
            let pct = |q: f64| match percentile_ms(&t.latencies_ms, q) {
                Some(ms) => Json::Num(ms),
                None => Json::Null,
            };
            entry.insert("p50_ms".to_string(), pct(0.50));
            entry.insert("p95_ms".to_string(), pct(0.95));
            entry.insert("requests".to_string(), Json::Num(t.requests as f64));
            entry.insert("shed".to_string(), Json::Num(t.shed as f64));
            entry.insert("failed".to_string(), Json::Num(t.failed as f64));
            entry.insert("shed_fraction".to_string(), Json::Num(t.shed_fraction()));
            let mut by_cause = BTreeMap::new();
            for cause in ShedCause::ALL {
                by_cause.insert(
                    cause.label().to_string(),
                    Json::Num(t.shed_by_cause[cause.index()] as f64),
                );
            }
            entry.insert("shed_by_cause".to_string(), Json::Obj(by_cause));
            let mut modes = BTreeMap::new();
            modes.insert("serve".to_string(), Json::Obj(entry));
            let mut p = BTreeMap::new();
            p.insert("exec_modes".to_string(), Json::Obj(modes));
            pipelines.insert(tenant.clone(), Json::Obj(p));
        }
        pipelines
    }
}

/// Drive a closed-loop fleet against a live server (see module docs).
/// Deterministic schedule: client `c`'s `i`-th request goes to the
/// weighted round-robin mix slot `(i)` with priority cycling
/// normal → high → low, so two runs offer identical traffic.
pub fn run_load(addr: SocketAddr, spec: &LoadSpec) -> anyhow::Result<LoadReport> {
    anyhow::ensure!(spec.clients > 0, "bench-serve needs at least one client");
    anyhow::ensure!(!spec.mix.is_empty(), "bench-serve needs a non-empty mix");
    let schedule: Vec<String> = spec
        .mix
        .iter()
        .flat_map(|(name, weight)| std::iter::repeat(name.clone()).take(*weight))
        .collect();
    const PRIORITIES: [Priority; 3] = [Priority::Normal, Priority::High, Priority::Low];
    let started = Instant::now();
    let mut workers = Vec::new();
    for _ in 0..spec.clients {
        let schedule = schedule.clone();
        let mix: Vec<String> = spec.mix.iter().map(|(n, _)| n.clone()).collect();
        let requests = spec.requests;
        workers.push(std::thread::spawn(move || -> anyhow::Result<
            BTreeMap<String, TenantLoad>,
        > {
            // One connection per mix entry; tenant id == pipeline name.
            let mut conns: BTreeMap<String, ServeClient> = BTreeMap::new();
            for tenant in &mix {
                conns.insert(tenant.clone(), ServeClient::connect(addr, tenant)?);
            }
            let mut loads: BTreeMap<String, TenantLoad> = BTreeMap::new();
            for i in 0..requests {
                let pipeline = &schedule[i % schedule.len()];
                let priority = PRIORITIES[i % PRIORITIES.len()];
                let conn = conns.get_mut(pipeline).expect("mix connection open");
                let load = loads.entry(pipeline.clone()).or_default();
                load.requests += 1;
                let t0 = Instant::now();
                match conn.call(pipeline, priority, None, WirePayload::Synthetic)? {
                    Frame::Completed(_) => {
                        load.completed += 1;
                        load.latencies_ms.push(t0.elapsed().as_secs_f64() * 1e3);
                    }
                    Frame::Shed { cause, .. } => {
                        load.shed += 1;
                        load.shed_by_cause[cause.index()] += 1;
                    }
                    Frame::Failed { .. } => load.failed += 1,
                    other => anyhow::bail!("unexpected resolution frame {}", other.kind()),
                }
            }
            // Churn: every connection drains gracefully. The Goodbye
            // ledger must agree with what this thread observed.
            for (tenant, conn) in conns {
                let (completed, shed, failed, by_cause) = conn.drain()?;
                let load = loads.entry(tenant.clone()).or_default();
                anyhow::ensure!(
                    (completed, shed, failed)
                        == (load.completed, load.shed, load.failed),
                    "goodbye ledger for {tenant} diverged from client counts"
                );
                anyhow::ensure!(
                    by_cause == load.shed_by_cause,
                    "goodbye per-cause sheds for {tenant} diverged: \
                     server {by_cause:?} vs client {:?}",
                    load.shed_by_cause
                );
            }
            Ok(loads)
        }));
    }
    let mut report = LoadReport::default();
    let mut errors = Vec::new();
    for worker in workers {
        match worker.join().expect("load generator thread panicked") {
            Ok(loads) => {
                for (tenant, load) in loads {
                    let t = report.per_tenant.entry(tenant).or_default();
                    t.requests += load.requests;
                    t.completed += load.completed;
                    t.shed += load.shed;
                    for (slot, n) in t.shed_by_cause.iter_mut().zip(load.shed_by_cause) {
                        *slot += n;
                    }
                    t.failed += load.failed;
                    t.latencies_ms.extend(load.latencies_ms);
                }
            }
            Err(e) => errors.push(format!("{e:#}")),
        }
    }
    anyhow::ensure!(errors.is_empty(), "load generator failed: {}", errors.join("; "));
    report.wall = started.elapsed();
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_follows_nearest_rank() {
        assert_eq!(percentile_ms(&[], 0.5), None);
        assert_eq!(percentile_ms(&[7.0], 0.95), Some(7.0));
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile_ms(&xs, 0.0), Some(1.0));
        assert_eq!(percentile_ms(&xs, 0.5), Some(3.0));
        assert_eq!(percentile_ms(&xs, 1.0), Some(5.0));
    }

    #[test]
    fn trajectory_pipelines_follow_the_bench_schema() {
        let mut report = LoadReport { wall: Duration::from_millis(500), ..Default::default() };
        report.per_tenant.insert(
            "census".to_string(),
            TenantLoad {
                requests: 10,
                completed: 8,
                shed: 2,
                shed_by_cause: {
                    let mut c = [0u64; SHED_CAUSE_COUNT];
                    c[ShedCause::DeadlineExpired.index()] = 1;
                    c[ShedCause::TenantLaneFull.index()] = 1;
                    c
                },
                failed: 0,
                latencies_ms: vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0],
            },
        );
        assert!(report.balances());
        let pipelines = report.trajectory_pipelines();
        let doc = Json::Obj(pipelines);
        let entry = doc
            .get("census")
            .and_then(|p| p.get("exec_modes"))
            .and_then(|m| m.get("serve"))
            .expect("serve mode entry");
        assert_eq!(entry.get("wall_s").and_then(Json::as_f64), Some(0.5));
        assert_eq!(entry.get("items").and_then(Json::as_f64), Some(8.0));
        assert_eq!(entry.get("items_per_s").and_then(Json::as_f64), Some(16.0));
        assert_eq!(entry.get("shed_fraction").and_then(Json::as_f64), Some(0.2));
        let by_cause = entry.get("shed_by_cause").expect("per-cause shed breakdown");
        for cause in ShedCause::ALL {
            assert!(
                by_cause.get(cause.label()).and_then(Json::as_f64).is_some(),
                "missing shed_by_cause.{cause}"
            );
        }
        assert_eq!(by_cause.get("deadline_expired").and_then(Json::as_f64), Some(1.0));
        assert_eq!(by_cause.get("queue_full").and_then(Json::as_f64), Some(0.0));
        assert!(entry.get("p50_ms").and_then(Json::as_f64).is_some());
        // Round trip through the parser like validate_bench does.
        let parsed = Json::parse(&doc.to_string_compact()).unwrap();
        assert_eq!(parsed.to_string_compact(), doc.to_string_compact());
    }

    #[test]
    fn tenant_load_ledger_math() {
        let t = TenantLoad {
            requests: 4,
            completed: 2,
            shed: 1,
            shed_by_cause: [1, 0, 0, 0, 0],
            failed: 1,
            ..Default::default()
        };
        assert!(t.balances());
        assert_eq!(t.shed_fraction(), 0.25);
        let unresolved = TenantLoad { requests: 4, completed: 2, ..Default::default() };
        assert!(!unresolved.balances());
        // A shed without a cause attribution does not balance either.
        let unattributed =
            TenantLoad { requests: 1, shed: 1, ..Default::default() };
        assert!(!unattributed.balances());
        assert_eq!(TenantLoad::default().shed_fraction(), 0.0);
    }
}
