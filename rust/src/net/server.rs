//! `PipelineServer` — the TCP serving edge over a
//! [`PipelineService`].
//!
//! One accept loop, one handler thread per connection, all speaking the
//! [`wire`](super::wire) protocol. The handler is a poll loop (short
//! read timeouts, never busy): it multiplexes many in-flight
//! [`Ticket`]s per connection via the non-consuming
//! [`Ticket::is_done`], so a connection can hold a pipeline's worth of
//! requests outstanding while responses stream back in completion
//! order, correlated by request id.
//!
//! **Per-tenant lanes.** Every connection declares a tenant id in its
//! `Hello`. The server holds one in-flight counter per tenant (shared
//! across that tenant's connections): a tenant at its
//! [`ServerConfig::per_tenant_depth`] gets an immediate first-class
//! [`Frame::Shed`] (`TenantLaneFull`) for further requests — one
//! noisy tenant saturates its own lane, not the shared admission
//! queue, and never costs anyone a connection.
//!
//! **Backpressure.** A connection may hold at most
//! [`ServerConfig::conn_inflight`] unresolved tickets. Past that, the
//! handler parks on the OLDEST ticket and writes its response before
//! reading another request — a slow reader stalls its own socket
//! (bounded memory), it does not balloon the pending set.
//!
//! **Graceful drain.** [`PipelineServer::drain`] stops the accept
//! loop, then every handler flushes its in-flight tickets, writes each
//! response, and closes with a `Goodbye` carrying the connection's
//! outcome counters — zero lost responses, which the soak tests pin
//! from the [`NetReport`] ledger (`accepted == drained`, and per
//! tenant `admitted == completed + shed + failed`), never wall-clock.

use super::wire::{
    self, Frame, ShedCause, WireCompletion, WireError, WireRequest, SHED_CAUSE_COUNT,
};
use crate::coordinator::telemetry::{NetLedger, NetReport};
use crate::service::{PipelineService, Request, Response, Ticket};
use std::collections::{BTreeMap, VecDeque};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// How a [`PipelineServer`] is provisioned.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Max in-flight (admitted, unresolved) requests per tenant across
    /// all of that tenant's connections; further requests shed with
    /// [`ShedCause::TenantLaneFull`].
    pub per_tenant_depth: usize,
    /// Max unresolved tickets per connection before the handler parks
    /// on the oldest one (write backpressure for slow readers).
    pub conn_inflight: usize,
    /// Handler read timeout — the poll cadence at which handlers notice
    /// resolved tickets and the drain flag. Liveness only: no
    /// correctness property depends on this value.
    pub poll_interval: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            per_tenant_depth: 8,
            conn_inflight: 32,
            poll_interval: Duration::from_millis(10),
        }
    }
}

struct Inner {
    service: Arc<PipelineService>,
    ledger: NetLedger,
    /// In-flight admitted requests per tenant (the admission lanes).
    lanes: Mutex<BTreeMap<String, usize>>,
    draining: AtomicBool,
    conns: Mutex<Vec<JoinHandle<()>>>,
    cfg: ServerConfig,
}

/// The TCP serving front-end (see module docs).
pub struct PipelineServer {
    inner: Arc<Inner>,
    addr: SocketAddr,
    accept: Option<JoinHandle<()>>,
}

impl PipelineServer {
    /// Bind `addr` (e.g. `127.0.0.1:0` for an ephemeral port) and start
    /// accepting connections over `service`.
    pub fn start(
        service: Arc<PipelineService>,
        addr: &str,
        cfg: ServerConfig,
    ) -> anyhow::Result<PipelineServer> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| anyhow::anyhow!("cannot bind {addr}: {e}"))?;
        let local = listener.local_addr()?;
        let inner = Arc::new(Inner {
            service,
            ledger: NetLedger::default(),
            lanes: Mutex::new(BTreeMap::new()),
            draining: AtomicBool::new(false),
            conns: Mutex::new(Vec::new()),
            cfg,
        });
        let accept_inner = Arc::clone(&inner);
        let accept = std::thread::Builder::new()
            .name("pipeline-server-accept".to_string())
            .spawn(move || accept_loop(&listener, &accept_inner))
            .expect("spawn accept loop");
        Ok(PipelineServer { inner, addr: local, accept: Some(accept) })
    }

    /// The bound address (with the real port when started on `:0`).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Live snapshot of the serving ledger.
    pub fn report(&self) -> NetReport {
        self.inner.ledger.snapshot()
    }

    /// Graceful drain: stop accepting, let every handler flush its
    /// in-flight tickets and say `Goodbye`, then return the final
    /// ledger. Requires the underlying service to be running (a paused
    /// service never resolves the in-flight tickets being flushed).
    pub fn drain(mut self) -> NetReport {
        self.shutdown()
    }

    fn shutdown(&mut self) -> NetReport {
        self.inner.draining.store(true, Ordering::SeqCst);
        if let Some(accept) = self.accept.take() {
            // A sentinel connection unblocks the accept() call so the
            // loop observes the drain flag; it is dropped uncounted.
            let _ = TcpStream::connect(self.addr);
            let _ = accept.join();
        }
        let conns: Vec<JoinHandle<()>> =
            std::mem::take(&mut *self.inner.conns.lock().unwrap());
        for handle in conns {
            let _ = handle.join();
        }
        self.inner.ledger.snapshot()
    }
}

impl Drop for PipelineServer {
    fn drop(&mut self) {
        if self.accept.is_some() {
            self.shutdown();
        }
    }
}

fn accept_loop(listener: &TcpListener, inner: &Arc<Inner>) {
    for stream in listener.incoming() {
        if inner.draining.load(Ordering::SeqCst) {
            // The final (possibly sentinel) stream is dropped without
            // counting: `accepted` only ever counts served connections.
            break;
        }
        let Ok(stream) = stream else { continue };
        inner.ledger.connection_accepted();
        let conn_inner = Arc::clone(inner);
        let handle = std::thread::Builder::new()
            .name("pipeline-server-conn".to_string())
            .spawn(move || {
                handle_conn(stream, &conn_inner);
                conn_inner.ledger.connection_drained();
            })
            .expect("spawn connection handler");
        inner.conns.lock().unwrap().push(handle);
    }
}

/// One unresolved request riding a connection.
struct Pending {
    id: u64,
    tenant: String,
    ticket: Ticket,
}

/// Per-connection handler state.
struct Conn {
    stream: TcpStream,
    tenant: String,
    pending: VecDeque<Pending>,
    /// False once a write failed (peer gone): ledger resolution
    /// continues, frames stop.
    writable: bool,
    completed: u64,
    shed: u64,
    /// Sheds broken out per [`ShedCause`] (in `ShedCause::ALL` order);
    /// sums to `shed` and rides the `Goodbye` so clients can attribute
    /// every shed without parsing individual frames.
    shed_by_cause: [u64; SHED_CAUSE_COUNT],
    failed: u64,
}

impl Conn {
    /// Write one frame unless the peer is already gone. Write failures
    /// flip `writable` instead of erroring: every pending ticket must
    /// still resolve in the ledger whatever the socket does.
    fn send(&mut self, inner: &Inner, frame: &Frame) {
        if !self.writable {
            return;
        }
        match wire::write_frame(&mut self.stream, frame) {
            Ok(()) => inner.ledger.frame_out(),
            Err(_) => self.writable = false,
        }
    }
}

fn handle_conn(stream: TcpStream, inner: &Arc<Inner>) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(inner.cfg.poll_interval));
    // Handshake: the first frame must be Hello{tenant}.
    let mut conn = Conn {
        stream,
        tenant: String::new(),
        pending: VecDeque::new(),
        writable: true,
        completed: 0,
        shed: 0,
        shed_by_cause: [0; SHED_CAUSE_COUNT],
        failed: 0,
    };
    loop {
        if inner.draining.load(Ordering::SeqCst) {
            // Drained before the handshake finished: nothing in flight.
            conn.send(
                inner,
                &Frame::Goodbye {
                    completed: 0,
                    shed: 0,
                    failed: 0,
                    shed_by_cause: [0; SHED_CAUSE_COUNT],
                },
            );
            return;
        }
        match wire::read_frame(&mut conn.stream) {
            Ok(Some(Frame::Hello { tenant })) => {
                inner.ledger.frame_in();
                conn.tenant = tenant;
                let pipelines =
                    inner.service.session_names().iter().map(|s| s.to_string()).collect();
                conn.send(inner, &Frame::HelloAck { pipelines });
                break;
            }
            Ok(Some(_)) | Ok(None) => return, // protocol error / peer gone
            Err(e) if e.is_timeout() => continue,
            Err(_) => return,
        }
    }
    serve(&mut conn, inner);
}

fn serve(conn: &mut Conn, inner: &Arc<Inner>) {
    loop {
        flush_ready(conn, inner);
        if inner.draining.load(Ordering::SeqCst) {
            finish(conn, inner);
            return;
        }
        if conn.pending.len() >= inner.cfg.conn_inflight {
            // Backpressure: park on the oldest ticket; its response is
            // written (possibly blocking on a slow reader's socket)
            // before another request frame is read.
            let p = conn.pending.pop_front().expect("pending non-empty");
            let resp = p.ticket.wait();
            resolve(conn, inner, p.id, &p.tenant, resp);
            continue;
        }
        match wire::read_frame(&mut conn.stream) {
            Ok(Some(frame)) => {
                inner.ledger.frame_in();
                match frame {
                    Frame::Request(req) => handle_request(conn, inner, req),
                    Frame::Drain => {
                        finish(conn, inner);
                        return;
                    }
                    Frame::StatsReq => {
                        let report = inner.ledger.snapshot();
                        conn.send(inner, &Frame::Stats(report));
                    }
                    // Anything else is a protocol violation from this
                    // side of the conversation; resolve and close.
                    _ => {
                        abandon(conn, inner);
                        return;
                    }
                }
            }
            Ok(None) => {
                // Peer closed without Drain: resolve what's in flight
                // for the ledger, skip the writes.
                abandon(conn, inner);
                return;
            }
            Err(e) if e.is_timeout() => continue,
            Err(_) => {
                abandon(conn, inner);
                return;
            }
        }
    }
}

fn handle_request(conn: &mut Conn, inner: &Arc<Inner>, req: WireRequest) {
    let WireRequest { id, pipeline, priority, deadline_ms, payload } = req;
    let tenant = conn.tenant.clone();
    inner.ledger.tenant_admitted(&tenant);
    // Tenant lane gate: at depth, shed immediately — first-class frame,
    // deterministic at a fixed depth, never a dropped connection.
    let lane_open = {
        let mut lanes = inner.lanes.lock().unwrap();
        let in_flight = lanes.entry(tenant.clone()).or_default();
        if *in_flight >= inner.cfg.per_tenant_depth {
            false
        } else {
            *in_flight += 1;
            true
        }
    };
    if !lane_open {
        inner.ledger.tenant_shed(&tenant);
        conn.shed += 1;
        conn.shed_by_cause[ShedCause::TenantLaneFull.index()] += 1;
        conn.send(
            inner,
            &Frame::Shed { id, pipeline, priority, cause: ShedCause::TenantLaneFull, waited_us: 0 },
        );
        return;
    }
    let request = Request {
        pipeline: pipeline.clone(),
        payload: payload.into_workload(),
        priority,
        deadline: wire::decode_deadline_ms(deadline_ms),
    };
    match inner.service.submit(request) {
        Ok(ticket) => conn.pending.push_back(Pending { id, tenant, ticket }),
        Err(e) => {
            lane_release(inner, &tenant);
            inner.ledger.tenant_failed(&tenant);
            conn.failed += 1;
            conn.send(inner, &Frame::Failed { id, pipeline, error: format!("{e:#}") });
        }
    }
}

fn lane_release(inner: &Inner, tenant: &str) {
    let mut lanes = inner.lanes.lock().unwrap();
    if let Some(in_flight) = lanes.get_mut(tenant) {
        *in_flight = in_flight.saturating_sub(1);
    }
}

/// Write (and account) the response for one resolved ticket.
fn resolve(conn: &mut Conn, inner: &Inner, id: u64, tenant: &str, resp: Response) {
    lane_release(inner, tenant);
    let frame = match resp {
        Response::Completed(c) => {
            inner.ledger.tenant_completed(tenant);
            conn.completed += 1;
            Frame::Completed(WireCompletion {
                id,
                pipeline: c.pipeline,
                items: c.result.items as u64,
                queue_wait_us: c.queue_wait.as_micros() as u64,
                service_us: c.service_time.as_micros() as u64,
                summary: c.output.summary(),
                metrics: c.result.metrics.into_iter().collect(),
            })
        }
        Response::Shed { pipeline, priority, reason, waited } => {
            inner.ledger.tenant_shed(tenant);
            let cause: ShedCause = reason.into();
            conn.shed += 1;
            conn.shed_by_cause[cause.index()] += 1;
            Frame::Shed { id, pipeline, priority, cause, waited_us: waited.as_micros() as u64 }
        }
        Response::Failed { pipeline, error } => {
            inner.ledger.tenant_failed(tenant);
            conn.failed += 1;
            Frame::Failed { id, pipeline, error }
        }
    };
    conn.send(inner, &frame);
}

/// Resolve every ticket whose response is already available.
fn flush_ready(conn: &mut Conn, inner: &Inner) {
    // Completion order, not submission order: scan the whole pending
    // set and resolve whatever is done (responses correlate by id).
    let mut i = 0;
    while i < conn.pending.len() {
        if conn.pending[i].ticket.is_done() {
            let p = conn.pending.remove(i).expect("index in bounds");
            let resp = p.ticket.wait(); // buffered: returns immediately
            resolve(conn, inner, p.id, &p.tenant, resp);
        } else {
            i += 1;
        }
    }
}

/// Drain this connection: flush every in-flight ticket (writing each
/// response), then close with the outcome counters. Zero responses are
/// lost — each pending ticket is waited to resolution.
fn finish(conn: &mut Conn, inner: &Inner) {
    while let Some(p) = conn.pending.pop_front() {
        let resp = p.ticket.wait();
        resolve(conn, inner, p.id, &p.tenant, resp);
    }
    let goodbye = Frame::Goodbye {
        completed: conn.completed,
        shed: conn.shed,
        failed: conn.failed,
        shed_by_cause: conn.shed_by_cause,
    };
    conn.send(inner, &goodbye);
}

/// The peer vanished (EOF or protocol garbage): resolve every pending
/// ticket for the ledger — lanes release and tenant ledgers balance
/// even when nobody is left to read the responses.
fn abandon(conn: &mut Conn, inner: &Inner) {
    conn.writable = false;
    while let Some(p) = conn.pending.pop_front() {
        let resp = p.ticket.wait();
        resolve(conn, inner, p.id, &p.tenant, resp);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipelines::{RunConfig, Toggles};
    use crate::service::{Priority, ServiceConfig};

    fn tiny() -> RunConfig {
        RunConfig { toggles: Toggles::optimized(), scale: 0.05, seed: 5, ..Default::default() }
    }

    fn start_census(cfg: ServerConfig) -> (Arc<PipelineService>, PipelineServer) {
        let svc = Arc::new(
            PipelineService::open(
                &["census"],
                ServiceConfig { defaults: tiny(), queue_depth: 32, ..Default::default() },
            )
            .unwrap(),
        );
        let server =
            PipelineServer::start(Arc::clone(&svc), "127.0.0.1:0", cfg).unwrap();
        (svc, server)
    }

    fn hello(stream: &mut TcpStream, tenant: &str) -> Vec<String> {
        wire::write_frame(stream, &Frame::Hello { tenant: to(tenant) }).unwrap();
        match wire::read_frame(stream).unwrap().unwrap() {
            Frame::HelloAck { pipelines } => pipelines,
            other => panic!("expected HelloAck, got {}", other.kind()),
        }
    }

    fn to(s: &str) -> String {
        s.to_string()
    }

    #[test]
    fn handshake_request_stats_drain_round_trip() {
        let (_svc, server) = start_census(ServerConfig::default());
        let mut c = TcpStream::connect(server.local_addr()).unwrap();
        assert_eq!(hello(&mut c, "t-a"), vec!["census".to_string()]);
        wire::write_frame(
            &mut c,
            &Frame::Request(WireRequest {
                id: 42,
                pipeline: to("census"),
                priority: Priority::Normal,
                deadline_ms: 0,
                payload: wire::WirePayload::Synthetic,
            }),
        )
        .unwrap();
        match wire::read_frame(&mut c).unwrap().unwrap() {
            Frame::Completed(done) => {
                assert_eq!(done.id, 42);
                assert_eq!(done.pipeline, "census");
                assert!(done.items > 0);
                assert!(done.metrics.iter().any(|(k, _)| k == "r2"));
                assert!(!done.summary.is_empty());
            }
            other => panic!("expected Completed, got {}", other.kind()),
        }
        // StatsReq sees the tenant's ledger mid-connection.
        wire::write_frame(&mut c, &Frame::StatsReq).unwrap();
        match wire::read_frame(&mut c).unwrap().unwrap() {
            Frame::Stats(report) => {
                assert_eq!(report.accepted, 1);
                assert_eq!(report.active(), 1, "this connection is still open");
                let t = report.tenants.get("t-a").expect("tenant ledger exists");
                assert_eq!(t.admitted, 1);
                assert_eq!(t.completed, 1);
                assert!(t.balances());
            }
            other => panic!("expected Stats, got {}", other.kind()),
        }
        // Client-initiated drain: Goodbye carries the outcome counters.
        wire::write_frame(&mut c, &Frame::Drain).unwrap();
        match wire::read_frame(&mut c).unwrap().unwrap() {
            Frame::Goodbye { completed, shed, failed, shed_by_cause } => {
                assert_eq!((completed, shed, failed), (1, 0, 0));
                assert_eq!(shed_by_cause, [0; SHED_CAUSE_COUNT]);
            }
            other => panic!("expected Goodbye, got {}", other.kind()),
        }
        assert!(wire::read_frame(&mut c).unwrap().is_none(), "server closed after Goodbye");
        let report = server.drain();
        assert_eq!(report.accepted, 1);
        assert_eq!(report.drained, 1);
        assert!(report.balanced(), "{report:?}");
    }

    #[test]
    fn unknown_pipeline_resolves_as_failed_frame() {
        let (_svc, server) = start_census(ServerConfig::default());
        let mut c = TcpStream::connect(server.local_addr()).unwrap();
        hello(&mut c, "t-bad");
        wire::write_frame(
            &mut c,
            &Frame::Request(WireRequest {
                id: 1,
                pipeline: to("nope"),
                priority: Priority::Normal,
                deadline_ms: 0,
                payload: wire::WirePayload::Synthetic,
            }),
        )
        .unwrap();
        match wire::read_frame(&mut c).unwrap().unwrap() {
            Frame::Failed { id, pipeline, error } => {
                assert_eq!(id, 1);
                assert_eq!(pipeline, "nope");
                assert!(error.contains("census"), "{error}");
            }
            other => panic!("expected Failed, got {}", other.kind()),
        }
        drop(c); // vanish without Drain: the ledger must still balance
        let report = server.drain();
        assert!(report.balanced(), "{report:?}");
        let t = &report.tenants["t-bad"];
        assert_eq!((t.admitted, t.failed), (1, 1));
    }

    #[test]
    fn garbage_bytes_close_the_connection_without_panic() {
        let (_svc, server) = start_census(ServerConfig::default());
        let mut c = TcpStream::connect(server.local_addr()).unwrap();
        use std::io::Write as _;
        c.write_all(b"GET / HTTP/1.1\r\n\r\n").unwrap();
        // The server closes on the protocol error; the connection still
        // counts accepted → drained.
        let mut buf = [0u8; 16];
        use std::io::Read as _;
        let _ = c.read(&mut buf);
        drop(c);
        let report = server.drain();
        assert_eq!(report.accepted, 1);
        assert_eq!(report.drained, 1);
        assert!(report.balanced(), "{report:?}");
    }
}
