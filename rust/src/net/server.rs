//! `PipelineServer` — the TCP serving edge over a
//! [`PipelineService`].
//!
//! One accept loop, one **resumable connection task per connection**,
//! all speaking the [`wire`](super::wire) protocol. Connection tasks
//! are multiplexed on a cooperative [`Scheduler`] pool — the service's
//! own `ExecMode::Async` pool when it has one, so sockets and plan
//! stages share one set of workers; otherwise a small pool owned by
//! the server ([`ServerConfig::net_workers`]). A connection with
//! nothing to do parks on its per-connection [`Signal`]
//! ([`Poll::Park`]) instead of burning a thread in a read-timeout
//! loop: ticket resolutions notify the signal directly
//! ([`PipelineService::submit_with_notify`]), and one timer thread
//! ticks every [`ServerConfig::poll_interval`] to wake parked tasks
//! for socket reads, drain checks, and the idle reaper.
//!
//! **Admission gate.** At [`ServerConfig::max_conns`] live
//! connections, further accepts are answered with a first-class
//! `Shed(ServerFull)` frame and closed — never a silent RST — and
//! counted in [`NetReport::rejected`] (never in `accepted`).
//!
//! **Idle reaper.** With [`ServerConfig::idle_after`] > 0, a
//! connection with no frame activity and nothing in flight for that
//! many timer ticks is closed with a `Goodbye` and counted in
//! [`NetReport::reaped_idle`] — or [`NetReport::reaped_handshake`]
//! when the peer never completed its `Hello` (those used to spin
//! forever). The drained-server invariant becomes
//! `accepted == drained + reaped`.
//!
//! **Per-tenant lanes.** Every connection declares a tenant id in its
//! `Hello`. The server holds one in-flight counter per tenant (shared
//! across that tenant's connections): a tenant at its
//! [`ServerConfig::per_tenant_depth`] gets an immediate first-class
//! [`Frame::Shed`] (`TenantLaneFull`) for further requests — one
//! noisy tenant saturates its own lane, not the shared admission
//! queue, and never costs anyone a connection. A lane entry is
//! removed the moment its in-flight count returns to zero, so a churn
//! of one-shot tenants cannot grow the map forever.
//!
//! **Backpressure.** A connection may hold at most
//! [`ServerConfig::conn_inflight`] unresolved tickets. Past that, the
//! task stops reading requests and parks until a ticket resolves — a
//! slow pipeline stalls its own connection (bounded memory), it does
//! not balloon the pending set. Writes are buffered per connection
//! and flushed as the nonblocking socket accepts them, so a slow
//! reader never wedges a pool worker.
//!
//! **Graceful drain.** [`PipelineServer::drain`] stops the accept
//! loop, then every connection task flushes its in-flight tickets,
//! writes each response, and closes with a `Goodbye` carrying the
//! connection's outcome counters — zero lost responses, which the
//! soak tests pin from the [`NetReport`] ledger (`accepted ==
//! drained + reaped`, and per tenant `admitted == completed + shed +
//! failed`), never wall-clock.

use super::wire::{self, Frame, ShedCause, WireCompletion, WireRequest, SHED_CAUSE_COUNT};
use crate::coordinator::sched::{Poll, Scheduler, Signal, WaitGroup};
use crate::coordinator::telemetry::{NetLedger, NetReport, SchedReport};
use crate::service::{PipelineService, Priority, Request, Response, Ticket};
use std::collections::{BTreeMap, VecDeque};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// How a [`PipelineServer`] is provisioned.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Max in-flight (admitted, unresolved) requests per tenant across
    /// all of that tenant's connections; further requests shed with
    /// [`ShedCause::TenantLaneFull`].
    pub per_tenant_depth: usize,
    /// Max unresolved tickets per connection before the task stops
    /// reading requests (backpressure for slow pipelines).
    pub conn_inflight: usize,
    /// Timer-tick cadence: how often parked connection tasks are woken
    /// to poll their sockets, notice the drain flag, and advance the
    /// idle clock. Liveness only: no correctness property depends on
    /// this value.
    pub poll_interval: Duration,
    /// Ceiling on live connections. At the ceiling, an accepted socket
    /// is answered with a `Shed(ServerFull)` frame and closed
    /// (counted in [`NetReport::rejected`], never `accepted`).
    pub max_conns: usize,
    /// Idle reaper threshold, in timer ticks: a connection with no
    /// frame activity and nothing in flight for this many ticks is
    /// closed and counted as reaped. `0` disables the reaper.
    pub idle_after: usize,
    /// Size of the server-owned scheduler pool used when the service
    /// has no shared `ExecMode::Async` pool to multiplex onto.
    pub net_workers: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            per_tenant_depth: 8,
            conn_inflight: 32,
            poll_interval: Duration::from_millis(1),
            max_conns: 1024,
            idle_after: 0,
            net_workers: 2,
        }
    }
}

struct Inner {
    service: Arc<PipelineService>,
    ledger: NetLedger,
    /// In-flight admitted requests per tenant (the admission lanes).
    /// Entries are removed on release-to-zero so tenant churn cannot
    /// grow the map without bound.
    lanes: Mutex<BTreeMap<String, usize>>,
    draining: AtomicBool,
    cfg: ServerConfig,
    /// Monotonic timer ticks — the reaper's (and only) clock.
    ticks: AtomicUsize,
    /// Every live connection's wakeup signal, notified on each tick.
    signals: Mutex<BTreeMap<u64, Signal>>,
    /// Live connections (accepted minus closed) — the `max_conns` gate.
    active: AtomicUsize,
    /// Outstanding connection tasks; drained by shutdown.
    conn_wg: WaitGroup,
    timer_stop: AtomicBool,
    next_conn_id: AtomicU64,
}

/// The TCP serving front-end (see module docs).
pub struct PipelineServer {
    inner: Arc<Inner>,
    addr: SocketAddr,
    accept: Option<JoinHandle<()>>,
    timer: Option<JoinHandle<()>>,
    /// The pool connection tasks run on. Deliberately NOT stored in
    /// [`Inner`]: tasks hold `Arc<Inner>`, and a task must never
    /// (transitively) own its own scheduler or the pool could be
    /// dropped — and join itself — from one of its own workers.
    sched: Arc<Scheduler>,
}

impl PipelineServer {
    /// Bind `addr` (e.g. `127.0.0.1:0` for an ephemeral port) and start
    /// accepting connections over `service`.
    pub fn start(
        service: Arc<PipelineService>,
        addr: &str,
        cfg: ServerConfig,
    ) -> anyhow::Result<PipelineServer> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| anyhow::anyhow!("cannot bind {addr}: {e}"))?;
        let local = listener.local_addr()?;
        // Multiplex onto the service's shared async pool when it has
        // one; otherwise the server owns a small pool of its own.
        let sched = match service.scheduler() {
            Some(shared) => shared,
            None => Arc::new(Scheduler::new(cfg.net_workers.max(1))),
        };
        let inner = Arc::new(Inner {
            service,
            ledger: NetLedger::default(),
            lanes: Mutex::new(BTreeMap::new()),
            draining: AtomicBool::new(false),
            cfg,
            ticks: AtomicUsize::new(0),
            signals: Mutex::new(BTreeMap::new()),
            active: AtomicUsize::new(0),
            conn_wg: WaitGroup::new(),
            timer_stop: AtomicBool::new(false),
            next_conn_id: AtomicU64::new(0),
        });
        let accept_inner = Arc::clone(&inner);
        let accept_sched = Arc::clone(&sched);
        let accept = std::thread::Builder::new()
            .name("pipeline-server-accept".to_string())
            .spawn(move || accept_loop(&listener, &accept_inner, &accept_sched))
            .expect("spawn accept loop");
        let timer_inner = Arc::clone(&inner);
        let timer = std::thread::Builder::new()
            .name("pipeline-server-timer".to_string())
            .spawn(move || timer_loop(&timer_inner))
            .expect("spawn server timer");
        Ok(PipelineServer { inner, addr: local, accept: Some(accept), timer: Some(timer), sched })
    }

    /// The bound address (with the real port when started on `:0`).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Live snapshot of the serving ledger.
    pub fn report(&self) -> NetReport {
        self.inner.ledger.snapshot()
    }

    /// Counters of the scheduler pool the connection tasks run on.
    /// When the service runs `ExecMode::Async` this is the SHARED pool,
    /// so the snapshot covers plan stages and socket tasks together.
    pub fn sched_report(&self) -> SchedReport {
        self.sched.counters()
    }

    /// Number of tenants currently holding a non-zero admission lane.
    /// Returns to zero whenever nothing is in flight — lane entries are
    /// removed on release-to-zero, which is what keeps a churn of
    /// one-shot tenants from growing the map forever.
    pub fn lane_count(&self) -> usize {
        self.inner.lanes.lock().unwrap().len()
    }

    /// Graceful drain: stop accepting, let every connection task flush
    /// its in-flight tickets and say `Goodbye`, then return the final
    /// ledger. Requires the underlying service to be running (a paused
    /// service never resolves the in-flight tickets being flushed).
    pub fn drain(mut self) -> NetReport {
        self.shutdown()
    }

    fn shutdown(&mut self) -> NetReport {
        self.inner.draining.store(true, Ordering::SeqCst);
        if let Some(accept) = self.accept.take() {
            // A sentinel connection unblocks the accept() call so the
            // loop observes the drain flag; it is dropped uncounted.
            let _ = TcpStream::connect(self.addr);
            let _ = accept.join();
        }
        // The timer keeps ticking while connection tasks drain — its
        // wakeups are how parked tasks observe the drain flag.
        self.inner.conn_wg.wait();
        self.inner.timer_stop.store(true, Ordering::SeqCst);
        if let Some(timer) = self.timer.take() {
            let _ = timer.join();
        }
        self.inner.ledger.snapshot()
    }
}

impl Drop for PipelineServer {
    fn drop(&mut self) {
        if self.accept.is_some() {
            self.shutdown();
        }
    }
}

/// Wake every live connection task and advance the reaper clock, once
/// per [`ServerConfig::poll_interval`], until told to stop.
fn timer_loop(inner: &Arc<Inner>) {
    while !inner.timer_stop.load(Ordering::SeqCst) {
        std::thread::sleep(inner.cfg.poll_interval);
        inner.ticks.fetch_add(1, Ordering::SeqCst);
        let signals: Vec<Signal> = inner.signals.lock().unwrap().values().cloned().collect();
        for signal in signals {
            signal.notify();
        }
    }
}

fn accept_loop(listener: &TcpListener, inner: &Arc<Inner>, sched: &Arc<Scheduler>) {
    for stream in listener.incoming() {
        if inner.draining.load(Ordering::SeqCst) {
            // The final (possibly sentinel) stream is dropped without
            // counting: `accepted` only ever counts served connections.
            break;
        }
        let Ok(mut stream) = stream else { continue };
        if inner.active.load(Ordering::SeqCst) >= inner.cfg.max_conns {
            // Admission gate: answer with a first-class frame, never a
            // silent RST. The write is best-effort and blocking — the
            // socket never reaches a pool worker.
            inner.ledger.connection_rejected();
            let refusal = Frame::Shed {
                id: 0,
                pipeline: String::new(),
                priority: Priority::Normal,
                cause: ShedCause::ServerFull,
                waited_us: 0,
            };
            if wire::write_frame(&mut stream, &refusal).is_ok() {
                inner.ledger.frame_out();
                // Consume whatever the peer already sent (typically its
                // Hello) before dropping: closing with unread receive
                // data resets the connection, which can destroy the
                // refusal frame in flight. FIN first so the peer's read
                // after the Shed sees a clean EOF; the drain is bounded
                // by a short read timeout.
                let _ = stream.shutdown(std::net::Shutdown::Write);
                let _ = stream.set_read_timeout(Some(Duration::from_millis(50)));
                let mut sink = [0u8; 256];
                use std::io::Read as _;
                while matches!(stream.read(&mut sink), Ok(n) if n > 0) {}
            }
            continue;
        }
        inner.ledger.connection_accepted();
        inner.active.fetch_add(1, Ordering::SeqCst);
        let _ = stream.set_nodelay(true);
        let _ = stream.set_nonblocking(true);
        let id = inner.next_conn_id.fetch_add(1, Ordering::SeqCst);
        let signal = Signal::new();
        inner.signals.lock().unwrap().insert(id, signal.clone());
        inner.conn_wg.add(1);
        let mut task = ConnTask::new(Arc::clone(inner), id, signal, stream);
        sched.spawn(Box::new(move || task.poll()));
    }
}

/// One unresolved request riding a connection.
struct Pending {
    id: u64,
    tenant: String,
    ticket: Ticket,
}

/// Where a connection task is in its life.
enum ConnState {
    /// Waiting for the peer's `Hello`.
    Handshake,
    /// Reading requests, resolving tickets.
    Serving,
    /// No further reads: resolve every pending ticket, then `Goodbye`.
    Flush,
    /// Goodbye queued: drain the write buffer, then close.
    Closing,
}

/// How the connection ends — what the close is counted as.
enum EndKind {
    Drained,
    ReapedIdle,
    ReapedHandshake,
}

/// A resumable connection task, polled by the scheduler pool. Between
/// wakeups it holds no thread: it parks on its [`Signal`], which is
/// notified by ticket resolutions and by the server timer.
struct ConnTask {
    inner: Arc<Inner>,
    id: u64,
    signal: Signal,
    stream: TcpStream,
    state: ConnState,
    end: EndKind,
    tenant: String,
    pending: VecDeque<Pending>,
    /// Outbound bytes not yet accepted by the nonblocking socket.
    out: Vec<u8>,
    /// False once a write failed (peer gone): ledger resolution
    /// continues, frames stop.
    writable: bool,
    /// Tick count at the last frame read or ticket resolution — the
    /// idle reaper compares this against the timer's tick clock.
    last_activity: usize,
    completed: u64,
    shed: u64,
    /// Sheds broken out per [`ShedCause`] (in `ShedCause::ALL` order);
    /// sums to `shed` and rides the `Goodbye` so clients can attribute
    /// every shed without parsing individual frames.
    shed_by_cause: [u64; SHED_CAUSE_COUNT],
    failed: u64,
}

impl ConnTask {
    fn new(inner: Arc<Inner>, id: u64, signal: Signal, stream: TcpStream) -> ConnTask {
        let last_activity = inner.ticks.load(Ordering::SeqCst);
        ConnTask {
            inner,
            id,
            signal,
            stream,
            state: ConnState::Handshake,
            end: EndKind::Drained,
            tenant: String::new(),
            pending: VecDeque::new(),
            out: Vec::new(),
            writable: true,
            last_activity,
            completed: 0,
            shed: 0,
            shed_by_cause: [0; SHED_CAUSE_COUNT],
            failed: 0,
        }
    }

    /// One cooperative poll. The signal generation is snapshotted
    /// BEFORE any blocking condition is checked (the park protocol), so
    /// a ticket resolution or timer tick racing the decision to park
    /// re-enqueues the task instead of stranding it.
    fn poll(&mut self) -> Poll {
        let seen = self.signal.generation();
        match self.state {
            ConnState::Handshake => self.poll_handshake(seen),
            ConnState::Serving => self.poll_serving(seen),
            ConnState::Flush => self.poll_flush(seen),
            ConnState::Closing => self.poll_closing(),
        }
    }

    fn poll_handshake(&mut self, seen: usize) -> Poll {
        if self.inner.draining.load(Ordering::SeqCst) {
            // Drained before the handshake finished: nothing in flight.
            self.queue_goodbye();
            self.state = ConnState::Closing;
            return Poll::Yield;
        }
        match wire::read_frame(&mut self.stream) {
            Ok(Some(Frame::Hello { tenant })) => {
                self.inner.ledger.frame_in();
                self.touch();
                self.tenant = tenant;
                let pipelines =
                    self.inner.service.session_names().iter().map(|s| s.to_string()).collect();
                self.send(&Frame::HelloAck { pipelines });
                self.state = ConnState::Serving;
                Poll::Yield
            }
            Ok(Some(_)) => {
                // A protocol-violating first frame is still a frame the
                // server read and parsed: count it, then close with a
                // zero-counter Goodbye so `frames_in` never disagrees
                // with bytes consumed off the socket.
                self.inner.ledger.frame_in();
                self.queue_goodbye();
                self.state = ConnState::Closing;
                Poll::Yield
            }
            Ok(None) => {
                // Peer closed before saying Hello.
                self.writable = false;
                self.state = ConnState::Closing;
                Poll::Yield
            }
            Err(e) if e.is_timeout() => {
                if self.reap_due() {
                    // A handshake that never completes used to spin its
                    // handler thread forever; now it is reaped.
                    self.end = EndKind::ReapedHandshake;
                    self.queue_goodbye();
                    self.state = ConnState::Closing;
                    return Poll::Yield;
                }
                Poll::Park { signal: self.signal.clone(), seen }
            }
            Err(_) => {
                // Garbage where the Hello should be: close without
                // trusting the stream with any further framing.
                self.writable = false;
                self.state = ConnState::Closing;
                Poll::Yield
            }
        }
    }

    fn poll_serving(&mut self, seen: usize) -> Poll {
        let mut progressed = self.flush_ready() > 0;
        self.flush_out();
        if self.inner.draining.load(Ordering::SeqCst) {
            self.state = ConnState::Flush;
            return Poll::Yield;
        }
        // Read until the in-flight cap: past it, the task parks until a
        // ticket resolves (its resolution notifies our signal).
        while self.pending.len() < self.inner.cfg.conn_inflight {
            match wire::read_frame(&mut self.stream) {
                Ok(Some(frame)) => {
                    self.inner.ledger.frame_in();
                    self.touch();
                    progressed = true;
                    match frame {
                        Frame::Request(req) => self.handle_request(req),
                        Frame::Drain => {
                            self.state = ConnState::Flush;
                            return Poll::Yield;
                        }
                        Frame::StatsReq => {
                            let report = self.inner.ledger.snapshot();
                            self.send(&Frame::Stats(report));
                        }
                        Frame::TenantStatsReq => {
                            let ledger = self
                                .inner
                                .ledger
                                .snapshot()
                                .tenants
                                .get(&self.tenant)
                                .copied()
                                .unwrap_or_default();
                            self.send(&Frame::TenantStats {
                                tenant: self.tenant.clone(),
                                ledger,
                            });
                        }
                        // Anything else is a protocol violation from
                        // this side of the conversation; resolve what's
                        // in flight (ledger!) and close without writes.
                        _ => {
                            self.writable = false;
                            self.state = ConnState::Flush;
                            return Poll::Yield;
                        }
                    }
                }
                Ok(None) => {
                    // Peer closed without Drain: resolve what's in
                    // flight for the ledger, skip the writes.
                    self.writable = false;
                    self.state = ConnState::Flush;
                    return Poll::Yield;
                }
                Err(e) if e.is_timeout() => break,
                Err(_) => {
                    self.writable = false;
                    self.state = ConnState::Flush;
                    return Poll::Yield;
                }
            }
        }
        if progressed {
            return Poll::Yield;
        }
        if self.pending.is_empty() && self.reap_due() {
            // Idle: established, nothing in flight, no frame activity
            // for `idle_after` ticks.
            self.end = EndKind::ReapedIdle;
            self.queue_goodbye();
            self.state = ConnState::Closing;
            return Poll::Yield;
        }
        Poll::Park { signal: self.signal.clone(), seen }
    }

    fn poll_flush(&mut self, seen: usize) -> Poll {
        let progressed = self.flush_ready() > 0;
        self.flush_out();
        if !self.pending.is_empty() {
            // Still waiting on tickets; their resolutions notify us.
            return if progressed {
                Poll::Yield
            } else {
                Poll::Park { signal: self.signal.clone(), seen }
            };
        }
        self.queue_goodbye();
        self.state = ConnState::Closing;
        Poll::Yield
    }

    fn poll_closing(&mut self) -> Poll {
        self.flush_out();
        if !self.out.is_empty() && self.writable {
            // The peer's socket is full; the next timer tick retries.
            let seen = self.signal.generation();
            return Poll::Park { signal: self.signal.clone(), seen };
        }
        self.close()
    }

    /// Final bookkeeping; the task must not be polled again.
    fn close(&mut self) -> Poll {
        self.inner.signals.lock().unwrap().remove(&self.id);
        self.inner.active.fetch_sub(1, Ordering::SeqCst);
        match self.end {
            EndKind::Drained => self.inner.ledger.connection_drained(),
            EndKind::ReapedIdle => self.inner.ledger.connection_reaped(false),
            EndKind::ReapedHandshake => self.inner.ledger.connection_reaped(true),
        }
        self.inner.conn_wg.done();
        Poll::Done
    }

    /// Record frame activity for the idle reaper.
    fn touch(&mut self) {
        self.last_activity = self.inner.ticks.load(Ordering::SeqCst);
    }

    /// Whether the idle reaper's threshold has elapsed since the last
    /// activity. Always false when the reaper is disabled.
    fn reap_due(&self) -> bool {
        let after = self.inner.cfg.idle_after;
        if after == 0 {
            return false;
        }
        let now = self.inner.ticks.load(Ordering::SeqCst);
        now.saturating_sub(self.last_activity) >= after
    }

    /// Queue one frame on the outbound buffer (unless the peer is
    /// already gone) and push what the socket will take.
    fn send(&mut self, frame: &Frame) {
        if !self.writable {
            return;
        }
        self.out.extend_from_slice(&wire::encode(frame));
        self.inner.ledger.frame_out();
        self.flush_out();
    }

    /// Push buffered bytes into the nonblocking socket. `WouldBlock`
    /// leaves the remainder for the next wakeup; any real write error
    /// flips `writable` (ledger resolution continues, frames stop).
    fn flush_out(&mut self) {
        use std::io::Write as _;
        while !self.out.is_empty() {
            if !self.writable {
                self.out.clear();
                return;
            }
            match self.stream.write(&self.out) {
                Ok(0) => {
                    self.writable = false;
                    self.out.clear();
                }
                Ok(n) => {
                    self.out.drain(..n);
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return,
                Err(_) => {
                    self.writable = false;
                    self.out.clear();
                }
            }
        }
    }

    fn queue_goodbye(&mut self) {
        let goodbye = Frame::Goodbye {
            completed: self.completed,
            shed: self.shed,
            failed: self.failed,
            shed_by_cause: self.shed_by_cause,
        };
        self.send(&goodbye);
    }

    fn handle_request(&mut self, req: WireRequest) {
        let WireRequest { id, pipeline, priority, deadline_ms, payload } = req;
        let tenant = self.tenant.clone();
        self.inner.ledger.tenant_admitted(&tenant);
        // Tenant lane gate: at depth, shed immediately — first-class
        // frame, deterministic at a fixed depth, never a dropped
        // connection.
        let lane_open = {
            let mut lanes = self.inner.lanes.lock().unwrap();
            let in_flight = lanes.entry(tenant.clone()).or_default();
            if *in_flight >= self.inner.cfg.per_tenant_depth {
                false
            } else {
                *in_flight += 1;
                true
            }
        };
        if !lane_open {
            self.inner.ledger.tenant_shed(&tenant);
            self.shed += 1;
            self.shed_by_cause[ShedCause::TenantLaneFull.index()] += 1;
            self.send(&Frame::Shed {
                id,
                pipeline,
                priority,
                cause: ShedCause::TenantLaneFull,
                waited_us: 0,
            });
            return;
        }
        let request = Request {
            pipeline: pipeline.clone(),
            payload: payload.into_workload(),
            priority,
            deadline: wire::decode_deadline_ms(deadline_ms),
        };
        // The ticket's resolution notifies this connection's signal —
        // that is what wakes a parked task; it never blocks in
        // `Ticket::wait`.
        match self.inner.service.submit_with_notify(request, self.signal.clone()) {
            Ok(ticket) => self.pending.push_back(Pending { id, tenant, ticket }),
            Err(e) => {
                lane_release(&self.inner, &tenant);
                self.inner.ledger.tenant_failed(&tenant);
                self.failed += 1;
                self.send(&Frame::Failed { id, pipeline, error: format!("{e:#}") });
            }
        }
    }

    /// Resolve every ticket whose response is already available;
    /// returns how many resolved. Never blocks: `is_done` is the
    /// non-consuming check, and `wait` on a done ticket returns its
    /// buffered response immediately.
    fn flush_ready(&mut self) -> usize {
        // Completion order, not submission order: scan the whole
        // pending set and resolve whatever is done (responses correlate
        // by id).
        let mut resolved = 0;
        let mut i = 0;
        while i < self.pending.len() {
            if self.pending[i].ticket.is_done() {
                let p = self.pending.remove(i).expect("index in bounds");
                let resp = p.ticket.wait(); // buffered: returns immediately
                self.resolve(p.id, &p.tenant, resp);
                resolved += 1;
            } else {
                i += 1;
            }
        }
        if resolved > 0 {
            self.touch();
        }
        resolved
    }

    /// Write (and account) the response for one resolved ticket.
    fn resolve(&mut self, id: u64, tenant: &str, resp: Response) {
        lane_release(&self.inner, tenant);
        let frame = match resp {
            Response::Completed(c) => {
                self.inner.ledger.tenant_completed(tenant);
                self.completed += 1;
                Frame::Completed(WireCompletion {
                    id,
                    pipeline: c.pipeline,
                    items: c.result.items as u64,
                    queue_wait_us: c.queue_wait.as_micros() as u64,
                    service_us: c.service_time.as_micros() as u64,
                    summary: c.output.summary(),
                    metrics: c.result.metrics.into_iter().collect(),
                })
            }
            Response::Shed { pipeline, priority, reason, waited } => {
                self.inner.ledger.tenant_shed(tenant);
                let cause: ShedCause = reason.into();
                self.shed += 1;
                self.shed_by_cause[cause.index()] += 1;
                Frame::Shed {
                    id,
                    pipeline,
                    priority,
                    cause,
                    waited_us: waited.as_micros() as u64,
                }
            }
            Response::Failed { pipeline, error } => {
                self.inner.ledger.tenant_failed(tenant);
                self.failed += 1;
                Frame::Failed { id, pipeline, error }
            }
        };
        self.send(&frame);
    }
}

/// Release one in-flight slot on a tenant's lane, removing the entry
/// entirely when the count returns to zero — the map tracks only
/// tenants with work in flight, so one-shot tenant churn stays O(live).
fn lane_release(inner: &Inner, tenant: &str) {
    let mut lanes = inner.lanes.lock().unwrap();
    if let Some(in_flight) = lanes.get_mut(tenant) {
        *in_flight = in_flight.saturating_sub(1);
        if *in_flight == 0 {
            lanes.remove(tenant);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipelines::{RunConfig, Toggles};
    use crate::service::{Priority, ServiceConfig};

    fn tiny() -> RunConfig {
        RunConfig { toggles: Toggles::optimized(), scale: 0.05, seed: 5, ..Default::default() }
    }

    fn start_census(cfg: ServerConfig) -> (Arc<PipelineService>, PipelineServer) {
        let svc = Arc::new(
            PipelineService::open(
                &["census"],
                ServiceConfig { defaults: tiny(), queue_depth: 32, ..Default::default() },
            )
            .unwrap(),
        );
        let server =
            PipelineServer::start(Arc::clone(&svc), "127.0.0.1:0", cfg).unwrap();
        (svc, server)
    }

    fn hello(stream: &mut TcpStream, tenant: &str) -> Vec<String> {
        wire::write_frame(stream, &Frame::Hello { tenant: to(tenant) }).unwrap();
        match wire::read_frame(stream).unwrap().unwrap() {
            Frame::HelloAck { pipelines } => pipelines,
            other => panic!("expected HelloAck, got {}", other.kind()),
        }
    }

    fn to(s: &str) -> String {
        s.to_string()
    }

    #[test]
    fn handshake_request_stats_drain_round_trip() {
        let (_svc, server) = start_census(ServerConfig::default());
        let mut c = TcpStream::connect(server.local_addr()).unwrap();
        assert_eq!(hello(&mut c, "t-a"), vec!["census".to_string()]);
        wire::write_frame(
            &mut c,
            &Frame::Request(WireRequest {
                id: 42,
                pipeline: to("census"),
                priority: Priority::Normal,
                deadline_ms: 0,
                payload: wire::WirePayload::Synthetic,
            }),
        )
        .unwrap();
        match wire::read_frame(&mut c).unwrap().unwrap() {
            Frame::Completed(done) => {
                assert_eq!(done.id, 42);
                assert_eq!(done.pipeline, "census");
                assert!(done.items > 0);
                assert!(done.metrics.iter().any(|(k, _)| k == "r2"));
                assert!(!done.summary.is_empty());
            }
            other => panic!("expected Completed, got {}", other.kind()),
        }
        // StatsReq sees the tenant's ledger mid-connection.
        wire::write_frame(&mut c, &Frame::StatsReq).unwrap();
        match wire::read_frame(&mut c).unwrap().unwrap() {
            Frame::Stats(report) => {
                assert_eq!(report.accepted, 1);
                assert_eq!(report.active(), 1, "this connection is still open");
                let t = report.tenants.get("t-a").expect("tenant ledger exists");
                assert_eq!(t.admitted, 1);
                assert_eq!(t.completed, 1);
                assert!(t.balances());
            }
            other => panic!("expected Stats, got {}", other.kind()),
        }
        // TenantStatsReq answers with just this connection's tenant.
        wire::write_frame(&mut c, &Frame::TenantStatsReq).unwrap();
        match wire::read_frame(&mut c).unwrap().unwrap() {
            Frame::TenantStats { tenant, ledger } => {
                assert_eq!(tenant, "t-a");
                assert_eq!(ledger.admitted, 1);
                assert_eq!(ledger.completed, 1);
            }
            other => panic!("expected TenantStats, got {}", other.kind()),
        }
        // Client-initiated drain: Goodbye carries the outcome counters.
        wire::write_frame(&mut c, &Frame::Drain).unwrap();
        match wire::read_frame(&mut c).unwrap().unwrap() {
            Frame::Goodbye { completed, shed, failed, shed_by_cause } => {
                assert_eq!((completed, shed, failed), (1, 0, 0));
                assert_eq!(shed_by_cause, [0; SHED_CAUSE_COUNT]);
            }
            other => panic!("expected Goodbye, got {}", other.kind()),
        }
        assert!(wire::read_frame(&mut c).unwrap().is_none(), "server closed after Goodbye");
        let report = server.drain();
        assert_eq!(report.accepted, 1);
        assert_eq!(report.drained, 1);
        assert!(report.balanced(), "{report:?}");
    }

    #[test]
    fn unknown_pipeline_resolves_as_failed_frame() {
        let (_svc, server) = start_census(ServerConfig::default());
        let mut c = TcpStream::connect(server.local_addr()).unwrap();
        hello(&mut c, "t-bad");
        wire::write_frame(
            &mut c,
            &Frame::Request(WireRequest {
                id: 1,
                pipeline: to("nope"),
                priority: Priority::Normal,
                deadline_ms: 0,
                payload: wire::WirePayload::Synthetic,
            }),
        )
        .unwrap();
        match wire::read_frame(&mut c).unwrap().unwrap() {
            Frame::Failed { id, pipeline, error } => {
                assert_eq!(id, 1);
                assert_eq!(pipeline, "nope");
                assert!(error.contains("census"), "{error}");
            }
            other => panic!("expected Failed, got {}", other.kind()),
        }
        drop(c); // vanish without Drain: the ledger must still balance
        let report = server.drain();
        assert!(report.balanced(), "{report:?}");
        let t = &report.tenants["t-bad"];
        assert_eq!((t.admitted, t.failed), (1, 1));
    }

    #[test]
    fn garbage_bytes_close_the_connection_without_panic() {
        let (_svc, server) = start_census(ServerConfig::default());
        let mut c = TcpStream::connect(server.local_addr()).unwrap();
        use std::io::Write as _;
        c.write_all(b"GET / HTTP/1.1\r\n\r\n").unwrap();
        // The server closes on the protocol error; the connection still
        // counts accepted → drained.
        let mut buf = [0u8; 16];
        use std::io::Read as _;
        let _ = c.read(&mut buf);
        drop(c);
        let report = server.drain();
        assert_eq!(report.accepted, 1);
        assert_eq!(report.drained, 1);
        assert!(report.balanced(), "{report:?}");
    }

    #[test]
    fn no_per_connection_threads_are_spawned() {
        let (_svc, server) = start_census(ServerConfig::default());
        let mut conns: Vec<TcpStream> = (0..4)
            .map(|_| {
                let mut c = TcpStream::connect(server.local_addr()).unwrap();
                hello(&mut c, "t-threads");
                c
            })
            .collect();
        // With four live connections there is still no
        // "pipeline-server-conn" thread anywhere in the process — the
        // connections are tasks on the scheduler pool.
        #[cfg(target_os = "linux")]
        {
            let mut names = Vec::new();
            for entry in std::fs::read_dir("/proc/self/task").unwrap() {
                let comm = entry.unwrap().path().join("comm");
                if let Ok(name) = std::fs::read_to_string(comm) {
                    names.push(name.trim().to_string());
                }
            }
            assert!(
                names.iter().all(|n| !n.starts_with("pipeline-server-conn")),
                "per-connection handler threads found: {names:?}"
            );
        }
        for c in &mut conns {
            wire::write_frame(c, &Frame::Drain).unwrap();
            match wire::read_frame(c).unwrap().unwrap() {
                Frame::Goodbye { .. } => {}
                other => panic!("expected Goodbye, got {}", other.kind()),
            }
        }
        drop(conns);
        let report = server.drain();
        assert_eq!(report.accepted, 4);
        assert_eq!(report.drained, 4);
        assert!(report.balanced(), "{report:?}");
    }
}
