//! The network serving edge: a TCP front-end over
//! [`PipelineService`](crate::service::PipelineService).
//!
//! Three layers, each testable below the next:
//!
//! * [`wire`] — the versioned length-prefixed binary protocol. Pure
//!   encode/decode over typed frames; no sockets required to test it.
//! * [`server`] — [`PipelineServer`]: accept loop, resumable
//!   per-connection tasks multiplexed on a shared scheduler pool (no
//!   thread per connection), connection limits with first-class
//!   `Shed(ServerFull)` refusals, an idle-connection reaper, per-tenant
//!   admission lanes, write backpressure, graceful drain. Ledgered end
//!   to end in [`NetReport`](crate::coordinator::telemetry::NetReport).
//! * [`client`] — [`ServeClient`] and the closed-loop load generator
//!   behind `repro bench-serve`.

pub mod client;
pub mod server;
pub mod wire;

pub use client::{run_load, LoadReport, LoadSpec, ServeClient, TenantLoad};
pub use server::{PipelineServer, ServerConfig};
pub use wire::{Frame, ShedCause, WireError, WirePayload, WireRequest};
