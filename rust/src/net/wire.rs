//! The serving wire protocol — a versioned, length-prefixed binary
//! framing over the existing typed request/response vocabulary.
//!
//! Every frame is an 11-byte header followed by a bounded payload:
//!
//! ```text
//! +----------+----------+--------+--------------+------------------+
//! | magic    | version  | type   | payload_len  | payload          |
//! | 4B RPLN  | u16 BE   | u8     | u32 BE       | payload_len bytes|
//! +----------+----------+--------+--------------+------------------+
//! ```
//!
//! The body grammar is a handful of fixed-width integers (big-endian),
//! IEEE-754 bit-pattern `f64`s, and length-prefixed UTF-8 strings — no
//! self-describing format, so encode/decode are pure functions a unit
//! test can exercise without a socket. Decoding NEVER panics: bad
//! magic, unknown versions or frame types, truncated bodies, oversized
//! length prefixes, and malformed UTF-8 all surface as typed
//! [`WireError`]s so a server can answer garbage with a clean protocol
//! error instead of dying.
//!
//! Frame vocabulary (the serving conversation):
//!
//! * [`Frame::Hello`] / [`Frame::HelloAck`] — the connection handshake.
//!   `Hello` declares the connection's **tenant id** (the admission-lane
//!   key); the ack lists the pipelines with open sessions.
//! * [`Frame::Request`] / [`Frame::Completed`] / [`Frame::Shed`] /
//!   [`Frame::Failed`] — one submitted request and its exactly-once
//!   resolution, correlated by a caller-chosen `id` so responses may
//!   arrive out of order while many tickets are in flight.
//! * [`Frame::Drain`] / [`Frame::Goodbye`] — graceful teardown: the
//!   sender of `Drain` promises no further requests; `Goodbye` carries
//!   the connection's outcome counters after the flush.
//! * [`Frame::StatsReq`] / [`Frame::Stats`] — the server's
//!   [`NetReport`] ledger on demand, which is how clients synchronize
//!   on counters instead of sleeping.
//! * [`Frame::TenantStatsReq`] / [`Frame::TenantStats`] — the calling
//!   connection's own [`TenantLedger`] on demand, so a tenant can poll
//!   its admission/outcome counters without receiving (or being
//!   trusted with) the whole-server snapshot.

use crate::coordinator::telemetry::{NetReport, TenantLedger};
use crate::pipelines::Workload;
use crate::service::{Priority, ShedReason};
use std::io::{Read, Write};

/// Frame magic: the four bytes every frame starts with.
pub const MAGIC: [u8; 4] = *b"RPLN";

/// Protocol version accepted by this build.
pub const VERSION: u16 = 1;

/// Fixed header length (magic + version + type + payload length).
pub const HEADER_LEN: usize = 11;

/// Hard cap on a frame's payload length: a length prefix past this is
/// rejected *before* any allocation, so a hostile or corrupt peer
/// cannot make the server balloon memory.
pub const MAX_PAYLOAD: usize = 16 << 20;

/// Why a frame could not be encoded, decoded, or read.
#[derive(Debug)]
pub enum WireError {
    /// Underlying socket/stream error.
    Io(std::io::Error),
    /// The stream did not start with [`MAGIC`].
    BadMagic([u8; 4]),
    /// The peer speaks a protocol version this build does not.
    BadVersion(u16),
    /// Unknown frame-type byte.
    UnknownFrame(u8),
    /// The length prefix exceeds [`MAX_PAYLOAD`].
    TooLarge { len: usize, max: usize },
    /// The stream ended mid-header or mid-payload.
    Truncated { context: &'static str },
    /// The payload bytes do not parse as the frame type's body.
    Malformed(String),
    /// The value has no wire representation (e.g. a [`Workload::Video`]
    /// payload, whose frames are process-local handles).
    Unrepresentable(&'static str),
    /// The server refused the connection itself (before any handshake
    /// completed) with a first-class `Shed` frame — e.g.
    /// [`ShedCause::ServerFull`] when the admission gate is at
    /// `max_conns`. Distinct from a protocol error: the peer spoke the
    /// protocol correctly and said "not now".
    Rejected(ShedCause),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Io(e) => write!(f, "wire i/o error: {e}"),
            WireError::BadMagic(m) => write!(f, "bad frame magic {m:02x?} (expected RPLN)"),
            WireError::BadVersion(v) => {
                write!(f, "unsupported protocol version {v} (this build speaks {VERSION})")
            }
            WireError::UnknownFrame(t) => write!(f, "unknown frame type 0x{t:02x}"),
            WireError::TooLarge { len, max } => {
                write!(f, "frame payload of {len} bytes exceeds the {max}-byte cap")
            }
            WireError::Truncated { context } => write!(f, "truncated frame: {context}"),
            WireError::Malformed(msg) => write!(f, "malformed frame body: {msg}"),
            WireError::Unrepresentable(what) => {
                write!(f, "{what} has no wire representation")
            }
            WireError::Rejected(cause) => {
                write!(f, "connection rejected by the server: {cause}")
            }
        }
    }
}

impl std::error::Error for WireError {}

impl From<std::io::Error> for WireError {
    fn from(e: std::io::Error) -> WireError {
        WireError::Io(e)
    }
}

impl WireError {
    /// True for transient socket conditions (read timeout) rather than
    /// protocol violations — the server's poll loop retries these.
    pub fn is_timeout(&self) -> bool {
        matches!(
            self,
            WireError::Io(e) if matches!(
                e.kind(),
                std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
            )
        )
    }
}

/// Why the serving edge shed a request — the wire-level superset of the
/// in-process [`ShedReason`], extended with the two causes only the
/// network edge can produce.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedCause {
    /// The shared admission queue was full (or the request was
    /// displaced by a higher priority).
    QueueFull,
    /// The request outwaited its deadline in the queue.
    DeadlineExpired,
    /// The connection's tenant already has its full lane depth of
    /// requests in flight — one tenant cannot displace everyone.
    TenantLaneFull,
    /// The server is draining: in-flight work flushes, new work sheds.
    Draining,
    /// The server is at its `max_conns` connection ceiling: the
    /// connection itself is refused with this cause (id 0, empty
    /// pipeline) before any handshake — never a silent RST.
    ServerFull,
}

/// Number of distinct [`ShedCause`]s — the length of the per-cause
/// count arrays carried on the wire, indexed in [`ShedCause::ALL`]
/// (wire-tag) order.
pub const SHED_CAUSE_COUNT: usize = 5;

impl ShedCause {
    /// All causes, in wire-tag order.
    pub const ALL: [ShedCause; SHED_CAUSE_COUNT] = [
        ShedCause::QueueFull,
        ShedCause::DeadlineExpired,
        ShedCause::TenantLaneFull,
        ShedCause::Draining,
        ShedCause::ServerFull,
    ];

    /// Index into per-cause count arrays (same order as [`Self::ALL`]).
    pub fn index(self) -> usize {
        self as usize
    }

    /// Label used in reports and the CLI.
    pub fn label(self) -> &'static str {
        match self {
            ShedCause::QueueFull => "queue_full",
            ShedCause::DeadlineExpired => "deadline_expired",
            ShedCause::TenantLaneFull => "tenant_lane_full",
            ShedCause::Draining => "draining",
            ShedCause::ServerFull => "server_full",
        }
    }

    fn tag(self) -> u8 {
        self as u8
    }

    fn from_tag(t: u8) -> Result<ShedCause, WireError> {
        ShedCause::ALL
            .get(t as usize)
            .copied()
            .ok_or_else(|| WireError::Malformed(format!("shed cause tag {t}")))
    }
}

impl From<ShedReason> for ShedCause {
    fn from(r: ShedReason) -> ShedCause {
        match r {
            ShedReason::QueueFull => ShedCause::QueueFull,
            ShedReason::DeadlineExpired => ShedCause::DeadlineExpired,
        }
    }
}

impl std::fmt::Display for ShedCause {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

/// The wire-encodable subset of [`Workload`]: everything whose data is
/// plain text/number content. The media payloads (`Video`, `Parts`)
/// hold process-local synthesized handles and are deliberately NOT
/// representable — encoding one is a typed error, and remote callers
/// use [`WirePayload::Synthetic`] to ask the session to synthesize its
/// own deterministic media payload server-side.
#[derive(Debug, Clone, PartialEq)]
pub enum WirePayload {
    /// Re-derive the session's deterministic dataset (any pipeline).
    Synthetic,
    /// Tabular CSV rows with the target column (census, iiot).
    Table { csv: String },
    /// Light-curve observations + per-object targets (plasticc).
    LightCurves { csv: String, targets: Vec<f64> },
    /// Documents (+ optional labels) for sentiment serving (dlsa).
    Documents { docs: Vec<String>, labels: Vec<i64> },
    /// Raw JSON review-log lines (dien).
    ReviewLog { json: String },
}

impl WirePayload {
    /// Encode a typed workload; media payloads are a typed error.
    pub fn from_workload(w: &Workload) -> Result<WirePayload, WireError> {
        match w {
            Workload::Synthetic => Ok(WirePayload::Synthetic),
            Workload::Table { csv } => Ok(WirePayload::Table { csv: csv.clone() }),
            Workload::LightCurves { csv, targets } => Ok(WirePayload::LightCurves {
                csv: csv.clone(),
                targets: targets.clone(),
            }),
            Workload::Documents { docs, labels } => Ok(WirePayload::Documents {
                docs: docs.clone(),
                labels: labels.clone(),
            }),
            Workload::ReviewLog { json } => Ok(WirePayload::ReviewLog { json: json.clone() }),
            Workload::Video { .. } => Err(WireError::Unrepresentable("a video payload")),
            Workload::Parts { .. } => Err(WireError::Unrepresentable("a parts payload")),
        }
    }

    /// The typed workload this payload decodes to.
    pub fn into_workload(self) -> Workload {
        match self {
            WirePayload::Synthetic => Workload::Synthetic,
            WirePayload::Table { csv } => Workload::Table { csv },
            WirePayload::LightCurves { csv, targets } => {
                Workload::LightCurves { csv, targets }
            }
            WirePayload::Documents { docs, labels } => Workload::Documents { docs, labels },
            WirePayload::ReviewLog { json } => Workload::ReviewLog { json },
        }
    }
}

/// One submitted request as it crosses the wire. `id` is caller-chosen
/// and echoed on the resolution frame, so a connection may hold many
/// requests in flight and match responses out of order.
#[derive(Debug, Clone, PartialEq)]
pub struct WireRequest {
    pub id: u64,
    pub pipeline: String,
    pub priority: Priority,
    /// Queue-wait deadline in milliseconds; 0 = none. Always produce
    /// this field through [`encode_deadline_ms`] — a present-but-zero
    /// deadline must never alias the "no deadline" sentinel.
    pub deadline_ms: u64,
    pub payload: WirePayload,
}

/// Encode an optional queue-wait deadline into the v1 `deadline_ms`
/// field, where `0` is the "no deadline" sentinel. A present deadline
/// saturates to at least 1 ms: `Some(Duration::ZERO)` (an
/// already-expired deadline) must cross the wire as the tightest
/// representable deadline, not silently become "wait forever".
pub fn encode_deadline_ms(deadline: Option<std::time::Duration>) -> u64 {
    match deadline {
        None => 0,
        Some(d) => (d.as_millis() as u64).max(1),
    }
}

/// Decode the v1 `deadline_ms` field back into an optional deadline
/// (`0` = none). Inverse of [`encode_deadline_ms`] up to its 1 ms
/// saturation of sub-millisecond deadlines.
pub fn decode_deadline_ms(deadline_ms: u64) -> Option<std::time::Duration> {
    (deadline_ms > 0).then(|| std::time::Duration::from_millis(deadline_ms))
}

/// A completed request's resolution: the typed output summary, the full
/// metric map (identical to a direct in-process run at the same seed —
/// the loopback conformance tests compare them), and the server-side
/// timing split.
#[derive(Debug, Clone, PartialEq)]
pub struct WireCompletion {
    pub id: u64,
    pub pipeline: String,
    /// Items processed end-to-end.
    pub items: u64,
    /// Queue wait before a dispatcher picked the request up, in µs.
    pub queue_wait_us: u64,
    /// Plan execution time, in µs.
    pub service_us: u64,
    /// One-line typed-output rendering ([`crate::pipelines::Output`]).
    pub summary: String,
    /// The run's named metrics, in map order.
    pub metrics: Vec<(String, f64)>,
}

/// Everything that crosses a serving connection (see module docs).
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// Client → server: declare this connection's tenant id.
    Hello { tenant: String },
    /// Server → client: handshake accepted; these pipelines are open.
    HelloAck { pipelines: Vec<String> },
    /// Client → server: submit one request.
    Request(WireRequest),
    /// Server → client: the request executed.
    Completed(WireCompletion),
    /// Server → client: the request was shed (first-class, never a
    /// dropped connection).
    Shed { id: u64, pipeline: String, priority: Priority, cause: ShedCause, waited_us: u64 },
    /// Server → client: the request errored.
    Failed { id: u64, pipeline: String, error: String },
    /// Either direction: the sender will produce no further requests;
    /// flush in-flight work and say goodbye.
    Drain,
    /// Server → client: drain complete; the connection's resolution
    /// counters (with sheds broken out per [`ShedCause`], indexed in
    /// [`ShedCause::ALL`] order), then the stream closes.
    Goodbye { completed: u64, shed: u64, failed: u64, shed_by_cause: [u64; SHED_CAUSE_COUNT] },
    /// Client → server: ask for the serving ledger.
    StatsReq,
    /// Server → client: the ledger snapshot.
    Stats(NetReport),
    /// Client → server: ask for the calling connection's own tenant
    /// ledger (the tenant declared in `Hello` — there is no argument,
    /// so one tenant cannot read another's counters).
    TenantStatsReq,
    /// Server → client: the requesting tenant's ledger snapshot. The
    /// tenant id is echoed so the reply is self-describing in captures.
    TenantStats { tenant: String, ledger: TenantLedger },
}

impl Frame {
    fn tag(&self) -> u8 {
        match self {
            Frame::Hello { .. } => 0x01,
            Frame::HelloAck { .. } => 0x02,
            Frame::Request(_) => 0x03,
            Frame::Completed(_) => 0x04,
            Frame::Shed { .. } => 0x05,
            Frame::Failed { .. } => 0x06,
            Frame::Drain => 0x07,
            Frame::Goodbye { .. } => 0x08,
            Frame::StatsReq => 0x09,
            Frame::Stats(_) => 0x0A,
            Frame::TenantStatsReq => 0x0B,
            Frame::TenantStats { .. } => 0x0C,
        }
    }

    /// Short label for logs and error messages.
    pub fn kind(&self) -> &'static str {
        match self {
            Frame::Hello { .. } => "hello",
            Frame::HelloAck { .. } => "hello_ack",
            Frame::Request(_) => "request",
            Frame::Completed(_) => "completed",
            Frame::Shed { .. } => "shed",
            Frame::Failed { .. } => "failed",
            Frame::Drain => "drain",
            Frame::Goodbye { .. } => "goodbye",
            Frame::StatsReq => "stats_req",
            Frame::Stats(_) => "stats",
            Frame::TenantStatsReq => "tenant_stats_req",
            Frame::TenantStats { .. } => "tenant_stats",
        }
    }
}

// ---- body encoding ----------------------------------------------------

fn put_u8(out: &mut Vec<u8>, v: u8) {
    out.push(v);
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_be_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_bits().to_be_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    out.extend_from_slice(&(s.len() as u32).to_be_bytes());
    out.extend_from_slice(s.as_bytes());
}

fn put_count(out: &mut Vec<u8>, n: usize) {
    out.extend_from_slice(&(n as u32).to_be_bytes());
}

fn priority_tag(p: Priority) -> u8 {
    p as u8
}

fn priority_from_tag(t: u8) -> Result<Priority, WireError> {
    Priority::ALL
        .get(t as usize)
        .copied()
        .ok_or_else(|| WireError::Malformed(format!("priority tag {t}")))
}

fn encode_body(frame: &Frame) -> Vec<u8> {
    let mut b = Vec::new();
    match frame {
        Frame::Hello { tenant } => put_str(&mut b, tenant),
        Frame::HelloAck { pipelines } => {
            put_count(&mut b, pipelines.len());
            for p in pipelines {
                put_str(&mut b, p);
            }
        }
        Frame::Request(r) => {
            put_u64(&mut b, r.id);
            put_str(&mut b, &r.pipeline);
            put_u8(&mut b, priority_tag(r.priority));
            put_u64(&mut b, r.deadline_ms);
            match &r.payload {
                WirePayload::Synthetic => put_u8(&mut b, 0),
                WirePayload::Table { csv } => {
                    put_u8(&mut b, 1);
                    put_str(&mut b, csv);
                }
                WirePayload::LightCurves { csv, targets } => {
                    put_u8(&mut b, 2);
                    put_str(&mut b, csv);
                    put_count(&mut b, targets.len());
                    for &t in targets {
                        put_f64(&mut b, t);
                    }
                }
                WirePayload::Documents { docs, labels } => {
                    put_u8(&mut b, 3);
                    put_count(&mut b, docs.len());
                    for d in docs {
                        put_str(&mut b, d);
                    }
                    put_count(&mut b, labels.len());
                    for &l in labels {
                        put_u64(&mut b, l as u64);
                    }
                }
                WirePayload::ReviewLog { json } => {
                    put_u8(&mut b, 4);
                    put_str(&mut b, json);
                }
            }
        }
        Frame::Completed(c) => {
            put_u64(&mut b, c.id);
            put_str(&mut b, &c.pipeline);
            put_u64(&mut b, c.items);
            put_u64(&mut b, c.queue_wait_us);
            put_u64(&mut b, c.service_us);
            put_str(&mut b, &c.summary);
            put_count(&mut b, c.metrics.len());
            for (name, value) in &c.metrics {
                put_str(&mut b, name);
                put_f64(&mut b, *value);
            }
        }
        Frame::Shed { id, pipeline, priority, cause, waited_us } => {
            put_u64(&mut b, *id);
            put_str(&mut b, pipeline);
            put_u8(&mut b, priority_tag(*priority));
            put_u8(&mut b, cause.tag());
            put_u64(&mut b, *waited_us);
        }
        Frame::Failed { id, pipeline, error } => {
            put_u64(&mut b, *id);
            put_str(&mut b, pipeline);
            put_str(&mut b, error);
        }
        Frame::Drain | Frame::StatsReq | Frame::TenantStatsReq => {}
        Frame::Goodbye { completed, shed, failed, shed_by_cause } => {
            put_u64(&mut b, *completed);
            put_u64(&mut b, *shed);
            put_u64(&mut b, *failed);
            for &n in shed_by_cause {
                put_u64(&mut b, n);
            }
        }
        Frame::Stats(report) => {
            put_u64(&mut b, report.accepted as u64);
            put_u64(&mut b, report.drained as u64);
            put_u64(&mut b, report.rejected as u64);
            put_u64(&mut b, report.reaped_idle as u64);
            put_u64(&mut b, report.reaped_handshake as u64);
            put_u64(&mut b, report.frames_in as u64);
            put_u64(&mut b, report.frames_out as u64);
            put_count(&mut b, report.tenants.len());
            for (tenant, t) in &report.tenants {
                put_str(&mut b, tenant);
                put_u64(&mut b, t.admitted);
                put_u64(&mut b, t.completed);
                put_u64(&mut b, t.shed);
                put_u64(&mut b, t.failed);
            }
        }
        Frame::TenantStats { tenant, ledger } => {
            put_str(&mut b, tenant);
            put_u64(&mut b, ledger.admitted);
            put_u64(&mut b, ledger.completed);
            put_u64(&mut b, ledger.shed);
            put_u64(&mut b, ledger.failed);
        }
    }
    b
}

// ---- body decoding ----------------------------------------------------

/// Bounds-checked reader over a frame body. Every accessor returns a
/// typed error on underrun — nothing here can panic on hostile input.
struct Cursor<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8], WireError> {
        if self.b.len() - self.pos < n {
            return Err(WireError::Malformed(format!(
                "{what}: needed {n} bytes, had {}",
                self.b.len() - self.pos
            )));
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self, what: &str) -> Result<u8, WireError> {
        Ok(self.take(1, what)?[0])
    }

    fn u64(&mut self, what: &str) -> Result<u64, WireError> {
        let s = self.take(8, what)?;
        Ok(u64::from_be_bytes(s.try_into().unwrap()))
    }

    fn f64(&mut self, what: &str) -> Result<f64, WireError> {
        Ok(f64::from_bits(self.u64(what)?))
    }

    /// A u32 count/length prefix, bounded by the bytes actually left in
    /// the body so a hostile count cannot drive a huge allocation.
    fn count(&mut self, what: &str) -> Result<usize, WireError> {
        let s = self.take(4, what)?;
        let n = u32::from_be_bytes(s.try_into().unwrap()) as usize;
        if n > self.b.len() - self.pos {
            return Err(WireError::Malformed(format!(
                "{what}: count {n} exceeds remaining {} bytes",
                self.b.len() - self.pos
            )));
        }
        Ok(n)
    }

    fn str(&mut self, what: &str) -> Result<String, WireError> {
        let n = self.count(what)?;
        let s = self.take(n, what)?;
        String::from_utf8(s.to_vec())
            .map_err(|_| WireError::Malformed(format!("{what}: invalid utf-8")))
    }

    fn finish(&self) -> Result<(), WireError> {
        if self.pos != self.b.len() {
            return Err(WireError::Malformed(format!(
                "{} trailing bytes after frame body",
                self.b.len() - self.pos
            )));
        }
        Ok(())
    }
}

fn decode_body(tag: u8, body: &[u8]) -> Result<Frame, WireError> {
    let mut c = Cursor { b: body, pos: 0 };
    let frame = match tag {
        0x01 => Frame::Hello { tenant: c.str("hello tenant")? },
        0x02 => {
            let n = c.count("hello_ack pipeline count")?;
            let mut pipelines = Vec::with_capacity(n);
            for _ in 0..n {
                pipelines.push(c.str("hello_ack pipeline")?);
            }
            Frame::HelloAck { pipelines }
        }
        0x03 => {
            let id = c.u64("request id")?;
            let pipeline = c.str("request pipeline")?;
            let priority = priority_from_tag(c.u8("request priority")?)?;
            let deadline_ms = c.u64("request deadline")?;
            let payload = match c.u8("payload tag")? {
                0 => WirePayload::Synthetic,
                1 => WirePayload::Table { csv: c.str("table csv")? },
                2 => {
                    let csv = c.str("light-curve csv")?;
                    let n = c.count("target count")?;
                    let mut targets = Vec::with_capacity(n);
                    for _ in 0..n {
                        targets.push(c.f64("target")?);
                    }
                    WirePayload::LightCurves { csv, targets }
                }
                3 => {
                    let n = c.count("doc count")?;
                    let mut docs = Vec::with_capacity(n);
                    for _ in 0..n {
                        docs.push(c.str("doc")?);
                    }
                    let n = c.count("label count")?;
                    let mut labels = Vec::with_capacity(n);
                    for _ in 0..n {
                        labels.push(c.u64("label")? as i64);
                    }
                    WirePayload::Documents { docs, labels }
                }
                4 => WirePayload::ReviewLog { json: c.str("review log")? },
                t => return Err(WireError::Malformed(format!("payload tag {t}"))),
            };
            Frame::Request(WireRequest { id, pipeline, priority, deadline_ms, payload })
        }
        0x04 => {
            let id = c.u64("completion id")?;
            let pipeline = c.str("completion pipeline")?;
            let items = c.u64("completion items")?;
            let queue_wait_us = c.u64("queue wait")?;
            let service_us = c.u64("service time")?;
            let summary = c.str("summary")?;
            let n = c.count("metric count")?;
            let mut metrics = Vec::with_capacity(n);
            for _ in 0..n {
                let name = c.str("metric name")?;
                let value = c.f64("metric value")?;
                metrics.push((name, value));
            }
            Frame::Completed(WireCompletion {
                id,
                pipeline,
                items,
                queue_wait_us,
                service_us,
                summary,
                metrics,
            })
        }
        0x05 => Frame::Shed {
            id: c.u64("shed id")?,
            pipeline: c.str("shed pipeline")?,
            priority: priority_from_tag(c.u8("shed priority")?)?,
            cause: ShedCause::from_tag(c.u8("shed cause")?)?,
            waited_us: c.u64("shed wait")?,
        },
        0x06 => Frame::Failed {
            id: c.u64("failed id")?,
            pipeline: c.str("failed pipeline")?,
            error: c.str("failed error")?,
        },
        0x07 => Frame::Drain,
        0x08 => {
            let completed = c.u64("goodbye completed")?;
            let shed = c.u64("goodbye shed")?;
            let failed = c.u64("goodbye failed")?;
            let mut shed_by_cause = [0u64; SHED_CAUSE_COUNT];
            for slot in &mut shed_by_cause {
                *slot = c.u64("goodbye shed cause count")?;
            }
            Frame::Goodbye { completed, shed, failed, shed_by_cause }
        }
        0x09 => Frame::StatsReq,
        0x0A => {
            let accepted = c.u64("stats accepted")? as usize;
            let drained = c.u64("stats drained")? as usize;
            let rejected = c.u64("stats rejected")? as usize;
            let reaped_idle = c.u64("stats reaped_idle")? as usize;
            let reaped_handshake = c.u64("stats reaped_handshake")? as usize;
            let frames_in = c.u64("stats frames_in")? as usize;
            let frames_out = c.u64("stats frames_out")? as usize;
            let n = c.count("tenant count")?;
            let mut tenants = std::collections::BTreeMap::new();
            for _ in 0..n {
                let tenant = c.str("tenant id")?;
                let ledger = TenantLedger {
                    admitted: c.u64("tenant admitted")?,
                    completed: c.u64("tenant completed")?,
                    shed: c.u64("tenant shed")?,
                    failed: c.u64("tenant failed")?,
                };
                tenants.insert(tenant, ledger);
            }
            Frame::Stats(NetReport {
                accepted,
                drained,
                rejected,
                reaped_idle,
                reaped_handshake,
                frames_in,
                frames_out,
                tenants,
            })
        }
        0x0B => Frame::TenantStatsReq,
        0x0C => Frame::TenantStats {
            tenant: c.str("tenant_stats tenant")?,
            ledger: TenantLedger {
                admitted: c.u64("tenant_stats admitted")?,
                completed: c.u64("tenant_stats completed")?,
                shed: c.u64("tenant_stats shed")?,
                failed: c.u64("tenant_stats failed")?,
            },
        },
        t => return Err(WireError::UnknownFrame(t)),
    };
    c.finish()?;
    Ok(frame)
}

// ---- framing ----------------------------------------------------------

/// Encode one frame to its full wire bytes (header + body).
pub fn encode(frame: &Frame) -> Vec<u8> {
    let body = encode_body(frame);
    let mut out = Vec::with_capacity(HEADER_LEN + body.len());
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&VERSION.to_be_bytes());
    out.push(frame.tag());
    out.extend_from_slice(&(body.len() as u32).to_be_bytes());
    out.extend_from_slice(&body);
    out
}

/// Parse a frame header: `(frame_type, payload_len)`. Rejects bad
/// magic, foreign versions, and oversized length prefixes — all before
/// any payload allocation.
pub fn decode_header(h: &[u8; HEADER_LEN]) -> Result<(u8, usize), WireError> {
    let magic: [u8; 4] = h[0..4].try_into().unwrap();
    if magic != MAGIC {
        return Err(WireError::BadMagic(magic));
    }
    let version = u16::from_be_bytes(h[4..6].try_into().unwrap());
    if version != VERSION {
        return Err(WireError::BadVersion(version));
    }
    let tag = h[6];
    let len = u32::from_be_bytes(h[7..11].try_into().unwrap()) as usize;
    if len > MAX_PAYLOAD {
        return Err(WireError::TooLarge { len, max: MAX_PAYLOAD });
    }
    Ok((tag, len))
}

/// Decode one frame from a buffer holding exactly one encoded frame.
pub fn decode(buf: &[u8]) -> Result<Frame, WireError> {
    if buf.len() < HEADER_LEN {
        return Err(WireError::Truncated { context: "header" });
    }
    let header: [u8; HEADER_LEN] = buf[..HEADER_LEN].try_into().unwrap();
    let (tag, len) = decode_header(&header)?;
    let body = &buf[HEADER_LEN..];
    if body.len() < len {
        return Err(WireError::Truncated { context: "payload" });
    }
    if body.len() > len {
        return Err(WireError::Malformed(format!(
            "{} bytes past the declared payload length",
            body.len() - len
        )));
    }
    decode_body(tag, body)
}

/// Read one frame from a stream. `Ok(None)` on a clean EOF at a frame
/// boundary (the peer closed between frames); EOF mid-frame is
/// [`WireError::Truncated`].
pub fn read_frame(r: &mut impl Read) -> Result<Option<Frame>, WireError> {
    let mut header = [0u8; HEADER_LEN];
    let mut got = 0;
    while got < HEADER_LEN {
        match r.read(&mut header[got..]) {
            Ok(0) => {
                if got == 0 {
                    return Ok(None);
                }
                return Err(WireError::Truncated { context: "header" });
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            // A read timeout with partial header bytes must keep
            // polling, not drop them: resurface only clean timeouts.
            Err(e)
                if got > 0
                    && matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) => {}
            Err(e) => return Err(WireError::Io(e)),
        }
    }
    let (tag, len) = decode_header(&header)?;
    let mut body = vec![0u8; len];
    let mut got = 0;
    while got < len {
        match r.read(&mut body[got..]) {
            Ok(0) => return Err(WireError::Truncated { context: "payload" }),
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) => {}
            Err(e) => return Err(WireError::Io(e)),
        }
    }
    decode_body(tag, &body)
}

/// Write one frame to a stream.
pub fn write_frame(w: &mut impl Write, frame: &Frame) -> Result<(), WireError> {
    w.write_all(&encode(frame))?;
    w.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn sample_frames() -> Vec<Frame> {
        vec![
            Frame::Hello { tenant: "tenant-a".to_string() },
            Frame::Hello { tenant: String::new() },
            Frame::HelloAck { pipelines: vec!["census".into(), "dlsa".into()] },
            Frame::HelloAck { pipelines: vec![] },
            Frame::Request(WireRequest {
                id: 7,
                pipeline: "census".into(),
                priority: Priority::High,
                deadline_ms: 250,
                payload: WirePayload::Synthetic,
            }),
            Frame::Request(WireRequest {
                id: u64::MAX,
                pipeline: "iiot".into(),
                priority: Priority::Low,
                deadline_ms: 0,
                payload: WirePayload::Table { csv: "a,b\n1,2\n".into() },
            }),
            Frame::Request(WireRequest {
                id: 3,
                pipeline: "plasticc".into(),
                priority: Priority::Normal,
                deadline_ms: 9,
                payload: WirePayload::LightCurves {
                    csv: "object_id,mjd\n".into(),
                    targets: vec![0.5, -1.25, f64::MAX],
                },
            }),
            Frame::Request(WireRequest {
                id: 4,
                pipeline: "dlsa".into(),
                priority: Priority::Normal,
                deadline_ms: 0,
                payload: WirePayload::Documents {
                    docs: vec!["great movie".into(), "héllo→ utf8".into()],
                    labels: vec![1, -1],
                },
            }),
            Frame::Request(WireRequest {
                id: 5,
                pipeline: "dien".into(),
                priority: Priority::Normal,
                deadline_ms: 0,
                payload: WirePayload::ReviewLog { json: "{\"u\":1}\n".into() },
            }),
            Frame::Completed(WireCompletion {
                id: 11,
                pipeline: "census".into(),
                items: 1200,
                queue_wait_us: 42,
                service_us: 900,
                summary: "r2=0.81".into(),
                metrics: vec![("r2".into(), 0.81), ("mse".into(), 1234.5)],
            }),
            Frame::Shed {
                id: 12,
                pipeline: "census".into(),
                priority: Priority::Low,
                cause: ShedCause::TenantLaneFull,
                waited_us: 17,
            },
            Frame::Failed { id: 13, pipeline: "nope".into(), error: "unknown pipeline".into() },
            Frame::Drain,
            Frame::Goodbye { completed: 9, shed: 2, failed: 0, shed_by_cause: [1, 1, 0, 0, 0] },
            Frame::StatsReq,
            Frame::TenantStatsReq,
            Frame::TenantStats {
                tenant: "tenant-a".to_string(),
                ledger: TenantLedger { admitted: 6, completed: 4, shed: 1, failed: 1 },
            },
            Frame::Stats(NetReport {
                accepted: 3,
                drained: 3,
                rejected: 2,
                reaped_idle: 1,
                reaped_handshake: 1,
                frames_in: 40,
                frames_out: 41,
                tenants: [
                    (
                        "a".to_string(),
                        TenantLedger { admitted: 5, completed: 4, shed: 1, failed: 0 },
                    ),
                    (
                        "b".to_string(),
                        TenantLedger { admitted: 2, completed: 2, shed: 0, failed: 0 },
                    ),
                ]
                .into_iter()
                .collect(),
            }),
        ]
    }

    #[test]
    fn every_frame_type_round_trips() {
        for frame in sample_frames() {
            let bytes = encode(&frame);
            assert_eq!(&bytes[0..4], &MAGIC, "{}", frame.kind());
            let back = decode(&bytes).unwrap_or_else(|e| panic!("{}: {e}", frame.kind()));
            assert_eq!(back, frame, "{} round trip", frame.kind());
            // Streamed read sees the same frame.
            let mut reader = &bytes[..];
            let streamed = read_frame(&mut reader).unwrap().expect("one frame present");
            assert_eq!(streamed, frame);
            // And the stream is now at a clean EOF.
            assert!(read_frame(&mut reader).unwrap().is_none());
        }
    }

    #[test]
    fn zero_length_payload_frames_are_exactly_a_header() {
        for frame in [Frame::Drain, Frame::StatsReq, Frame::TenantStatsReq] {
            let bytes = encode(&frame);
            assert_eq!(bytes.len(), HEADER_LEN);
            assert_eq!(decode(&bytes).unwrap(), frame);
        }
    }

    #[test]
    fn truncated_reads_error_cleanly_at_every_cut_point() {
        // Cutting an encoded frame at ANY byte boundary must produce a
        // typed error — never a panic, never a bogus frame.
        for frame in sample_frames() {
            let bytes = encode(&frame);
            for cut in 0..bytes.len() {
                let err = decode(&bytes[..cut]);
                assert!(err.is_err(), "{} cut at {cut} decoded", frame.kind());
                if cut > 0 {
                    let mut reader = &bytes[..cut];
                    assert!(
                        read_frame(&mut reader).is_err(),
                        "{} streamed cut at {cut} read",
                        frame.kind()
                    );
                }
            }
        }
    }

    #[test]
    fn bad_magic_and_version_are_protocol_errors() {
        let mut bytes = encode(&Frame::Drain);
        bytes[0] = b'X';
        assert!(matches!(decode(&bytes), Err(WireError::BadMagic(_))));
        let mut bytes = encode(&Frame::Drain);
        bytes[4] = 0xFF;
        assert!(matches!(decode(&bytes), Err(WireError::BadVersion(_))));
        let mut bytes = encode(&Frame::Drain);
        bytes[6] = 0x7F;
        assert!(matches!(decode(&bytes), Err(WireError::UnknownFrame(0x7F))));
    }

    #[test]
    fn oversized_length_prefix_is_rejected_before_allocation() {
        let mut header = [0u8; HEADER_LEN];
        header[0..4].copy_from_slice(&MAGIC);
        header[4..6].copy_from_slice(&VERSION.to_be_bytes());
        header[6] = 0x01;
        header[7..11].copy_from_slice(&((MAX_PAYLOAD as u32) + 1).to_be_bytes());
        match decode_header(&header) {
            Err(WireError::TooLarge { len, max }) => {
                assert_eq!(len, MAX_PAYLOAD + 1);
                assert_eq!(max, MAX_PAYLOAD);
            }
            other => panic!("expected TooLarge, got {other:?}"),
        }
        // A frame-body count prefix past the remaining bytes is equally
        // rejected (no allocation from a hostile count).
        let mut bytes = encode(&Frame::HelloAck { pipelines: vec!["census".into()] });
        let count_at = HEADER_LEN;
        bytes[count_at..count_at + 4].copy_from_slice(&u32::MAX.to_be_bytes());
        assert!(matches!(decode(&bytes), Err(WireError::Malformed(_))));
    }

    #[test]
    fn malformed_bodies_error_not_panic() {
        // Bad priority tag.
        let mut bytes = encode(&Frame::Request(WireRequest {
            id: 1,
            pipeline: "p".into(),
            priority: Priority::Low,
            deadline_ms: 0,
            payload: WirePayload::Synthetic,
        }));
        let prio_at = HEADER_LEN + 8 + 4 + 1; // id + strlen + "p"
        bytes[prio_at] = 9;
        assert!(matches!(decode(&bytes), Err(WireError::Malformed(_))));
        // Bad shed-cause tag.
        let mut bytes = encode(&Frame::Shed {
            id: 1,
            pipeline: "p".into(),
            priority: Priority::Low,
            cause: ShedCause::QueueFull,
            waited_us: 0,
        });
        bytes[HEADER_LEN + 8 + 4 + 1 + 1] = 200;
        assert!(matches!(decode(&bytes), Err(WireError::Malformed(_))));
        // Invalid UTF-8 in a string field.
        let mut bytes = encode(&Frame::Hello { tenant: "ab".into() });
        bytes[HEADER_LEN + 4] = 0xFF;
        bytes[HEADER_LEN + 5] = 0xFE;
        assert!(matches!(decode(&bytes), Err(WireError::Malformed(_))));
        // Trailing bytes past the body are rejected too.
        let mut bytes = encode(&Frame::Drain);
        bytes[7..11].copy_from_slice(&1u32.to_be_bytes());
        bytes.push(0);
        assert!(matches!(decode(&bytes), Err(WireError::Malformed(_))));
    }

    #[test]
    fn wire_payload_round_trips_typed_workloads() {
        let workloads = [
            Workload::Synthetic,
            Workload::Table { csv: "a,b\n1,2\n".into() },
            Workload::LightCurves { csv: "h\n".into(), targets: vec![1.0, 2.0] },
            Workload::Documents { docs: vec!["d".into()], labels: vec![0] },
            Workload::ReviewLog { json: "{}".into() },
        ];
        for w in workloads {
            let kind = w.kind();
            let wire = WirePayload::from_workload(&w).unwrap();
            assert_eq!(wire.into_workload().kind(), kind);
        }
        // Media payloads are typed errors, not panics.
        let err = WirePayload::from_workload(&Workload::Video { frames: vec![] });
        assert!(matches!(err, Err(WireError::Unrepresentable(_))));
        let err =
            WirePayload::from_workload(&Workload::Parts { train: vec![], test: vec![] });
        assert!(matches!(err, Err(WireError::Unrepresentable(_))));
    }

    /// Seeded random frame generator for the property round trip.
    fn random_frame(rng: &mut Rng) -> Frame {
        let rand_str = |rng: &mut Rng| -> String {
            let n = rng.below(12);
            (0..n).map(|_| (b'a' + rng.below(26) as u8) as char).collect()
        };
        match rng.below(12) {
            0 => Frame::Hello { tenant: rand_str(rng) },
            1 => {
                let n = rng.below(4);
                Frame::HelloAck { pipelines: (0..n).map(|_| rand_str(rng)).collect() }
            }
            2 => {
                let payload = match rng.below(5) {
                    0 => WirePayload::Synthetic,
                    1 => WirePayload::Table { csv: rand_str(rng) },
                    2 => WirePayload::LightCurves {
                        csv: rand_str(rng),
                        targets: (0..rng.below(5)).map(|_| rng.f64() - 0.5).collect(),
                    },
                    3 => WirePayload::Documents {
                        docs: (0..rng.below(4)).map(|_| rand_str(rng)).collect(),
                        labels: (0..rng.below(4)).map(|_| rng.below(3) as i64 - 1).collect(),
                    },
                    _ => WirePayload::ReviewLog { json: rand_str(rng) },
                };
                Frame::Request(WireRequest {
                    id: rng.below(1 << 30) as u64,
                    pipeline: rand_str(rng),
                    priority: *rng.choice(&Priority::ALL),
                    deadline_ms: rng.below(1000) as u64,
                    payload,
                })
            }
            3 => Frame::Completed(WireCompletion {
                id: rng.below(1 << 20) as u64,
                pipeline: rand_str(rng),
                items: rng.below(10_000) as u64,
                queue_wait_us: rng.below(1 << 20) as u64,
                service_us: rng.below(1 << 20) as u64,
                summary: rand_str(rng),
                metrics: (0..rng.below(5))
                    .map(|_| (rand_str(rng), rng.f64() * 100.0))
                    .collect(),
            }),
            4 => Frame::Shed {
                id: rng.below(1 << 20) as u64,
                pipeline: rand_str(rng),
                priority: *rng.choice(&Priority::ALL),
                cause: *rng.choice(&ShedCause::ALL),
                waited_us: rng.below(1 << 16) as u64,
            },
            5 => Frame::Failed {
                id: rng.below(1 << 20) as u64,
                pipeline: rand_str(rng),
                error: rand_str(rng),
            },
            6 => Frame::Drain,
            7 => {
                let mut shed_by_cause = [0u64; SHED_CAUSE_COUNT];
                for slot in &mut shed_by_cause {
                    *slot = rng.below(25) as u64;
                }
                Frame::Goodbye {
                    completed: rng.below(100) as u64,
                    shed: shed_by_cause.iter().sum(),
                    failed: rng.below(100) as u64,
                    shed_by_cause,
                }
            }
            8 => Frame::StatsReq,
            9 => Frame::TenantStatsReq,
            10 => Frame::TenantStats {
                tenant: rand_str(rng),
                ledger: TenantLedger {
                    admitted: rng.below(100) as u64,
                    completed: rng.below(100) as u64,
                    shed: rng.below(100) as u64,
                    failed: rng.below(100) as u64,
                },
            },
            _ => Frame::Stats(NetReport {
                accepted: rng.below(10),
                drained: rng.below(10),
                rejected: rng.below(10),
                reaped_idle: rng.below(10),
                reaped_handshake: rng.below(10),
                frames_in: rng.below(1000),
                frames_out: rng.below(1000),
                tenants: (0..rng.below(4))
                    .map(|i| {
                        (
                            format!("t{i}"),
                            TenantLedger {
                                admitted: rng.below(100) as u64,
                                completed: rng.below(100) as u64,
                                shed: rng.below(100) as u64,
                                failed: rng.below(100) as u64,
                            },
                        )
                    })
                    .collect(),
            }),
        }
    }

    #[test]
    fn randomized_frames_round_trip_and_survive_concatenation() {
        // Property: any frame the encoder can produce decodes back to
        // itself, and a stream of concatenated frames reads back in
        // order (the framing never loses sync).
        for seed in 0..6u64 {
            let mut rng = Rng::new(0x3E7 + seed);
            let frames: Vec<Frame> = (0..40).map(|_| random_frame(&mut rng)).collect();
            let mut stream = Vec::new();
            for f in &frames {
                assert_eq!(&decode(&encode(f)).unwrap(), f, "seed {seed}");
                stream.extend_from_slice(&encode(f));
            }
            let mut reader = &stream[..];
            for (i, f) in frames.iter().enumerate() {
                let got = read_frame(&mut reader)
                    .unwrap_or_else(|e| panic!("seed {seed} frame {i}: {e}"))
                    .unwrap_or_else(|| panic!("seed {seed} frame {i}: early EOF"));
                assert_eq!(&got, f, "seed {seed} frame {i}");
            }
            assert!(read_frame(&mut reader).unwrap().is_none(), "seed {seed}: clean EOF");
        }
    }

    #[test]
    fn zero_duration_deadline_never_aliases_the_none_sentinel() {
        use std::time::Duration;
        // The sentinel itself.
        assert_eq!(encode_deadline_ms(None), 0);
        assert_eq!(decode_deadline_ms(0), None);
        // Some(Duration::ZERO) is an already-expired deadline, NOT "no
        // deadline": it must saturate to the tightest encodable value.
        let ms = encode_deadline_ms(Some(Duration::ZERO));
        assert_eq!(ms, 1);
        assert_eq!(decode_deadline_ms(ms), Some(Duration::from_millis(1)));
        // Sub-millisecond deadlines saturate the same way.
        assert_eq!(encode_deadline_ms(Some(Duration::from_micros(250))), 1);
        // Millisecond-resolution deadlines round trip exactly.
        for ms_in in [1u64, 9, 250, 10_000] {
            let enc = encode_deadline_ms(Some(Duration::from_millis(ms_in)));
            assert_eq!(enc, ms_in);
            assert_eq!(decode_deadline_ms(enc), Some(Duration::from_millis(ms_in)));
        }
        // And end-to-end through a Request frame codec round trip.
        let frame = Frame::Request(WireRequest {
            id: 1,
            pipeline: "census".into(),
            priority: Priority::Normal,
            deadline_ms: encode_deadline_ms(Some(Duration::ZERO)),
            payload: WirePayload::Synthetic,
        });
        match decode(&encode(&frame)).unwrap() {
            Frame::Request(r) => {
                assert_eq!(decode_deadline_ms(r.deadline_ms), Some(Duration::from_millis(1)));
            }
            other => panic!("expected Request, got {}", other.kind()),
        }
    }

    #[test]
    fn goodbye_carries_per_cause_shed_counts() {
        let frame = Frame::Goodbye {
            completed: 7,
            shed: 3,
            failed: 1,
            shed_by_cause: [0, 2, 1, 0, 0],
        };
        match decode(&encode(&frame)).unwrap() {
            Frame::Goodbye { completed, shed, failed, shed_by_cause } => {
                assert_eq!((completed, shed, failed), (7, 3, 1));
                assert_eq!(shed_by_cause[ShedCause::DeadlineExpired.index()], 2);
                assert_eq!(shed_by_cause[ShedCause::TenantLaneFull.index()], 1);
                assert_eq!(shed_by_cause.iter().sum::<u64>(), shed);
            }
            other => panic!("expected Goodbye, got {}", other.kind()),
        }
    }

    #[test]
    fn shed_cause_covers_service_reasons_with_labels() {
        assert_eq!(ShedCause::from(ShedReason::QueueFull), ShedCause::QueueFull);
        assert_eq!(
            ShedCause::from(ShedReason::DeadlineExpired),
            ShedCause::DeadlineExpired
        );
        for c in ShedCause::ALL {
            assert!(!c.label().is_empty());
            assert_eq!(ShedCause::from_tag(c.tag()).unwrap(), c);
        }
        assert!(ShedCause::from_tag(99).is_err());
    }
}
