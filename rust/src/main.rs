//! `repro` — CLI for the E2E-AI-pipeline reproduction.
//!
//! ```text
//! repro list                       # Table 1: the eight pipelines
//! repro run <pipeline> [--opt baseline|optimized] [--exec sequential|streaming|multi[:N]]
//!                      [--scale F] [--seed N]
//! repro fig1 [--scale F]           # Figure 1 stage breakdown, all pipelines
//! repro config                     # Table 3 analogue: software config
//! repro models                     # AOT artifacts available to the runtime
//! ```

use repro::coordinator::ExecMode;
use repro::pipelines::{registry, run_by_name, RunConfig, Toggles};
use repro::util::cli::Args;
use repro::util::fmt::{self, Table};
use repro::OptLevel;

fn main() {
    let args = Args::from_env();
    let code = match args.command.as_str() {
        "list" => cmd_list(),
        "run" => cmd_run(&args),
        "fig1" => cmd_fig1(&args),
        "config" => cmd_config(),
        "models" => cmd_models(),
        "" | "help" | "--help" => {
            print_help();
            0
        }
        other => {
            eprintln!("unknown command: {other}\n");
            print_help();
            2
        }
    };
    std::process::exit(code);
}

fn print_help() {
    println!(
        "repro — E2E AI pipeline optimization reproduction\n\
         \n\
         USAGE:\n  repro <command> [options]\n\
         \n\
         COMMANDS:\n\
         \x20 list                 list the eight pipelines (Table 1)\n\
         \x20 run <pipeline>       run one pipeline and print its report\n\
         \x20 fig1                 stage-time breakdown for every pipeline (Figure 1)\n\
         \x20 config               print the software configuration (Table 3)\n\
         \x20 models               list AOT model artifacts\n\
         \n\
         OPTIONS (run/fig1):\n\
         \x20 --opt baseline|optimized          optimization level (default optimized)\n\
         \x20 --exec sequential|streaming|multi[:N]\n\
         \x20                                   executor for the pipeline plan\n\
         \x20                                   (default sequential; multi defaults to 2 instances)\n\
         \x20 --scale F                         dataset scale multiplier (default 1.0)\n\
         \x20 --seed N                          RNG seed (default 0xE2E)\n"
    );
}

fn parse_cfg(args: &Args) -> RunConfig {
    let opt = match args.get_or("opt", "optimized") {
        "baseline" => OptLevel::Baseline,
        "optimized" => OptLevel::Optimized,
        other => {
            eprintln!("invalid --opt {other:?}; use baseline|optimized");
            std::process::exit(2);
        }
    };
    let exec_spec = args.get_or("exec", "sequential");
    let Some(exec) = ExecMode::parse(exec_spec) else {
        eprintln!("invalid --exec {exec_spec:?}; use sequential|streaming|multi[:N]");
        std::process::exit(2);
    };
    RunConfig {
        toggles: Toggles::all(opt),
        scale: args.get_parse("scale", 1.0f64),
        seed: args.get_parse("seed", 0xE2Eu64),
        exec,
    }
}

fn cmd_list() -> i32 {
    let mut t = Table::new(&["pipeline", "description"]);
    for e in registry() {
        t.row(&[e.name.to_string(), e.description.to_string()]);
    }
    t.print();
    0
}

fn cmd_run(args: &Args) -> i32 {
    let Some(name) = args.positional.first() else {
        eprintln!("usage: repro run <pipeline> [--opt …] [--exec …] [--scale …]");
        return 2;
    };
    let cfg = parse_cfg(args);
    match run_by_name(name, &cfg) {
        Ok(res) => {
            println!(
                "pipeline: {name}   executor: {}   ({} items)",
                cfg.exec, res.items
            );
            res.report.table().print();
            let (pre, ai) = res.report.fig1_split();
            println!(
                "breakdown: {pre:.1}% pre/post, {ai:.1}% ai   total {}",
                fmt::dur(res.report.total())
            );
            println!("throughput: {:.1} items/s", res.throughput());
            for (k, v) in &res.metrics {
                println!("metric {k} = {v:.4}");
            }
            0
        }
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

fn cmd_fig1(args: &Args) -> i32 {
    let cfg = parse_cfg(args);
    let mut t = Table::new(&["pipeline", "% pre/post", "% ai", "total", "items/s"]);
    for e in registry() {
        match (e.run)(&cfg) {
            Ok(res) => {
                let (pre, ai) = res.report.fig1_split();
                t.row(&[
                    e.name.to_string(),
                    format!("{pre:.1}%"),
                    format!("{ai:.1}%"),
                    fmt::dur(res.report.total()),
                    format!("{:.1}", res.throughput()),
                ]);
            }
            Err(err) => {
                t.row(&[
                    e.name.to_string(),
                    format!("error: {err}"),
                    String::new(),
                    String::new(),
                    String::new(),
                ]);
            }
        }
    }
    println!(
        "Figure 1 — percent time in pre/post-processing vs AI ({}, {}, scale {}):",
        cfg.toggles.dataframe.label(),
        cfg.exec,
        cfg.scale
    );
    t.print();
    0
}

fn cmd_config() -> i32 {
    println!("software configuration (Table 3 analogue):");
    let mut t = Table::new(&["component", "version / detail"]);
    t.row(&["rustc".into(), "1.95 (offline sandbox)".into()]);
    t.row(&[
        "xla crate".into(),
        "offline stub (swap rust/shims/xla for xla 0.1.6 + PJRT CPU)".into(),
    ]);
    t.row(&["jax (build-time)".into(), "0.8.x — Pallas interpret-mode kernels".into()]);
    t.row(&[
        "artifacts".into(),
        format!("{}", repro::runtime::default_artifacts_dir().display()),
    ]);
    t.row(&["threads".into(), format!("{}", repro::parallel::default_threads())]);
    t.print();
    0
}

fn cmd_models() -> i32 {
    match repro::runtime::Engine::local() {
        Ok(engine) => {
            let mut t = Table::new(&["artifact", "inputs", "outputs"]);
            let manifest = engine.manifest();
            for name in manifest.names() {
                let m = manifest.model(name).unwrap();
                let specs = |v: &[repro::runtime::TensorSpec]| {
                    v.iter()
                        .map(|s| format!("{:?}:{}", s.shape, s.dtype))
                        .collect::<Vec<_>>()
                        .join(", ")
                };
                t.row(&[name.to_string(), specs(&m.inputs), specs(&m.outputs)]);
            }
            t.print();
            println!(
                "stage chains: {:?}",
                manifest.stage_chains.keys().collect::<Vec<_>>()
            );
            0
        }
        Err(e) => {
            eprintln!("cannot load artifacts ({e}); run `make artifacts` first");
            1
        }
    }
}
