//! `repro` — CLI for the E2E-AI-pipeline reproduction.
//!
//! ```text
//! repro list                       # Table 1: the eight pipelines
//! repro run <pipeline> [--opt baseline|optimized]
//!                      [--exec sequential|streaming|multi[:N]|shard[:N]|async[:T]]
//!                      [--scale F] [--seed N]
//! repro explain <pipeline>         # pre/post-optimization stage graph, fired rewrite
//!                                  # rules, and cost-model suggestions
//! repro serve [--requests N] [--mix census:4,dlsa:1] [--depth D] [--workers W]
//!             [--listen ADDR]      # soak a PipelineService with a mixed-priority request mix
//!                                  # (--listen serves it over TCP instead of in-process)
//! repro bench-serve [--clients C] [--requests N] [--mix census:4,iiot:1]
//!                                  # closed-loop TCP load generator; writes BENCH_serve.json
//! repro bench-kernels [--rows N] [--iters K]
//!                                  # per-verb columnar-kernel microbench; writes BENCH_kernels.json
//! repro fig1 [--scale F]           # Figure 1 stage breakdown, all pipelines
//! repro config                     # Table 3 analogue: software config
//! repro models                     # AOT artifacts available to the runtime
//! ```

use repro::coordinator::ExecMode;
use repro::net::{run_load, LoadSpec, PipelineServer, ServerConfig};
use repro::pipelines::{registry, run_by_name, RunConfig, Toggles};
use repro::service::{
    parse_mix, PipelineService, Priority, Request, Response, ServiceConfig,
};
use repro::util::cli::Args;
use repro::util::fmt::{self, Table};
use repro::OptLevel;
use std::collections::BTreeMap;
use std::sync::Arc;

fn main() {
    let args = Args::from_env();
    let code = match args.command.as_str() {
        "list" => cmd_list(),
        "run" => cmd_run(&args),
        "explain" => cmd_explain(&args),
        "serve" => cmd_serve(&args),
        "bench-serve" => cmd_bench_serve(&args),
        "bench-kernels" => cmd_bench_kernels(&args),
        "fig1" => cmd_fig1(&args),
        "config" => cmd_config(),
        "models" => cmd_models(),
        "" | "help" | "--help" => {
            print_help();
            0
        }
        other => {
            eprintln!("unknown command: {other}\n");
            print_help();
            2
        }
    };
    std::process::exit(code);
}

fn print_help() {
    println!(
        "repro — E2E AI pipeline optimization reproduction\n\
         \n\
         USAGE:\n  repro <command> [options]\n\
         \n\
         COMMANDS:\n\
         \x20 list                 list the eight pipelines (Table 1)\n\
         \x20 run <pipeline>       run one pipeline and print its report\n\
         \x20 explain <pipeline>   print the pre/post-optimization stage graph with\n\
         \x20                      per-stage profiles, the rewrite rules that fired, and\n\
         \x20                      the cost model's batch-rows / exec-mode suggestions\n\
         \x20 serve                soak a PipelineService with a mixed-priority request mix\n\
         \x20 bench-serve          closed-loop TCP load generator over a loopback PipelineServer;\n\
         \x20                      writes BENCH_serve.json (per-tenant throughput, p50/p95, sheds)\n\
         \x20 bench-kernels        per-verb columnar-kernel microbench (filter/with_column/astype/\n\
         \x20                      dropna/fillna rows/s + KernelReport ledger) plus one sequential\n\
         \x20                      census anchor; writes BENCH_kernels.json\n\
         \x20 fig1                 stage-time breakdown for every pipeline (Figure 1)\n\
         \x20 config               print the software configuration (Table 3)\n\
         \x20 models               list AOT model artifacts\n\
         \n\
         OPTIONS (run/explain/serve/fig1):\n\
         \x20 --opt baseline|optimized          optimization level (default optimized)\n\
         \x20 --exec sequential|streaming|multi[:N]|shard[:N]|async[:T]\n\
         \x20                                   executor for the pipeline plan\n\
         \x20                                   (default sequential; multi/shard/async default to 2)\n\
         \x20                                   multi:N runs N copies of the stream (§3.4);\n\
         \x20                                   shard:N splits ONE dataset round-robin across\n\
         \x20                                   N workers and merges sink state in shard order,\n\
         \x20                                   so metrics match the sequential run exactly;\n\
         \x20                                   async:T runs the stages as cooperative tasks on\n\
         \x20                                   T pooled workers (no thread per stage — one pool\n\
         \x20                                   multiplexes many in-flight plans when serving),\n\
         \x20                                   again with metrics identical to sequential\n\
         \x20 --scale F                         dataset scale multiplier (default 1.0)\n\
         \x20 --seed N                          RNG seed (default 0xE2E)\n\
         \x20 --batch-rows N                    rows per columnar batch for the tabular\n\
         \x20                                   pipelines (0 = per-item data plane; default 0)\n\
         \n\
         OPTIONS (serve):\n\
         \x20 --requests N                      requests to submit (default 12)\n\
         \x20 --mix name[:W],name[:W],…         weighted pipeline mix\n\
         \x20                                   (default census:2,plasticc:1,iiot:1)\n\
         \x20 --depth D                         admission-queue bound (default 8)\n\
         \x20 --workers W                       dispatcher threads (default 2)\n\
         \x20 --listen ADDR                     serve the soak over TCP at ADDR (the request\n\
         \x20                                   mix arrives through a loopback wire client;\n\
         \x20                                   --requests 0 keeps the server up until killed)\n\
         \x20 --max-conns N                     live-connection ceiling; over-limit connects\n\
         \x20                                   get a Shed(server_full) frame (default 1024)\n\
         \x20 --idle-after T                    reap connections idle for T timer ticks, incl.\n\
         \x20                                   never-finished handshakes (default 0 = off)\n\
         \n\
         OPTIONS (bench-serve):\n\
         \x20 --clients C                       closed-loop generator threads (default 2)\n\
         \x20 --requests N                      requests per client (default 12)\n\
         \x20 --mix name[:W],name[:W],…         tenant/pipeline mix (default census:2,iiot:1)\n\
         \x20 --depth D / --workers W           service provisioning (defaults 8 / 2)\n\
         \x20 --per-tenant D                    per-tenant in-flight lane depth (default 8)\n\
         \x20 --max-conns N / --idle-after T    serving-edge limits (as for serve --listen)\n\
         \x20 --out PATH                        trajectory path (default BENCH_serve.json)\n\
         \n\
         OPTIONS (bench-kernels):\n\
         \x20 --rows N                          rows per synthetic frame (default 200000 * --scale)\n\
         \x20 --iters K                         timed passes per verb (default 5)\n\
         \x20 --out PATH                        trajectory path (default BENCH_kernels.json)\n"
    );
}

fn parse_cfg(args: &Args) -> RunConfig {
    let opt = match args.get_or("opt", "optimized") {
        "baseline" => OptLevel::Baseline,
        "optimized" => OptLevel::Optimized,
        other => {
            eprintln!("invalid --opt {other:?}; use baseline|optimized");
            std::process::exit(2);
        }
    };
    let exec_spec = args.get_or("exec", "sequential");
    let Some(exec) = ExecMode::parse(exec_spec) else {
        eprintln!(
            "invalid --exec {exec_spec:?}; use sequential|streaming|multi[:N]|shard[:N]|async[:T]"
        );
        std::process::exit(2);
    };
    RunConfig {
        toggles: Toggles::all(opt),
        scale: args.get_parse("scale", 1.0f64),
        seed: args.get_parse("seed", 0xE2Eu64),
        exec,
        batch_rows: args.get_parse("batch-rows", 0usize),
    }
}

fn cmd_list() -> i32 {
    let mut t = Table::new(&["pipeline", "description"]);
    for e in registry() {
        t.row(&[e.name.to_string(), e.description.to_string()]);
    }
    t.print();
    0
}

fn cmd_run(args: &Args) -> i32 {
    let Some(name) = args.positional.first() else {
        eprintln!("usage: repro run <pipeline> [--opt …] [--exec …] [--scale …]");
        return 2;
    };
    let cfg = parse_cfg(args);
    match run_by_name(name, &cfg) {
        Ok(res) => {
            println!(
                "pipeline: {name}   executor: {}   ({} items)",
                cfg.exec, res.items
            );
            res.report.table().print();
            let (pre, ai) = res.report.fig1_split();
            println!(
                "breakdown: {pre:.1}% pre/post, {ai:.1}% ai   total {}",
                fmt::dur(res.report.total())
            );
            println!("throughput: {:.1} items/s", res.throughput());
            for (k, v) in &res.metrics {
                println!("metric {k} = {v:.4}");
            }
            if let Some(sched) = &res.sched {
                println!(
                    "scheduler: {} workers, {} tasks ({} polls, {} requeues, {} parked/{} woken), max in-flight {}",
                    sched.workers,
                    sched.tasks_run,
                    sched.polls,
                    sched.requeues,
                    sched.parked,
                    sched.woken,
                    sched.max_in_flight
                );
            }
            if let Some(b) = &res.batching {
                println!(
                    "batches: {} ({:.1} rows/batch; {} rows in = {} out + {} filtered; {:.1}% of moved bytes zero-copy)",
                    b.batches,
                    b.mean_rows(),
                    b.rows_in,
                    b.rows_out,
                    b.rows_filtered,
                    b.zero_copy_fraction() * 100.0
                );
            }
            if let Some(k) = &res.kernels {
                println!(
                    "kernels: {} rows through columnar verbs ({:.1}% vector path, {} chunks, {:.1}% lanes masked)",
                    k.rows(),
                    k.vector_fraction() * 100.0,
                    k.chunks,
                    k.masked_fraction() * 100.0
                );
            }
            if let Some(sharding) = &res.sharding {
                println!(
                    "shards: {} over one dataset (balance {:.2}, {:.1} items/s of wall, {} folds streamed ahead of the last pass)",
                    sharding.shard_count(),
                    sharding.balance(),
                    sharding.dataset_throughput(),
                    sharding.streamed_folds
                );
                sharding.table().print();
                let mut pcts = sharding.latency_percentiles(&[0.50, 0.95]).into_iter();
                let pct = |p: Option<std::time::Duration>| match p {
                    Some(d) => fmt::dur(d),
                    None => "-".to_string(),
                };
                println!(
                    "pooled item latency: p50 {} p95 {}",
                    pct(pcts.next().flatten()),
                    pct(pcts.next().flatten())
                );
            }
            0
        }
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

/// `repro explain <pipeline>`: compile the graph exactly as written,
/// profile one sequential run for per-stage item counters, then run the
/// plan optimizer fed by that profile and print both graphs, the fired
/// rules, and the deterministic cost-model suggestions. Exits non-zero
/// if the optimized graph's metrics diverge from the as-written run's
/// (they are pinned identical by the conformance matrix).
fn cmd_explain(args: &Args) -> i32 {
    let Some(name) = args.positional.first() else {
        eprintln!("usage: repro explain <pipeline> [--opt …] [--scale …] [--seed …]");
        return 2;
    };
    let cfg = parse_cfg(args);
    let Some(entry) = repro::pipelines::find(name) else {
        eprintln!(
            "unknown pipeline: {name} (known: {})",
            repro::pipelines::names().join(", ")
        );
        return 2;
    };
    let mut compiled = match repro::pipelines::compile_entry(entry, &cfg) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e:#}");
            return 1;
        }
    };
    let profile_cfg = RunConfig { exec: ExecMode::Sequential, ..cfg };
    let baseline = match repro::pipelines::run_compiled(
        entry,
        &compiled,
        repro::pipelines::Workload::Synthetic,
        &profile_cfg,
    ) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("error: {e:#}");
            return 1;
        }
    };
    println!("pipeline: {name}   (scale {}, seed {:#x})", cfg.scale, cfg.seed);
    println!(
        "pre-optimization graph ({} stages, profiled over one sequential run of {} items):",
        compiled.stage_count(),
        baseline.items
    );
    print!("{}", repro::coordinator::render_graph(&compiled, Some(&baseline.report)));
    let report = repro::coordinator::optimize_profiled(&mut compiled, &baseline.report);
    println!("post-optimization graph ({} stages):", compiled.stage_count());
    print!("{}", repro::coordinator::render_graph(&compiled, None));
    if report.rules.is_empty() {
        println!("rules fired: none (graph already minimal)");
    } else {
        println!("rules fired:");
        for (rule, n) in &report.rules {
            println!("  {rule} x{n}");
        }
    }
    println!(
        "stages: {} -> {} transform nodes ({} fused, {} elided, {} hoisted); per-item task hops saved: {}",
        report.stages_before,
        report.stages_after,
        report.fused,
        report.elided,
        report.hoisted,
        report.task_hops_saved
    );
    match (report.suggested_batch_rows, report.suggested_exec.as_deref()) {
        (None, None) => println!("cost model: no suggestions at this scale"),
        (rows, exec) => {
            let rows = rows.map_or("-".to_string(), |r| r.to_string());
            println!(
                "cost model: suggested batch_rows {rows}, suggested exec {} \
                 (advisory — apply via --batch-rows / --exec)",
                exec.unwrap_or("-")
            );
        }
    }
    let check = match repro::pipelines::run_compiled(
        entry,
        &compiled,
        repro::pipelines::Workload::Synthetic,
        &profile_cfg,
    ) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("error: optimized graph failed to run: {e:#}");
            return 1;
        }
    };
    // Wall-clock-valued metrics (fps) differ run to run by nature;
    // every deterministic metric must match bit-for-bit.
    let deterministic = |m: &std::collections::BTreeMap<String, f64>| {
        m.iter()
            .filter(|(k, _)| k.as_str() != "fps")
            .map(|(k, v)| (k.clone(), v.to_bits()))
            .collect::<Vec<_>>()
    };
    let identical = deterministic(&check.metrics) == deterministic(&baseline.metrics)
        && check.items == baseline.items;
    println!("conformance: optimized metrics identical to as-written run: {identical}");
    if !identical {
        eprintln!("error: optimization changed metrics");
        return 1;
    }
    0
}

fn cmd_serve(args: &Args) -> i32 {
    let cfg = parse_cfg(args);
    let requests: usize = args.get_parse("requests", 12usize);
    let depth: usize = args.get_parse("depth", 8usize);
    let workers: usize = args.get_parse("workers", 2usize);
    let mix_spec = args.get_or("mix", "census:2,plasticc:1,iiot:1");
    let mix = match parse_mix(mix_spec) {
        Ok(mix) => mix,
        Err(e) => {
            eprintln!("invalid --mix {mix_spec:?}: {e:#}");
            return 2;
        }
    };

    let names: Vec<&str> = mix.iter().map(|(n, _)| n.as_str()).collect();
    let svc = match PipelineService::open(
        &names,
        ServiceConfig {
            defaults: cfg,
            queue_depth: depth,
            workers,
            start_paused: false,
            skip_unavailable: true,
        },
    ) {
        Ok(svc) => svc,
        Err(e) => {
            eprintln!("error: {e:#}");
            return 1;
        }
    };
    for (name, why) in svc.skipped() {
        eprintln!("note: skipping {name} (no artifacts): {why}");
    }
    if let Some(listen) = args.get("listen") {
        let server_cfg = ServerConfig {
            max_conns: args.get_parse("max-conns", ServerConfig::default().max_conns),
            idle_after: args.get_parse("idle-after", 0usize),
            ..Default::default()
        };
        return cmd_serve_listen(Arc::new(svc), listen, &mix, requests, server_cfg);
    }
    // Steady state begins here: sessions have compiled their graphs and
    // warmed their model sets at open. Any warm round-trip past this
    // point would be a per-request regression.
    let warm_at_open = repro::runtime::warm_rpc_count();

    // Deterministic weighted round-robin over the opened sessions, with
    // priorities cycling normal → high → low.
    let schedule: Vec<&str> = mix
        .iter()
        .filter(|(name, _)| svc.session(name).is_some())
        .flat_map(|(name, weight)| std::iter::repeat(name.as_str()).take(*weight))
        .collect();
    if schedule.is_empty() {
        eprintln!("error: no pipeline in the mix could be opened");
        return 1;
    }
    const PRIORITIES: [Priority; 3] = [Priority::Normal, Priority::High, Priority::Low];
    let mut tickets = Vec::with_capacity(requests);
    for i in 0..requests {
        let req = Request::synthetic(schedule[i % schedule.len()])
            .with_priority(PRIORITIES[i % PRIORITIES.len()]);
        match svc.submit(req) {
            Ok(ticket) => tickets.push(ticket),
            Err(e) => {
                eprintln!("error: {e:#}");
                return 1;
            }
        }
    }

    let mut completed: BTreeMap<String, usize> = BTreeMap::new();
    let mut shed: BTreeMap<String, usize> = BTreeMap::new();
    let mut last_output: BTreeMap<String, String> = BTreeMap::new();
    let mut failed = 0usize;
    for ticket in tickets {
        match ticket.wait() {
            Response::Completed(c) => {
                *completed.entry(c.pipeline.clone()).or_default() += 1;
                last_output.insert(c.pipeline, c.output.summary());
            }
            Response::Shed { pipeline, priority, reason, .. } => {
                eprintln!("shed: {pipeline} ({priority}, {})", reason.label());
                *shed.entry(pipeline).or_default() += 1;
            }
            Response::Failed { pipeline, error } => {
                eprintln!("request failed ({pipeline}): {error}");
                failed += 1;
            }
        }
    }

    println!(
        "serve soak: {requests} requests over {} (depth {depth}, {workers} workers, {}):",
        svc.session_names().join(", "),
        cfg.exec,
    );
    let mut t = Table::new(&["pipeline", "completed", "shed", "last output"]);
    for name in svc.session_names() {
        t.row(&[
            name.to_string(),
            completed.get(name).copied().unwrap_or(0).to_string(),
            shed.get(name).copied().unwrap_or(0).to_string(),
            last_output.get(name).cloned().unwrap_or_default(),
        ]);
    }
    t.print();

    let qs = svc.queue_stats();
    println!(
        "queue: admitted {} shed {} dispatched {} peak depth {}",
        qs.admitted, qs.shed, qs.dispatched, qs.peak_depth
    );
    let stats = svc.stats();
    println!(
        "outcomes: submitted {} = completed {} + shed {} + failed {} (balanced: {})",
        stats.submitted,
        stats.completed,
        stats.shed,
        stats.failed,
        stats.balances()
    );
    if let Some(sc) = svc.scheduler_counters() {
        println!(
            "async pool: {} workers, {} tasks ({} polls, {} requeues, {} parked/{} woken), max in-flight {}",
            sc.workers,
            sc.tasks_run,
            sc.polls,
            sc.requeues,
            sc.parked,
            sc.woken,
            sc.max_in_flight
        );
    }
    // Compile-once accounting, from counters (never wall-clock-only):
    // per-session binds + bind time, plus the amortization factor.
    let mut t = Table::new(&[
        "pipeline",
        "graph builds",
        "binds",
        "mean bind",
        "binds/build",
        "est. saved",
    ]);
    for (name, br) in svc.bind_reports() {
        t.row(&[
            name.to_string(),
            br.compiles.to_string(),
            br.binds.to_string(),
            fmt::dur(br.mean_bind_time()),
            format!("{:.1}", br.binds_per_compile()),
            fmt::dur(br.amortized_saving()),
        ]);
    }
    println!("plan reuse (compile once, bind per request):");
    t.print();
    let total = svc.bind_report_total();
    let warm_delta = repro::runtime::warm_rpc_count() - warm_at_open;
    println!(
        "steady state: {} graph builds served {} binds ({} rebuilds avoided, ~{} setup saved); {} warm rpcs after open{}",
        total.compiles,
        total.binds,
        total.rebuilds_avoided(),
        fmt::dur(total.amortized_saving()),
        warm_delta,
        if warm_delta == 0 { " (compile-once holds)" } else { " (UNEXPECTED)" },
    );
    let report = svc.scaling_report();
    let pct = |p: Option<std::time::Duration>| match p {
        Some(d) => fmt::dur(d),
        None => "-".to_string(),
    };
    let mut pcts = report.latency_percentiles(&[0.50, 0.95]).into_iter();
    println!(
        "request latency: p50 {} p95 {}",
        pct(pcts.next().flatten()),
        pct(pcts.next().flatten())
    );
    if failed > 0 {
        eprintln!("{failed} request(s) failed");
        return 1;
    }
    0
}

fn print_net_report(report: &repro::coordinator::telemetry::NetReport) {
    println!(
        "connections: accepted {} drained {} reaped {} ({} idle, {} handshake) \
         rejected {} active {}; frames {} in / {} out",
        report.accepted,
        report.drained,
        report.reaped(),
        report.reaped_idle,
        report.reaped_handshake,
        report.rejected,
        report.active(),
        report.frames_in,
        report.frames_out
    );
    let mut t = Table::new(&["tenant", "admitted", "completed", "shed", "failed", "balanced"]);
    for (tenant, l) in &report.tenants {
        t.row(&[
            tenant.clone(),
            l.admitted.to_string(),
            l.completed.to_string(),
            l.shed.to_string(),
            l.failed.to_string(),
            l.balances().to_string(),
        ]);
    }
    t.print();
    println!("net ledger balanced: {}", report.balanced());
}

/// `serve --listen ADDR`: put the opened service behind a
/// `PipelineServer` and push the soak through a loopback wire client
/// (or serve until killed with `--requests 0`).
fn cmd_serve_listen(
    svc: Arc<PipelineService>,
    listen: &str,
    mix: &[(String, usize)],
    requests: usize,
    server_cfg: ServerConfig,
) -> i32 {
    let server =
        match PipelineServer::start(Arc::clone(&svc), listen, server_cfg) {
            Ok(server) => server,
            Err(e) => {
                eprintln!("error: {e:#}");
                return 1;
            }
        };
    println!(
        "serving {} at {} (wire protocol v{}; tenant = pipeline name)",
        svc.session_names().join(", "),
        server.local_addr(),
        repro::net::wire::VERSION
    );
    if requests == 0 {
        println!("--requests 0: serving until killed");
        loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        }
    }
    let mix: Vec<(String, usize)> =
        mix.iter().filter(|(n, _)| svc.session(n).is_some()).cloned().collect();
    if mix.is_empty() {
        eprintln!("error: no pipeline in the mix could be opened");
        return 1;
    }
    let report = match run_load(server.local_addr(), &LoadSpec { clients: 1, requests, mix }) {
        Ok(report) => report,
        Err(e) => {
            eprintln!("error: {e:#}");
            return 1;
        }
    };
    let net = server.drain();
    println!(
        "loopback soak: {requests} closed-loop requests, {} completed in {:.2}s",
        report.total_completed(),
        report.wall.as_secs_f64()
    );
    print_net_report(&net);
    if !net.balanced() || !report.balances() {
        eprintln!("error: serving ledger did not balance");
        return 1;
    }
    0
}

fn cmd_bench_serve(args: &Args) -> i32 {
    let cfg = parse_cfg(args);
    let clients: usize = args.get_parse("clients", 2usize);
    let requests: usize = args.get_parse("requests", 12usize);
    let depth: usize = args.get_parse("depth", 8usize);
    let workers: usize = args.get_parse("workers", 2usize);
    let per_tenant: usize = args.get_parse("per-tenant", 8usize);
    let out = args.get_or("out", "BENCH_serve.json");
    let mix_spec = args.get_or("mix", "census:2,iiot:1");
    let mix = match parse_mix(mix_spec) {
        Ok(mix) => mix,
        Err(e) => {
            eprintln!("invalid --mix {mix_spec:?}: {e:#}");
            return 2;
        }
    };
    let names: Vec<&str> = mix.iter().map(|(n, _)| n.as_str()).collect();
    let svc = match PipelineService::open(
        &names,
        ServiceConfig {
            defaults: cfg,
            queue_depth: depth,
            workers,
            start_paused: false,
            skip_unavailable: true,
        },
    ) {
        Ok(svc) => Arc::new(svc),
        Err(e) => {
            eprintln!("error: {e:#}");
            return 1;
        }
    };
    for (name, why) in svc.skipped() {
        eprintln!("note: skipping {name} (no artifacts): {why}");
    }
    let mix: Vec<(String, usize)> =
        mix.into_iter().filter(|(n, _)| svc.session(n).is_some()).collect();
    if mix.is_empty() {
        eprintln!("error: no pipeline in the mix could be opened");
        return 1;
    }
    let server = match PipelineServer::start(
        Arc::clone(&svc),
        "127.0.0.1:0",
        ServerConfig {
            per_tenant_depth: per_tenant,
            max_conns: args.get_parse("max-conns", ServerConfig::default().max_conns),
            idle_after: args.get_parse("idle-after", 0usize),
            ..Default::default()
        },
    ) {
        Ok(server) => server,
        Err(e) => {
            eprintln!("error: {e:#}");
            return 1;
        }
    };
    println!(
        "bench-serve: {clients} clients x {requests} closed-loop requests over {} at {}",
        mix.iter().map(|(n, w)| format!("{n}:{w}")).collect::<Vec<_>>().join(","),
        server.local_addr()
    );
    let report = match run_load(server.local_addr(), &LoadSpec { clients, requests, mix }) {
        Ok(report) => report,
        Err(e) => {
            eprintln!("error: {e:#}");
            return 1;
        }
    };
    let net = server.drain();
    let secs = report.wall.as_secs_f64();
    let mut t = Table::new(&[
        "tenant",
        "requests",
        "completed",
        "req/s",
        "p50 ms",
        "p95 ms",
        "shed",
        "failed",
    ]);
    for (tenant, l) in &report.per_tenant {
        let pct = |q: f64| match repro::net::client::percentile_ms(&l.latencies_ms, q) {
            Some(ms) => format!("{ms:.2}"),
            None => "-".to_string(),
        };
        t.row(&[
            tenant.clone(),
            l.requests.to_string(),
            l.completed.to_string(),
            format!("{:.1}", l.completed as f64 / secs.max(1e-12)),
            pct(0.50),
            pct(0.95),
            format!("{} ({:.0}%)", l.shed, l.shed_fraction() * 100.0),
            l.failed.to_string(),
        ]);
    }
    t.print();
    // Per-cause shed attribution: every shed above is broken out by its
    // wire-level ShedCause, cross-checked against the server's Goodbye.
    for (tenant, l) in &report.per_tenant {
        if l.shed == 0 {
            continue;
        }
        let causes: Vec<String> = repro::net::ShedCause::ALL
            .iter()
            .filter(|c| l.shed_by_cause[c.index()] > 0)
            .map(|c| format!("{c}: {}", l.shed_by_cause[c.index()]))
            .collect();
        println!("sheds for {tenant}: {}", causes.join(", "));
    }
    print_net_report(&net);
    let qs = svc.queue_stats();
    for p in Priority::ALL {
        let lane = qs.lane(p);
        println!(
            "lane {p}: admitted {} shed {} dispatched {} peak depth {}",
            lane.admitted, lane.shed, lane.dispatched, lane.peak_depth
        );
    }
    if !net.balanced() || !report.balances() {
        eprintln!("error: serving ledger did not balance");
        return 1;
    }
    // Top-level `net` section: the server's connection ledger rides
    // beside the per-tenant trajectories so validate_bench can gate the
    // serving-edge balance (`accepted == drained + reaped`) from the
    // persisted artifact, not just this process's stdout.
    let net_section = {
        use repro::util::json::Json;
        let mut o = std::collections::BTreeMap::new();
        o.insert("accepted".to_string(), Json::Num(net.accepted as f64));
        o.insert("drained".to_string(), Json::Num(net.drained as f64));
        o.insert("rejected".to_string(), Json::Num(net.rejected as f64));
        o.insert("reaped_idle".to_string(), Json::Num(net.reaped_idle as f64));
        o.insert(
            "reaped_handshake".to_string(),
            Json::Num(net.reaped_handshake as f64),
        );
        o.insert("frames_in".to_string(), Json::Num(net.frames_in as f64));
        o.insert("frames_out".to_string(), Json::Num(net.frames_out as f64));
        let mut extra = std::collections::BTreeMap::new();
        extra.insert("net".to_string(), Json::Obj(o));
        extra
    };
    match repro::util::bench::write_trajectory_with(
        out,
        "bench_serve",
        cfg.scale,
        report.trajectory_pipelines(),
        net_section,
    ) {
        Ok(_) => {
            println!("wrote {out}");
            0
        }
        Err(e) => {
            eprintln!("cannot write {out}: {e}");
            1
        }
    }
}

/// `repro bench-kernels`: time each rewritten dataframe verb over a
/// synthetic masked frame, ledger every pass through the columnar
/// kernel layer, and persist the per-verb rows/s trajectory (plus one
/// tiny sequential census run as the cross-bench E2E anchor) to
/// `BENCH_kernels.json`. Counters prove WHERE rows went (vector vs
/// scalar path); the wall clocks prove how fast they moved.
fn cmd_bench_kernels(args: &Args) -> i32 {
    use repro::dataframe::{kernels, ops, Column, DType, DataFrame, Engine, Expr, FrameError};
    use repro::util::bench;
    use repro::util::json::Json;
    use std::hint::black_box;
    use std::time::Instant;

    let cfg = parse_cfg(args);
    let default_rows = ((200_000.0 * cfg.scale) as usize).max(4_096);
    let rows: usize = args.get_parse("rows", default_rows);
    let iters: usize = args.get_parse("iters", 5usize).max(1);
    let out = args.get_or("out", "BENCH_kernels.json");
    let engine = match cfg.toggles.dataframe {
        OptLevel::Optimized => Engine::Optimized,
        OptLevel::Baseline => Engine::Baseline,
    };

    // Synthetic frame shaped like the tabular pipelines' hot columns:
    // masked f64 `x` (~12% nulls), masked i64 `k` (~8% nulls), and an
    // unmasked f64 `y`. Deterministic from --seed.
    let mut rng = repro::util::Rng::new(cfg.seed);
    let mut xv = Vec::with_capacity(rows);
    let mut xm = Vec::with_capacity(rows);
    let mut kv = Vec::with_capacity(rows);
    let mut km = Vec::with_capacity(rows);
    let mut yv = Vec::with_capacity(rows);
    for _ in 0..rows {
        xv.push(rng.normal());
        xm.push(!rng.chance(0.12));
        kv.push(rng.below(1000) as i64 - 500);
        km.push(!rng.chance(0.08));
        yv.push(rng.f64());
    }
    let df = DataFrame::from_cols(vec![
        ("x", Column::F64(xv, Some(xm))),
        ("k", Column::I64(kv, Some(km))),
        ("y", Column::f64(yv)),
    ]);

    let filter_pred = Expr::col("x").gt(Expr::lit(0.25));
    let derive = Expr::col("x")
        .mul(Expr::col("k"))
        .add(Expr::col("y").div(Expr::col("x")));
    let run_verb = |verb: &str, d: &DataFrame| -> Result<DataFrame, FrameError> {
        match verb {
            "filter" => ops::filter(d, &filter_pred, engine),
            "with_column" => ops::with_column(d, "z", &derive, engine),
            "astype" => ops::astype(d, "k", DType::F64, engine),
            "dropna" => ops::dropna(d, &[], engine),
            "fillna" => ops::fillna_f64(d, "x", -7.25, engine),
            other => unreachable!("unknown verb {other}"),
        }
    };

    println!(
        "bench-kernels: {} engine, {rows} rows x {iters} iters per verb",
        cfg.toggles.dataframe.label()
    );
    let mut t = Table::new(&["verb", "rows/s", "vector rows", "scalar rows", "vector %"]);
    let mut section = BTreeMap::new();
    for name in ["filter", "with_column", "astype", "dropna", "fillna"] {
        let before = kernels::snapshot();
        let t0 = Instant::now();
        for _ in 0..iters {
            match run_verb(name, &df) {
                Ok(res) => {
                    black_box(res.nrows());
                }
                Err(e) => {
                    eprintln!("error: {name}: {e:?}");
                    return 1;
                }
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let delta = kernels::snapshot().since(&before);
        let total_rows = rows * iters;
        let rows_per_s = total_rows as f64 / wall.max(1e-12);
        t.row(&[
            name.to_string(),
            format!("{rows_per_s:.0}"),
            delta.vector_rows.to_string(),
            delta.scalar_rows.to_string(),
            format!("{:.1}%", delta.vector_fraction() * 100.0),
        ]);
        let mut e = BTreeMap::new();
        e.insert("rows".to_string(), Json::Num(total_rows as f64));
        e.insert("iters".to_string(), Json::Num(iters as f64));
        e.insert("wall_s".to_string(), Json::Num(wall));
        e.insert("rows_per_s".to_string(), Json::Num(rows_per_s));
        e.insert("vector_rows".to_string(), Json::Num(delta.vector_rows as f64));
        e.insert("scalar_rows".to_string(), Json::Num(delta.scalar_rows as f64));
        e.insert("chunks".to_string(), Json::Num(delta.chunks as f64));
        e.insert("masked_rows".to_string(), Json::Num(delta.masked_rows as f64));
        e.insert("vector_fraction".to_string(), Json::Num(delta.vector_fraction()));
        section.insert(name.to_string(), Json::Obj(e));
    }
    t.print();

    // One tiny sequential census run anchors the verb throughputs to an
    // E2E trajectory every other bench also records.
    let census_cfg = RunConfig { exec: ExecMode::Sequential, ..cfg };
    let t0 = Instant::now();
    let res = match run_by_name("census", &census_cfg) {
        Ok(res) => res,
        Err(e) => {
            eprintln!("error: census anchor: {e:#}");
            return 1;
        }
    };
    let anchor = bench::mode_entry(&res, t0.elapsed());
    if let Some(k) = &res.kernels {
        println!(
            "census anchor: {:.1}% of {} dataframe rows on the vector path",
            k.vector_fraction() * 100.0,
            k.rows()
        );
    }
    let mut modes = BTreeMap::new();
    modes.insert("sequential".to_string(), anchor);
    let mut census = BTreeMap::new();
    census.insert("exec_modes".to_string(), Json::Obj(modes));
    let mut pipelines = BTreeMap::new();
    pipelines.insert("census".to_string(), Json::Obj(census));
    let mut extra = BTreeMap::new();
    extra.insert("kernels".to_string(), Json::Obj(section));
    match bench::write_trajectory_with(out, "bench_kernels", cfg.scale, pipelines, extra) {
        Ok(_) => {
            println!("wrote {out}");
            0
        }
        Err(e) => {
            eprintln!("cannot write {out}: {e}");
            1
        }
    }
}

fn cmd_fig1(args: &Args) -> i32 {
    let cfg = parse_cfg(args);
    let mut t = Table::new(&["pipeline", "% pre/post", "% ai", "total", "items/s"]);
    for e in registry() {
        match (e.run)(&cfg) {
            Ok(res) => {
                let (pre, ai) = res.report.fig1_split();
                t.row(&[
                    e.name.to_string(),
                    format!("{pre:.1}%"),
                    format!("{ai:.1}%"),
                    fmt::dur(res.report.total()),
                    format!("{:.1}", res.throughput()),
                ]);
            }
            Err(err) => {
                t.row(&[
                    e.name.to_string(),
                    format!("error: {err}"),
                    String::new(),
                    String::new(),
                    String::new(),
                ]);
            }
        }
    }
    println!(
        "Figure 1 — percent time in pre/post-processing vs AI ({}, {}, scale {}):",
        cfg.toggles.dataframe.label(),
        cfg.exec,
        cfg.scale
    );
    t.print();
    0
}

fn cmd_config() -> i32 {
    println!("software configuration (Table 3 analogue):");
    let mut t = Table::new(&["component", "version / detail"]);
    t.row(&["rustc".into(), "1.95 (offline sandbox)".into()]);
    t.row(&[
        "xla crate".into(),
        "offline stub (swap rust/shims/xla for xla 0.1.6 + PJRT CPU)".into(),
    ]);
    t.row(&["jax (build-time)".into(), "0.8.x — Pallas interpret-mode kernels".into()]);
    t.row(&[
        "artifacts".into(),
        format!("{}", repro::runtime::default_artifacts_dir().display()),
    ]);
    t.row(&["threads".into(), format!("{}", repro::parallel::default_threads())]);
    t.print();
    0
}

fn cmd_models() -> i32 {
    match repro::runtime::Engine::local() {
        Ok(engine) => {
            let mut t = Table::new(&["artifact", "inputs", "outputs"]);
            let manifest = engine.manifest();
            for name in manifest.names() {
                let m = manifest.model(name).unwrap();
                let specs = |v: &[repro::runtime::TensorSpec]| {
                    v.iter()
                        .map(|s| format!("{:?}:{}", s.shape, s.dtype))
                        .collect::<Vec<_>>()
                        .join(", ")
                };
                t.row(&[name.to_string(), specs(&m.inputs), specs(&m.outputs)]);
            }
            t.print();
            println!(
                "stage chains: {:?}",
                manifest.stage_chains.keys().collect::<Vec<_>>()
            );
            0
        }
        Err(e) => {
            eprintln!("cannot load artifacts ({e}); run `make artifacts` first");
            1
        }
    }
}
