//! Plan IR — the declarative pipeline representation every workload
//! compiles down to.
//!
//! A [`Plan`] is a linear graph of named, [`Category`]-tagged stage nodes:
//! one **source** (produces items), any number of **map / flat-map**
//! transforms (1→1 / 1→0..n, so filters and expanders fit), optional
//! **batch** nodes (group items under a [`BatcherConfig`] policy — the
//! DLSA dynamic-batching serving path), and one **sink** that folds items
//! into a state from which [`PlanOutput`] metrics are computed.
//!
//! Plans say *what* the pipeline computes; the interchangeable executors
//! in [`super::exec`] decide *how*: in-thread sequential, thread-per-stage
//! streaming over bounded channels, N replicated instances (§3.4), or N
//! data-parallel shards over one dataset ([`Sharder`] /
//! `ExecMode::Sharded`). Because the plan is data, cross-cutting
//! optimizations (batching, scaling, sharding, telemetry) are implemented
//! once in an executor instead of being re-wired into every workload —
//! the tf.data / BigDL split between pipeline definition and execution
//! strategy.
//!
//! Typing: the builder ([`PlanBuilder`]) is statically typed stage to
//! stage; items are type-erased to `Box<dyn Any + Send>` internally so
//! heterogeneous plans share one executor implementation. A mismatch
//! (impossible via the typed builder) surfaces as a descriptive error,
//! not UB. A plan's closures are single-use: executors consume the plan,
//! and replication (multi-instance) re-invokes the plan-builder function.

use super::batcher::BatcherConfig;
use super::telemetry::Category;
use std::any::Any;
use std::collections::BTreeMap;
use std::marker::PhantomData;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// A type-erased item flowing between stages.
pub type DynItem = Box<dyn Any + Send>;

pub(crate) type SourceFn = Box<dyn FnMut(&mut dyn FnMut(DynItem)) + Send>;
pub(crate) type StageFn = Box<dyn FnMut(DynItem) -> anyhow::Result<Vec<DynItem>> + Send>;
pub(crate) type GroupFn = Box<dyn FnMut(Vec<DynItem>) -> anyhow::Result<DynItem> + Send>;
pub(crate) type SinkFn = Box<dyn FnMut(DynItem) -> anyhow::Result<()> + Send>;
pub(crate) type FinishFn = Box<dyn FnOnce() -> anyhow::Result<PlanOutput> + Send>;

/// What a finished plan reports: deterministic metrics + item count.
/// (Per-stage timing comes from the executor's telemetry, not the plan.)
#[derive(Debug, Clone)]
pub struct PlanOutput {
    /// Named quality/throughput metrics (auc, r2, agreement, …).
    pub metrics: BTreeMap<String, f64>,
    /// Items processed end-to-end (rows, docs, frames, …).
    pub items: usize,
}

/// How a transform node rewrites the item stream.
pub(crate) enum NodeKind {
    /// 1 → 0..n items.
    FlatMap(StageFn),
    /// Group items into batches under a max-size / max-wait policy; the
    /// grouped batch flows downstream as a single item.
    Batch(BatcherConfig, GroupFn),
}

/// An in-flight item plus its source-emission instant; the stamp rides
/// along so the sink stage can record a true per-item end-to-end
/// latency. Batch nodes keep the earliest stamp of their members (a
/// batch is as old as its oldest item).
pub(crate) struct Stamped {
    pub(crate) born: Instant,
    pub(crate) item: DynItem,
}

/// How a transform node consumes items when it runs as a resumable
/// stage task (the async executor): flat-maps pass each item straight
/// through their closure; batch nodes buffer until `max_batch` items
/// and cut size-based batches. Every item of the one pass eventually
/// arrives — exactly the sequential executor's situation — so async
/// batch boundaries equal sequential ones, which is part of what keeps
/// the executor-conformance matrix green.
pub(crate) enum ResumableKind {
    FlatMap(StageFn),
    Batch { max_batch: usize, group: GroupFn, pending: Vec<Stamped> },
}

/// One transform node re-packaged as a resumable stage task: feed items
/// with `push` as they arrive, then `flush` once upstream is exhausted.
/// Both report how many work units (flat-map calls / batches cut) they
/// performed, so the caller records stage telemetry with the same item
/// counts as the sequential executor.
pub(crate) struct ResumableNode {
    pub(crate) name: String,
    pub(crate) category: Category,
    kind: ResumableKind,
}

impl ResumableNode {
    /// Feed one item; returns the outputs ready now plus the work units
    /// performed (0 when a batch node merely buffered).
    pub(crate) fn push(&mut self, s: Stamped) -> anyhow::Result<(Vec<Stamped>, usize)> {
        match &mut self.kind {
            ResumableKind::FlatMap(f) => {
                let Stamped { born, item } = s;
                let outs = f(item)?;
                Ok((outs.into_iter().map(|item| Stamped { born, item }).collect(), 1))
            }
            ResumableKind::Batch { max_batch, group, pending } => {
                pending.push(s);
                if pending.len() >= *max_batch {
                    let batch: Vec<Stamped> = pending.drain(..).collect();
                    Ok((vec![cut_batch(group, batch)?], 1))
                } else {
                    Ok((Vec::new(), 0))
                }
            }
        }
    }

    /// Upstream is exhausted: emit whatever the node still buffers (the
    /// final short batch). Flat-maps buffer nothing.
    pub(crate) fn flush(&mut self) -> anyhow::Result<(Vec<Stamped>, usize)> {
        match &mut self.kind {
            ResumableKind::FlatMap(_) => Ok((Vec::new(), 0)),
            ResumableKind::Batch { group, pending, .. } => {
                if pending.is_empty() {
                    return Ok((Vec::new(), 0));
                }
                let batch: Vec<Stamped> = pending.drain(..).collect();
                Ok((vec![cut_batch(group, batch)?], 1))
            }
        }
    }
}

/// Group a non-empty batch into one downstream item stamped with its
/// oldest member's birth.
fn cut_batch(group: &mut GroupFn, batch: Vec<Stamped>) -> anyhow::Result<Stamped> {
    let born = batch.iter().map(|s| s.born).min().expect("non-empty batch");
    let members: Vec<DynItem> = batch.into_iter().map(|s| s.item).collect();
    Ok(Stamped { born, item: group(members)? })
}

/// One transform node of a plan.
pub(crate) struct Node {
    pub(crate) name: String,
    pub(crate) category: Category,
    pub(crate) kind: NodeKind,
}

impl Node {
    /// Re-package this node for resumable (task-at-a-time) execution.
    /// `max_wait` is dropped for batch nodes: a resumable pass, like a
    /// sequential one, eventually sees every item, so batches flush on
    /// size (plus one final remainder flush) and the boundaries match
    /// the sequential executor's exactly.
    pub(crate) fn into_resumable(self) -> ResumableNode {
        let kind = match self.kind {
            NodeKind::FlatMap(f) => ResumableKind::FlatMap(f),
            NodeKind::Batch(cfg, group) => ResumableKind::Batch {
                max_batch: cfg.max_batch.max(1),
                group,
                pending: Vec::new(),
            },
        };
        ResumableNode { name: self.name, category: self.category, kind }
    }
}

/// A fully-built pipeline plan, ready for one execution.
pub struct Plan {
    pub(crate) name: String,
    pub(crate) source: (String, Category, SourceFn),
    pub(crate) nodes: Vec<Node>,
    pub(crate) sink: (String, Category, SinkFn),
    pub(crate) finish: FinishFn,
}

impl Plan {
    /// Start a plan from a source closure that pushes typed items through
    /// `emit` and returns when the stream is exhausted.
    pub fn source<T, F>(
        pipeline: &str,
        stage: &str,
        category: Category,
        mut produce: F,
    ) -> PlanBuilder<T>
    where
        T: Send + 'static,
        F: FnMut(&mut dyn FnMut(T)) + Send + 'static,
    {
        let erased: SourceFn = Box::new(move |emit: &mut dyn FnMut(DynItem)| {
            let mut typed = |t: T| emit(Box::new(t) as DynItem);
            produce(&mut typed);
        });
        PlanBuilder {
            name: pipeline.to_string(),
            source: (stage.to_string(), category, erased),
            nodes: Vec::new(),
            _marker: PhantomData,
        }
    }

    /// Pipeline name.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Stage names in execution order (source, transforms, sink).
    pub fn stage_names(&self) -> Vec<String> {
        let mut names = vec![self.source.0.clone()];
        names.extend(self.nodes.iter().map(|n| n.name.clone()));
        names.push(self.sink.0.clone());
        names
    }

    /// Number of stages including source and sink.
    pub fn stage_count(&self) -> usize {
        self.nodes.len() + 2
    }
}

/// Deterministic round-robin partitioner over a plan source's emission
/// stream: emission `i` belongs to shard `i % of`. Partitions are
/// disjoint and cover the stream, and ownership depends only on the
/// emission index — never on thread timing — so a sharded run processes
/// exactly the dataset a sequential run would, split `of` ways.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Sharder {
    shard: usize,
    of: usize,
}

impl Sharder {
    /// Partition `shard` of `of` (0-based; `shard < of`, `of >= 1`).
    pub fn new(shard: usize, of: usize) -> Sharder {
        assert!(of >= 1, "sharding needs at least one shard");
        assert!(shard < of, "shard index {shard} out of range for {of} shards");
        Sharder { shard, of }
    }

    /// This partition's 0-based index.
    pub fn shard(&self) -> usize {
        self.shard
    }

    /// Total number of partitions.
    pub fn of(&self) -> usize {
        self.of
    }

    /// Whether source emission `index` belongs to this partition.
    pub fn owns(&self, index: usize) -> bool {
        index % self.of == self.shard
    }
}

impl std::fmt::Display for Sharder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}/{}", self.shard, self.of)
    }
}

impl Plan {
    /// Restrict this plan's source to the emissions `sharder` owns: the
    /// wrapped source produces the full stream, but only every
    /// `of`-th item (offset by the shard index) is forwarded downstream.
    /// Transform and sink stages are untouched — the sharded executor
    /// runs one such restricted plan per shard over the same stage graph
    /// and merges sink state in shard order.
    pub fn shard(mut self, sharder: Sharder) -> Plan {
        let (name, category, mut produce) = self.source;
        let filtered: SourceFn = Box::new(move |emit: &mut dyn FnMut(DynItem)| {
            let mut index = 0usize;
            produce(&mut |item| {
                if sharder.owns(index) {
                    emit(item);
                }
                index += 1;
            });
        });
        self.source = (name, category, filtered);
        self
    }
}

fn downcast<T: 'static>(item: DynItem, stage: &str) -> anyhow::Result<T> {
    match item.downcast::<T>() {
        Ok(boxed) => Ok(*boxed),
        Err(_) => Err(anyhow::anyhow!(
            "plan type mismatch at stage `{stage}`: expected {}",
            std::any::type_name::<T>()
        )),
    }
}

/// Typed builder for a [`Plan`]; `T` is the item type flowing out of the
/// last appended stage.
pub struct PlanBuilder<T> {
    name: String,
    source: (String, Category, SourceFn),
    nodes: Vec<Node>,
    _marker: PhantomData<fn() -> T>,
}

impl<T: Send + 'static> PlanBuilder<T> {
    fn push_node<O: Send + 'static>(mut self, node: Node) -> PlanBuilder<O> {
        self.nodes.push(node);
        PlanBuilder {
            name: self.name,
            source: self.source,
            nodes: self.nodes,
            _marker: PhantomData,
        }
    }

    /// Append a 1→1 transform.
    pub fn map<O, F>(self, name: &str, category: Category, mut f: F) -> PlanBuilder<O>
    where
        O: Send + 'static,
        F: FnMut(T) -> anyhow::Result<O> + Send + 'static,
    {
        let stage = name.to_string();
        let erased: StageFn = Box::new(move |item| {
            let t = downcast::<T>(item, &stage)?;
            Ok(vec![Box::new(f(t)?) as DynItem])
        });
        self.push_node(Node {
            name: name.to_string(),
            category,
            kind: NodeKind::FlatMap(erased),
        })
    }

    /// Append a 1→0..n transform (filters, expanders, batch unpackers).
    pub fn flat_map<O, F>(self, name: &str, category: Category, mut f: F) -> PlanBuilder<O>
    where
        O: Send + 'static,
        F: FnMut(T) -> anyhow::Result<Vec<O>> + Send + 'static,
    {
        let stage = name.to_string();
        let erased: StageFn = Box::new(move |item| {
            let t = downcast::<T>(item, &stage)?;
            Ok(f(t)?.into_iter().map(|o| Box::new(o) as DynItem).collect())
        });
        self.push_node(Node {
            name: name.to_string(),
            category,
            kind: NodeKind::FlatMap(erased),
        })
    }

    /// Append a dynamic-batching node: downstream stages receive
    /// `Vec<T>` batches. Under the streaming executor batches flush on
    /// `max_batch` items *or* `max_wait` elapsed (the serving trade-off);
    /// under the sequential executor all items are already available, so
    /// batches flush on size alone.
    pub fn batch(self, name: &str, category: Category, cfg: BatcherConfig) -> PlanBuilder<Vec<T>> {
        let stage = name.to_string();
        let group: GroupFn = Box::new(move |items: Vec<DynItem>| {
            let mut out: Vec<T> = Vec::with_capacity(items.len());
            for item in items {
                out.push(downcast::<T>(item, &stage)?);
            }
            Ok(Box::new(out) as DynItem)
        });
        self.push_node(Node {
            name: name.to_string(),
            category,
            kind: NodeKind::Batch(cfg, group),
        })
    }

    /// Terminate the plan with a sink fold plus a finish step that turns
    /// the folded state into the plan's [`PlanOutput`]. The fold runs per
    /// item inside the timed sink stage; `finish` runs once, untimed,
    /// after the stream drains (offline audits belong there).
    pub fn sink<S, F, G>(
        self,
        name: &str,
        category: Category,
        state: S,
        mut fold: F,
        finish: G,
    ) -> Plan
    where
        S: Send + 'static,
        F: FnMut(&mut S, T) -> anyhow::Result<()> + Send + 'static,
        G: FnOnce(S) -> anyhow::Result<PlanOutput> + Send + 'static,
    {
        let stage = name.to_string();
        let cell = Arc::new(Mutex::new(Some(state)));
        let fold_cell = Arc::clone(&cell);
        let sink_fn: SinkFn = Box::new(move |item| {
            let t = downcast::<T>(item, &stage)?;
            let mut guard = fold_cell.lock().unwrap();
            let s = guard.as_mut().expect("sink state taken before the run finished");
            fold(s, t)
        });
        let finish_fn: FinishFn = Box::new(move || {
            let s = cell.lock().unwrap().take().expect("plan finish ran twice");
            finish(s)
        });
        Plan {
            name: self.name,
            source: self.source,
            nodes: self.nodes,
            sink: (name.to_string(), category, sink_fn),
            finish: finish_fn,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn count_plan() -> Plan {
        Plan::source("test", "gen", Category::Pre, |emit| {
            for i in 0..10 {
                emit(i);
            }
        })
        .map("double", Category::Pre, |x: i32| Ok(x * 2))
        .flat_map("keep_even_quarters", Category::Ai, |x: i32| {
            Ok(if x % 4 == 0 { vec![x] } else { vec![] })
        })
        .sink(
            "collect",
            Category::Post,
            Vec::new(),
            |v: &mut Vec<i32>, x| {
                v.push(x);
                Ok(())
            },
            |v| {
                let mut metrics = BTreeMap::new();
                metrics.insert("sum".to_string(), v.iter().sum::<i32>() as f64);
                Ok(PlanOutput { metrics, items: v.len() })
            },
        )
    }

    #[test]
    fn stage_names_in_order() {
        let p = count_plan();
        assert_eq!(p.name(), "test");
        assert_eq!(p.stage_count(), 4);
        assert_eq!(
            p.stage_names(),
            vec!["gen", "double", "keep_even_quarters", "collect"]
        );
    }

    #[test]
    fn batch_node_registers() {
        let p = Plan::source("b", "src", Category::Pre, |emit| emit(1u32))
            .batch(
                "batcher",
                Category::Pre,
                BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) },
            )
            .sink(
                "out",
                Category::Post,
                0usize,
                |n: &mut usize, b: Vec<u32>| {
                    *n += b.len();
                    Ok(())
                },
                |n| Ok(PlanOutput { metrics: BTreeMap::new(), items: n }),
            );
        assert_eq!(p.stage_names(), vec!["src", "batcher", "out"]);
    }

    #[test]
    fn downcast_mismatch_is_descriptive() {
        let err = downcast::<String>(Box::new(5i32), "stagex").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("stagex"), "{msg}");
        assert!(msg.contains("String"), "{msg}");
    }

    #[test]
    fn sharder_partitions_are_disjoint_and_cover() {
        // Every emission index belongs to exactly one of the n shards.
        for of in 1..=5usize {
            for index in 0..40usize {
                let owners: Vec<usize> =
                    (0..of).filter(|&s| Sharder::new(s, of).owns(index)).collect();
                assert_eq!(owners, vec![index % of], "index {index} of {of}");
            }
        }
        assert_eq!(Sharder::new(1, 4).to_string(), "1/4");
        assert_eq!(Sharder::new(2, 3).shard(), 2);
        assert_eq!(Sharder::new(2, 3).of(), 3);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn sharder_rejects_out_of_range_index() {
        let _ = Sharder::new(3, 3);
    }

    #[test]
    fn plan_shard_filters_the_source_round_robin() {
        // 0..10 doubled → evens kept; shard 1 of 2 owns odd emission
        // indices 1,3,5,7,9 → doubled 2,6,10,14,18 → quarters filter
        // keeps those divisible by 4.
        let sharded = count_plan().shard(Sharder::new(1, 2));
        let out = crate::coordinator::exec::run_sequential(sharded).unwrap();
        // Owned emissions: 1,3,5,7,9 → doubled 2,6,10,14,18 → none % 4 == 0
        // except... 2,6,10,14,18 are ≡ 2 (mod 4), so the filter drops all.
        assert_eq!(out.output.items, 0);
        let shard0 = count_plan().shard(Sharder::new(0, 2));
        let out0 = crate::coordinator::exec::run_sequential(shard0).unwrap();
        // Owned emissions 0,2,4,6,8 → doubled 0,4,8,12,16 all kept.
        assert_eq!(out0.output.items, 5);
        assert_eq!(out0.output.metrics["sum"], 40.0);
    }

    #[test]
    fn resumable_batch_node_cuts_sequential_boundaries() {
        let group: GroupFn = Box::new(|items: Vec<DynItem>| Ok(Box::new(items.len()) as DynItem));
        let node = Node {
            name: "batch".to_string(),
            category: Category::Pre,
            kind: NodeKind::Batch(
                BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(1) },
                group,
            ),
        };
        let mut r = node.into_resumable();
        assert_eq!(r.name, "batch");
        assert_eq!(r.category, Category::Pre);
        let mut cuts: Vec<usize> = Vec::new();
        for i in 0..20u32 {
            let (outs, units) = r
                .push(Stamped { born: Instant::now(), item: Box::new(i) as DynItem })
                .unwrap();
            assert_eq!(outs.len(), units, "batch emits exactly its cut");
            for s in outs {
                cuts.push(*s.item.downcast::<usize>().unwrap());
            }
        }
        let (outs, units) = r.flush().unwrap();
        assert_eq!(units, 1, "remainder flushes as one short batch");
        for s in outs {
            cuts.push(*s.item.downcast::<usize>().unwrap());
        }
        // 20 items at max_batch 8 → 8/8/4: the sequential boundaries.
        assert_eq!(cuts, vec![8, 8, 4]);
        let (outs, units) = r.flush().unwrap();
        assert!(outs.is_empty(), "second flush buffers nothing");
        assert_eq!(units, 0);
    }

    #[test]
    fn resumable_flat_map_counts_one_unit_per_item() {
        let node = Node {
            name: "double".to_string(),
            category: Category::Ai,
            kind: NodeKind::FlatMap(Box::new(|item: DynItem| {
                let x = *item.downcast::<i32>().unwrap();
                Ok(vec![Box::new(x * 2) as DynItem])
            })),
        };
        let mut r = node.into_resumable();
        let (outs, units) =
            r.push(Stamped { born: Instant::now(), item: Box::new(21i32) as DynItem }).unwrap();
        assert_eq!(units, 1);
        assert_eq!(*outs.into_iter().next().unwrap().item.downcast::<i32>().unwrap(), 42);
        let (outs, units) = r.flush().unwrap();
        assert!(outs.is_empty());
        assert_eq!(units, 0);
    }

    #[test]
    fn shard_of_one_is_the_identity_partition() {
        let whole = crate::coordinator::exec::run_sequential(count_plan()).unwrap();
        let sharded =
            crate::coordinator::exec::run_sequential(count_plan().shard(Sharder::new(0, 1)))
                .unwrap();
        assert_eq!(whole.output.items, sharded.output.items);
        assert_eq!(whole.output.metrics, sharded.output.metrics);
    }
}
