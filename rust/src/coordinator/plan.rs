//! Plan IR — the declarative pipeline representation every workload
//! compiles down to.
//!
//! A [`Plan`] is a linear graph of named, [`Category`]-tagged stage nodes:
//! one **source** (produces items), any number of **map / flat-map**
//! transforms (1→1 / 1→0..n, so filters and expanders fit), optional
//! **batch** nodes (group items under a [`BatcherConfig`] policy — the
//! DLSA dynamic-batching serving path), and one **sink** that folds items
//! into a state from which [`PlanOutput`] metrics are computed.
//!
//! Plans say *what* the pipeline computes; the interchangeable executors
//! in [`super::exec`] decide *how*: in-thread sequential, thread-per-stage
//! streaming over bounded channels, N replicated instances (§3.4), or N
//! data-parallel shards over one dataset ([`Sharder`] /
//! `ExecMode::Sharded`). Because the plan is data, cross-cutting
//! optimizations (batching, scaling, sharding, telemetry) are implemented
//! once in an executor instead of being re-wired into every workload —
//! the tf.data / BigDL split between pipeline definition and execution
//! strategy.
//!
//! Typing: the builder ([`PlanBuilder`]) is statically typed stage to
//! stage; items are type-erased to `Box<dyn Any + Send>` internally so
//! heterogeneous plans share one executor implementation. A mismatch
//! (impossible via the typed builder) surfaces as a descriptive error,
//! not UB. A plan's closures are single-use: executors consume the plan,
//! and replication (multi-instance) re-invokes the plan-builder function.
//!
//! **Compile once, bind many** ([`CompiledPlan`]): a [`Plan`] is a
//! *bound* artifact — payload baked into its source closure, one
//! execution, gone. For serving, where one pipeline answers many
//! requests, the graph is instead compiled ONCE into a [`CompiledPlan`]
//! — a payload-free template set (source template, node templates with
//! batch policies and category tags, sink template, warm model-set
//! declaration) — and each request performs a cheap
//! [`CompiledPlan::bind`] to get the [`BoundPlan`] the executors run.
//! Sharded execution binds each shard to a pre-sliced payload
//! ([`CompiledPlan::bind_shard`] over a [`WorkloadSlice`]) so workers
//! stop materializing the full source stream just to drop the emissions
//! they do not own. Bind-vs-compile cost is tracked on the compiled
//! plan ([`CompiledPlan::bind_report`]) so the amortization is
//! observable from counters — the tf.data build-once/re-bind property
//! and BigDL's build-once/run-everywhere plan, in one type.
//!
//! **Columnar batch items**: items are opaque to the IR, so a batched
//! tabular pipeline moves whole [`ColumnBatch`] chunks (Arc-backed
//! zero-copy column views) through the same map/flat-map nodes instead
//! of one row-state per hop; a [`CompiledPlanBuilder::gather`] node
//! deterministically reassembles the chunk stream before the model
//! stages, and the plan's attached [`BatchLedger`]
//! ([`CompiledPlan::with_batch_ledger`]) counts batches, rows, and
//! clone-avoided bytes so amortization is asserted from ledgers, never
//! wall-clock.
//!
//! [`ColumnBatch`]: crate::dataframe::ColumnBatch

use super::batcher::BatcherConfig;
use super::telemetry::{BatchLedger, BatchReport, BindReport, Category, OptReport};
use std::any::Any;
use std::collections::BTreeMap;
use std::marker::PhantomData;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// A type-erased item flowing between stages.
pub type DynItem = Box<dyn Any + Send>;

pub(crate) type SourceFn = Box<dyn FnMut(&mut dyn FnMut(DynItem)) + Send>;
pub(crate) type StageFn = Box<dyn FnMut(DynItem) -> anyhow::Result<Vec<DynItem>> + Send>;
pub(crate) type GroupFn = Box<dyn FnMut(Vec<DynItem>) -> anyhow::Result<DynItem> + Send>;
pub(crate) type SinkFn = Box<dyn FnMut(DynItem) -> anyhow::Result<()> + Send>;
pub(crate) type FinishFn = Box<dyn FnOnce() -> anyhow::Result<PlanOutput> + Send>;

/// What a finished plan reports: deterministic metrics + item count.
/// (Per-stage timing comes from the executor's telemetry, not the plan.)
#[derive(Debug, Clone)]
pub struct PlanOutput {
    /// Named quality/throughput metrics (auc, r2, agreement, …).
    pub metrics: BTreeMap<String, f64>,
    /// Items processed end-to-end (rows, docs, frames, …).
    pub items: usize,
}

/// How a transform node rewrites the item stream.
pub(crate) enum NodeKind {
    /// 1 → 0..n items.
    FlatMap(StageFn),
    /// Group items into batches under a max-size / max-wait policy; the
    /// grouped batch flows downstream as a single item.
    Batch(BatcherConfig, GroupFn),
}

/// An in-flight item plus its source-emission instant; the stamp rides
/// along so the sink stage can record a true per-item end-to-end
/// latency. Batch nodes keep the earliest stamp of their members (a
/// batch is as old as its oldest item).
pub(crate) struct Stamped {
    pub(crate) born: Instant,
    pub(crate) item: DynItem,
}

/// How a transform node consumes items when it runs as a resumable
/// stage task (the async executor): flat-maps pass each item straight
/// through their closure; batch nodes buffer until `max_batch` items
/// and cut size-based batches. Every item of the one pass eventually
/// arrives — exactly the sequential executor's situation — so async
/// batch boundaries equal sequential ones, which is part of what keeps
/// the executor-conformance matrix green.
pub(crate) enum ResumableKind {
    FlatMap(StageFn),
    Batch { max_batch: usize, group: GroupFn, pending: Vec<Stamped> },
}

/// One transform node re-packaged as a resumable stage task: feed items
/// with `push` as they arrive, then `flush` once upstream is exhausted.
/// Both report how many work units (flat-map calls / batches cut) they
/// performed, so the caller records stage telemetry with the same item
/// counts as the sequential executor.
pub(crate) struct ResumableNode {
    pub(crate) name: String,
    pub(crate) category: Category,
    kind: ResumableKind,
}

impl ResumableNode {
    /// Feed one item; returns the outputs ready now plus the work units
    /// performed (0 when a batch node merely buffered).
    pub(crate) fn push(&mut self, s: Stamped) -> anyhow::Result<(Vec<Stamped>, usize)> {
        match &mut self.kind {
            ResumableKind::FlatMap(f) => {
                let Stamped { born, item } = s;
                let outs = f(item)?;
                Ok((outs.into_iter().map(|item| Stamped { born, item }).collect(), 1))
            }
            ResumableKind::Batch { max_batch, group, pending } => {
                pending.push(s);
                if pending.len() >= *max_batch {
                    let batch: Vec<Stamped> = pending.drain(..).collect();
                    Ok((vec![cut_batch(group, batch)?], 1))
                } else {
                    Ok((Vec::new(), 0))
                }
            }
        }
    }

    /// Upstream is exhausted: emit whatever the node still buffers (the
    /// final short batch). Flat-maps buffer nothing.
    pub(crate) fn flush(&mut self) -> anyhow::Result<(Vec<Stamped>, usize)> {
        match &mut self.kind {
            ResumableKind::FlatMap(_) => Ok((Vec::new(), 0)),
            ResumableKind::Batch { group, pending, .. } => {
                if pending.is_empty() {
                    return Ok((Vec::new(), 0));
                }
                let batch: Vec<Stamped> = pending.drain(..).collect();
                Ok((vec![cut_batch(group, batch)?], 1))
            }
        }
    }
}

/// Group a non-empty batch into one downstream item stamped with its
/// oldest member's birth.
fn cut_batch(group: &mut GroupFn, batch: Vec<Stamped>) -> anyhow::Result<Stamped> {
    let born = batch.iter().map(|s| s.born).min().expect("non-empty batch");
    let members: Vec<DynItem> = batch.into_iter().map(|s| s.item).collect();
    Ok(Stamped { born, item: group(members)? })
}

/// One transform node of a plan.
pub(crate) struct Node {
    pub(crate) name: String,
    pub(crate) category: Category,
    pub(crate) kind: NodeKind,
}

impl Node {
    /// Re-package this node for resumable (task-at-a-time) execution.
    /// `max_wait` is dropped for batch nodes: a resumable pass, like a
    /// sequential one, eventually sees every item, so batches flush on
    /// size (plus one final remainder flush) and the boundaries match
    /// the sequential executor's exactly.
    pub(crate) fn into_resumable(self) -> ResumableNode {
        let kind = match self.kind {
            NodeKind::FlatMap(f) => ResumableKind::FlatMap(f),
            NodeKind::Batch(cfg, group) => ResumableKind::Batch {
                max_batch: cfg.max_batch.max(1),
                group,
                pending: Vec::new(),
            },
        };
        ResumableNode { name: self.name, category: self.category, kind }
    }
}

/// A fully-built pipeline plan, ready for one execution. Every executor
/// runs these; [`CompiledPlan::bind`] is the cheap way to mint one per
/// request from a graph compiled once.
pub struct Plan {
    pub(crate) name: String,
    pub(crate) source: (String, Category, SourceFn),
    pub(crate) nodes: Vec<Node>,
    pub(crate) sink: (String, Category, SinkFn),
    pub(crate) finish: FinishFn,
}

impl Plan {
    /// Start a plan from a source closure that pushes typed items through
    /// `emit` and returns when the stream is exhausted.
    pub fn source<T, F>(
        pipeline: &str,
        stage: &str,
        category: Category,
        mut produce: F,
    ) -> PlanBuilder<T>
    where
        T: Send + 'static,
        F: FnMut(&mut dyn FnMut(T)) + Send + 'static,
    {
        let erased: SourceFn = Box::new(move |emit: &mut dyn FnMut(DynItem)| {
            let mut typed = |t: T| emit(Box::new(t) as DynItem);
            produce(&mut typed);
        });
        PlanBuilder {
            name: pipeline.to_string(),
            source: (stage.to_string(), category, erased),
            nodes: Vec::new(),
            _marker: PhantomData,
        }
    }

    /// Pipeline name.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Stage names in execution order (source, transforms, sink).
    pub fn stage_names(&self) -> Vec<String> {
        let mut names = vec![self.source.0.clone()];
        names.extend(self.nodes.iter().map(|n| n.name.clone()));
        names.push(self.sink.0.clone());
        names
    }

    /// Number of stages including source and sink.
    pub fn stage_count(&self) -> usize {
        self.nodes.len() + 2
    }
}

/// Deterministic round-robin partitioner over a plan source's emission
/// stream: emission `i` belongs to shard `i % of`. Partitions are
/// disjoint and cover the stream, and ownership depends only on the
/// emission index — never on thread timing — so a sharded run processes
/// exactly the dataset a sequential run would, split `of` ways.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Sharder {
    shard: usize,
    of: usize,
}

impl Sharder {
    /// Partition `shard` of `of` (0-based; `shard < of`, `of >= 1`).
    pub fn new(shard: usize, of: usize) -> Sharder {
        assert!(of >= 1, "sharding needs at least one shard");
        assert!(shard < of, "shard index {shard} out of range for {of} shards");
        Sharder { shard, of }
    }

    /// This partition's 0-based index.
    pub fn shard(&self) -> usize {
        self.shard
    }

    /// Total number of partitions.
    pub fn of(&self) -> usize {
        self.of
    }

    /// Whether source emission `index` belongs to this partition.
    pub fn owns(&self, index: usize) -> bool {
        index % self.of == self.shard
    }

    /// How many of `total` emissions this partition owns — explicit
    /// zeros included, so shard counts larger than the dataset still
    /// yield one (empty) partition per shard and the cover/balance
    /// invariants stay checkable.
    pub fn owned_count(&self, total: usize) -> usize {
        total / self.of + usize::from(self.shard < total % self.of)
    }

    /// The global emission index of this partition's `local`-th owned
    /// item (`shard + local·of`) — how a pre-sliced source reconstructs
    /// the indices a filtered full stream would have carried.
    pub fn global_index(&self, local: usize) -> usize {
        self.shard + local * self.of
    }

    /// The trivial whole-stream partition (shard 0 of 1).
    pub fn whole() -> Sharder {
        Sharder { shard: 0, of: 1 }
    }
}

impl std::fmt::Display for Sharder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}/{}", self.shard, self.of)
    }
}

impl Plan {
    /// Restrict this plan's source to the emissions `sharder` owns: the
    /// wrapped source produces the full stream, but only every
    /// `of`-th item (offset by the shard index) is forwarded downstream.
    /// Transform and sink stages are untouched — the sharded executor
    /// runs one such restricted plan per shard over the same stage graph
    /// and merges sink state in shard order.
    pub fn shard(mut self, sharder: Sharder) -> Plan {
        let (name, category, mut produce) = self.source;
        let filtered: SourceFn = Box::new(move |emit: &mut dyn FnMut(DynItem)| {
            let mut index = 0usize;
            produce(&mut |item| {
                if sharder.owns(index) {
                    emit(item);
                }
                index += 1;
            });
        });
        self.source = (name, category, filtered);
        self
    }
}

/// A plan ready to execute — the artifact [`CompiledPlan::bind`] mints
/// per request. Alias of [`Plan`]: binding is what turns the reusable
/// compiled graph into the single-use closures the executors consume.
pub type BoundPlan = Plan;

/// What a bind hands a source template: the payload (pre-sliced for
/// per-item plans under sharded execution, whole otherwise), the
/// partition it represents, and the per-bind seed. Sliced sources
/// reconstruct global emission indices via
/// [`WorkloadSlice::global_index`], so downstream stages see exactly
/// the indices a filtered full stream would have carried.
pub struct WorkloadSlice<P> {
    /// The (possibly pre-sliced) payload.
    pub payload: P,
    /// Which round-robin partition this slice is (`0/1` for a whole
    /// run).
    pub sharder: Sharder,
    /// Seed for this bind (multi-instance replicas bind at shifted
    /// seeds).
    pub seed: u64,
}

impl<P> WorkloadSlice<P> {
    /// Global emission index of the slice's `local`-th item.
    pub fn global_index(&self, local: usize) -> usize {
        self.sharder.global_index(local)
    }
}

/// How a compiled plan's source partitions under sharded execution.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Slicing {
    /// The source emits one state item (the tabular shape): only the
    /// shard owning emission 0 runs the source at all; every other
    /// shard gets an empty source without its template being invoked.
    SingleState,
    /// The source emits one item per payload element: each shard binds
    /// a round-robin [`WorkloadSlice`] of the payload and emits only
    /// its own items, with global indices reconstructed from the
    /// sharder — no shard materializes the stream it does not own.
    PerItem,
}

pub(crate) type SourceTemplateFn<P> =
    Box<dyn Fn(WorkloadSlice<P>) -> anyhow::Result<SourceFn> + Send + Sync>;
pub(crate) type StageTemplateFn = Box<dyn Fn(u64) -> StageFn + Send + Sync>;
pub(crate) type GroupTemplateFn = Box<dyn Fn(u64) -> GroupFn + Send + Sync>;
pub(crate) type SinkTemplateFn<P> =
    Box<dyn Fn(&P, u64) -> anyhow::Result<(SinkFn, FinishFn)> + Send + Sync>;

/// Optimizer-facing annotations on a compiled node: semantic facts the
/// builder can assert about a stage that its type-erased closure can no
/// longer reveal. `identity` marks a stage that forwards every item
/// unchanged (elidable); `pure_elementwise` marks a batch-level stage
/// that applies a pure per-element function to every member of a
/// `Vec<T>` batch; `per_item` carries the equivalent per-item template
/// for such a stage — the handle that lets
/// [`super::optimizer::optimize`] hoist the work across the upstream
/// batch boundary without inspecting closures.
#[derive(Default)]
pub(crate) struct StageHints {
    pub(crate) identity: bool,
    pub(crate) pure_elementwise: bool,
    pub(crate) per_item: Option<StageTemplateFn>,
}

/// One transform node of a compiled plan: everything a [`Node`] carries
/// except the single-use closure, which a factory re-mints per bind.
pub(crate) struct NodeTemplate {
    pub(crate) name: String,
    pub(crate) category: Category,
    pub(crate) kind: NodeTemplateKind,
    pub(crate) hints: StageHints,
}

pub(crate) enum NodeTemplateKind {
    FlatMap(StageTemplateFn),
    Batch(BatcherConfig, GroupTemplateFn),
}

impl NodeTemplate {
    fn instantiate(&self, seed: u64) -> Node {
        let kind = match &self.kind {
            NodeTemplateKind::FlatMap(make) => NodeKind::FlatMap(make(seed)),
            NodeTemplateKind::Batch(cfg, make) => NodeKind::Batch(*cfg, make(seed)),
        };
        Node { name: self.name.clone(), category: self.category, kind }
    }
}

/// A pipeline's stage graph, compiled once and bound to payloads many
/// times (see the module docs). `P` is the payload type a bind accepts
/// — the registry pipelines use their typed `Workload`. The compiled
/// plan is `Send + Sync`, so one instance serves concurrent binds from
/// a session shared across worker threads.
pub struct CompiledPlan<P: 'static> {
    name: String,
    slicing: Slicing,
    source: (String, Category, SourceTemplateFn<P>),
    pub(crate) nodes: Vec<NodeTemplate>,
    sink: (String, Category, SinkTemplateFn<P>),
    warm_models: Vec<String>,
    batch_ledger: Option<Arc<BatchLedger>>,
    compile_nanos: AtomicU64,
    binds: AtomicUsize,
    bind_nanos: AtomicU64,
    pub(crate) opt: Option<OptReport>,
}

impl<P: 'static> CompiledPlan<P> {
    /// Start a compiled plan from a source template: `make` is invoked
    /// once per bind with that bind's [`WorkloadSlice`] and returns the
    /// run's source closure (or a descriptive payload-mismatch error).
    pub fn source<T, MK, SRC>(
        pipeline: &str,
        stage: &str,
        category: Category,
        slicing: Slicing,
        make: MK,
    ) -> CompiledPlanBuilder<P, T>
    where
        T: Send + 'static,
        MK: Fn(WorkloadSlice<P>) -> anyhow::Result<SRC> + Send + Sync + 'static,
        SRC: FnMut(&mut dyn FnMut(T)) + Send + 'static,
    {
        let erased: SourceTemplateFn<P> = Box::new(move |slice| {
            let mut produce = make(slice)?;
            let src: SourceFn = Box::new(move |emit: &mut dyn FnMut(DynItem)| {
                let mut typed = |t: T| emit(Box::new(t) as DynItem);
                produce(&mut typed);
            });
            Ok(src)
        });
        CompiledPlanBuilder {
            name: pipeline.to_string(),
            slicing,
            source: (stage.to_string(), category, erased),
            nodes: Vec::new(),
            started: Instant::now(),
            _marker: PhantomData,
        }
    }

    /// Pipeline name.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// How this plan's source partitions under sharded execution.
    pub fn slicing(&self) -> Slicing {
        self.slicing
    }

    /// Stage names in execution order (source, transforms, sink).
    pub fn stage_names(&self) -> Vec<String> {
        let mut names = vec![self.source.0.clone()];
        names.extend(self.nodes.iter().map(|n| n.name.clone()));
        names.push(self.sink.0.clone());
        names
    }

    /// Number of stages including source and sink.
    pub fn stage_count(&self) -> usize {
        self.nodes.len() + 2
    }

    /// `(stage name, category, node kind)` specs for source, transforms,
    /// and sink in execution order — the EXPLAIN view of the graph.
    pub fn stage_specs(&self) -> Vec<(String, Category, &'static str)> {
        let mut specs = vec![(self.source.0.clone(), self.source.1, "source")];
        for n in &self.nodes {
            let kind = match n.kind {
                NodeTemplateKind::FlatMap(_) => "map",
                NodeTemplateKind::Batch(..) => "batch",
            };
            specs.push((n.name.clone(), n.category, kind));
        }
        specs.push((self.sink.0.clone(), self.sink.1, "sink"));
        specs
    }

    /// The optimization report attached by
    /// [`super::optimizer::optimize`]; `None` for a graph that still
    /// executes exactly as written.
    pub fn opt_report(&self) -> Option<&OptReport> {
        self.opt.as_ref()
    }

    /// Declare the model artifacts this plan's stages execute — the set
    /// a serving session warms once at open so binds never re-issue
    /// warm round-trips.
    pub fn declare_warm(mut self, models: &[&str]) -> Self {
        self.warm_models = models.iter().map(|m| m.to_string()).collect();
        self
    }

    /// The declared warm model set (empty for model-free pipelines).
    pub fn warm_models(&self) -> &[String] {
        &self.warm_models
    }

    /// Attach the [`BatchLedger`] this plan's batched stages record
    /// into. The compile step mints one ledger, clones the `Arc` into
    /// the stage templates that split/transform/gather column batches,
    /// and hangs the original here so executors can snapshot
    /// per-run deltas ([`Self::batch_report`]) without threading the
    /// ledger through every call site.
    pub fn with_batch_ledger(mut self, ledger: Arc<BatchLedger>) -> Self {
        self.batch_ledger = Some(ledger);
        self
    }

    /// Cumulative batch-plane counters for this plan (zeros when no
    /// ledger is attached, i.e. the plan runs per-item). Runs snapshot
    /// before and after, then diff with [`BatchReport::since`].
    pub fn batch_report(&self) -> BatchReport {
        self.batch_ledger.as_ref().map(|l| l.snapshot()).unwrap_or_default()
    }

    /// Fold front-loaded work (model warmup, payload-independent config
    /// derivation) into the recorded compile time; callers that time
    /// the whole `compile(cfg)` call overwrite the builder's own stamp
    /// with the full duration.
    pub fn set_compile_time(&self, d: Duration) {
        self.compile_nanos.store(d.as_nanos() as u64, Ordering::Relaxed);
    }

    /// Build-vs-bind accounting for this graph (compiles is always 1;
    /// aggregate across plans with [`BindReport::merge`]).
    pub fn bind_report(&self) -> BindReport {
        BindReport {
            compiles: 1,
            compile_time: Duration::from_nanos(self.compile_nanos.load(Ordering::Relaxed)),
            binds: self.binds.load(Ordering::Relaxed),
            bind_time: Duration::from_nanos(self.bind_nanos.load(Ordering::Relaxed)),
        }
    }

    fn record_bind(&self, d: Duration) {
        self.binds.fetch_add(1, Ordering::Relaxed);
        self.bind_nanos.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    fn assemble(
        &self,
        source: SourceFn,
        sink_fn: SinkFn,
        finish: FinishFn,
        seed: u64,
    ) -> BoundPlan {
        Plan {
            name: self.name.clone(),
            source: (self.source.0.clone(), self.source.1, source),
            nodes: self.nodes.iter().map(|n| n.instantiate(seed)).collect(),
            sink: (self.sink.0.clone(), self.sink.1, sink_fn),
            finish,
        }
    }

    /// Bind one payload for a whole (unsharded) run: instantiate fresh
    /// stage closures around it. No graph re-walk, no model warmup —
    /// the cost is counted into [`Self::bind_report`].
    pub fn bind(&self, payload: P, seed: u64) -> anyhow::Result<BoundPlan> {
        let t0 = Instant::now();
        let (sink_fn, finish) = (self.sink.2)(&payload, seed)?;
        let source =
            (self.source.2)(WorkloadSlice { payload, sharder: Sharder::whole(), seed })?;
        let plan = self.assemble(source, sink_fn, finish, seed);
        self.record_bind(t0.elapsed());
        Ok(plan)
    }

    /// Bind one shard's pass plan for data-parallel execution. `slice`
    /// is the shard's pre-sliced payload (the whole payload for
    /// [`Slicing::SingleState`] shard 0); `sink_payload` is the FULL
    /// payload, which binds shard 0's sink — the sharded executor folds
    /// every shard's output into shard 0's sink, and that sink must
    /// account for the whole dataset (item totals, per-index label
    /// tables), not one partition. The executor discards every other
    /// shard's sink unused, so shards > 0 carry an inert stub instead
    /// of paying the sink template (payload scans, label clones) n-1
    /// times per run; the stub errors loudly if a caller runs such a
    /// pass plan standalone. Non-owning shards of a single-state plan
    /// likewise get an empty source without their template being
    /// invoked, so "emit the state" templates never need their own
    /// ownership check.
    pub fn bind_shard(
        &self,
        slice: P,
        sharder: Sharder,
        sink_payload: &P,
        seed: u64,
    ) -> anyhow::Result<BoundPlan> {
        let t0 = Instant::now();
        let (sink_fn, finish) = if sharder.shard() == 0 {
            (self.sink.2)(sink_payload, seed)?
        } else {
            let name = self.name.clone();
            let sink: SinkFn = Box::new(move |_item| {
                Err(anyhow::anyhow!(
                    "plan `{name}`: a non-merge shard's sink must never fold \
                     (only shard 0's sink merges; run pass plans through the sharded executor)"
                ))
            });
            let name = self.name.clone();
            let finish_fn: FinishFn = Box::new(move || {
                Err(anyhow::anyhow!(
                    "plan `{name}`: a non-merge shard's sink must never finish \
                     (only shard 0's sink merges; run pass plans through the sharded executor)"
                ))
            });
            (sink, finish_fn)
        };
        let source: SourceFn =
            if matches!(self.slicing, Slicing::SingleState) && !sharder.owns(0) {
                Box::new(|_emit: &mut dyn FnMut(DynItem)| {})
            } else {
                (self.source.2)(WorkloadSlice { payload: slice, sharder, seed })?
            };
        let plan = self.assemble(source, sink_fn, finish, seed);
        self.record_bind(t0.elapsed());
        Ok(plan)
    }
}

/// Typed builder for a [`CompiledPlan`]; mirrors [`PlanBuilder`] with
/// per-stage factories in place of single-use closures. `T` is the item
/// type flowing out of the last appended stage.
pub struct CompiledPlanBuilder<P: 'static, T> {
    name: String,
    slicing: Slicing,
    source: (String, Category, SourceTemplateFn<P>),
    nodes: Vec<NodeTemplate>,
    started: Instant,
    _marker: PhantomData<fn(P) -> T>,
}

impl<P: 'static, T: Send + 'static> CompiledPlanBuilder<P, T> {
    fn push_node<O: Send + 'static>(mut self, node: NodeTemplate) -> CompiledPlanBuilder<P, O> {
        self.nodes.push(node);
        CompiledPlanBuilder {
            name: self.name,
            slicing: self.slicing,
            source: self.source,
            nodes: self.nodes,
            started: self.started,
            _marker: PhantomData,
        }
    }

    /// Append a 1→1 transform: `make(seed)` mints the stage closure per
    /// bind (per-bind state like lazy tokenizers lives in the closure).
    pub fn map<O, MK, F>(self, name: &str, category: Category, make: MK) -> CompiledPlanBuilder<P, O>
    where
        O: Send + 'static,
        MK: Fn(u64) -> F + Send + Sync + 'static,
        F: FnMut(T) -> anyhow::Result<O> + Send + 'static,
    {
        let stage = name.to_string();
        let tpl: StageTemplateFn = Box::new(move |seed| {
            let mut f = make(seed);
            let stage = stage.clone();
            Box::new(move |item: DynItem| {
                let t = downcast::<T>(item, &stage)?;
                Ok(vec![Box::new(f(t)?) as DynItem])
            })
        });
        self.push_node(NodeTemplate {
            name: name.to_string(),
            category,
            kind: NodeTemplateKind::FlatMap(tpl),
            hints: StageHints::default(),
        })
    }

    /// Mark the last appended stage as an identity transform (it
    /// forwards every item unchanged); the optimizer may elide it.
    /// The claim is the builder's to make — the erased closure cannot
    /// be inspected — and the conformance matrix pins that eliding a
    /// correctly-declared identity never changes metrics.
    pub fn hint_identity(mut self) -> Self {
        if let Some(node) = self.nodes.last_mut() {
            node.hints.identity = true;
        }
        self
    }

    /// Mark the last appended stage as a pure function of its input
    /// (no per-bind state, no side effects observable downstream).
    /// Purity is a precondition for hoisting rules.
    pub fn hint_pure(mut self) -> Self {
        if let Some(node) = self.nodes.last_mut() {
            node.hints.pure_elementwise = true;
        }
        self
    }

    /// Append a 1→0..n transform.
    pub fn flat_map<O, MK, F>(
        self,
        name: &str,
        category: Category,
        make: MK,
    ) -> CompiledPlanBuilder<P, O>
    where
        O: Send + 'static,
        MK: Fn(u64) -> F + Send + Sync + 'static,
        F: FnMut(T) -> anyhow::Result<Vec<O>> + Send + 'static,
    {
        let stage = name.to_string();
        let tpl: StageTemplateFn = Box::new(move |seed| {
            let mut f = make(seed);
            let stage = stage.clone();
            Box::new(move |item: DynItem| {
                let t = downcast::<T>(item, &stage)?;
                Ok(f(t)?.into_iter().map(|o| Box::new(o) as DynItem).collect())
            })
        });
        self.push_node(NodeTemplate {
            name: name.to_string(),
            category,
            kind: NodeTemplateKind::FlatMap(tpl),
            hints: StageHints::default(),
        })
    }

    /// Append a 1→0..1 transform — the reassembly point of the batch
    /// data plane. A gather stage buffers indexed chunks and emits one
    /// combined item once every chunk of a group has arrived, as a pure
    /// function of the items themselves (each chunk carries its
    /// `index`/`total`). That determinism is the reason dataset
    /// reassembly is a gather map and **not** a [`Self::batch`] node:
    /// a dynamic batcher's cut points depend on arrival timing
    /// (`max_wait` flushes), so its groups differ across executors,
    /// while a gather stage regroups identically everywhere — which is
    /// what keeps batched metrics bit-identical across the executor
    /// ladder.
    pub fn gather<O, MK, F>(
        self,
        name: &str,
        category: Category,
        make: MK,
    ) -> CompiledPlanBuilder<P, O>
    where
        O: Send + 'static,
        MK: Fn(u64) -> F + Send + Sync + 'static,
        F: FnMut(T) -> anyhow::Result<Option<O>> + Send + 'static,
    {
        let stage = name.to_string();
        let tpl: StageTemplateFn = Box::new(move |seed| {
            let mut f = make(seed);
            let stage = stage.clone();
            Box::new(move |item: DynItem| {
                let t = downcast::<T>(item, &stage)?;
                Ok(f(t)?.into_iter().map(|o| Box::new(o) as DynItem).collect())
            })
        });
        self.push_node(NodeTemplate {
            name: name.to_string(),
            category,
            kind: NodeTemplateKind::FlatMap(tpl),
            hints: StageHints::default(),
        })
    }

    /// Append a dynamic-batching node under `cfg` (the policy is part of
    /// the compiled graph; the grouping closure is re-minted per bind).
    pub fn batch(
        self,
        name: &str,
        category: Category,
        cfg: BatcherConfig,
    ) -> CompiledPlanBuilder<P, Vec<T>> {
        let stage = name.to_string();
        let tpl: GroupTemplateFn = Box::new(move |_seed| {
            let stage = stage.clone();
            Box::new(move |items: Vec<DynItem>| {
                let mut out: Vec<T> = Vec::with_capacity(items.len());
                for item in items {
                    out.push(downcast::<T>(item, &stage)?);
                }
                Ok(Box::new(out) as DynItem)
            })
        });
        self.push_node(NodeTemplate {
            name: name.to_string(),
            category,
            kind: NodeTemplateKind::Batch(cfg, tpl),
            hints: StageHints::default(),
        })
    }

    /// Terminate with a sink template: `make(payload, seed)` returns
    /// the per-bind (state, fold, finish) triple. The payload reference
    /// is the bind's FULL payload even for shard binds, so finish steps
    /// that report dataset totals or index into per-item tables stay
    /// correct under the merge-aware sink contract.
    pub fn sink<S, F, G, MK>(self, name: &str, category: Category, make: MK) -> CompiledPlan<P>
    where
        S: Send + 'static,
        F: FnMut(&mut S, T) -> anyhow::Result<()> + Send + 'static,
        G: FnOnce(S) -> anyhow::Result<PlanOutput> + Send + 'static,
        MK: Fn(&P, u64) -> anyhow::Result<(S, F, G)> + Send + Sync + 'static,
    {
        let stage = name.to_string();
        let tpl: SinkTemplateFn<P> = Box::new(move |payload, seed| {
            let (state, mut fold, finish) = make(payload, seed)?;
            let stage = stage.clone();
            let cell = Arc::new(Mutex::new(Some(state)));
            let fold_cell = Arc::clone(&cell);
            let sink_fn: SinkFn = Box::new(move |item| {
                let t = downcast::<T>(item, &stage)?;
                let mut guard = fold_cell.lock().unwrap();
                let s = guard.as_mut().expect("sink state taken before the run finished");
                fold(s, t)
            });
            let finish_fn: FinishFn = Box::new(move || {
                let s = cell.lock().unwrap().take().expect("plan finish ran twice");
                finish(s)
            });
            Ok((sink_fn, finish_fn))
        });
        let compile_nanos = self.started.elapsed().as_nanos() as u64;
        CompiledPlan {
            name: self.name,
            slicing: self.slicing,
            source: self.source,
            nodes: self.nodes,
            sink: (name.to_string(), category, tpl),
            warm_models: Vec::new(),
            batch_ledger: None,
            compile_nanos: AtomicU64::new(compile_nanos),
            binds: AtomicUsize::new(0),
            bind_nanos: AtomicU64::new(0),
            opt: None,
        }
    }
}

impl<P: 'static, T: Send + 'static> CompiledPlanBuilder<P, Vec<T>> {
    /// Append a pure per-element 1→1 transform over batched items:
    /// `Vec<T>` → `Vec<T>`, applying `make(seed)` to every element in
    /// order. Because the builder still knows the element type here, it
    /// also records the equivalent per-item template in the node's
    /// [`StageHints`] — which is what allows
    /// [`super::optimizer::optimize`] to hoist the work in front of the
    /// upstream batch node: batch cuts are count-based (`max_batch`
    /// plus one remainder flush), so the sink sees identical values in
    /// identical order whether elements are transformed before or after
    /// grouping.
    pub fn map_each<MK, F>(
        self,
        name: &str,
        category: Category,
        make: MK,
    ) -> CompiledPlanBuilder<P, Vec<T>>
    where
        MK: Fn(u64) -> F + Send + Sync + Clone + 'static,
        F: FnMut(T) -> anyhow::Result<T> + Send + 'static,
    {
        let stage = name.to_string();
        let make_batch = make.clone();
        let batch_tpl: StageTemplateFn = Box::new(move |seed| {
            let mut f = make_batch(seed);
            let stage = stage.clone();
            Box::new(move |item: DynItem| {
                let batch = downcast::<Vec<T>>(item, &stage)?;
                let mut out: Vec<T> = Vec::with_capacity(batch.len());
                for t in batch {
                    out.push(f(t)?);
                }
                Ok(vec![Box::new(out) as DynItem])
            })
        });
        let stage = name.to_string();
        let item_tpl: StageTemplateFn = Box::new(move |seed| {
            let mut f = make(seed);
            let stage = stage.clone();
            Box::new(move |item: DynItem| {
                let t = downcast::<T>(item, &stage)?;
                Ok(vec![Box::new(f(t)?) as DynItem])
            })
        });
        self.push_node(NodeTemplate {
            name: name.to_string(),
            category,
            kind: NodeTemplateKind::FlatMap(batch_tpl),
            hints: StageHints {
                identity: false,
                pure_elementwise: true,
                per_item: Some(item_tpl),
            },
        })
    }
}

fn downcast<T: 'static>(item: DynItem, stage: &str) -> anyhow::Result<T> {
    match item.downcast::<T>() {
        Ok(boxed) => Ok(*boxed),
        Err(_) => Err(anyhow::anyhow!(
            "plan type mismatch at stage `{stage}`: expected {}",
            std::any::type_name::<T>()
        )),
    }
}

/// Typed builder for a [`Plan`]; `T` is the item type flowing out of the
/// last appended stage.
pub struct PlanBuilder<T> {
    name: String,
    source: (String, Category, SourceFn),
    nodes: Vec<Node>,
    _marker: PhantomData<fn() -> T>,
}

impl<T: Send + 'static> PlanBuilder<T> {
    fn push_node<O: Send + 'static>(mut self, node: Node) -> PlanBuilder<O> {
        self.nodes.push(node);
        PlanBuilder {
            name: self.name,
            source: self.source,
            nodes: self.nodes,
            _marker: PhantomData,
        }
    }

    /// Append a 1→1 transform.
    pub fn map<O, F>(self, name: &str, category: Category, mut f: F) -> PlanBuilder<O>
    where
        O: Send + 'static,
        F: FnMut(T) -> anyhow::Result<O> + Send + 'static,
    {
        let stage = name.to_string();
        let erased: StageFn = Box::new(move |item| {
            let t = downcast::<T>(item, &stage)?;
            Ok(vec![Box::new(f(t)?) as DynItem])
        });
        self.push_node(Node {
            name: name.to_string(),
            category,
            kind: NodeKind::FlatMap(erased),
        })
    }

    /// Append a 1→0..n transform (filters, expanders, batch unpackers).
    pub fn flat_map<O, F>(self, name: &str, category: Category, mut f: F) -> PlanBuilder<O>
    where
        O: Send + 'static,
        F: FnMut(T) -> anyhow::Result<Vec<O>> + Send + 'static,
    {
        let stage = name.to_string();
        let erased: StageFn = Box::new(move |item| {
            let t = downcast::<T>(item, &stage)?;
            Ok(f(t)?.into_iter().map(|o| Box::new(o) as DynItem).collect())
        });
        self.push_node(Node {
            name: name.to_string(),
            category,
            kind: NodeKind::FlatMap(erased),
        })
    }

    /// Append a dynamic-batching node: downstream stages receive
    /// `Vec<T>` batches. Under the streaming executor batches flush on
    /// `max_batch` items *or* `max_wait` elapsed (the serving trade-off);
    /// under the sequential executor all items are already available, so
    /// batches flush on size alone.
    pub fn batch(self, name: &str, category: Category, cfg: BatcherConfig) -> PlanBuilder<Vec<T>> {
        let stage = name.to_string();
        let group: GroupFn = Box::new(move |items: Vec<DynItem>| {
            let mut out: Vec<T> = Vec::with_capacity(items.len());
            for item in items {
                out.push(downcast::<T>(item, &stage)?);
            }
            Ok(Box::new(out) as DynItem)
        });
        self.push_node(Node {
            name: name.to_string(),
            category,
            kind: NodeKind::Batch(cfg, group),
        })
    }

    /// Terminate the plan with a sink fold plus a finish step that turns
    /// the folded state into the plan's [`PlanOutput`]. The fold runs per
    /// item inside the timed sink stage; `finish` runs once, untimed,
    /// after the stream drains (offline audits belong there).
    pub fn sink<S, F, G>(
        self,
        name: &str,
        category: Category,
        state: S,
        mut fold: F,
        finish: G,
    ) -> Plan
    where
        S: Send + 'static,
        F: FnMut(&mut S, T) -> anyhow::Result<()> + Send + 'static,
        G: FnOnce(S) -> anyhow::Result<PlanOutput> + Send + 'static,
    {
        let stage = name.to_string();
        let cell = Arc::new(Mutex::new(Some(state)));
        let fold_cell = Arc::clone(&cell);
        let sink_fn: SinkFn = Box::new(move |item| {
            let t = downcast::<T>(item, &stage)?;
            let mut guard = fold_cell.lock().unwrap();
            let s = guard.as_mut().expect("sink state taken before the run finished");
            fold(s, t)
        });
        let finish_fn: FinishFn = Box::new(move || {
            let s = cell.lock().unwrap().take().expect("plan finish ran twice");
            finish(s)
        });
        Plan {
            name: self.name,
            source: self.source,
            nodes: self.nodes,
            sink: (name.to_string(), category, sink_fn),
            finish: finish_fn,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn count_plan() -> Plan {
        Plan::source("test", "gen", Category::Pre, |emit| {
            for i in 0..10 {
                emit(i);
            }
        })
        .map("double", Category::Pre, |x: i32| Ok(x * 2))
        .flat_map("keep_even_quarters", Category::Ai, |x: i32| {
            Ok(if x % 4 == 0 { vec![x] } else { vec![] })
        })
        .sink(
            "collect",
            Category::Post,
            Vec::new(),
            |v: &mut Vec<i32>, x| {
                v.push(x);
                Ok(())
            },
            |v| {
                let mut metrics = BTreeMap::new();
                metrics.insert("sum".to_string(), v.iter().sum::<i32>() as f64);
                Ok(PlanOutput { metrics, items: v.len() })
            },
        )
    }

    #[test]
    fn stage_names_in_order() {
        let p = count_plan();
        assert_eq!(p.name(), "test");
        assert_eq!(p.stage_count(), 4);
        assert_eq!(
            p.stage_names(),
            vec!["gen", "double", "keep_even_quarters", "collect"]
        );
    }

    #[test]
    fn batch_node_registers() {
        let p = Plan::source("b", "src", Category::Pre, |emit| emit(1u32))
            .batch(
                "batcher",
                Category::Pre,
                BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) },
            )
            .sink(
                "out",
                Category::Post,
                0usize,
                |n: &mut usize, b: Vec<u32>| {
                    *n += b.len();
                    Ok(())
                },
                |n| Ok(PlanOutput { metrics: BTreeMap::new(), items: n }),
            );
        assert_eq!(p.stage_names(), vec!["src", "batcher", "out"]);
    }

    #[test]
    fn downcast_mismatch_is_descriptive() {
        let err = downcast::<String>(Box::new(5i32), "stagex").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("stagex"), "{msg}");
        assert!(msg.contains("String"), "{msg}");
    }

    #[test]
    fn sharder_partitions_are_disjoint_and_cover() {
        // Every emission index belongs to exactly one of the n shards.
        for of in 1..=5usize {
            for index in 0..40usize {
                let owners: Vec<usize> =
                    (0..of).filter(|&s| Sharder::new(s, of).owns(index)).collect();
                assert_eq!(owners, vec![index % of], "index {index} of {of}");
            }
        }
        assert_eq!(Sharder::new(1, 4).to_string(), "1/4");
        assert_eq!(Sharder::new(2, 3).shard(), 2);
        assert_eq!(Sharder::new(2, 3).of(), 3);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn sharder_rejects_out_of_range_index() {
        let _ = Sharder::new(3, 3);
    }

    #[test]
    fn plan_shard_filters_the_source_round_robin() {
        // 0..10 doubled → evens kept; shard 1 of 2 owns odd emission
        // indices 1,3,5,7,9 → doubled 2,6,10,14,18 → quarters filter
        // keeps those divisible by 4.
        let sharded = count_plan().shard(Sharder::new(1, 2));
        let out = crate::coordinator::exec::run_sequential(sharded).unwrap();
        // Owned emissions: 1,3,5,7,9 → doubled 2,6,10,14,18 → none % 4 == 0
        // except... 2,6,10,14,18 are ≡ 2 (mod 4), so the filter drops all.
        assert_eq!(out.output.items, 0);
        let shard0 = count_plan().shard(Sharder::new(0, 2));
        let out0 = crate::coordinator::exec::run_sequential(shard0).unwrap();
        // Owned emissions 0,2,4,6,8 → doubled 0,4,8,12,16 all kept.
        assert_eq!(out0.output.items, 5);
        assert_eq!(out0.output.metrics["sum"], 40.0);
    }

    #[test]
    fn resumable_batch_node_cuts_sequential_boundaries() {
        let group: GroupFn = Box::new(|items: Vec<DynItem>| Ok(Box::new(items.len()) as DynItem));
        let node = Node {
            name: "batch".to_string(),
            category: Category::Pre,
            kind: NodeKind::Batch(
                BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(1) },
                group,
            ),
        };
        let mut r = node.into_resumable();
        assert_eq!(r.name, "batch");
        assert_eq!(r.category, Category::Pre);
        let mut cuts: Vec<usize> = Vec::new();
        for i in 0..20u32 {
            let (outs, units) = r
                .push(Stamped { born: Instant::now(), item: Box::new(i) as DynItem })
                .unwrap();
            assert_eq!(outs.len(), units, "batch emits exactly its cut");
            for s in outs {
                cuts.push(*s.item.downcast::<usize>().unwrap());
            }
        }
        let (outs, units) = r.flush().unwrap();
        assert_eq!(units, 1, "remainder flushes as one short batch");
        for s in outs {
            cuts.push(*s.item.downcast::<usize>().unwrap());
        }
        // 20 items at max_batch 8 → 8/8/4: the sequential boundaries.
        assert_eq!(cuts, vec![8, 8, 4]);
        let (outs, units) = r.flush().unwrap();
        assert!(outs.is_empty(), "second flush buffers nothing");
        assert_eq!(units, 0);
    }

    #[test]
    fn resumable_flat_map_counts_one_unit_per_item() {
        let node = Node {
            name: "double".to_string(),
            category: Category::Ai,
            kind: NodeKind::FlatMap(Box::new(|item: DynItem| {
                let x = *item.downcast::<i32>().unwrap();
                Ok(vec![Box::new(x * 2) as DynItem])
            })),
        };
        let mut r = node.into_resumable();
        let (outs, units) =
            r.push(Stamped { born: Instant::now(), item: Box::new(21i32) as DynItem }).unwrap();
        assert_eq!(units, 1);
        assert_eq!(*outs.into_iter().next().unwrap().item.downcast::<i32>().unwrap(), 42);
        let (outs, units) = r.flush().unwrap();
        assert!(outs.is_empty());
        assert_eq!(units, 0);
    }

    /// A compiled per-item plan over `Vec<i32>`: sums the payload after
    /// doubling, with emission indices threaded so the fold order is
    /// observable. The generic-payload analogue of the registry's
    /// per-item pipelines.
    fn compiled_sum_plan() -> CompiledPlan<Vec<i32>> {
        CompiledPlan::source(
            "csum",
            "gen",
            Category::Pre,
            Slicing::PerItem,
            |slice: WorkloadSlice<Vec<i32>>| {
                let items: Vec<(usize, i32)> = slice
                    .payload
                    .iter()
                    .enumerate()
                    .map(|(j, &v)| (slice.global_index(j), v))
                    .collect();
                let mut feed = Some(items);
                Ok(move |emit: &mut dyn FnMut((usize, i32))| {
                    for item in feed.take().into_iter().flatten() {
                        emit(item);
                    }
                })
            },
        )
        .map("double", Category::Ai, |_seed| |(i, v): (usize, i32)| Ok((i, v * 2)))
        .sink(
            "sum",
            Category::Post,
            |payload: &Vec<i32>, _seed| {
                let total_items = payload.len();
                Ok((
                    (0i64, 0i64),
                    |(sum, hash): &mut (i64, i64), (i, v): (usize, i32)| {
                        *sum += v as i64;
                        // Order-sensitive fold so sharded merge order is
                        // pinned by the metric, not just the sum.
                        *hash = hash.wrapping_mul(31).wrapping_add(i as i64);
                        Ok(())
                    },
                    move |(sum, hash)| {
                        let mut metrics = BTreeMap::new();
                        metrics.insert("sum".to_string(), sum as f64);
                        metrics.insert("hash".to_string(), hash as f64);
                        Ok(PlanOutput { metrics, items: total_items })
                    },
                ))
            },
        )
    }

    /// Round-robin slice of a `Vec<i32>` payload (test analogue of
    /// `Workload::slice`).
    fn slice_vec(payload: &[i32], shard: usize, of: usize) -> Vec<i32> {
        payload
            .iter()
            .enumerate()
            .filter(|(i, _)| Sharder::new(shard, of).owns(*i))
            .map(|(_, &v)| v)
            .collect()
    }

    #[test]
    fn compiled_plan_binds_and_reuses_deterministically() {
        let compiled = compiled_sum_plan();
        assert_eq!(compiled.name(), "csum");
        assert_eq!(compiled.stage_count(), 3);
        assert_eq!(compiled.stage_names(), vec!["gen", "double", "sum"]);
        assert_eq!(compiled.slicing(), Slicing::PerItem);
        let payload: Vec<i32> = (0..20).collect();
        // One compile, three binds: identical metrics every time, and
        // the bind report counts exactly what happened.
        let mut outputs = Vec::new();
        for _ in 0..3 {
            let out = crate::coordinator::exec::run_sequential(
                compiled.bind(payload.clone(), 7).unwrap(),
            )
            .unwrap();
            outputs.push((out.output.metrics, out.output.items));
        }
        assert_eq!(outputs[0], outputs[1]);
        assert_eq!(outputs[1], outputs[2]);
        assert_eq!(outputs[0].1, 20);
        let br = compiled.bind_report();
        assert_eq!(br.compiles, 1);
        assert_eq!(br.binds, 3);
        assert_eq!(br.rebuilds_avoided(), 2);
    }

    #[test]
    fn compiled_bind_shard_slices_match_clone_based_filtering() {
        // The tentpole equivalence at the plan layer: a full sharded
        // run over pre-sliced binds produces exactly the metrics —
        // index-hash included, so the per-shard streams and the merge
        // order are pinned, not just the totals — that cloning the
        // full payload and filtering by emission index does.
        let compiled = compiled_sum_plan();
        let payload: Vec<i32> = (0..23).map(|v| v * 3 + 1).collect();
        // Shard 0 carries the real (merge) sink, so its pass plan also
        // runs standalone and must equal a whole-payload bind filtered
        // to partition 0.
        let sliced0 = compiled
            .bind_shard(slice_vec(&payload, 0, 2), Sharder::new(0, 2), &payload, 7)
            .unwrap();
        let cloned0 = compiled.bind(payload.clone(), 7).unwrap().shard(Sharder::new(0, 2));
        let a = crate::coordinator::exec::run_sequential(sliced0).unwrap();
        let b = crate::coordinator::exec::run_sequential(cloned0).unwrap();
        assert_eq!(a.report.stages[0].items, Sharder::new(0, 2).owned_count(payload.len()));
        assert_eq!(a.output.metrics, b.output.metrics);
        for of in 1..=4usize {
            let sliced = crate::coordinator::exec::run_sharded(of, |s| {
                compiled.bind_shard(
                    slice_vec(&payload, s, of),
                    Sharder::new(s, of),
                    &payload,
                    7,
                )
            })
            .unwrap();
            let cloned = crate::coordinator::exec::run_sharded(of, |s| {
                compiled.bind(payload.clone(), 7).map(|p| p.shard(Sharder::new(s, of)))
            })
            .unwrap();
            assert_eq!(sliced.output.metrics, cloned.output.metrics, "of={of}");
            assert_eq!(sliced.output.items, cloned.output.items, "of={of}");
            let sharding = sliced.sharding.expect("sharded run reports partitions");
            for sh in &sharding.shards {
                assert_eq!(
                    sh.owned,
                    Sharder::new(sh.shard, of).owned_count(payload.len()),
                    "of={of} shard {}",
                    sh.shard
                );
            }
        }
    }

    #[test]
    fn compiled_bind_shard_yields_explicit_empty_shards() {
        // More shards than items: the tail shards own nothing but still
        // bind, run, and report zero — never silently skipped.
        let compiled = compiled_sum_plan();
        let payload: Vec<i32> = vec![5, 9];
        let out = crate::coordinator::exec::run_sharded(4, |s| {
            compiled.bind_shard(slice_vec(&payload, s, 4), Sharder::new(s, 4), &payload, 7)
        })
        .unwrap();
        assert_eq!(out.output.items, 2);
        let sharding = out.sharding.expect("sharded run reports partitions");
        assert_eq!(sharding.shard_count(), 4, "empty shards stay explicit");
        assert_eq!(sharding.total_owned(), 2);
        for sh in &sharding.shards {
            assert_eq!(sh.owned, Sharder::new(sh.shard, 4).owned_count(2), "{}", sh.shard);
            if sh.shard >= 2 {
                assert_eq!(sh.owned, 0, "{}", sh.shard);
            }
        }
    }

    #[test]
    fn non_merge_shard_sinks_error_loudly_when_misused() {
        // Shards > 0 get an inert sink (the sharded executor discards
        // it): running such a pass plan standalone must fail with a
        // descriptive error, never fold into a half-bound sink.
        let compiled = compiled_sum_plan();
        let payload: Vec<i32> = (0..8).collect();
        let plan = compiled
            .bind_shard(slice_vec(&payload, 1, 2), Sharder::new(1, 2), &payload, 7)
            .unwrap();
        let err = crate::coordinator::exec::run_sequential(plan).unwrap_err().to_string();
        assert!(err.contains("non-merge shard"), "{err}");
        assert!(err.contains("csum"), "{err}");
    }

    #[test]
    fn single_state_bind_shard_skips_non_owning_sources() {
        // A SingleState compiled plan whose source template would panic
        // if invoked for a non-owning shard: bind_shard must install an
        // empty source instead of calling it.
        let compiled = CompiledPlan::source(
            "one",
            "gen",
            Category::Pre,
            Slicing::SingleState,
            |slice: WorkloadSlice<i64>| {
                assert!(
                    slice.sharder.owns(0),
                    "source template invoked for a non-owning shard"
                );
                let mut state = Some(slice.payload);
                Ok(move |emit: &mut dyn FnMut(i64)| {
                    if let Some(v) = state.take() {
                        emit(v);
                    }
                })
            },
        )
        .sink(
            "out",
            Category::Post,
            |_payload: &i64, _seed| {
                Ok((
                    0i64,
                    |acc: &mut i64, v: i64| {
                        *acc += v;
                        Ok(())
                    },
                    |acc| {
                        let mut metrics = BTreeMap::new();
                        metrics.insert("sum".to_string(), acc as f64);
                        Ok(PlanOutput { metrics, items: 1 })
                    },
                ))
            },
        );
        assert_eq!(compiled.slicing(), Slicing::SingleState);
        // Shards 1..3: binding succeeds WITHOUT invoking the source
        // template (the assert inside it would fire here otherwise).
        for shard in 1..3usize {
            let plan = compiled.bind_shard(42, Sharder::new(shard, 3), &42, 0).unwrap();
            assert_eq!(plan.stage_names(), vec!["gen", "out"], "{shard}");
        }
        // Shard 0 owns the state and carries the real sink.
        let plan = compiled.bind_shard(42, Sharder::new(0, 3), &42, 0).unwrap();
        let out = crate::coordinator::exec::run_sequential(plan).unwrap();
        assert_eq!(out.report.stages[0].items, 1);
        assert_eq!(out.output.metrics["sum"], 42.0);
        // The full sharded run reproduces the whole answer.
        let sharded = crate::coordinator::exec::run_sharded(3, |s| {
            compiled.bind_shard(42, Sharder::new(s, 3), &42, 0)
        })
        .unwrap();
        assert_eq!(sharded.output.metrics["sum"], 42.0);
    }

    #[test]
    fn compiled_plan_declares_its_warm_models() {
        let compiled = compiled_sum_plan().declare_warm(&["model_a", "model_b"]);
        assert_eq!(compiled.warm_models(), ["model_a", "model_b"]);
        assert!(compiled_sum_plan().warm_models().is_empty());
    }

    #[test]
    fn sharder_owned_count_and_global_index_agree_with_owns() {
        for of in 1..=5usize {
            for total in 0..13usize {
                let mut covered = 0usize;
                for shard in 0..of {
                    let s = Sharder::new(shard, of);
                    let owned = s.owned_count(total);
                    covered += owned;
                    // global_index enumerates exactly the owned indices.
                    for local in 0..owned {
                        let g = s.global_index(local);
                        assert!(g < total, "{shard}/{of} local {local}");
                        assert!(s.owns(g), "{shard}/{of} local {local}");
                    }
                }
                assert_eq!(covered, total, "partition must cover 0..{total} of {of}");
            }
        }
        assert_eq!(Sharder::whole(), Sharder::new(0, 1));
    }

    #[test]
    fn shard_of_one_is_the_identity_partition() {
        let whole = crate::coordinator::exec::run_sequential(count_plan()).unwrap();
        let sharded =
            crate::coordinator::exec::run_sequential(count_plan().shard(Sharder::new(0, 1)))
                .unwrap();
        assert_eq!(whole.output.items, sharded.output.items);
        assert_eq!(whole.output.metrics, sharded.output.metrics);
    }
}
