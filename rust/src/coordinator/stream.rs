//! Streaming pipeline runner — the video/serving workloads' shape.
//!
//! Each stage runs on its own thread; stages are connected by bounded
//! channels so a slow stage backpressures everything upstream (the
//! paper's pipelines are throughput-bound, and unbounded queues would
//! hide that and blow memory). Stage workers record busy time into the
//! shared [`Telemetry`], producing the same Figure 1 breakdown as the
//! sequential runner.
//!
//! Typing: stages transform `I → Vec<O>` (0..n outputs per input, so
//! filters and batchers fit). The builder is a simple typed chain.

use super::telemetry::{Category, Report, Telemetry};
use crate::parallel::channel::{bounded, Receiver};
use std::thread::JoinHandle;

/// A running streaming pipeline typed by its current tail type `T`.
pub struct StreamPipeline<T: Send + 'static> {
    telemetry: Telemetry,
    tail: Receiver<T>,
    workers: Vec<JoinHandle<()>>,
    queue_cap: usize,
}

impl<T: Send + 'static> StreamPipeline<T> {
    /// Start a pipeline from a source closure that pushes items and
    /// returns when done. `queue_cap` bounds every inter-stage queue.
    pub fn source(
        name: &str,
        queue_cap: usize,
        mut produce: impl FnMut(&mut dyn FnMut(T)) + Send + 'static,
    ) -> StreamPipeline<T> {
        let telemetry = Telemetry::new();
        let handle = telemetry.stage(name, Category::Pre);
        let (tx, rx) = bounded(queue_cap.max(1));
        let worker = std::thread::Builder::new()
            .name(format!("repro-src-{name}"))
            .spawn(move || {
                // Busy time = wall time minus time blocked inside send():
                // send-blocking is backpressure (the downstream stage's
                // cost), not production work — counting it would smear the
                // slowest stage's time over the source in the Figure 1
                // breakdown.
                let t0 = std::time::Instant::now();
                let mut blocked = std::time::Duration::ZERO;
                let mut count = 0usize;
                let mut emit = |item: T| {
                    count += 1;
                    let s0 = std::time::Instant::now();
                    let _ = tx.send(item);
                    blocked += s0.elapsed();
                };
                produce(&mut emit);
                handle.record(t0.elapsed().saturating_sub(blocked), count);
            })
            .expect("spawn source");
        StreamPipeline { telemetry, tail: rx, workers: vec![worker], queue_cap }
    }

    /// Append a transforming stage (`I → 0..n` outputs).
    pub fn stage<O: Send + 'static>(
        mut self,
        name: &str,
        category: Category,
        mut f: impl FnMut(T) -> Vec<O> + Send + 'static,
    ) -> StreamPipeline<O> {
        let handle = self.telemetry.stage(name, category);
        let (tx, rx) = bounded(self.queue_cap);
        let upstream = self.tail;
        let worker = std::thread::Builder::new()
            .name(format!("repro-stage-{name}"))
            .spawn(move || {
                while let Ok(item) = upstream.recv() {
                    let t0 = std::time::Instant::now();
                    let outs = f(item);
                    handle.record(t0.elapsed(), 1);
                    for o in outs {
                        if tx.send(o).is_err() {
                            return; // downstream gone
                        }
                    }
                }
            })
            .expect("spawn stage");
        self.workers.push(worker);
        StreamPipeline {
            telemetry: self.telemetry,
            tail: rx,
            workers: self.workers,
            queue_cap: self.queue_cap,
        }
    }

    /// Consume the pipeline with a sink; blocks until the source finishes
    /// and every queue drains, then returns the sink fold state and the
    /// telemetry report.
    pub fn sink<S>(
        self,
        name: &str,
        category: Category,
        mut state: S,
        mut f: impl FnMut(&mut S, T),
    ) -> (S, Report) {
        let handle = self.telemetry.stage(name, category);
        while let Ok(item) = self.tail.recv() {
            let t0 = std::time::Instant::now();
            f(&mut state, item);
            handle.record(t0.elapsed(), 1);
        }
        for w in self.workers {
            let _ = w.join();
        }
        (state, self.telemetry.report())
    }

    /// Queue depth of the tail (telemetry/debug).
    pub fn tail_depth(&self) -> usize {
        // Receivers don't expose depth directly; senders do. Acceptable to
        // skip: depth is surfaced through the batcher instead.
        0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn items_flow_through_all_stages_in_order() {
        let p = StreamPipeline::source("gen", 4, |emit| {
            for i in 0..100 {
                emit(i);
            }
        })
        .stage("double", Category::Pre, |x: i32| vec![x * 2])
        .stage("keep_even_quarters", Category::Ai, |x: i32| {
            if x % 4 == 0 {
                vec![x]
            } else {
                vec![]
            }
        });
        let (collected, report) = p.sink("collect", Category::Post, Vec::new(), |v, x| {
            v.push(x);
        });
        let want: Vec<i32> = (0..100).map(|i| i * 2).filter(|x| x % 4 == 0).collect();
        assert_eq!(collected, want);
        assert_eq!(report.stages.len(), 4);
        // Source saw 100, doubler saw 100, filter saw 100, sink saw 50.
        assert_eq!(report.stages[1].items, 100);
        assert_eq!(report.stages[3].items, 50);
    }

    #[test]
    fn one_to_many_stage() {
        let p = StreamPipeline::source("gen", 2, |emit| {
            for i in 0..5 {
                emit(i);
            }
        })
        .stage("explode", Category::Pre, |x: i32| vec![x; 3]);
        let (n, _) = p.sink("count", Category::Post, 0usize, |n, _| *n += 1);
        assert_eq!(n, 15);
    }

    #[test]
    fn bounded_queues_do_not_deadlock_with_slow_sink() {
        let p = StreamPipeline::source("fast", 1, |emit| {
            for i in 0..50 {
                emit(i);
            }
        })
        .stage("id", Category::Ai, |x: i32| vec![x]);
        let (n, report) = p.sink("slow", Category::Post, 0usize, |n, _| {
            std::thread::sleep(std::time::Duration::from_micros(200));
            *n += 1;
        });
        assert_eq!(n, 50);
        // Sink must dominate the busy time (backpressure did its job).
        let sink_busy = report.stages.last().unwrap().busy;
        assert!(sink_busy >= report.stages[1].busy);
    }

    #[test]
    fn empty_source() {
        let p = StreamPipeline::<i32>::source("none", 2, |_emit| {});
        let (n, report) = p.sink("count", Category::Post, 0usize, |n, _| *n += 1);
        assert_eq!(n, 0);
        assert_eq!(report.stages[0].items, 0);
    }
}
