//! Per-stage timing telemetry — the measurement behind Figure 1.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Stage category for the pre/post-processing vs AI breakdown.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Category {
    /// Data ingestion / preprocessing / feature engineering.
    Pre,
    /// Model execution (the "AI" share of Figure 1).
    Ai,
    /// Postprocessing / upload / reporting.
    Post,
}

impl Category {
    /// Label used in reports.
    pub fn label(self) -> &'static str {
        match self {
            Category::Pre => "pre",
            Category::Ai => "ai",
            Category::Post => "post",
        }
    }
}

/// Aggregated timing for one stage.
#[derive(Debug, Clone)]
pub struct StageReport {
    pub name: String,
    pub category: Category,
    pub items: usize,
    pub busy: Duration,
}

/// Shared telemetry collector: stages register once and record laps;
/// executors additionally record one end-to-end latency sample per item
/// that completes the sink stage.
#[derive(Debug, Clone, Default)]
pub struct Telemetry {
    stages: Arc<Mutex<Vec<StageReport>>>,
    latencies: Arc<Mutex<Vec<Duration>>>,
}

/// Handle for recording one stage's time.
#[derive(Debug, Clone)]
pub struct StageHandle {
    stages: Arc<Mutex<Vec<StageReport>>>,
    index: usize,
}

impl Telemetry {
    /// Fresh collector.
    pub fn new() -> Telemetry {
        Telemetry::default()
    }

    /// Register a stage; returns its recording handle.
    pub fn stage(&self, name: &str, category: Category) -> StageHandle {
        let mut stages = self.stages.lock().unwrap();
        stages.push(StageReport {
            name: name.to_string(),
            category,
            items: 0,
            busy: Duration::ZERO,
        });
        StageHandle { stages: Arc::clone(&self.stages), index: stages.len() - 1 }
    }

    /// Record one per-item end-to-end latency sample (source emission →
    /// sink completion). Executors call this from the sink stage so the
    /// scaling percentiles reflect item latency, not instance wall time.
    pub fn record_latency(&self, d: Duration) {
        self.latencies.lock().unwrap().push(d);
    }

    /// Snapshot of all stages and latency samples.
    pub fn report(&self) -> Report {
        Report {
            stages: self.stages.lock().unwrap().clone(),
            latencies: self.latencies.lock().unwrap().clone(),
        }
    }
}

impl StageHandle {
    /// Record `d` of busy time covering `items` processed items.
    pub fn record(&self, d: Duration, items: usize) {
        let mut stages = self.stages.lock().unwrap();
        let s = &mut stages[self.index];
        s.busy += d;
        s.items += items;
    }

    /// Time a closure and record it as one item.
    pub fn time<T>(&self, f: impl FnOnce() -> T) -> T {
        let t0 = std::time::Instant::now();
        let out = f();
        self.record(t0.elapsed(), 1);
        out
    }
}

/// A finished run's telemetry.
#[derive(Debug, Clone)]
pub struct Report {
    pub stages: Vec<StageReport>,
    /// Per-item end-to-end latency samples (source emission → sink
    /// completion), in sink-arrival order. Empty when nothing reached the
    /// sink. Multi-instance execution pools samples across instances.
    pub latencies: Vec<Duration>,
}

use crate::util::stats::percentile_sorted;

impl Report {
    /// Latency percentile (`q` in 0..=1) over the per-item samples;
    /// `None` when no samples were recorded.
    pub fn latency_percentile(&self, q: f64) -> Option<Duration> {
        let mut sorted = self.latencies.clone();
        sorted.sort_unstable();
        percentile_sorted(&sorted, q)
    }

    /// Total busy time across stages.
    pub fn total(&self) -> Duration {
        self.stages.iter().map(|s| s.busy).sum()
    }

    /// Busy time for one category.
    pub fn category_time(&self, c: Category) -> Duration {
        self.stages.iter().filter(|s| s.category == c).map(|s| s.busy).sum()
    }

    /// Percent of total busy time in a category (0–100); the Figure 1
    /// quantity. Pre and Post are combined by the caller when the paper's
    /// two-way split is wanted.
    pub fn category_pct(&self, c: Category) -> f64 {
        let total = self.total().as_secs_f64();
        if total == 0.0 {
            return 0.0;
        }
        100.0 * self.category_time(c).as_secs_f64() / total
    }

    /// The Figure 1 split: (pre+post %, ai %).
    pub fn fig1_split(&self) -> (f64, f64) {
        let pre = self.category_pct(Category::Pre) + self.category_pct(Category::Post);
        let ai = self.category_pct(Category::Ai);
        (pre, ai)
    }

    /// Render a per-stage table.
    pub fn table(&self) -> crate::util::fmt::Table {
        let mut t =
            crate::util::fmt::Table::new(&["stage", "category", "items", "busy", "% of total"]);
        let total = self.total().as_secs_f64().max(1e-12);
        for s in &self.stages {
            t.row(&[
                s.name.clone(),
                s.category.label().to_string(),
                s.items.to_string(),
                crate::util::fmt::dur(s.busy),
                format!("{:.1}%", 100.0 * s.busy.as_secs_f64() / total),
            ]);
        }
        t
    }
}

/// Counters from one cooperative-scheduler run ([`ExecMode::Async`],
/// and sharded runs, whose merge fold now streams on the same
/// scheduler): how many resumable tasks were spawned and completed, how
/// many polls and requeues the run took, how many blocked tasks parked
/// on a wakeup [`Signal`] (and were woken), and the peak number of
/// tasks being polled at once (bounded by the worker pool). Kept out of
/// the metric map so async runs stay metric-identical to sequential
/// runs — the executor-conformance contract.
///
/// [`ExecMode::Async`]: super::exec::ExecMode
/// [`Signal`]: super::sched::Signal
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SchedReport {
    /// Worker threads in the pool (1 for the seeded virtual scheduler).
    pub workers: usize,
    /// Tasks submitted to the scheduler.
    pub tasks_spawned: usize,
    /// Tasks that ran to completion.
    pub tasks_run: usize,
    /// Total task polls.
    pub polls: usize,
    /// Polls that returned without finishing and requeued their task
    /// (parking polls included — a park is a requeue that waits for a
    /// wakeup instead of spinning the run queue).
    pub requeues: usize,
    /// Blocked tasks parked on a wakeup signal instead of requeued hot
    /// (0 under the seeded virtual scheduler, which never sleeps).
    pub parked: usize,
    /// Parked tasks re-enqueued by a signal notification.
    pub woken: usize,
    /// Peak tasks being polled simultaneously.
    pub max_in_flight: usize,
}

impl SchedReport {
    /// The ledger every drained scheduler run satisfies: every spawned
    /// task ran to completion, every poll either finished or requeued
    /// its task, every parked task was woken, and in-flight tasks never
    /// exceeded the pool. (A snapshot of a long-lived shared pool
    /// balances whenever no task is mid-poll or parked.)
    pub fn balanced(&self) -> bool {
        self.tasks_run == self.tasks_spawned
            && self.polls == self.tasks_run + self.requeues
            && self.parked == self.woken
            && self.max_in_flight <= self.workers
    }
}

/// Build-vs-bind accounting for a reusable compiled plan: how many
/// times the stage graph was compiled (once per
/// [`CompiledPlan`]), how many payloads were bound to it, and the time
/// each side cost. A serving session holds ONE compiled graph and binds
/// every request to it, so steady state shows `compiles` frozen while
/// `binds` grows — the amortization the paper's setup-once serving
/// deployments (§3.1, §3.4) rely on, observable from counters instead
/// of wall-clock guesswork.
///
/// [`CompiledPlan`]: super::plan::CompiledPlan
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct BindReport {
    /// Stage-graph compilations this report covers (1 per compiled
    /// plan; aggregated reports sum them).
    pub compiles: usize,
    /// Time spent compiling: template construction plus whatever the
    /// pipeline front-loads (model warmup, config derivation).
    pub compile_time: Duration,
    /// Payload bindings instantiated from the compiled graph(s).
    pub binds: usize,
    /// Cumulative time spent binding payloads.
    pub bind_time: Duration,
}

impl BindReport {
    /// Requests served per graph build — the amortization factor.
    pub fn binds_per_compile(&self) -> f64 {
        self.binds as f64 / self.compiles.max(1) as f64
    }

    /// Mean time to bind one payload (zero when nothing bound).
    pub fn mean_bind_time(&self) -> Duration {
        if self.binds == 0 {
            Duration::ZERO
        } else {
            self.bind_time / self.binds as u32
        }
    }

    /// Graph rebuilds a build-per-request loop would have performed
    /// that the compile-once path skipped.
    pub fn rebuilds_avoided(&self) -> usize {
        self.binds.saturating_sub(self.compiles)
    }

    /// Estimated setup time saved vs rebuilding the graph per bind:
    /// rebuilds avoided × mean compile cost.
    pub fn amortized_saving(&self) -> Duration {
        if self.compiles == 0 {
            return Duration::ZERO;
        }
        self.compile_time / self.compiles as u32 * self.rebuilds_avoided() as u32
    }

    /// Merge another report into this one (service-level aggregation
    /// across sessions).
    pub fn merge(&mut self, other: &BindReport) {
        self.compiles += other.compiles;
        self.compile_time += other.compile_time;
        self.binds += other.binds;
        self.bind_time += other.bind_time;
    }
}

/// What the rule-based plan optimizer did to one compiled graph: which
/// rewrite rules fired, how the stage count shrank, and what its
/// deterministic cost model would suggest — all counters derived from
/// graph structure and per-stage item tallies, never wall-clock, so the
/// report is stable across machines and reruns. Rides on
/// [`CompiledPlan`] beside [`BindReport`] and on pipeline results; it
/// never enters the metric map (optimized metrics are pinned
/// bit-identical to unoptimized ones).
///
/// [`CompiledPlan`]: super::plan::CompiledPlan
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct OptReport {
    /// Transform-node count before optimization (source/sink excluded).
    pub stages_before: usize,
    /// Transform-node count after optimization.
    pub stages_after: usize,
    /// Adjacent map pairs fused into one node (a chain of `n` maps
    /// collapsing into one counts `n - 1` fusions).
    pub fused: usize,
    /// Identity stages elided outright.
    pub elided: usize,
    /// Pure per-item maps hoisted across a batch boundary.
    pub hoisted: usize,
    /// Per-item task hops the rewrite removed. Unprofiled this is the
    /// graph-level node reduction; with a stage profile it is the sum
    /// of items that flowed through each removed hop.
    pub task_hops_saved: usize,
    /// Rule name → number of times it fired.
    pub rules: std::collections::BTreeMap<String, usize>,
    /// Cost-model suggestion: columnar batch rows for this graph
    /// (`None` without a profile or for non-batchable shapes).
    pub suggested_batch_rows: Option<usize>,
    /// Cost-model suggestion: executor mode spec (e.g. `shard:4`).
    pub suggested_exec: Option<String>,
}

impl OptReport {
    /// Total rule applications across all rules.
    pub fn rules_fired(&self) -> usize {
        self.rules.values().sum()
    }

    /// Net transform nodes removed by the rewrite.
    pub fn stages_removed(&self) -> usize {
        self.stages_before.saturating_sub(self.stages_after)
    }

    /// Merge another report into this one (service-level aggregation
    /// across sessions; suggestions keep the first non-`None` value).
    pub fn merge(&mut self, other: &OptReport) {
        self.stages_before += other.stages_before;
        self.stages_after += other.stages_after;
        self.fused += other.fused;
        self.elided += other.elided;
        self.hoisted += other.hoisted;
        self.task_hops_saved += other.task_hops_saved;
        for (rule, n) in &other.rules {
            *self.rules.entry(rule.clone()).or_default() += n;
        }
        if self.suggested_batch_rows.is_none() {
            self.suggested_batch_rows = other.suggested_batch_rows;
        }
        if self.suggested_exec.is_none() {
            self.suggested_exec = other.suggested_exec.clone();
        }
    }
}

/// Shared atomic counters behind the columnar batch data plane: the
/// batched stages of a compiled tabular pipeline record how many
/// [`ColumnBatch`] items they split, transformed, and gathered, and how
/// many bytes stayed shared (`Arc` views) versus copied out. Stages
/// across all executors write the same `Arc<BatchLedger>`, so one
/// snapshot delta covers Sequential, Streaming, MultiInstance, Sharded,
/// and Async runs alike. Like [`SchedReport`], the numbers ride on the
/// result struct — never the metric map — so batched runs stay
/// metric-identical to per-item runs (the conformance contract), and
/// tests assert amortization from these ledgers instead of wall-clock.
///
/// [`ColumnBatch`]: crate::dataframe::ColumnBatch
#[derive(Debug, Default)]
pub struct BatchLedger {
    batches: AtomicUsize,
    rows_in: AtomicUsize,
    rows_out: AtomicUsize,
    rows_filtered: AtomicUsize,
    clone_avoided_bytes: AtomicUsize,
    copied_bytes: AtomicUsize,
}

impl BatchLedger {
    /// A dataset of `rows` rows entered the batch plane as `batches`
    /// zero-copy views sharing `shared_bytes` of parent allocation.
    pub fn record_split(&self, batches: usize, rows: usize, shared_bytes: usize) {
        self.batches.fetch_add(batches, Ordering::Relaxed);
        self.rows_in.fetch_add(rows, Ordering::Relaxed);
        self.clone_avoided_bytes.fetch_add(shared_bytes, Ordering::Relaxed);
    }

    /// A transform passed `shared_bytes` through as views without
    /// copying (metadata-only column drop, no-op fill).
    pub fn record_view(&self, shared_bytes: usize) {
        self.clone_avoided_bytes.fetch_add(shared_bytes, Ordering::Relaxed);
    }

    /// A batched filter dropped `rows` rows from the plane.
    pub fn record_filter(&self, rows: usize) {
        self.rows_filtered.fetch_add(rows, Ordering::Relaxed);
    }

    /// A transform materialized `bytes` of fresh allocation (filter
    /// output, cast, computed column) — the honest counterweight to
    /// [`Self::record_view`].
    pub fn record_copy(&self, bytes: usize) {
        self.copied_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    /// `rows` rows left the plane through a gather stage (batch views
    /// reassembled into one frame for the model stages).
    pub fn record_gather(&self, rows: usize) {
        self.rows_out.fetch_add(rows, Ordering::Relaxed);
    }

    /// Snapshot the counters.
    pub fn snapshot(&self) -> BatchReport {
        BatchReport {
            batches: self.batches.load(Ordering::Relaxed),
            rows_in: self.rows_in.load(Ordering::Relaxed),
            rows_out: self.rows_out.load(Ordering::Relaxed),
            rows_filtered: self.rows_filtered.load(Ordering::Relaxed),
            clone_avoided_bytes: self.clone_avoided_bytes.load(Ordering::Relaxed),
            copied_bytes: self.copied_bytes.load(Ordering::Relaxed),
        }
    }
}

/// Snapshot of a [`BatchLedger`]: the batch data plane's row/byte
/// accounting for one run (or, for a long-lived compiled plan, the
/// delta between two snapshots).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct BatchReport {
    /// Batch views created by splitting source datasets.
    pub batches: usize,
    /// Rows that entered the batch plane at split points.
    pub rows_in: usize,
    /// Rows that left the plane through gather stages.
    pub rows_out: usize,
    /// Rows dropped by batched filters between split and gather.
    pub rows_filtered: usize,
    /// Bytes that stayed shared behind `Arc` views instead of being
    /// cloned per batch/shard.
    pub clone_avoided_bytes: usize,
    /// Bytes genuinely materialized by batched transforms.
    pub copied_bytes: usize,
}

impl BatchReport {
    /// Mean rows per batch; `batches × mean_rows == rows_in` by
    /// construction (zero when no batches were split).
    pub fn mean_rows(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.rows_in as f64 / self.batches as f64
        }
    }

    /// The conservation law every batched run satisfies: rows in ==
    /// rows out + rows filtered. An unbalanced ledger means a batch was
    /// dropped or duplicated between split and gather.
    pub fn balanced(&self) -> bool {
        self.rows_in == self.rows_out + self.rows_filtered
    }

    /// Fraction of touched bytes that stayed zero-copy (1.0 = no
    /// materialization at all; 0.0 when nothing was recorded).
    pub fn zero_copy_fraction(&self) -> f64 {
        let total = self.clone_avoided_bytes + self.copied_bytes;
        if total == 0 {
            0.0
        } else {
            self.clone_avoided_bytes as f64 / total as f64
        }
    }

    /// Merge another report into this one (aggregation across sessions
    /// or instances).
    pub fn merge(&mut self, other: &BatchReport) {
        self.batches += other.batches;
        self.rows_in += other.rows_in;
        self.rows_out += other.rows_out;
        self.rows_filtered += other.rows_filtered;
        self.clone_avoided_bytes += other.clone_avoided_bytes;
        self.copied_bytes += other.copied_bytes;
    }

    /// Counter delta since `earlier` (both snapshots of one monotonic
    /// ledger) — how a run isolates its own activity on a long-lived
    /// compiled plan.
    pub fn since(&self, earlier: &BatchReport) -> BatchReport {
        BatchReport {
            batches: self.batches.saturating_sub(earlier.batches),
            rows_in: self.rows_in.saturating_sub(earlier.rows_in),
            rows_out: self.rows_out.saturating_sub(earlier.rows_out),
            rows_filtered: self.rows_filtered.saturating_sub(earlier.rows_filtered),
            clone_avoided_bytes: self
                .clone_avoided_bytes
                .saturating_sub(earlier.clone_avoided_bytes),
            copied_bytes: self.copied_bytes.saturating_sub(earlier.copied_bytes),
        }
    }
}

/// Shared atomic counters behind the vectorized kernel layer
/// (`dataframe/kernels.rs`): every columnar verb that runs a chunked,
/// branch-free inner loop records the rows it carried on the **vector
/// path**, and every row that fell back to per-element boxed execution
/// (string columns, mixed dtypes the kernels don't cover) lands on the
/// **scalar path**. Like [`BatchLedger`], the counters ride on
/// [`PipelineResult`](crate::pipelines::PipelineResult) — never the
/// metric map — so the kernel rewrite stays metric-invisible and tests
/// assert coverage (vector fraction, mask density) from the ledger
/// instead of timing.
///
/// Unlike `BatchLedger` (per-plan `Arc`), kernels are free functions
/// deep in `column.rs`/`expr.rs` with no plan context, so the crate
/// keeps one process-global ledger
/// ([`kernels::ledger`](crate::dataframe::kernels::ledger), the
/// [`warm_rpc_count`](crate::runtime::warm_rpc_count) precedent) and
/// runs isolate their activity with [`KernelReport::since`] deltas.
/// Total rows are **derived** as `vector_rows + scalar_rows`, so the
/// balance invariant is structural — concurrent recorders can never
/// make a snapshot unbalanced.
#[derive(Debug, Default)]
pub struct KernelLedger {
    vector_rows: AtomicUsize,
    scalar_rows: AtomicUsize,
    chunks: AtomicUsize,
    masked_rows: AtomicUsize,
}

impl KernelLedger {
    /// A const constructor so the process-global ledger can live in a
    /// `static` (statics cannot call `Default::default`).
    pub const fn new() -> KernelLedger {
        KernelLedger {
            vector_rows: AtomicUsize::new(0),
            scalar_rows: AtomicUsize::new(0),
            chunks: AtomicUsize::new(0),
            masked_rows: AtomicUsize::new(0),
        }
    }

    /// A chunked kernel carried `rows` rows over `chunks` contiguous
    /// windows, of which `masked` lanes were null (written back through
    /// the select-via-mask pass rather than branched on).
    pub fn record_vector(&self, rows: usize, chunks: usize, masked: usize) {
        self.vector_rows.fetch_add(rows, Ordering::Relaxed);
        self.chunks.fetch_add(chunks, Ordering::Relaxed);
        self.masked_rows.fetch_add(masked, Ordering::Relaxed);
    }

    /// `rows` rows fell back to per-element boxed execution — the
    /// honest counterweight to [`Self::record_vector`], and the number
    /// the >90%-vector-coverage acceptance gate watches.
    pub fn record_scalar(&self, rows: usize) {
        self.scalar_rows.fetch_add(rows, Ordering::Relaxed);
    }

    /// Snapshot the counters.
    pub fn snapshot(&self) -> KernelReport {
        KernelReport {
            vector_rows: self.vector_rows.load(Ordering::Relaxed),
            scalar_rows: self.scalar_rows.load(Ordering::Relaxed),
            chunks: self.chunks.load(Ordering::Relaxed),
            masked_rows: self.masked_rows.load(Ordering::Relaxed),
        }
    }
}

/// Snapshot of a [`KernelLedger`]: vector-vs-scalar row accounting for
/// one run (or a `since` delta on the process-global ledger).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct KernelReport {
    /// Rows carried by chunked branch-free kernels.
    pub vector_rows: usize,
    /// Rows that fell back to per-element boxed execution.
    pub scalar_rows: usize,
    /// Contiguous chunk windows the vector path iterated.
    pub chunks: usize,
    /// Null lanes encountered on the vector path (handled by the
    /// select-via-mask writeback, never a per-element branch).
    pub masked_rows: usize,
}

impl KernelReport {
    /// Total rows through the kernel layer. Derived, not stored: the
    /// `vector_rows + scalar_rows == rows` balance holds by
    /// construction on every snapshot.
    pub fn rows(&self) -> usize {
        self.vector_rows + self.scalar_rows
    }

    /// Fraction of rows the vector path carried (0.0 when nothing was
    /// recorded). The tabular pipelines' acceptance gate: > 0.9.
    pub fn vector_fraction(&self) -> f64 {
        let total = self.rows();
        if total == 0 {
            0.0
        } else {
            self.vector_rows as f64 / total as f64
        }
    }

    /// Fraction of vector-path lanes that were null (0.0 when the
    /// vector path saw no rows).
    pub fn masked_fraction(&self) -> f64 {
        if self.vector_rows == 0 {
            0.0
        } else {
            self.masked_rows as f64 / self.vector_rows as f64
        }
    }

    /// Internal consistency every snapshot and delta must satisfy:
    /// masked lanes are a subset of vector lanes, and chunk windows
    /// never outnumber the rows they covered.
    pub fn balanced(&self) -> bool {
        self.masked_rows <= self.vector_rows && self.chunks <= self.vector_rows
    }

    /// Merge another report into this one (aggregation across runs).
    pub fn merge(&mut self, other: &KernelReport) {
        self.vector_rows += other.vector_rows;
        self.scalar_rows += other.scalar_rows;
        self.chunks += other.chunks;
        self.masked_rows += other.masked_rows;
    }

    /// Counter delta since `earlier` (both snapshots of the monotonic
    /// process-global ledger) — how a run isolates its own kernel
    /// activity.
    pub fn since(&self, earlier: &KernelReport) -> KernelReport {
        KernelReport {
            vector_rows: self.vector_rows.saturating_sub(earlier.vector_rows),
            scalar_rows: self.scalar_rows.saturating_sub(earlier.scalar_rows),
            chunks: self.chunks.saturating_sub(earlier.chunks),
            masked_rows: self.masked_rows.saturating_sub(earlier.masked_rows),
        }
    }
}

/// Per-tenant outcome counters on the serving edge: every `Request`
/// frame a [`PipelineServer`] reads for a tenant is **admitted** into
/// the ledger, and resolves exactly once as completed, shed (tenant
/// lane full, queue full, deadline expired, or server draining), or
/// failed. The balance invariant is what the loopback soak asserts
/// instead of timing.
///
/// [`PipelineServer`]: crate::net::PipelineServer
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TenantLedger {
    /// Request frames read off this tenant's connections.
    pub admitted: u64,
    /// Requests that executed and answered with a `Completed` frame.
    pub completed: u64,
    /// Requests answered with a `Shed` frame (lane, queue, deadline, or
    /// drain shedding — all first-class, never dropped connections).
    pub shed: u64,
    /// Requests answered with a `Failed` frame.
    pub failed: u64,
}

impl TenantLedger {
    /// Every admitted request resolved exactly once.
    pub fn balances(&self) -> bool {
        self.admitted == self.completed + self.shed + self.failed
    }

    /// Fraction of admitted requests that were shed (0.0 when idle).
    pub fn shed_fraction(&self) -> f64 {
        if self.admitted == 0 {
            0.0
        } else {
            self.shed as f64 / self.admitted as f64
        }
    }

    /// Merge another tenant's (or connection's) counters into this one.
    pub fn merge(&mut self, other: &TenantLedger) {
        self.admitted += other.admitted;
        self.completed += other.completed;
        self.shed += other.shed;
        self.failed += other.failed;
    }
}

/// Shared atomic counters behind the TCP serving edge
/// ([`crate::net::PipelineServer`]): connection lifecycle, frame
/// traffic, and per-tenant request outcomes. Connection handlers write
/// it from their own threads; [`Self::snapshot`] produces the
/// [`NetReport`] the soak suites assert from — ledgers, never
/// wall-clock.
#[derive(Debug, Default)]
pub struct NetLedger {
    accepted: AtomicUsize,
    drained: AtomicUsize,
    rejected: AtomicUsize,
    reaped_idle: AtomicUsize,
    reaped_handshake: AtomicUsize,
    frames_in: AtomicUsize,
    frames_out: AtomicUsize,
    tenants: Mutex<std::collections::BTreeMap<String, TenantLedger>>,
}

impl NetLedger {
    /// A connection left the accept loop with a handler attached.
    pub fn connection_accepted(&self) {
        self.accepted.fetch_add(1, Ordering::Relaxed);
    }

    /// A handler finished: in-flight tickets flushed, stream closed.
    pub fn connection_drained(&self) {
        self.drained.fetch_add(1, Ordering::Relaxed);
    }

    /// The accept loop refused a connection at the `max_conns` ceiling
    /// (answered with a `Shed(ServerFull)` frame, never accepted —
    /// rejected connections do NOT count toward `accepted`).
    pub fn connection_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// The idle reaper closed an accepted connection: `handshake` is
    /// true when the peer never completed its `Hello`, false when an
    /// established connection went idle with nothing in flight.
    pub fn connection_reaped(&self, handshake: bool) {
        if handshake {
            self.reaped_handshake.fetch_add(1, Ordering::Relaxed);
        } else {
            self.reaped_idle.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// One frame read off a connection.
    pub fn frame_in(&self) {
        self.frames_in.fetch_add(1, Ordering::Relaxed);
    }

    /// One frame written to a connection.
    pub fn frame_out(&self) {
        self.frames_out.fetch_add(1, Ordering::Relaxed);
    }

    fn tenant(&self, tenant: &str, f: impl FnOnce(&mut TenantLedger)) {
        let mut tenants = self.tenants.lock().unwrap();
        f(tenants.entry(tenant.to_string()).or_default());
    }

    /// A `Request` frame arrived for `tenant`.
    pub fn tenant_admitted(&self, tenant: &str) {
        self.tenant(tenant, |t| t.admitted += 1);
    }

    /// A request resolved with a `Completed` frame.
    pub fn tenant_completed(&self, tenant: &str) {
        self.tenant(tenant, |t| t.completed += 1);
    }

    /// A request resolved with a `Shed` frame.
    pub fn tenant_shed(&self, tenant: &str) {
        self.tenant(tenant, |t| t.shed += 1);
    }

    /// A request resolved with a `Failed` frame.
    pub fn tenant_failed(&self, tenant: &str) {
        self.tenant(tenant, |t| t.failed += 1);
    }

    /// Snapshot every counter.
    pub fn snapshot(&self) -> NetReport {
        NetReport {
            accepted: self.accepted.load(Ordering::Relaxed),
            drained: self.drained.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            reaped_idle: self.reaped_idle.load(Ordering::Relaxed),
            reaped_handshake: self.reaped_handshake.load(Ordering::Relaxed),
            frames_in: self.frames_in.load(Ordering::Relaxed),
            frames_out: self.frames_out.load(Ordering::Relaxed),
            tenants: self.tenants.lock().unwrap().clone(),
        }
    }
}

/// Snapshot of a [`NetLedger`]: the serving edge's connection, frame,
/// and per-tenant request accounting. Like [`SchedReport`] and
/// [`BatchReport`], this rides beside `ServiceStats` so network soak
/// tests assert behavior from counters — `accepted == drained +
/// reaped` after a drain, `admitted == completed + shed + failed` per
/// tenant — never from timing.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct NetReport {
    /// Connections handed to a connection task by the accept loop.
    pub accepted: usize,
    /// Connections whose task flushed its in-flight tickets and closed
    /// (client disconnect, client `Drain`, or server drain).
    pub drained: usize,
    /// Connections the accept loop refused at the `max_conns` ceiling
    /// with a `Shed(ServerFull)` frame. Never counted in `accepted`.
    pub rejected: usize,
    /// Established connections the idle reaper closed: no frame
    /// activity and nothing in flight for `idle_after` ticks.
    pub reaped_idle: usize,
    /// Connections reaped while still waiting for their `Hello` — the
    /// never-completed handshakes that used to spin forever.
    pub reaped_handshake: usize,
    /// Frames read across all connections.
    pub frames_in: usize,
    /// Frames written across all connections.
    pub frames_out: usize,
    /// Per-tenant request outcomes, keyed by the tenant id each
    /// connection declared in its `Hello` frame.
    pub tenants: std::collections::BTreeMap<String, TenantLedger>,
}

impl NetReport {
    /// Connections closed by the idle reaper, either side of the
    /// handshake.
    pub fn reaped(&self) -> usize {
        self.reaped_idle + self.reaped_handshake
    }

    /// Connections currently being served.
    pub fn active(&self) -> usize {
        self.accepted.saturating_sub(self.drained).saturating_sub(self.reaped())
    }

    /// The drained-server ledger: every accepted connection either
    /// drained or was reaped, and every tenant's requests resolved
    /// exactly once. (`rejected` connections never enter `accepted`,
    /// so they do not appear here.)
    pub fn balanced(&self) -> bool {
        self.accepted == self.drained + self.reaped()
            && self.tenants.values().all(TenantLedger::balances)
    }

    /// All tenants' counters merged.
    pub fn total(&self) -> TenantLedger {
        let mut total = TenantLedger::default();
        for t in self.tenants.values() {
            total.merge(t);
        }
        total
    }
}

/// One shard's slice of a data-parallel ([`ExecMode::Sharded`]) run.
///
/// [`ExecMode::Sharded`]: super::exec::ExecMode
#[derive(Debug, Clone)]
pub struct ShardReport {
    /// 0-based shard index (also the merge order of its sink state).
    pub shard: usize,
    /// Source emissions this shard owned under the round-robin partition.
    pub owned: usize,
    /// Items from this shard that completed the merge sink.
    pub completed: usize,
    /// Wall time of the shard's source+transform pass (excludes the
    /// merge fold, which runs once on the merging thread).
    pub elapsed: Duration,
    /// Per-item end-to-end latency samples for this shard's items
    /// (source emission → merge-sink completion).
    pub latencies: Vec<Duration>,
}

impl ShardReport {
    /// Owned source emissions per second of shard pass time.
    pub fn throughput(&self) -> f64 {
        self.owned as f64 / self.elapsed.as_secs_f64().max(1e-12)
    }
}

/// Aggregate view of a sharded run: one [`ShardReport`] per shard plus
/// the run's wall time. Per-item latencies are pooled across shards, so
/// the percentiles describe the whole dataset, not one partition — the
/// sharded analogue of [`super::scaler::ScalingReport`], keyed by data
/// partition instead of replicated instance.
#[derive(Debug, Clone)]
pub struct ShardedReport {
    /// Per-shard slices, indexed by shard (merge order).
    pub shards: Vec<ShardReport>,
    /// Wall time of the whole sharded run (passes + merge fold).
    pub wall: Duration,
    /// Shard folds that began while at least one shard pass was still
    /// running: > 0 means the merge streamed ahead of the full barrier
    /// instead of waiting for every pass to join (the fold order is
    /// still strict shard order, so metrics are unaffected). Always 0
    /// for a single shard, whose fold can only start after its own —
    /// the last — pass.
    pub streamed_folds: usize,
}

impl ShardedReport {
    /// Number of shards.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// True when at least one shard's fold overlapped a still-running
    /// shard pass (see [`Self::streamed_folds`]).
    pub fn merge_streamed(&self) -> bool {
        self.streamed_folds > 0
    }

    /// Source emissions across all shards (= the dataset size).
    pub fn total_owned(&self) -> usize {
        self.shards.iter().map(|s| s.owned).sum()
    }

    /// Items completing the merge sink across all shards.
    pub fn total_completed(&self) -> usize {
        self.shards.iter().map(|s| s.completed).sum()
    }

    /// Dataset throughput: sink completions per second of wall time.
    /// Unlike multi-instance aggregate throughput this measures ONE
    /// dataset finishing faster, not n copies finishing together.
    pub fn dataset_throughput(&self) -> f64 {
        self.total_completed() as f64 / self.wall.as_secs_f64().max(1e-12)
    }

    /// Partition balance: min/max owned emissions across shards
    /// (1.0 = perfectly balanced; round-robin keeps it ≥ k/(k+1) for
    /// any dataset of k·n + r items).
    pub fn balance(&self) -> f64 {
        let min = self.shards.iter().map(|s| s.owned).min().unwrap_or(0);
        let max = self.shards.iter().map(|s| s.owned).max().unwrap_or(0);
        if max == 0 {
            1.0
        } else {
            min as f64 / max as f64
        }
    }

    /// Every shard's latency samples pooled and sorted.
    pub fn pooled_latencies(&self) -> Vec<Duration> {
        let mut pooled: Vec<Duration> =
            self.shards.iter().flat_map(|s| s.latencies.iter().copied()).collect();
        pooled.sort_unstable();
        pooled
    }

    /// Latency percentile (`q` in 0..=1) over the pooled per-item
    /// samples; `None` when nothing completed.
    pub fn latency_percentile(&self, q: f64) -> Option<Duration> {
        percentile_sorted(&self.pooled_latencies(), q)
    }

    /// Several pooled percentiles from a single pool+sort.
    pub fn latency_percentiles(&self, qs: &[f64]) -> Vec<Option<Duration>> {
        let pooled = self.pooled_latencies();
        qs.iter().map(|&q| percentile_sorted(&pooled, q)).collect()
    }

    /// Render a per-shard table (owned / completed / pass time).
    pub fn table(&self) -> crate::util::fmt::Table {
        let mut t = crate::util::fmt::Table::new(&["shard", "owned", "completed", "pass time"]);
        for s in &self.shards {
            t.row(&[
                s.shard.to_string(),
                s.owned.to_string(),
                s.completed.to_string(),
                crate::util::fmt::dur(s.elapsed),
            ]);
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_aggregates() {
        let tel = Telemetry::new();
        let pre = tel.stage("ingest", Category::Pre);
        let ai = tel.stage("model", Category::Ai);
        pre.record(Duration::from_millis(30), 10);
        ai.record(Duration::from_millis(10), 10);
        let r = tel.report();
        assert_eq!(r.stages.len(), 2);
        assert_eq!(r.total(), Duration::from_millis(40));
        let (pre_pct, ai_pct) = r.fig1_split();
        assert!((pre_pct - 75.0).abs() < 1e-9);
        assert!((ai_pct - 25.0).abs() < 1e-9);
    }

    #[test]
    fn time_closure_counts_an_item() {
        let tel = Telemetry::new();
        let h = tel.stage("s", Category::Post);
        let v = h.time(|| 5);
        assert_eq!(v, 5);
        let r = tel.report();
        assert_eq!(r.stages[0].items, 1);
        assert!(r.category_pct(Category::Post) > 99.0);
    }

    #[test]
    fn empty_report_is_zero() {
        let r = Telemetry::new().report();
        assert_eq!(r.total(), Duration::ZERO);
        assert_eq!(r.fig1_split(), (0.0, 0.0));
        assert!(r.latencies.is_empty());
        assert!(r.latency_percentile(0.5).is_none());
    }

    #[test]
    fn latency_samples_drive_percentiles() {
        let tel = Telemetry::new();
        for ms in [5u64, 1, 9, 3, 7] {
            tel.record_latency(Duration::from_millis(ms));
        }
        let r = tel.report();
        assert_eq!(r.latencies.len(), 5);
        assert_eq!(r.latency_percentile(0.5), Some(Duration::from_millis(5)));
        assert_eq!(r.latency_percentile(1.0), Some(Duration::from_millis(9)));
        assert!(r.latency_percentile(0.95) >= r.latency_percentile(0.5));
    }

    #[test]
    fn table_renders() {
        let tel = Telemetry::new();
        tel.stage("a", Category::Pre).record(Duration::from_millis(1), 2);
        let s = tel.report().table().render();
        assert!(s.contains("a"), "{s}");
        assert!(s.contains("pre"));
    }

    fn shard(i: usize, owned: usize, lat_ms: &[u64]) -> ShardReport {
        ShardReport {
            shard: i,
            owned,
            completed: lat_ms.len(),
            elapsed: Duration::from_millis(10),
            latencies: lat_ms.iter().map(|&ms| Duration::from_millis(ms)).collect(),
        }
    }

    #[test]
    fn sharded_report_pools_latencies_across_shards() {
        let r = ShardedReport {
            shards: vec![shard(0, 3, &[5, 1, 9]), shard(1, 2, &[3, 7])],
            wall: Duration::from_millis(20),
            streamed_folds: 0,
        };
        assert_eq!(r.shard_count(), 2);
        assert_eq!(r.total_owned(), 5);
        assert_eq!(r.total_completed(), 5);
        let pooled = r.pooled_latencies();
        assert_eq!(pooled.len(), 5);
        assert!(pooled.windows(2).all(|w| w[0] <= w[1]), "pooled must be sorted");
        assert_eq!(r.latency_percentile(0.5), Some(Duration::from_millis(5)));
        assert_eq!(r.latency_percentile(1.0), Some(Duration::from_millis(9)));
        assert!(r.latency_percentile(0.95) >= r.latency_percentile(0.5));
        let pcts = r.latency_percentiles(&[0.5, 0.95]);
        assert_eq!(pcts[0], r.latency_percentile(0.5));
        assert_eq!(pcts[1], r.latency_percentile(0.95));
        assert!(r.dataset_throughput() > 0.0);
    }

    #[test]
    fn sharded_report_balance_and_empty_cases() {
        let even = ShardedReport {
            shards: vec![shard(0, 4, &[1]), shard(1, 4, &[2])],
            wall: Duration::from_millis(1),
            streamed_folds: 1,
        };
        assert!((even.balance() - 1.0).abs() < 1e-12);
        assert!(even.merge_streamed());
        let skewed = ShardedReport {
            shards: vec![shard(0, 1, &[]), shard(1, 4, &[])],
            wall: Duration::from_millis(1),
            streamed_folds: 0,
        };
        assert!(!skewed.merge_streamed());
        assert!((skewed.balance() - 0.25).abs() < 1e-12);
        assert!(skewed.latency_percentile(0.5).is_none());
        assert_eq!(skewed.latency_percentiles(&[0.5, 0.95]), vec![None, None]);
        let empty = ShardedReport { shards: vec![], wall: Duration::ZERO, streamed_folds: 0 };
        assert_eq!(empty.balance(), 1.0);
        assert_eq!(empty.total_owned(), 0);
        let s = even.table().render();
        assert!(s.contains("shard"), "{s}");
    }

    #[test]
    fn sched_report_ledger_balances() {
        let ok = SchedReport {
            workers: 2,
            tasks_spawned: 5,
            tasks_run: 5,
            polls: 9,
            requeues: 4,
            parked: 2,
            woken: 2,
            max_in_flight: 2,
        };
        assert!(ok.balanced());
        // A task that never completed, an unaccounted poll, a parked
        // task never woken, or an in-flight excursion past the pool all
        // break the ledger.
        assert!(!SchedReport { tasks_run: 4, ..ok }.balanced());
        assert!(!SchedReport { polls: 10, ..ok }.balanced());
        assert!(!SchedReport { parked: 3, ..ok }.balanced());
        assert!(!SchedReport { max_in_flight: 3, ..ok }.balanced());
        assert!(SchedReport::default().balanced());
    }

    #[test]
    fn batch_ledger_balances_and_deltas() {
        let ledger = BatchLedger::default();
        let before = ledger.snapshot();
        assert_eq!(before, BatchReport::default());
        assert!(before.balanced());
        assert_eq!(before.mean_rows(), 0.0);
        assert_eq!(before.zero_copy_fraction(), 0.0);

        // A run: 100 rows split into 3 views, 10 rows filtered, the
        // survivors gathered back out.
        ledger.record_split(3, 100, 8_000);
        ledger.record_view(2_000);
        ledger.record_filter(10);
        ledger.record_copy(500);
        ledger.record_gather(90);
        let after = ledger.snapshot();
        let run = after.since(&before);
        assert!(run.balanced());
        assert_eq!(run.batches, 3);
        assert_eq!(run.rows_in, 100);
        assert_eq!(run.rows_out, 90);
        assert_eq!(run.rows_filtered, 10);
        // batches × mean rows reproduces the total rows in.
        assert!((run.mean_rows() * run.batches as f64 - run.rows_in as f64).abs() < 1e-9);
        assert!((run.zero_copy_fraction() - 10_000.0 / 10_500.0).abs() < 1e-12);

        // A dropped batch (gather never saw its rows) breaks the law.
        assert!(!BatchReport { rows_out: 80, ..run }.balanced());

        // Second run on the same ledger: the delta isolates it.
        ledger.record_split(2, 40, 1_000);
        ledger.record_gather(40);
        let second = ledger.snapshot().since(&after);
        assert_eq!(second.batches, 2);
        assert_eq!(second.rows_in, 40);
        assert!(second.balanced());

        // Aggregation sums every counter.
        let mut total = run;
        total.merge(&second);
        assert_eq!(total.batches, 5);
        assert_eq!(total.rows_in, 140);
        assert!(total.balanced());
    }

    #[test]
    fn net_ledger_balances_per_tenant_and_per_connection() {
        let ledger = NetLedger::default();
        assert!(ledger.snapshot().balanced(), "empty ledger balances");
        ledger.connection_accepted();
        ledger.connection_accepted();
        for _ in 0..5 {
            ledger.frame_in();
        }
        ledger.frame_out();
        // Tenant a: 3 admitted = 2 completed + 1 shed; tenant b: 1
        // admitted, unresolved so far.
        for _ in 0..3 {
            ledger.tenant_admitted("a");
        }
        ledger.tenant_completed("a");
        ledger.tenant_completed("a");
        ledger.tenant_shed("a");
        ledger.tenant_admitted("b");
        let mid = ledger.snapshot();
        assert_eq!(mid.accepted, 2);
        assert_eq!(mid.drained, 0);
        assert_eq!(mid.active(), 2);
        assert_eq!(mid.frames_in, 5);
        assert_eq!(mid.frames_out, 1);
        assert!(mid.tenants["a"].balances());
        assert!(!mid.tenants["b"].balances(), "b has an unresolved request");
        assert!(!mid.balanced(), "active connections keep the report unbalanced");
        // Resolve b and drain both connections: the ledger balances.
        ledger.tenant_failed("b");
        ledger.connection_drained();
        ledger.connection_drained();
        let done = ledger.snapshot();
        assert_eq!(done.active(), 0);
        assert!(done.balanced(), "{done:?}");
        let total = done.total();
        assert_eq!(total.admitted, 4);
        assert_eq!(total.completed, 2);
        assert_eq!(total.shed, 1);
        assert_eq!(total.failed, 1);
        assert!((done.tenants["a"].shed_fraction() - 1.0 / 3.0).abs() < 1e-12);
        assert_eq!(TenantLedger::default().shed_fraction(), 0.0);
    }

    #[test]
    fn net_ledger_reaps_and_rejections_extend_the_balance() {
        let ledger = NetLedger::default();
        // Three accepted: one drains cleanly, one is reaped idle, one
        // is reaped mid-handshake. Two more are rejected at the
        // admission gate and never enter `accepted` at all.
        for _ in 0..3 {
            ledger.connection_accepted();
        }
        ledger.connection_rejected();
        ledger.connection_rejected();
        let mid = ledger.snapshot();
        assert_eq!(mid.rejected, 2);
        assert_eq!(mid.active(), 3);
        assert!(!mid.balanced(), "three connections still open");
        ledger.connection_drained();
        ledger.connection_reaped(false);
        ledger.connection_reaped(true);
        let done = ledger.snapshot();
        assert_eq!(done.accepted, 3);
        assert_eq!(done.drained, 1);
        assert_eq!(done.reaped_idle, 1);
        assert_eq!(done.reaped_handshake, 1);
        assert_eq!(done.reaped(), 2);
        assert_eq!(done.active(), 0);
        assert_eq!(done.accepted, done.drained + done.reaped());
        assert!(done.balanced(), "{done:?}");
        // A reap can never double as a drain: over-resolving trips the
        // balance instead of silently passing.
        ledger.connection_drained();
        assert!(!ledger.snapshot().balanced());
    }

    #[test]
    fn bind_report_amortization_math() {
        let br = BindReport {
            compiles: 1,
            compile_time: Duration::from_millis(100),
            binds: 5,
            bind_time: Duration::from_millis(10),
        };
        assert!((br.binds_per_compile() - 5.0).abs() < 1e-12);
        assert_eq!(br.mean_bind_time(), Duration::from_millis(2));
        assert_eq!(br.rebuilds_avoided(), 4);
        assert_eq!(br.amortized_saving(), Duration::from_millis(400));
        // Nothing bound yet: no division blowups, zero savings.
        let empty = BindReport { compiles: 1, ..Default::default() };
        assert_eq!(empty.mean_bind_time(), Duration::ZERO);
        assert_eq!(empty.rebuilds_avoided(), 0);
        assert_eq!(empty.amortized_saving(), Duration::ZERO);
        assert_eq!(BindReport::default().amortized_saving(), Duration::ZERO);
        // Aggregation sums both sides.
        let mut total = br;
        total.merge(&BindReport {
            compiles: 1,
            compile_time: Duration::from_millis(50),
            binds: 3,
            bind_time: Duration::from_millis(6),
        });
        assert_eq!(total.compiles, 2);
        assert_eq!(total.binds, 8);
        assert_eq!(total.compile_time, Duration::from_millis(150));
        assert_eq!(total.bind_time, Duration::from_millis(16));
        assert!((total.binds_per_compile() - 4.0).abs() < 1e-12);
    }
}
