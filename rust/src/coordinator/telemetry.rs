//! Per-stage timing telemetry — the measurement behind Figure 1.

use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Stage category for the pre/post-processing vs AI breakdown.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Category {
    /// Data ingestion / preprocessing / feature engineering.
    Pre,
    /// Model execution (the "AI" share of Figure 1).
    Ai,
    /// Postprocessing / upload / reporting.
    Post,
}

impl Category {
    /// Label used in reports.
    pub fn label(self) -> &'static str {
        match self {
            Category::Pre => "pre",
            Category::Ai => "ai",
            Category::Post => "post",
        }
    }
}

/// Aggregated timing for one stage.
#[derive(Debug, Clone)]
pub struct StageReport {
    pub name: String,
    pub category: Category,
    pub items: usize,
    pub busy: Duration,
}

/// Shared telemetry collector: stages register once and record laps;
/// executors additionally record one end-to-end latency sample per item
/// that completes the sink stage.
#[derive(Debug, Clone, Default)]
pub struct Telemetry {
    stages: Arc<Mutex<Vec<StageReport>>>,
    latencies: Arc<Mutex<Vec<Duration>>>,
}

/// Handle for recording one stage's time.
#[derive(Debug, Clone)]
pub struct StageHandle {
    stages: Arc<Mutex<Vec<StageReport>>>,
    index: usize,
}

impl Telemetry {
    /// Fresh collector.
    pub fn new() -> Telemetry {
        Telemetry::default()
    }

    /// Register a stage; returns its recording handle.
    pub fn stage(&self, name: &str, category: Category) -> StageHandle {
        let mut stages = self.stages.lock().unwrap();
        stages.push(StageReport {
            name: name.to_string(),
            category,
            items: 0,
            busy: Duration::ZERO,
        });
        StageHandle { stages: Arc::clone(&self.stages), index: stages.len() - 1 }
    }

    /// Record one per-item end-to-end latency sample (source emission →
    /// sink completion). Executors call this from the sink stage so the
    /// scaling percentiles reflect item latency, not instance wall time.
    pub fn record_latency(&self, d: Duration) {
        self.latencies.lock().unwrap().push(d);
    }

    /// Snapshot of all stages and latency samples.
    pub fn report(&self) -> Report {
        Report {
            stages: self.stages.lock().unwrap().clone(),
            latencies: self.latencies.lock().unwrap().clone(),
        }
    }
}

impl StageHandle {
    /// Record `d` of busy time covering `items` processed items.
    pub fn record(&self, d: Duration, items: usize) {
        let mut stages = self.stages.lock().unwrap();
        let s = &mut stages[self.index];
        s.busy += d;
        s.items += items;
    }

    /// Time a closure and record it as one item.
    pub fn time<T>(&self, f: impl FnOnce() -> T) -> T {
        let t0 = std::time::Instant::now();
        let out = f();
        self.record(t0.elapsed(), 1);
        out
    }
}

/// A finished run's telemetry.
#[derive(Debug, Clone)]
pub struct Report {
    pub stages: Vec<StageReport>,
    /// Per-item end-to-end latency samples (source emission → sink
    /// completion), in sink-arrival order. Empty when nothing reached the
    /// sink. Multi-instance execution pools samples across instances.
    pub latencies: Vec<Duration>,
}

impl Report {
    /// Latency percentile (`q` in 0..=1) over the per-item samples;
    /// `None` when no samples were recorded.
    pub fn latency_percentile(&self, q: f64) -> Option<Duration> {
        if self.latencies.is_empty() {
            return None;
        }
        let mut sorted = self.latencies.clone();
        sorted.sort_unstable();
        let idx = ((sorted.len() - 1) as f64 * q.clamp(0.0, 1.0)).round() as usize;
        Some(sorted[idx])
    }

    /// Total busy time across stages.
    pub fn total(&self) -> Duration {
        self.stages.iter().map(|s| s.busy).sum()
    }

    /// Busy time for one category.
    pub fn category_time(&self, c: Category) -> Duration {
        self.stages.iter().filter(|s| s.category == c).map(|s| s.busy).sum()
    }

    /// Percent of total busy time in a category (0–100); the Figure 1
    /// quantity. Pre and Post are combined by the caller when the paper's
    /// two-way split is wanted.
    pub fn category_pct(&self, c: Category) -> f64 {
        let total = self.total().as_secs_f64();
        if total == 0.0 {
            return 0.0;
        }
        100.0 * self.category_time(c).as_secs_f64() / total
    }

    /// The Figure 1 split: (pre+post %, ai %).
    pub fn fig1_split(&self) -> (f64, f64) {
        let pre = self.category_pct(Category::Pre) + self.category_pct(Category::Post);
        let ai = self.category_pct(Category::Ai);
        (pre, ai)
    }

    /// Render a per-stage table.
    pub fn table(&self) -> crate::util::fmt::Table {
        let mut t =
            crate::util::fmt::Table::new(&["stage", "category", "items", "busy", "% of total"]);
        let total = self.total().as_secs_f64().max(1e-12);
        for s in &self.stages {
            t.row(&[
                s.name.clone(),
                s.category.label().to_string(),
                s.items.to_string(),
                crate::util::fmt::dur(s.busy),
                format!("{:.1}%", 100.0 * s.busy.as_secs_f64() / total),
            ]);
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_aggregates() {
        let tel = Telemetry::new();
        let pre = tel.stage("ingest", Category::Pre);
        let ai = tel.stage("model", Category::Ai);
        pre.record(Duration::from_millis(30), 10);
        ai.record(Duration::from_millis(10), 10);
        let r = tel.report();
        assert_eq!(r.stages.len(), 2);
        assert_eq!(r.total(), Duration::from_millis(40));
        let (pre_pct, ai_pct) = r.fig1_split();
        assert!((pre_pct - 75.0).abs() < 1e-9);
        assert!((ai_pct - 25.0).abs() < 1e-9);
    }

    #[test]
    fn time_closure_counts_an_item() {
        let tel = Telemetry::new();
        let h = tel.stage("s", Category::Post);
        let v = h.time(|| 5);
        assert_eq!(v, 5);
        let r = tel.report();
        assert_eq!(r.stages[0].items, 1);
        assert!(r.category_pct(Category::Post) > 99.0);
    }

    #[test]
    fn empty_report_is_zero() {
        let r = Telemetry::new().report();
        assert_eq!(r.total(), Duration::ZERO);
        assert_eq!(r.fig1_split(), (0.0, 0.0));
        assert!(r.latencies.is_empty());
        assert!(r.latency_percentile(0.5).is_none());
    }

    #[test]
    fn latency_samples_drive_percentiles() {
        let tel = Telemetry::new();
        for ms in [5u64, 1, 9, 3, 7] {
            tel.record_latency(Duration::from_millis(ms));
        }
        let r = tel.report();
        assert_eq!(r.latencies.len(), 5);
        assert_eq!(r.latency_percentile(0.5), Some(Duration::from_millis(5)));
        assert_eq!(r.latency_percentile(1.0), Some(Duration::from_millis(9)));
        assert!(r.latency_percentile(0.95) >= r.latency_percentile(0.5));
    }

    #[test]
    fn table_renders() {
        let tel = Telemetry::new();
        tel.stage("a", Category::Pre).record(Duration::from_millis(1), 2);
        let s = tel.report().table().render();
        assert!(s.contains("a"), "{s}");
        assert!(s.contains("pre"));
    }
}
