//! Multi-instance workload scaling (§3.4).
//!
//! "Multi-instance execution allows parallel streams of the application to
//! be executed on a single Xeon server" — anomaly detection runs 10 camera
//! streams, DIEN 40 instances/socket, DLSA 5–10 streams. This module
//! replicates a pipeline-instance closure N times on worker threads and
//! aggregates per-instance and total throughput, fairness, and latency
//! percentiles. The plan layer's multi-instance executor
//! ([`crate::coordinator::exec::run_multi_instance`]) builds on the same
//! report types.
//!
//! Sandbox note (DESIGN.md §2): with one hardware core the aggregate
//! throughput stays roughly flat as instances scale (time-slicing), so the
//! scaling bench reports *fairness* (per-instance share) and p50/p95
//! latency — the quantities that must stay healthy for the paper's claim
//! to hold on many-core hardware. Throughput alone can look "fair" while
//! one instance starves; the latency percentiles make that visible.

use std::time::{Duration, Instant};

use crate::util::stats::percentile_sorted;

/// Result of one instance run.
#[derive(Debug, Clone)]
pub struct InstanceReport {
    pub instance: usize,
    pub items: usize,
    pub elapsed: Duration,
    /// Per-item (or per-batch) latency samples recorded by the instance;
    /// empty when the workload does not record them.
    pub latencies: Vec<Duration>,
}

impl InstanceReport {
    /// Items per second for this instance.
    pub fn throughput(&self) -> f64 {
        self.items as f64 / self.elapsed.as_secs_f64().max(1e-12)
    }

    /// Latency percentile (`q` in 0..=1) over this instance's samples;
    /// `None` when no samples were recorded.
    pub fn latency_percentile(&self, q: f64) -> Option<Duration> {
        let mut sorted = self.latencies.clone();
        sorted.sort_unstable();
        percentile_sorted(&sorted, q)
    }
}

/// Aggregate over all instances.
#[derive(Debug, Clone)]
pub struct ScalingReport {
    pub instances: Vec<InstanceReport>,
    pub wall: Duration,
}

impl ScalingReport {
    /// Total items processed.
    pub fn total_items(&self) -> usize {
        self.instances.iter().map(|i| i.items).sum()
    }

    /// Aggregate throughput (items/s over wall time).
    pub fn aggregate_throughput(&self) -> f64 {
        self.total_items() as f64 / self.wall.as_secs_f64().max(1e-12)
    }

    /// Fairness: min/max per-instance items (1.0 = perfectly fair).
    pub fn fairness(&self) -> f64 {
        let min = self.instances.iter().map(|i| i.items).min().unwrap_or(0);
        let max = self.instances.iter().map(|i| i.items).max().unwrap_or(0);
        if max == 0 {
            1.0
        } else {
            min as f64 / max as f64
        }
    }

    /// All instances' latency samples pooled and sorted. The plan
    /// executors now stamp every item at source emission and record its
    /// sink-completion latency, so plan-driven reports always carry real
    /// per-item samples; the per-instance wall-time fallback remains for
    /// hand-rolled [`run_instances`] workloads that record nothing
    /// (coarse, but monotone with instance skew).
    fn pooled_sorted(&self) -> Vec<Duration> {
        let mut pooled: Vec<Duration> =
            self.instances.iter().flat_map(|i| i.latencies.iter().copied()).collect();
        if pooled.is_empty() {
            pooled = self.instances.iter().map(|i| i.elapsed).collect();
        }
        pooled.sort_unstable();
        pooled
    }

    /// Latency percentile (`q` in 0..=1) pooled across every instance's
    /// recorded samples. Use [`Self::latency_percentiles`] when reading
    /// several quantiles — it pools and sorts once.
    pub fn latency_percentile(&self, q: f64) -> Option<Duration> {
        percentile_sorted(&self.pooled_sorted(), q)
    }

    /// Several pooled latency percentiles from a single sort.
    pub fn latency_percentiles(&self, qs: &[f64]) -> Vec<Option<Duration>> {
        let sorted = self.pooled_sorted();
        qs.iter().map(|&q| percentile_sorted(&sorted, q)).collect()
    }

    /// Median latency.
    pub fn latency_p50(&self) -> Option<Duration> {
        self.latency_percentile(0.50)
    }

    /// Tail latency.
    pub fn latency_p95(&self) -> Option<Duration> {
        self.latency_percentile(0.95)
    }
}

/// Latency sample collector handed to each instance by
/// [`run_instances_timed`].
#[derive(Debug, Default)]
pub struct LatencyRecorder {
    samples: Vec<Duration>,
}

impl LatencyRecorder {
    /// Record one latency sample.
    pub fn record(&mut self, d: Duration) {
        self.samples.push(d);
    }

    /// Time a closure and record its duration.
    pub fn time<T>(&mut self, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.record(t0.elapsed());
        out
    }

    /// Samples recorded so far.
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    /// True when nothing was recorded.
    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }
}

/// Run `n` instances of `work` concurrently. Each instance gets its id and
/// must return the number of items it processed.
pub fn run_instances(n: usize, work: impl Fn(usize) -> usize + Sync) -> ScalingReport {
    run_instances_timed(n, |i, _lat| work(i))
}

/// Like [`run_instances`], but each instance also gets a
/// [`LatencyRecorder`] for per-item/per-batch latency samples, so the
/// report's p50/p95 reflect request latency rather than instance wall
/// time.
pub fn run_instances_timed(
    n: usize,
    work: impl Fn(usize, &mut LatencyRecorder) -> usize + Sync,
) -> ScalingReport {
    let t0 = Instant::now();
    let mut instances: Vec<InstanceReport> = Vec::with_capacity(n);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..n)
            .map(|i| {
                let work = &work;
                scope.spawn(move || {
                    let it0 = Instant::now();
                    let mut recorder = LatencyRecorder::default();
                    let items = work(i, &mut recorder);
                    InstanceReport {
                        instance: i,
                        items,
                        elapsed: it0.elapsed(),
                        latencies: recorder.samples,
                    }
                })
            })
            .collect();
        for h in handles {
            instances.push(h.join().expect("instance panicked"));
        }
    });
    ScalingReport { instances, wall: t0.elapsed() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn all_instances_run() {
        let counter = AtomicUsize::new(0);
        let report = run_instances(4, |i| {
            counter.fetch_add(1, Ordering::Relaxed);
            10 * (i + 1)
        });
        assert_eq!(counter.load(Ordering::Relaxed), 4);
        assert_eq!(report.instances.len(), 4);
        assert_eq!(report.total_items(), 10 + 20 + 30 + 40);
        assert!(report.aggregate_throughput() > 0.0);
    }

    #[test]
    fn fairness_metrics() {
        let fair = run_instances(3, |_| 100);
        assert_eq!(fair.fairness(), 1.0);
        let unfair = run_instances(2, |i| if i == 0 { 10 } else { 100 });
        assert!((unfair.fairness() - 0.1).abs() < 1e-9);
    }

    #[test]
    fn zero_instances() {
        let r = run_instances(0, |_| 1);
        assert_eq!(r.total_items(), 0);
        assert_eq!(r.fairness(), 1.0);
        assert!(r.latency_p50().is_none());
    }

    #[test]
    fn instance_ids_are_distinct() {
        let r = run_instances(5, |i| i);
        let mut ids: Vec<usize> = r.instances.iter().map(|x| x.items).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn recorded_latencies_drive_percentiles() {
        let r = run_instances_timed(2, |i, lat| {
            for k in 1..=10u64 {
                lat.record(Duration::from_millis(k + i as u64 * 10));
            }
            10
        });
        // Pooled samples: 1..=10 and 11..=20 ms → p50 ≈ 10–11ms band.
        let p50 = r.latency_p50().unwrap();
        assert!(p50 >= Duration::from_millis(9) && p50 <= Duration::from_millis(12), "{p50:?}");
        let p95 = r.latency_p95().unwrap();
        assert!(p95 >= Duration::from_millis(18), "{p95:?}");
        assert!(p95 >= p50);
    }

    #[test]
    fn elapsed_fallback_when_no_samples() {
        let r = run_instances(3, |_| 5);
        // No recorded samples → percentiles fall back to instance wall
        // times, which always exist.
        assert!(r.latency_p50().is_some());
        assert!(r.latency_p95().unwrap() >= r.latency_p50().unwrap());
    }

    #[test]
    fn recorder_time_counts_samples() {
        let mut lat = LatencyRecorder::default();
        assert!(lat.is_empty());
        let v = lat.time(|| 42);
        assert_eq!(v, 42);
        assert_eq!(lat.len(), 1);
    }
}
