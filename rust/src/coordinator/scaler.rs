//! Multi-instance workload scaling (§3.4).
//!
//! "Multi-instance execution allows parallel streams of the application to
//! be executed on a single Xeon server" — anomaly detection runs 10 camera
//! streams, DIEN 40 instances/socket, DLSA 5–10 streams. This module
//! replicates a pipeline-instance closure N times on worker threads and
//! aggregates per-instance and total throughput.
//!
//! Sandbox note (DESIGN.md §2): with one hardware core the aggregate
//! throughput stays roughly flat as instances scale (time-slicing), so the
//! scaling bench reports *fairness* (per-instance share) and the
//! coordination overhead — the quantities that must stay healthy for the
//! paper's claim to hold on many-core hardware.

use std::time::{Duration, Instant};

/// Result of one instance run.
#[derive(Debug, Clone)]
pub struct InstanceReport {
    pub instance: usize,
    pub items: usize,
    pub elapsed: Duration,
}

impl InstanceReport {
    /// Items per second for this instance.
    pub fn throughput(&self) -> f64 {
        self.items as f64 / self.elapsed.as_secs_f64().max(1e-12)
    }
}

/// Aggregate over all instances.
#[derive(Debug, Clone)]
pub struct ScalingReport {
    pub instances: Vec<InstanceReport>,
    pub wall: Duration,
}

impl ScalingReport {
    /// Total items processed.
    pub fn total_items(&self) -> usize {
        self.instances.iter().map(|i| i.items).sum()
    }

    /// Aggregate throughput (items/s over wall time).
    pub fn aggregate_throughput(&self) -> f64 {
        self.total_items() as f64 / self.wall.as_secs_f64().max(1e-12)
    }

    /// Fairness: min/max per-instance items (1.0 = perfectly fair).
    pub fn fairness(&self) -> f64 {
        let min = self.instances.iter().map(|i| i.items).min().unwrap_or(0);
        let max = self.instances.iter().map(|i| i.items).max().unwrap_or(0);
        if max == 0 {
            1.0
        } else {
            min as f64 / max as f64
        }
    }
}

/// Run `n` instances of `work` concurrently. Each instance gets its id and
/// must return the number of items it processed.
pub fn run_instances(
    n: usize,
    work: impl Fn(usize) -> usize + Sync,
) -> ScalingReport {
    let t0 = Instant::now();
    let mut instances: Vec<InstanceReport> = Vec::with_capacity(n);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..n)
            .map(|i| {
                let work = &work;
                scope.spawn(move || {
                    let it0 = Instant::now();
                    let items = work(i);
                    InstanceReport { instance: i, items, elapsed: it0.elapsed() }
                })
            })
            .collect();
        for h in handles {
            instances.push(h.join().expect("instance panicked"));
        }
    });
    ScalingReport { instances, wall: t0.elapsed() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn all_instances_run() {
        let counter = AtomicUsize::new(0);
        let report = run_instances(4, |i| {
            counter.fetch_add(1, Ordering::Relaxed);
            10 * (i + 1)
        });
        assert_eq!(counter.load(Ordering::Relaxed), 4);
        assert_eq!(report.instances.len(), 4);
        assert_eq!(report.total_items(), 10 + 20 + 30 + 40);
        assert!(report.aggregate_throughput() > 0.0);
    }

    #[test]
    fn fairness_metrics() {
        let fair = run_instances(3, |_| 100);
        assert_eq!(fair.fairness(), 1.0);
        let unfair = run_instances(2, |i| if i == 0 { 10 } else { 100 });
        assert!((unfair.fairness() - 0.1).abs() < 1e-9);
    }

    #[test]
    fn zero_instances() {
        let r = run_instances(0, |_| 1);
        assert_eq!(r.total_items(), 0);
        assert_eq!(r.fairness(), 1.0);
    }

    #[test]
    fn instance_ids_are_distinct() {
        let r = run_instances(5, |i| i);
        let mut ids: Vec<usize> = r.instances.iter().map(|x| x.items).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2, 3, 4]);
    }
}
