//! Cooperative work-queue scheduler — the task substrate behind
//! `ExecMode::Async` and the sharded streaming merge.
//!
//! The thread-based executors in [`super::exec`] spend a thread per
//! stage (streaming) or per instance/shard (multi, sharded). This
//! module provides the alternative the paper's §3.4 deployments and
//! tf.data's cooperative runtime point at: a **fixed pool** of worker
//! threads draining a shared queue of small resumable **tasks**. A task
//! is polled repeatedly; each poll does a bounded chunk of work and
//! reports [`Poll::Done`], [`Poll::Yield`] (progress made, requeue me),
//! [`Poll::Park`] (blocked on a producer that will [`Signal::notify`] —
//! park me until then, costing zero polls while I wait) or
//! [`Poll::Pending`] (blocked with no signal to park on; requeue me
//! behind a micro-sleep). Because no task owns a thread, one pool can
//! hold arbitrarily many plans in flight at once — the serving shape
//! where a single `PipelineService` worker multiplexes many requests.
//! The stage mailboxes in [`super::exec`] all carry a [`Signal`], so at
//! high fan-out blocked stages park instead of spinning the run queue.
//! The TCP serving edge rides the same substrate: each accepted socket
//! becomes a resumable connection task
//! ([`PipelineServer`](crate::net::PipelineServer)) parked on its own
//! [`Signal`], sharing this pool with the plan stages it submits.
//!
//! Two runners share the task contract:
//!
//! * [`Scheduler`] — the real thing: `workers` OS threads, FIFO queue,
//!   blocking on a condvar when idle. Counters ([`SchedReport`]) track
//!   spawns, completions, polls, requeues and peak in-flight tasks.
//! * [`VirtualScheduler`] — a single-threaded, **seeded** runner that
//!   picks the next ready task with a deterministic PRNG. No wall
//!   clock, no threads: the property-test hook that lets the suites
//!   assert metrics and fold order are invariant under randomized task
//!   interleavings (InTune's "make scheduler behavior observable"
//!   turned into a test fixture).
//!
//! [`SchedReport`]: super::telemetry::SchedReport

use super::telemetry::SchedReport;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// What one poll of a task reports back to its runner.
pub enum Poll {
    /// Finished; the task must not be polled again.
    Done,
    /// Progress was made and more work remains; requeue.
    Yield,
    /// Blocked on another task's output; requeue (the runner yields the
    /// OS thread so the producer can run). Prefer [`Poll::Park`] when
    /// the producer exposes a [`Signal`] — a pending task spins the run
    /// queue (bounded by a micro-sleep), a parked one costs nothing
    /// until its wakeup.
    Pending,
    /// Blocked on another task's output that will announce itself
    /// through `signal`: park this task until the signal's notify
    /// generation moves past `seen`. `seen` must have been read
    /// ([`Signal::generation`]) BEFORE the task checked the condition
    /// it is blocking on — the runner re-checks the generation under
    /// the signal's lock and requeues instead of parking if a notify
    /// already landed, so a wakeup can never be lost.
    Park {
        /// The producer-side wakeup latch.
        signal: Signal,
        /// Generation observed before the blocking check.
        seen: usize,
    },
}

/// Wakeup latch connecting a blocked consumer task to its producer: the
/// consumer snapshots [`Signal::generation`], checks its condition, and
/// parks via [`Poll::Park`] when blocked; the producer calls
/// [`Signal::notify`] after every push/close. Parked tasks cost no
/// polls and no sleeps until woken — the replacement for the scheduler's
/// requeue-with-micro-sleep treatment of [`Poll::Pending`], which
/// churned the run queue at high fan-out.
#[derive(Clone, Default)]
pub struct Signal {
    core: Arc<SignalCore>,
}

#[derive(Default)]
struct SignalCore {
    /// Bumped on every notify. Readers snapshot it before checking the
    /// condition they might block on, so a notify that races the
    /// decision to park is detected at park time.
    generation: AtomicUsize,
    /// Tasks parked until the next notify, each with the pool that must
    /// re-enqueue it.
    parked: Mutex<Vec<(Arc<Shared>, Task)>>,
}

impl Signal {
    /// A fresh latch.
    pub fn new() -> Signal {
        Signal::default()
    }

    /// Snapshot the notify generation. Call BEFORE checking the guarded
    /// condition and pass the value back via [`Poll::Park`].
    pub fn generation(&self) -> usize {
        self.core.generation.load(Ordering::Acquire)
    }

    /// Announce progress (an item pushed, a stream closed): bump the
    /// generation and re-enqueue every parked task onto its pool.
    pub fn notify(&self) {
        self.core.generation.fetch_add(1, Ordering::AcqRel);
        let drained: Vec<(Arc<Shared>, Task)> = {
            let mut parked = self.core.parked.lock().unwrap();
            if parked.is_empty() {
                return;
            }
            parked.drain(..).collect()
        };
        for (shared, task) in drained {
            shared.counters.woken.fetch_add(1, Ordering::SeqCst);
            enqueue_woken(&shared, task);
        }
    }

    /// Park `task` on this signal unless the generation moved past
    /// `seen` (a notify raced the decision to block); hands the task
    /// back when it must be requeued instead. Internal to the
    /// scheduler's `Park` handling. The `parked` counter bumps under
    /// the same lock that publishes the task to `notify`, so a wake can
    /// never be counted before its park.
    fn park(&self, seen: usize, shared: &Arc<Shared>, task: Task) -> Option<Task> {
        let mut parked = self.core.parked.lock().unwrap();
        if self.core.generation.load(Ordering::Acquire) != seen {
            return Some(task);
        }
        shared.counters.parked.fetch_add(1, Ordering::SeqCst);
        parked.push((Arc::clone(shared), task));
        None
    }
}

/// Re-enqueue a woken task; on a closing pool the task is dropped (its
/// run has been abandoned — the same contract as a blocked pending task
/// on a closing pool).
fn enqueue_woken(shared: &Arc<Shared>, task: Task) {
    let mut s = shared.state.lock().unwrap();
    if s.closed {
        return;
    }
    s.queue.push_back(task);
    drop(s);
    shared.ready.notify_one();
}

/// A resumable unit of work, polled until it reports [`Poll::Done`].
/// Tasks are `FnMut`, not `Fn` — a task owns its state (stage closures,
/// batch buffers, fold cursors) and only ever runs on one worker at a
/// time, so no `Sync` is required of pipeline code.
pub type Task = Box<dyn FnMut() -> Poll + Send>;

/// Countdown latch for "this batch of tasks has drained": `add` before
/// spawning, `done` when a task completes, `wait` to block until zero.
#[derive(Clone, Default)]
pub struct WaitGroup {
    inner: Arc<(Mutex<usize>, Condvar)>,
}

impl WaitGroup {
    /// An empty (already-drained) group.
    pub fn new() -> WaitGroup {
        WaitGroup::default()
    }

    /// Register `n` more outstanding completions.
    pub fn add(&self, n: usize) {
        *self.inner.0.lock().unwrap() += n;
    }

    /// Mark one completion. Every decrement notifies, because waiters
    /// may be bounding the count ([`Self::wait_below`]), not just
    /// waiting for zero.
    pub fn done(&self) {
        let mut count = self.inner.0.lock().unwrap();
        *count = count.checked_sub(1).expect("WaitGroup::done without a matching add");
        self.inner.1.notify_all();
    }

    /// Block until every registered completion has landed.
    pub fn wait(&self) {
        let mut count = self.inner.0.lock().unwrap();
        while *count > 0 {
            count = self.inner.1.wait(count).unwrap();
        }
    }

    /// Block until fewer than `bound` completions are outstanding — the
    /// backpressure primitive. Note the bound is advisory when several
    /// producers race a separate `add` behind it; use
    /// [`Self::acquire`] for an airtight bound.
    pub fn wait_below(&self, bound: usize) {
        let mut count = self.inner.0.lock().unwrap();
        while *count >= bound.max(1) {
            count = self.inner.1.wait(count).unwrap();
        }
    }

    /// Atomically wait until fewer than `bound` completions are
    /// outstanding AND register one more — the combined
    /// wait-then-`add(1)` under a single lock acquisition, so the bound
    /// holds exactly even with several producers sharing the group.
    pub fn acquire(&self, bound: usize) {
        let mut count = self.inner.0.lock().unwrap();
        while *count >= bound.max(1) {
            count = self.inner.1.wait(count).unwrap();
        }
        *count += 1;
    }

    /// True when nothing is outstanding.
    pub fn is_idle(&self) -> bool {
        *self.inner.0.lock().unwrap() == 0
    }
}

#[derive(Default)]
struct Counters {
    spawned: AtomicUsize,
    completed: AtomicUsize,
    polls: AtomicUsize,
    requeues: AtomicUsize,
    parked: AtomicUsize,
    woken: AtomicUsize,
    in_flight: AtomicUsize,
    max_in_flight: AtomicUsize,
}

impl Counters {
    fn snapshot(&self, workers: usize) -> SchedReport {
        SchedReport {
            workers,
            tasks_spawned: self.spawned.load(Ordering::SeqCst),
            tasks_run: self.completed.load(Ordering::SeqCst),
            polls: self.polls.load(Ordering::SeqCst),
            requeues: self.requeues.load(Ordering::SeqCst),
            parked: self.parked.load(Ordering::SeqCst),
            woken: self.woken.load(Ordering::SeqCst),
            max_in_flight: self.max_in_flight.load(Ordering::SeqCst),
        }
    }
}

struct State {
    queue: VecDeque<Task>,
    closed: bool,
}

struct Shared {
    state: Mutex<State>,
    ready: Condvar,
    counters: Counters,
}

fn worker_loop(shared: &Arc<Shared>) {
    loop {
        let mut task = {
            let mut s = shared.state.lock().unwrap();
            loop {
                if let Some(t) = s.queue.pop_front() {
                    break t;
                }
                if s.closed {
                    return;
                }
                s = shared.ready.wait(s).unwrap();
            }
        };
        let now = shared.counters.in_flight.fetch_add(1, Ordering::SeqCst) + 1;
        shared.counters.max_in_flight.fetch_max(now, Ordering::SeqCst);
        // Credit the poll — and optimistically the completion — BEFORE
        // polling: a task's final poll may release a WaitGroup waiter
        // from inside (completion hooks, plan wait), and the ledger
        // must already balance when that waiter resumes and snapshots
        // the counters. Non-final polls give the completion credit back
        // below; mid-poll snapshots may transiently over-read tasks_run,
        // which is why `balanced()` is only meaningful at quiescence.
        shared.counters.polls.fetch_add(1, Ordering::SeqCst);
        shared.counters.completed.fetch_add(1, Ordering::SeqCst);
        let poll = task();
        shared.counters.in_flight.fetch_sub(1, Ordering::SeqCst);
        match poll {
            Poll::Done => {}
            Poll::Yield => {
                shared.counters.completed.fetch_sub(1, Ordering::SeqCst);
                shared.counters.requeues.fetch_add(1, Ordering::SeqCst);
                shared.state.lock().unwrap().queue.push_back(task);
                shared.ready.notify_one();
            }
            Poll::Pending => {
                shared.counters.completed.fetch_sub(1, Ordering::SeqCst);
                shared.counters.requeues.fetch_add(1, Ordering::SeqCst);
                let mut s = shared.state.lock().unwrap();
                // A blocked task on a closed (abandoning) scheduler can
                // never unblock — its producer will not run again — so
                // it is dropped instead of spinning the drain forever.
                // Owners that care about completion wait on a WaitGroup
                // before dropping the scheduler, and never hit this.
                if !s.closed {
                    s.queue.push_back(task);
                    drop(s);
                    shared.ready.notify_one();
                    // No signal to park on: give the producer the core
                    // and don't hot-spin the queue while it runs.
                    std::thread::yield_now();
                    std::thread::sleep(std::time::Duration::from_micros(20));
                }
            }
            Poll::Park { signal, seen } => {
                shared.counters.completed.fetch_sub(1, Ordering::SeqCst);
                shared.counters.requeues.fetch_add(1, Ordering::SeqCst);
                if let Some(task) = signal.park(seen, shared, task) {
                    // A notify landed between the task's blocking check
                    // and here: the producer made progress, so requeue
                    // hot instead of risking a missed wakeup. (Dropped
                    // on a closing pool, like a blocked pending task.)
                    let mut s = shared.state.lock().unwrap();
                    if !s.closed {
                        s.queue.push_back(task);
                        drop(s);
                        shared.ready.notify_one();
                    }
                }
            }
        }
    }
}

/// Fixed-size cooperative worker pool (see the module docs). Dropping
/// the scheduler closes the queue, drains what can still progress, and
/// joins the workers.
pub struct Scheduler {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    pool: usize,
}

impl Scheduler {
    /// Start a pool of `workers` (at least 1) threads.
    pub fn new(workers: usize) -> Scheduler {
        let pool = workers.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(State { queue: VecDeque::new(), closed: false }),
            ready: Condvar::new(),
            counters: Counters::default(),
        });
        let workers = (0..pool)
            .map(|w| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("sched-worker-{w}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawn scheduler worker")
            })
            .collect();
        Scheduler { shared, workers, pool }
    }

    /// Worker threads in the pool.
    pub fn workers(&self) -> usize {
        self.pool
    }

    /// Enqueue a task. Panics if the scheduler is already closed (only
    /// possible via a use-after-drop, which `&self` rules out).
    pub fn spawn(&self, task: Task) {
        self.shared.counters.spawned.fetch_add(1, Ordering::SeqCst);
        let mut s = self.shared.state.lock().unwrap();
        assert!(!s.closed, "spawn on a closed scheduler");
        s.queue.push_back(task);
        drop(s);
        self.shared.ready.notify_one();
    }

    /// Snapshot of the pool's lifetime counters. On a long-lived shared
    /// pool the snapshot is cumulative across every plan it has run; it
    /// balances ([`SchedReport::balanced`]) whenever nothing is mid-poll.
    ///
    /// [`SchedReport::balanced`]: super::telemetry::SchedReport::balanced
    pub fn counters(&self) -> SchedReport {
        self.shared.counters.snapshot(self.pool)
    }
}

impl Drop for Scheduler {
    fn drop(&mut self) {
        self.shared.state.lock().unwrap().closed = true;
        self.ready_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Scheduler {
    fn ready_all(&self) {
        self.shared.ready.notify_all();
    }
}

/// Single-threaded, seeded-interleaving task runner: each step polls a
/// uniformly random ready task (deterministic per seed, no wall clock).
/// See the module docs — this is the property-test fixture behind the
/// "metrics are invariant under task interleaving" suites.
pub struct VirtualScheduler {
    ready: Vec<Task>,
    rng: crate::util::Rng,
    spawned: usize,
    completed: usize,
    polls: usize,
    requeues: usize,
}

impl VirtualScheduler {
    /// A runner whose interleaving is fully determined by `seed`.
    pub fn new(seed: u64) -> VirtualScheduler {
        VirtualScheduler {
            ready: Vec::new(),
            rng: crate::util::Rng::new(seed),
            spawned: 0,
            completed: 0,
            polls: 0,
            requeues: 0,
        }
    }

    /// Enqueue a task.
    pub fn spawn(&mut self, task: Task) {
        self.spawned += 1;
        self.ready.push(task);
    }

    /// Poll random ready tasks until every task reports done; returns
    /// the run's counters (`workers` is 1, `max_in_flight` at most 1).
    /// Panics loudly on livelock (every ready task blocked for a very
    /// long stretch) rather than hanging a test.
    pub fn run_to_idle(&mut self) -> SchedReport {
        let mut starved = 0usize;
        while !self.ready.is_empty() {
            let i = self.rng.below(self.ready.len());
            let mut task = self.ready.swap_remove(i);
            self.polls += 1;
            match task() {
                Poll::Done => {
                    self.completed += 1;
                    starved = 0;
                }
                Poll::Yield => {
                    self.requeues += 1;
                    starved = 0;
                    self.ready.push(task);
                }
                // The virtual scheduler is single-threaded and never
                // sleeps, so parking degenerates to a plain requeue:
                // the producer the task waits on is itself a ready
                // task that a later step will pick.
                Poll::Pending | Poll::Park { .. } => {
                    self.requeues += 1;
                    starved += 1;
                    assert!(
                        starved <= 10_000 * (self.ready.len() + 1),
                        "virtual scheduler livelocked: every ready task is blocked"
                    );
                    self.ready.push(task);
                }
            }
        }
        SchedReport {
            workers: 1,
            tasks_spawned: self.spawned,
            tasks_run: self.completed,
            polls: self.polls,
            requeues: self.requeues,
            parked: 0,
            woken: 0,
            max_in_flight: usize::from(self.polls > 0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    /// A task needing `polls` polls: yields `polls - 1` times, then
    /// bumps the shared counter and finishes.
    fn stepped(polls: usize, hits: &Arc<AtomicUsize>) -> Task {
        let hits = Arc::clone(hits);
        let mut left = polls;
        Box::new(move || {
            left -= 1;
            if left == 0 {
                hits.fetch_add(1, Ordering::SeqCst);
                Poll::Done
            } else {
                Poll::Yield
            }
        })
    }

    #[test]
    fn threaded_pool_runs_every_task_within_counter_bounds() {
        let hits = Arc::new(AtomicUsize::new(0));
        let wg = WaitGroup::new();
        let sched = Scheduler::new(3);
        assert_eq!(sched.workers(), 3);
        for i in 0..10usize {
            wg.add(1);
            let wg = wg.clone();
            let mut inner = stepped(1 + i % 4, &hits);
            sched.spawn(Box::new(move || match inner() {
                Poll::Done => {
                    wg.done();
                    Poll::Done
                }
                other => other,
            }));
        }
        wg.wait();
        assert!(wg.is_idle());
        assert_eq!(hits.load(Ordering::SeqCst), 10);
        let c = sched.counters();
        assert_eq!(c.tasks_spawned, 10);
        assert_eq!(c.tasks_run, 10);
        assert_eq!(c.polls, c.tasks_run + c.requeues);
        // Polls per task i: 1 + i % 4 → total 10 + (0+1+2+3)*2 + 0+1 = 23.
        assert_eq!(c.polls, 23);
        assert!(c.max_in_flight >= 1 && c.max_in_flight <= 3, "{c:?}");
        assert!(c.balanced(), "{c:?}");
    }

    #[test]
    fn zero_worker_pool_is_clamped_to_one() {
        let sched = Scheduler::new(0);
        assert_eq!(sched.workers(), 1);
        let wg = WaitGroup::new();
        wg.add(1);
        let wg2 = wg.clone();
        sched.spawn(Box::new(move || {
            wg2.done();
            Poll::Done
        }));
        wg.wait();
        assert!(sched.counters().balanced());
    }

    /// Producer pushes 1..=N through a shared FIFO in chunks; consumer
    /// drains it. Under every seeded interleaving the consumer observes
    /// exactly 1..=N in order — the invariance the async executor's
    /// metric determinism rests on. The producer exposes a [`Signal`]
    /// and the blocked consumer parks on it (no remaining signal-less
    /// `Poll::Pending` site): on the virtual scheduler parking
    /// degenerates to a requeue, so parked/woken stay zero.
    #[test]
    fn seeded_interleavings_preserve_fifo_handoff_order() {
        const N: u64 = 100;
        for seed in 0..24u64 {
            let signal = Signal::new();
            let pipe: Arc<Mutex<VecDeque<u64>>> = Arc::new(Mutex::new(VecDeque::new()));
            let produced_all = Arc::new(AtomicUsize::new(0));
            let seen: Arc<Mutex<Vec<u64>>> = Arc::new(Mutex::new(Vec::new()));

            let mut vs = VirtualScheduler::new(seed);
            {
                let signal = signal.clone();
                let pipe = Arc::clone(&pipe);
                let produced_all = Arc::clone(&produced_all);
                let mut next = 1u64;
                vs.spawn(Box::new(move || {
                    // Push up to 7 values per poll.
                    {
                        let mut q = pipe.lock().unwrap();
                        for _ in 0..7 {
                            if next > N {
                                break;
                            }
                            q.push_back(next);
                            next += 1;
                        }
                    }
                    if next > N {
                        produced_all.store(1, Ordering::SeqCst);
                        signal.notify();
                        Poll::Done
                    } else {
                        signal.notify();
                        Poll::Yield
                    }
                }));
            }
            {
                let signal = signal.clone();
                let pipe = Arc::clone(&pipe);
                let produced_all = Arc::clone(&produced_all);
                let seen = Arc::clone(&seen);
                vs.spawn(Box::new(move || {
                    // Generation snapshot BEFORE the blocking check, so
                    // a racing notify is caught at park time.
                    let gen = signal.generation();
                    let done = produced_all.load(Ordering::SeqCst) == 1;
                    let drained: Vec<u64> = pipe.lock().unwrap().drain(..).collect();
                    if drained.is_empty() {
                        if done {
                            return Poll::Done;
                        }
                        return Poll::Park { signal: signal.clone(), seen: gen };
                    }
                    seen.lock().unwrap().extend(drained);
                    Poll::Yield
                }));
            }
            let c = vs.run_to_idle();
            let seen = seen.lock().unwrap();
            let expect: Vec<u64> = (1..=N).collect();
            assert_eq!(*seen, expect, "seed {seed}: handoff reordered");
            assert_eq!(c.tasks_run, c.tasks_spawned, "seed {seed}");
            assert_eq!(c.polls, c.tasks_run + c.requeues, "seed {seed}");
            assert_eq!((c.parked, c.woken), (0, 0), "seed {seed}: VS never parks");
            assert!(c.balanced(), "seed {seed}: {c:?}");
        }
    }

    /// The deadline-spin fix pinned from counters, never timing: with
    /// the producer's [`Signal`] in hand, a blocked FIFO consumer on
    /// the REAL threaded pool parks instead of requeue-spinning behind
    /// the `Poll::Pending` micro-sleep. Counter bounds:
    ///
    /// * producer: ceil(N/CHUNK) = 15 polls → 14 `Yield` requeues;
    /// * consumer `Yield`s once per non-empty drain → at most 15;
    /// * each blocked consumer poll either parks or hot-requeues behind
    ///   a racing notify → at most `parked` + 16 (one race per notify).
    ///
    /// So `requeues ≤ 45 + parked`, where the old signal-less `Pending`
    /// path admitted unboundedly many sleep-gated spins between pushes.
    #[test]
    fn blocked_fifo_consumer_parks_instead_of_spinning() {
        const N: u64 = 100;
        const CHUNK: u64 = 7;
        let signal = Signal::new();
        let pipe: Arc<Mutex<VecDeque<u64>>> = Arc::new(Mutex::new(VecDeque::new()));
        let produced_all = Arc::new(AtomicUsize::new(0));
        let seen_vals: Arc<Mutex<Vec<u64>>> = Arc::new(Mutex::new(Vec::new()));
        let wg = WaitGroup::new();
        // ONE worker and the consumer spawned first: its first poll runs
        // before the producer can, so it MUST park at least once.
        let sched = Scheduler::new(1);
        wg.add(2);
        {
            let signal = signal.clone();
            let pipe = Arc::clone(&pipe);
            let produced_all = Arc::clone(&produced_all);
            let seen_vals = Arc::clone(&seen_vals);
            let wg = wg.clone();
            sched.spawn(Box::new(move || {
                let gen = signal.generation();
                let done = produced_all.load(Ordering::SeqCst) == 1;
                let drained: Vec<u64> = pipe.lock().unwrap().drain(..).collect();
                if drained.is_empty() {
                    if done {
                        wg.done();
                        return Poll::Done;
                    }
                    return Poll::Park { signal: signal.clone(), seen: gen };
                }
                seen_vals.lock().unwrap().extend(drained);
                Poll::Yield
            }));
        }
        {
            let signal = signal.clone();
            let pipe = Arc::clone(&pipe);
            let produced_all = Arc::clone(&produced_all);
            let wg = wg.clone();
            let mut next = 1u64;
            sched.spawn(Box::new(move || {
                {
                    let mut q = pipe.lock().unwrap();
                    for _ in 0..CHUNK {
                        if next > N {
                            break;
                        }
                        q.push_back(next);
                        next += 1;
                    }
                }
                if next > N {
                    produced_all.store(1, Ordering::SeqCst);
                    signal.notify();
                    wg.done();
                    Poll::Done
                } else {
                    signal.notify();
                    Poll::Yield
                }
            }));
        }
        wg.wait();
        let seen = seen_vals.lock().unwrap();
        let expect: Vec<u64> = (1..=N).collect();
        assert_eq!(*seen, expect, "handoff reordered");
        let c = sched.counters();
        assert!(c.parked >= 1, "the consumer's first poll must park: {c:?}");
        assert_eq!(c.parked, c.woken, "{c:?}");
        let pushes = N.div_ceil(CHUNK) as usize;
        assert!(
            c.requeues <= (pushes - 1) + pushes + c.parked + (pushes + 1),
            "blocked consumer spun the run queue: {c:?}"
        );
        assert!(c.balanced(), "{c:?}");
    }

    #[test]
    fn parked_task_wakes_on_notify() {
        // A consumer parks on a signal; the producer notifies later.
        // The consumer must complete, with the park and the wake both
        // on the ledger (and the ledger balanced).
        let signal = Signal::new();
        let sched = Scheduler::new(2);
        let wg = WaitGroup::new();
        wg.add(1);
        let fired = Arc::new(AtomicUsize::new(0));
        {
            let signal = signal.clone();
            let wg = wg.clone();
            let fired = Arc::clone(&fired);
            let mut waited = false;
            sched.spawn(Box::new(move || {
                let seen = signal.generation();
                if fired.load(Ordering::SeqCst) == 0 {
                    waited = true;
                    return Poll::Park { signal: signal.clone(), seen };
                }
                assert!(waited, "consumer must have parked at least once");
                wg.done();
                Poll::Done
            }));
        }
        // Wait until the consumer is actually parked (no notify has
        // happened yet, so its park cannot lose the generation race),
        // then let the producer fire.
        let t0 = std::time::Instant::now();
        while sched.counters().parked == 0 {
            assert!(
                t0.elapsed() < std::time::Duration::from_secs(5),
                "consumer never parked: {:?}",
                sched.counters()
            );
            std::thread::yield_now();
        }
        fired.store(1, Ordering::SeqCst);
        signal.notify();
        wg.wait();
        let c = sched.counters();
        assert!(c.parked >= 1, "{c:?}");
        assert_eq!(c.parked, c.woken, "{c:?}");
        assert!(c.balanced(), "{c:?}");
    }

    #[test]
    fn notify_racing_the_park_decision_requeues_instead_of_parking() {
        // The task snapshots generation 0, but a notify lands before
        // the scheduler parks it: the stale `seen` must force a hot
        // requeue (never a lost wakeup), and nothing counts as parked.
        let signal = Signal::new();
        let stale = signal.generation();
        signal.notify(); // generation moves past `stale` up front
        let sched = Scheduler::new(1);
        let wg = WaitGroup::new();
        wg.add(1);
        {
            let signal = signal.clone();
            let wg = wg.clone();
            let mut first = true;
            sched.spawn(Box::new(move || {
                if first {
                    first = false;
                    return Poll::Park { signal: signal.clone(), seen: stale };
                }
                wg.done();
                Poll::Done
            }));
        }
        wg.wait();
        let c = sched.counters();
        assert_eq!(c.parked, 0, "{c:?}");
        assert_eq!(c.woken, 0, "{c:?}");
        assert!(c.requeues >= 1, "{c:?}");
        assert!(c.balanced(), "{c:?}");
    }

    #[test]
    fn virtual_scheduler_treats_park_as_requeue() {
        // Single-threaded seeded runs never sleep, so Park degenerates
        // to a requeue and the parked/woken counters stay zero.
        let signal = Signal::new();
        let mut vs = VirtualScheduler::new(11);
        let produced = Arc::new(AtomicUsize::new(0));
        {
            let produced = Arc::clone(&produced);
            vs.spawn(Box::new(move || {
                produced.store(1, Ordering::SeqCst);
                Poll::Done
            }));
        }
        {
            let signal = signal.clone();
            let produced = Arc::clone(&produced);
            vs.spawn(Box::new(move || {
                let seen = signal.generation();
                if produced.load(Ordering::SeqCst) == 0 {
                    return Poll::Park { signal: signal.clone(), seen };
                }
                Poll::Done
            }));
        }
        let c = vs.run_to_idle();
        assert_eq!(c.parked, 0);
        assert_eq!(c.woken, 0);
        assert_eq!(c.tasks_run, 2);
        assert!(c.balanced(), "{c:?}");
    }

    #[test]
    fn waitgroup_counts_down_across_threads() {
        let wg = WaitGroup::new();
        wg.add(4);
        assert!(!wg.is_idle());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let wg = wg.clone();
                std::thread::spawn(move || wg.done())
            })
            .collect();
        wg.wait();
        assert!(wg.is_idle());
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn waitgroup_wait_below_bounds_outstanding_work() {
        let wg = WaitGroup::new();
        wg.wait_below(1); // idle: returns immediately
        wg.add(3);
        let releaser = {
            let wg = wg.clone();
            std::thread::spawn(move || {
                std::thread::sleep(std::time::Duration::from_millis(10));
                wg.done();
                wg.done();
            })
        };
        // Unblocks once outstanding drops under the bound (3 → 1 < 2).
        wg.wait_below(2);
        assert!(!wg.is_idle());
        wg.done();
        wg.wait();
        releaser.join().unwrap();
    }

    #[test]
    fn waitgroup_acquire_holds_the_bound_exactly() {
        let wg = WaitGroup::new();
        wg.acquire(2); // 0 → 1
        wg.acquire(2); // 1 → 2: at the bound
        assert!(!wg.is_idle());
        let releaser = {
            let wg = wg.clone();
            std::thread::spawn(move || {
                std::thread::sleep(std::time::Duration::from_millis(10));
                wg.done();
            })
        };
        // Blocks until 2 → 1, then takes the freed slot (1 → 2).
        wg.acquire(2);
        releaser.join().unwrap();
        wg.done();
        wg.done();
        assert!(wg.is_idle());
    }
}
