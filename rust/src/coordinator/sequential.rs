//! Sequential (batch) pipeline runner — the tabular workloads' shape.
//!
//! Census/PLAsTiCC/IIoT/DIEN-preprocessing run stage after stage over one
//! dataset. The runner threads a typed state `T` through named,
//! categorized stages, timing each into a [`Telemetry`] so every run
//! yields the Figure 1 breakdown for free.

use super::telemetry::{Category, Report, Telemetry};

type StageFn<T> = Box<dyn FnOnce(T) -> anyhow::Result<T>>;

/// A typed, named sequence of stages over state `T`.
pub struct SequentialPipeline<T> {
    name: String,
    stages: Vec<(String, Category, StageFn<T>)>,
}

impl<T> SequentialPipeline<T> {
    /// New pipeline with a display name.
    pub fn new(name: &str) -> Self {
        SequentialPipeline { name: name.to_string(), stages: Vec::new() }
    }

    /// Append a stage.
    pub fn stage(
        mut self,
        name: &str,
        category: Category,
        f: impl FnOnce(T) -> anyhow::Result<T> + 'static,
    ) -> Self {
        self.stages.push((name.to_string(), category, Box::new(f)));
        self
    }

    /// Pipeline name.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Run all stages; returns the final state and the telemetry report.
    pub fn run(self, initial: T) -> anyhow::Result<(T, Report)> {
        let telemetry = Telemetry::new();
        let mut state = initial;
        for (name, category, f) in self.stages {
            let handle = telemetry.stage(&name, category);
            let t0 = std::time::Instant::now();
            state = f(state)?;
            handle.record(t0.elapsed(), 1);
        }
        Ok((state, telemetry.report()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn threads_state_through_stages() {
        let p = SequentialPipeline::new("test")
            .stage("double", Category::Pre, |x: i32| Ok(x * 2))
            .stage("add", Category::Ai, |x| Ok(x + 1));
        let (out, report) = p.run(10).unwrap();
        assert_eq!(out, 21);
        assert_eq!(report.stages.len(), 2);
        assert_eq!(report.stages[0].name, "double");
        assert_eq!(report.stages[1].category, Category::Ai);
    }

    #[test]
    fn error_stops_pipeline() {
        let p = SequentialPipeline::new("failing")
            .stage("ok", Category::Pre, |x: i32| Ok(x))
            .stage("boom", Category::Ai, |_| anyhow::bail!("boom"))
            .stage("never", Category::Post, |x| Ok(x + 100));
        assert!(p.run(1).is_err());
    }

    #[test]
    fn name_accessor() {
        let p: SequentialPipeline<()> = SequentialPipeline::new("census");
        assert_eq!(p.name(), "census");
    }
}
