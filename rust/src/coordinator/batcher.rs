//! Dynamic batching — the DLSA serving optimization (§3.3: "number of
//! inference instances and batch size are tuned to achieve high E2E
//! throughput").
//!
//! Collects items from an input channel into batches, flushing on either
//! `max_batch` items or `max_wait` elapsed since the batch opened — the
//! standard throughput/latency trade the paper tunes.

use crate::parallel::channel::Receiver;
use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(5) }
    }
}

/// Pull-based dynamic batcher over a channel receiver.
pub struct DynamicBatcher<T> {
    rx: Receiver<T>,
    cfg: BatcherConfig,
    /// Count of batches flushed by timeout (vs size) — ablation telemetry.
    pub timeout_flushes: usize,
    pub size_flushes: usize,
}

impl<T> DynamicBatcher<T> {
    /// Wrap a receiver.
    pub fn new(rx: Receiver<T>, cfg: BatcherConfig) -> Self {
        DynamicBatcher { rx, cfg, timeout_flushes: 0, size_flushes: 0 }
    }

    /// Next batch: `None` when the channel is closed and drained. Blocks
    /// for the first item, then fills until `max_batch` or `max_wait`.
    pub fn next_batch(&mut self) -> Option<Vec<T>> {
        let first = self.rx.recv().ok()?;
        let mut batch = Vec::with_capacity(self.cfg.max_batch);
        batch.push(first);
        let deadline = Instant::now() + self.cfg.max_wait;
        while batch.len() < self.cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                self.timeout_flushes += 1;
                return Some(batch);
            }
            match self.rx.recv_timeout(deadline - now) {
                Ok(item) => batch.push(item),
                Err(true) => {
                    // timed out
                    self.timeout_flushes += 1;
                    return Some(batch);
                }
                Err(false) => {
                    // closed: emit what we have
                    self.timeout_flushes += 1;
                    return Some(batch);
                }
            }
        }
        self.size_flushes += 1;
        Some(batch)
    }

    /// Drain everything into batches (for tests/benches).
    pub fn drain(&mut self) -> Vec<Vec<T>> {
        let mut out = Vec::new();
        while let Some(b) = self.next_batch() {
            out.push(b);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parallel::channel::bounded;

    #[test]
    fn full_batches_when_queue_is_hot() {
        let (tx, rx) = bounded(64);
        for i in 0..20 {
            tx.send(i).unwrap();
        }
        drop(tx);
        let mut b = DynamicBatcher::new(
            rx,
            BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(50) },
        );
        let batches = b.drain();
        assert_eq!(batches.len(), 3);
        assert_eq!(batches[0].len(), 8);
        assert_eq!(batches[2].len(), 4);
        assert_eq!(b.size_flushes, 2);
        assert_eq!(b.timeout_flushes, 1);
        // Order preserved.
        assert_eq!(batches[0][0], 0);
        assert_eq!(batches[2][3], 19);
    }

    #[test]
    fn timeout_flush_with_slow_producer() {
        let (tx, rx) = bounded(8);
        let producer = std::thread::spawn(move || {
            tx.send(1).unwrap();
            std::thread::sleep(Duration::from_millis(30));
            tx.send(2).unwrap();
        });
        let mut b = DynamicBatcher::new(
            rx,
            BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(5) },
        );
        let first = b.next_batch().unwrap();
        assert_eq!(first, vec![1]); // flushed by timeout before item 2
        assert_eq!(b.timeout_flushes, 1);
        let second = b.next_batch().unwrap();
        assert_eq!(second, vec![2]);
        assert!(b.next_batch().is_none());
        producer.join().unwrap();
    }

    #[test]
    fn closed_empty_channel_yields_none() {
        let (tx, rx) = bounded::<u32>(2);
        drop(tx);
        let mut b = DynamicBatcher::new(rx, BatcherConfig::default());
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn batch_of_one_when_max_batch_is_one() {
        let (tx, rx) = bounded(4);
        tx.send(9).unwrap();
        drop(tx);
        let mut b = DynamicBatcher::new(
            rx,
            BatcherConfig { max_batch: 1, max_wait: Duration::from_millis(1) },
        );
        assert_eq!(b.next_batch().unwrap(), vec![9]);
        assert_eq!(b.size_flushes, 1);
    }

    /// Drain a batcher over `items` and return the flattened stream.
    fn drain_flat(items: &[u32], cfg: BatcherConfig) -> (Vec<u32>, usize) {
        let (tx, rx) = bounded(items.len().max(1));
        for &i in items {
            tx.send(i).unwrap();
        }
        drop(tx);
        let mut b = DynamicBatcher::new(rx, cfg);
        let batches = b.drain();
        let n = batches.len();
        (batches.into_iter().flatten().collect(), n)
    }

    #[test]
    fn empty_source_drains_to_no_batches() {
        // Edge case: an empty source produces zero batches — never a
        // phantom empty batch that a downstream group stage would choke
        // on.
        let (flat, n) = drain_flat(&[], BatcherConfig::default());
        assert!(flat.is_empty());
        assert_eq!(n, 0);
    }

    #[test]
    fn batch_size_one_preserves_the_item_stream_exactly() {
        // max_batch 1 degenerates to unbatched execution: one singleton
        // batch per item, in arrival order.
        let items: Vec<u32> = (0..17).collect();
        let (flat, n) = drain_flat(
            &items,
            BatcherConfig { max_batch: 1, max_wait: Duration::from_millis(5) },
        );
        assert_eq!(flat, items);
        assert_eq!(n, items.len());
    }

    #[test]
    fn remainder_flush_preserves_the_item_multiset() {
        // 23 items under max_batch 5: four full batches + a remainder of
        // 3. Batching must repartition, never drop or duplicate —
        // flattening the batches reproduces the unbatched stream exactly
        // (order preserved, so multiset equality follows).
        let items: Vec<u32> = (0..23).map(|i| i * 7 % 23).collect();
        let (flat, n) = drain_flat(
            &items,
            BatcherConfig { max_batch: 5, max_wait: Duration::from_millis(50) },
        );
        assert_eq!(flat, items);
        assert_eq!(n, 5);
    }

    #[test]
    fn column_batches_ride_the_batcher_without_copying() {
        use crate::dataframe::batch::ColumnBatch;
        use crate::dataframe::{Column, DataFrame};

        // 23 rows split into max-5-row chunks: four full + one
        // remainder chunk of 3. Transporting the chunks through a
        // channel and the dynamic batcher must preserve pointer
        // identity with the parent allocation — views move, row data
        // never copies.
        let df = DataFrame::from_cols(vec![
            ("x", Column::f64((0..23).map(f64::from).collect())),
            ("y", Column::i64((0..23i64).collect())),
        ]);
        let parent = ColumnBatch::from_frame(df);
        let chunks = parent.split(5);
        assert_eq!(chunks.len(), 5);
        assert_eq!(chunks.last().unwrap().nrows(), 3, "remainder chunk");

        let (tx, rx) = bounded(8);
        for c in &chunks {
            tx.send(c.clone()).unwrap();
        }
        drop(tx);
        let mut b = DynamicBatcher::new(
            rx,
            BatcherConfig { max_batch: 2, max_wait: Duration::from_millis(50) },
        );
        let batches = b.drain();
        assert_eq!(batches.iter().map(Vec::len).sum::<usize>(), 5, "no chunk dropped");
        let mut rows = 0usize;
        for batch in &batches {
            for chunk in batch {
                rows += chunk.nrows();
                // Arc pointer identity, not value equality: the
                // batcher moved views, not data.
                assert!(chunk.shares_allocation(&parent));
                assert!(chunk.col("x").unwrap().shares_parent(parent.col("x").unwrap()));
                assert!(chunk.col("y").unwrap().shares_parent(parent.col("y").unwrap()));
            }
        }
        assert_eq!(rows, 23, "batching repartitions, never drops or duplicates rows");
    }

    #[test]
    fn empty_column_batch_survives_the_batcher() {
        use crate::dataframe::batch::ColumnBatch;
        use crate::dataframe::{Column, DataFrame};

        // A zero-row parent still splits into one (empty) chunk, and
        // that chunk rides the batcher as a real item: downstream
        // gather stages see it, count its zero rows, and stay balanced.
        let parent = ColumnBatch::from_frame(DataFrame::from_cols(vec![(
            "x",
            Column::f64(vec![]),
        )]));
        let chunks = parent.split(64);
        assert_eq!(chunks.len(), 1);
        assert_eq!(chunks[0].nrows(), 0);

        let (tx, rx) = bounded(2);
        tx.send(chunks[0].clone()).unwrap();
        drop(tx);
        let mut b = DynamicBatcher::new(
            rx,
            BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(5) },
        );
        let batches = b.drain();
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].len(), 1);
        assert_eq!(batches[0][0].nrows(), 0);
        assert!(batches[0][0].shares_allocation(&parent), "empty views still alias the parent");
    }

    #[test]
    fn degenerate_max_batch_zero_behaves_like_batch_size_one() {
        // A zero max_batch cannot make progress any other way; the
        // batcher treats it as "flush after the first item" rather than
        // looping forever or panicking (the sequential executor's batch
        // node clamps the same way).
        let items: Vec<u32> = (0..6).collect();
        let (flat, n) = drain_flat(
            &items,
            BatcherConfig { max_batch: 0, max_wait: Duration::from_millis(5) },
        );
        assert_eq!(flat, items);
        assert_eq!(n, items.len());
    }
}
