//! Rule-based plan optimizer — the rewrite pass between `compile(cfg)`
//! and `bind(payload, seed)`.
//!
//! The paper's end-to-end gains come from removing redundant work
//! between pipeline stages (§3): fusing preprocessing steps so items
//! stop paying a task hop per map, and picking batch sizes / executor
//! shapes per pipeline instead of globally. The Plan IR executes graphs
//! exactly as written, so this module rewrites [`CompiledPlan`]
//! templates *before* any payload is bound:
//!
//! * **`fuse_adjacent_maps`** — two neighbouring flat-map nodes with the
//!   same [`Category`] collapse into one node that runs both closures
//!   per item (`a+b`). One task hop instead of two per item, with the
//!   same emission order (the fused closure feeds each of `a`'s outputs
//!   straight through `b`). Category equality is required so the
//!   Figure 1 pre/ai attribution of the fused stage stays honest.
//! * **`elide_identity`** — stages the builder declared as identities
//!   ([`CompiledPlanBuilder::hint_identity`]) are removed outright.
//! * **`hoist_across_batch`** — a pure per-element map over batches
//!   ([`CompiledPlanBuilder::map_each`] records the equivalent per-item
//!   template in its [`StageHints`]) moves in front of the batch node
//!   it follows. Batch cuts group items without reordering them and an
//!   elementwise map commutes with any grouping, so the sink sees
//!   identical values in identical order — and the hoisted map can now
//!   fuse with upstream per-item stages.
//!
//! A small deterministic **cost model** ([`optimize_profiled`]) reads
//! per-stage item counters from an observed [`Report`] — never
//! wall-clock — and records `batch_rows` / `ExecMode` suggestions in
//! the [`OptReport`]. Suggestions are advisory only: applying them is
//! the caller's choice, so an optimized graph always produces metrics
//! bit-identical to the unoptimized one (pinned for every pipeline
//! across the executor ladder in `rust/tests/executor_equivalence.rs`).
//!
//! `repro explain <pipeline>` prints the pre/post-optimization graph
//! ([`render_graph`]) with per-stage profiles and the fired rules.

use super::plan::{
    CompiledPlan, NodeTemplate, NodeTemplateKind, Slicing, StageHints, StageTemplateFn,
};
use super::telemetry::{OptReport, Report};

/// Rewrite `plan` in place with every rule, without a stage profile:
/// `task_hops_saved` counts graph-level hops and the cost-model
/// suggestions stay `None`. The report is also attached to the plan
/// ([`CompiledPlan::opt_report`]).
pub fn optimize<P: 'static>(plan: &mut CompiledPlan<P>) -> OptReport {
    let (mut report, removed) = rewrite(plan);
    report.task_hops_saved = removed.len();
    plan.opt = Some(report.clone());
    report
}

/// Rewrite `plan` in place and feed the deterministic cost model with
/// the per-stage item counters of `profile` (an observed run of the
/// *unoptimized* graph): `task_hops_saved` becomes the number of items
/// that flowed through each removed hop, and the report carries
/// `batch_rows` / exec-mode suggestions.
pub fn optimize_profiled<P: 'static>(
    plan: &mut CompiledPlan<P>,
    profile: &Report,
) -> OptReport {
    let (mut report, removed) = rewrite(plan);
    let items_of = |name: &str| {
        profile.stages.iter().find(|s| s.name == name).map(|s| s.items).unwrap_or(0)
    };
    report.task_hops_saved = removed.iter().map(|n| items_of(n)).sum();
    let (rows, exec) = suggest(plan, profile);
    report.suggested_batch_rows = rows;
    report.suggested_exec = exec;
    plan.opt = Some(report.clone());
    report
}

/// Render the plan's stage graph for EXPLAIN output: one line per stage
/// (kind, name, category), annotated with observed per-stage item
/// counts when a profile is supplied.
pub fn render_graph<P: 'static>(plan: &CompiledPlan<P>, profile: Option<&Report>) -> String {
    let mut out = String::new();
    for (name, category, kind) in plan.stage_specs() {
        let items = profile
            .and_then(|r| r.stages.iter().find(|s| s.name == name))
            .map(|s| format!("  {:>8} items", s.items))
            .unwrap_or_default();
        out.push_str(&format!(
            "  {kind:>6}  {name:<44} [{:>4}]{items}\n",
            category.label()
        ));
    }
    out
}

/// Run all rewrite rules; returns the (suggestion-free) report plus the
/// original stage names whose incoming hop was removed (elided nodes
/// and the right-hand side of every fusion) for profiled hop
/// accounting.
fn rewrite<P: 'static>(plan: &mut CompiledPlan<P>) -> (OptReport, Vec<String>) {
    let mut report = OptReport { stages_before: plan.nodes.len(), ..OptReport::default() };
    let mut removed: Vec<String> = Vec::new();

    // Rule 1: elide stages declared as identities.
    let mut i = 0;
    while i < plan.nodes.len() {
        let elidable = plan.nodes[i].hints.identity
            && matches!(plan.nodes[i].kind, NodeTemplateKind::FlatMap(_));
        if elidable {
            let node = plan.nodes.remove(i);
            removed.push(node.name);
            report.elided += 1;
            *report.rules.entry("elide_identity".to_string()).or_default() += 1;
        } else {
            i += 1;
        }
    }

    // Rule 2: hoist pure per-element maps in front of the batch node
    // they follow (fixpoint: a hoisted map may sit behind another
    // batch, and a batch may be followed by a chain of such maps).
    let mut changed = true;
    while changed {
        changed = false;
        let mut i = 0;
        while i + 1 < plan.nodes.len() {
            let hoistable = matches!(plan.nodes[i].kind, NodeTemplateKind::Batch(..))
                && matches!(plan.nodes[i + 1].kind, NodeTemplateKind::FlatMap(_))
                && plan.nodes[i + 1].hints.pure_elementwise
                && plan.nodes[i + 1].hints.per_item.is_some();
            if hoistable {
                let node = plan.nodes.remove(i + 1);
                let per_item = node.hints.per_item.expect("checked above");
                plan.nodes.insert(
                    i,
                    NodeTemplate {
                        name: node.name,
                        category: node.category,
                        kind: NodeTemplateKind::FlatMap(per_item),
                        hints: StageHints {
                            identity: node.hints.identity,
                            pure_elementwise: true,
                            per_item: None,
                        },
                    },
                );
                report.hoisted += 1;
                *report.rules.entry("hoist_across_batch".to_string()).or_default() += 1;
                changed = true;
            }
            i += 1;
        }
    }

    // Rule 3: fuse adjacent same-category flat-map nodes. The fused
    // node may fuse again with its new right neighbour, so the index
    // only advances past non-fusable pairs — a chain of n maps
    // collapses into one node with n-1 fusions.
    let mut i = 0;
    while i + 1 < plan.nodes.len() {
        let fusable = matches!(plan.nodes[i].kind, NodeTemplateKind::FlatMap(_))
            && matches!(plan.nodes[i + 1].kind, NodeTemplateKind::FlatMap(_))
            && plan.nodes[i].category == plan.nodes[i + 1].category;
        if !fusable {
            i += 1;
            continue;
        }
        let b = plan.nodes.remove(i + 1);
        let a = plan.nodes.remove(i);
        removed.push(b.name.clone());
        let (NodeTemplateKind::FlatMap(fa), NodeTemplateKind::FlatMap(fb)) = (a.kind, b.kind)
        else {
            unreachable!("fusable pair checked above");
        };
        plan.nodes.insert(
            i,
            NodeTemplate {
                name: format!("{}+{}", a.name, b.name),
                category: a.category,
                kind: NodeTemplateKind::FlatMap(compose(fa, fb)),
                hints: StageHints {
                    identity: a.hints.identity && b.hints.identity,
                    pure_elementwise: a.hints.pure_elementwise && b.hints.pure_elementwise,
                    per_item: match (a.hints.per_item, b.hints.per_item) {
                        (Some(pa), Some(pb)) => Some(compose(pa, pb)),
                        _ => None,
                    },
                },
            },
        );
        report.fused += 1;
        *report.rules.entry("fuse_adjacent_maps".to_string()).or_default() += 1;
    }

    report.stages_after = plan.nodes.len();
    (report, removed)
}

/// Compose two stage templates into one: per bind, mint both closures
/// and feed every output of the first through the second, preserving
/// emission order.
fn compose(fa: StageTemplateFn, fb: StageTemplateFn) -> StageTemplateFn {
    Box::new(move |seed| {
        let mut sa = fa(seed);
        let mut sb = fb(seed);
        Box::new(move |item| {
            let mut out = Vec::new();
            for mid in sa(item)? {
                out.extend(sb(mid)?);
            }
            Ok(out)
        })
    })
}

/// The deterministic cost model: suggestions derived purely from the
/// source item counter of an observed run and the rewritten graph
/// shape, so the same profile always yields the same advice.
///
/// * `batch_rows` — per-item plans moving ≥ 64 items want a columnar
///   batch plane; the suggested row count is the smallest power of two
///   in `[16, 256]` that keeps the run under ~16 batches (amortization
///   without starving downstream parallelism).
/// * exec mode — datasets large enough to feed ≥ 2 shards of ≥ 256
///   items suggest `shard:n` (n capped at 4); smaller runs with deep
///   graphs (≥ 3 transform nodes after rewriting) suggest `streaming`.
fn suggest<P: 'static>(
    plan: &CompiledPlan<P>,
    profile: &Report,
) -> (Option<usize>, Option<String>) {
    let source_items = profile.stages.first().map(|s| s.items).unwrap_or(0);
    let rows = if plan.slicing() == Slicing::PerItem && source_items >= 64 {
        let mut b = 16usize;
        while b < 256 && b * 16 < source_items {
            b *= 2;
        }
        Some(b)
    } else {
        None
    };
    let shards = (source_items / 256).clamp(1, 4);
    let exec = if shards >= 2 {
        Some(format!("shard:{shards}"))
    } else if plan.nodes.len() >= 3 && source_items >= 2 {
        Some("streaming".to_string())
    } else {
        None
    };
    (rows, exec)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::BatcherConfig;
    use crate::coordinator::exec::{
        run_async_seeded, run_sequential, run_streaming, DEFAULT_QUEUE_CAP,
    };
    use crate::coordinator::plan::{CompiledPlanBuilder, PlanOutput, WorkloadSlice};
    use crate::coordinator::telemetry::Category;
    use crate::util::rng::Rng;
    use std::collections::BTreeMap;
    use std::time::Duration;

    type Builder = CompiledPlanBuilder<Vec<i64>, i64>;

    /// Start a per-item compiled plan over a `Vec<i64>` payload.
    fn source(name: &str) -> Builder {
        CompiledPlan::source(
            name,
            "gen",
            Category::Pre,
            Slicing::PerItem,
            |slice: WorkloadSlice<Vec<i64>>| {
                let mut feed = Some(slice.payload);
                Ok(move |emit: &mut dyn FnMut(i64)| {
                    for v in feed.take().into_iter().flatten() {
                        emit(v);
                    }
                })
            },
        )
    }

    /// Terminate with an order-sensitive fold: `hash` pins the exact
    /// sink arrival sequence, not just the multiset of values.
    fn fold_sink(b: Builder) -> CompiledPlan<Vec<i64>> {
        b.sink("fold", Category::Post, |_payload: &Vec<i64>, _seed| {
            Ok((
                (0i64, 0i64, 0usize),
                |(sum, hash, n): &mut (i64, i64, usize), v: i64| {
                    *sum = sum.wrapping_add(v);
                    *hash = hash.wrapping_mul(31).wrapping_add(v);
                    *n += 1;
                    Ok(())
                },
                |(sum, hash, n)| {
                    let mut metrics = BTreeMap::new();
                    metrics.insert("sum".to_string(), sum as f64);
                    metrics.insert("hash".to_string(), hash as f64);
                    Ok(PlanOutput { metrics, items: n })
                },
            ))
        })
    }

    fn run_metrics(
        plan: &CompiledPlan<Vec<i64>>,
        payload: &[i64],
    ) -> (BTreeMap<String, f64>, usize) {
        let out = run_sequential(plan.bind(payload.to_vec(), 7).unwrap()).unwrap();
        (out.output.metrics, out.output.items)
    }

    #[test]
    fn adjacent_same_category_maps_fuse_into_one_node() {
        let build = || {
            fold_sink(
                source("fuse")
                    .map("a", Category::Pre, |_s| |v: i64| Ok(v.wrapping_mul(3)))
                    .map("b", Category::Pre, |_s| |v: i64| Ok(v.wrapping_add(11)))
                    .map("c", Category::Pre, |_s| |v: i64| Ok(v ^ 5))
                    .map("model", Category::Ai, |_s| |v: i64| Ok(v.wrapping_mul(7))),
            )
        };
        let baseline = build();
        let mut optimized = build();
        let report = optimize(&mut optimized);
        // The three Pre maps collapse; the Ai map stays separate
        // (category boundary).
        assert_eq!(report.stages_before, 4);
        assert_eq!(report.stages_after, 2);
        assert_eq!(report.fused, 2);
        assert_eq!(report.task_hops_saved, 2);
        assert_eq!(report.rules["fuse_adjacent_maps"], 2);
        assert_eq!(report.rules_fired(), 2);
        assert_eq!(optimized.stage_names(), vec!["gen", "a+b+c", "model", "fold"]);
        assert_eq!(optimized.opt_report(), Some(&report));
        let payload: Vec<i64> = (0..37).collect();
        assert_eq!(run_metrics(&baseline, &payload), run_metrics(&optimized, &payload));
    }

    #[test]
    fn declared_identity_stages_are_elided() {
        let build = || {
            fold_sink(
                source("elide")
                    .map("scale", Category::Pre, |_s| |v: i64| Ok(v.wrapping_mul(2)))
                    .map("noop", Category::Ai, |_s| |v: i64| Ok(v))
                    .hint_identity()
                    .map("shift", Category::Post, |_s| |v: i64| Ok(v + 1)),
            )
        };
        let baseline = build();
        let mut optimized = build();
        let report = optimize(&mut optimized);
        assert_eq!(report.elided, 1);
        // With `noop` gone, `scale` and `shift` still differ in
        // category, so nothing fuses.
        assert_eq!(report.fused, 0);
        assert_eq!(report.stages_removed(), 1);
        assert_eq!(optimized.stage_names(), vec!["gen", "scale", "shift", "fold"]);
        let payload: Vec<i64> = (0..23).map(|v| v * 5 - 11).collect();
        assert_eq!(run_metrics(&baseline, &payload), run_metrics(&optimized, &payload));
    }

    /// Batch → per-element map → unbatch, with an upstream per-item
    /// map: the hoist rule moves the elementwise work in front of the
    /// batch node, where fusion then merges it with the upstream map.
    fn hoist_plan() -> CompiledPlan<Vec<i64>> {
        source("hoist")
            .map("pre", Category::Pre, |_s| |v: i64| Ok(v.wrapping_add(100)))
            .batch(
                "pack",
                Category::Pre,
                BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) },
            )
            .map_each("scale_each", Category::Pre, |_s| |v: i64| Ok(v.wrapping_mul(3)))
            .flat_map("unpack", Category::Pre, |_s| |vs: Vec<i64>| Ok(vs))
            .sink("fold", Category::Post, |_payload: &Vec<i64>, _seed| {
                Ok((
                    (0i64, 0i64, 0usize),
                    |(sum, hash, n): &mut (i64, i64, usize), v: i64| {
                        *sum = sum.wrapping_add(v);
                        *hash = hash.wrapping_mul(31).wrapping_add(v);
                        *n += 1;
                        Ok(())
                    },
                    |(sum, hash, n)| {
                        let mut metrics = BTreeMap::new();
                        metrics.insert("sum".to_string(), sum as f64);
                        metrics.insert("hash".to_string(), hash as f64);
                        Ok(PlanOutput { metrics, items: n })
                    },
                ))
            })
    }

    #[test]
    fn pure_elementwise_maps_hoist_across_the_batch_boundary_and_fuse() {
        let baseline = hoist_plan();
        let mut optimized = hoist_plan();
        let report = optimize(&mut optimized);
        assert_eq!(report.hoisted, 1);
        assert_eq!(report.rules["hoist_across_batch"], 1);
        // After the hoist: pre → scale_each → pack → unpack, and the
        // two leading Pre maps fuse.
        assert_eq!(report.fused, 1);
        assert_eq!(
            optimized.stage_names(),
            vec!["gen", "pre+scale_each", "pack", "unpack", "fold"]
        );
        let payload: Vec<i64> = (0..19).map(|v| v * 7 - 3).collect();
        assert_eq!(run_metrics(&baseline, &payload), run_metrics(&optimized, &payload));
        // The streaming executor cuts batches on time as well as count;
        // an elementwise map commutes with any grouping, so metrics
        // still match.
        let a = run_streaming(baseline.bind(payload.clone(), 7).unwrap(), DEFAULT_QUEUE_CAP)
            .unwrap();
        let b = run_streaming(optimized.bind(payload, 7).unwrap(), DEFAULT_QUEUE_CAP).unwrap();
        assert_eq!(a.output.metrics, b.output.metrics);
    }

    #[test]
    fn cost_model_suggestions_are_deterministic_from_counters() {
        let build = || {
            fold_sink(
                source("cost")
                    .map("a", Category::Pre, |_s| |v: i64| Ok(v + 1))
                    .map("b", Category::Ai, |_s| |v: i64| Ok(v * 2)),
            )
        };
        let payload: Vec<i64> = (0..600).collect();
        let profile = run_sequential(build().bind(payload.clone(), 7).unwrap()).unwrap().report;
        let mut first = build();
        let r1 = optimize_profiled(&mut first, &profile);
        let mut second = build();
        let r2 = optimize_profiled(&mut second, &profile);
        assert_eq!(r1, r2, "same profile, same advice");
        // 600 items: 16·16 and 32·16 are under 600, 64·16 is not.
        assert_eq!(r1.suggested_batch_rows, Some(64));
        // 600 / 256 = 2 shards.
        assert_eq!(r1.suggested_exec.as_deref(), Some("shard:2"));
        // Profiled hop accounting: no rule fires here (category
        // boundary), so no hops are saved.
        assert_eq!(r1.task_hops_saved, 0);

        // A small payload keeps everything sequential-shaped.
        let tiny: Vec<i64> = (0..8).collect();
        let profile = run_sequential(build().bind(tiny, 7).unwrap()).unwrap().report;
        let mut third = build();
        let r3 = optimize_profiled(&mut third, &profile);
        assert_eq!(r3.suggested_batch_rows, None);
        assert_eq!(r3.suggested_exec, None);
    }

    #[test]
    fn profiled_hop_savings_count_items_not_nodes() {
        let build = || {
            fold_sink(
                source("hops")
                    .map("a", Category::Pre, |_s| |v: i64| Ok(v + 1))
                    .map("b", Category::Pre, |_s| |v: i64| Ok(v * 2)),
            )
        };
        let payload: Vec<i64> = (0..50).collect();
        let profile = run_sequential(build().bind(payload, 7).unwrap()).unwrap().report;
        let mut optimized = build();
        let report = optimize_profiled(&mut optimized, &profile);
        assert_eq!(report.fused, 1);
        // 50 items each skipped the hop into `b`.
        assert_eq!(report.task_hops_saved, 50);
    }

    #[test]
    fn render_graph_lists_stages_with_profile_counts() {
        let plan = fold_sink(
            source("render").map("a", Category::Pre, |_s| |v: i64| Ok(v + 1)),
        );
        let payload: Vec<i64> = (0..5).collect();
        let profile = run_sequential(plan.bind(payload, 7).unwrap()).unwrap().report;
        let rendered = render_graph(&plan, Some(&profile));
        assert!(rendered.contains("source"), "{rendered}");
        assert!(rendered.contains("gen"), "{rendered}");
        assert!(rendered.contains("a"), "{rendered}");
        assert!(rendered.contains("5 items"), "{rendered}");
        let bare = render_graph(&plan, None);
        assert!(!bare.contains("items"), "{bare}");
    }

    #[test]
    fn opt_reports_aggregate_by_merge() {
        let mut total = OptReport::default();
        let mut a = fold_sink(
            source("ma")
                .map("x", Category::Pre, |_s| |v: i64| Ok(v + 1))
                .map("y", Category::Pre, |_s| |v: i64| Ok(v + 2)),
        );
        total.merge(&optimize(&mut a));
        let mut b = fold_sink(
            source("mb").map("z", Category::Ai, |_s| |v: i64| Ok(v)).hint_identity(),
        );
        total.merge(&optimize(&mut b));
        assert_eq!(total.fused, 1);
        assert_eq!(total.elided, 1);
        assert_eq!(total.stages_before, 3);
        assert_eq!(total.stages_after, 1);
        assert_eq!(total.rules_fired(), 2);
    }

    // ---- Seeded property test: random plans, every rule, pinned ----
    // ---- equality under sequential AND VirtualScheduler runs.    ----

    /// One randomly chosen stage of a generated plan. `BatchBlock`
    /// exercises the hoist rule: batch → per-element maps → unbatch.
    #[derive(Clone, Debug)]
    enum Op {
        Affine(i64, i64),
        Identity,
        FilterMod(i64),
        Expand(i64),
        BatchBlock { max: usize, each: Vec<(i64, i64)> },
    }

    fn random_spec(rng: &mut Rng) -> Vec<(Op, Category)> {
        let len = rng.below(7);
        (0..len)
            .map(|_| {
                let op = match rng.below(5) {
                    0 => Op::Affine(rng.range_i64(-5, 6), rng.range_i64(-20, 21)),
                    1 => Op::Identity,
                    2 => Op::FilterMod(rng.range_i64(2, 6)),
                    3 => Op::Expand(rng.range_i64(1, 9)),
                    _ => Op::BatchBlock {
                        max: 2 + rng.below(6),
                        each: (0..1 + rng.below(2))
                            .map(|_| (rng.range_i64(-4, 5), rng.range_i64(-9, 10)))
                            .collect(),
                    },
                };
                let cat = *rng.choice(&[Category::Pre, Category::Ai, Category::Post]);
                (op, cat)
            })
            .collect()
    }

    fn build_from_spec(spec: &[(Op, Category)]) -> CompiledPlan<Vec<i64>> {
        let mut b = source("prop");
        for (k, (op, cat)) in spec.iter().enumerate() {
            let cat = *cat;
            b = match op.clone() {
                Op::Affine(m, c) => b.map(&format!("affine{k}"), cat, move |_s| {
                    move |v: i64| Ok(v.wrapping_mul(m).wrapping_add(c))
                }),
                Op::Identity => b
                    .map(&format!("id{k}"), cat, |_s| |v: i64| Ok(v))
                    .hint_identity(),
                Op::FilterMod(m) => b.flat_map(&format!("filter{k}"), cat, move |_s| {
                    move |v: i64| Ok(if v.rem_euclid(m) == 0 { vec![] } else { vec![v] })
                }),
                Op::Expand(x) => b.flat_map(&format!("expand{k}"), cat, move |_s| {
                    move |v: i64| Ok(vec![v, v ^ x])
                }),
                Op::BatchBlock { max, each } => {
                    let mut vb = b.batch(
                        &format!("pack{k}"),
                        cat,
                        BatcherConfig { max_batch: max, max_wait: Duration::from_millis(1) },
                    );
                    for (j, (m, c)) in each.into_iter().enumerate() {
                        vb = vb.map_each(&format!("each{k}_{j}"), cat, move |_s| {
                            move |v: i64| Ok(v.wrapping_mul(m).wrapping_add(c))
                        });
                    }
                    vb.flat_map(&format!("unpack{k}"), cat, |_s| |vs: Vec<i64>| Ok(vs))
                }
            };
        }
        fold_sink(b)
    }

    #[test]
    fn property_random_plans_optimize_metric_and_order_identically() {
        for case in 0..24u64 {
            let mut rng = Rng::new(0x0917 + case);
            let spec = random_spec(&mut rng);
            let payload: Vec<i64> =
                (0..rng.below(40)).map(|_| rng.range_i64(-100, 101)).collect();
            let baseline = build_from_spec(&spec);
            let mut optimized = build_from_spec(&spec);
            let report = optimize(&mut optimized);
            assert!(
                report.stages_after <= report.stages_before,
                "case {case}: {report:?}"
            );
            assert_eq!(
                report.stages_removed(),
                report.fused + report.elided,
                "case {case}: every removed node is a fusion or elision: {report:?}"
            );
            let seq_a = run_sequential(baseline.bind(payload.clone(), 7).unwrap()).unwrap();
            let seq_b = run_sequential(optimized.bind(payload.clone(), 7).unwrap()).unwrap();
            assert_eq!(
                seq_a.output.metrics, seq_b.output.metrics,
                "case {case} spec {spec:?}"
            );
            assert_eq!(seq_a.output.items, seq_b.output.items, "case {case}");
            // The optimized plan's metrics — hash included, so the sink
            // order is pinned — survive every seeded interleaving.
            for vseed in [1u64, 7, 13] {
                let va =
                    run_async_seeded(baseline.bind(payload.clone(), 7).unwrap(), vseed)
                        .unwrap();
                let vb =
                    run_async_seeded(optimized.bind(payload.clone(), 7).unwrap(), vseed)
                        .unwrap();
                assert_eq!(
                    va.output.metrics, seq_a.output.metrics,
                    "case {case} vseed {vseed}"
                );
                assert_eq!(
                    vb.output.metrics, seq_a.output.metrics,
                    "case {case} vseed {vseed}"
                );
                assert_eq!(vb.output.items, seq_a.output.items, "case {case}");
            }
        }
    }
}
