//! Pipeline coordinator — the L3 orchestration layer.
//!
//! The paper's eight workloads decompose into stages (decode → preprocess
//! → inference → postprocess → upload). This module provides:
//!
//! * [`telemetry`] — per-stage, per-category timing: the data behind
//!   Figure 1 ("percent time in pre/postprocessing vs AI").
//! * [`sequential`] — a batch pipeline runner (the tabular workloads):
//!   named, categorized stages executed in order with timing.
//! * [`stream`] — a streaming runner (the video/serving workloads): one
//!   thread per stage connected by bounded channels → backpressure, with
//!   the same telemetry.
//! * [`batcher`] — dynamic batching (max batch size / max wait) used by
//!   the DLSA serving path.
//! * [`scaler`] — multi-instance execution (§3.4 workload scaling):
//!   replicates a pipeline instance N times and aggregates throughput.

pub mod telemetry;
pub mod sequential;
pub mod stream;
pub mod batcher;
pub mod scaler;

pub use batcher::{BatcherConfig, DynamicBatcher};
pub use scaler::{run_instances, ScalingReport};
pub use sequential::SequentialPipeline;
pub use stream::StreamPipeline;
pub use telemetry::{Category, Report, StageReport, Telemetry};
