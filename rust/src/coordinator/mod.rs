//! Pipeline coordinator — the L3 orchestration layer.
//!
//! The paper's eight workloads decompose into stages (decode → preprocess
//! → inference → postprocess → upload). Since the plan/executor split,
//! the layer has three halves:
//!
//! **What to run** — [`plan`]: a pipeline is declared once as a typed
//! graph of named, [`Category`]-tagged stage nodes (source / map /
//! flat-map / batch / sink). The plan is data; it encodes no execution
//! strategy. Serving callers compile the graph once into a
//! [`CompiledPlan`] of payload-free stage templates and bind a payload
//! per run/request ([`CompiledPlan::bind`] → [`BoundPlan`]); sharded
//! binds take pre-sliced payloads ([`CompiledPlan::bind_shard`]) so no
//! worker materializes the stream it does not own. Bind-vs-compile
//! cost is accounted in [`BindReport`].
//!
//! **How to run it** — [`exec`]: interchangeable executors selected by
//! [`ExecMode`]:
//!
//! * `Sequential` — in-thread, stage-at-a-time (the tabular shape);
//! * `Streaming` — one thread per stage over bounded channels with
//!   backpressure (the video/serving shape);
//! * `MultiInstance(n)` — n replicated plan instances aggregated by the
//!   scaler (§3.4 workload scaling: n copies of the stream);
//! * `Sharded(n)` — n data-parallel workers over ONE dataset: the
//!   source is partitioned round-robin by emission index ([`Sharder`])
//!   and sink state is merged in shard order, so a fixed dataset
//!   finishes faster instead of running more copies;
//! * `Async(t)` — cooperative task-based execution on a fixed pool of t
//!   workers ([`sched`]): every stage is a resumable task, no stage
//!   owns a thread, and one pool multiplexes many in-flight plans (the
//!   serving shape). Sharded runs now execute on the same scheduler,
//!   which lets the merge fold stream ahead of still-running shard
//!   passes instead of waiting on a barrier.
//!
//! **Who gets to run** — [`router`]: the serving-side admission layer.
//! An [`AdmissionQueue`] is a bounded priority queue with load shedding
//! (displaced and rejected requests are first-class shed outcomes, not
//! errors); [`crate::service::PipelineService`] routes typed requests
//! through it onto warm per-pipeline sessions.
//!
//! Any pipeline runs under any executor (`repro run <p> --exec …`), and
//! cross-cutting optimizations — dynamic batching ([`batcher`], a plan
//! node), telemetry ([`telemetry`], recorded identically by every
//! executor, the data behind Figure 1, now including per-item end-to-end
//! latency samples and cooperative-scheduler counters), instance
//! scaling ([`scaler`]), data-parallel sharding ([`plan::Sharder`] +
//! the merge-aware streaming sink in [`exec`]), cooperative task
//! scheduling ([`sched`]), admission control ([`router`]) — are
//! implemented once against the IR instead of per workload.

pub mod telemetry;
pub mod plan;
pub mod optimizer;
pub mod exec;
pub mod sched;
pub mod batcher;
pub mod router;
pub mod scaler;

pub use batcher::{BatcherConfig, DynamicBatcher};
pub use exec::{execute, run_multi_instance, run_sequential, run_sharded, run_streaming};
pub use exec::{run_async, run_async_on, run_async_seeded, spawn_async_on};
pub use exec::{run_sharded_async, run_sharded_seeded};
pub use exec::{ExecMode, ExecOutcome};
pub use optimizer::{optimize, optimize_profiled, render_graph};
pub use plan::{BoundPlan, CompiledPlan, CompiledPlanBuilder, Slicing, WorkloadSlice};
pub use plan::{Plan, PlanBuilder, PlanOutput, Sharder};
pub use router::{AdmissionQueue, AdmitOutcome, Priority, QueueStats};
pub use scaler::{run_instances, run_instances_timed, LatencyRecorder};
pub use scaler::{InstanceReport, ScalingReport};
pub use sched::{Poll, Scheduler, Signal, Task, VirtualScheduler, WaitGroup};
pub use telemetry::{BatchLedger, BatchReport};
pub use telemetry::{KernelLedger, KernelReport};
pub use telemetry::{
    BindReport, Category, OptReport, Report, SchedReport, ShardReport, ShardedReport, StageReport,
};
pub use telemetry::Telemetry;
