//! Plan executors — interchangeable strategies for running a [`Plan`].
//!
//! * [`run_sequential`] — in-thread, stage-at-a-time (the tabular shape):
//!   lowest overhead, items materialized between stages.
//! * [`run_streaming`] — one thread per stage over bounded channels (the
//!   video/serving shape): backpressure keeps memory flat and exposes the
//!   slowest stage; batch nodes use the [`DynamicBatcher`] max-wait flush.
//! * [`run_multi_instance`] — N replicated plan instances on worker
//!   threads (§3.4 workload scaling), aggregated by the scaler with
//!   fairness and latency percentiles.
//! * [`run_sharded`] — N data-parallel workers over ONE dataset: each
//!   worker runs the same stage graph with its source restricted to a
//!   round-robin partition ([`Sharder`]), and the sink state is merged
//!   in shard order. Where multi-instance scales compute by replicating
//!   the stream n times, sharding makes a fixed dataset finish faster
//!   (the tf.data / BigDL source-partition shape).
//! * [`run_async`] — cooperative task-based execution: the plan's
//!   stages become resumable tasks on a small fixed worker pool
//!   ([`Scheduler`]) — no thread per stage — so stages overlap like
//!   streaming while the thread count stays constant however many plans
//!   share the pool (the serving shape: one pool multiplexes many
//!   in-flight requests). [`run_async_seeded`] runs the same tasks
//!   under a seeded single-threaded interleaving for property tests.
//!
//! All five record the same per-stage [`Telemetry`], so every mode
//! yields the Figure 1 breakdown, and all five produce identical
//! deterministic metrics for a fixed seed — the executor-conformance
//! suite (`rust/tests/executor_equivalence.rs`) asserts exactly that.
//! Stages in async mode talk through FIFO mailboxes and each stage is
//! one resumable task, so items cross every stage in source-emission
//! order no matter how the scheduler interleaves polls — sink fold
//! order, batch boundaries, and therefore metrics equal sequential's.
//!
//! **Merge-aware sink contract (sharded mode).** Shard workers run
//! source → transforms only; no shard touches the sink. A merge task
//! then folds every shard's output into the single sink state in
//! ascending shard order (all of shard 0's items, then shard 1's, …) and
//! runs `finish` once. The fold order is therefore deterministic — a
//! permutation of the sequential order that depends only on the partition
//! arithmetic, never on thread timing. A plan is shardable when its sink
//! fold is insensitive to that permutation (single-state sinks, counter
//! sinks, and index-sorting accumulators all qualify — every registry
//! pipeline does; the conformance matrix pins it). Since the executors
//! moved onto the cooperative scheduler, the merge task **streams**:
//! shard s's fold begins as soon as shards 0..s have folded and shard
//! s's pass has landed, even while later passes are still running —
//! [`ShardedReport::streamed_folds`] counts the folds that overlapped a
//! running pass, replacing PR 3's full barrier without changing one
//! metric.
//!
//! Every item is stamped at source emission and its end-to-end latency
//! recorded when it completes the sink, so [`Report::latencies`] carries
//! measured per-item samples under every executor and the scaling
//! percentiles no longer fall back to instance wall time. Under the
//! streaming executor these are true in-flight latencies; under the
//! stage-at-a-time sequential executor an item's sink completion
//! necessarily trails the whole upstream pass, so its samples skew
//! toward the run duration (an honest property of that execution shape).

use super::batcher::DynamicBatcher;
use super::plan::{DynItem, Node, NodeKind, Plan, PlanOutput, Sharder, Stamped};
use super::scaler::{InstanceReport, ScalingReport};
use super::sched::{Poll, Scheduler, Signal, Task, VirtualScheduler, WaitGroup};
use super::telemetry::{
    Category, Report, SchedReport, ShardReport, ShardedReport, StageReport, Telemetry,
};
use crate::parallel::channel::bounded;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Which executor runs a plan; selected via `RunConfig::exec` or `--exec`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecMode {
    /// In-thread, stage-at-a-time.
    #[default]
    Sequential,
    /// Thread-per-stage over bounded channels with backpressure.
    Streaming,
    /// N replicated plan instances (each sequential), scaler-aggregated.
    /// Each instance processes its own stream: n× the data, n× the work.
    MultiInstance(usize),
    /// N data-parallel shards over one dataset: the source is partitioned
    /// round-robin across n workers sharing the stage graph, and sink
    /// state is merged in shard order (see the module docs for the
    /// merge-aware sink contract). Each worker runs 1/n of the transform
    /// and sink work. Compiled-plan callers bind each worker to a
    /// pre-sliced payload, so no worker materializes the stream it does
    /// not own; the plan-closure path falls back to cloning the full
    /// source per shard and filtering (pipeline-agnostic, but the
    /// redundant source passes cap the speedup on source-heavy plans).
    Sharded(usize),
    /// Cooperative task-based execution on a fixed pool of T workers:
    /// every stage is a resumable task, no stage owns a thread, and one
    /// pool can multiplex many in-flight plans (the serving shape).
    /// Metrics are identical to `Sequential` — items cross the FIFO
    /// stage mailboxes in source-emission order regardless of how the
    /// scheduler interleaves task polls.
    Async(usize),
}

/// Worker count a bare `--exec async` gets (matching the bare `multi` /
/// `shard` default of 2).
pub const DEFAULT_ASYNC_WORKERS: usize = 2;

/// Strict instance/shard count: ASCII digits only (no sign, no
/// whitespace, no garbage suffix), at least 1.
fn parse_count(s: &str) -> Option<usize> {
    if s.is_empty() || !s.bytes().all(|b| b.is_ascii_digit()) {
        return None;
    }
    s.parse().ok().filter(|&n| n >= 1)
}

impl ExecMode {
    /// Parse a CLI spelling: `sequential`, `streaming`, `multi[:<n>]`,
    /// `shard[:<n>]`, `async[:<t>]` (bare `multi` / `shard` / `async`
    /// default to 2). Counts must be plain positive integers —
    /// `multi:0`, `shard:0`, `async:0`, signs, whitespace, and trailing
    /// garbage are all rejected.
    pub fn parse(s: &str) -> Option<ExecMode> {
        match s {
            "sequential" | "seq" => Some(ExecMode::Sequential),
            "streaming" | "stream" => Some(ExecMode::Streaming),
            "multi" => Some(ExecMode::MultiInstance(2)),
            "shard" | "sharded" => Some(ExecMode::Sharded(2)),
            "async" => Some(ExecMode::Async(DEFAULT_ASYNC_WORKERS)),
            _ => {
                if let Some(rest) = s.strip_prefix("multi:") {
                    parse_count(rest).map(ExecMode::MultiInstance)
                } else if let Some(rest) = s.strip_prefix("shard:") {
                    parse_count(rest).map(ExecMode::Sharded)
                } else if let Some(rest) = s.strip_prefix("async:") {
                    parse_count(rest).map(ExecMode::Async)
                } else {
                    None
                }
            }
        }
    }
}

impl std::fmt::Display for ExecMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ExecMode::Sequential => f.write_str("sequential"),
            ExecMode::Streaming => f.write_str("streaming"),
            ExecMode::MultiInstance(n) => write!(f, "multi:{n}"),
            ExecMode::Sharded(n) => write!(f, "shard:{n}"),
            ExecMode::Async(n) => write!(f, "async:{n}"),
        }
    }
}

/// Bound on every inter-stage queue in streaming mode.
pub const DEFAULT_QUEUE_CAP: usize = 8;

/// What an executor returns: telemetry, the plan's output, and (for
/// multi-instance / sharded / async) the scaling, sharding, or
/// scheduler aggregate.
pub struct ExecOutcome {
    /// Per-stage timing (Figure 1 source). Multi-instance and sharded
    /// execution merge stage busy time and item counts across workers.
    pub report: Report,
    /// The plan's deterministic metrics and item count. Multi-instance
    /// reports instance 0's metrics with `items` summed over instances;
    /// sharded reports the merged sink's metrics over the one dataset.
    pub output: PlanOutput,
    /// Present only for multi-instance execution.
    pub scaling: Option<ScalingReport>,
    /// Present only for sharded execution: per-shard partition sizes and
    /// pooled per-item latencies.
    pub sharding: Option<ShardedReport>,
    /// Present for executors that ran on the cooperative task scheduler
    /// (async, and sharded runs, whose merge streams on it); `None`
    /// under the thread-based executors. Never part of the metric map.
    pub sched: Option<SchedReport>,
}

/// Dispatch a plan-builder through the executor selected by `mode`.
/// `make_plan` is invoked once per instance (instance 0 for the
/// single-instance modes) so every replica gets fresh stage closures.
/// Sharded execution calls `make_plan(0)` once per shard and restricts
/// each copy with [`Plan::shard`] — every shard must see the *same*
/// stream (sharding partitions one dataset; it never gives workers
/// distinct streams the way multi-instance does). This is the
/// clone-based sharding path; callers holding a
/// [`super::plan::CompiledPlan`] bind pre-sliced shard plans and call
/// [`run_sharded`] directly instead.
pub fn execute(
    mode: ExecMode,
    make_plan: impl Fn(usize) -> anyhow::Result<Plan> + Sync,
) -> anyhow::Result<ExecOutcome> {
    match mode {
        ExecMode::Sequential => run_sequential(make_plan(0)?),
        ExecMode::Streaming => run_streaming(make_plan(0)?, DEFAULT_QUEUE_CAP),
        ExecMode::MultiInstance(n) => run_multi_instance(n, make_plan),
        ExecMode::Sharded(n) => {
            run_sharded(n, |s| make_plan(0).map(|p| p.shard(Sharder::new(s, n))))
        }
        ExecMode::Async(workers) => run_async(make_plan(0)?, workers),
    }
}

/// The stage-at-a-time source+transform pass shared by the sequential
/// and sharded executors: run the source, then each transform node over
/// the whole stream, recording per-stage telemetry. Returns the stamped
/// pre-sink items. Batch nodes flush on size alone (every item is
/// already available, so the max-wait timer is irrelevant by
/// construction).
fn run_stages(
    telemetry: &Telemetry,
    source: (String, Category, crate::coordinator::plan::SourceFn),
    nodes: Vec<Node>,
) -> anyhow::Result<Vec<Stamped>> {
    let (src_name, src_cat, mut produce) = source;
    let handle = telemetry.stage(&src_name, src_cat);
    let mut items: Vec<Stamped> = Vec::new();
    let t0 = Instant::now();
    let mut produced = 0usize;
    produce(&mut |item| {
        produced += 1;
        items.push(Stamped { born: Instant::now(), item });
    });
    handle.record(t0.elapsed(), produced);

    for node in nodes {
        let handle = telemetry.stage(&node.name, node.category);
        match node.kind {
            NodeKind::FlatMap(mut f) => {
                let mut next = Vec::with_capacity(items.len());
                for Stamped { born, item } in items {
                    let t0 = Instant::now();
                    let outs = f(item)?;
                    handle.record(t0.elapsed(), 1);
                    next.extend(outs.into_iter().map(|item| Stamped { born, item }));
                }
                items = next;
            }
            NodeKind::Batch(cfg, mut group) => {
                let max = cfg.max_batch.max(1);
                let mut next = Vec::new();
                let mut iter = items.into_iter().peekable();
                while iter.peek().is_some() {
                    let batch: Vec<Stamped> = iter.by_ref().take(max).collect();
                    let born = batch.iter().map(|s| s.born).min().expect("non-empty batch");
                    let members: Vec<DynItem> = batch.into_iter().map(|s| s.item).collect();
                    let t0 = Instant::now();
                    next.push(Stamped { born, item: group(members)? });
                    handle.record(t0.elapsed(), 1);
                }
                items = next;
            }
        }
    }
    Ok(items)
}

/// Run a plan in the calling thread, one stage at a time over the whole
/// item stream.
pub fn run_sequential(plan: Plan) -> anyhow::Result<ExecOutcome> {
    let telemetry = Telemetry::new();
    let Plan { source, nodes, sink, finish, .. } = plan;
    let items = run_stages(&telemetry, source, nodes)?;

    let (sink_name, sink_cat, mut sink_fn) = sink;
    let handle = telemetry.stage(&sink_name, sink_cat);
    for Stamped { born, item } in items {
        let t0 = Instant::now();
        sink_fn(item)?;
        handle.record(t0.elapsed(), 1);
        telemetry.record_latency(born.elapsed());
    }
    let output = finish()?;
    Ok(ExecOutcome {
        report: telemetry.report(),
        output,
        scaling: None,
        sharding: None,
        sched: None,
    })
}

/// Run a plan with one thread per stage connected by bounded channels, so
/// a slow stage backpressures everything upstream. The sink folds on the
/// calling thread. Source busy time subtracts send-blocking (that is the
/// downstream stage's cost, not production work — counting it would smear
/// the slowest stage over the source in the Figure 1 breakdown).
pub fn run_streaming(plan: Plan, queue_cap: usize) -> anyhow::Result<ExecOutcome> {
    let telemetry = Telemetry::new();
    let cap = queue_cap.max(1);
    let first_err: Arc<Mutex<Option<anyhow::Error>>> = Arc::new(Mutex::new(None));
    let Plan { source: (src_name, src_cat, mut produce), nodes, sink, finish, .. } = plan;
    let (sink_name, sink_cat, mut sink_fn) = sink;
    let mut workers = Vec::with_capacity(nodes.len() + 1);

    let handle = telemetry.stage(&src_name, src_cat);
    let (tx, mut tail) = bounded::<Stamped>(cap);
    workers.push(
        std::thread::Builder::new()
            .name(format!("plan-src-{src_name}"))
            .spawn(move || {
                let t0 = Instant::now();
                let mut blocked = std::time::Duration::ZERO;
                let mut count = 0usize;
                produce(&mut |item| {
                    count += 1;
                    let stamped = Stamped { born: Instant::now(), item };
                    let s0 = Instant::now();
                    let _ = tx.send(stamped);
                    blocked += s0.elapsed();
                });
                handle.record(t0.elapsed().saturating_sub(blocked), count);
            })
            .expect("spawn plan source"),
    );

    for node in nodes {
        let handle = telemetry.stage(&node.name, node.category);
        let (tx, rx) = bounded::<Stamped>(cap);
        let upstream = tail;
        tail = rx;
        let errs = Arc::clone(&first_err);
        let worker = match node.kind {
            NodeKind::FlatMap(mut f) => std::thread::Builder::new()
                .name(format!("plan-stage-{}", node.name))
                .spawn(move || {
                    while let Ok(Stamped { born, item }) = upstream.recv() {
                        let t0 = Instant::now();
                        match f(item) {
                            Ok(outs) => {
                                handle.record(t0.elapsed(), 1);
                                for out in outs {
                                    if tx.send(Stamped { born, item: out }).is_err() {
                                        return; // downstream gone
                                    }
                                }
                            }
                            Err(e) => {
                                errs.lock().unwrap().get_or_insert(e);
                                return;
                            }
                        }
                    }
                })
                .expect("spawn plan stage"),
            NodeKind::Batch(cfg, mut group) => std::thread::Builder::new()
                .name(format!("plan-batch-{}", node.name))
                .spawn(move || {
                    let mut batcher = DynamicBatcher::new(upstream, cfg);
                    while let Some(batch) = batcher.next_batch() {
                        let born =
                            batch.iter().map(|s| s.born).min().expect("non-empty batch");
                        let members: Vec<DynItem> =
                            batch.into_iter().map(|s| s.item).collect();
                        let t0 = Instant::now();
                        match group(members) {
                            Ok(item) => {
                                handle.record(t0.elapsed(), 1);
                                if tx.send(Stamped { born, item }).is_err() {
                                    return;
                                }
                            }
                            Err(e) => {
                                errs.lock().unwrap().get_or_insert(e);
                                return;
                            }
                        }
                    }
                })
                .expect("spawn plan batch"),
        };
        workers.push(worker);
    }

    let handle = telemetry.stage(&sink_name, sink_cat);
    while let Ok(Stamped { born, item }) = tail.recv() {
        let t0 = Instant::now();
        if let Err(e) = sink_fn(item) {
            first_err.lock().unwrap().get_or_insert(e);
            break;
        }
        handle.record(t0.elapsed(), 1);
        telemetry.record_latency(born.elapsed());
    }
    // Dropping the tail receiver makes upstream sends fail fast if we
    // broke out early; workers then unwind without deadlocking.
    drop(tail);
    let mut panicked: Option<String> = None;
    for worker in workers {
        let name = worker.thread().name().unwrap_or("plan-worker").to_string();
        if let Err(payload) = worker.join() {
            let msg = panic_message(payload);
            panicked.get_or_insert(format!("{name} panicked: {msg}"));
        }
    }
    if let Some(e) = first_err.lock().unwrap().take() {
        return Err(e);
    }
    // A stage panic must surface as loudly as it would under the
    // sequential executor, not as partial metrics.
    if let Some(msg) = panicked {
        return Err(anyhow::anyhow!("streaming stage failed: {msg}"));
    }
    let output = finish()?;
    Ok(ExecOutcome {
        report: telemetry.report(),
        output,
        scaling: None,
        sharding: None,
        sched: None,
    })
}

/// Run `n` replicated instances of the plan on worker threads (each
/// instance sequential — the paper's parallel-streams shape), and
/// aggregate throughput, fairness, and latency percentiles. The merged
/// report sums per-stage busy time and items across instances.
pub fn run_multi_instance(
    n: usize,
    make_plan: impl Fn(usize) -> anyhow::Result<Plan> + Sync,
) -> anyhow::Result<ExecOutcome> {
    anyhow::ensure!(n >= 1, "multi-instance execution needs at least one instance");
    let t0 = Instant::now();
    let mut results: Vec<(anyhow::Result<ExecOutcome>, std::time::Duration)> =
        Vec::with_capacity(n);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..n)
            .map(|i| {
                let make_plan = &make_plan;
                scope.spawn(move || {
                    // Plan construction (data generation, model warmup) is
                    // explicitly outside the timed run — the pipelines
                    // measure steady state, and the scaling metrics must
                    // match that.
                    let plan = make_plan(i);
                    let it0 = Instant::now();
                    let res = plan.and_then(run_sequential);
                    (res, it0.elapsed())
                })
            })
            .collect();
        for h in handles {
            results.push(h.join().expect("plan instance panicked"));
        }
    });
    let wall = t0.elapsed();

    let mut instances = Vec::with_capacity(n);
    let mut reports: Vec<Report> = Vec::with_capacity(n);
    let mut first_output: Option<PlanOutput> = None;
    for (i, (res, elapsed)) in results.into_iter().enumerate() {
        let outcome = res?;
        instances.push(InstanceReport {
            instance: i,
            items: outcome.output.items,
            elapsed,
            // Per-item samples recorded by the instance's sink. Each
            // replica runs sequentially (stage-at-a-time), so samples
            // approximate the instance pass for multi-item plans — still
            // measured per item, no longer the wall-time fallback.
            latencies: outcome.report.latencies.clone(),
        });
        reports.push(outcome.report);
        if first_output.is_none() {
            first_output = Some(outcome.output);
        }
    }
    let scaling = ScalingReport { instances, wall };
    let mut output = first_output.expect("n >= 1 guarantees one outcome");
    output.items = scaling.total_items();
    Ok(ExecOutcome {
        report: merge_reports(&reports),
        output,
        scaling: Some(scaling),
        sharding: None,
        sched: None,
    })
}

/// Items a resumable stage task processes per poll before yielding its
/// worker — small enough that one pool multiplexes many stages (and
/// many plans) fairly, large enough to amortize the mailbox locks.
pub const ASYNC_TASK_CHUNK: usize = 32;

/// Unbounded FIFO mailbox between two resumable stage tasks. `close`
/// publishes "producer finished" *after* the final push, and readers
/// check the flag *before* draining — so a reader that observes
/// `closed` over an empty queue has seen every item. Every push and the
/// close notify the mailbox's [`Signal`], so a consumer task blocked on
/// an empty mailbox parks on the signal ([`Poll::Park`]) instead of
/// spinning the scheduler's run queue.
struct Mailbox {
    queue: Mutex<VecDeque<Stamped>>,
    done: AtomicBool,
    signal: Signal,
}

impl Mailbox {
    fn new() -> Arc<Mailbox> {
        Arc::new(Mailbox {
            queue: Mutex::new(VecDeque::new()),
            done: AtomicBool::new(false),
            signal: Signal::new(),
        })
    }

    fn push(&self, s: Stamped) {
        self.queue.lock().unwrap().push_back(s);
        self.signal.notify();
    }

    fn drain(&self, max: usize) -> Vec<Stamped> {
        let mut q = self.queue.lock().unwrap();
        let take = q.len().min(max);
        q.drain(..take).collect()
    }

    fn close(&self) {
        self.done.store(true, Ordering::Release);
        self.signal.notify();
    }

    fn is_closed(&self) -> bool {
        self.done.load(Ordering::Acquire)
    }

    /// Park point for this mailbox's consumer: snapshot BEFORE checking
    /// `is_closed`/`drain`, park with the snapshot if both came up
    /// empty.
    fn signal(&self) -> &Signal {
        &self.signal
    }
}

/// Shared failure state of one task-based run: the first error wins and
/// flips the abort flag; every task checks the flag at poll start and
/// unwinds cooperatively (closing its downstream mailbox) so the run
/// drains instead of deadlocking. `fail` also notifies every watched
/// wakeup signal (the run's mailboxes, a sharded run's slot signal):
/// a PANICKING task cannot run its own close/notify cleanup, so
/// without the broadcast a consumer parked on the panicked stage's
/// output would sleep forever instead of waking, observing the abort,
/// and unwinding — the panic-containment guarantee the streaming
/// executor gives would silently become a hang.
#[derive(Clone)]
struct AbortHandle {
    first_err: Arc<Mutex<Option<anyhow::Error>>>,
    aborted: Arc<AtomicBool>,
    wakers: Arc<Mutex<Vec<Signal>>>,
}

impl AbortHandle {
    fn new() -> AbortHandle {
        AbortHandle {
            first_err: Arc::new(Mutex::new(None)),
            aborted: Arc::new(AtomicBool::new(false)),
            wakers: Arc::new(Mutex::new(Vec::new())),
        }
    }

    /// Register a signal to notify on abort, waking anything parked on
    /// it. Signals bump a generation on notify, so a task that decides
    /// to park AFTER the broadcast still requeues instead of sleeping.
    fn watch(&self, signal: &Signal) {
        self.wakers.lock().unwrap().push(signal.clone());
    }

    fn fail(&self, e: anyhow::Error) {
        self.first_err.lock().unwrap().get_or_insert(e);
        self.aborted.store(true, Ordering::Release);
        for signal in self.wakers.lock().unwrap().iter() {
            signal.notify();
        }
    }

    fn is_aborted(&self) -> bool {
        self.aborted.load(Ordering::Acquire)
    }

    fn take_err(&self) -> Option<anyhow::Error> {
        self.first_err.lock().unwrap().take()
    }
}

/// Handles for observing one task-based run: shared by the spawned
/// tasks, read once the run's WaitGroup drains (or by the completion
/// hook when the sink task finishes).
struct AsyncRun {
    telemetry: Telemetry,
    abort: AbortHandle,
    output: Arc<Mutex<Option<PlanOutput>>>,
    wg: WaitGroup,
}

impl AsyncRun {
    fn new() -> AsyncRun {
        AsyncRun {
            telemetry: Telemetry::new(),
            abort: AbortHandle::new(),
            output: Arc::new(Mutex::new(None)),
            wg: WaitGroup::new(),
        }
    }
}

/// What [`spawn_async_on`] calls when a plan's sink task finishes —
/// normal completion, first error, or stage panic alike. The serving
/// layer uses it to resolve a ticket without blocking a dispatcher.
type CompletionFn = Box<dyn FnOnce(anyhow::Result<ExecOutcome>) + Send>;

fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    payload
        .downcast_ref::<&str>()
        .map(|s| s.to_string())
        .or_else(|| payload.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "non-string panic payload".to_string())
}

/// Turn a run's shared handles into its outcome (scheduler counters are
/// attached by the caller, which knows which pool ran the tasks).
fn assemble_async(
    telemetry: &Telemetry,
    abort: &AbortHandle,
    output: &Mutex<Option<PlanOutput>>,
) -> anyhow::Result<ExecOutcome> {
    if let Some(e) = abort.take_err() {
        return Err(e);
    }
    let out = output
        .lock()
        .unwrap()
        .take()
        .ok_or_else(|| anyhow::anyhow!("async plan finished without producing output"))?;
    Ok(ExecOutcome {
        report: telemetry.report(),
        output: out,
        scaling: None,
        sharding: None,
        sched: None,
    })
}

/// Wrap a raw stage task with the run's bookkeeping: WaitGroup
/// registration, panic containment (a stage panic becomes the run's
/// first error, exactly as loudly as the streaming executor reports
/// it), and — for the sink task — the one-shot completion hook.
fn track(
    run: &AsyncRun,
    mut on_done: Option<CompletionFn>,
    mut task: impl FnMut() -> Poll + Send + 'static,
) -> Task {
    run.wg.add(1);
    let wg = run.wg.clone();
    let abort = run.abort.clone();
    let telemetry = run.telemetry.clone();
    let output = Arc::clone(&run.output);
    let mut finished = false;
    Box::new(move || {
        if finished {
            return Poll::Done;
        }
        let poll = std::panic::catch_unwind(std::panic::AssertUnwindSafe(&mut task))
            .unwrap_or_else(|payload| {
                abort.fail(anyhow::anyhow!(
                    "async stage panicked: {}",
                    panic_message(payload)
                ));
                Poll::Done
            });
        if matches!(poll, Poll::Done) {
            finished = true;
            if let Some(f) = on_done.take() {
                f(assemble_async(&telemetry, &abort, &output));
            }
            wg.done();
        }
        poll
    })
}

/// Decompose a plan into resumable stage tasks and hand them to `spawn`
/// (a scheduler's spawn hook). Stages talk through FIFO mailboxes and
/// each stage is exactly one task, so items cross every stage in
/// source-emission order — sink fold order and every deterministic
/// metric equal the sequential executor's regardless of how the
/// scheduler interleaves polls. `on_done`, when given, fires exactly
/// once when the sink task completes (error and panic paths included).
fn spawn_plan_tasks(
    plan: Plan,
    spawn: &mut dyn FnMut(Task),
    on_done: Option<CompletionFn>,
) -> AsyncRun {
    let run = AsyncRun::new();
    let Plan { source: (src_name, src_cat, mut produce), nodes, sink, finish, .. } = plan;

    // Register every stage up front, in plan order, so the report's
    // stage order matches the sequential executor's.
    let src_handle = run.telemetry.stage(&src_name, src_cat);
    let resumable: Vec<_> = nodes.into_iter().map(Node::into_resumable).collect();
    let node_handles: Vec<_> =
        resumable.iter().map(|n| run.telemetry.stage(&n.name, n.category)).collect();
    let (sink_name, sink_cat, mut sink_fn) = sink;
    let sink_handle = run.telemetry.stage(&sink_name, sink_cat);

    // source → mailbox[0] → node 0 → mailbox[1] → … → sink
    let mut mailboxes = vec![Mailbox::new()];
    for _ in &resumable {
        mailboxes.push(Mailbox::new());
    }
    // An abort (error or contained panic) must wake every parked
    // consumer: a panicked producer cannot close its own mailbox.
    for mailbox in &mailboxes {
        run.abort.watch(mailbox.signal());
    }

    // Source task: the source closure cannot be suspended mid-stream,
    // so it runs in one poll — pushing each emission as it happens, so
    // downstream tasks on other workers start before it returns.
    {
        let out = Arc::clone(&mailboxes[0]);
        let abort = run.abort.clone();
        spawn(track(&run, None, move || {
            if abort.is_aborted() {
                out.close();
                return Poll::Done;
            }
            let t0 = Instant::now();
            let mut count = 0usize;
            produce(&mut |item| {
                count += 1;
                out.push(Stamped { born: Instant::now(), item });
            });
            src_handle.record(t0.elapsed(), count);
            out.close();
            Poll::Done
        }));
    }

    // One resumable task per transform node: drain a chunk, process it,
    // yield; flush and close downstream when upstream is exhausted;
    // park on the input mailbox's signal when starved.
    for (i, (mut node, handle)) in resumable.into_iter().zip(node_handles).enumerate() {
        let input = Arc::clone(&mailboxes[i]);
        let output = Arc::clone(&mailboxes[i + 1]);
        let abort = run.abort.clone();
        spawn(track(&run, None, move || {
            if abort.is_aborted() {
                output.close();
                return Poll::Done;
            }
            // Snapshot the wakeup generation BEFORE the emptiness
            // checks: a push/close that races them bumps the
            // generation, so the park below requeues instead of
            // missing the wakeup.
            let seen = input.signal().generation();
            let upstream_done = input.is_closed();
            let items = input.drain(ASYNC_TASK_CHUNK);
            if items.is_empty() {
                if !upstream_done {
                    return Poll::Park { signal: input.signal().clone(), seen };
                }
                let t0 = Instant::now();
                match node.flush() {
                    Ok((outs, units)) => {
                        if units > 0 {
                            handle.record(t0.elapsed(), units);
                        }
                        for o in outs {
                            output.push(o);
                        }
                        output.close();
                        Poll::Done
                    }
                    Err(e) => {
                        abort.fail(e);
                        output.close();
                        Poll::Done
                    }
                }
            } else {
                for s in items {
                    let t0 = Instant::now();
                    match node.push(s) {
                        Ok((outs, units)) => {
                            if units > 0 {
                                handle.record(t0.elapsed(), units);
                            }
                            for o in outs {
                                output.push(o);
                            }
                        }
                        Err(e) => {
                            abort.fail(e);
                            output.close();
                            return Poll::Done;
                        }
                    }
                }
                Poll::Yield
            }
        }));
    }

    // Sink task: fold arrivals in order, record per-item latency, and
    // run `finish` once upstream is exhausted.
    {
        let input = Arc::clone(&mailboxes[mailboxes.len() - 1]);
        let abort = run.abort.clone();
        let telemetry = run.telemetry.clone();
        let output_slot = Arc::clone(&run.output);
        let mut finish = Some(finish);
        spawn(track(&run, on_done, move || {
            if abort.is_aborted() {
                return Poll::Done;
            }
            let seen = input.signal().generation();
            let upstream_done = input.is_closed();
            let items = input.drain(ASYNC_TASK_CHUNK);
            if items.is_empty() {
                if !upstream_done {
                    return Poll::Park { signal: input.signal().clone(), seen };
                }
                let finish = finish.take().expect("async sink finished twice");
                match finish() {
                    Ok(out) => {
                        *output_slot.lock().unwrap() = Some(out);
                    }
                    Err(e) => abort.fail(e),
                }
                Poll::Done
            } else {
                for Stamped { born, item } in items {
                    let t0 = Instant::now();
                    if let Err(e) = sink_fn(item) {
                        abort.fail(e);
                        return Poll::Done;
                    }
                    sink_handle.record(t0.elapsed(), 1);
                    telemetry.record_latency(born.elapsed());
                }
                Poll::Yield
            }
        }));
    }
    run
}

/// Run a plan as cooperative tasks on a private pool of `workers`
/// threads (see [`ExecMode::Async`]). Blocks until the plan drains;
/// metrics are identical to [`run_sequential`]'s.
pub fn run_async(plan: Plan, workers: usize) -> anyhow::Result<ExecOutcome> {
    let sched = Scheduler::new(workers);
    run_async_on(plan, &sched)
}

/// Like [`run_async`], but on a caller-owned (possibly shared) pool;
/// blocks until *this plan's* tasks complete. The attached counters
/// snapshot the pool, so on a shared pool they are cumulative across
/// every plan it has run.
pub fn run_async_on(plan: Plan, sched: &Scheduler) -> anyhow::Result<ExecOutcome> {
    let run = spawn_plan_tasks(plan, &mut |t| sched.spawn(t), None);
    run.wg.wait();
    let mut outcome = assemble_async(&run.telemetry, &run.abort, &run.output)?;
    outcome.sched = Some(sched.counters());
    Ok(outcome)
}

/// Spawn a plan's tasks on a shared pool WITHOUT blocking: `on_done`
/// fires exactly once — with the outcome, the first stage error, or a
/// contained stage panic — when the sink task completes. This is the
/// serving hook: one dispatcher thread holds many plans in flight on
/// one pool.
pub fn spawn_async_on(
    plan: Plan,
    sched: &Scheduler,
    on_done: impl FnOnce(anyhow::Result<ExecOutcome>) + Send + 'static,
) {
    spawn_plan_tasks(plan, &mut |t| sched.spawn(t), Some(Box::new(on_done)));
}

/// Run a plan's tasks single-threaded under a seeded random
/// interleaving — no wall clock, no threads ([`VirtualScheduler`]).
/// For every seed the metrics equal [`run_sequential`]'s; the property
/// suites pin exactly that.
pub fn run_async_seeded(plan: Plan, seed: u64) -> anyhow::Result<ExecOutcome> {
    let mut vs = VirtualScheduler::new(seed);
    let run = spawn_plan_tasks(plan, &mut |t| vs.spawn(t), None);
    let counters = vs.run_to_idle();
    let mut outcome = assemble_async(&run.telemetry, &run.abort, &run.output)?;
    outcome.sched = Some(counters);
    Ok(outcome)
}

type ShardSink = (
    (String, Category, crate::coordinator::plan::SinkFn),
    crate::coordinator::plan::FinishFn,
);

/// One shard pass's result, parked in its slot until the merge task
/// folds it (in shard order).
struct ShardPassDone {
    items: Vec<Stamped>,
    report: Report,
    elapsed: Duration,
}

/// Shared state of one sharded run: per-shard pass results parked for
/// the merge task, the count of passes still running (what makes
/// "the merge streamed" observable without timing), the signal the
/// merge task parks on while the next shard's pass is outstanding, and
/// the merge task's assembled result.
struct ShardedState {
    slots: Vec<Mutex<Option<ShardPassDone>>>,
    passes_left: AtomicUsize,
    /// Notified by every pass task on completion (success, error, or
    /// abort), so a merge task parked on an empty next slot wakes.
    signal: Signal,
    result: Mutex<Option<(Report, PlanOutput, ShardedReport)>>,
    started: Instant,
}

/// Spawn one sharded run's tasks — `n` pass tasks plus the streaming
/// merge task — onto `spawn`. `make_plan(s)` must return shard `s`'s
/// ALREADY-partitioned plan: either a full plan restricted with
/// [`Plan::shard`] (the clone-based path — pipeline-agnostic, pays the
/// full source pass per shard) or a [`super::plan::CompiledPlan`]
/// shard bind over a pre-sliced workload (the payload-aware path — no
/// redundant source passes). Shard 0's sink is the merge sink, so it
/// must account for the whole dataset. Plans are built up front, one
/// builder thread per shard (construction — payload binding, model
/// warmup — stays outside the timed pass and stays parallel; DL plans
/// share the one ModelServer across shards), so a plan-build error
/// surfaces here, before any task runs. Building eagerly is what lets
/// the pass tasks be `'static` while `make_plan` stays borrowed.
fn spawn_sharded_tasks(
    n: usize,
    spawn: &mut dyn FnMut(Task),
    make_plan: impl Fn(usize) -> anyhow::Result<Plan> + Sync,
) -> anyhow::Result<(AsyncRun, Arc<ShardedState>)> {
    anyhow::ensure!(n >= 1, "sharded execution needs at least one shard");
    let run = AsyncRun::new();
    let state = Arc::new(ShardedState {
        slots: (0..n).map(|_| Mutex::new(None)).collect(),
        passes_left: AtomicUsize::new(n),
        signal: Signal::new(),
        result: Mutex::new(None),
        started: Instant::now(),
    });
    // A pass task that PANICS cannot decrement `passes_left` or notify;
    // the abort broadcast wakes a merge task parked on the slot signal
    // so it observes the abort instead of sleeping forever.
    run.abort.watch(&state.signal);

    let mut built: Vec<anyhow::Result<Plan>> = Vec::with_capacity(n);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..n)
            .map(|s| {
                let make_plan = &make_plan;
                scope.spawn(move || make_plan(s))
            })
            .collect();
        for h in handles {
            built.push(h.join().expect("shard plan builder panicked"));
        }
    });

    let mut donated_sink: Option<ShardSink> = None;
    let mut pass_inputs = Vec::with_capacity(n);
    for (s, plan) in built.into_iter().enumerate() {
        let Plan { source, nodes, sink, finish, .. } = plan?;
        if s == 0 {
            donated_sink = Some((sink, finish));
        }
        pass_inputs.push((source, nodes));
    }

    // Pass tasks: source → transforms for one shard, parked in its slot.
    // A pass is one poll (the source closure cannot be suspended).
    for (s, pass_input) in pass_inputs.into_iter().enumerate() {
        let state_pass = Arc::clone(&state);
        let abort = run.abort.clone();
        let mut input = Some(pass_input);
        spawn(track(&run, None, move || {
            let (source, nodes) = input.take().expect("shard pass polled twice");
            if abort.is_aborted() {
                state_pass.passes_left.fetch_sub(1, Ordering::AcqRel);
                state_pass.signal.notify();
                return Poll::Done;
            }
            let it0 = Instant::now();
            let telemetry = Telemetry::new();
            match run_stages(&telemetry, source, nodes) {
                Ok(items) => {
                    // Decrement BEFORE parking: the merge task reads
                    // `passes_left` only after taking a parked slot, so
                    // a fold must never count its own shard's finishing
                    // pass as "still running" (a single shard's fold is
                    // then guaranteed streamed_folds == 0).
                    state_pass.passes_left.fetch_sub(1, Ordering::AcqRel);
                    *state_pass.slots[s].lock().unwrap() = Some(ShardPassDone {
                        items,
                        report: telemetry.report(),
                        elapsed: it0.elapsed(),
                    });
                }
                Err(e) => {
                    state_pass.passes_left.fetch_sub(1, Ordering::AcqRel);
                    abort.fail(e);
                }
            }
            // Every exit wakes a merge task parked on the next slot.
            state_pass.signal.notify();
            Poll::Done
        }));
    }

    // Merge task: folds each shard's parked items into shard 0's sink
    // in STRICT shard order — the merge-aware sink contract — but
    // begins a shard's fold as soon as that shard (and every earlier
    // one) has landed, even while later passes are still running. On a
    // pool with ≥ 2 workers, or under a favorable seeded interleaving,
    // the fold therefore overlaps the tail shards instead of waiting on
    // PR 3's full barrier; `streamed_folds` counts the overlapped folds
    // so tests assert the streaming via counters, never timing.
    let ((sink_name, sink_cat, mut sink_fn), finish) =
        donated_sink.expect("n >= 1 guarantees shard 0 donates the merge sink");
    let mut finish = Some(finish);
    let state_merge = Arc::clone(&state);
    let abort = run.abort.clone();
    let mut next = 0usize;
    let mut reports: Vec<Report> = Vec::with_capacity(n);
    let mut shards: Vec<ShardReport> = Vec::with_capacity(n);
    let mut sink_busy = Duration::ZERO;
    let mut sink_count = 0usize;
    let mut streamed_folds = 0usize;
    spawn(track(&run, None, move || {
        if abort.is_aborted() {
            return Poll::Done;
        }
        if next < n {
            // Snapshot before checking the slot so a pass landing (and
            // notifying) mid-check requeues the park instead of losing
            // the wakeup.
            let seen = state_merge.signal.generation();
            let parked = state_merge.slots[next].lock().unwrap().take();
            let Some(pass) = parked else {
                return Poll::Park { signal: state_merge.signal.clone(), seen };
            };
            // This fold begins now; it streamed when at least one shard
            // pass task had not finished yet.
            if state_merge.passes_left.load(Ordering::Acquire) > 0 {
                streamed_folds += 1;
            }
            let ShardPassDone { items, report, elapsed } = pass;
            // Owned emissions = the shard's source stage count (the
            // filtered source only forwards — and the pass only counts
            // — items the shard's partition owns).
            let owned = report.stages.first().map_or(0, |st| st.items);
            let mut latencies = Vec::with_capacity(items.len());
            for Stamped { born, item } in items {
                let f0 = Instant::now();
                if let Err(e) = sink_fn(item) {
                    abort.fail(e);
                    return Poll::Done;
                }
                sink_busy += f0.elapsed();
                sink_count += 1;
                latencies.push(born.elapsed());
            }
            shards.push(ShardReport {
                shard: next,
                owned,
                completed: latencies.len(),
                elapsed,
                latencies,
            });
            reports.push(report);
            next += 1;
            return Poll::Yield;
        }
        // Every shard folded: finish once and assemble the result.
        let finish = finish.take().expect("sharded merge finished twice");
        let out = match finish() {
            Ok(out) => out,
            Err(e) => {
                abort.fail(e);
                return Poll::Done;
            }
        };
        let mut merged = merge_reports(&reports);
        for s in &shards {
            merged.latencies.extend_from_slice(&s.latencies);
        }
        merged.stages.push(StageReport {
            name: sink_name.clone(),
            category: sink_cat,
            items: sink_count,
            busy: sink_busy,
        });
        let sharding = ShardedReport {
            shards: std::mem::take(&mut shards),
            wall: state_merge.started.elapsed(),
            streamed_folds,
        };
        *state_merge.result.lock().unwrap() = Some((merged, out, sharding));
        Poll::Done
    }));
    Ok((run, state))
}

/// Turn a drained sharded run into its outcome.
fn finish_sharded(
    run: &AsyncRun,
    state: &ShardedState,
    counters: SchedReport,
) -> anyhow::Result<ExecOutcome> {
    if let Some(e) = run.abort.take_err() {
        return Err(e);
    }
    let (report, output, sharding) = state
        .result
        .lock()
        .unwrap()
        .take()
        .expect("sharded merge finished without a result");
    Ok(ExecOutcome {
        report,
        output,
        scaling: None,
        sharding: Some(sharding),
        sched: Some(counters),
    })
}

/// Run one dataset as `n` data-parallel shards (§3.4 turned from
/// replication into partitioning): `make_plan(s)` builds shard `s`'s
/// already-partitioned plan — deterministically, all shards over the
/// same one dataset — and each shard runs source → transforms as a
/// task on a pool of `n` workers. No shard touches the sink; the merge
/// task folds all pre-sink items into shard 0's sink **in shard order**
/// and runs `finish` once (the merge-aware sink contract — see the
/// module docs), streaming the folds ahead of still-running passes.
/// Metrics are therefore deterministic and, for fold-order-insensitive
/// sinks, identical to a sequential run of the same plan; `Sharded(1)`
/// is always identical to `Sequential`.
///
/// Cost model, by how `make_plan` partitions:
/// * **Clone-based** ([`Plan::shard`] over a full plan, what
///   [`execute`] does): the full source pass runs once *per shard*,
///   each worker dropping the emissions it does not own — pipeline-
///   agnostic, but the redundant source passes cap the speedup on
///   source-heavy plans.
/// * **Payload-aware** ([`super::plan::CompiledPlan::bind_shard`] over
///   a pre-sliced workload, the serving path): each shard's source
///   materializes only its own partition, so the n-times source pass
///   disappears while the round-robin emission-index semantics — and
///   therefore every metric — stay bit-identical.
pub fn run_sharded(
    n: usize,
    make_plan: impl Fn(usize) -> anyhow::Result<Plan> + Sync,
) -> anyhow::Result<ExecOutcome> {
    run_sharded_async(n, n, make_plan)
}

/// Sharded execution composed with the async executor: the `n` shard
/// passes and the streaming merge run as cooperative tasks on a pool of
/// `workers` threads (so `workers < n` time-slices the passes instead
/// of oversubscribing, and `workers ≥ 2` lets the merge overlap the
/// tail passes). Metrics equal [`run_sharded`]'s — which equal
/// [`run_sequential`]'s — for any worker count.
pub fn run_sharded_async(
    n: usize,
    workers: usize,
    make_plan: impl Fn(usize) -> anyhow::Result<Plan> + Sync,
) -> anyhow::Result<ExecOutcome> {
    anyhow::ensure!(n >= 1, "sharded execution needs at least one shard");
    let sched = Scheduler::new(workers);
    let (run, state) = spawn_sharded_tasks(n, &mut |t| sched.spawn(t), make_plan)?;
    run.wg.wait();
    let counters = sched.counters();
    finish_sharded(&run, &state, counters)
}

/// Sharded execution under a seeded single-threaded interleaving
/// ([`VirtualScheduler`]): the property-test hook pinning that merge
/// streaming never changes a metric. For every seed the metrics equal
/// [`run_sequential`]'s; across seeds the interleaving — and therefore
/// [`ShardedReport::streamed_folds`] — varies deterministically.
pub fn run_sharded_seeded(
    n: usize,
    seed: u64,
    make_plan: impl Fn(usize) -> anyhow::Result<Plan> + Sync,
) -> anyhow::Result<ExecOutcome> {
    let mut vs = VirtualScheduler::new(seed);
    let (run, state) = spawn_sharded_tasks(n, &mut |t| vs.spawn(t), make_plan)?;
    let counters = vs.run_to_idle();
    finish_sharded(&run, &state, counters)
}

fn merge_reports(reports: &[Report]) -> Report {
    let mut merged = reports[0].clone();
    for r in &reports[1..] {
        for (m, s) in merged.stages.iter_mut().zip(&r.stages) {
            debug_assert_eq!(m.name, s.name, "instances must share a stage structure");
            m.busy += s.busy;
            m.items += s.items;
        }
        merged.latencies.extend_from_slice(&r.latencies);
    }
    merged
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::BatcherConfig;
    use crate::coordinator::telemetry::Category;
    use std::collections::BTreeMap;
    use std::time::Duration;

    /// Clone-based shard builder for tests: the full plan restricted to
    /// shard `s` of `n` (what `execute` does for plan-closure callers).
    fn cloned(
        n: usize,
        make: impl Fn() -> anyhow::Result<Plan> + Sync,
    ) -> impl Fn(usize) -> anyhow::Result<Plan> + Sync {
        move |s| make().map(|p| p.shard(Sharder::new(s, n)))
    }

    /// source 0..n → double → drop odd halves → collect; returns sum.
    fn arithmetic_plan(n: i32) -> Plan {
        Plan::source("t", "gen", Category::Pre, move |emit| {
            for i in 0..n {
                emit(i);
            }
        })
        .map("double", Category::Pre, |x: i32| Ok(x * 2))
        .flat_map("keep_quarters", Category::Ai, |x: i32| {
            Ok(if x % 4 == 0 { vec![x] } else { vec![] })
        })
        .sink(
            "collect",
            Category::Post,
            Vec::new(),
            |v: &mut Vec<i32>, x| {
                v.push(x);
                Ok(())
            },
            |v| {
                let mut metrics = BTreeMap::new();
                metrics.insert("sum".to_string(), v.iter().sum::<i32>() as f64);
                Ok(PlanOutput { metrics, items: v.len() })
            },
        )
    }

    fn batch_len_plan(n: u32, max_batch: usize, max_wait_ms: u64, gap_ms: u64) -> Plan {
        Plan::source("b", "gen", Category::Pre, move |emit| {
            for i in 0..n {
                if gap_ms > 0 && i > 0 {
                    std::thread::sleep(Duration::from_millis(gap_ms));
                }
                emit(i);
            }
        })
        .batch(
            "batcher",
            Category::Pre,
            BatcherConfig { max_batch, max_wait: Duration::from_millis(max_wait_ms) },
        )
        .map("len", Category::Ai, |b: Vec<u32>| Ok(b.len()))
        .sink(
            "collect",
            Category::Post,
            Vec::new(),
            |v: &mut Vec<usize>, l| {
                v.push(l);
                Ok(())
            },
            |v| {
                let mut metrics = BTreeMap::new();
                metrics.insert("batches".to_string(), v.len() as f64);
                Ok(PlanOutput { metrics, items: v.iter().sum() })
            },
        )
    }

    #[test]
    fn sequential_and_streaming_agree() {
        let seq = run_sequential(arithmetic_plan(100)).unwrap();
        let stream = run_streaming(arithmetic_plan(100), 4).unwrap();
        assert_eq!(seq.output.items, stream.output.items);
        assert_eq!(seq.output.metrics, stream.output.metrics);
        assert_eq!(seq.report.stages.len(), 4);
        assert_eq!(stream.report.stages.len(), 4);
        // Same stage structure in the same order.
        for (a, b) in seq.report.stages.iter().zip(&stream.report.stages) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.items, b.items);
        }
    }

    #[test]
    fn multi_instance_of_one_matches_sequential() {
        let seq = run_sequential(arithmetic_plan(40)).unwrap();
        let multi = run_multi_instance(1, |_| Ok(arithmetic_plan(40))).unwrap();
        assert_eq!(seq.output.items, multi.output.items);
        assert_eq!(seq.output.metrics, multi.output.metrics);
        let scaling = multi.scaling.unwrap();
        assert_eq!(scaling.instances.len(), 1);
        assert_eq!(scaling.total_items(), seq.output.items);
    }

    #[test]
    fn multi_instance_aggregates() {
        let multi = run_multi_instance(3, |_| Ok(arithmetic_plan(40))).unwrap();
        let seq = run_sequential(arithmetic_plan(40)).unwrap();
        assert_eq!(multi.output.items, 3 * seq.output.items);
        let scaling = multi.scaling.unwrap();
        assert_eq!(scaling.instances.len(), 3);
        assert!((scaling.fairness() - 1.0).abs() < 1e-9);
        assert!(scaling.latency_p50().is_some());
        // Merged report sums item counts across instances.
        assert_eq!(multi.report.stages[0].items, 3 * seq.report.stages[0].items);
    }

    #[test]
    fn sequential_batch_flushes_on_size() {
        // 20 items, max_batch 8 → batches of 8/8/4 regardless of max_wait.
        let out = run_sequential(batch_len_plan(20, 8, 1, 0)).unwrap();
        assert_eq!(out.output.items, 20);
        assert_eq!(out.output.metrics["batches"], 3.0);
    }

    #[test]
    fn streaming_batch_flushes_on_timeout() {
        // Items arrive 30ms apart with a 5ms max wait → every batch
        // flushes by timeout with a single item.
        let out = run_streaming(batch_len_plan(3, 8, 5, 30), 4).unwrap();
        assert_eq!(out.output.items, 3);
        assert_eq!(out.output.metrics["batches"], 3.0);
    }

    #[test]
    fn streaming_batch_fills_on_fast_source() {
        // A hot queue with a generous wait fills batches to max_batch.
        let out = run_streaming(batch_len_plan(16, 4, 250, 0), 32).unwrap();
        assert_eq!(out.output.items, 16);
        assert_eq!(out.output.metrics["batches"], 4.0);
    }

    #[test]
    fn errors_propagate_from_both_executors() {
        let failing = || {
            Plan::source("f", "gen", Category::Pre, |emit| emit(1i32))
                .map("boom", Category::Ai, |_x: i32| {
                    Err::<i32, _>(anyhow::anyhow!("boom"))
                })
                .sink(
                    "out",
                    Category::Post,
                    (),
                    |_s: &mut (), _x: i32| Ok(()),
                    |_| Ok(PlanOutput { metrics: BTreeMap::new(), items: 0 }),
                )
        };
        assert!(run_sequential(failing()).unwrap_err().to_string().contains("boom"));
        assert!(run_streaming(failing(), 2).unwrap_err().to_string().contains("boom"));
        assert!(run_multi_instance(2, |_| Ok(failing())).is_err());
        assert!(run_sharded(2, cloned(2, || Ok(failing()))).unwrap_err().to_string().contains("boom"));
        assert!(run_async(failing(), 2).unwrap_err().to_string().contains("boom"));
        assert!(run_async_seeded(failing(), 7).unwrap_err().to_string().contains("boom"));
        assert!(
            run_sharded_async(2, 2, cloned(2, || Ok(failing()))).unwrap_err().to_string().contains("boom")
        );
    }

    #[test]
    fn streaming_surfaces_stage_panics() {
        // A stage panic must fail the run like it would sequentially,
        // never return Ok with partial metrics.
        let plan = Plan::source("p", "gen", Category::Pre, |emit| emit(1i32))
            .map("kaboom", Category::Ai, |_x: i32| -> anyhow::Result<i32> {
                panic!("kaboom payload")
            })
            .sink(
                "out",
                Category::Post,
                (),
                |_s: &mut (), _x: i32| Ok(()),
                |_| Ok(PlanOutput { metrics: BTreeMap::new(), items: 0 }),
            );
        let err = run_streaming(plan, 2).unwrap_err().to_string();
        assert!(err.contains("panicked"), "{err}");
        assert!(err.contains("kaboom payload"), "{err}");
    }

    #[test]
    fn exec_mode_parses() {
        assert_eq!(ExecMode::parse("sequential"), Some(ExecMode::Sequential));
        assert_eq!(ExecMode::parse("seq"), Some(ExecMode::Sequential));
        assert_eq!(ExecMode::parse("streaming"), Some(ExecMode::Streaming));
        assert_eq!(ExecMode::parse("stream"), Some(ExecMode::Streaming));
        assert_eq!(ExecMode::parse("multi"), Some(ExecMode::MultiInstance(2)));
        assert_eq!(ExecMode::parse("multi:6"), Some(ExecMode::MultiInstance(6)));
        assert_eq!(ExecMode::parse("shard"), Some(ExecMode::Sharded(2)));
        assert_eq!(ExecMode::parse("sharded"), Some(ExecMode::Sharded(2)));
        assert_eq!(ExecMode::parse("shard:4"), Some(ExecMode::Sharded(4)));
        assert_eq!(ExecMode::parse("async"), Some(ExecMode::Async(DEFAULT_ASYNC_WORKERS)));
        assert_eq!(ExecMode::parse("async:1"), Some(ExecMode::Async(1)));
        assert_eq!(ExecMode::parse("async:6"), Some(ExecMode::Async(6)));
        assert_eq!(ExecMode::parse("warp"), None);
        assert_eq!(ExecMode::MultiInstance(4).to_string(), "multi:4");
        assert_eq!(ExecMode::Sharded(4).to_string(), "shard:4");
        assert_eq!(ExecMode::Async(4).to_string(), "async:4");
    }

    #[test]
    fn exec_mode_display_parse_round_trips() {
        let modes = [
            ExecMode::Sequential,
            ExecMode::Streaming,
            ExecMode::MultiInstance(1),
            ExecMode::MultiInstance(2),
            ExecMode::MultiInstance(17),
            ExecMode::Sharded(1),
            ExecMode::Sharded(2),
            ExecMode::Sharded(17),
            ExecMode::Async(1),
            ExecMode::Async(2),
            ExecMode::Async(17),
        ];
        for mode in modes {
            assert_eq!(ExecMode::parse(&mode.to_string()), Some(mode), "{mode}");
        }
    }

    #[test]
    fn exec_mode_rejects_malformed_specs() {
        // Zero workers is meaningless, a trailing colon has no count,
        // signs/whitespace/garbage suffixes must not parse as a count
        // (`"+2".parse::<usize>()` would accept the sign — the strict
        // digit check exists to reject exactly that class).
        let bad_specs = [
            "multi:0", "multi:", "multi:x", "multi:3x", "multi:-1", "multi:+2", "multi: 2",
            "multi:2.5", "multi:2 ", "shard:0", "shard:", "shard:x", "shard:3x", "shard:-1",
            "shard:+2", "shard: 2", "shard:2.5", " shard:2 ", "shard:2 ", " shard:2", "",
            "sequentially", "shards", "async:0", "async:", "async:x", "async:3x", "async:-1",
            "async:+2", "async: 2", "async:2.5", "async:2 ", " async:2", "asynchronous",
        ];
        for bad in bad_specs {
            assert_eq!(ExecMode::parse(bad), None, "{bad:?} must not parse");
        }
    }

    #[test]
    fn sharded_of_one_matches_sequential() {
        let seq = run_sequential(arithmetic_plan(40)).unwrap();
        let sharded = run_sharded(1, cloned(1, || Ok(arithmetic_plan(40)))).unwrap();
        assert_eq!(seq.output.items, sharded.output.items);
        assert_eq!(seq.output.metrics, sharded.output.metrics);
        let sharding = sharded.sharding.unwrap();
        assert_eq!(sharding.shard_count(), 1);
        assert_eq!(sharding.total_owned(), 40);
        assert_eq!(sharding.total_completed(), seq.output.items);
        assert!(sharded.scaling.is_none(), "sharded runs carry no scaling aggregate");
    }

    #[test]
    fn sharded_partitions_one_dataset_and_merges_in_shard_order() {
        let seq = run_sequential(arithmetic_plan(100)).unwrap();
        for n in 2..=4usize {
            let sharded = run_sharded(n, cloned(n, || Ok(arithmetic_plan(100)))).unwrap();
            // One dataset: items and metrics equal sequential (NOT n×,
            // which is what multi-instance would report).
            assert_eq!(sharded.output.items, seq.output.items, "n={n}");
            assert_eq!(sharded.output.metrics, seq.output.metrics, "n={n}");
            // Same stage structure as sequential, with per-stage item
            // counts summing to the sequential counts across shards.
            let names: Vec<&String> = sharded.report.stages.iter().map(|s| &s.name).collect();
            let seq_names: Vec<&String> = seq.report.stages.iter().map(|s| &s.name).collect();
            assert_eq!(names, seq_names, "n={n}");
            for (a, b) in sharded.report.stages.iter().zip(&seq.report.stages) {
                assert_eq!(a.items, b.items, "stage {} n={n}", a.name);
            }
            let sharding = sharded.sharding.unwrap();
            assert_eq!(sharding.shard_count(), n);
            // Round-robin partition: disjoint cover of the 100 emissions.
            assert_eq!(sharding.total_owned(), 100, "n={n}");
            for s in &sharding.shards {
                assert_eq!(s.owned, 100 / n + usize::from(s.shard < 100 % n), "n={n}");
                assert_eq!(s.latencies.len(), s.completed);
            }
            assert!(sharding.balance() > 0.7, "n={n}: {}", sharding.balance());
            // Pooled latency samples: one per item completing the sink.
            assert_eq!(sharding.pooled_latencies().len(), seq.output.items, "n={n}");
            assert_eq!(sharded.report.latencies.len(), seq.output.items, "n={n}");
            let p50 = sharding.latency_percentile(0.50).unwrap();
            let p95 = sharding.latency_percentile(0.95).unwrap();
            assert!(p95 >= p50, "n={n}");
        }
    }

    #[test]
    fn sharded_single_item_source_lands_on_shard_zero() {
        // The tabular pipelines emit one state item; sharding must not
        // lose it or fail the idle shards.
        let one = |emit: &mut dyn FnMut(i32)| emit(7);
        let make = move || {
            Ok(Plan::source("one", "gen", Category::Pre, one)
                .map("id", Category::Ai, |x: i32| Ok(x))
                .sink(
                    "out",
                    Category::Post,
                    0i64,
                    |acc: &mut i64, x: i32| {
                        *acc += x as i64;
                        Ok(())
                    },
                    |acc| {
                        let mut metrics = BTreeMap::new();
                        metrics.insert("sum".to_string(), acc as f64);
                        Ok(PlanOutput { metrics, items: 1 })
                    },
                ))
        };
        let out = run_sharded(4, cloned(4, make)).unwrap();
        assert_eq!(out.output.metrics["sum"], 7.0);
        let sharding = out.sharding.unwrap();
        assert_eq!(sharding.total_owned(), 1);
        assert_eq!(sharding.shards[0].owned, 1);
        for s in &sharding.shards[1..] {
            assert_eq!(s.owned, 0, "shard {} must own nothing", s.shard);
            assert_eq!(s.completed, 0);
        }
    }

    #[test]
    fn sharded_batch_plans_batch_within_each_partition() {
        // 20 items, max_batch 8: sequential cuts 8/8/4 = 3 batches;
        // two shards of 10 cut 8/2 each = 4 batches. Item counts are
        // preserved; batch boundaries are an executor property (exactly
        // like the streaming executor's timeout flushes).
        let sharded = run_sharded(2, cloned(2, || Ok(batch_len_plan(20, 8, 1, 0)))).unwrap();
        assert_eq!(sharded.output.items, 20);
        assert_eq!(sharded.output.metrics["batches"], 4.0);
        let sharding = sharded.sharding.unwrap();
        assert_eq!(sharding.total_owned(), 20);
        // One latency sample per sink arrival (a batch).
        assert_eq!(sharding.pooled_latencies().len(), 4);
    }

    #[test]
    fn sharded_empty_source_still_finishes() {
        let make = || {
            Ok(Plan::source("e", "none", Category::Pre, |_emit: &mut dyn FnMut(i32)| {}).sink(
                "out",
                Category::Post,
                0usize,
                |n: &mut usize, _x: i32| {
                    *n += 1;
                    Ok(())
                },
                |n| Ok(PlanOutput { metrics: BTreeMap::new(), items: n }),
            ))
        };
        let out = run_sharded(3, cloned(3, make)).unwrap();
        assert_eq!(out.output.items, 0);
        let sharding = out.sharding.unwrap();
        assert_eq!(sharding.total_owned(), 0);
        assert!(sharding.latency_percentile(0.5).is_none());
    }

    #[test]
    fn sharded_sink_errors_propagate_from_the_merge_fold() {
        // Transforms succeed on every shard; the sink rejects one item.
        let make = || {
            Ok(Plan::source("s", "gen", Category::Pre, |emit: &mut dyn FnMut(i32)| {
                for i in 0..10 {
                    emit(i);
                }
            })
            .sink(
                "picky",
                Category::Post,
                (),
                |_s: &mut (), x: i32| {
                    anyhow::ensure!(x != 7, "sink rejects item 7");
                    Ok(())
                },
                |_| Ok(PlanOutput { metrics: BTreeMap::new(), items: 0 }),
            ))
        };
        let err = run_sharded(3, cloned(3, make)).unwrap_err().to_string();
        assert!(err.contains("rejects item 7"), "{err}");
    }

    #[test]
    fn sharded_pass_panics_fail_the_run_instead_of_hanging() {
        // A panicking pass task cannot run its own slot/notify cleanup;
        // the abort broadcast must wake the (parked) merge task so the
        // run fails loudly — the thread-based executor's panic
        // guarantee, preserved under cooperative parking.
        let make = |s: usize| -> anyhow::Result<Plan> {
            Ok(Plan::source("p", "gen", Category::Pre, |emit: &mut dyn FnMut(i32)| emit(1))
                .map("kaboom", Category::Ai, |_x: i32| -> anyhow::Result<i32> {
                    panic!("pass kaboom")
                })
                .sink(
                    "out",
                    Category::Post,
                    (),
                    |_s: &mut (), _x: i32| Ok(()),
                    |_| Ok(PlanOutput { metrics: BTreeMap::new(), items: 0 }),
                )
                .shard(Sharder::new(s, 2)))
        };
        let err = run_sharded(2, make).unwrap_err().to_string();
        assert!(err.contains("panicked"), "{err}");
        assert!(err.contains("pass kaboom"), "{err}");
    }

    #[test]
    fn sharded_rejects_zero_shards() {
        let err = run_sharded(0, cloned(1, || Ok(arithmetic_plan(4)))).unwrap_err().to_string();
        assert!(err.contains("at least one shard"), "{err}");
    }

    #[test]
    fn executors_record_per_item_latency_samples() {
        // One sample per item that completes the sink, under both
        // single-instance executors.
        let seq = run_sequential(arithmetic_plan(100)).unwrap();
        assert_eq!(seq.report.latencies.len(), seq.output.items);
        let stream = run_streaming(arithmetic_plan(100), 4).unwrap();
        assert_eq!(stream.report.latencies.len(), stream.output.items);
        let p50 = stream.report.latency_percentile(0.5).unwrap();
        let p95 = stream.report.latency_percentile(0.95).unwrap();
        assert!(p95 >= p50);
        // Batch plans record one sample per sink arrival (a batch).
        let batched = run_sequential(batch_len_plan(20, 8, 1, 0)).unwrap();
        assert_eq!(batched.report.latencies.len(), 3);
    }

    #[test]
    fn multi_instance_pools_per_item_latencies() {
        let multi = run_multi_instance(3, |_| Ok(arithmetic_plan(40))).unwrap();
        let scaling = multi.scaling.as_ref().unwrap();
        let per_instance = run_sequential(arithmetic_plan(40)).unwrap().output.items;
        for inst in &scaling.instances {
            assert_eq!(inst.latencies.len(), per_instance, "instance {}", inst.instance);
        }
        // Merged report pools every instance's samples.
        assert_eq!(multi.report.latencies.len(), 3 * per_instance);
    }

    #[test]
    fn empty_source_still_finishes() {
        let plan = Plan::source("e", "none", Category::Pre, |_emit: &mut dyn FnMut(i32)| {})
            .sink(
                "out",
                Category::Post,
                0usize,
                |n: &mut usize, _x: i32| {
                    *n += 1;
                    Ok(())
                },
                |n| Ok(PlanOutput { metrics: BTreeMap::new(), items: n }),
            );
        let out = run_sequential(plan).unwrap();
        assert_eq!(out.output.items, 0);
    }

    /// A plan whose metric depends on SINK FOLD ORDER (h = h·31 + x):
    /// any interleaving that reorders items past the sink changes the
    /// hash, so metric equality pins the fold order itself.
    fn order_hash_plan(n: i64) -> Plan {
        Plan::source("h", "gen", Category::Pre, move |emit| {
            for i in 0..n {
                emit(i);
            }
        })
        .map("inc", Category::Ai, |x: i64| Ok(x + 1))
        .sink(
            "hash",
            Category::Post,
            0i64,
            |h: &mut i64, x: i64| {
                *h = h.wrapping_mul(31).wrapping_add(x);
                Ok(())
            },
            |h| {
                let mut metrics = BTreeMap::new();
                metrics.insert("hash".to_string(), h as f64);
                Ok(PlanOutput { metrics, items: 0 })
            },
        )
    }

    #[test]
    fn async_matches_sequential_for_every_pool_size() {
        let seq = run_sequential(arithmetic_plan(100)).unwrap();
        for workers in 1..=3usize {
            let a = run_async(arithmetic_plan(100), workers).unwrap();
            assert_eq!(a.output.items, seq.output.items, "workers {workers}");
            assert_eq!(a.output.metrics, seq.output.metrics, "workers {workers}");
            let names: Vec<&String> = a.report.stages.iter().map(|s| &s.name).collect();
            let seq_names: Vec<&String> = seq.report.stages.iter().map(|s| &s.name).collect();
            assert_eq!(names, seq_names, "workers {workers}");
            for (x, y) in a.report.stages.iter().zip(&seq.report.stages) {
                assert_eq!(x.items, y.items, "stage {} workers {workers}", x.name);
            }
            // One latency sample per item completing the sink.
            assert_eq!(a.report.latencies.len(), seq.output.items, "workers {workers}");
            assert!(a.scaling.is_none() && a.sharding.is_none(), "workers {workers}");
            let sched = a.sched.expect("async runs carry scheduler counters");
            assert!(sched.balanced(), "workers {workers}: {sched:?}");
            assert_eq!(sched.workers, workers);
            // Stage tasks: source + two transforms + sink.
            assert_eq!(sched.tasks_spawned, 4, "workers {workers}");
        }
    }

    #[test]
    fn async_batch_boundaries_equal_sequential() {
        // 20 items at max_batch 8 → 8/8/4 under sequential; the async
        // batch node flushes on size plus one final remainder, so the
        // boundaries (and the batch count metric) are identical.
        let seq = run_sequential(batch_len_plan(20, 8, 1, 0)).unwrap();
        let a = run_async(batch_len_plan(20, 8, 1, 0), 2).unwrap();
        assert_eq!(a.output.items, 20);
        assert_eq!(a.output.metrics, seq.output.metrics);
        assert_eq!(a.output.metrics["batches"], 3.0);
    }

    #[test]
    fn async_seeded_interleavings_preserve_fold_order() {
        let seq = run_sequential(order_hash_plan(200)).unwrap();
        for seed in 0..24u64 {
            let a = run_async_seeded(order_hash_plan(200), seed).unwrap();
            assert_eq!(
                a.output.metrics, seq.output.metrics,
                "seed {seed}: sink fold order drifted under interleaving"
            );
            let sched = a.sched.expect("seeded runs carry scheduler counters");
            assert!(sched.balanced(), "seed {seed}: {sched:?}");
            assert_eq!(sched.tasks_run, sched.tasks_spawned, "seed {seed}");
            assert!(sched.max_in_flight <= sched.workers, "seed {seed}");
        }
    }

    #[test]
    fn async_empty_source_still_finishes() {
        let make = || {
            Plan::source("e", "none", Category::Pre, |_emit: &mut dyn FnMut(i32)| {}).sink(
                "out",
                Category::Post,
                0usize,
                |n: &mut usize, _x: i32| {
                    *n += 1;
                    Ok(())
                },
                |n| Ok(PlanOutput { metrics: BTreeMap::new(), items: n }),
            )
        };
        let out = run_async(make(), 2).unwrap();
        assert_eq!(out.output.items, 0);
        let seeded = run_async_seeded(make(), 3).unwrap();
        assert_eq!(seeded.output.items, 0);
    }

    #[test]
    fn async_surfaces_stage_panics() {
        // A stage panic must fail the run as loudly as it does under the
        // sequential executor, never hang the pool or drop the ticket.
        let plan = Plan::source("p", "gen", Category::Pre, |emit| emit(1i32))
            .map("kaboom", Category::Ai, |_x: i32| -> anyhow::Result<i32> {
                panic!("kaboom payload")
            })
            .sink(
                "out",
                Category::Post,
                (),
                |_s: &mut (), _x: i32| Ok(()),
                |_| Ok(PlanOutput { metrics: BTreeMap::new(), items: 0 }),
            );
        let err = run_async(plan, 2).unwrap_err().to_string();
        assert!(err.contains("panicked"), "{err}");
        assert!(err.contains("kaboom payload"), "{err}");
    }

    #[test]
    fn async_completion_hook_fires_exactly_once() {
        use std::sync::mpsc;
        let sched = Scheduler::new(2);
        let (tx, rx) = mpsc::channel();
        spawn_async_on(arithmetic_plan(40), &sched, move |res| {
            tx.send(res.map(|o| o.output.metrics["sum"])).unwrap();
        });
        let sum = rx.recv().unwrap().unwrap();
        let seq = run_sequential(arithmetic_plan(40)).unwrap();
        assert!((sum - seq.output.metrics["sum"]).abs() < 1e-12);
        // Exactly once: nothing further arrives and the channel closes.
        assert!(rx.recv().is_err(), "completion hook fired twice");
    }

    #[test]
    fn sharded_async_composition_matches_sequential() {
        let seq = run_sequential(arithmetic_plan(100)).unwrap();
        for n in 1..=4usize {
            for workers in [1usize, 2, 4] {
                let res = run_sharded_async(n, workers, cloned(n, || Ok(arithmetic_plan(100)))).unwrap();
                assert_eq!(res.output.items, seq.output.items, "n={n} w={workers}");
                assert_eq!(res.output.metrics, seq.output.metrics, "n={n} w={workers}");
                let sharding = res.sharding.as_ref().expect("sharded run reports partitions");
                assert_eq!(sharding.shard_count(), n, "n={n} w={workers}");
                assert_eq!(sharding.total_owned(), 100, "n={n} w={workers}");
                let sched = res.sched.expect("sharded runs carry scheduler counters");
                assert!(sched.balanced(), "n={n} w={workers}: {sched:?}");
                // n pass tasks + 1 merge task.
                assert_eq!(sched.tasks_spawned, n + 1, "n={n} w={workers}");
            }
        }
    }

    /// Compiled per-item plan over `Vec<i32>` with an order-sensitive
    /// sink hash — the exec-level fixture for payload-aware slicing.
    fn compiled_vec_plan() -> crate::coordinator::plan::CompiledPlan<Vec<i32>> {
        use crate::coordinator::plan::{CompiledPlan, Slicing, WorkloadSlice};
        CompiledPlan::source(
            "cvec",
            "gen",
            Category::Pre,
            Slicing::PerItem,
            |slice: WorkloadSlice<Vec<i32>>| {
                let items: Vec<(usize, i32)> = slice
                    .payload
                    .iter()
                    .enumerate()
                    .map(|(j, &v)| (slice.global_index(j), v))
                    .collect();
                let mut feed = Some(items);
                Ok(move |emit: &mut dyn FnMut((usize, i32))| {
                    for item in feed.take().into_iter().flatten() {
                        emit(item);
                    }
                })
            },
        )
        .map("double", Category::Ai, |_seed| |(i, v): (usize, i32)| Ok((i, v * 2)))
        .sink(
            "hash",
            Category::Post,
            |payload: &Vec<i32>, _seed| {
                let total = payload.len();
                Ok((
                    (0i64, 0i64),
                    |(sum, hash): &mut (i64, i64), (i, v): (usize, i32)| {
                        *sum += v as i64;
                        *hash = hash.wrapping_mul(31).wrapping_add(i as i64);
                        Ok(())
                    },
                    move |(sum, hash)| {
                        let mut metrics = BTreeMap::new();
                        metrics.insert("sum".to_string(), sum as f64);
                        metrics.insert("hash".to_string(), hash as f64);
                        Ok(PlanOutput { metrics, items: total })
                    },
                ))
            },
        )
    }

    #[test]
    fn sharded_executor_runs_pre_sliced_compiled_binds() {
        // The payload-aware slicing path end to end: each shard binds a
        // round-robin slice of ONE payload, the merge folds in shard
        // order, and every metric — including the order-sensitive index
        // hash for n = 1 — matches a sequential bind of the full
        // payload. Owned counts come from actual slice sizes, so the
        // redundant full-source pass is provably gone: a shard's source
        // stage only ever sees its own items.
        let compiled = compiled_vec_plan();
        let payload: Vec<i32> = (0..50).map(|v| v * 7 % 23).collect();
        let seq = run_sequential(compiled.bind(payload.clone(), 3).unwrap()).unwrap();
        for n in 1..=4usize {
            let res = run_sharded(n, |s| {
                let sharder = Sharder::new(s, n);
                let slice: Vec<i32> = payload
                    .iter()
                    .enumerate()
                    .filter(|(i, _)| sharder.owns(*i))
                    .map(|(_, &v)| v)
                    .collect();
                compiled.bind_shard(slice, sharder, &payload, 3)
            })
            .unwrap();
            assert_eq!(res.output.items, seq.output.items, "n={n}");
            assert_eq!(res.output.metrics["sum"], seq.output.metrics["sum"], "n={n}");
            if n == 1 {
                assert_eq!(res.output.metrics["hash"], seq.output.metrics["hash"]);
            }
            // Bit-identical to clone-based sharding, order-sensitive
            // hash included: slicing changes WHERE the partition
            // happens (payload vs emission filter), never the streams.
            let cloned_res = run_sharded(n, |s| {
                compiled.bind(payload.clone(), 3).map(|p| p.shard(Sharder::new(s, n)))
            })
            .unwrap();
            assert_eq!(res.output.metrics, cloned_res.output.metrics, "n={n}");
            assert_eq!(res.output.items, cloned_res.output.items, "n={n}");
            let sharding = res.sharding.expect("sharded run reports partitions");
            assert_eq!(sharding.total_owned(), payload.len(), "n={n}");
            for sh in &sharding.shards {
                assert_eq!(
                    sh.owned,
                    Sharder::new(sh.shard, n).owned_count(payload.len()),
                    "n={n} shard {}",
                    sh.shard
                );
            }
            assert!(res.sched.expect("counters").balanced(), "n={n}");
        }
        // 1 sequential bind + sliced and clone-based shard binds above.
        let br = compiled.bind_report();
        assert_eq!(br.compiles, 1);
        assert_eq!(br.binds, 1 + 2 * (1 + 2 + 3 + 4));
    }

    #[test]
    fn sharded_seeded_interleavings_stream_the_merge_without_changing_metrics() {
        // The acceptance assertion for the streaming merge, via
        // counters and deterministic seeds — never timing: across 32
        // seeded interleavings the metrics never move, and at least one
        // interleaving folds a shard while later passes are still
        // pending (streamed_folds > 0).
        let seq = run_sequential(arithmetic_plan(100)).unwrap();
        let mut streamed_any = false;
        for seed in 0..32u64 {
            let res = run_sharded_seeded(4, seed, cloned(4, || Ok(arithmetic_plan(100)))).unwrap();
            assert_eq!(res.output.metrics, seq.output.metrics, "seed {seed}");
            assert_eq!(res.output.items, seq.output.items, "seed {seed}");
            let sharding = res.sharding.expect("seeded sharded run reports partitions");
            assert!(sharding.streamed_folds <= sharding.shard_count(), "seed {seed}");
            streamed_any |= sharding.merge_streamed();
            assert!(res.sched.expect("counters").balanced(), "seed {seed}");
        }
        assert!(
            streamed_any,
            "no seed in 0..32 overlapped a fold with a running pass — the merge is not streaming"
        );
        // A single shard can never stream: its fold starts only after
        // its own — the last — pass.
        let one = run_sharded_seeded(1, 9, cloned(1, || Ok(arithmetic_plan(40)))).unwrap();
        assert_eq!(one.sharding.unwrap().streamed_folds, 0);
    }
}
