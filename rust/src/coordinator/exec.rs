//! Plan executors — interchangeable strategies for running a [`Plan`].
//!
//! * [`run_sequential`] — in-thread, stage-at-a-time (the tabular shape):
//!   lowest overhead, items materialized between stages.
//! * [`run_streaming`] — one thread per stage over bounded channels (the
//!   video/serving shape): backpressure keeps memory flat and exposes the
//!   slowest stage; batch nodes use the [`DynamicBatcher`] max-wait flush.
//! * [`run_multi_instance`] — N replicated plan instances on worker
//!   threads (§3.4 workload scaling), aggregated by the scaler with
//!   fairness and latency percentiles.
//!
//! All three record the same per-stage [`Telemetry`], so every mode
//! yields the Figure 1 breakdown, and all three produce identical
//! deterministic metrics for a fixed seed — the executor-equivalence
//! suite (`rust/tests/executor_equivalence.rs`) asserts exactly that.
//!
//! Every item is stamped at source emission and its end-to-end latency
//! recorded when it completes the sink, so [`Report::latencies`] carries
//! measured per-item samples under every executor and the scaling
//! percentiles no longer fall back to instance wall time. Under the
//! streaming executor these are true in-flight latencies; under the
//! stage-at-a-time sequential executor an item's sink completion
//! necessarily trails the whole upstream pass, so its samples skew
//! toward the run duration (an honest property of that execution shape).

use super::batcher::DynamicBatcher;
use super::plan::{DynItem, NodeKind, Plan, PlanOutput};
use super::scaler::{InstanceReport, ScalingReport};
use super::telemetry::{Report, Telemetry};
use crate::parallel::channel::bounded;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Which executor runs a plan; selected via `RunConfig::exec` or `--exec`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecMode {
    /// In-thread, stage-at-a-time.
    #[default]
    Sequential,
    /// Thread-per-stage over bounded channels with backpressure.
    Streaming,
    /// N replicated plan instances (each sequential), scaler-aggregated.
    MultiInstance(usize),
}

impl ExecMode {
    /// Parse a CLI spelling: `sequential`, `streaming`, `multi`,
    /// `multi:<n>`.
    pub fn parse(s: &str) -> Option<ExecMode> {
        match s {
            "sequential" | "seq" => Some(ExecMode::Sequential),
            "streaming" | "stream" => Some(ExecMode::Streaming),
            _ => {
                let rest = s.strip_prefix("multi")?;
                if rest.is_empty() {
                    Some(ExecMode::MultiInstance(2))
                } else {
                    rest.strip_prefix(':')?
                        .parse()
                        .ok()
                        .filter(|&n| n >= 1)
                        .map(ExecMode::MultiInstance)
                }
            }
        }
    }
}

impl std::fmt::Display for ExecMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ExecMode::Sequential => f.write_str("sequential"),
            ExecMode::Streaming => f.write_str("streaming"),
            ExecMode::MultiInstance(n) => write!(f, "multi:{n}"),
        }
    }
}

/// Bound on every inter-stage queue in streaming mode.
pub const DEFAULT_QUEUE_CAP: usize = 8;

/// An in-flight item plus its source-emission instant; the stamp rides
/// along so the sink can record a true per-item end-to-end latency.
/// Batch nodes keep the earliest stamp of their members (a batch is as
/// old as its oldest item).
struct Stamped {
    born: Instant,
    item: DynItem,
}

/// What an executor returns: telemetry, the plan's output, and (for
/// multi-instance) the scaling aggregate.
pub struct ExecOutcome {
    /// Per-stage timing (Figure 1 source). Multi-instance merges stage
    /// busy time and item counts across instances.
    pub report: Report,
    /// The plan's deterministic metrics and item count. Multi-instance
    /// reports instance 0's metrics with `items` summed over instances.
    pub output: PlanOutput,
    /// Present only for multi-instance execution.
    pub scaling: Option<ScalingReport>,
}

/// Dispatch a plan-builder through the executor selected by `mode`.
/// `make_plan` is invoked once per instance (instance 0 for the
/// single-instance modes) so every replica gets fresh stage closures.
pub fn execute(
    mode: ExecMode,
    make_plan: impl Fn(usize) -> anyhow::Result<Plan> + Sync,
) -> anyhow::Result<ExecOutcome> {
    match mode {
        ExecMode::Sequential => run_sequential(make_plan(0)?),
        ExecMode::Streaming => run_streaming(make_plan(0)?, DEFAULT_QUEUE_CAP),
        ExecMode::MultiInstance(n) => run_multi_instance(n, make_plan),
    }
}

/// Run a plan in the calling thread, one stage at a time over the whole
/// item stream. Batch nodes flush on size alone (every item is already
/// available, so the max-wait timer is irrelevant by construction).
pub fn run_sequential(plan: Plan) -> anyhow::Result<ExecOutcome> {
    let telemetry = Telemetry::new();
    let Plan { source: (src_name, src_cat, mut produce), nodes, sink, finish, .. } = plan;
    let (sink_name, sink_cat, mut sink_fn) = sink;

    let handle = telemetry.stage(&src_name, src_cat);
    let mut items: Vec<Stamped> = Vec::new();
    let t0 = Instant::now();
    let mut produced = 0usize;
    produce(&mut |item| {
        produced += 1;
        items.push(Stamped { born: Instant::now(), item });
    });
    handle.record(t0.elapsed(), produced);

    for node in nodes {
        let handle = telemetry.stage(&node.name, node.category);
        match node.kind {
            NodeKind::FlatMap(mut f) => {
                let mut next = Vec::with_capacity(items.len());
                for Stamped { born, item } in items {
                    let t0 = Instant::now();
                    let outs = f(item)?;
                    handle.record(t0.elapsed(), 1);
                    next.extend(outs.into_iter().map(|item| Stamped { born, item }));
                }
                items = next;
            }
            NodeKind::Batch(cfg, mut group) => {
                let max = cfg.max_batch.max(1);
                let mut next = Vec::new();
                let mut iter = items.into_iter().peekable();
                while iter.peek().is_some() {
                    let batch: Vec<Stamped> = iter.by_ref().take(max).collect();
                    let born = batch.iter().map(|s| s.born).min().expect("non-empty batch");
                    let members: Vec<DynItem> = batch.into_iter().map(|s| s.item).collect();
                    let t0 = Instant::now();
                    next.push(Stamped { born, item: group(members)? });
                    handle.record(t0.elapsed(), 1);
                }
                items = next;
            }
        }
    }

    let handle = telemetry.stage(&sink_name, sink_cat);
    for Stamped { born, item } in items {
        let t0 = Instant::now();
        sink_fn(item)?;
        handle.record(t0.elapsed(), 1);
        telemetry.record_latency(born.elapsed());
    }
    let output = finish()?;
    Ok(ExecOutcome { report: telemetry.report(), output, scaling: None })
}

/// Run a plan with one thread per stage connected by bounded channels, so
/// a slow stage backpressures everything upstream. The sink folds on the
/// calling thread. Source busy time subtracts send-blocking (that is the
/// downstream stage's cost, not production work — counting it would smear
/// the slowest stage over the source in the Figure 1 breakdown).
pub fn run_streaming(plan: Plan, queue_cap: usize) -> anyhow::Result<ExecOutcome> {
    let telemetry = Telemetry::new();
    let cap = queue_cap.max(1);
    let first_err: Arc<Mutex<Option<anyhow::Error>>> = Arc::new(Mutex::new(None));
    let Plan { source: (src_name, src_cat, mut produce), nodes, sink, finish, .. } = plan;
    let (sink_name, sink_cat, mut sink_fn) = sink;
    let mut workers = Vec::with_capacity(nodes.len() + 1);

    let handle = telemetry.stage(&src_name, src_cat);
    let (tx, mut tail) = bounded::<Stamped>(cap);
    workers.push(
        std::thread::Builder::new()
            .name(format!("plan-src-{src_name}"))
            .spawn(move || {
                let t0 = Instant::now();
                let mut blocked = std::time::Duration::ZERO;
                let mut count = 0usize;
                produce(&mut |item| {
                    count += 1;
                    let stamped = Stamped { born: Instant::now(), item };
                    let s0 = Instant::now();
                    let _ = tx.send(stamped);
                    blocked += s0.elapsed();
                });
                handle.record(t0.elapsed().saturating_sub(blocked), count);
            })
            .expect("spawn plan source"),
    );

    for node in nodes {
        let handle = telemetry.stage(&node.name, node.category);
        let (tx, rx) = bounded::<Stamped>(cap);
        let upstream = tail;
        tail = rx;
        let errs = Arc::clone(&first_err);
        let worker = match node.kind {
            NodeKind::FlatMap(mut f) => std::thread::Builder::new()
                .name(format!("plan-stage-{}", node.name))
                .spawn(move || {
                    while let Ok(Stamped { born, item }) = upstream.recv() {
                        let t0 = Instant::now();
                        match f(item) {
                            Ok(outs) => {
                                handle.record(t0.elapsed(), 1);
                                for out in outs {
                                    if tx.send(Stamped { born, item: out }).is_err() {
                                        return; // downstream gone
                                    }
                                }
                            }
                            Err(e) => {
                                errs.lock().unwrap().get_or_insert(e);
                                return;
                            }
                        }
                    }
                })
                .expect("spawn plan stage"),
            NodeKind::Batch(cfg, mut group) => std::thread::Builder::new()
                .name(format!("plan-batch-{}", node.name))
                .spawn(move || {
                    let mut batcher = DynamicBatcher::new(upstream, cfg);
                    while let Some(batch) = batcher.next_batch() {
                        let born =
                            batch.iter().map(|s| s.born).min().expect("non-empty batch");
                        let members: Vec<DynItem> =
                            batch.into_iter().map(|s| s.item).collect();
                        let t0 = Instant::now();
                        match group(members) {
                            Ok(item) => {
                                handle.record(t0.elapsed(), 1);
                                if tx.send(Stamped { born, item }).is_err() {
                                    return;
                                }
                            }
                            Err(e) => {
                                errs.lock().unwrap().get_or_insert(e);
                                return;
                            }
                        }
                    }
                })
                .expect("spawn plan batch"),
        };
        workers.push(worker);
    }

    let handle = telemetry.stage(&sink_name, sink_cat);
    while let Ok(Stamped { born, item }) = tail.recv() {
        let t0 = Instant::now();
        if let Err(e) = sink_fn(item) {
            first_err.lock().unwrap().get_or_insert(e);
            break;
        }
        handle.record(t0.elapsed(), 1);
        telemetry.record_latency(born.elapsed());
    }
    // Dropping the tail receiver makes upstream sends fail fast if we
    // broke out early; workers then unwind without deadlocking.
    drop(tail);
    let mut panicked: Option<String> = None;
    for worker in workers {
        let name = worker.thread().name().unwrap_or("plan-worker").to_string();
        if let Err(payload) = worker.join() {
            let msg = payload
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "non-string panic payload".to_string());
            panicked.get_or_insert(format!("{name} panicked: {msg}"));
        }
    }
    if let Some(e) = first_err.lock().unwrap().take() {
        return Err(e);
    }
    // A stage panic must surface as loudly as it would under the
    // sequential executor, not as partial metrics.
    if let Some(msg) = panicked {
        return Err(anyhow::anyhow!("streaming stage failed: {msg}"));
    }
    let output = finish()?;
    Ok(ExecOutcome { report: telemetry.report(), output, scaling: None })
}

/// Run `n` replicated instances of the plan on worker threads (each
/// instance sequential — the paper's parallel-streams shape), and
/// aggregate throughput, fairness, and latency percentiles. The merged
/// report sums per-stage busy time and items across instances.
pub fn run_multi_instance(
    n: usize,
    make_plan: impl Fn(usize) -> anyhow::Result<Plan> + Sync,
) -> anyhow::Result<ExecOutcome> {
    anyhow::ensure!(n >= 1, "multi-instance execution needs at least one instance");
    let t0 = Instant::now();
    let mut results: Vec<(anyhow::Result<ExecOutcome>, std::time::Duration)> =
        Vec::with_capacity(n);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..n)
            .map(|i| {
                let make_plan = &make_plan;
                scope.spawn(move || {
                    // Plan construction (data generation, model warmup) is
                    // explicitly outside the timed run — the pipelines
                    // measure steady state, and the scaling metrics must
                    // match that.
                    let plan = make_plan(i);
                    let it0 = Instant::now();
                    let res = plan.and_then(run_sequential);
                    (res, it0.elapsed())
                })
            })
            .collect();
        for h in handles {
            results.push(h.join().expect("plan instance panicked"));
        }
    });
    let wall = t0.elapsed();

    let mut instances = Vec::with_capacity(n);
    let mut reports: Vec<Report> = Vec::with_capacity(n);
    let mut first_output: Option<PlanOutput> = None;
    for (i, (res, elapsed)) in results.into_iter().enumerate() {
        let outcome = res?;
        instances.push(InstanceReport {
            instance: i,
            items: outcome.output.items,
            elapsed,
            // Per-item samples recorded by the instance's sink. Each
            // replica runs sequentially (stage-at-a-time), so samples
            // approximate the instance pass for multi-item plans — still
            // measured per item, no longer the wall-time fallback.
            latencies: outcome.report.latencies.clone(),
        });
        reports.push(outcome.report);
        if first_output.is_none() {
            first_output = Some(outcome.output);
        }
    }
    let scaling = ScalingReport { instances, wall };
    let mut output = first_output.expect("n >= 1 guarantees one outcome");
    output.items = scaling.total_items();
    Ok(ExecOutcome { report: merge_reports(&reports), output, scaling: Some(scaling) })
}

fn merge_reports(reports: &[Report]) -> Report {
    let mut merged = reports[0].clone();
    for r in &reports[1..] {
        for (m, s) in merged.stages.iter_mut().zip(&r.stages) {
            debug_assert_eq!(m.name, s.name, "instances must share a stage structure");
            m.busy += s.busy;
            m.items += s.items;
        }
        merged.latencies.extend_from_slice(&r.latencies);
    }
    merged
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::BatcherConfig;
    use crate::coordinator::telemetry::Category;
    use std::collections::BTreeMap;
    use std::time::Duration;

    /// source 0..n → double → drop odd halves → collect; returns sum.
    fn arithmetic_plan(n: i32) -> Plan {
        Plan::source("t", "gen", Category::Pre, move |emit| {
            for i in 0..n {
                emit(i);
            }
        })
        .map("double", Category::Pre, |x: i32| Ok(x * 2))
        .flat_map("keep_quarters", Category::Ai, |x: i32| {
            Ok(if x % 4 == 0 { vec![x] } else { vec![] })
        })
        .sink(
            "collect",
            Category::Post,
            Vec::new(),
            |v: &mut Vec<i32>, x| {
                v.push(x);
                Ok(())
            },
            |v| {
                let mut metrics = BTreeMap::new();
                metrics.insert("sum".to_string(), v.iter().sum::<i32>() as f64);
                Ok(PlanOutput { metrics, items: v.len() })
            },
        )
    }

    fn batch_len_plan(n: u32, max_batch: usize, max_wait_ms: u64, gap_ms: u64) -> Plan {
        Plan::source("b", "gen", Category::Pre, move |emit| {
            for i in 0..n {
                if gap_ms > 0 && i > 0 {
                    std::thread::sleep(Duration::from_millis(gap_ms));
                }
                emit(i);
            }
        })
        .batch(
            "batcher",
            Category::Pre,
            BatcherConfig { max_batch, max_wait: Duration::from_millis(max_wait_ms) },
        )
        .map("len", Category::Ai, |b: Vec<u32>| Ok(b.len()))
        .sink(
            "collect",
            Category::Post,
            Vec::new(),
            |v: &mut Vec<usize>, l| {
                v.push(l);
                Ok(())
            },
            |v| {
                let mut metrics = BTreeMap::new();
                metrics.insert("batches".to_string(), v.len() as f64);
                Ok(PlanOutput { metrics, items: v.iter().sum() })
            },
        )
    }

    #[test]
    fn sequential_and_streaming_agree() {
        let seq = run_sequential(arithmetic_plan(100)).unwrap();
        let stream = run_streaming(arithmetic_plan(100), 4).unwrap();
        assert_eq!(seq.output.items, stream.output.items);
        assert_eq!(seq.output.metrics, stream.output.metrics);
        assert_eq!(seq.report.stages.len(), 4);
        assert_eq!(stream.report.stages.len(), 4);
        // Same stage structure in the same order.
        for (a, b) in seq.report.stages.iter().zip(&stream.report.stages) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.items, b.items);
        }
    }

    #[test]
    fn multi_instance_of_one_matches_sequential() {
        let seq = run_sequential(arithmetic_plan(40)).unwrap();
        let multi = run_multi_instance(1, |_| Ok(arithmetic_plan(40))).unwrap();
        assert_eq!(seq.output.items, multi.output.items);
        assert_eq!(seq.output.metrics, multi.output.metrics);
        let scaling = multi.scaling.unwrap();
        assert_eq!(scaling.instances.len(), 1);
        assert_eq!(scaling.total_items(), seq.output.items);
    }

    #[test]
    fn multi_instance_aggregates() {
        let multi = run_multi_instance(3, |_| Ok(arithmetic_plan(40))).unwrap();
        let seq = run_sequential(arithmetic_plan(40)).unwrap();
        assert_eq!(multi.output.items, 3 * seq.output.items);
        let scaling = multi.scaling.unwrap();
        assert_eq!(scaling.instances.len(), 3);
        assert!((scaling.fairness() - 1.0).abs() < 1e-9);
        assert!(scaling.latency_p50().is_some());
        // Merged report sums item counts across instances.
        assert_eq!(multi.report.stages[0].items, 3 * seq.report.stages[0].items);
    }

    #[test]
    fn sequential_batch_flushes_on_size() {
        // 20 items, max_batch 8 → batches of 8/8/4 regardless of max_wait.
        let out = run_sequential(batch_len_plan(20, 8, 1, 0)).unwrap();
        assert_eq!(out.output.items, 20);
        assert_eq!(out.output.metrics["batches"], 3.0);
    }

    #[test]
    fn streaming_batch_flushes_on_timeout() {
        // Items arrive 30ms apart with a 5ms max wait → every batch
        // flushes by timeout with a single item.
        let out = run_streaming(batch_len_plan(3, 8, 5, 30), 4).unwrap();
        assert_eq!(out.output.items, 3);
        assert_eq!(out.output.metrics["batches"], 3.0);
    }

    #[test]
    fn streaming_batch_fills_on_fast_source() {
        // A hot queue with a generous wait fills batches to max_batch.
        let out = run_streaming(batch_len_plan(16, 4, 250, 0), 32).unwrap();
        assert_eq!(out.output.items, 16);
        assert_eq!(out.output.metrics["batches"], 4.0);
    }

    #[test]
    fn errors_propagate_from_both_executors() {
        let failing = || {
            Plan::source("f", "gen", Category::Pre, |emit| emit(1i32))
                .map("boom", Category::Ai, |_x: i32| {
                    Err::<i32, _>(anyhow::anyhow!("boom"))
                })
                .sink(
                    "out",
                    Category::Post,
                    (),
                    |_s: &mut (), _x: i32| Ok(()),
                    |_| Ok(PlanOutput { metrics: BTreeMap::new(), items: 0 }),
                )
        };
        assert!(run_sequential(failing()).unwrap_err().to_string().contains("boom"));
        assert!(run_streaming(failing(), 2).unwrap_err().to_string().contains("boom"));
        assert!(run_multi_instance(2, |_| Ok(failing())).is_err());
    }

    #[test]
    fn streaming_surfaces_stage_panics() {
        // A stage panic must fail the run like it would sequentially,
        // never return Ok with partial metrics.
        let plan = Plan::source("p", "gen", Category::Pre, |emit| emit(1i32))
            .map("kaboom", Category::Ai, |_x: i32| -> anyhow::Result<i32> {
                panic!("kaboom payload")
            })
            .sink(
                "out",
                Category::Post,
                (),
                |_s: &mut (), _x: i32| Ok(()),
                |_| Ok(PlanOutput { metrics: BTreeMap::new(), items: 0 }),
            );
        let err = run_streaming(plan, 2).unwrap_err().to_string();
        assert!(err.contains("panicked"), "{err}");
        assert!(err.contains("kaboom payload"), "{err}");
    }

    #[test]
    fn exec_mode_parses() {
        assert_eq!(ExecMode::parse("sequential"), Some(ExecMode::Sequential));
        assert_eq!(ExecMode::parse("seq"), Some(ExecMode::Sequential));
        assert_eq!(ExecMode::parse("streaming"), Some(ExecMode::Streaming));
        assert_eq!(ExecMode::parse("stream"), Some(ExecMode::Streaming));
        assert_eq!(ExecMode::parse("multi"), Some(ExecMode::MultiInstance(2)));
        assert_eq!(ExecMode::parse("multi:6"), Some(ExecMode::MultiInstance(6)));
        assert_eq!(ExecMode::parse("warp"), None);
        assert_eq!(ExecMode::MultiInstance(4).to_string(), "multi:4");
    }

    #[test]
    fn exec_mode_display_parse_round_trips() {
        let modes = [
            ExecMode::Sequential,
            ExecMode::Streaming,
            ExecMode::MultiInstance(1),
            ExecMode::MultiInstance(2),
            ExecMode::MultiInstance(17),
        ];
        for mode in modes {
            assert_eq!(ExecMode::parse(&mode.to_string()), Some(mode), "{mode}");
        }
    }

    #[test]
    fn exec_mode_rejects_malformed_multi_specs() {
        // Zero instances is meaningless, a trailing colon has no count,
        // and garbage suffixes must not parse as a count.
        let bad_specs = [
            "multi:0", "multi:", "multi:x", "multi:3x", "multi:-1", "multi: 2", "multi:2.5",
            "", "sequentially",
        ];
        for bad in bad_specs {
            assert_eq!(ExecMode::parse(bad), None, "{bad:?} must not parse");
        }
    }

    #[test]
    fn executors_record_per_item_latency_samples() {
        // One sample per item that completes the sink, under both
        // single-instance executors.
        let seq = run_sequential(arithmetic_plan(100)).unwrap();
        assert_eq!(seq.report.latencies.len(), seq.output.items);
        let stream = run_streaming(arithmetic_plan(100), 4).unwrap();
        assert_eq!(stream.report.latencies.len(), stream.output.items);
        let p50 = stream.report.latency_percentile(0.5).unwrap();
        let p95 = stream.report.latency_percentile(0.95).unwrap();
        assert!(p95 >= p50);
        // Batch plans record one sample per sink arrival (a batch).
        let batched = run_sequential(batch_len_plan(20, 8, 1, 0)).unwrap();
        assert_eq!(batched.report.latencies.len(), 3);
    }

    #[test]
    fn multi_instance_pools_per_item_latencies() {
        let multi = run_multi_instance(3, |_| Ok(arithmetic_plan(40))).unwrap();
        let scaling = multi.scaling.as_ref().unwrap();
        let per_instance = run_sequential(arithmetic_plan(40)).unwrap().output.items;
        for inst in &scaling.instances {
            assert_eq!(inst.latencies.len(), per_instance, "instance {}", inst.instance);
        }
        // Merged report pools every instance's samples.
        assert_eq!(multi.report.latencies.len(), 3 * per_instance);
    }

    #[test]
    fn empty_source_still_finishes() {
        let plan = Plan::source("e", "none", Category::Pre, |_emit: &mut dyn FnMut(i32)| {})
            .sink(
                "out",
                Category::Post,
                0usize,
                |n: &mut usize, _x: i32| {
                    *n += 1;
                    Ok(())
                },
                |n| Ok(PlanOutput { metrics: BTreeMap::new(), items: n }),
            );
        let out = run_sequential(plan).unwrap();
        assert_eq!(out.output.items, 0);
    }
}
