//! Plan executors — interchangeable strategies for running a [`Plan`].
//!
//! * [`run_sequential`] — in-thread, stage-at-a-time (the tabular shape):
//!   lowest overhead, items materialized between stages.
//! * [`run_streaming`] — one thread per stage over bounded channels (the
//!   video/serving shape): backpressure keeps memory flat and exposes the
//!   slowest stage; batch nodes use the [`DynamicBatcher`] max-wait flush.
//! * [`run_multi_instance`] — N replicated plan instances on worker
//!   threads (§3.4 workload scaling), aggregated by the scaler with
//!   fairness and latency percentiles.
//! * [`run_sharded`] — N data-parallel workers over ONE dataset: each
//!   worker runs the same stage graph with its source restricted to a
//!   round-robin partition ([`Sharder`]), and the sink state is merged
//!   in shard order on the coordinating thread. Where multi-instance
//!   scales compute by replicating the stream n times, sharding makes a
//!   fixed dataset finish faster (the tf.data / BigDL source-partition
//!   shape).
//!
//! All four record the same per-stage [`Telemetry`], so every mode
//! yields the Figure 1 breakdown, and all four produce identical
//! deterministic metrics for a fixed seed — the executor-conformance
//! suite (`rust/tests/executor_equivalence.rs`) asserts exactly that.
//!
//! **Merge-aware sink contract (sharded mode).** Shard workers run
//! source → transforms only; no shard touches the sink. The coordinating
//! thread then folds every shard's output into the single sink state in
//! ascending shard order (all of shard 0's items, then shard 1's, …) and
//! runs `finish` once. The fold order is therefore deterministic — a
//! permutation of the sequential order that depends only on the partition
//! arithmetic, never on thread timing. A plan is shardable when its sink
//! fold is insensitive to that permutation (single-state sinks, counter
//! sinks, and index-sorting accumulators all qualify — every registry
//! pipeline does; the conformance matrix pins it).
//!
//! Every item is stamped at source emission and its end-to-end latency
//! recorded when it completes the sink, so [`Report::latencies`] carries
//! measured per-item samples under every executor and the scaling
//! percentiles no longer fall back to instance wall time. Under the
//! streaming executor these are true in-flight latencies; under the
//! stage-at-a-time sequential executor an item's sink completion
//! necessarily trails the whole upstream pass, so its samples skew
//! toward the run duration (an honest property of that execution shape).

use super::batcher::DynamicBatcher;
use super::plan::{DynItem, Node, NodeKind, Plan, PlanOutput, Sharder};
use super::scaler::{InstanceReport, ScalingReport};
use super::telemetry::{Category, Report, ShardReport, ShardedReport, StageReport, Telemetry};
use crate::parallel::channel::bounded;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Which executor runs a plan; selected via `RunConfig::exec` or `--exec`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecMode {
    /// In-thread, stage-at-a-time.
    #[default]
    Sequential,
    /// Thread-per-stage over bounded channels with backpressure.
    Streaming,
    /// N replicated plan instances (each sequential), scaler-aggregated.
    /// Each instance processes its own stream: n× the data, n× the work.
    MultiInstance(usize),
    /// N data-parallel shards over one dataset: the source is partitioned
    /// round-robin across n workers sharing the stage graph, and sink
    /// state is merged in shard order (see the module docs for the
    /// merge-aware sink contract). Each worker runs 1/n of the transform
    /// and sink work; every worker still produces (or clones) the full
    /// source stream and drops the emissions it does not own, so the
    /// speedup ceiling is set by how transform-heavy the plan is relative
    /// to its source.
    Sharded(usize),
}

/// Strict instance/shard count: ASCII digits only (no sign, no
/// whitespace, no garbage suffix), at least 1.
fn parse_count(s: &str) -> Option<usize> {
    if s.is_empty() || !s.bytes().all(|b| b.is_ascii_digit()) {
        return None;
    }
    s.parse().ok().filter(|&n| n >= 1)
}

impl ExecMode {
    /// Parse a CLI spelling: `sequential`, `streaming`, `multi[:<n>]`,
    /// `shard[:<n>]` (bare `multi` / `shard` default to 2). Counts must
    /// be plain positive integers — `multi:0`, `shard:0`, signs,
    /// whitespace, and trailing garbage are all rejected.
    pub fn parse(s: &str) -> Option<ExecMode> {
        match s {
            "sequential" | "seq" => Some(ExecMode::Sequential),
            "streaming" | "stream" => Some(ExecMode::Streaming),
            "multi" => Some(ExecMode::MultiInstance(2)),
            "shard" | "sharded" => Some(ExecMode::Sharded(2)),
            _ => {
                if let Some(rest) = s.strip_prefix("multi:") {
                    parse_count(rest).map(ExecMode::MultiInstance)
                } else if let Some(rest) = s.strip_prefix("shard:") {
                    parse_count(rest).map(ExecMode::Sharded)
                } else {
                    None
                }
            }
        }
    }
}

impl std::fmt::Display for ExecMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ExecMode::Sequential => f.write_str("sequential"),
            ExecMode::Streaming => f.write_str("streaming"),
            ExecMode::MultiInstance(n) => write!(f, "multi:{n}"),
            ExecMode::Sharded(n) => write!(f, "shard:{n}"),
        }
    }
}

/// Bound on every inter-stage queue in streaming mode.
pub const DEFAULT_QUEUE_CAP: usize = 8;

/// An in-flight item plus its source-emission instant; the stamp rides
/// along so the sink can record a true per-item end-to-end latency.
/// Batch nodes keep the earliest stamp of their members (a batch is as
/// old as its oldest item).
struct Stamped {
    born: Instant,
    item: DynItem,
}

/// What an executor returns: telemetry, the plan's output, and (for
/// multi-instance / sharded) the scaling or sharding aggregate.
pub struct ExecOutcome {
    /// Per-stage timing (Figure 1 source). Multi-instance and sharded
    /// execution merge stage busy time and item counts across workers.
    pub report: Report,
    /// The plan's deterministic metrics and item count. Multi-instance
    /// reports instance 0's metrics with `items` summed over instances;
    /// sharded reports the merged sink's metrics over the one dataset.
    pub output: PlanOutput,
    /// Present only for multi-instance execution.
    pub scaling: Option<ScalingReport>,
    /// Present only for sharded execution: per-shard partition sizes and
    /// pooled per-item latencies.
    pub sharding: Option<ShardedReport>,
}

/// Dispatch a plan-builder through the executor selected by `mode`.
/// `make_plan` is invoked once per instance (instance 0 for the
/// single-instance modes) so every replica gets fresh stage closures.
/// Sharded execution calls `make_plan(0)` once per shard — every shard
/// must see the *same* stream (sharding partitions one dataset; it never
/// gives workers distinct streams the way multi-instance does).
pub fn execute(
    mode: ExecMode,
    make_plan: impl Fn(usize) -> anyhow::Result<Plan> + Sync,
) -> anyhow::Result<ExecOutcome> {
    match mode {
        ExecMode::Sequential => run_sequential(make_plan(0)?),
        ExecMode::Streaming => run_streaming(make_plan(0)?, DEFAULT_QUEUE_CAP),
        ExecMode::MultiInstance(n) => run_multi_instance(n, make_plan),
        ExecMode::Sharded(n) => run_sharded(n, || make_plan(0)),
    }
}

/// The stage-at-a-time source+transform pass shared by the sequential
/// and sharded executors: run the source, then each transform node over
/// the whole stream, recording per-stage telemetry. Returns the stamped
/// pre-sink items. Batch nodes flush on size alone (every item is
/// already available, so the max-wait timer is irrelevant by
/// construction).
fn run_stages(
    telemetry: &Telemetry,
    source: (String, Category, crate::coordinator::plan::SourceFn),
    nodes: Vec<Node>,
) -> anyhow::Result<Vec<Stamped>> {
    let (src_name, src_cat, mut produce) = source;
    let handle = telemetry.stage(&src_name, src_cat);
    let mut items: Vec<Stamped> = Vec::new();
    let t0 = Instant::now();
    let mut produced = 0usize;
    produce(&mut |item| {
        produced += 1;
        items.push(Stamped { born: Instant::now(), item });
    });
    handle.record(t0.elapsed(), produced);

    for node in nodes {
        let handle = telemetry.stage(&node.name, node.category);
        match node.kind {
            NodeKind::FlatMap(mut f) => {
                let mut next = Vec::with_capacity(items.len());
                for Stamped { born, item } in items {
                    let t0 = Instant::now();
                    let outs = f(item)?;
                    handle.record(t0.elapsed(), 1);
                    next.extend(outs.into_iter().map(|item| Stamped { born, item }));
                }
                items = next;
            }
            NodeKind::Batch(cfg, mut group) => {
                let max = cfg.max_batch.max(1);
                let mut next = Vec::new();
                let mut iter = items.into_iter().peekable();
                while iter.peek().is_some() {
                    let batch: Vec<Stamped> = iter.by_ref().take(max).collect();
                    let born = batch.iter().map(|s| s.born).min().expect("non-empty batch");
                    let members: Vec<DynItem> = batch.into_iter().map(|s| s.item).collect();
                    let t0 = Instant::now();
                    next.push(Stamped { born, item: group(members)? });
                    handle.record(t0.elapsed(), 1);
                }
                items = next;
            }
        }
    }
    Ok(items)
}

/// Run a plan in the calling thread, one stage at a time over the whole
/// item stream.
pub fn run_sequential(plan: Plan) -> anyhow::Result<ExecOutcome> {
    let telemetry = Telemetry::new();
    let Plan { source, nodes, sink, finish, .. } = plan;
    let items = run_stages(&telemetry, source, nodes)?;

    let (sink_name, sink_cat, mut sink_fn) = sink;
    let handle = telemetry.stage(&sink_name, sink_cat);
    for Stamped { born, item } in items {
        let t0 = Instant::now();
        sink_fn(item)?;
        handle.record(t0.elapsed(), 1);
        telemetry.record_latency(born.elapsed());
    }
    let output = finish()?;
    Ok(ExecOutcome { report: telemetry.report(), output, scaling: None, sharding: None })
}

/// Run a plan with one thread per stage connected by bounded channels, so
/// a slow stage backpressures everything upstream. The sink folds on the
/// calling thread. Source busy time subtracts send-blocking (that is the
/// downstream stage's cost, not production work — counting it would smear
/// the slowest stage over the source in the Figure 1 breakdown).
pub fn run_streaming(plan: Plan, queue_cap: usize) -> anyhow::Result<ExecOutcome> {
    let telemetry = Telemetry::new();
    let cap = queue_cap.max(1);
    let first_err: Arc<Mutex<Option<anyhow::Error>>> = Arc::new(Mutex::new(None));
    let Plan { source: (src_name, src_cat, mut produce), nodes, sink, finish, .. } = plan;
    let (sink_name, sink_cat, mut sink_fn) = sink;
    let mut workers = Vec::with_capacity(nodes.len() + 1);

    let handle = telemetry.stage(&src_name, src_cat);
    let (tx, mut tail) = bounded::<Stamped>(cap);
    workers.push(
        std::thread::Builder::new()
            .name(format!("plan-src-{src_name}"))
            .spawn(move || {
                let t0 = Instant::now();
                let mut blocked = std::time::Duration::ZERO;
                let mut count = 0usize;
                produce(&mut |item| {
                    count += 1;
                    let stamped = Stamped { born: Instant::now(), item };
                    let s0 = Instant::now();
                    let _ = tx.send(stamped);
                    blocked += s0.elapsed();
                });
                handle.record(t0.elapsed().saturating_sub(blocked), count);
            })
            .expect("spawn plan source"),
    );

    for node in nodes {
        let handle = telemetry.stage(&node.name, node.category);
        let (tx, rx) = bounded::<Stamped>(cap);
        let upstream = tail;
        tail = rx;
        let errs = Arc::clone(&first_err);
        let worker = match node.kind {
            NodeKind::FlatMap(mut f) => std::thread::Builder::new()
                .name(format!("plan-stage-{}", node.name))
                .spawn(move || {
                    while let Ok(Stamped { born, item }) = upstream.recv() {
                        let t0 = Instant::now();
                        match f(item) {
                            Ok(outs) => {
                                handle.record(t0.elapsed(), 1);
                                for out in outs {
                                    if tx.send(Stamped { born, item: out }).is_err() {
                                        return; // downstream gone
                                    }
                                }
                            }
                            Err(e) => {
                                errs.lock().unwrap().get_or_insert(e);
                                return;
                            }
                        }
                    }
                })
                .expect("spawn plan stage"),
            NodeKind::Batch(cfg, mut group) => std::thread::Builder::new()
                .name(format!("plan-batch-{}", node.name))
                .spawn(move || {
                    let mut batcher = DynamicBatcher::new(upstream, cfg);
                    while let Some(batch) = batcher.next_batch() {
                        let born =
                            batch.iter().map(|s| s.born).min().expect("non-empty batch");
                        let members: Vec<DynItem> =
                            batch.into_iter().map(|s| s.item).collect();
                        let t0 = Instant::now();
                        match group(members) {
                            Ok(item) => {
                                handle.record(t0.elapsed(), 1);
                                if tx.send(Stamped { born, item }).is_err() {
                                    return;
                                }
                            }
                            Err(e) => {
                                errs.lock().unwrap().get_or_insert(e);
                                return;
                            }
                        }
                    }
                })
                .expect("spawn plan batch"),
        };
        workers.push(worker);
    }

    let handle = telemetry.stage(&sink_name, sink_cat);
    while let Ok(Stamped { born, item }) = tail.recv() {
        let t0 = Instant::now();
        if let Err(e) = sink_fn(item) {
            first_err.lock().unwrap().get_or_insert(e);
            break;
        }
        handle.record(t0.elapsed(), 1);
        telemetry.record_latency(born.elapsed());
    }
    // Dropping the tail receiver makes upstream sends fail fast if we
    // broke out early; workers then unwind without deadlocking.
    drop(tail);
    let mut panicked: Option<String> = None;
    for worker in workers {
        let name = worker.thread().name().unwrap_or("plan-worker").to_string();
        if let Err(payload) = worker.join() {
            let msg = payload
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "non-string panic payload".to_string());
            panicked.get_or_insert(format!("{name} panicked: {msg}"));
        }
    }
    if let Some(e) = first_err.lock().unwrap().take() {
        return Err(e);
    }
    // A stage panic must surface as loudly as it would under the
    // sequential executor, not as partial metrics.
    if let Some(msg) = panicked {
        return Err(anyhow::anyhow!("streaming stage failed: {msg}"));
    }
    let output = finish()?;
    Ok(ExecOutcome { report: telemetry.report(), output, scaling: None, sharding: None })
}

/// Run `n` replicated instances of the plan on worker threads (each
/// instance sequential — the paper's parallel-streams shape), and
/// aggregate throughput, fairness, and latency percentiles. The merged
/// report sums per-stage busy time and items across instances.
pub fn run_multi_instance(
    n: usize,
    make_plan: impl Fn(usize) -> anyhow::Result<Plan> + Sync,
) -> anyhow::Result<ExecOutcome> {
    anyhow::ensure!(n >= 1, "multi-instance execution needs at least one instance");
    let t0 = Instant::now();
    let mut results: Vec<(anyhow::Result<ExecOutcome>, std::time::Duration)> =
        Vec::with_capacity(n);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..n)
            .map(|i| {
                let make_plan = &make_plan;
                scope.spawn(move || {
                    // Plan construction (data generation, model warmup) is
                    // explicitly outside the timed run — the pipelines
                    // measure steady state, and the scaling metrics must
                    // match that.
                    let plan = make_plan(i);
                    let it0 = Instant::now();
                    let res = plan.and_then(run_sequential);
                    (res, it0.elapsed())
                })
            })
            .collect();
        for h in handles {
            results.push(h.join().expect("plan instance panicked"));
        }
    });
    let wall = t0.elapsed();

    let mut instances = Vec::with_capacity(n);
    let mut reports: Vec<Report> = Vec::with_capacity(n);
    let mut first_output: Option<PlanOutput> = None;
    for (i, (res, elapsed)) in results.into_iter().enumerate() {
        let outcome = res?;
        instances.push(InstanceReport {
            instance: i,
            items: outcome.output.items,
            elapsed,
            // Per-item samples recorded by the instance's sink. Each
            // replica runs sequentially (stage-at-a-time), so samples
            // approximate the instance pass for multi-item plans — still
            // measured per item, no longer the wall-time fallback.
            latencies: outcome.report.latencies.clone(),
        });
        reports.push(outcome.report);
        if first_output.is_none() {
            first_output = Some(outcome.output);
        }
    }
    let scaling = ScalingReport { instances, wall };
    let mut output = first_output.expect("n >= 1 guarantees one outcome");
    output.items = scaling.total_items();
    Ok(ExecOutcome {
        report: merge_reports(&reports),
        output,
        scaling: Some(scaling),
        sharding: None,
    })
}

/// One shard's source+transform pass: its pre-sink items, its stage
/// telemetry (source + transforms, no sink), and — for shard 0 only —
/// the donated sink the merge phase folds every shard's items into.
struct ShardPass {
    items: Vec<Stamped>,
    report: Report,
    elapsed: Duration,
    sink: Option<ShardSink>,
}

type ShardSink = (
    (String, Category, crate::coordinator::plan::SinkFn),
    crate::coordinator::plan::FinishFn,
);

/// Run one dataset as `n` data-parallel shards (§3.4 turned from
/// replication into partitioning): every shard builds the same plan —
/// `make_plan` must be deterministic — restricted to its round-robin
/// partition via [`Plan::shard`], and runs source → transforms on its
/// own worker thread. No shard touches the sink; the coordinating
/// thread then folds all pre-sink items into shard 0's sink **in shard
/// order** and runs `finish` once (the merge-aware sink contract — see
/// the module docs). Metrics are therefore deterministic and, for
/// fold-order-insensitive sinks, identical to a sequential run of the
/// same plan; `Sharded(1)` is always identical to `Sequential`.
///
/// Cost model: plan construction and the full source pass run once
/// *per shard* (each worker drops the emissions it does not own — the
/// plan-level filter keeps sharding pipeline-agnostic), while transform
/// and sink work split 1/n. Sharding therefore pays off on
/// transform-heavy plans (the per-item DL pipelines) and degenerates
/// gracefully to sequential cost on source-heavy or single-item plans.
/// Payload-aware source slicing (splitting an already-materialized
/// `Workload` before plan build) is the follow-up that would drop the
/// redundant source passes.
pub fn run_sharded(
    n: usize,
    make_plan: impl Fn() -> anyhow::Result<Plan> + Sync,
) -> anyhow::Result<ExecOutcome> {
    anyhow::ensure!(n >= 1, "sharded execution needs at least one shard");
    let t0 = Instant::now();
    let mut passes: Vec<anyhow::Result<ShardPass>> = Vec::with_capacity(n);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..n)
            .map(|s| {
                let make_plan = &make_plan;
                scope.spawn(move || -> anyhow::Result<ShardPass> {
                    // Plan construction (payload binding, model warmup)
                    // stays outside the timed pass, like multi-instance.
                    // DL plans share the one ModelServer across shards.
                    let plan = make_plan()?.shard(Sharder::new(s, n));
                    let it0 = Instant::now();
                    let telemetry = Telemetry::new();
                    let Plan { source, nodes, sink, finish, .. } = plan;
                    let items = run_stages(&telemetry, source, nodes)?;
                    Ok(ShardPass {
                        items,
                        report: telemetry.report(),
                        elapsed: it0.elapsed(),
                        sink: (s == 0).then_some((sink, finish)),
                    })
                })
            })
            .collect();
        for h in handles {
            passes.push(h.join().expect("shard worker panicked"));
        }
    });

    let mut reports = Vec::with_capacity(n);
    let mut shard_items = Vec::with_capacity(n);
    let mut donated_sink = None;
    for pass in passes {
        let ShardPass { items, report, elapsed, sink } = pass?;
        if let Some(sink) = sink {
            donated_sink = Some(sink);
        }
        // Owned emissions = the shard's source stage count (the filtered
        // source only forwards — and the executor only counts — items
        // the shard's partition owns).
        let owned = report.stages.first().map_or(0, |s| s.items);
        shard_items.push((items, elapsed, owned));
        reports.push(report);
    }
    let ((sink_name, sink_cat, mut sink_fn), finish) =
        donated_sink.expect("shard 0 donates the merge sink");

    // Merge phase: fold every shard's items into the single sink state
    // in ascending shard order, timing the folds as the sink stage and
    // recording each item's end-to-end latency against its shard.
    let mut merged = merge_reports(&reports);
    let mut shards = Vec::with_capacity(n);
    let mut sink_busy = Duration::ZERO;
    let mut sink_count = 0usize;
    for (shard, (items, elapsed, owned)) in shard_items.into_iter().enumerate() {
        let mut latencies = Vec::with_capacity(items.len());
        for Stamped { born, item } in items {
            let f0 = Instant::now();
            sink_fn(item)?;
            sink_busy += f0.elapsed();
            sink_count += 1;
            latencies.push(born.elapsed());
        }
        merged.latencies.extend_from_slice(&latencies);
        shards.push(ShardReport { shard, owned, completed: latencies.len(), elapsed, latencies });
    }
    merged.stages.push(StageReport {
        name: sink_name,
        category: sink_cat,
        items: sink_count,
        busy: sink_busy,
    });
    let output = finish()?;
    let sharding = ShardedReport { shards, wall: t0.elapsed() };
    Ok(ExecOutcome { report: merged, output, scaling: None, sharding: Some(sharding) })
}

fn merge_reports(reports: &[Report]) -> Report {
    let mut merged = reports[0].clone();
    for r in &reports[1..] {
        for (m, s) in merged.stages.iter_mut().zip(&r.stages) {
            debug_assert_eq!(m.name, s.name, "instances must share a stage structure");
            m.busy += s.busy;
            m.items += s.items;
        }
        merged.latencies.extend_from_slice(&r.latencies);
    }
    merged
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::BatcherConfig;
    use crate::coordinator::telemetry::Category;
    use std::collections::BTreeMap;
    use std::time::Duration;

    /// source 0..n → double → drop odd halves → collect; returns sum.
    fn arithmetic_plan(n: i32) -> Plan {
        Plan::source("t", "gen", Category::Pre, move |emit| {
            for i in 0..n {
                emit(i);
            }
        })
        .map("double", Category::Pre, |x: i32| Ok(x * 2))
        .flat_map("keep_quarters", Category::Ai, |x: i32| {
            Ok(if x % 4 == 0 { vec![x] } else { vec![] })
        })
        .sink(
            "collect",
            Category::Post,
            Vec::new(),
            |v: &mut Vec<i32>, x| {
                v.push(x);
                Ok(())
            },
            |v| {
                let mut metrics = BTreeMap::new();
                metrics.insert("sum".to_string(), v.iter().sum::<i32>() as f64);
                Ok(PlanOutput { metrics, items: v.len() })
            },
        )
    }

    fn batch_len_plan(n: u32, max_batch: usize, max_wait_ms: u64, gap_ms: u64) -> Plan {
        Plan::source("b", "gen", Category::Pre, move |emit| {
            for i in 0..n {
                if gap_ms > 0 && i > 0 {
                    std::thread::sleep(Duration::from_millis(gap_ms));
                }
                emit(i);
            }
        })
        .batch(
            "batcher",
            Category::Pre,
            BatcherConfig { max_batch, max_wait: Duration::from_millis(max_wait_ms) },
        )
        .map("len", Category::Ai, |b: Vec<u32>| Ok(b.len()))
        .sink(
            "collect",
            Category::Post,
            Vec::new(),
            |v: &mut Vec<usize>, l| {
                v.push(l);
                Ok(())
            },
            |v| {
                let mut metrics = BTreeMap::new();
                metrics.insert("batches".to_string(), v.len() as f64);
                Ok(PlanOutput { metrics, items: v.iter().sum() })
            },
        )
    }

    #[test]
    fn sequential_and_streaming_agree() {
        let seq = run_sequential(arithmetic_plan(100)).unwrap();
        let stream = run_streaming(arithmetic_plan(100), 4).unwrap();
        assert_eq!(seq.output.items, stream.output.items);
        assert_eq!(seq.output.metrics, stream.output.metrics);
        assert_eq!(seq.report.stages.len(), 4);
        assert_eq!(stream.report.stages.len(), 4);
        // Same stage structure in the same order.
        for (a, b) in seq.report.stages.iter().zip(&stream.report.stages) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.items, b.items);
        }
    }

    #[test]
    fn multi_instance_of_one_matches_sequential() {
        let seq = run_sequential(arithmetic_plan(40)).unwrap();
        let multi = run_multi_instance(1, |_| Ok(arithmetic_plan(40))).unwrap();
        assert_eq!(seq.output.items, multi.output.items);
        assert_eq!(seq.output.metrics, multi.output.metrics);
        let scaling = multi.scaling.unwrap();
        assert_eq!(scaling.instances.len(), 1);
        assert_eq!(scaling.total_items(), seq.output.items);
    }

    #[test]
    fn multi_instance_aggregates() {
        let multi = run_multi_instance(3, |_| Ok(arithmetic_plan(40))).unwrap();
        let seq = run_sequential(arithmetic_plan(40)).unwrap();
        assert_eq!(multi.output.items, 3 * seq.output.items);
        let scaling = multi.scaling.unwrap();
        assert_eq!(scaling.instances.len(), 3);
        assert!((scaling.fairness() - 1.0).abs() < 1e-9);
        assert!(scaling.latency_p50().is_some());
        // Merged report sums item counts across instances.
        assert_eq!(multi.report.stages[0].items, 3 * seq.report.stages[0].items);
    }

    #[test]
    fn sequential_batch_flushes_on_size() {
        // 20 items, max_batch 8 → batches of 8/8/4 regardless of max_wait.
        let out = run_sequential(batch_len_plan(20, 8, 1, 0)).unwrap();
        assert_eq!(out.output.items, 20);
        assert_eq!(out.output.metrics["batches"], 3.0);
    }

    #[test]
    fn streaming_batch_flushes_on_timeout() {
        // Items arrive 30ms apart with a 5ms max wait → every batch
        // flushes by timeout with a single item.
        let out = run_streaming(batch_len_plan(3, 8, 5, 30), 4).unwrap();
        assert_eq!(out.output.items, 3);
        assert_eq!(out.output.metrics["batches"], 3.0);
    }

    #[test]
    fn streaming_batch_fills_on_fast_source() {
        // A hot queue with a generous wait fills batches to max_batch.
        let out = run_streaming(batch_len_plan(16, 4, 250, 0), 32).unwrap();
        assert_eq!(out.output.items, 16);
        assert_eq!(out.output.metrics["batches"], 4.0);
    }

    #[test]
    fn errors_propagate_from_both_executors() {
        let failing = || {
            Plan::source("f", "gen", Category::Pre, |emit| emit(1i32))
                .map("boom", Category::Ai, |_x: i32| {
                    Err::<i32, _>(anyhow::anyhow!("boom"))
                })
                .sink(
                    "out",
                    Category::Post,
                    (),
                    |_s: &mut (), _x: i32| Ok(()),
                    |_| Ok(PlanOutput { metrics: BTreeMap::new(), items: 0 }),
                )
        };
        assert!(run_sequential(failing()).unwrap_err().to_string().contains("boom"));
        assert!(run_streaming(failing(), 2).unwrap_err().to_string().contains("boom"));
        assert!(run_multi_instance(2, |_| Ok(failing())).is_err());
        assert!(run_sharded(2, || Ok(failing())).unwrap_err().to_string().contains("boom"));
    }

    #[test]
    fn streaming_surfaces_stage_panics() {
        // A stage panic must fail the run like it would sequentially,
        // never return Ok with partial metrics.
        let plan = Plan::source("p", "gen", Category::Pre, |emit| emit(1i32))
            .map("kaboom", Category::Ai, |_x: i32| -> anyhow::Result<i32> {
                panic!("kaboom payload")
            })
            .sink(
                "out",
                Category::Post,
                (),
                |_s: &mut (), _x: i32| Ok(()),
                |_| Ok(PlanOutput { metrics: BTreeMap::new(), items: 0 }),
            );
        let err = run_streaming(plan, 2).unwrap_err().to_string();
        assert!(err.contains("panicked"), "{err}");
        assert!(err.contains("kaboom payload"), "{err}");
    }

    #[test]
    fn exec_mode_parses() {
        assert_eq!(ExecMode::parse("sequential"), Some(ExecMode::Sequential));
        assert_eq!(ExecMode::parse("seq"), Some(ExecMode::Sequential));
        assert_eq!(ExecMode::parse("streaming"), Some(ExecMode::Streaming));
        assert_eq!(ExecMode::parse("stream"), Some(ExecMode::Streaming));
        assert_eq!(ExecMode::parse("multi"), Some(ExecMode::MultiInstance(2)));
        assert_eq!(ExecMode::parse("multi:6"), Some(ExecMode::MultiInstance(6)));
        assert_eq!(ExecMode::parse("shard"), Some(ExecMode::Sharded(2)));
        assert_eq!(ExecMode::parse("sharded"), Some(ExecMode::Sharded(2)));
        assert_eq!(ExecMode::parse("shard:4"), Some(ExecMode::Sharded(4)));
        assert_eq!(ExecMode::parse("warp"), None);
        assert_eq!(ExecMode::MultiInstance(4).to_string(), "multi:4");
        assert_eq!(ExecMode::Sharded(4).to_string(), "shard:4");
    }

    #[test]
    fn exec_mode_display_parse_round_trips() {
        let modes = [
            ExecMode::Sequential,
            ExecMode::Streaming,
            ExecMode::MultiInstance(1),
            ExecMode::MultiInstance(2),
            ExecMode::MultiInstance(17),
            ExecMode::Sharded(1),
            ExecMode::Sharded(2),
            ExecMode::Sharded(17),
        ];
        for mode in modes {
            assert_eq!(ExecMode::parse(&mode.to_string()), Some(mode), "{mode}");
        }
    }

    #[test]
    fn exec_mode_rejects_malformed_specs() {
        // Zero workers is meaningless, a trailing colon has no count,
        // signs/whitespace/garbage suffixes must not parse as a count
        // (`"+2".parse::<usize>()` would accept the sign — the strict
        // digit check exists to reject exactly that class).
        let bad_specs = [
            "multi:0", "multi:", "multi:x", "multi:3x", "multi:-1", "multi:+2", "multi: 2",
            "multi:2.5", "multi:2 ", "shard:0", "shard:", "shard:x", "shard:3x", "shard:-1",
            "shard:+2", "shard: 2", "shard:2.5", " shard:2 ", "shard:2 ", " shard:2", "",
            "sequentially", "shards",
        ];
        for bad in bad_specs {
            assert_eq!(ExecMode::parse(bad), None, "{bad:?} must not parse");
        }
    }

    #[test]
    fn sharded_of_one_matches_sequential() {
        let seq = run_sequential(arithmetic_plan(40)).unwrap();
        let sharded = run_sharded(1, || Ok(arithmetic_plan(40))).unwrap();
        assert_eq!(seq.output.items, sharded.output.items);
        assert_eq!(seq.output.metrics, sharded.output.metrics);
        let sharding = sharded.sharding.unwrap();
        assert_eq!(sharding.shard_count(), 1);
        assert_eq!(sharding.total_owned(), 40);
        assert_eq!(sharding.total_completed(), seq.output.items);
        assert!(sharded.scaling.is_none(), "sharded runs carry no scaling aggregate");
    }

    #[test]
    fn sharded_partitions_one_dataset_and_merges_in_shard_order() {
        let seq = run_sequential(arithmetic_plan(100)).unwrap();
        for n in 2..=4usize {
            let sharded = run_sharded(n, || Ok(arithmetic_plan(100))).unwrap();
            // One dataset: items and metrics equal sequential (NOT n×,
            // which is what multi-instance would report).
            assert_eq!(sharded.output.items, seq.output.items, "n={n}");
            assert_eq!(sharded.output.metrics, seq.output.metrics, "n={n}");
            // Same stage structure as sequential, with per-stage item
            // counts summing to the sequential counts across shards.
            let names: Vec<&String> = sharded.report.stages.iter().map(|s| &s.name).collect();
            let seq_names: Vec<&String> = seq.report.stages.iter().map(|s| &s.name).collect();
            assert_eq!(names, seq_names, "n={n}");
            for (a, b) in sharded.report.stages.iter().zip(&seq.report.stages) {
                assert_eq!(a.items, b.items, "stage {} n={n}", a.name);
            }
            let sharding = sharded.sharding.unwrap();
            assert_eq!(sharding.shard_count(), n);
            // Round-robin partition: disjoint cover of the 100 emissions.
            assert_eq!(sharding.total_owned(), 100, "n={n}");
            for s in &sharding.shards {
                assert_eq!(s.owned, 100 / n + usize::from(s.shard < 100 % n), "n={n}");
                assert_eq!(s.latencies.len(), s.completed);
            }
            assert!(sharding.balance() > 0.7, "n={n}: {}", sharding.balance());
            // Pooled latency samples: one per item completing the sink.
            assert_eq!(sharding.pooled_latencies().len(), seq.output.items, "n={n}");
            assert_eq!(sharded.report.latencies.len(), seq.output.items, "n={n}");
            let p50 = sharding.latency_percentile(0.50).unwrap();
            let p95 = sharding.latency_percentile(0.95).unwrap();
            assert!(p95 >= p50, "n={n}");
        }
    }

    #[test]
    fn sharded_single_item_source_lands_on_shard_zero() {
        // The tabular pipelines emit one state item; sharding must not
        // lose it or fail the idle shards.
        let one = |emit: &mut dyn FnMut(i32)| emit(7);
        let make = move || {
            Ok(Plan::source("one", "gen", Category::Pre, one)
                .map("id", Category::Ai, |x: i32| Ok(x))
                .sink(
                    "out",
                    Category::Post,
                    0i64,
                    |acc: &mut i64, x: i32| {
                        *acc += x as i64;
                        Ok(())
                    },
                    |acc| {
                        let mut metrics = BTreeMap::new();
                        metrics.insert("sum".to_string(), acc as f64);
                        Ok(PlanOutput { metrics, items: 1 })
                    },
                ))
        };
        let out = run_sharded(4, make).unwrap();
        assert_eq!(out.output.metrics["sum"], 7.0);
        let sharding = out.sharding.unwrap();
        assert_eq!(sharding.total_owned(), 1);
        assert_eq!(sharding.shards[0].owned, 1);
        for s in &sharding.shards[1..] {
            assert_eq!(s.owned, 0, "shard {} must own nothing", s.shard);
            assert_eq!(s.completed, 0);
        }
    }

    #[test]
    fn sharded_batch_plans_batch_within_each_partition() {
        // 20 items, max_batch 8: sequential cuts 8/8/4 = 3 batches;
        // two shards of 10 cut 8/2 each = 4 batches. Item counts are
        // preserved; batch boundaries are an executor property (exactly
        // like the streaming executor's timeout flushes).
        let sharded = run_sharded(2, || Ok(batch_len_plan(20, 8, 1, 0))).unwrap();
        assert_eq!(sharded.output.items, 20);
        assert_eq!(sharded.output.metrics["batches"], 4.0);
        let sharding = sharded.sharding.unwrap();
        assert_eq!(sharding.total_owned(), 20);
        // One latency sample per sink arrival (a batch).
        assert_eq!(sharding.pooled_latencies().len(), 4);
    }

    #[test]
    fn sharded_empty_source_still_finishes() {
        let make = || {
            Ok(Plan::source("e", "none", Category::Pre, |_emit: &mut dyn FnMut(i32)| {}).sink(
                "out",
                Category::Post,
                0usize,
                |n: &mut usize, _x: i32| {
                    *n += 1;
                    Ok(())
                },
                |n| Ok(PlanOutput { metrics: BTreeMap::new(), items: n }),
            ))
        };
        let out = run_sharded(3, make).unwrap();
        assert_eq!(out.output.items, 0);
        let sharding = out.sharding.unwrap();
        assert_eq!(sharding.total_owned(), 0);
        assert!(sharding.latency_percentile(0.5).is_none());
    }

    #[test]
    fn sharded_sink_errors_propagate_from_the_merge_fold() {
        // Transforms succeed on every shard; the sink rejects one item.
        let make = || {
            Ok(Plan::source("s", "gen", Category::Pre, |emit: &mut dyn FnMut(i32)| {
                for i in 0..10 {
                    emit(i);
                }
            })
            .sink(
                "picky",
                Category::Post,
                (),
                |_s: &mut (), x: i32| {
                    anyhow::ensure!(x != 7, "sink rejects item 7");
                    Ok(())
                },
                |_| Ok(PlanOutput { metrics: BTreeMap::new(), items: 0 }),
            ))
        };
        let err = run_sharded(3, make).unwrap_err().to_string();
        assert!(err.contains("rejects item 7"), "{err}");
    }

    #[test]
    fn sharded_rejects_zero_shards() {
        let err = run_sharded(0, || Ok(arithmetic_plan(4))).unwrap_err().to_string();
        assert!(err.contains("at least one shard"), "{err}");
    }

    #[test]
    fn executors_record_per_item_latency_samples() {
        // One sample per item that completes the sink, under both
        // single-instance executors.
        let seq = run_sequential(arithmetic_plan(100)).unwrap();
        assert_eq!(seq.report.latencies.len(), seq.output.items);
        let stream = run_streaming(arithmetic_plan(100), 4).unwrap();
        assert_eq!(stream.report.latencies.len(), stream.output.items);
        let p50 = stream.report.latency_percentile(0.5).unwrap();
        let p95 = stream.report.latency_percentile(0.95).unwrap();
        assert!(p95 >= p50);
        // Batch plans record one sample per sink arrival (a batch).
        let batched = run_sequential(batch_len_plan(20, 8, 1, 0)).unwrap();
        assert_eq!(batched.report.latencies.len(), 3);
    }

    #[test]
    fn multi_instance_pools_per_item_latencies() {
        let multi = run_multi_instance(3, |_| Ok(arithmetic_plan(40))).unwrap();
        let scaling = multi.scaling.as_ref().unwrap();
        let per_instance = run_sequential(arithmetic_plan(40)).unwrap().output.items;
        for inst in &scaling.instances {
            assert_eq!(inst.latencies.len(), per_instance, "instance {}", inst.instance);
        }
        // Merged report pools every instance's samples.
        assert_eq!(multi.report.latencies.len(), 3 * per_instance);
    }

    #[test]
    fn empty_source_still_finishes() {
        let plan = Plan::source("e", "none", Category::Pre, |_emit: &mut dyn FnMut(i32)| {})
            .sink(
                "out",
                Category::Post,
                0usize,
                |n: &mut usize, _x: i32| {
                    *n += 1;
                    Ok(())
                },
                |n| Ok(PlanOutput { metrics: BTreeMap::new(), items: n }),
            );
        let out = run_sequential(plan).unwrap();
        assert_eq!(out.output.items, 0);
    }
}
