//! Request routing and admission control — the serving-side queue that
//! sits between a [`crate::service::PipelineService`] session and the
//! plan executors.
//!
//! ROADMAP named the batcher node as the natural seam for priority
//! queues and load shedding; this module is that seam made explicit. An
//! [`AdmissionQueue`] is a bounded, priority-laned MPMC queue:
//!
//! * **Admission** is synchronous and never blocks: a request either
//!   enters a lane, displaces a strictly-lower-priority entry (the
//!   displaced entry is *shed*, not dropped silently), or is itself shed
//!   when nothing below its priority is queued. Shedding is a first-class
//!   outcome ([`AdmitOutcome`]) so callers can resolve shed requests as
//!   typed responses instead of errors.
//! * **Dispatch** ([`AdmissionQueue::pop`]) serves the highest non-empty
//!   priority lane, FIFO within a lane, blocking until work arrives or
//!   the queue is closed and drained.
//!
//! The queue is workload-agnostic (`T` is whatever the caller enqueues);
//! [`QueueStats`] counts admissions, sheds, dispatches, and peak depth
//! for the soak reports.
//!
//! **Executor interaction.** Admission semantics are identical for every
//! session executor — same depth bound, same displacement rule, same
//! first-class sheds (the service suites pin deterministic shedding at a
//! fixed depth under async sessions too). What changes under an
//! `ExecMode::Async` service is dispatch *pressure*: an async dispatcher
//! spawns each popped request onto the shared task pool and immediately
//! pops again, so the pop rate is bounded by plan *construction*, not
//! plan *execution*. Queue depth then measures the spawn backlog while
//! the pool's own ledger ([`SchedReport`]) measures execution backlog —
//! shedding still engages whenever producers outrun admission, exactly
//! as before.
//!
//! [`SchedReport`]: super::telemetry::SchedReport

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

/// Request priority; admission prefers higher levels and sheds lower
/// ones first. `Ord`: `Low < Normal < High`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub enum Priority {
    /// Best-effort: first to be shed under load.
    Low,
    /// The default serving level.
    #[default]
    Normal,
    /// Latency-sensitive: displaces queued lower-priority work when the
    /// queue is full.
    High,
}

impl Priority {
    /// All levels, lowest first (lane order).
    pub const ALL: [Priority; 3] = [Priority::Low, Priority::Normal, Priority::High];

    /// Label used in reports and the CLI.
    pub fn label(self) -> &'static str {
        match self {
            Priority::Low => "low",
            Priority::Normal => "normal",
            Priority::High => "high",
        }
    }

    /// Parse a CLI spelling.
    pub fn parse(s: &str) -> Option<Priority> {
        match s {
            "low" => Some(Priority::Low),
            "normal" => Some(Priority::Normal),
            "high" => Some(Priority::High),
            _ => None,
        }
    }

    fn lane(self) -> usize {
        self as usize
    }
}

impl std::fmt::Display for Priority {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

/// What [`AdmissionQueue::admit`] decided.
pub struct AdmitOutcome<T> {
    /// Whether the incoming item entered the queue.
    pub admitted: bool,
    /// Entries shed to reach that decision: the incoming item itself when
    /// it was rejected, or displaced lower-priority entries when the
    /// incoming item was admitted into a full queue.
    pub shed: Vec<(Priority, T)>,
}

/// Per-priority-lane counters inside [`QueueStats`], so overload
/// reports can show WHICH traffic class absorbed the shedding (the
/// displacement rule concentrates sheds in the lowest lanes).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LaneStats {
    /// Entries queued in this lane right now (snapshot at
    /// [`AdmissionQueue::stats`] time).
    pub depth: usize,
    /// Highest simultaneous depth this lane reached.
    pub peak_depth: usize,
    /// Requests that entered this lane.
    pub admitted: u64,
    /// Requests shed FROM this lane: rejected at this priority, or
    /// displaced out of it by higher-priority admissions.
    pub shed: u64,
    /// Requests popped from this lane.
    pub dispatched: u64,
}

/// Counters over an [`AdmissionQueue`]'s lifetime.
#[derive(Debug, Clone, Copy, Default)]
pub struct QueueStats {
    /// Requests that entered a lane.
    pub admitted: u64,
    /// Requests shed at admission (rejected or displaced).
    pub shed: u64,
    /// Requests handed to a worker by [`AdmissionQueue::pop`].
    pub dispatched: u64,
    /// Highest simultaneous queue depth observed.
    pub peak_depth: usize,
    /// The same ledger split per priority lane, indexed by
    /// `Priority::lane()` (use [`QueueStats::lane`] for typed access).
    pub lanes: [LaneStats; 3],
}

impl QueueStats {
    /// The counters for one priority's lane.
    pub fn lane(&self, p: Priority) -> LaneStats {
        self.lanes[p.lane()]
    }
}

struct State<T> {
    /// One FIFO lane per [`Priority`], indexed by `Priority::lane()`.
    lanes: [VecDeque<T>; 3],
    len: usize,
    closed: bool,
    stats: QueueStats,
}

/// Bounded priority admission queue with load shedding (see module docs).
pub struct AdmissionQueue<T> {
    state: Mutex<State<T>>,
    ready: Condvar,
    depth: usize,
}

impl<T> AdmissionQueue<T> {
    /// A queue admitting at most `depth` (>= 1) simultaneous entries.
    pub fn new(depth: usize) -> AdmissionQueue<T> {
        AdmissionQueue {
            state: Mutex::new(State {
                lanes: [VecDeque::new(), VecDeque::new(), VecDeque::new()],
                len: 0,
                closed: false,
                stats: QueueStats::default(),
            }),
            ready: Condvar::new(),
            depth: depth.max(1),
        }
    }

    /// Admission bound.
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Try to enqueue `item` at `priority`. Never blocks; see
    /// [`AdmitOutcome`] for the shedding contract. Items offered after
    /// [`Self::close`] are shed.
    pub fn admit(&self, priority: Priority, item: T) -> AdmitOutcome<T> {
        let mut s = self.state.lock().unwrap();
        if s.closed {
            s.stats.shed += 1;
            s.stats.lanes[priority.lane()].shed += 1;
            return AdmitOutcome { admitted: false, shed: vec![(priority, item)] };
        }
        let mut shed = Vec::new();
        if s.len >= self.depth {
            // Displace from the lowest non-empty lane strictly below the
            // incoming priority; shed the incoming item when there is none.
            let mut displaced = None;
            for lane in 0..priority.lane() {
                if let Some(victim) = s.lanes[lane].pop_back() {
                    displaced = Some((Priority::ALL[lane], victim));
                    break;
                }
            }
            match displaced {
                Some(victim) => {
                    s.len -= 1;
                    s.stats.lanes[victim.0.lane()].shed += 1;
                    shed.push(victim);
                }
                None => {
                    s.stats.shed += 1;
                    s.stats.lanes[priority.lane()].shed += 1;
                    return AdmitOutcome { admitted: false, shed: vec![(priority, item)] };
                }
            }
        }
        s.lanes[priority.lane()].push_back(item);
        s.len += 1;
        s.stats.admitted += 1;
        s.stats.shed += shed.len() as u64;
        s.stats.peak_depth = s.stats.peak_depth.max(s.len);
        let lane_len = s.lanes[priority.lane()].len();
        let lane_stats = &mut s.stats.lanes[priority.lane()];
        lane_stats.admitted += 1;
        lane_stats.peak_depth = lane_stats.peak_depth.max(lane_len);
        drop(s);
        self.ready.notify_one();
        AdmitOutcome { admitted: true, shed }
    }

    /// Dequeue the highest-priority entry (FIFO within a lane), blocking
    /// until one arrives. `None` once the queue is closed and drained.
    pub fn pop(&self) -> Option<(Priority, T)> {
        let mut s = self.state.lock().unwrap();
        loop {
            for lane in (0..3).rev() {
                if let Some(item) = s.lanes[lane].pop_front() {
                    s.len -= 1;
                    s.stats.dispatched += 1;
                    s.stats.lanes[lane].dispatched += 1;
                    return Some((Priority::ALL[lane], item));
                }
            }
            if s.closed {
                return None;
            }
            s = self.ready.wait(s).unwrap();
        }
    }

    /// Close the queue: later admissions are shed, poppers drain what is
    /// queued and then observe `None`.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.ready.notify_all();
    }

    /// Entries currently queued.
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().len
    }

    /// True when nothing is queued.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Lifetime counters, with each lane's current depth snapshotted.
    pub fn stats(&self) -> QueueStats {
        let s = self.state.lock().unwrap();
        let mut stats = s.stats;
        for lane in 0..3 {
            stats.lanes[lane].depth = s.lanes[lane].len();
        }
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn priority_order_and_lane_fifo() {
        let q = AdmissionQueue::new(8);
        assert!(q.admit(Priority::Low, 1).admitted);
        assert!(q.admit(Priority::Normal, 2).admitted);
        assert!(q.admit(Priority::High, 3).admitted);
        assert!(q.admit(Priority::Normal, 4).admitted);
        q.close();
        let drained: Vec<(Priority, i32)> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(
            drained,
            vec![
                (Priority::High, 3),
                (Priority::Normal, 2),
                (Priority::Normal, 4),
                (Priority::Low, 1)
            ]
        );
    }

    #[test]
    fn full_queue_sheds_low_incoming() {
        let q = AdmissionQueue::new(2);
        assert!(q.admit(Priority::Normal, 1).admitted);
        assert!(q.admit(Priority::Normal, 2).admitted);
        let out = q.admit(Priority::Low, 3);
        assert!(!out.admitted);
        assert_eq!(out.shed, vec![(Priority::Low, 3)]);
        // Equal priority does not displace either.
        let out = q.admit(Priority::Normal, 4);
        assert!(!out.admitted);
        assert_eq!(out.shed, vec![(Priority::Normal, 4)]);
    }

    #[test]
    fn full_queue_displaces_lower_priority_for_high() {
        let q = AdmissionQueue::new(2);
        assert!(q.admit(Priority::Low, 1).admitted);
        assert!(q.admit(Priority::Normal, 2).admitted);
        let out = q.admit(Priority::High, 3);
        assert!(out.admitted);
        assert_eq!(out.shed, vec![(Priority::Low, 1)]);
        q.close();
        let drained: Vec<i32> = std::iter::from_fn(|| q.pop()).map(|(_, v)| v).collect();
        assert_eq!(drained, vec![3, 2]);
    }

    #[test]
    fn displacement_takes_newest_of_the_lowest_lane() {
        let q = AdmissionQueue::new(3);
        for v in [1, 2, 3] {
            assert!(q.admit(Priority::Low, v).admitted);
        }
        let out = q.admit(Priority::Normal, 4);
        assert!(out.admitted);
        // The most recently queued low entry is shed, preserving FIFO for
        // the survivors.
        assert_eq!(out.shed, vec![(Priority::Low, 3)]);
        q.close();
        let drained: Vec<i32> = std::iter::from_fn(|| q.pop()).map(|(_, v)| v).collect();
        assert_eq!(drained, vec![4, 1, 2]);
    }

    #[test]
    fn stats_count_admissions_sheds_dispatches() {
        let q = AdmissionQueue::new(1);
        assert!(q.admit(Priority::Normal, 1).admitted);
        assert!(!q.admit(Priority::Low, 2).admitted);
        assert!(q.admit(Priority::High, 3).admitted); // displaces 1
        q.close();
        while q.pop().is_some() {}
        let stats = q.stats();
        assert_eq!(stats.admitted, 2);
        assert_eq!(stats.shed, 2);
        assert_eq!(stats.dispatched, 1);
        assert_eq!(stats.peak_depth, 1);
    }

    #[test]
    fn lane_stats_split_the_ledger_per_priority() {
        let q = AdmissionQueue::new(2);
        assert!(q.admit(Priority::Low, 1).admitted);
        assert!(q.admit(Priority::Normal, 2).admitted);
        // Full queue: high displaces the low entry; a second low is
        // rejected outright.
        assert!(q.admit(Priority::High, 3).admitted);
        assert!(!q.admit(Priority::Low, 4).admitted);
        let stats = q.stats();
        assert_eq!(stats.lane(Priority::Low).admitted, 1);
        assert_eq!(stats.lane(Priority::Low).shed, 2, "one displaced + one rejected");
        assert_eq!(stats.lane(Priority::Low).depth, 0);
        assert_eq!(stats.lane(Priority::Normal).admitted, 1);
        assert_eq!(stats.lane(Priority::Normal).shed, 0);
        assert_eq!(stats.lane(Priority::Normal).depth, 1);
        assert_eq!(stats.lane(Priority::High).admitted, 1);
        assert_eq!(stats.lane(Priority::High).peak_depth, 1);
        // The per-lane split sums back to the aggregate counters.
        let sum = |f: fn(&LaneStats) -> u64| stats.lanes.iter().map(f).sum::<u64>();
        assert_eq!(sum(|l| l.admitted), stats.admitted);
        assert_eq!(sum(|l| l.shed), stats.shed);
        assert_eq!(q.pop(), Some((Priority::High, 3)));
        assert_eq!(q.pop(), Some((Priority::Normal, 2)));
        let stats = q.stats();
        assert_eq!(stats.lane(Priority::High).dispatched, 1);
        assert_eq!(stats.lane(Priority::Normal).dispatched, 1);
        assert_eq!(stats.lane(Priority::Low).dispatched, 0);
        assert_eq!(
            stats.lanes.iter().map(|l| l.dispatched).sum::<u64>(),
            stats.dispatched
        );
        // Closed-queue sheds land in the rejected priority's lane too.
        q.close();
        assert!(!q.admit(Priority::Normal, 9).admitted);
        assert_eq!(q.stats().lane(Priority::Normal).shed, 1);
    }

    #[test]
    fn len_and_is_empty_track_queue_state() {
        let q = AdmissionQueue::new(4);
        assert!(q.is_empty());
        assert_eq!(q.len(), 0);
        assert_eq!(q.depth(), 4);
        assert!(q.admit(Priority::Normal, 1).admitted);
        assert!(q.admit(Priority::High, 2).admitted);
        assert!(!q.is_empty());
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop(), Some((Priority::High, 2)));
        assert_eq!(q.len(), 1);
        assert_eq!(q.pop(), Some((Priority::Normal, 1)));
        assert!(q.is_empty());
    }

    #[test]
    fn close_sheds_later_admissions() {
        let q = AdmissionQueue::new(4);
        assert!(q.admit(Priority::Normal, 1).admitted);
        q.close();
        let out = q.admit(Priority::High, 2);
        assert!(!out.admitted);
        assert_eq!(q.pop(), Some((Priority::Normal, 1)));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn pop_blocks_until_admission() {
        use std::sync::Arc;
        let q = Arc::new(AdmissionQueue::new(2));
        let popper = {
            let q = Arc::clone(&q);
            std::thread::spawn(move || q.pop())
        };
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert!(q.admit(Priority::Normal, 7).admitted);
        assert_eq!(popper.join().unwrap(), Some((Priority::Normal, 7)));
    }

    #[test]
    fn priority_parse_display_round_trip() {
        for p in Priority::ALL {
            assert_eq!(Priority::parse(&p.to_string()), Some(p));
        }
        assert_eq!(Priority::parse("urgent"), None);
        assert!(Priority::Low < Priority::Normal);
        assert!(Priority::Normal < Priority::High);
        assert_eq!(Priority::default(), Priority::Normal);
    }

    /// A queued test request: id + the deadline it was submitted with
    /// (deadlines are dispatch-side metadata; the queue must carry them
    /// through untouched).
    type Req = (u64, Option<u64>);

    /// Reference model of the admission contract: three FIFO lanes, a
    /// hard depth bound, displacement from the newest entry of the
    /// lowest non-empty strictly-lower lane.
    struct ModelQueue {
        lanes: [VecDeque<(Priority, Req)>; 3],
        depth: usize,
    }

    impl ModelQueue {
        fn new(depth: usize) -> ModelQueue {
            ModelQueue { lanes: [VecDeque::new(), VecDeque::new(), VecDeque::new()], depth }
        }

        fn len(&self) -> usize {
            self.lanes.iter().map(|l| l.len()).sum()
        }

        /// Mirrors `AdmissionQueue::admit`: returns (admitted, shed).
        fn admit(&mut self, p: Priority, req: Req) -> (bool, Vec<(Priority, Req)>) {
            if self.len() >= self.depth {
                let victim = (0..p as usize)
                    .find_map(|lane| self.lanes[lane].pop_back());
                match victim {
                    Some(v) => {
                        self.lanes[p as usize].push_back((p, req));
                        (true, vec![v])
                    }
                    None => (false, vec![(p, req)]),
                }
            } else {
                self.lanes[p as usize].push_back((p, req));
                (true, vec![])
            }
        }

        /// Mirrors `AdmissionQueue::pop` drain order: highest lane first,
        /// FIFO within a lane.
        fn drain(mut self) -> Vec<(Priority, Req)> {
            let mut out = Vec::new();
            loop {
                let Some(next) = (0..3).rev().find_map(|lane| self.lanes[lane].pop_front())
                else {
                    break;
                };
                out.push(next);
            }
            out
        }
    }

    #[test]
    fn randomized_admissions_match_the_reference_model() {
        // Seeded property test: for random priority/deadline sequences
        // into bounded queues, the AdmissionQueue must (a) never exceed
        // its depth, (b) shed exactly the requests the displacement rule
        // says it sheds — no more, no fewer, the same ids — and
        // (c) drain FIFO-within-priority, highest priority first.
        for seed in 0..12u64 {
            let mut rng = crate::util::Rng::new(0x40D3 + seed);
            let depth = 1 + rng.below(6);
            let q = AdmissionQueue::new(depth);
            let mut model = ModelQueue::new(depth);
            let n_requests = 40 + rng.below(160);
            for id in 0..n_requests as u64 {
                let p = *rng.choice(&Priority::ALL);
                let deadline = rng.chance(0.3).then(|| rng.below(50) as u64);
                let req: Req = (id, deadline);
                let out = q.admit(p, req);
                let (model_admitted, model_shed) = model.admit(p, req);
                assert_eq!(
                    out.admitted, model_admitted,
                    "seed {seed} id {id}: admit decision diverged"
                );
                assert_eq!(
                    out.shed, model_shed,
                    "seed {seed} id {id}: shed set diverged"
                );
                // Invariant: the bound holds after every admission.
                assert!(
                    q.len() <= depth,
                    "seed {seed} id {id}: depth {} exceeded bound {depth}",
                    q.len()
                );
                assert_eq!(q.len(), model.len(), "seed {seed} id {id}");
            }
            // Conservation: admitted == drained + nothing lost.
            let stats = q.stats();
            assert_eq!(
                stats.admitted + stats.shed - model_displaced_count(&stats, &q),
                n_requests as u64,
                "seed {seed}: every request was admitted or shed exactly once"
            );
            q.close();
            let drained: Vec<(Priority, Req)> = std::iter::from_fn(|| q.pop()).collect();
            let expected = model.drain();
            assert_eq!(drained, expected, "seed {seed}: drain order diverged");
            // FIFO within each priority: ids strictly increase lane-wise.
            for p in Priority::ALL {
                let ids: Vec<u64> = drained
                    .iter()
                    .filter(|(dp, _)| *dp == p)
                    .map(|(_, (id, _))| *id)
                    .collect();
                assert!(
                    ids.windows(2).all(|w| w[0] < w[1]),
                    "seed {seed}: {p} lane not FIFO: {ids:?}"
                );
            }
            // Priorities are non-increasing across the drain.
            assert!(
                drained.windows(2).all(|w| w[0].0 >= w[1].0),
                "seed {seed}: drain not priority-ordered"
            );
        }
    }

    /// Every request is counted exactly once across admitted/shed, except
    /// that a displaced request is counted in BOTH (admitted at entry,
    /// shed on displacement). The displaced count is admitted - queued -
    /// dispatched; with nothing dispatched yet, admitted - len.
    fn model_displaced_count(stats: &QueueStats, q: &AdmissionQueue<Req>) -> u64 {
        stats.admitted - q.len() as u64 - stats.dispatched
    }

    #[test]
    fn randomized_displacement_sheds_only_strictly_lower_priorities() {
        // Sharper shedding property: whenever an admission displaces, the
        // victim's priority is strictly below the incoming one, and the
        // incoming request itself is only shed when nothing below it is
        // queued.
        let mut rng = crate::util::Rng::new(0xD15B);
        for _ in 0..4 {
            let depth = 1 + rng.below(4);
            let q: AdmissionQueue<u64> = AdmissionQueue::new(depth);
            for id in 0..120u64 {
                let p = *rng.choice(&Priority::ALL);
                let was_full = q.len() >= depth;
                let out = q.admit(p, id);
                if out.admitted {
                    for (victim_p, _) in &out.shed {
                        assert!(
                            *victim_p < p,
                            "displaced {victim_p} not strictly below incoming {p}"
                        );
                        assert!(was_full, "displacement only happens when full");
                    }
                } else {
                    assert!(was_full, "rejections only happen when full");
                    assert_eq!(out.shed.len(), 1, "a rejection sheds exactly the incoming");
                    assert_eq!(out.shed[0].0, p);
                    assert_eq!(out.shed[0].1, id);
                }
            }
        }
    }
}
