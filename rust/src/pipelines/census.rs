//! Census pipeline (§2.1): predict income from education over census
//! microdata with ridge regression.
//!
//! Stages (Table 1): load data to data frame, drop columns, remove rows,
//! arithmetic ops, type conversion, train/test split → ridge train +
//! inference. Table 2 axes: Modin 6×, sklearnex 59×.
//!
//! Declared as a [`Plan`] whose single item is the pipeline state — the
//! tabular shape: one dataset threaded stage to stage under whichever
//! executor `cfg.exec` selects.
//!
//! Dataset: synthetic IPUMS-like microdata. Income is generated from a
//! planted linear model over education/age/hours plus noise, so the fitted
//! R² is a real quality metric with a known-good value (≈ the planted
//! signal-to-noise).

use super::{CompiledPipeline, Output, PipelineResult, RunConfig, Workload};
use crate::coordinator::plan::{CompiledPlan, Slicing, WorkloadSlice};
use crate::coordinator::telemetry::{BatchLedger, Category};
use crate::coordinator::{Plan, PlanOutput};
use crate::dataframe::{self as df, ColumnBatch, DType, DataFrame, Engine, Expr};
use crate::linalg::Matrix;
use crate::ml::{metrics, Ridge};
use crate::util::Rng;
use std::collections::BTreeMap;
use std::sync::Arc;

/// Generate the synthetic census CSV (the "load" stage parses this text,
/// so CSV parsing cost is measured like the paper's data ingestion).
/// Extra survey columns (IPUMS microdata is wide; these model the many
/// dummy/auxiliary variables the regression consumes).
pub const EXTRA_COLS: usize = 24;

pub fn generate_csv(rows: usize, seed: u64) -> String {
    let mut rng = Rng::new(seed);
    let mut out = String::with_capacity(rows * (48 + EXTRA_COLS * 8));
    out.push_str("year,age,sex,education,hours,serial");
    for k in 0..EXTRA_COLS {
        out.push_str(&format!(",v{k}"));
    }
    out.push_str(",income\n");
    for _ in 0..rows {
        let year = 1970 + 10 * rng.below(5) as i64;
        let age = rng.range_i64(14, 95);
        let sex = rng.below(2) as i64;
        let education = rng.range_i64(0, 18);
        let hours = rng.range_i64(0, 80);
        let serial = rng.next_u32() as i64;
        out.push_str(&format!("{year},{age},{sex},{education},{hours},{serial}"));
        // Auxiliary variables: weak planted coefficients + noise.
        let mut aux_signal = 0.0;
        for k in 0..EXTRA_COLS {
            let v = rng.normal();
            aux_signal += v * (100.0 / (1.0 + k as f64));
            out.push_str(&format!(",{v:.4}"));
        }
        // Planted model + ~3% missing target (empty field).
        if rng.chance(0.03) {
            out.push(',');
            out.push('\n');
        } else {
            let income = 1200.0 * education as f64
                + 120.0 * age as f64
                + 150.0 * hours as f64
                + aux_signal
                + rng.normal_with(10_000.0, 2_000.0);
            out.push_str(&format!(",{income:.2}\n"));
        }
    }
    out
}

struct State {
    csv: String,
    frame: DataFrame,
    train: DataFrame,
    test: DataFrame,
    pred: Vec<f64>,
    truth: Vec<f64>,
    engine: Engine,
    ml: crate::OptLevel,
    seed: u64,
}

/// Synthesize the default census payload for `cfg`.
pub fn payload(cfg: &RunConfig) -> Workload {
    Workload::Table { csv: generate_csv(cfg.scaled(12_000, 200), cfg.seed) }
}

/// Build the census plan over a synthetic payload (one-shot: compiles
/// and binds in one call; serving paths compile once via [`compile`]).
pub fn plan(cfg: &RunConfig) -> anyhow::Result<Plan> {
    plan_with(cfg, Workload::Synthetic)
}

/// Build the census plan over a supplied payload (one-shot shim over
/// [`compile`] + bind).
pub fn plan_with(cfg: &RunConfig, workload: Workload) -> anyhow::Result<Plan> {
    let payload = match workload {
        Workload::Synthetic => payload(cfg),
        w => w,
    };
    compile(cfg)?.bind(payload, cfg.seed)
}

/// Compile the census stage graph once; binds accept a
/// [`Workload::Table`] payload. The single-state tabular shape: the
/// source emits one state item, so sharded binds run the whole pass on
/// the shard owning emission 0. With `cfg.batch_rows > 0` the batched
/// twin graph compiles instead — same stage names, same metrics, but
/// the preprocessing stages move [`ColumnBatch`] views.
pub fn compile(cfg: &RunConfig) -> anyhow::Result<CompiledPipeline> {
    if cfg.batch_rows > 0 {
        return compile_batched(cfg);
    }
    let engine: Engine = cfg.toggles.dataframe.into();
    let ml = cfg.toggles.ml;
    Ok(CompiledPlan::source(
        "census",
        "source",
        Category::Pre,
        Slicing::SingleState,
        move |slice: WorkloadSlice<Workload>| {
            let csv = match slice.payload {
                Workload::Table { csv } => csv,
                other => return Err(super::workload_mismatch("census", "table", &other)),
            };
            let mut initial = Some(State {
                csv,
                frame: DataFrame::new(),
                train: DataFrame::new(),
                test: DataFrame::new(),
                pred: Vec::new(),
                truth: Vec::new(),
                engine,
                ml,
                seed: slice.seed,
            });
            // The source only hands over the pre-generated dataset;
            // parsing cost is measured by the read_csv stage like the
            // paper's load.
            Ok(move |emit: &mut dyn FnMut(State)| {
                if let Some(state) = initial.take() {
                    emit(state);
                }
            })
        },
    )
    .map("read_csv", Category::Pre, |_seed| {
        |mut s: State| {
            s.frame = df::csv::read_str(&s.csv, s.engine)?;
            s.csv.clear();
            Ok(s)
        }
    })
    .map("drop_columns", Category::Pre, |_seed| {
        |mut s: State| {
            // IPUMS ships ids/serials the analysis drops.
            s.frame = s.frame.drop_cols(&["serial", "year"]);
            Ok(s)
        }
    })
    .map("remove_rows", Category::Pre, |_seed| {
        |mut s: State| {
            // Working-age adults with observed income.
            let keep = Expr::col("age")
                .ge(Expr::lit_i64(18))
                .and(Expr::col("income").is_null().not());
            s.frame = df::ops::filter(&s.frame, &keep, s.engine)?;
            Ok(s)
        }
    })
    .map("arithmetic_ops", Category::Pre, |_seed| {
        |mut s: State| {
            // Feature engineering: hours² interaction and age decade.
            let hours_sq = Expr::col("hours").mul(Expr::col("hours"));
            s.frame = df::ops::with_column(&s.frame, "hours_sq", &hours_sq, s.engine)?;
            let decade = Expr::col("age").div(Expr::lit(10.0));
            s.frame = df::ops::with_column(&s.frame, "age_decade", &decade, s.engine)?;
            Ok(s)
        }
    })
    .map("type_conversion", Category::Pre, |_seed| {
        |mut s: State| {
            for c in ["age", "education", "hours", "sex", "hours_sq"] {
                s.frame = df::ops::astype(&s.frame, c, DType::F64, s.engine)?;
            }
            Ok(s)
        }
    })
    .map("train_test_split", Category::Pre, |_seed| {
        |mut s: State| {
            let (train, test) = df::ops::train_test_split(&s.frame, 0.25, s.seed);
            s.train = train;
            s.test = test;
            s.frame = DataFrame::new();
            Ok(s)
        }
    })
    .map("ridge_train_infer", Category::Ai, |_seed| {
        |mut s: State| {
            let (pred, truth) = ridge_scores(&s.train, &s.test, s.ml)?;
            s.pred = pred;
            s.truth = truth;
            Ok(s)
        }
    })
    .sink("finalize", Category::Post, move |payload: &Workload, _seed| {
        // One line per record after the header, so external payloads
        // report the same item count the synthetic generator would.
        let rows = match payload {
            Workload::Table { csv } => csv.lines().count().saturating_sub(1),
            other => return Err(super::workload_mismatch("census", "table", other)),
        };
        Ok((
            None,
            |slot: &mut Option<State>, s: State| {
                *slot = Some(s);
                Ok(())
            },
            move |slot: Option<State>| {
                let state = slot
                    .ok_or_else(|| anyhow::anyhow!("census pipeline produced no result"))?;
                let mut m = BTreeMap::new();
                m.insert("r2".to_string(), metrics::r2_score(&state.truth, &state.pred));
                m.insert("mse".to_string(), metrics::mse(&state.truth, &state.pred));
                Ok(PlanOutput { metrics: m, items: rows })
            },
        ))
    }))
}

/// One zero-copy slice of the parsed census table flowing through the
/// batched graph. `index`/`total` make the downstream gather stage a
/// pure function of the items, so every executor regroups identically.
struct Chunk {
    index: usize,
    total: usize,
    batch: ColumnBatch,
}

/// The gathered train/test frames (post-split, pre-model).
struct SplitFrames {
    train: DataFrame,
    test: DataFrame,
}

/// The model stage's output: predictions plus held-out truth.
struct Scores {
    pred: Vec<f64>,
    truth: Vec<f64>,
}

/// The batched twin of [`compile`]: same stage names and categories,
/// same metrics (pinned by the conformance suite), but the
/// preprocessing stages move [`ColumnBatch`] chunks — Arc-backed views
/// of the one parsed allocation — and run the vectorized
/// `Engine::Optimized` column kernels directly on each view. The
/// attached [`BatchLedger`] counts batches, rows, and clone-avoided
/// bytes; amortization is asserted from those counters, never
/// wall-clock.
fn compile_batched(cfg: &RunConfig) -> anyhow::Result<CompiledPipeline> {
    let engine: Engine = cfg.toggles.dataframe.into();
    let ml = cfg.toggles.ml;
    let batch_rows = cfg.batch_rows;
    let ledger = Arc::new(BatchLedger::default());
    let split_ledger = Arc::clone(&ledger);
    let filter_ledger = Arc::clone(&ledger);
    let arith_ledger = Arc::clone(&ledger);
    let cast_ledger = Arc::clone(&ledger);
    let gather_ledger = Arc::clone(&ledger);
    Ok(CompiledPlan::source(
        "census",
        "source",
        Category::Pre,
        Slicing::SingleState,
        move |slice: WorkloadSlice<Workload>| {
            let csv = match slice.payload {
                Workload::Table { csv } => csv,
                other => return Err(super::workload_mismatch("census", "table", &other)),
            };
            let mut initial = Some(csv);
            Ok(move |emit: &mut dyn FnMut(String)| {
                if let Some(csv) = initial.take() {
                    emit(csv);
                }
            })
        },
    )
    .flat_map("read_csv", Category::Pre, move |_seed| {
        let ledger = Arc::clone(&split_ledger);
        move |csv: String| {
            let whole = ColumnBatch::from_frame(df::csv::read_str(&csv, engine)?);
            let parts = whole.split(batch_rows);
            let shared: usize = parts.iter().map(ColumnBatch::heap_bytes).sum();
            ledger.record_split(parts.len(), whole.nrows(), shared);
            let total = parts.len();
            Ok(parts
                .into_iter()
                .enumerate()
                .map(|(index, batch)| Chunk { index, total, batch })
                .collect())
        }
    })
    .map("drop_columns", Category::Pre, |_seed| {
        |mut c: Chunk| {
            // Metadata-only on a batch: surviving views keep sharing
            // their parents.
            c.batch = c.batch.drop_cols(&["serial", "year"]);
            Ok(c)
        }
    })
    .map("remove_rows", Category::Pre, move |_seed| {
        let ledger = Arc::clone(&filter_ledger);
        let keep = Expr::col("age")
            .ge(Expr::lit_i64(18))
            .and(Expr::col("income").is_null().not());
        move |mut c: Chunk| {
            let before = c.batch.nrows();
            c.batch = c.batch.filter_expr(&keep)?;
            ledger.record_filter(before - c.batch.nrows());
            ledger.record_copy(c.batch.heap_bytes());
            Ok(c)
        }
    })
    .map("arithmetic_ops", Category::Pre, move |_seed| {
        let ledger = Arc::clone(&arith_ledger);
        let hours_sq = Expr::col("hours").mul(Expr::col("hours"));
        let decade = Expr::col("age").div(Expr::lit(10.0));
        move |mut c: Chunk| {
            let sq = c.batch.eval(&hours_sq)?;
            ledger.record_copy(sq.heap_bytes());
            c.batch = c.batch.with_column("hours_sq", sq)?;
            let dec = c.batch.eval(&decade)?;
            ledger.record_copy(dec.heap_bytes());
            c.batch = c.batch.with_column("age_decade", dec)?;
            Ok(c)
        }
    })
    .map("type_conversion", Category::Pre, move |_seed| {
        let ledger = Arc::clone(&cast_ledger);
        move |mut c: Chunk| {
            for name in ["age", "education", "hours", "sex", "hours_sq"] {
                c.batch = c.batch.astype(name, DType::F64)?;
                ledger.record_copy(c.batch.col(name)?.heap_bytes());
            }
            Ok(c)
        }
    })
    .gather("train_test_split", Category::Pre, move |seed| {
        let ledger = Arc::clone(&gather_ledger);
        let mut pending: Vec<Chunk> = Vec::new();
        move |c: Chunk| {
            let total = c.total;
            pending.push(c);
            if pending.len() < total {
                return Ok(None);
            }
            pending.sort_by_key(|c| c.index);
            let parts: Vec<ColumnBatch> = pending.drain(..).map(|c| c.batch).collect();
            let frame = ColumnBatch::concat(&parts)?;
            ledger.record_gather(frame.nrows());
            let (train, test) = df::ops::train_test_split(&frame, 0.25, seed);
            Ok(Some(SplitFrames { train, test }))
        }
    })
    .map("ridge_train_infer", Category::Ai, move |_seed| {
        move |s: SplitFrames| {
            let (pred, truth) = ridge_scores(&s.train, &s.test, ml)?;
            Ok(Scores { pred, truth })
        }
    })
    .sink("finalize", Category::Post, move |payload: &Workload, _seed| {
        let rows = match payload {
            Workload::Table { csv } => csv.lines().count().saturating_sub(1),
            other => return Err(super::workload_mismatch("census", "table", other)),
        };
        Ok((
            None,
            |slot: &mut Option<Scores>, s: Scores| {
                *slot = Some(s);
                Ok(())
            },
            move |slot: Option<Scores>| {
                let s = slot
                    .ok_or_else(|| anyhow::anyhow!("census pipeline produced no result"))?;
                let mut m = BTreeMap::new();
                m.insert("r2".to_string(), metrics::r2_score(&s.truth, &s.pred));
                m.insert("mse".to_string(), metrics::mse(&s.truth, &s.pred));
                Ok(PlanOutput { metrics: m, items: rows })
            },
        ))
    })
    .with_batch_ledger(ledger))
}

/// Run the census pipeline under `cfg.exec`.
pub fn run(cfg: &RunConfig) -> anyhow::Result<PipelineResult> {
    super::run_entry(super::find("census").expect("census is registered"), cfg)
}

/// Typed projection of a census run's metrics.
pub fn output(res: &PipelineResult) -> Output {
    Output::Regression { r2: res.metric_or_nan("r2"), mse: res.metric_or_nan("mse") }
}

/// Shared model-stage body for both data planes: assemble feature
/// matrices, fit ridge, score the held-out split.
fn ridge_scores(
    train: &DataFrame,
    test: &DataFrame,
    ml: crate::OptLevel,
) -> anyhow::Result<(Vec<f64>, Vec<f64>)> {
    let mut features: Vec<String> =
        ["age", "education", "hours", "sex", "hours_sq", "age_decade"]
            .iter()
            .map(|s| s.to_string())
            .collect();
    features.extend((0..EXTRA_COLS).map(|k| format!("v{k}")));
    let features: Vec<&str> = features.iter().map(|s| s.as_str()).collect();
    let (x_train, y_train) = to_matrix(train, &features, "income")?;
    let (x_test, y_test) = to_matrix(test, &features, "income")?;
    let model = Ridge::fit(&x_train, &y_train, 1.0, ml)
        .ok_or_else(|| anyhow::anyhow!("ridge fit failed"))?;
    Ok((model.predict(&x_test), y_test))
}

fn to_matrix(
    frame: &DataFrame,
    features: &[&str],
    target: &str,
) -> anyhow::Result<(Matrix, Vec<f64>)> {
    let mut cols: Vec<&[f64]> = Vec::with_capacity(features.len());
    for f in features {
        cols.push(frame.f64s(f)?);
    }
    let x = Matrix::from_columns(&cols);
    let y = frame.f64s(target)?.to_vec();
    Ok((x, y))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ExecMode;
    use crate::pipelines::Toggles;
    use crate::OptLevel;

    fn small(toggles: Toggles) -> PipelineResult {
        run(&RunConfig { toggles, scale: 0.05, seed: 7, ..Default::default() }).unwrap()
    }

    #[test]
    fn recovers_planted_signal() {
        let res = small(Toggles::optimized());
        assert!(res.metric("r2").unwrap() > 0.9, "{:?}", res.metrics);
    }

    #[test]
    fn baseline_and_optimized_agree_on_quality() {
        let a = small(Toggles::baseline());
        let b = small(Toggles::optimized());
        assert!((a.metric("r2").unwrap() - b.metric("r2").unwrap()).abs() < 0.02);
    }

    #[test]
    fn preprocessing_dominates_breakdown() {
        // Fig 1 shows Census ≈ 90%+ preprocessing.
        let res = small(Toggles::optimized());
        let (pre, ai) = res.report.fig1_split();
        assert!(pre > 50.0, "pre={pre} ai={ai}");
    }

    #[test]
    fn optimized_is_faster_at_scale() {
        let base = run(&RunConfig {
            toggles: Toggles::baseline(),
            scale: 0.2,
            seed: 3,
            ..Default::default()
        })
        .unwrap();
        let opt = run(&RunConfig {
            toggles: Toggles::optimized(),
            scale: 0.2,
            seed: 3,
            ..Default::default()
        })
        .unwrap();
        let speedup = base.report.total().as_secs_f64() / opt.report.total().as_secs_f64();
        assert!(speedup > 1.2, "census E2E speedup {speedup}");
    }

    #[test]
    fn ml_toggle_changes_only_ai_stage() {
        let mut t = Toggles::optimized();
        t.ml = OptLevel::Baseline;
        let res = small(t);
        assert!(res.metric("r2").unwrap() > 0.9);
    }

    #[test]
    fn stage_names_match_table1() {
        let res = small(Toggles::optimized());
        let names: Vec<&str> = res.report.stages.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(
            names,
            vec![
                "source",
                "read_csv",
                "drop_columns",
                "remove_rows",
                "arithmetic_ops",
                "type_conversion",
                "train_test_split",
                "ridge_train_infer",
                "finalize"
            ]
        );
    }

    #[test]
    fn batched_data_plane_matches_per_item_metrics() {
        // batch_rows switches the data plane, never the answer: metrics
        // and items are bit-identical, and the batch counters ride on
        // PipelineResult::batching (ledgers, not wall-clock).
        let cfg = RunConfig { toggles: Toggles::optimized(), scale: 0.05, seed: 7, ..Default::default() };
        let per_item = run(&cfg).unwrap();
        assert!(per_item.batching.is_none(), "per-item runs carry no batch report");
        let batched = run(&RunConfig { batch_rows: 64, ..cfg }).unwrap();
        assert_eq!(per_item.metrics, batched.metrics);
        assert_eq!(per_item.items, batched.items);
        let b = batched.batching.expect("batched run reports batch counters");
        assert!(b.batches > 1, "{b:?}");
        assert!(b.balanced(), "rows in != rows out + filtered: {b:?}");
        assert!(b.clone_avoided_bytes > 0, "{b:?}");
        assert!((b.mean_rows() * b.batches as f64 - b.rows_in as f64).abs() < 1e-9);
    }

    #[test]
    fn batched_graph_keeps_table1_stage_names() {
        let res = run(&RunConfig {
            toggles: Toggles::optimized(),
            scale: 0.05,
            seed: 7,
            batch_rows: 32,
            ..Default::default()
        })
        .unwrap();
        let names: Vec<&str> = res.report.stages.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(
            names,
            vec![
                "source",
                "read_csv",
                "drop_columns",
                "remove_rows",
                "arithmetic_ops",
                "type_conversion",
                "train_test_split",
                "ridge_train_infer",
                "finalize"
            ]
        );
    }

    #[test]
    fn streaming_executor_matches_sequential() {
        let cfg = RunConfig { toggles: Toggles::optimized(), scale: 0.05, seed: 7, ..Default::default() };
        let seq = run(&cfg).unwrap();
        let stream =
            run(&RunConfig { exec: ExecMode::Streaming, ..cfg }).unwrap();
        assert_eq!(seq.metrics, stream.metrics);
        assert_eq!(seq.items, stream.items);
    }

    #[test]
    fn sharded_executor_matches_sequential() {
        // Census emits one state item, so sharding degenerates to shard
        // 0 doing the work — the merge-aware sink must still reproduce
        // the sequential answer exactly, with idle shards contributing
        // nothing.
        let cfg = RunConfig { toggles: Toggles::optimized(), scale: 0.05, seed: 7, ..Default::default() };
        let seq = run(&cfg).unwrap();
        let sharded = run(&RunConfig { exec: ExecMode::Sharded(4), ..cfg }).unwrap();
        assert_eq!(seq.metrics, sharded.metrics);
        assert_eq!(seq.items, sharded.items);
        let sharding = sharded.sharding.unwrap();
        assert_eq!(sharding.total_owned(), 1);
        assert_eq!(sharding.shards[0].owned, 1);
    }
}
