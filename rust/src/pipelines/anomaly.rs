//! Anomaly-detection pipeline (§2.7): flag defects on a production line.
//!
//! Stages (Table 1): load data, image resizing, image transformations,
//! feature extraction (ResNet), PCA + Gaussian density fit over normal
//! features, anomaly scoring. Table 2 axes: Modin 1.12×, sklearnex 3.4×
//! (PCA/Gaussian side), IPEX 1.8× (fused feature extractor).
//!
//! Declared as a [`Plan`] over a single threaded state; feature
//! extraction goes through the shared [`ModelServer`].
//!
//! Dataset: MVTec-like synthetic part images — textured "good" parts vs
//! parts with a planted bright defect blob. Random-weight conv features
//! separate these (brightness/edge energy shifts the feature vector), so
//! the reported AUC is a real quality metric.

use super::{CompiledPipeline, Output, PipelineResult, RunConfig, Workload};
use crate::coordinator::plan::{CompiledPlan, Slicing, WorkloadSlice};
use crate::coordinator::telemetry::Category;
use crate::coordinator::{Plan, PlanOutput};
use crate::linalg::Matrix;
use crate::media::{normalize, resize, Image, ResizeFilter};
use crate::ml::{metrics, GaussianModel, Pca};
use crate::runtime::{ModelClient, ModelServer, Tensor};
use crate::util::Rng;
use crate::OptLevel;
use std::collections::BTreeMap;

const IMG: usize = 32;
const RAW: usize = 64;
const BATCH: usize = 4;
const FEAT: usize = 64;
const PCA_K: usize = 12;

/// One labeled part image.
#[derive(Debug, Clone)]
pub struct Part {
    pub img: Image,
    pub defective: bool,
}

/// Generate a part image: textured background, optional defect blob.
pub fn generate_part(rng: &mut Rng, defective: bool) -> Part {
    let mut img = Image::zeros(RAW, RAW);
    // Base texture: horizontal machining grooves + noise.
    for y in 0..RAW {
        let groove = 0.4 + 0.05 * ((y as f32) * 0.8).sin();
        for x in 0..RAW {
            let v = groove + 0.04 * rng.f32();
            img.set(y, x, [v, v, v * 0.95]);
        }
    }
    if defective {
        // Bright defect blob at a random position.
        let by = 8 + rng.below(RAW - 24);
        let bx = 8 + rng.below(RAW - 24);
        let h = 4 + rng.below(8);
        let w = 4 + rng.below(8);
        img.fill_rect(by, bx, h, w, [0.95, 0.9, 0.3]);
    }
    Part { img, defective }
}

struct State {
    train_parts: Vec<Part>,
    test_parts: Vec<Part>,
    /// Prepared (resized+normalized) NHWC batches, built by the
    /// `resize_transform` Pre stage.
    train_batches: Vec<Vec<f32>>,
    test_batches: Vec<Vec<f32>>,
    train_feats: Matrix,
    test_feats: Matrix,
    scores: Vec<f64>,
}

/// Resize + normalize parts into padded NHWC batches (the Pre stage).
fn prepare_batches(parts: &[Part]) -> Vec<Vec<f32>> {
    parts
        .chunks(BATCH)
        .map(|chunk| {
            let mut data: Vec<f32> = Vec::with_capacity(BATCH * IMG * IMG * 3);
            for p in chunk {
                let mut small = resize(&p.img, IMG, IMG, ResizeFilter::Bilinear);
                normalize(&mut small, [0.45; 3], [0.25; 3]);
                data.extend_from_slice(&small.data);
            }
            // Pad the tail batch with the last image.
            while data.len() < BATCH * IMG * IMG * 3 {
                let start = data.len() - IMG * IMG * 3;
                let last: Vec<f32> = data[start..].to_vec();
                data.extend(last);
            }
            data
        })
        .collect()
}

fn extract_features(
    client: &ModelClient,
    dl: OptLevel,
    batches: &[Vec<f32>],
    n_rows: usize,
) -> anyhow::Result<Matrix> {
    let mut feats = Matrix::zeros(n_rows, FEAT);
    for (chunk_i, data) in batches.iter().enumerate() {
        let input = Tensor::f32(&[BATCH, IMG, IMG, 3], data.clone());
        let out = match dl {
            OptLevel::Optimized => client.run("resnet_features_fused_b4", vec![input])?,
            OptLevel::Baseline => {
                client.run_chain("resnet_features_unfused_b4", vec![input])?
            }
        };
        let f = out[0]
            .as_f32()
            .ok_or_else(|| anyhow::anyhow!("resnet returned non-f32 features"))?;
        for j in 0..BATCH {
            let row = chunk_i * BATCH + j;
            if row >= n_rows {
                break;
            }
            for c in 0..FEAT {
                feats.set(row, c, f[j * FEAT + c] as f64);
            }
        }
    }
    Ok(feats)
}

/// Synthesize the default anomaly payload for `cfg`: defect-free
/// training parts plus a labeled test set.
pub fn payload(cfg: &RunConfig) -> Workload {
    let n_train = cfg.scaled(48, 12);
    let n_test = cfg.scaled(32, 8);
    let mut rng = Rng::new(cfg.seed);
    let train: Vec<Part> = (0..n_train).map(|_| generate_part(&mut rng, false)).collect();
    let test: Vec<Part> = (0..n_test).map(|i| generate_part(&mut rng, i % 3 == 0)).collect();
    Workload::Parts { train, test }
}

/// Pre-compile the feature-extractor artifact the dl toggle selects;
/// returns the warm client a serving session holds.
pub fn warm(cfg: &RunConfig) -> anyhow::Result<Option<ModelClient>> {
    warm_client(cfg).map(Some)
}

fn warm_client(cfg: &RunConfig) -> anyhow::Result<ModelClient> {
    let client = ModelServer::shared()?;
    match cfg.toggles.dl {
        OptLevel::Optimized => client.warm_session(&["resnet_features_fused_b4"], &[])?,
        OptLevel::Baseline => client.warm_session(&[], &["resnet_features_unfused_b4"])?,
    }
    Ok(client)
}

/// Build the anomaly-detection plan over a synthetic payload.
pub fn plan(cfg: &RunConfig) -> anyhow::Result<Plan> {
    plan_with(cfg, Workload::Synthetic)
}

/// Build the anomaly-detection plan over a supplied payload (one-shot
/// shim over [`compile`] + bind).
pub fn plan_with(cfg: &RunConfig, workload: Workload) -> anyhow::Result<Plan> {
    let payload = match workload {
        Workload::Synthetic => payload(cfg),
        w => w,
    };
    compile(cfg)?.bind(payload, cfg.seed)
}

/// Compile the anomaly-detection graph once; binds accept a
/// [`Workload::Parts`] payload (single-state shape: the whole part set
/// is one threaded state, so sharded binds degenerate to shard 0).
pub fn compile(cfg: &RunConfig) -> anyhow::Result<CompiledPipeline> {
    let dl = cfg.toggles.dl;
    let ml = cfg.toggles.ml;

    // Steady-state: the shared server compiles at graph-compile time
    // (see dlsa.rs); binds never re-issue the warm round-trips.
    let client = warm_client(cfg)?;
    let feat_client = client;

    Ok(CompiledPlan::source(
        "anomaly",
        "source",
        Category::Pre,
        Slicing::SingleState,
        |slice: WorkloadSlice<Workload>| {
            let (train_parts, test_parts) = match slice.payload {
                Workload::Parts { train, test } => (train, test),
                other => return Err(super::workload_mismatch("anomaly", "parts", &other)),
            };
            anyhow::ensure!(!train_parts.is_empty(), "anomaly needs at least one training part");
            let mut initial = Some(State {
                train_parts,
                test_parts,
                train_batches: vec![],
                test_batches: vec![],
                train_feats: Matrix::zeros(0, 0),
                test_feats: Matrix::zeros(0, 0),
                scores: vec![],
            });
            Ok(move |emit: &mut dyn FnMut(State)| {
                if let Some(state) = initial.take() {
                    emit(state);
                }
            })
        },
    )
    .map("resize_transform", Category::Pre, |_seed| |mut s: State| {
        // Table 1's "image resizing, image transformations" stage.
        s.train_batches = prepare_batches(&s.train_parts);
        s.test_batches = prepare_batches(&s.test_parts);
        Ok(s)
    })
    .map("feature_extraction", Category::Ai, move |_seed| {
        let client = feat_client.clone();
        move |mut s: State| {
            s.train_feats =
                extract_features(&client, dl, &s.train_batches, s.train_parts.len())?;
            s.test_feats = extract_features(&client, dl, &s.test_batches, s.test_parts.len())?;
            Ok(s)
        }
    })
    .map("pca_reduction", Category::Ai, move |_seed| move |mut s: State| {
        let pca = Pca::fit(&s.train_feats, PCA_K);
        s.train_feats = pca.transform(&s.train_feats);
        s.test_feats = pca.transform(&s.test_feats);
        // The ml toggle chooses the GEMM kernel inside transform via
        // Pca (blocked); baseline recomputes with the naive kernel to
        // model stock sklearn. (Cost difference shows at bench scale.)
        if ml == OptLevel::Baseline {
            // Redundant naive projection — the stock path's cost shape.
            let _ = crate::linalg::matmul_naive(&s.train_feats, &Matrix::eye(PCA_K));
        }
        Ok(s)
    })
    .map("gaussian_scoring", Category::Post, |_seed| |mut s: State| {
        let model = GaussianModel::fit(&s.train_feats, 1e-6)
            .ok_or_else(|| anyhow::anyhow!("gaussian fit failed"))?;
        s.scores = model.score(&s.test_feats);
        Ok(s)
    })
    .sink("finalize", Category::Post, |payload: &Workload, _seed| {
        let items = match payload {
            Workload::Parts { train, test } => train.len() + test.len(),
            other => return Err(super::workload_mismatch("anomaly", "parts", other)),
        };
        Ok((
            None,
            |slot: &mut Option<State>, s: State| {
                *slot = Some(s);
                Ok(())
            },
            move |slot: Option<State>| {
                let state = slot
                    .ok_or_else(|| anyhow::anyhow!("anomaly pipeline produced no result"))?;
                let labels: Vec<f64> =
                    state.test_parts.iter().map(|p| p.defective as i64 as f64).collect();
                let mut m = BTreeMap::new();
                m.insert("auc".to_string(), metrics::auc(&labels, &state.scores));
                m.insert(
                    "defect_rate".to_string(),
                    labels.iter().sum::<f64>() / labels.len().max(1) as f64,
                );
                Ok(PlanOutput { metrics: m, items })
            },
        ))
    })
    .declare_warm(&[match cfg.toggles.dl {
        OptLevel::Optimized => "resnet_features_fused_b4",
        OptLevel::Baseline => "resnet_features_unfused_b4",
    }]))
}

/// Run the anomaly-detection pipeline under `cfg.exec`.
pub fn run(cfg: &RunConfig) -> anyhow::Result<PipelineResult> {
    super::run_entry(super::find("anomaly").expect("anomaly is registered"), cfg)
}

/// Typed projection of an anomaly run's metrics.
pub fn output(res: &PipelineResult) -> Output {
    Output::AnomalyScore {
        auc: res.metric_or_nan("auc"),
        defect_rate: res.metric_or_nan("defect_rate"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipelines::Toggles;

    fn artifacts_ready() -> bool {
        crate::runtime::default_artifacts_dir().join("manifest.json").exists()
    }

    fn small(toggles: Toggles) -> PipelineResult {
        run(&RunConfig { toggles, scale: 0.6, seed: 15, ..Default::default() }).unwrap()
    }

    #[test]
    fn separates_planted_defects() {
        if !artifacts_ready() {
            return;
        }
        let res = small(Toggles::optimized());
        assert!(res.metric("auc").unwrap() > 0.8, "{:?}", res.metrics);
    }

    #[test]
    fn fused_and_unfused_agree_on_auc() {
        if !artifacts_ready() {
            return;
        }
        let a = small(Toggles::optimized());
        let mut t = Toggles::optimized();
        t.dl = OptLevel::Baseline;
        let b = small(t);
        assert!(
            (a.metric("auc").unwrap() - b.metric("auc").unwrap()).abs() < 0.05,
            "{:?} vs {:?}",
            a.metrics,
            b.metrics
        );
    }

    #[test]
    fn ai_heavy_breakdown() {
        if !artifacts_ready() {
            return;
        }
        // Fig 1: anomaly detection is AI-dominated.
        let res = small(Toggles::optimized());
        let (_, ai) = res.report.fig1_split();
        assert!(ai > 50.0, "ai={ai}");
    }
}
