//! Industrial-IoT pipeline (§2.3): predict production-line failures.
//!
//! Stages (Table 1): read measurements CSV, clean to essential features
//! (drop mostly-null columns, fill the rest), train/test split → random
//! forest. Table 2 axes: Modin 4.8×, sklearnex 113×.
//!
//! Declared as a [`Plan`] over a single threaded state (tabular shape).
//!
//! Dataset: a wide, sparse sensor table (Bosch-like): many columns, high
//! null fraction, a planted failure rule over a few "essential" sensors.

use super::{CompiledPipeline, Output, PipelineResult, RunConfig, Workload};
use crate::coordinator::plan::{CompiledPlan, Slicing, WorkloadSlice};
use crate::coordinator::telemetry::{BatchLedger, Category};
use crate::coordinator::{Plan, PlanOutput};
use crate::dataframe::{self as df, ColumnBatch, DataFrame, Engine};
use crate::linalg::Matrix;
use crate::ml::{metrics, RandomForest, RandomForestParams};
use crate::util::Rng;
use crate::OptLevel;
use std::collections::BTreeMap;
use std::sync::Arc;

const SENSORS: usize = 48;
/// Sensors that actually carry the failure signal.
const ESSENTIAL: usize = 6;

/// Generate the wide sparse sensor CSV.
pub fn generate_csv(rows: usize, seed: u64) -> String {
    let mut rng = Rng::new(seed);
    let mut out = String::with_capacity(rows * SENSORS * 8);
    out.push_str("line_id");
    for sid in 0..SENSORS {
        out.push_str(&format!(",s{sid}"));
    }
    out.push_str(",failure\n");
    for row in 0..rows {
        out.push_str(&row.to_string());
        // Essential sensors: dense, signal-bearing. Others: very sparse.
        let mut signal = 0.0;
        for sid in 0..SENSORS {
            let essential = sid < ESSENTIAL;
            let null_p = if essential { 0.05 } else { 0.85 };
            if rng.chance(null_p) {
                out.push(',');
            } else {
                let v = rng.normal();
                if essential {
                    signal += v * [1.5, -1.2, 0.9, 0.7, -0.5, 0.4][sid];
                }
                out.push_str(&format!(",{v:.4}"));
            }
        }
        let failure = (signal + rng.normal_with(0.0, 0.4) > 0.8) as i64;
        out.push_str(&format!(",{failure}\n"));
    }
    out
}

struct State {
    csv: String,
    frame: DataFrame,
    engine: Engine,
    ml: OptLevel,
    seed: u64,
    pred: Vec<f64>,
    proba: Vec<f64>,
    truth: Vec<f64>,
    kept_cols: usize,
}

/// Synthesize the default IIoT payload for `cfg`.
pub fn payload(cfg: &RunConfig) -> Workload {
    Workload::Table { csv: generate_csv(cfg.scaled(3_000, 150), cfg.seed) }
}

/// Build the IIoT plan over a synthetic payload.
pub fn plan(cfg: &RunConfig) -> anyhow::Result<Plan> {
    plan_with(cfg, Workload::Synthetic)
}

/// Build the IIoT plan over a supplied payload (one-shot shim over
/// [`compile`] + bind).
pub fn plan_with(cfg: &RunConfig, workload: Workload) -> anyhow::Result<Plan> {
    let payload = match workload {
        Workload::Synthetic => payload(cfg),
        w => w,
    };
    compile(cfg)?.bind(payload, cfg.seed)
}

/// Compile the IIoT stage graph once; binds accept a
/// [`Workload::Table`] payload (single-state tabular shape). With
/// `cfg.batch_rows > 0` the batched twin graph compiles instead.
pub fn compile(cfg: &RunConfig) -> anyhow::Result<CompiledPipeline> {
    if cfg.batch_rows > 0 {
        return compile_batched(cfg);
    }
    let engine: Engine = cfg.toggles.dataframe.into();
    let ml = cfg.toggles.ml;
    Ok(CompiledPlan::source(
        "iiot",
        "source",
        Category::Pre,
        Slicing::SingleState,
        move |slice: WorkloadSlice<Workload>| {
            let csv = match slice.payload {
                Workload::Table { csv } => csv,
                other => return Err(super::workload_mismatch("iiot", "table", &other)),
            };
            let mut initial = Some(State {
                csv,
                frame: DataFrame::new(),
                engine,
                ml,
                seed: slice.seed,
                pred: vec![],
                proba: vec![],
                truth: vec![],
                kept_cols: 0,
            });
            Ok(move |emit: &mut dyn FnMut(State)| {
                if let Some(state) = initial.take() {
                    emit(state);
                }
            })
        },
    )
    .map("read_measurements", Category::Pre, |_seed| |mut s: State| {
        s.frame = df::csv::read_str(&s.csv, s.engine)?;
        s.csv.clear();
        Ok(s)
    })
    .map("drop_inessential_columns", Category::Pre, |_seed| |mut s: State| {
        // Keep columns with < 50% nulls (the "only necessary features"
        // cleaning step of the paper).
        let n = s.frame.nrows().max(1);
        let mut drop: Vec<String> = Vec::new();
        for (name, _) in s.frame.schema() {
            if name == "failure" || name == "line_id" {
                continue;
            }
            let nulls = s.frame.col(&name)?.null_count();
            if nulls * 2 > n {
                drop.push(name);
            }
        }
        let drop_refs: Vec<&str> = drop.iter().map(|s| s.as_str()).collect();
        s.frame = s.frame.drop_cols(&drop_refs);
        s.frame = s.frame.drop_cols(&["line_id"]);
        s.kept_cols = s.frame.ncols() - 1;
        Ok(s)
    })
    .map("fill_missing", Category::Pre, |_seed| |mut s: State| {
        let names: Vec<String> = s.frame.schema().into_iter().map(|(n, _)| n).collect();
        for name in names {
            if name != "failure" {
                s.frame = df::ops::fillna_f64(&s.frame, &name, 0.0, s.engine)?;
            }
        }
        Ok(s)
    })
    .map("train_test_split", Category::Pre, |_seed| |s: State| Ok(s))
    .map("random_forest", Category::Ai, |_seed| |mut s: State| {
        let (pred, proba, truth) = rf_scores(&s.frame, s.ml, s.seed)?;
        s.pred = pred;
        s.proba = proba;
        s.truth = truth;
        Ok(s)
    })
    .sink("finalize", Category::Post, move |payload: &Workload, _seed| {
        // One measurement row per line after the header.
        let rows = match payload {
            Workload::Table { csv } => csv.lines().count().saturating_sub(1),
            other => return Err(super::workload_mismatch("iiot", "table", other)),
        };
        Ok((
            None,
            |slot: &mut Option<State>, s: State| {
                *slot = Some(s);
                Ok(())
            },
            move |slot: Option<State>| {
                let state = slot
                    .ok_or_else(|| anyhow::anyhow!("iiot pipeline produced no result"))?;
                let mut m = BTreeMap::new();
                m.insert("f1".to_string(), metrics::f1(&state.truth, &state.pred));
                m.insert("accuracy".to_string(), metrics::accuracy(&state.truth, &state.pred));
                m.insert("auc".to_string(), metrics::auc(&state.truth, &state.proba));
                m.insert("kept_columns".to_string(), state.kept_cols as f64);
                Ok(PlanOutput { metrics: m, items: rows })
            },
        ))
    }))
}

/// Shared model-stage body for both data planes: split 70/30, assemble
/// X/y in one contiguous row-major pass ([`Matrix::from_columns`]), fit
/// the forest, score the held-out split.
fn rf_scores(
    frame: &DataFrame,
    ml: OptLevel,
    seed: u64,
) -> anyhow::Result<(Vec<f64>, Vec<f64>, Vec<f64>)> {
    let (train, test) = df::ops::train_test_split(frame, 0.3, seed);
    let to_xy = |frame: &DataFrame| -> anyhow::Result<(Matrix, Vec<usize>)> {
        let feats: Vec<String> = frame
            .schema()
            .into_iter()
            .map(|(n, _)| n)
            .filter(|n| n != "failure")
            .collect();
        let mut cols: Vec<&[f64]> = Vec::with_capacity(feats.len());
        for f in &feats {
            cols.push(frame.f64s(f)?);
        }
        let x = Matrix::from_columns(&cols);
        let y: Vec<usize> = frame.i64s("failure")?.iter().map(|&v| v as usize).collect();
        Ok((x, y))
    };
    let (xt, yt) = to_xy(&train)?;
    let (xs, ys) = to_xy(&test)?;
    let rf = RandomForest::fit(
        &xt,
        &yt,
        &RandomForestParams { n_trees: 20, max_depth: 8, ..Default::default() },
        ml,
    );
    let pred: Vec<f64> = rf.predict(&xs).iter().map(|&c| c as f64).collect();
    let proba: Vec<f64> = rf
        .predict_proba(&xs)
        .iter()
        .map(|p| p.get(1).copied().unwrap_or(0.0))
        .collect();
    let truth: Vec<f64> = ys.iter().map(|&c| c as f64).collect();
    Ok((pred, proba, truth))
}

/// One zero-copy slice of the parsed sensor table in the batched graph.
struct Chunk {
    index: usize,
    total: usize,
    batch: ColumnBatch,
}

/// The gathered, cleaned table (post-concat, pre-model).
struct Gathered {
    frame: DataFrame,
    kept_cols: usize,
}

/// The model stage's output.
struct Scores {
    pred: Vec<f64>,
    proba: Vec<f64>,
    truth: Vec<f64>,
    kept_cols: usize,
}

/// The batched twin of [`compile`]. The drop decision is global (a
/// column is dropped when over half of ALL its rows are null), but
/// every chunk's views share the same parent allocations — so the
/// first chunk computes the drop list from the parents' whole-column
/// null counts, the closure caches it, and every chunk applies the
/// identical list regardless of arrival order.
fn compile_batched(cfg: &RunConfig) -> anyhow::Result<CompiledPipeline> {
    let engine: Engine = cfg.toggles.dataframe.into();
    let ml = cfg.toggles.ml;
    let batch_rows = cfg.batch_rows;
    let ledger = Arc::new(BatchLedger::default());
    let split_ledger = Arc::clone(&ledger);
    let drop_ledger = Arc::clone(&ledger);
    let fill_ledger = Arc::clone(&ledger);
    let gather_ledger = Arc::clone(&ledger);
    Ok(CompiledPlan::source(
        "iiot",
        "source",
        Category::Pre,
        Slicing::SingleState,
        move |slice: WorkloadSlice<Workload>| {
            let csv = match slice.payload {
                Workload::Table { csv } => csv,
                other => return Err(super::workload_mismatch("iiot", "table", &other)),
            };
            let mut initial = Some(csv);
            Ok(move |emit: &mut dyn FnMut(String)| {
                if let Some(csv) = initial.take() {
                    emit(csv);
                }
            })
        },
    )
    .flat_map("read_measurements", Category::Pre, move |_seed| {
        let ledger = Arc::clone(&split_ledger);
        move |csv: String| {
            let whole = ColumnBatch::from_frame(df::csv::read_str(&csv, engine)?);
            let parts = whole.split(batch_rows);
            let shared: usize = parts.iter().map(ColumnBatch::heap_bytes).sum();
            ledger.record_split(parts.len(), whole.nrows(), shared);
            let total = parts.len();
            Ok(parts
                .into_iter()
                .enumerate()
                .map(|(index, batch)| Chunk { index, total, batch })
                .collect())
        }
    })
    .map("drop_inessential_columns", Category::Pre, move |_seed| {
        let ledger = Arc::clone(&drop_ledger);
        let mut cached_drop: Option<Vec<String>> = None;
        move |mut c: Chunk| {
            if cached_drop.is_none() {
                // Whole-column null counts from the shared parents:
                // identical from any chunk, computed once per bind.
                let mut drop: Vec<String> = Vec::new();
                for name in c.batch.names().to_vec() {
                    if name == "failure" || name == "line_id" {
                        continue;
                    }
                    let v = c.batch.col(&name)?;
                    let n = v.parent().len().max(1);
                    if v.parent().null_count() * 2 > n {
                        drop.push(name);
                    }
                }
                cached_drop = Some(drop);
            }
            let drop = cached_drop.as_ref().expect("drop list cached above");
            let mut drop_refs: Vec<&str> = drop.iter().map(|s| s.as_str()).collect();
            drop_refs.push("line_id");
            c.batch = c.batch.drop_cols(&drop_refs);
            ledger.record_view(c.batch.heap_bytes());
            Ok(c)
        }
    })
    .map("fill_missing", Category::Pre, move |_seed| {
        let ledger = Arc::clone(&fill_ledger);
        move |mut c: Chunk| {
            for name in c.batch.names().to_vec() {
                if name != "failure" {
                    let had_mask = c.batch.col(&name)?.parent().mask().is_some();
                    c.batch = c.batch.fillna_f64(&name, 0.0)?;
                    if had_mask {
                        ledger.record_copy(c.batch.col(&name)?.heap_bytes());
                    }
                }
            }
            Ok(c)
        }
    })
    .gather("train_test_split", Category::Pre, move |_seed| {
        let ledger = Arc::clone(&gather_ledger);
        let mut pending: Vec<Chunk> = Vec::new();
        move |c: Chunk| {
            let total = c.total;
            pending.push(c);
            if pending.len() < total {
                return Ok(None);
            }
            pending.sort_by_key(|c| c.index);
            let parts: Vec<ColumnBatch> = pending.drain(..).map(|c| c.batch).collect();
            let frame = ColumnBatch::concat(&parts)?;
            ledger.record_gather(frame.nrows());
            let kept_cols = frame.ncols() - 1;
            Ok(Some(Gathered { frame, kept_cols }))
        }
    })
    .map("random_forest", Category::Ai, move |seed| {
        move |g: Gathered| {
            let (pred, proba, truth) = rf_scores(&g.frame, ml, seed)?;
            Ok(Scores { pred, proba, truth, kept_cols: g.kept_cols })
        }
    })
    .sink("finalize", Category::Post, move |payload: &Workload, _seed| {
        let rows = match payload {
            Workload::Table { csv } => csv.lines().count().saturating_sub(1),
            other => return Err(super::workload_mismatch("iiot", "table", other)),
        };
        Ok((
            None,
            |slot: &mut Option<Scores>, s: Scores| {
                *slot = Some(s);
                Ok(())
            },
            move |slot: Option<Scores>| {
                let s = slot
                    .ok_or_else(|| anyhow::anyhow!("iiot pipeline produced no result"))?;
                let mut m = BTreeMap::new();
                m.insert("f1".to_string(), metrics::f1(&s.truth, &s.pred));
                m.insert("accuracy".to_string(), metrics::accuracy(&s.truth, &s.pred));
                m.insert("auc".to_string(), metrics::auc(&s.truth, &s.proba));
                m.insert("kept_columns".to_string(), s.kept_cols as f64);
                Ok(PlanOutput { metrics: m, items: rows })
            },
        ))
    })
    .with_batch_ledger(ledger))
}

/// Run the IIoT pipeline under `cfg.exec`.
pub fn run(cfg: &RunConfig) -> anyhow::Result<PipelineResult> {
    super::run_entry(super::find("iiot").expect("iiot is registered"), cfg)
}

/// Typed projection of an IIoT run's metrics.
pub fn output(res: &PipelineResult) -> Output {
    Output::Classification {
        accuracy: res.metric_or_nan("accuracy"),
        auc: res.metric_or_nan("auc"),
        f1: res.metric_or_nan("f1"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipelines::Toggles;

    fn small(toggles: Toggles) -> PipelineResult {
        run(&RunConfig { toggles, scale: 0.15, seed: 4, ..Default::default() }).unwrap()
    }

    #[test]
    fn detects_planted_failures() {
        let res = small(Toggles::optimized());
        assert!(res.metric("auc").unwrap() > 0.8, "{:?}", res.metrics);
    }

    #[test]
    fn sparse_columns_dropped() {
        let res = small(Toggles::optimized());
        let kept = res.metric("kept_columns").unwrap() as usize;
        // Essential sensors (6) survive; most sparse ones are dropped.
        assert!((ESSENTIAL..SENSORS / 2).contains(&kept), "kept={kept}");
    }

    #[test]
    fn batched_data_plane_matches_per_item_metrics() {
        // The drop decision is global; the batched graph must reproduce
        // it (and every downstream metric, kept_columns included) from
        // chunk-shared parent allocations.
        let cfg = RunConfig { toggles: Toggles::optimized(), scale: 0.15, seed: 4, ..Default::default() };
        let per_item = run(&cfg).unwrap();
        let batched = run(&RunConfig { batch_rows: 128, ..cfg }).unwrap();
        assert_eq!(per_item.metrics, batched.metrics);
        assert_eq!(per_item.items, batched.items);
        let b = batched.batching.expect("batched run reports batch counters");
        assert!(b.batches > 1, "{b:?}");
        assert!(b.balanced(), "rows in != rows out + filtered: {b:?}");
        assert!(b.clone_avoided_bytes > 0, "{b:?}");
    }

    #[test]
    fn engines_agree_on_quality() {
        let a = small(Toggles::baseline());
        let b = small(Toggles::optimized());
        assert!(
            (a.metric("auc").unwrap() - b.metric("auc").unwrap()).abs() < 0.08,
            "{:?} vs {:?}",
            a.metrics,
            b.metrics
        );
    }

    #[test]
    fn optimized_faster_e2e() {
        let base = run(&RunConfig {
            toggles: Toggles::baseline(),
            scale: 0.4,
            seed: 5,
            ..Default::default()
        })
        .unwrap();
        let opt = run(&RunConfig {
            toggles: Toggles::optimized(),
            scale: 0.4,
            seed: 5,
            ..Default::default()
        })
        .unwrap();
        let speedup = base.report.total().as_secs_f64() / opt.report.total().as_secs_f64();
        assert!(speedup > 1.2, "iiot speedup {speedup}");
    }
}
