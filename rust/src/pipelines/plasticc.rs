//! PLAsTiCC pipeline (§2.2): classify astronomical light curves.
//!
//! Stages (Table 1): load data, drop columns, **groupby aggregation**,
//! arithmetic ops, type conversion, train/test split → XGBoost-style GBT.
//! Table 2 axes: Modin 30×, sklearnex 8×, XGBoost 1× (hist is already the
//! shipped default — our bench shows hist vs exact explicitly instead).
//!
//! Declared as a [`Plan`] over a single threaded state (tabular shape).
//!
//! Dataset: synthetic light curves. Two object classes differ in flux
//! variability (transients vs periodic), so per-object flux statistics
//! are genuinely discriminative and the GBT accuracy is a real metric.

use super::{CompiledPipeline, Output, PipelineResult, RunConfig, Workload};
use crate::coordinator::plan::{CompiledPlan, Slicing, WorkloadSlice};
use crate::coordinator::telemetry::{BatchLedger, Category};
use crate::coordinator::{Plan, PlanOutput};
use crate::dataframe::{self as df, groupby::Agg, ColumnBatch, DType, DataFrame, Engine, Expr};
use crate::linalg::Matrix;
use crate::ml::{metrics, Gbt, GbtParams, TreeMethod};
use crate::util::Rng;
use crate::OptLevel;
use std::collections::BTreeMap;
use std::sync::Arc;

/// Generate the light-curve observations CSV: one row per (object, epoch,
/// passband) with flux/flux_err, plus a per-object hidden class.
pub fn generate_csv(objects: usize, epochs: usize, seed: u64) -> (String, Vec<f64>) {
    let mut rng = Rng::new(seed);
    let mut out = String::with_capacity(objects * epochs * 40);
    out.push_str("object_id,mjd,passband,flux,flux_err,detected\n");
    let mut labels = Vec::with_capacity(objects);
    for obj in 0..objects {
        let class = rng.chance(0.5); // true = transient
        labels.push(class as i64 as f64);
        let base = rng.normal_with(100.0, 20.0);
        for e in 0..epochs {
            let mjd = 59000.0 + e as f64;
            let passband = rng.below(6) as i64;
            // Transients: burst profile (high variance); periodic: sine.
            let flux = if class {
                base + 80.0 * (-((e as f64 - epochs as f64 / 2.0).powi(2)) / 20.0).exp()
                    + rng.normal_with(0.0, 12.0)
            } else {
                base + 10.0 * (e as f64 * 0.7).sin() + rng.normal_with(0.0, 3.0)
            };
            let err = rng.range_f64(0.5, 4.0);
            let detected = (flux > base) as i64;
            out.push_str(&format!(
                "{obj},{mjd:.1},{passband},{flux:.3},{err:.3},{detected}\n"
            ));
        }
    }
    (out, labels)
}

struct State {
    csv: String,
    labels: Vec<f64>,
    frame: DataFrame,
    features: DataFrame,
    engine: Engine,
    ml: OptLevel,
    seed: u64,
    x_train: Matrix,
    y_train: Vec<f64>,
    x_test: Matrix,
    y_test: Vec<f64>,
    pred: Vec<f64>,
    proba: Vec<f64>,
}

/// Epochs per object in the synthetic light curves.
const EPOCHS: usize = 40;

/// Synthesize the default PLAsTiCC payload for `cfg`.
pub fn payload(cfg: &RunConfig) -> Workload {
    let (csv, targets) = generate_csv(cfg.scaled(300, 24), EPOCHS, cfg.seed);
    Workload::LightCurves { csv, targets }
}

/// Build the PLAsTiCC plan over a synthetic payload.
pub fn plan(cfg: &RunConfig) -> anyhow::Result<Plan> {
    plan_with(cfg, Workload::Synthetic)
}

/// Build the PLAsTiCC plan over a supplied payload (one-shot shim over
/// [`compile`] + bind).
pub fn plan_with(cfg: &RunConfig, workload: Workload) -> anyhow::Result<Plan> {
    let payload = match workload {
        Workload::Synthetic => payload(cfg),
        w => w,
    };
    compile(cfg)?.bind(payload, cfg.seed)
}

/// Compile the PLAsTiCC stage graph once; binds accept a
/// [`Workload::LightCurves`] payload (single-state tabular shape).
/// With `cfg.batch_rows > 0` the batched twin graph compiles instead.
pub fn compile(cfg: &RunConfig) -> anyhow::Result<CompiledPipeline> {
    if cfg.batch_rows > 0 {
        return compile_batched(cfg);
    }
    let engine: Engine = cfg.toggles.dataframe.into();
    let ml = cfg.toggles.ml;
    Ok(CompiledPlan::source(
        "plasticc",
        "source",
        Category::Pre,
        Slicing::SingleState,
        move |slice: WorkloadSlice<Workload>| {
            let (csv, labels) = match slice.payload {
                Workload::LightCurves { csv, targets } => (csv, targets),
                other => {
                    return Err(super::workload_mismatch("plasticc", "light_curves", &other))
                }
            };
            let mut initial = Some(State {
                csv,
                labels,
                frame: DataFrame::new(),
                features: DataFrame::new(),
                engine,
                ml,
                seed: slice.seed,
                x_train: Matrix::zeros(0, 0),
                y_train: vec![],
                x_test: Matrix::zeros(0, 0),
                y_test: vec![],
                pred: vec![],
                proba: vec![],
            });
            Ok(move |emit: &mut dyn FnMut(State)| {
                if let Some(state) = initial.take() {
                    emit(state);
                }
            })
        },
    )
    .map("load_data", Category::Pre, |_seed| |mut s: State| {
        s.frame = df::csv::read_str(&s.csv, s.engine)?;
        s.csv.clear();
        Ok(s)
    })
    .map("drop_columns", Category::Pre, |_seed| |mut s: State| {
        s.frame = s.frame.drop_cols(&["mjd", "detected"]);
        Ok(s)
    })
    .map("arithmetic_ops", Category::Pre, |_seed| |mut s: State| {
        // SNR column feeds the aggregations.
        let snr = Expr::col("flux").div(Expr::col("flux_err"));
        s.frame = df::ops::with_column(&s.frame, "snr", &snr, s.engine)?;
        Ok(s)
    })
    .map("groupby_aggregation", Category::Pre, |_seed| |mut s: State| {
        s.features = df::groupby::groupby_agg(
            &s.frame,
            &["object_id"],
            &[
                ("flux", Agg::Mean),
                ("flux", Agg::Std),
                ("flux", Agg::Min),
                ("flux", Agg::Max),
                ("snr", Agg::Mean),
                ("snr", Agg::Std),
                ("flux_err", Agg::Mean),
            ],
            s.engine,
        )?;
        s.frame = DataFrame::new();
        Ok(s)
    })
    .map("type_conversion", Category::Pre, |_seed| |mut s: State| {
        s.features = df::ops::astype(&s.features, "object_id", DType::I64, s.engine)?;
        Ok(s)
    })
    .map("train_test_split", Category::Pre, |_seed| |mut s: State| {
        let (xt, yt, xs, ys) = split_features(&s.features, &s.labels, s.seed)?;
        s.x_train = xt;
        s.y_train = yt;
        s.x_test = xs;
        s.y_test = ys;
        Ok(s)
    })
    .map("gbt_train_infer", Category::Ai, |_seed| |mut s: State| {
        let (pred, proba) = gbt_scores(&s.x_train, &s.y_train, &s.x_test, s.ml);
        s.pred = pred;
        s.proba = proba;
        Ok(s)
    })
    .sink("finalize", Category::Post, move |payload: &Workload, _seed| {
        // One observation row per line after the header.
        let observations = match payload {
            Workload::LightCurves { csv, .. } => csv.lines().count().saturating_sub(1),
            other => return Err(super::workload_mismatch("plasticc", "light_curves", other)),
        };
        Ok((
            None,
            |slot: &mut Option<State>, s: State| {
                *slot = Some(s);
                Ok(())
            },
            move |slot: Option<State>| {
                let state = slot
                    .ok_or_else(|| anyhow::anyhow!("plasticc pipeline produced no result"))?;
                let mut m = BTreeMap::new();
                m.insert("accuracy".to_string(), metrics::accuracy(&state.y_test, &state.pred));
                m.insert("auc".to_string(), metrics::auc(&state.y_test, &state.proba));
                Ok(PlanOutput { metrics: m, items: observations })
            },
        ))
    }))
}

/// Shared split-stage body: attach labels by object id, assemble the
/// feature matrix in one contiguous row-major pass
/// ([`Matrix::from_columns`]), deterministic 75/25 shuffled split.
fn split_features(
    features: &DataFrame,
    all_labels: &[f64],
    seed: u64,
) -> anyhow::Result<(Matrix, Vec<f64>, Matrix, Vec<f64>)> {
    // Features come out grouped by object id (0..objects); attach
    // labels then split.
    let n = features.nrows();
    let ids = features.i64s("object_id")?;
    let labels: Vec<f64> = ids
        .iter()
        .map(|&i| {
            all_labels.get(i as usize).copied().ok_or_else(|| {
                anyhow::anyhow!(
                    "plasticc: no target for object_id {i} (payload has {})",
                    all_labels.len()
                )
            })
        })
        .collect::<anyhow::Result<_>>()?;
    let cols = [
        "flux_mean", "flux_std", "flux_min", "flux_max", "snr_mean", "snr_std",
        "flux_err_mean",
    ];
    let mut feature_cols: Vec<&[f64]> = Vec::with_capacity(cols.len());
    for c in cols {
        feature_cols.push(features.f64s(c)?);
    }
    let x = Matrix::from_columns(&feature_cols);
    // Deterministic shuffled split 75/25.
    let mut idx: Vec<usize> = (0..n).collect();
    let mut rng = Rng::new(seed ^ 0x51);
    rng.shuffle(&mut idx);
    let n_test = n / 4;
    let (test_idx, train_idx) = idx.split_at(n_test);
    let take = |rows: &[usize]| {
        let mut xm = Matrix::zeros(rows.len(), cols.len());
        let mut ym = Vec::with_capacity(rows.len());
        for (r, &i) in rows.iter().enumerate() {
            for j in 0..cols.len() {
                xm.set(r, j, x.get(i, j));
            }
            ym.push(labels[i]);
        }
        (xm, ym)
    };
    let (x_train, y_train) = take(train_idx);
    let (x_test, y_test) = take(test_idx);
    Ok((x_train, y_train, x_test, y_test))
}

/// Shared model-stage body for both data planes.
fn gbt_scores(
    x_train: &Matrix,
    y_train: &[f64],
    x_test: &Matrix,
    ml: OptLevel,
) -> (Vec<f64>, Vec<f64>) {
    let method = match ml {
        OptLevel::Baseline => TreeMethod::Exact,
        OptLevel::Optimized => TreeMethod::Hist,
    };
    let gbt = Gbt::fit(
        x_train,
        y_train,
        GbtParams { method, n_trees: 25, max_depth: 4, ..Default::default() },
    );
    (gbt.predict(x_test), gbt.predict_proba(x_test))
}

/// Raw payload handoff in the batched graph: the observation CSV plus
/// the per-object labels the post-gather stages need.
struct Raw {
    csv: String,
    labels: Arc<Vec<f64>>,
}

/// One zero-copy slice of the parsed observation table. The labels ride
/// along as a shared `Arc` so the gather stage can hand them to the
/// split without a side channel.
struct Chunk {
    index: usize,
    total: usize,
    batch: ColumnBatch,
    labels: Arc<Vec<f64>>,
}

/// Gathered per-object features (post-groupby) plus labels.
struct Features {
    frame: DataFrame,
    labels: Arc<Vec<f64>>,
}

/// The four split matrices (post-split, pre-model).
struct SplitMats {
    x_train: Matrix,
    y_train: Vec<f64>,
    x_test: Matrix,
    y_test: Vec<f64>,
}

/// The model stage's output.
struct Scores {
    pred: Vec<f64>,
    proba: Vec<f64>,
    y_test: Vec<f64>,
}

/// The batched twin of [`compile`]: chunked observation rows flow as
/// [`ColumnBatch`] views through the row-local stages (drop, SNR);
/// the gather at `groupby_aggregation` reassembles the full table —
/// groupby needs every observation of an object — and everything
/// downstream matches the per-item stages exactly.
fn compile_batched(cfg: &RunConfig) -> anyhow::Result<CompiledPipeline> {
    let engine: Engine = cfg.toggles.dataframe.into();
    let ml = cfg.toggles.ml;
    let batch_rows = cfg.batch_rows;
    let ledger = Arc::new(BatchLedger::default());
    let split_ledger = Arc::clone(&ledger);
    let drop_ledger = Arc::clone(&ledger);
    let arith_ledger = Arc::clone(&ledger);
    let gather_ledger = Arc::clone(&ledger);
    Ok(CompiledPlan::source(
        "plasticc",
        "source",
        Category::Pre,
        Slicing::SingleState,
        move |slice: WorkloadSlice<Workload>| {
            let (csv, labels) = match slice.payload {
                Workload::LightCurves { csv, targets } => (csv, targets),
                other => {
                    return Err(super::workload_mismatch("plasticc", "light_curves", &other))
                }
            };
            let mut initial = Some(Raw { csv, labels: Arc::new(labels) });
            Ok(move |emit: &mut dyn FnMut(Raw)| {
                if let Some(raw) = initial.take() {
                    emit(raw);
                }
            })
        },
    )
    .flat_map("load_data", Category::Pre, move |_seed| {
        let ledger = Arc::clone(&split_ledger);
        move |raw: Raw| {
            let whole = ColumnBatch::from_frame(df::csv::read_str(&raw.csv, engine)?);
            let parts = whole.split(batch_rows);
            let shared: usize = parts.iter().map(ColumnBatch::heap_bytes).sum();
            ledger.record_split(parts.len(), whole.nrows(), shared);
            let total = parts.len();
            let labels = raw.labels;
            Ok(parts
                .into_iter()
                .enumerate()
                .map(|(index, batch)| Chunk {
                    index,
                    total,
                    batch,
                    labels: Arc::clone(&labels),
                })
                .collect())
        }
    })
    .map("drop_columns", Category::Pre, move |_seed| {
        let ledger = Arc::clone(&drop_ledger);
        move |mut c: Chunk| {
            c.batch = c.batch.drop_cols(&["mjd", "detected"]);
            // The kept views still share the parse allocation — bytes a
            // per-item drop would have cloned.
            ledger.record_view(c.batch.heap_bytes());
            Ok(c)
        }
    })
    .map("arithmetic_ops", Category::Pre, move |_seed| {
        let ledger = Arc::clone(&arith_ledger);
        let snr = Expr::col("flux").div(Expr::col("flux_err"));
        move |mut c: Chunk| {
            let col = c.batch.eval(&snr)?;
            ledger.record_copy(col.heap_bytes());
            c.batch = c.batch.with_column("snr", col)?;
            Ok(c)
        }
    })
    .gather("groupby_aggregation", Category::Pre, move |_seed| {
        let ledger = Arc::clone(&gather_ledger);
        let mut pending: Vec<Chunk> = Vec::new();
        move |c: Chunk| {
            let total = c.total;
            pending.push(c);
            if pending.len() < total {
                return Ok(None);
            }
            pending.sort_by_key(|c| c.index);
            let labels = Arc::clone(&pending[0].labels);
            let parts: Vec<ColumnBatch> = pending.drain(..).map(|c| c.batch).collect();
            let frame = ColumnBatch::concat(&parts)?;
            ledger.record_gather(frame.nrows());
            let features = df::groupby::groupby_agg(
                &frame,
                &["object_id"],
                &[
                    ("flux", Agg::Mean),
                    ("flux", Agg::Std),
                    ("flux", Agg::Min),
                    ("flux", Agg::Max),
                    ("snr", Agg::Mean),
                    ("snr", Agg::Std),
                    ("flux_err", Agg::Mean),
                ],
                engine,
            )?;
            Ok(Some(Features { frame: features, labels }))
        }
    })
    .map("type_conversion", Category::Pre, move |_seed| {
        move |mut f: Features| {
            f.frame = df::ops::astype(&f.frame, "object_id", DType::I64, engine)?;
            Ok(f)
        }
    })
    .map("train_test_split", Category::Pre, |seed| {
        move |f: Features| {
            let (x_train, y_train, x_test, y_test) =
                split_features(&f.frame, &f.labels, seed)?;
            Ok(SplitMats { x_train, y_train, x_test, y_test })
        }
    })
    .map("gbt_train_infer", Category::Ai, move |_seed| {
        move |s: SplitMats| {
            let (pred, proba) = gbt_scores(&s.x_train, &s.y_train, &s.x_test, ml);
            Ok(Scores { pred, proba, y_test: s.y_test })
        }
    })
    .sink("finalize", Category::Post, move |payload: &Workload, _seed| {
        let observations = match payload {
            Workload::LightCurves { csv, .. } => csv.lines().count().saturating_sub(1),
            other => return Err(super::workload_mismatch("plasticc", "light_curves", other)),
        };
        Ok((
            None,
            |slot: &mut Option<Scores>, s: Scores| {
                *slot = Some(s);
                Ok(())
            },
            move |slot: Option<Scores>| {
                let s = slot
                    .ok_or_else(|| anyhow::anyhow!("plasticc pipeline produced no result"))?;
                let mut m = BTreeMap::new();
                m.insert("accuracy".to_string(), metrics::accuracy(&s.y_test, &s.pred));
                m.insert("auc".to_string(), metrics::auc(&s.y_test, &s.proba));
                Ok(PlanOutput { metrics: m, items: observations })
            },
        ))
    })
    .with_batch_ledger(ledger))
}

/// Run the PLAsTiCC pipeline under `cfg.exec`.
pub fn run(cfg: &RunConfig) -> anyhow::Result<PipelineResult> {
    super::run_entry(super::find("plasticc").expect("plasticc is registered"), cfg)
}

/// Typed projection of a PLAsTiCC run's metrics (no F1 is computed for
/// this workload, so it reports `NaN`).
pub fn output(res: &PipelineResult) -> Output {
    Output::Classification {
        accuracy: res.metric_or_nan("accuracy"),
        auc: res.metric_or_nan("auc"),
        f1: res.metric_or_nan("f1"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipelines::Toggles;

    fn small(toggles: Toggles) -> PipelineResult {
        run(&RunConfig { toggles, scale: 0.3, seed: 11, ..Default::default() }).unwrap()
    }

    #[test]
    fn classifies_planted_classes() {
        let res = small(Toggles::optimized());
        assert!(res.metric("auc").unwrap() > 0.85, "{:?}", res.metrics);
        assert!(res.metric("accuracy").unwrap() > 0.75, "{:?}", res.metrics);
    }

    #[test]
    fn exact_and_hist_agree_on_quality() {
        let a = small(Toggles::baseline());
        let b = small(Toggles::optimized());
        assert!(
            (a.metric("auc").unwrap() - b.metric("auc").unwrap()).abs() < 0.1,
            "{:?} vs {:?}",
            a.metrics,
            b.metrics
        );
    }

    #[test]
    fn batched_data_plane_matches_per_item_metrics() {
        let cfg = RunConfig { toggles: Toggles::optimized(), scale: 0.3, seed: 11, ..Default::default() };
        let per_item = run(&cfg).unwrap();
        let batched = run(&RunConfig { batch_rows: 256, ..cfg }).unwrap();
        assert_eq!(per_item.metrics, batched.metrics);
        assert_eq!(per_item.items, batched.items);
        let b = batched.batching.expect("batched run reports batch counters");
        assert!(b.batches > 1, "{b:?}");
        assert!(b.balanced(), "rows in != rows out + filtered: {b:?}");
        assert_eq!(b.rows_filtered, 0, "plasticc drops no observation rows");
        assert!(b.clone_avoided_bytes > 0, "{b:?}");
    }

    #[test]
    fn groupby_dominates_preprocessing() {
        let res = small(Toggles::optimized());
        let (pre, _) = res.report.fig1_split();
        assert!(pre > 50.0, "pre={pre}");
    }

    #[test]
    fn optimized_faster_e2e() {
        let base = run(&RunConfig {
            toggles: Toggles::baseline(),
            scale: 0.5,
            seed: 2,
            ..Default::default()
        })
        .unwrap();
        let opt = run(&RunConfig {
            toggles: Toggles::optimized(),
            scale: 0.5,
            seed: 2,
            ..Default::default()
        })
        .unwrap();
        let speedup = base.report.total().as_secs_f64() / opt.report.total().as_secs_f64();
        assert!(speedup > 1.2, "plasticc speedup {speedup}");
    }
}
