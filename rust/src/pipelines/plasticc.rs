//! PLAsTiCC pipeline (§2.2): classify astronomical light curves.
//!
//! Stages (Table 1): load data, drop columns, **groupby aggregation**,
//! arithmetic ops, type conversion, train/test split → XGBoost-style GBT.
//! Table 2 axes: Modin 30×, sklearnex 8×, XGBoost 1× (hist is already the
//! shipped default — our bench shows hist vs exact explicitly instead).
//!
//! Declared as a [`Plan`] over a single threaded state (tabular shape).
//!
//! Dataset: synthetic light curves. Two object classes differ in flux
//! variability (transients vs periodic), so per-object flux statistics
//! are genuinely discriminative and the GBT accuracy is a real metric.

use super::{CompiledPipeline, Output, PipelineResult, RunConfig, Workload};
use crate::coordinator::plan::{CompiledPlan, Slicing, WorkloadSlice};
use crate::coordinator::telemetry::Category;
use crate::coordinator::{Plan, PlanOutput};
use crate::dataframe::{self as df, groupby::Agg, DType, DataFrame, Engine, Expr};
use crate::linalg::Matrix;
use crate::ml::{metrics, Gbt, GbtParams, TreeMethod};
use crate::util::Rng;
use crate::OptLevel;
use std::collections::BTreeMap;

/// Generate the light-curve observations CSV: one row per (object, epoch,
/// passband) with flux/flux_err, plus a per-object hidden class.
pub fn generate_csv(objects: usize, epochs: usize, seed: u64) -> (String, Vec<f64>) {
    let mut rng = Rng::new(seed);
    let mut out = String::with_capacity(objects * epochs * 40);
    out.push_str("object_id,mjd,passband,flux,flux_err,detected\n");
    let mut labels = Vec::with_capacity(objects);
    for obj in 0..objects {
        let class = rng.chance(0.5); // true = transient
        labels.push(class as i64 as f64);
        let base = rng.normal_with(100.0, 20.0);
        for e in 0..epochs {
            let mjd = 59000.0 + e as f64;
            let passband = rng.below(6) as i64;
            // Transients: burst profile (high variance); periodic: sine.
            let flux = if class {
                base + 80.0 * (-((e as f64 - epochs as f64 / 2.0).powi(2)) / 20.0).exp()
                    + rng.normal_with(0.0, 12.0)
            } else {
                base + 10.0 * (e as f64 * 0.7).sin() + rng.normal_with(0.0, 3.0)
            };
            let err = rng.range_f64(0.5, 4.0);
            let detected = (flux > base) as i64;
            out.push_str(&format!(
                "{obj},{mjd:.1},{passband},{flux:.3},{err:.3},{detected}\n"
            ));
        }
    }
    (out, labels)
}

struct State {
    csv: String,
    labels: Vec<f64>,
    frame: DataFrame,
    features: DataFrame,
    engine: Engine,
    ml: OptLevel,
    seed: u64,
    x_train: Matrix,
    y_train: Vec<f64>,
    x_test: Matrix,
    y_test: Vec<f64>,
    pred: Vec<f64>,
    proba: Vec<f64>,
}

/// Epochs per object in the synthetic light curves.
const EPOCHS: usize = 40;

/// Synthesize the default PLAsTiCC payload for `cfg`.
pub fn payload(cfg: &RunConfig) -> Workload {
    let (csv, targets) = generate_csv(cfg.scaled(300, 24), EPOCHS, cfg.seed);
    Workload::LightCurves { csv, targets }
}

/// Build the PLAsTiCC plan over a synthetic payload.
pub fn plan(cfg: &RunConfig) -> anyhow::Result<Plan> {
    plan_with(cfg, Workload::Synthetic)
}

/// Build the PLAsTiCC plan over a supplied payload (one-shot shim over
/// [`compile`] + bind).
pub fn plan_with(cfg: &RunConfig, workload: Workload) -> anyhow::Result<Plan> {
    let payload = match workload {
        Workload::Synthetic => payload(cfg),
        w => w,
    };
    compile(cfg)?.bind(payload, cfg.seed)
}

/// Compile the PLAsTiCC stage graph once; binds accept a
/// [`Workload::LightCurves`] payload (single-state tabular shape).
pub fn compile(cfg: &RunConfig) -> anyhow::Result<CompiledPipeline> {
    let engine: Engine = cfg.toggles.dataframe.into();
    let ml = cfg.toggles.ml;
    Ok(CompiledPlan::source(
        "plasticc",
        "source",
        Category::Pre,
        Slicing::SingleState,
        move |slice: WorkloadSlice<Workload>| {
            let (csv, labels) = match slice.payload {
                Workload::LightCurves { csv, targets } => (csv, targets),
                other => {
                    return Err(super::workload_mismatch("plasticc", "light_curves", &other))
                }
            };
            let mut initial = Some(State {
                csv,
                labels,
                frame: DataFrame::new(),
                features: DataFrame::new(),
                engine,
                ml,
                seed: slice.seed,
                x_train: Matrix::zeros(0, 0),
                y_train: vec![],
                x_test: Matrix::zeros(0, 0),
                y_test: vec![],
                pred: vec![],
                proba: vec![],
            });
            Ok(move |emit: &mut dyn FnMut(State)| {
                if let Some(state) = initial.take() {
                    emit(state);
                }
            })
        },
    )
    .map("load_data", Category::Pre, |_seed| |mut s: State| {
        s.frame = df::csv::read_str(&s.csv, s.engine)?;
        s.csv.clear();
        Ok(s)
    })
    .map("drop_columns", Category::Pre, |_seed| |mut s: State| {
        s.frame = s.frame.drop_cols(&["mjd", "detected"]);
        Ok(s)
    })
    .map("arithmetic_ops", Category::Pre, |_seed| |mut s: State| {
        // SNR column feeds the aggregations.
        let snr = Expr::col("flux").div(Expr::col("flux_err"));
        s.frame = df::ops::with_column(&s.frame, "snr", &snr, s.engine)?;
        Ok(s)
    })
    .map("groupby_aggregation", Category::Pre, |_seed| |mut s: State| {
        s.features = df::groupby::groupby_agg(
            &s.frame,
            &["object_id"],
            &[
                ("flux", Agg::Mean),
                ("flux", Agg::Std),
                ("flux", Agg::Min),
                ("flux", Agg::Max),
                ("snr", Agg::Mean),
                ("snr", Agg::Std),
                ("flux_err", Agg::Mean),
            ],
            s.engine,
        )?;
        s.frame = DataFrame::new();
        Ok(s)
    })
    .map("type_conversion", Category::Pre, |_seed| |mut s: State| {
        s.features = df::ops::astype(&s.features, "object_id", DType::I64, s.engine)?;
        Ok(s)
    })
    .map("train_test_split", Category::Pre, |_seed| |mut s: State| {
        // Features come out grouped by object id (0..objects); attach
        // labels then split.
        let n = s.features.nrows();
        let ids = s.features.i64s("object_id")?.to_vec();
        let labels: Vec<f64> = ids
            .iter()
            .map(|&i| {
                s.labels.get(i as usize).copied().ok_or_else(|| {
                    anyhow::anyhow!("plasticc: no target for object_id {i} (payload has {})",
                        s.labels.len())
                })
            })
            .collect::<anyhow::Result<_>>()?;
        let cols = [
            "flux_mean", "flux_std", "flux_min", "flux_max", "snr_mean", "snr_std",
            "flux_err_mean",
        ];
        let mut x = Matrix::zeros(n, cols.len());
        for (j, c) in cols.iter().enumerate() {
            let v = s.features.f64s(c)?;
            for i in 0..n {
                x.set(i, j, v[i]);
            }
        }
        // Deterministic shuffled split 75/25.
        let mut idx: Vec<usize> = (0..n).collect();
        let mut rng = Rng::new(s.seed ^ 0x51);
        rng.shuffle(&mut idx);
        let n_test = n / 4;
        let (test_idx, train_idx) = idx.split_at(n_test);
        let take = |rows: &[usize]| {
            let mut xm = Matrix::zeros(rows.len(), cols.len());
            let mut ym = Vec::with_capacity(rows.len());
            for (r, &i) in rows.iter().enumerate() {
                for j in 0..cols.len() {
                    xm.set(r, j, x.get(i, j));
                }
                ym.push(labels[i]);
            }
            (xm, ym)
        };
        let (xt, yt) = take(train_idx);
        s.x_train = xt;
        s.y_train = yt;
        let (xs, ys) = take(test_idx);
        s.x_test = xs;
        s.y_test = ys;
        Ok(s)
    })
    .map("gbt_train_infer", Category::Ai, |_seed| |mut s: State| {
        let method = match s.ml {
            OptLevel::Baseline => TreeMethod::Exact,
            OptLevel::Optimized => TreeMethod::Hist,
        };
        let gbt = Gbt::fit(
            &s.x_train,
            &s.y_train,
            GbtParams { method, n_trees: 25, max_depth: 4, ..Default::default() },
        );
        s.pred = gbt.predict(&s.x_test);
        s.proba = gbt.predict_proba(&s.x_test);
        Ok(s)
    })
    .sink("finalize", Category::Post, move |payload: &Workload, _seed| {
        // One observation row per line after the header.
        let observations = match payload {
            Workload::LightCurves { csv, .. } => csv.lines().count().saturating_sub(1),
            other => return Err(super::workload_mismatch("plasticc", "light_curves", other)),
        };
        Ok((
            None,
            |slot: &mut Option<State>, s: State| {
                *slot = Some(s);
                Ok(())
            },
            move |slot: Option<State>| {
                let state = slot
                    .ok_or_else(|| anyhow::anyhow!("plasticc pipeline produced no result"))?;
                let mut m = BTreeMap::new();
                m.insert("accuracy".to_string(), metrics::accuracy(&state.y_test, &state.pred));
                m.insert("auc".to_string(), metrics::auc(&state.y_test, &state.proba));
                Ok(PlanOutput { metrics: m, items: observations })
            },
        ))
    }))
}

/// Run the PLAsTiCC pipeline under `cfg.exec`.
pub fn run(cfg: &RunConfig) -> anyhow::Result<PipelineResult> {
    super::run_entry(super::find("plasticc").expect("plasticc is registered"), cfg)
}

/// Typed projection of a PLAsTiCC run's metrics (no F1 is computed for
/// this workload, so it reports `NaN`).
pub fn output(res: &PipelineResult) -> Output {
    Output::Classification {
        accuracy: res.metric_or_nan("accuracy"),
        auc: res.metric_or_nan("auc"),
        f1: res.metric_or_nan("f1"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipelines::Toggles;

    fn small(toggles: Toggles) -> PipelineResult {
        run(&RunConfig { toggles, scale: 0.3, seed: 11, ..Default::default() }).unwrap()
    }

    #[test]
    fn classifies_planted_classes() {
        let res = small(Toggles::optimized());
        assert!(res.metric("auc").unwrap() > 0.85, "{:?}", res.metrics);
        assert!(res.metric("accuracy").unwrap() > 0.75, "{:?}", res.metrics);
    }

    #[test]
    fn exact_and_hist_agree_on_quality() {
        let a = small(Toggles::baseline());
        let b = small(Toggles::optimized());
        assert!(
            (a.metric("auc").unwrap() - b.metric("auc").unwrap()).abs() < 0.1,
            "{:?} vs {:?}",
            a.metrics,
            b.metrics
        );
    }

    #[test]
    fn groupby_dominates_preprocessing() {
        let res = small(Toggles::optimized());
        let (pre, _) = res.report.fig1_split();
        assert!(pre > 50.0, "pre={pre}");
    }

    #[test]
    fn optimized_faster_e2e() {
        let base = run(&RunConfig {
            toggles: Toggles::baseline(),
            scale: 0.5,
            seed: 2,
            ..Default::default()
        })
        .unwrap();
        let opt = run(&RunConfig {
            toggles: Toggles::optimized(),
            scale: 0.5,
            seed: 2,
            ..Default::default()
        })
        .unwrap();
        let speedup = base.report.total().as_secs_f64() / opt.report.total().as_secs_f64();
        assert!(speedup > 1.2, "plasticc speedup {speedup}");
    }
}
