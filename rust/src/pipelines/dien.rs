//! DIEN pipeline (§2.5): click-through-rate inference over a review log.
//!
//! Stages (Table 1): data ingestion (JSON parse), label encoding, get
//! history sequence, negative sampling, data split, inference. Table 2
//! axes: Modin 23.2× (here: the baseline vs optimized feature engineering
//! + dataframe path) and Intel-TF 9.82× (here: fused vs unfused
//! `dien_tiny` graphs).
//!
//! Declared as a [`Plan`] over a single threaded state (tabular shape);
//! inference goes through the shared [`ModelServer`], so the same plan
//! runs under the streaming and multi-instance executors.
//!
//! Quality note: the model is untrained (deterministic random weights), so
//! CTR AUC hovers at chance — recorded for completeness; the pipeline's
//! deliverables are the preprocessing speedup and inference throughput,
//! matching how the paper reports DIEN.

use super::{CompiledPipeline, Output, PipelineResult, RunConfig, Workload};
use crate::coordinator::plan::{CompiledPlan, Slicing, WorkloadSlice};
use crate::coordinator::telemetry::Category;
use crate::coordinator::{Plan, PlanOutput};
use crate::ml::metrics;
use crate::recsys::{
    build_examples, generate_log, parse_log, parse_log_via_dataframe, DienExample, ReviewEvent,
};
use crate::runtime::{ModelClient, ModelServer, Tensor};
use crate::OptLevel;
use std::collections::BTreeMap;

const HIST: usize = 10;
const CATALOG: usize = 1024;
const BATCH: usize = 16;

struct State {
    raw: String,
    events: Vec<ReviewEvent>,
    examples: Vec<DienExample>,
    scores: Vec<f32>,
}

fn model_name(dl: OptLevel) -> &'static str {
    match dl {
        OptLevel::Optimized => "dien_fused_b16",
        OptLevel::Baseline => "dien_unfused_b16",
    }
}

/// Synthesize the default DIEN payload for `cfg`: a JSON review log.
pub fn payload(cfg: &RunConfig) -> Workload {
    let n_events = cfg.scaled(4_000, 300);
    let n_users = (n_events / 12).max(8);
    Workload::ReviewLog { json: generate_log(n_events, n_users, 400, cfg.seed) }
}

/// Pre-compile the DIEN artifact the dl toggle selects; returns the warm
/// client a serving session holds.
pub fn warm(cfg: &RunConfig) -> anyhow::Result<Option<ModelClient>> {
    warm_client(cfg).map(Some)
}

fn warm_client(cfg: &RunConfig) -> anyhow::Result<ModelClient> {
    let model = model_name(cfg.toggles.dl);
    let client = ModelServer::shared()?;
    match cfg.toggles.dl {
        OptLevel::Optimized => client.warm_session(&[model], &[])?,
        OptLevel::Baseline => client.warm_session(&[], &[model])?,
    }
    Ok(client)
}

/// Build the DIEN plan over a synthetic payload.
pub fn plan(cfg: &RunConfig) -> anyhow::Result<Plan> {
    plan_with(cfg, Workload::Synthetic)
}

/// Build the DIEN plan over a supplied payload (one-shot shim over
/// [`compile`] + bind).
pub fn plan_with(cfg: &RunConfig, workload: Workload) -> anyhow::Result<Plan> {
    let payload = match workload {
        Workload::Synthetic => payload(cfg),
        w => w,
    };
    compile(cfg)?.bind(payload, cfg.seed)
}

/// Compile the DIEN stage graph once; binds accept a
/// [`Workload::ReviewLog`] payload (single-state tabular shape). The
/// negative-sampling seed is a bind parameter, so multi-instance
/// replicas bound at shifted seeds draw distinct samples exactly as
/// the per-build path did.
pub fn compile(cfg: &RunConfig) -> anyhow::Result<CompiledPipeline> {
    let opt_df = cfg.toggles.dataframe;
    let dl = cfg.toggles.dl;
    let model = model_name(dl);

    // Steady-state: the shared server compiles at graph-compile time
    // (see dlsa.rs); binds never re-issue the warm round-trips.
    let client = warm_client(cfg)?;

    Ok(CompiledPlan::source(
        "dien",
        "source",
        Category::Pre,
        Slicing::SingleState,
        |slice: WorkloadSlice<Workload>| {
            let json = match slice.payload {
                Workload::ReviewLog { json } => json,
                other => return Err(super::workload_mismatch("dien", "review_log", &other)),
            };
            let mut initial = Some(State {
                raw: json,
                events: vec![],
                examples: vec![],
                scores: vec![],
            });
            Ok(move |emit: &mut dyn FnMut(State)| {
                if let Some(state) = initial.take() {
                    emit(state);
                }
            })
        },
    )
    .map("json_ingestion", Category::Pre, move |_seed| move |mut s: State| {
        // Baseline: json → boxed-row dataframe → events (the paper's
        // unoptimized "parse into dataframes" path). Optimized: direct
        // struct parse, no intermediate frame.
        let (events, skipped) = match opt_df {
            OptLevel::Baseline => parse_log_via_dataframe(&s.raw),
            OptLevel::Optimized => parse_log(&s.raw),
        };
        anyhow::ensure!(skipped == 0, "review log has {skipped} malformed events");
        s.events = events;
        s.raw.clear();
        Ok(s)
    })
    .map("feature_engineering", Category::Pre, move |seed| move |mut s: State| {
        // label encoding + history sequences + negative sampling.
        let (examples, _, _) = build_examples(&s.events, HIST, CATALOG - 1, seed, opt_df);
        s.examples = examples;
        s.events.clear();
        Ok(s)
    })
    .map("ctr_inference", Category::Ai, move |_seed| {
        let client = client.clone();
        move |mut s: State| {
            let mut scores = Vec::with_capacity(s.examples.len());
            for chunk in s.examples.chunks(BATCH) {
                let mut hist: Vec<i32> = Vec::with_capacity(BATCH * HIST);
                let mut cand: Vec<i32> = Vec::with_capacity(BATCH);
                for ex in chunk {
                    hist.extend(ex.history.iter().map(|&h| (h as usize % CATALOG) as i32));
                    cand.push((ex.candidate as usize % CATALOG) as i32);
                }
                // Pad the tail batch by repeating the last example.
                while cand.len() < BATCH {
                    let start = hist.len() - HIST;
                    let last_h: Vec<i32> = hist[start..].to_vec();
                    hist.extend(last_h);
                    let last_c = *cand.last().unwrap();
                    cand.push(last_c);
                }
                let inputs =
                    vec![Tensor::i32(&[BATCH, HIST], hist), Tensor::i32(&[BATCH], cand)];
                let out = match dl {
                    OptLevel::Optimized => client.run(model, inputs)?,
                    OptLevel::Baseline => client.run_chain(model, inputs)?,
                };
                let p = out[0]
                    .as_f32()
                    .ok_or_else(|| anyhow::anyhow!("dien returned non-f32 probabilities"))?;
                scores.extend_from_slice(&p[..chunk.len()]);
            }
            s.scores = scores;
            Ok(s)
        }
    })
    .map("ranking_postprocess", Category::Post, |_seed| |s: State| {
        // CTR consumers sort candidates per user; modeled by a sort.
        let mut ranked: Vec<(usize, f32)> = s.scores.iter().copied().enumerate().collect();
        ranked.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        Ok(s)
    })
    .sink("finalize", Category::Post, move |payload: &Workload, _seed| {
        // One JSON event object per non-empty line.
        let n_events = match payload {
            Workload::ReviewLog { json } => {
                json.lines().filter(|l| !l.trim().is_empty()).count()
            }
            other => return Err(super::workload_mismatch("dien", "review_log", other)),
        };
        Ok((
            None,
            |slot: &mut Option<State>, s: State| {
                *slot = Some(s);
                Ok(())
            },
            move |slot: Option<State>| {
                let state = slot
                    .ok_or_else(|| anyhow::anyhow!("dien pipeline produced no result"))?;
                let labels: Vec<f64> = state.examples.iter().map(|e| e.label as f64).collect();
                let scores: Vec<f64> = state.scores.iter().map(|&p| p as f64).collect();
                let mut m = BTreeMap::new();
                m.insert("auc".to_string(), metrics::auc(&labels, &scores));
                m.insert("examples".to_string(), state.examples.len() as f64);
                Ok(PlanOutput { metrics: m, items: n_events })
            },
        ))
    })
    .declare_warm(&[model]))
}

/// Run the DIEN pipeline under `cfg.exec`.
pub fn run(cfg: &RunConfig) -> anyhow::Result<PipelineResult> {
    super::run_entry(super::find("dien").expect("dien is registered"), cfg)
}

/// Typed projection of a DIEN run's metrics.
pub fn output(res: &PipelineResult) -> Output {
    Output::Ranking {
        auc: res.metric_or_nan("auc"),
        examples: res.metric("examples").unwrap_or(0.0) as usize,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipelines::Toggles;

    fn artifacts_ready() -> bool {
        crate::runtime::default_artifacts_dir().join("manifest.json").exists()
    }

    fn small(toggles: Toggles) -> PipelineResult {
        run(&RunConfig { toggles, scale: 0.2, seed: 6, ..Default::default() }).unwrap()
    }

    #[test]
    fn runs_and_scores_every_example() {
        if !artifacts_ready() {
            return;
        }
        let res = small(Toggles::optimized());
        assert!(res.metric("examples").unwrap() > 0.0);
        let auc = res.metric("auc").unwrap();
        assert!((0.0..=1.0).contains(&auc));
    }

    #[test]
    fn fused_and_unfused_score_identically() {
        if !artifacts_ready() {
            return;
        }
        let mut t = Toggles::optimized();
        let a = small(t);
        t.dl = OptLevel::Baseline;
        let b = small(t);
        // Same seed → same examples; fp32 fused vs unfused must agree.
        assert!((a.metric("auc").unwrap() - b.metric("auc").unwrap()).abs() < 1e-6);
    }

    #[test]
    fn preprocessing_heavy_breakdown() {
        if !artifacts_ready() {
            return;
        }
        // Fig 1: DIEN E2E is preprocessing-heavy (~60%+).
        let res = small(Toggles::optimized());
        let (pre, _) = res.report.fig1_split();
        assert!(pre > 30.0, "pre={pre}");
    }
}
