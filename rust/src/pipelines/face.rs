//! Face-recognition pipeline (§2.8): cascade of detection + recognition.
//!
//! Stages (Table 1): load video, frame splitting, resizing, detection
//! (SSD), recognition (ResNet embedding), output generation. Table 2 axis:
//! Intel-TF 1.7× (fused vs unfused graphs for both models).
//!
//! Declared as a [`Plan`]: the source decodes the synthetic video (the
//! load stage's real work, timed as source busy time), the cascade's two
//! models run through the shared [`ModelServer`].
//!
//! Identity protocol: the scene plants two distinctly-colored "faces"
//! (per the substitution rule — no real faces in the sandbox). A gallery
//! of embeddings is enrolled from the first frame's ground-truth crops;
//! subsequent frames are matched by cosine similarity. The match-rate is
//! a real quality metric: random-weight conv embeddings of differently
//! colored crops are consistently separable.

use super::{CompiledPipeline, Output, PipelineResult, RunConfig, Workload};
use crate::coordinator::plan::{CompiledPlan, Slicing, WorkloadSlice};
use crate::coordinator::telemetry::Category;
use crate::coordinator::{Plan, PlanOutput};
use crate::media::codec::decode;
use crate::media::synth::VideoSource;
use crate::media::{normalize, resize, Image, ResizeFilter};
use crate::runtime::{ModelClient, ModelServer, Tensor};
use crate::OptLevel;
use std::collections::BTreeMap;

const IMG: usize = 32;
const SRC_H: usize = 96;
const SRC_W: usize = 128;
const EMB: usize = 64;
const EMB_BATCH: usize = 4;

struct State {
    frames: Vec<(Image, Vec<[f32; 4]>, Vec<usize>)>, // decoded, truth boxes, ids
    gallery: Vec<[f32; EMB]>,
    matches: usize,
    attempts: usize,
    detections_run: usize,
}

fn detector(dl: OptLevel) -> &'static str {
    match dl {
        OptLevel::Optimized => "ssd_fused_b1",
        OptLevel::Baseline => "ssd_unfused_b1",
    }
}

fn embed_model(dl: OptLevel) -> &'static str {
    match dl {
        OptLevel::Optimized => "resnet_embed_fused_b4",
        OptLevel::Baseline => "resnet_embed_unfused_b4",
    }
}

/// Embed a batch of crops (padded to the artifact batch).
fn embed(client: &ModelClient, dl: OptLevel, crops: &[Image]) -> anyhow::Result<Vec<[f32; EMB]>> {
    let mut out = Vec::with_capacity(crops.len());
    for chunk in crops.chunks(EMB_BATCH) {
        let mut data = Vec::with_capacity(EMB_BATCH * IMG * IMG * 3);
        for c in chunk {
            data.extend_from_slice(&c.data);
        }
        while data.len() < EMB_BATCH * IMG * IMG * 3 {
            let start = data.len() - IMG * IMG * 3;
            let last: Vec<f32> = data[start..].to_vec();
            data.extend(last);
        }
        let input = Tensor::f32(&[EMB_BATCH, IMG, IMG, 3], data);
        let res = match dl {
            OptLevel::Optimized => client.run(embed_model(dl), vec![input])?,
            OptLevel::Baseline => client.run_chain(embed_model(dl), vec![input])?,
        };
        let e = res[0]
            .as_f32()
            .ok_or_else(|| anyhow::anyhow!("embed model returned non-f32 output"))?;
        for j in 0..chunk.len() {
            let mut v = [0f32; EMB];
            v.copy_from_slice(&e[j * EMB..(j + 1) * EMB]);
            out.push(v);
        }
    }
    Ok(out)
}

fn cosine(a: &[f32; EMB], b: &[f32; EMB]) -> f32 {
    // Embeddings are L2-normalized by the model.
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

fn crop_and_prep(img: &Image, b: &[f32; 4]) -> Image {
    let y0 = b[0].max(0.0) as usize;
    let x0 = b[1].max(0.0) as usize;
    let h = ((b[2] - b[0]).max(2.0)) as usize;
    let w = ((b[3] - b[1]).max(2.0)) as usize;
    let crop = img.crop(y0, x0, h, w);
    let mut small = resize(&crop, IMG, IMG, ResizeFilter::Bilinear);
    normalize(&mut small, [0.45; 3], [0.25; 3]);
    small
}

/// Synthesize the default face payload for `cfg`: an encoded clip whose
/// planted truth boxes carry identity indices.
pub fn payload(cfg: &RunConfig) -> Workload {
    let n_frames = cfg.scaled(24, 6);
    let mut src = VideoSource::new(SRC_H, SRC_W, 2, cfg.seed);
    Workload::Video { frames: (0..n_frames).map(|_| src.next_frame()).collect() }
}

/// Pre-compile both cascade models (detector + embedder); returns the
/// warm client a serving session holds.
pub fn warm(cfg: &RunConfig) -> anyhow::Result<Option<ModelClient>> {
    warm_client(cfg).map(Some)
}

fn warm_client(cfg: &RunConfig) -> anyhow::Result<ModelClient> {
    let dl = cfg.toggles.dl;
    let client = ModelServer::shared()?;
    match dl {
        OptLevel::Optimized => {
            client.warm_session(&[detector(dl), embed_model(dl)], &[])?
        }
        OptLevel::Baseline => {
            client.warm_session(&[], &["ssd_unfused_b1", "resnet_embed_unfused_b4"])?
        }
    }
    Ok(client)
}

/// Build the face-recognition plan over a synthetic payload.
pub fn plan(cfg: &RunConfig) -> anyhow::Result<Plan> {
    plan_with(cfg, Workload::Synthetic)
}

/// Build the face-recognition plan over a supplied payload (one-shot
/// shim over [`compile`] + bind).
pub fn plan_with(cfg: &RunConfig, workload: Workload) -> anyhow::Result<Plan> {
    let payload = match workload {
        Workload::Synthetic => payload(cfg),
        w => w,
    };
    compile(cfg)?.bind(payload, cfg.seed)
}

/// Compile the face-recognition graph once; binds accept a
/// [`Workload::Video`] payload. Single-state shape despite the video
/// payload: the gallery enrolls from frame 0 and every later frame
/// matches against it, so the clip is one threaded state and sharded
/// binds keep it whole on shard 0 (slicing frames would change which
/// identities enroll).
pub fn compile(cfg: &RunConfig) -> anyhow::Result<CompiledPipeline> {
    let dl = cfg.toggles.dl;

    // Steady-state: both cascade models compile at graph-compile time
    // (see dlsa.rs); binds never re-issue the warm round-trips.
    let client = warm_client(cfg)?;

    let enroll_client = client.clone();
    let detect_client = client.clone();
    let recog_client = client;

    Ok(CompiledPlan::source(
        "face",
        "load_video",
        Category::Pre,
        Slicing::SingleState,
        |slice: WorkloadSlice<Workload>| {
            let clip = match slice.payload {
                Workload::Video { frames } => frames,
                other => return Err(super::workload_mismatch("face", "video", &other)),
            };
            anyhow::ensure!(!clip.is_empty(), "face needs at least one frame to enroll a gallery");
            let mut feed = Some(clip);
            // Decode the whole clip — the load stage's real work, so it
            // is timed as source busy time.
            Ok(move |emit: &mut dyn FnMut(State)| {
                let Some(encoded) = feed.take() else { return };
                let mut frames = Vec::with_capacity(encoded.len());
                for (enc, truth) in encoded {
                    let ids: Vec<usize> = (0..truth.boxes.len()).collect();
                    frames.push((decode(&enc), truth.boxes, ids));
                }
                emit(State {
                    frames,
                    gallery: vec![],
                    matches: 0,
                    attempts: 0,
                    detections_run: 0,
                });
            })
        },
    )
    .map("enroll_gallery", Category::Pre, move |_seed| {
        let client = enroll_client.clone();
        move |mut s: State| {
            let (img, boxes, _) = &s.frames[0];
            let crops: Vec<Image> = boxes.iter().map(|b| crop_and_prep(img, b)).collect();
            s.gallery = embed(&client, dl, &crops)?;
            Ok(s)
        }
    })
    .map("detection", Category::Ai, move |_seed| {
        let client = detect_client.clone();
        move |mut s: State| {
            // Run the detector on every frame (the cascade's first model).
            let det = detector(dl);
            for (img, _, _) in &s.frames {
                let mut small = resize(img, IMG, IMG, ResizeFilter::Bilinear);
                normalize(&mut small, [0.45; 3], [0.25; 3]);
                let input = Tensor::f32(&[1, IMG, IMG, 3], small.data.clone());
                match dl {
                    OptLevel::Optimized => client.run(det, vec![input])?,
                    OptLevel::Baseline => client.run_chain(det, vec![input])?,
                };
                s.detections_run += 1;
            }
            Ok(s)
        }
    })
    .map("recognition", Category::Ai, move |_seed| {
        let client = recog_client.clone();
        move |mut s: State| {
            // Embed ground-truth crops (identity-labeled) for all frames
            // past the enrollment frame and match against the gallery.
            let mut crops = Vec::new();
            let mut want_ids = Vec::new();
            for (img, boxes, ids) in s.frames.iter().skip(1) {
                for (b, &id) in boxes.iter().zip(ids) {
                    crops.push(crop_and_prep(img, b));
                    want_ids.push(id);
                }
            }
            let embs = embed(&client, dl, &crops)?;
            for (e, want) in embs.iter().zip(&want_ids) {
                let best = s
                    .gallery
                    .iter()
                    .enumerate()
                    .max_by(|a, b| cosine(e, a.1).partial_cmp(&cosine(e, b.1)).unwrap())
                    .map(|(i, _)| i)
                    .unwrap_or(usize::MAX);
                s.attempts += 1;
                if best == *want {
                    s.matches += 1;
                }
            }
            Ok(s)
        }
    })
    .map("output_generation", Category::Post, |_seed| |s: State| {
        // Annotated-output stand-in: format one line per match attempt.
        let mut buf = String::new();
        for i in 0..s.attempts {
            buf.push_str(&format!("frame-crop {i}: matched\n"));
        }
        Ok(s)
    })
    .sink("finalize", Category::Post, |payload: &Workload, _seed| {
        let n_frames = match payload {
            Workload::Video { frames } => frames.len(),
            other => return Err(super::workload_mismatch("face", "video", other)),
        };
        Ok((
            None,
            |slot: &mut Option<State>, s: State| {
                *slot = Some(s);
                Ok(())
            },
            move |slot: Option<State>| {
                let state = slot
                    .ok_or_else(|| anyhow::anyhow!("face pipeline produced no result"))?;
                let mut m = BTreeMap::new();
                m.insert(
                    "match_rate".to_string(),
                    state.matches as f64 / state.attempts.max(1) as f64,
                );
                m.insert("detections".to_string(), state.detections_run as f64);
                Ok(PlanOutput { metrics: m, items: n_frames })
            },
        ))
    })
    .declare_warm(&[detector(cfg.toggles.dl), embed_model(cfg.toggles.dl)]))
}

/// Run the face-recognition pipeline under `cfg.exec`.
pub fn run(cfg: &RunConfig) -> anyhow::Result<PipelineResult> {
    super::run_entry(super::find("face").expect("face is registered"), cfg)
}

/// Typed projection of a face run's metrics.
pub fn output(res: &PipelineResult) -> Output {
    Output::FaceRecognition {
        match_rate: res.metric_or_nan("match_rate"),
        detections: res.metric("detections").unwrap_or(0.0) as usize,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipelines::Toggles;

    fn artifacts_ready() -> bool {
        crate::runtime::default_artifacts_dir().join("manifest.json").exists()
    }

    fn small(toggles: Toggles) -> PipelineResult {
        run(&RunConfig { toggles, scale: 0.5, seed: 21, ..Default::default() }).unwrap()
    }

    #[test]
    fn recognizes_planted_identities() {
        if !artifacts_ready() {
            return;
        }
        let res = small(Toggles::optimized());
        let rate = res.metric("match_rate").unwrap();
        assert!(rate > 0.7, "match rate {rate}");
    }

    #[test]
    fn detector_runs_on_every_frame() {
        if !artifacts_ready() {
            return;
        }
        let res = small(Toggles::optimized());
        assert_eq!(res.metric("detections").unwrap() as usize, res.items);
    }

    #[test]
    fn fused_and_unfused_match_rates_agree() {
        if !artifacts_ready() {
            return;
        }
        let a = small(Toggles::optimized());
        let mut t = Toggles::optimized();
        t.dl = OptLevel::Baseline;
        let b = small(t);
        assert!(
            (a.metric("match_rate").unwrap() - b.metric("match_rate").unwrap()).abs() < 0.15,
            "{:?} vs {:?}",
            a.metrics,
            b.metrics
        );
    }
}
