//! The eight end-to-end pipelines of Table 1, each parameterized by the
//! optimization toggles of Table 2.
//!
//! | module | paper workload | model | Table 2 axes |
//! |---|---|---|---|
//! | [`census`] | Census (ridge regression) | `ml::Ridge` | Modin, sklearnex |
//! | [`plasticc`] | PLAsTiCC (XGBoost) | `ml::Gbt` | Modin, sklearnex, XGBoost-hist |
//! | [`iiot`] | Industrial IoT (random forest) | `ml::RandomForest` | Modin, sklearnex |
//! | [`dlsa`] | Document-level sentiment | `bert_tiny` | IPEX (fused), INT8 |
//! | [`dien`] | DIEN recommendation | `dien_tiny` | Modin, Intel-TF (fused) |
//! | [`video_streamer`] | Video analytics | `ssd_tiny` | Intel-TF (fused), INT8 |
//! | [`anomaly`] | Anomaly detection | `resnet_tiny` + PCA/Gaussian | Modin, sklearnex, IPEX |
//! | [`face`] | Face recognition | `ssd_tiny` + `resnet_embed` | Intel-TF (fused) |
//!
//! Every pipeline is declared once as a compiled stage graph and
//! executed by whichever executor [`RunConfig::exec`] selects — see
//! [`crate::coordinator`]. Each pipeline's API splits the lifecycle
//! into **compile → bind → execute**:
//!
//! * `payload(&RunConfig)` synthesizes the pipeline's deterministic
//!   dataset as a typed [`Workload`];
//! * `compile(&RunConfig)` builds the reusable [`CompiledPipeline`]
//!   (payload-free templates + warm model-set declaration; model
//!   artifacts warm here, once) — the single definition of the graph;
//! * `CompiledPipeline::bind(payload, seed)` instantiates a run's
//!   single-use plan in microseconds — a serving session compiles once
//!   and binds per request ([`crate::service::Session`]);
//! * `plan(&RunConfig)` / `plan_with(&RunConfig, Workload)` are the
//!   one-shot compile+bind compositions for benches and tests;
//! * `output(&PipelineResult)` projects the metric map into the typed
//!   [`Output`] for that pipeline's category;
//! * `warm(&RunConfig)` pre-compiles the pipeline's model artifacts and
//!   returns the warm [`ModelClient`] a serving session holds.
//!
//! The [`registry`] is a static table of these typed handles; the
//! long-lived serving facade over it lives in [`crate::service`].
//! `run`/`run_by_name` remain as one-shot conveniences for the benches
//! and CLI; their telemetry report carries the Figure 1 stage breakdown.
//! Sharded execution through [`run_compiled`] binds each shard to a
//! pre-sliced [`Workload`] ([`Workload::slice`]), closing the
//! redundant-source-pass seam the clone-based path pays.

pub mod census;
pub mod plasticc;
pub mod iiot;
pub mod dlsa;
pub mod dien;
pub mod video_streamer;
pub mod anomaly;
pub mod face;
pub mod workload;

pub use workload::{Output, Workload};
pub(crate) use workload::workload_mismatch;

use crate::coordinator::plan::{CompiledPlan, Sharder, Slicing};
use crate::coordinator::telemetry::{
    BatchReport, KernelReport, OptReport, Report, SchedReport, ShardedReport,
};
use crate::coordinator::{exec, ExecMode, ExecOutcome, Plan};
use crate::runtime::ModelClient;
use crate::OptLevel;
use std::collections::BTreeMap;
use std::time::Instant;

/// Per-axis optimization toggles — the columns of Table 2.
#[derive(Debug, Clone, Copy)]
pub struct Toggles {
    /// Dataframe engine: pandas-like vs Modin-like (Table 2 "Modin").
    pub dataframe: OptLevel,
    /// Classical-ML kernels: stock vs accelerated (Table 2 "Scikit-learn"
    /// / "XGBoost" hist).
    pub ml: OptLevel,
    /// DL graph: unfused per-stage chains vs fused single executables
    /// (Table 2 "IPEX" / "Intel-optimized TensorFlow").
    pub dl: OptLevel,
    /// INT8 quantization of DL inference (Table 2 "INT8 quantization").
    pub quant: bool,
    /// Tokenizer path (part of the DLSA preprocessing stack).
    pub tokenizer: OptLevel,
    /// NMS implementation (detection postprocessing).
    pub nms: OptLevel,
}

impl Toggles {
    /// Everything at one level. `quant` stays OFF even when optimized:
    /// this substrate has no INT8 dot-product hardware (VNNI/MXU), so the
    /// INT8 artifacts preserve accuracy but do not speed up CPU execution
    /// — including them in the default optimized config would *pessimize*
    /// it (measured in EXPERIMENTS.md §INT8). The quant axis is exercised
    /// explicitly by the Table 2 bench and the int8 tests.
    pub fn all(opt: OptLevel) -> Toggles {
        Toggles {
            dataframe: opt,
            ml: opt,
            dl: opt,
            quant: false,
            tokenizer: opt,
            nms: opt,
        }
    }

    /// Fully-baseline.
    pub fn baseline() -> Toggles {
        Toggles::all(OptLevel::Baseline)
    }

    /// Fully-optimized.
    pub fn optimized() -> Toggles {
        Toggles::all(OptLevel::Optimized)
    }
}

/// One pipeline run's configuration.
#[derive(Debug, Clone, Copy)]
pub struct RunConfig {
    pub toggles: Toggles,
    /// Dataset-size multiplier (1.0 = the default small workload used by
    /// tests; benches raise it).
    pub scale: f64,
    pub seed: u64,
    /// Which executor runs the plan (sequential / streaming / multi).
    pub exec: ExecMode,
    /// Rows per [`ColumnBatch`] for the tabular pipelines' columnar
    /// data plane. `0` (the default) keeps the per-item graph; any
    /// positive value compiles the batched graph, whose stages move
    /// Arc-backed zero-copy batch views instead of one whole-dataset
    /// state item. Metrics are identical either way (pinned by the
    /// conformance suite); only the data plane changes.
    ///
    /// [`ColumnBatch`]: crate::dataframe::ColumnBatch
    pub batch_rows: usize,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            toggles: Toggles::optimized(),
            scale: 1.0,
            seed: 0xE2E,
            exec: ExecMode::Sequential,
            batch_rows: 0,
        }
    }
}

impl RunConfig {
    /// Scale helper: `base * scale`, at least `min`.
    pub fn scaled(&self, base: usize, min: usize) -> usize {
        ((base as f64 * self.scale) as usize).max(min)
    }
}

/// Result of one E2E run.
#[derive(Debug, Clone)]
pub struct PipelineResult {
    /// Per-stage telemetry (Figure 1 source), including per-item
    /// end-to-end latency samples.
    pub report: Report,
    /// Named quality/throughput metrics (auc, r2, fps, agreement, …).
    pub metrics: BTreeMap<String, f64>,
    /// Items processed end-to-end (rows, docs, frames, …).
    pub items: usize,
    /// Per-shard partition report for `ExecMode::Sharded` runs; `None`
    /// under every other executor. Kept out of `metrics` so a sharded
    /// run's metric map stays identical to the sequential run's (the
    /// conformance contract).
    pub sharding: Option<ShardedReport>,
    /// Cooperative-scheduler counters for runs that executed on the
    /// task scheduler (`ExecMode::Async`, and sharded runs, whose merge
    /// streams on it); `None` under the thread-based executors. Kept
    /// out of `metrics` for the same conformance reason as `sharding`.
    pub sched: Option<SchedReport>,
    /// Batch-plane counters for runs whose graph moved [`ColumnBatch`]
    /// items (`RunConfig::batch_rows > 0` on a batched pipeline);
    /// `None` for per-item runs. Kept out of `metrics` for the same
    /// conformance reason as `sharding`: a batched run's metric map
    /// must equal the per-item run's bit-for-bit.
    ///
    /// [`ColumnBatch`]: crate::dataframe::ColumnBatch
    pub batching: Option<BatchReport>,
    /// What the plan optimizer did to the compiled graph this run
    /// executed (`None` when the graph ran exactly as written). Kept
    /// out of `metrics`: optimized and unoptimized runs must produce
    /// bit-identical metric maps (the conformance contract).
    pub opt: Option<OptReport>,
    /// Columnar-kernel counters for runs whose dataframe verbs went
    /// through the vectorized kernel layer ([`crate::dataframe::kernels`]);
    /// `None` when no kernel recorded activity. Counter-based only —
    /// vector-path rows vs scalar-fallback rows, chunks, masked lanes —
    /// and kept out of `metrics` for the same conformance reason as
    /// `batching`: kernel-path and scalar-path runs answer identically.
    pub kernels: Option<KernelReport>,
}

impl PipelineResult {
    /// Convenience metric accessor.
    pub fn metric(&self, name: &str) -> Option<f64> {
        self.metrics.get(name).copied()
    }

    /// Like [`Self::metric`] but `NaN` when absent — for the typed
    /// [`Output`] projections, which never drop fields silently.
    pub fn metric_or_nan(&self, name: &str) -> f64 {
        self.metric(name).unwrap_or(f64::NAN)
    }

    /// End-to-end throughput (items per second of total busy time).
    pub fn throughput(&self) -> f64 {
        self.items as f64 / self.report.total().as_secs_f64().max(1e-12)
    }
}

/// A pipeline's reusable compiled stage graph: templates over a typed
/// [`Workload`] payload, bound per run/request.
pub type CompiledPipeline = CompiledPlan<Workload>;

/// A pipeline's one-shot plan-builder entry point (synthetic payload).
pub type PlanFn = fn(&RunConfig) -> anyhow::Result<Plan>;
/// A pipeline's payload-accepting plan builder.
pub type PayloadPlanFn = fn(&RunConfig, Workload) -> anyhow::Result<Plan>;
/// A pipeline's graph compiler: the compile-once half of the
/// compile/bind split (see [`CompiledPipeline`]).
pub type CompileFn = fn(&RunConfig) -> anyhow::Result<CompiledPipeline>;
/// A pipeline's synthetic payload generator.
pub type PayloadFn = fn(&RunConfig) -> Workload;
/// A pipeline's typed-output projection.
pub type OutputFn = fn(&PipelineResult) -> Output;
/// A pipeline's model pre-compilation hook; `None` for pipelines without
/// model artifacts (the tabular three).
pub type WarmFn = fn(&RunConfig) -> anyhow::Result<Option<ModelClient>>;

/// Execute a plan-builder under the executor `cfg.exec` selects. Each
/// multi-instance replica gets a distinct stream (`seed + instance`), so
/// instance i processes its own data like the paper's parallel streams;
/// `MultiInstance(1)` is therefore bit-identical to `Sequential`. For
/// n > 1 the scaling aggregate is appended as `scaling_*` metrics.
/// Sharded execution instead partitions ONE stream: every shard builds
/// the plan at the base seed (the executor pins instance 0), so
/// `Sharded(n)` processes exactly the sequential dataset and reports the
/// same metrics — the partition detail lands in
/// [`PipelineResult::sharding`], never in the metric map.
pub fn run_plan(plan_fn: PlanFn, cfg: &RunConfig) -> anyhow::Result<PipelineResult> {
    let base = *cfg;
    let outcome = exec::execute(cfg.exec, move |instance| {
        let mut instance_cfg = base;
        instance_cfg.seed = base.seed.wrapping_add(instance as u64);
        plan_fn(&instance_cfg)
    })?;
    Ok(finish_outcome(outcome))
}

/// Like [`run_plan`], but over a supplied [`Workload`] through the
/// one-shot plan builders (each call rebuilds the stage graph; each
/// shard clones the full payload and filters by emission index). Kept
/// as the uncompiled reference path — the conformance suite pins it
/// metric-identical to [`run_compiled`], which serving uses instead.
/// Multi-instance replicas each process a clone of the payload at a
/// shifted seed (distinct streams); sharded workers each process a
/// clone at the base seed (one stream, partitioned).
pub fn run_plan_with(
    plan_fn: PayloadPlanFn,
    payload: Workload,
    cfg: &RunConfig,
) -> anyhow::Result<PipelineResult> {
    let base = *cfg;
    let outcome = match cfg.exec {
        ExecMode::Sequential => exec::run_sequential(plan_fn(cfg, payload)?)?,
        ExecMode::Streaming => {
            exec::run_streaming(plan_fn(cfg, payload)?, exec::DEFAULT_QUEUE_CAP)?
        }
        ExecMode::MultiInstance(_) => exec::execute(cfg.exec, move |instance| {
            let mut instance_cfg = base;
            instance_cfg.seed = base.seed.wrapping_add(instance as u64);
            plan_fn(&instance_cfg, payload.clone())
        })?,
        ExecMode::Sharded(n) => exec::run_sharded(n, move |s| {
            plan_fn(&base, payload.clone()).map(|p| p.shard(Sharder::new(s, n)))
        })?,
        ExecMode::Async(workers) => exec::run_async(plan_fn(cfg, payload)?, workers)?,
    };
    Ok(finish_outcome(outcome))
}

/// Compile one pipeline's stage graph, timing the whole compilation —
/// warmup included — into the graph's [`BindReport`]. The serving
/// session's open-time half of the compile/bind split.
///
/// [`BindReport`]: crate::coordinator::telemetry::BindReport
pub fn compile_entry(
    entry: &PipelineEntry,
    cfg: &RunConfig,
) -> anyhow::Result<CompiledPipeline> {
    let t0 = Instant::now();
    let compiled = (entry.compile)(cfg)?;
    compiled.set_compile_time(t0.elapsed());
    Ok(compiled)
}

/// [`compile_entry`] by registry name.
pub fn compile_by_name(name: &str, cfg: &RunConfig) -> anyhow::Result<CompiledPipeline> {
    let entry = find(name).ok_or_else(|| unknown_pipeline(name))?;
    compile_entry(entry, cfg)
}

/// Materialize a payload: synthetic workloads re-derive the pipeline's
/// deterministic dataset from `cfg`; anything else passes through.
fn materialize(entry: &PipelineEntry, cfg: &RunConfig, payload: Workload) -> Workload {
    match payload {
        Workload::Synthetic => (entry.payload)(cfg),
        w => w,
    }
}

/// Execute a payload against an already-compiled graph under `cfg.exec`
/// — the steady-state serving path: no graph rebuild, no warm
/// round-trips, just a bind per plan instance. Mode semantics match
/// [`run_plan`] / [`run_plan_with`] exactly:
///
/// * single-instance modes bind once (synthetic payloads materialize at
///   the base seed);
/// * `MultiInstance(n)` binds replica `i` at seed + i, with synthetic
///   payloads re-derived per instance (distinct streams) and explicit
///   payloads cloned;
/// * `Sharded(n)` binds each shard to a **pre-sliced** payload
///   ([`Workload::slice`] for per-item graphs; whole-to-shard-0 for
///   single-state ones), so the redundant per-shard full source pass of
///   the clone-based path disappears while the round-robin
///   emission-index semantics — and therefore every metric — stay
///   identical. The merge sink always binds against the full payload.
pub fn run_compiled(
    entry: &PipelineEntry,
    compiled: &CompiledPipeline,
    payload: Workload,
    cfg: &RunConfig,
) -> anyhow::Result<PipelineResult> {
    let base = *cfg;
    let batch_before = compiled.batch_report();
    let kernel_before = crate::dataframe::kernels::snapshot();
    let outcome = match cfg.exec {
        ExecMode::Sequential => {
            exec::run_sequential(compiled.bind(materialize(entry, cfg, payload), cfg.seed)?)?
        }
        ExecMode::Streaming => exec::run_streaming(
            compiled.bind(materialize(entry, cfg, payload), cfg.seed)?,
            exec::DEFAULT_QUEUE_CAP,
        )?,
        ExecMode::Async(workers) => exec::run_async(
            compiled.bind(materialize(entry, cfg, payload), cfg.seed)?,
            workers,
        )?,
        ExecMode::MultiInstance(n) => exec::run_multi_instance(n, |instance| {
            let mut instance_cfg = base;
            instance_cfg.seed = base.seed.wrapping_add(instance as u64);
            let instance_payload = match &payload {
                Workload::Synthetic => (entry.payload)(&instance_cfg),
                w => w.clone(),
            };
            compiled.bind(instance_payload, instance_cfg.seed)
        })?,
        ExecMode::Sharded(n) => {
            let full = materialize(entry, cfg, payload);
            exec::run_sharded(n, |s| {
                let sharder = Sharder::new(s, n);
                let slice = match compiled.slicing() {
                    Slicing::PerItem => full.slice(s, n),
                    Slicing::SingleState => {
                        if s == 0 {
                            full.clone()
                        } else {
                            full.empty_like()
                        }
                    }
                };
                compiled.bind_shard(slice, sharder, &full, cfg.seed)
            })?
        }
    };
    let mut result = finish_outcome(outcome);
    let batch_delta = compiled.batch_report().since(&batch_before);
    if batch_delta.batches > 0 {
        result.batching = Some(batch_delta);
    }
    result.opt = compiled.opt_report().cloned();
    // The kernel ledger is process-global, so under a parallel test
    // harness the delta may include neighboring runs' rows — it is
    // telemetry about HOW rows moved, never part of the answer, and the
    // balance invariants hold for any interleaving of recordings.
    let kernel_delta = crate::dataframe::kernels::snapshot().since(&kernel_before);
    if kernel_delta.rows() > 0 {
        result.kernels = Some(kernel_delta);
    }
    Ok(result)
}

/// Compile + execute one registry entry over its synthetic payload —
/// what `run_by_name` and each pipeline's `run` convenience call. One
/// compile per call (the one-shot cost profile); long-lived callers
/// hold a `Session` and reuse its compiled graph instead.
pub fn run_entry(entry: &PipelineEntry, cfg: &RunConfig) -> anyhow::Result<PipelineResult> {
    let compiled = compile_entry(entry, cfg)?;
    run_compiled(entry, &compiled, Workload::Synthetic, cfg)
}

/// Fold an executor outcome into a [`PipelineResult`], appending the
/// `scaling_*` metrics for multi-instance runs. `pub(crate)` so the
/// serving layer can project outcomes arriving via the async completion
/// hook the same way.
pub(crate) fn finish_outcome(outcome: ExecOutcome) -> PipelineResult {
    let mut metrics = outcome.output.metrics;
    if let Some(scaling) = &outcome.scaling {
        if scaling.instances.len() > 1 {
            metrics.insert("scaling_instances".to_string(), scaling.instances.len() as f64);
            metrics
                .insert("scaling_throughput".to_string(), scaling.aggregate_throughput());
            metrics.insert("scaling_fairness".to_string(), scaling.fairness());
            let pcts = scaling.latency_percentiles(&[0.50, 0.95]);
            for (name, p) in ["scaling_latency_p50_ms", "scaling_latency_p95_ms"].iter().zip(pcts)
            {
                if let Some(p) = p {
                    metrics.insert(name.to_string(), p.as_secs_f64() * 1e3);
                }
            }
        }
    }
    PipelineResult {
        report: outcome.report,
        metrics,
        items: outcome.output.items,
        sharding: outcome.sharding,
        sched: outcome.sched,
        batching: None,
        opt: None,
        kernels: None,
    }
}

/// A registered pipeline: the typed handles a serving session needs.
pub struct PipelineEntry {
    pub name: &'static str,
    pub description: &'static str,
    /// One-shot plan over the synthetic payload (compile + bind fused;
    /// the graph definition itself lives in `compile`).
    pub plan: PlanFn,
    /// One-shot plan over a supplied payload (compile + bind fused).
    pub plan_with: PayloadPlanFn,
    /// Compile the reusable stage graph — the serving path: sessions
    /// compile once at open and bind every request to it.
    pub compile: CompileFn,
    /// Synthetic payload generator (what `plan` feeds `plan_with`).
    pub payload: PayloadFn,
    /// Typed projection of a finished run's metrics.
    pub output: OutputFn,
    /// Pre-compile model artifacts; the session-held warm client.
    pub warm: WarmFn,
    /// Convenience runner: executes the plan under `cfg.exec`.
    pub run: fn(&RunConfig) -> anyhow::Result<PipelineResult>,
}

/// Warm hook for pipelines without model artifacts.
fn warm_none(_cfg: &RunConfig) -> anyhow::Result<Option<ModelClient>> {
    Ok(None)
}

/// All eight pipelines, in the paper's Table 1 order.
static REGISTRY: [PipelineEntry; 8] = [
    PipelineEntry {
        name: "census",
        description: "Ridge regression over synthetic IPUMS-like census data",
        plan: census::plan,
        plan_with: census::plan_with,
        compile: census::compile,
        payload: census::payload,
        output: census::output,
        warm: warm_none,
        run: census::run,
    },
    PipelineEntry {
        name: "plasticc",
        description: "GBT classification of synthetic LSST light curves",
        plan: plasticc::plan,
        plan_with: plasticc::plan_with,
        compile: plasticc::compile,
        payload: plasticc::payload,
        output: plasticc::output,
        warm: warm_none,
        run: plasticc::run,
    },
    PipelineEntry {
        name: "iiot",
        description: "Random-forest failure prediction on a wide sensor table",
        plan: iiot::plan,
        plan_with: iiot::plan_with,
        compile: iiot::compile,
        payload: iiot::payload,
        output: iiot::output,
        warm: warm_none,
        run: iiot::run,
    },
    PipelineEntry {
        name: "dlsa",
        description: "BERT-tiny document sentiment over synthetic reviews",
        plan: dlsa::plan,
        plan_with: dlsa::plan_with,
        compile: dlsa::compile,
        payload: dlsa::payload,
        output: dlsa::output,
        warm: dlsa::warm,
        run: dlsa::run,
    },
    PipelineEntry {
        name: "dien",
        description: "DIEN CTR inference over a synthetic JSON review log",
        plan: dien::plan,
        plan_with: dien::plan_with,
        compile: dien::compile,
        payload: dien::payload,
        output: dien::output,
        warm: dien::warm,
        run: dien::run,
    },
    PipelineEntry {
        name: "video_streamer",
        description: "Decode → SSD detection → NMS → metadata upload",
        plan: video_streamer::plan,
        plan_with: video_streamer::plan_with,
        compile: video_streamer::compile,
        payload: video_streamer::payload,
        output: video_streamer::output,
        warm: video_streamer::warm,
        run: video_streamer::run,
    },
    PipelineEntry {
        name: "anomaly",
        description: "ResNet features + PCA + Gaussian anomaly scoring",
        plan: anomaly::plan,
        plan_with: anomaly::plan_with,
        compile: anomaly::compile,
        payload: anomaly::payload,
        output: anomaly::output,
        warm: anomaly::warm,
        run: anomaly::run,
    },
    PipelineEntry {
        name: "face",
        description: "SSD face detect → ResNet embed → gallery match",
        plan: face::plan,
        plan_with: face::plan_with,
        compile: face::compile,
        payload: face::payload,
        output: face::output,
        warm: face::warm,
        run: face::run,
    },
];

/// The static pipeline table, in the paper's Table 1 order.
pub fn registry() -> &'static [PipelineEntry] {
    &REGISTRY
}

/// Look up one pipeline by name without walking callers through the full
/// table.
pub fn find(name: &str) -> Option<&'static PipelineEntry> {
    REGISTRY.iter().find(|e| e.name == name)
}

/// Every registered pipeline name, in table order.
pub fn names() -> Vec<&'static str> {
    REGISTRY.iter().map(|e| e.name).collect()
}

/// Error for an unregistered pipeline name; lists the valid names.
pub(crate) fn unknown_pipeline(name: &str) -> anyhow::Error {
    anyhow::anyhow!("unknown pipeline: {name} (known: {})", names().join(", "))
}

/// Run a pipeline by name under `cfg.exec` (compile + bind + execute;
/// sharded runs use payload-aware slicing via [`run_compiled`]).
pub fn run_by_name(name: &str, cfg: &RunConfig) -> anyhow::Result<PipelineResult> {
    let entry = find(name).ok_or_else(|| unknown_pipeline(name))?;
    run_entry(entry, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_has_eight_unique_names() {
        let names: Vec<&str> = registry().iter().map(|e| e.name).collect();
        assert_eq!(names.len(), 8);
        let mut dedup = names.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), 8);
    }

    #[test]
    fn find_locates_every_entry() {
        for e in registry() {
            assert_eq!(find(e.name).map(|f| f.name), Some(e.name));
        }
        assert!(find("nope").is_none());
    }

    #[test]
    fn unknown_pipeline_error_lists_known_names() {
        let err = run_by_name("nope", &RunConfig::default()).unwrap_err().to_string();
        assert!(err.contains("nope"), "{err}");
        for name in names() {
            assert!(err.contains(name), "{err} missing {name}");
        }
    }

    #[test]
    fn toggles_all() {
        let t = Toggles::baseline();
        assert_eq!(t.dataframe, OptLevel::Baseline);
        assert!(!t.quant);
        let t = Toggles::optimized();
        assert_eq!(t.ml, OptLevel::Optimized);
        assert!(!t.quant, "int8 stays opt-in on a VNNI-less substrate");
    }

    #[test]
    fn scaled_respects_min() {
        let cfg = RunConfig { scale: 0.001, ..Default::default() };
        assert_eq!(cfg.scaled(1000, 16), 16);
        let cfg = RunConfig { scale: 2.0, ..Default::default() };
        assert_eq!(cfg.scaled(1000, 16), 2000);
    }

    #[test]
    fn default_exec_is_sequential() {
        assert_eq!(RunConfig::default().exec, ExecMode::Sequential);
    }

    #[test]
    fn every_registry_entry_builds_a_plan_or_reports_missing_artifacts() {
        // Plan construction must either succeed or fail with a clean
        // artifacts/manifest error (DL pipelines without `make artifacts`)
        // — never panic.
        let cfg = RunConfig { scale: 0.05, ..Default::default() };
        for e in registry() {
            match (e.plan)(&cfg) {
                Ok(plan) => {
                    assert!(plan.stage_count() >= 3, "{} too small", e.name);
                    assert_eq!(plan.name(), e.name);
                }
                Err(err) => {
                    let msg = format!("{err:#}").to_lowercase();
                    assert!(
                        msg.contains("manifest") || msg.contains("artifact"),
                        "{}: unexpected plan error: {err:#}",
                        e.name
                    );
                }
            }
        }
    }

    #[test]
    fn plan_with_rejects_mismatched_workloads() {
        // A payload of the wrong category is a descriptive error naming
        // the pipeline, not a panic or a type-mismatch deep in a stage.
        let cfg = RunConfig { scale: 0.05, ..Default::default() };
        let err = (find("census").unwrap().plan_with)(
            &cfg,
            Workload::ReviewLog { json: String::new() },
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("census"), "{err}");
        assert!(err.contains("review_log"), "{err}");
    }

    #[test]
    fn tabular_payloads_round_trip_through_plan_with() {
        // plan(cfg) and plan_with(cfg, payload(cfg)) are the same
        // pipeline: identical metrics for the tabular three.
        let cfg = RunConfig { scale: 0.05, seed: 31, ..Default::default() };
        for name in ["census", "plasticc", "iiot"] {
            let e = find(name).unwrap();
            let direct = run_plan(e.plan, &cfg).unwrap();
            let served = run_plan_with(e.plan_with, (e.payload)(&cfg), &cfg).unwrap();
            assert_eq!(direct.metrics, served.metrics, "{name}");
            assert_eq!(direct.items, served.items, "{name}");
        }
    }

    #[test]
    fn sharded_runs_report_sequential_metrics_plus_a_sharding_report() {
        // Sharding partitions the one dataset: metrics and items equal
        // the sequential run (no scaling_* additions, no n× items), and
        // the partition detail rides on PipelineResult::sharding.
        let seq_cfg = RunConfig { scale: 0.05, seed: 31, ..Default::default() };
        let seq = run_by_name("census", &seq_cfg).unwrap();
        assert!(seq.sharding.is_none(), "sequential runs carry no sharding report");
        let cfg = RunConfig { exec: ExecMode::Sharded(3), ..seq_cfg };
        let sharded = run_by_name("census", &cfg).unwrap();
        assert_eq!(sharded.metrics, seq.metrics);
        assert_eq!(sharded.items, seq.items);
        let sharding = sharded.sharding.expect("sharded run must report its partitions");
        assert_eq!(sharding.shard_count(), 3);
        // census emits one state item: shard 0 owns it, the others idle.
        assert_eq!(sharding.total_owned(), 1);
        assert_eq!(sharding.shards[0].owned, 1);
    }

    #[test]
    fn async_runs_report_sequential_metrics_plus_scheduler_counters() {
        // The async executor changes HOW a plan runs, never what it
        // answers: metrics and items equal the sequential run, and the
        // scheduler detail rides on PipelineResult::sched (never the
        // metric map).
        let seq_cfg = RunConfig { scale: 0.05, seed: 31, ..Default::default() };
        let seq = run_by_name("census", &seq_cfg).unwrap();
        assert!(seq.sched.is_none(), "sequential runs carry no scheduler counters");
        let cfg = RunConfig { exec: ExecMode::Async(2), ..seq_cfg };
        let a = run_by_name("census", &cfg).unwrap();
        assert_eq!(a.metrics, seq.metrics);
        assert_eq!(a.items, seq.items);
        let sched = a.sched.expect("async run must report scheduler counters");
        assert!(sched.balanced(), "{sched:?}");
        assert_eq!(sched.workers, 2);
        // The serving path over a pre-generated payload agrees too.
        let e = find("census").unwrap();
        let served = run_plan_with(e.plan_with, (e.payload)(&seq_cfg), &cfg).unwrap();
        assert_eq!(served.metrics, seq.metrics);
        assert_eq!(served.items, seq.items);
    }

    #[test]
    fn compiled_graphs_bind_repeatedly_with_identical_metrics() {
        // One compile, three binds: metrics never move, the bind
        // report counts exactly what happened, and the compiled path
        // answers like the one-shot plan_with path.
        let cfg = RunConfig { scale: 0.05, seed: 31, ..Default::default() };
        for name in ["census", "plasticc", "iiot"] {
            let e = find(name).unwrap();
            let compiled = compile_entry(e, &cfg).unwrap();
            let payload = (e.payload)(&cfg);
            let a = run_compiled(e, &compiled, payload.clone(), &cfg).unwrap();
            let b = run_compiled(e, &compiled, payload.clone(), &cfg).unwrap();
            let c = run_compiled(e, &compiled, payload, &cfg).unwrap();
            assert_eq!(a.metrics, b.metrics, "{name}");
            assert_eq!(b.metrics, c.metrics, "{name}");
            let br = compiled.bind_report();
            assert_eq!(br.compiles, 1, "{name}");
            assert_eq!(br.binds, 3, "{name}");
            assert_eq!(br.rebuilds_avoided(), 2, "{name}");
            let direct = run_plan_with(e.plan_with, (e.payload)(&cfg), &cfg).unwrap();
            assert_eq!(a.metrics, direct.metrics, "{name}");
            assert_eq!(a.items, direct.items, "{name}");
        }
    }

    #[test]
    fn sliced_sharded_compiled_runs_match_clone_based_sharding() {
        // The artifact-free slice == clone pin (the full eight-pipeline
        // matrix lives in the executor-equivalence suite): payload-aware
        // slicing must reproduce clone-based sharding's metrics, items,
        // and per-shard ownership exactly.
        let cfg = RunConfig { scale: 0.05, seed: 31, ..Default::default() };
        let shard_cfg = RunConfig { exec: ExecMode::Sharded(3), ..cfg };
        for name in ["census", "plasticc", "iiot"] {
            let e = find(name).unwrap();
            let payload = (e.payload)(&cfg);
            let cloned = run_plan_with(e.plan_with, payload.clone(), &shard_cfg).unwrap();
            let compiled = compile_entry(e, &cfg).unwrap();
            let sliced = run_compiled(e, &compiled, payload, &shard_cfg).unwrap();
            assert_eq!(sliced.metrics, cloned.metrics, "{name}");
            assert_eq!(sliced.items, cloned.items, "{name}");
            let a = sliced.sharding.expect("sliced run reports partitions");
            let b = cloned.sharding.expect("cloned run reports partitions");
            assert_eq!(a.shard_count(), b.shard_count(), "{name}");
            for (x, y) in a.shards.iter().zip(&b.shards) {
                assert_eq!(x.owned, y.owned, "{name} shard {}", x.shard);
                assert_eq!(x.completed, y.completed, "{name} shard {}", x.shard);
            }
        }
    }

    #[test]
    fn tabular_compiled_runs_surface_a_kernel_report() {
        // The tabular pipelines' dataframe verbs run on the vectorized
        // kernel layer; the per-run delta rides PipelineResult::kernels
        // (never the metric map). The ledger is process-global, so a
        // parallel test harness may inflate the delta — assertions are
        // therefore presence + direction, not exact counts.
        let cfg = RunConfig { scale: 0.05, seed: 31, ..Default::default() };
        for name in ["census", "plasticc", "iiot"] {
            let res = run_by_name(name, &cfg).unwrap();
            let k = res.kernels.expect("tabular runs drive the kernel layer");
            assert!(k.vector_rows > 0, "{name}: {k:?}");
            assert!(k.rows() >= k.vector_rows, "{name}: {k:?}");
        }
    }

    #[test]
    fn sharded_plan_with_partitions_a_shared_payload() {
        // The serving path: one payload, executed sharded — same
        // answers as the sequential serving path over the same payload.
        let cfg = RunConfig { scale: 0.05, seed: 31, ..Default::default() };
        for name in ["census", "plasticc", "iiot"] {
            let e = find(name).unwrap();
            let payload = (e.payload)(&cfg);
            let seq = run_plan_with(e.plan_with, payload.clone(), &cfg).unwrap();
            let shard_cfg = RunConfig { exec: ExecMode::Sharded(4), ..cfg };
            let sharded = run_plan_with(e.plan_with, payload, &shard_cfg).unwrap();
            assert_eq!(sharded.metrics, seq.metrics, "{name}");
            assert_eq!(sharded.items, seq.items, "{name}");
            assert_eq!(
                sharded.sharding.as_ref().map(|s| s.shard_count()),
                Some(4),
                "{name}"
            );
        }
    }
}
