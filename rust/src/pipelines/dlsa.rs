//! DLSA pipeline (§2.4): document-level sentiment analysis with a
//! BERT-style encoder.
//!
//! Stages (Table 1): load data, tokenize/encode, dynamic batching,
//! inference, postprocess. Table 2 axes: IPEX 4.15× (here: fused Pallas
//! graph vs unfused per-stage chain with host round-trips) and INT8 3.9×
//! (here: the INT8 artifact).
//!
//! This is the paper's **serving** shape, declared per-document: items
//! are individual reviews, a [`BatcherConfig`] plan node groups them
//! under the max-batch/max-wait policy (§3.3's batch-size tuning), and
//! inference runs through the shared [`ModelServer`] so any executor —
//! including thread-per-stage streaming — can drive the same plan.
//!
//! Quality note (DESIGN.md §2): the encoder has deterministic random
//! weights — task accuracy is meaningless without training, so the
//! reported quality metrics are (a) FP32↔INT8 prediction agreement (the
//! paper's "little to no accuracy loss" claim) and (b) throughput.

use super::{CompiledPipeline, Output, PipelineResult, RunConfig, Workload};
use crate::coordinator::plan::{CompiledPlan, Slicing, WorkloadSlice};
use crate::coordinator::telemetry::Category;
use crate::coordinator::{BatcherConfig, Plan, PlanOutput};
use crate::runtime::{ModelClient, ModelServer, Tensor};
use crate::text::{ReviewGenerator, TokenizerKind, Vocab, WordPiece};
use crate::OptLevel;
use std::collections::BTreeMap;
use std::time::Duration;

const SEQ: usize = 64;
const BATCH: usize = 8;

/// Which artifact the (dl, quant) toggles select.
fn model_choice(dl: OptLevel, quant: bool) -> (&'static str, bool) {
    match (dl, quant) {
        (OptLevel::Optimized, true) => (concat!("bert_int8_b", 8), false),
        (OptLevel::Optimized, false) => (concat!("bert_fused_b", 8), false),
        // Baseline: unfused per-stage chain (graph breaks). INT8 without
        // graph fusion isn't a paper configuration; quant implies the
        // optimized runtime.
        (OptLevel::Baseline, _) => ("bert_unfused_b8", true),
    }
}

/// Score one (possibly partial) batch of encoded docs; the tail is padded
/// by repeating the final document, so per-document logits are invariant
/// to how the batcher cut the stream.
fn infer_batch(
    client: &ModelClient,
    model: &str,
    is_chain: bool,
    batch: &[(usize, Vec<i64>)],
) -> anyhow::Result<Vec<[f32; 2]>> {
    let mut ids: Vec<i32> = Vec::with_capacity(BATCH * SEQ);
    for (_, doc) in batch {
        ids.extend(doc.iter().map(|&t| t as i32));
    }
    while ids.len() < BATCH * SEQ {
        let start = ids.len() - SEQ;
        let last: Vec<i32> = ids[start..].to_vec();
        ids.extend(last);
    }
    let input = Tensor::i32(&[BATCH, SEQ], ids);
    let outputs = if is_chain {
        client.run_chain(model, vec![input])?
    } else {
        client.run(model, vec![input])?
    };
    let logits = outputs[0]
        .as_f32()
        .ok_or_else(|| anyhow::anyhow!("bert returned non-f32 logits"))?;
    Ok((0..batch.len()).map(|d| [logits[d * 2], logits[d * 2 + 1]]).collect())
}

fn argmax2(l: &[f32; 2]) -> usize {
    (l[1] > l[0]) as usize
}

/// Synthesize the default DLSA payload for `cfg`: labeled reviews.
pub fn payload(cfg: &RunConfig) -> Workload {
    let n_docs = cfg.scaled(96, 16);
    let mut gen = ReviewGenerator::new(cfg.seed, 30);
    let reviews = gen.batch(n_docs);
    let labels: Vec<i64> = reviews.iter().map(|r| r.label).collect();
    let docs: Vec<String> = reviews.into_iter().map(|r| r.text).collect();
    Workload::Documents { docs, labels }
}

/// Pre-compile the artifacts the (dl, quant) toggles select plus the
/// FP32 fused reference the agreement audit scores against; returns the
/// warm client a serving session holds.
pub fn warm(cfg: &RunConfig) -> anyhow::Result<Option<ModelClient>> {
    warm_client(cfg).map(Some)
}

fn warm_client(cfg: &RunConfig) -> anyhow::Result<ModelClient> {
    let (model, is_chain) = model_choice(cfg.toggles.dl, cfg.toggles.quant);
    let client = ModelServer::shared()?;
    if is_chain {
        client.warm_session(&["bert_fused_b8"], &[model])?;
    } else {
        client.warm_session(&[model, "bert_fused_b8"], &[])?;
    }
    Ok(client)
}

/// Build the DLSA serving plan over a synthetic payload.
pub fn plan(cfg: &RunConfig) -> anyhow::Result<Plan> {
    plan_with(cfg, Workload::Synthetic)
}

/// Build the DLSA serving plan over a supplied payload (one-shot shim
/// over [`compile`] + bind).
pub fn plan_with(cfg: &RunConfig, workload: Workload) -> anyhow::Result<Plan> {
    let payload = match workload {
        Workload::Synthetic => payload(cfg),
        w => w,
    };
    compile(cfg)?.bind(payload, cfg.seed)
}

/// Compile the DLSA serving graph once: model artifacts are warmed here
/// (the compile-time cost a session pays at open), and every bind after
/// that instantiates stage closures around a [`Workload::Documents`]
/// payload with zero warm round-trips. Per-item shape: sharded binds
/// slice the document stream, each shard batching its own partition.
pub fn compile(cfg: &RunConfig) -> anyhow::Result<CompiledPipeline> {
    let tok_kind = match cfg.toggles.tokenizer {
        OptLevel::Baseline => TokenizerKind::Baseline,
        OptLevel::Optimized => TokenizerKind::Optimized,
    };
    let (model, is_chain) = model_choice(cfg.toggles.dl, cfg.toggles.quant);

    // Steady-state measurement: the shared model server compiles at
    // graph-compile time, outside every timed bind (the paper's Fig 1
    // measures serving, with model compilation amortized). Requests
    // bound to this graph never re-issue the warm round-trips.
    let client = warm_client(cfg)?;
    let infer_client = client.clone();
    let audit_client = client;

    Ok(CompiledPlan::source(
        "dlsa",
        "load_data",
        Category::Pre,
        Slicing::PerItem,
        |slice: WorkloadSlice<Workload>| {
            let docs = match slice.payload {
                Workload::Documents { docs, .. } => docs,
                other => return Err(super::workload_mismatch("dlsa", "documents", &other)),
            };
            // Emit global document indices (`shard + j·of`), so sliced
            // binds produce exactly the streams a filtered full payload
            // would — the sink's index sort and label audit depend on it.
            let items: Vec<(usize, String)> = docs
                .into_iter()
                .enumerate()
                .map(|(j, text)| (slice.global_index(j), text))
                .collect();
            let mut feed = Some(items);
            Ok(move |emit: &mut dyn FnMut((usize, String))| {
                for item in feed.take().into_iter().flatten() {
                    emit(item);
                }
            })
        },
    )
    .map("tokenize", Category::Pre, move |_seed| {
        // Tokenizer init happens lazily on the first document of each
        // bound run, so its cost lands in this Pre stage like Table 1's
        // "initialize tokenizer".
        let mut tok: Option<WordPiece> = None;
        move |(i, text): (usize, String)| {
            let tok = tok.get_or_insert_with(|| {
                WordPiece::new(Vocab::build_from_corpus(&ReviewGenerator::lexicon(), 64), SEQ)
            });
            Ok((i, tok.encode(&text, tok_kind)))
        }
    })
    .batch(
        "dynamic_batch",
        Category::Pre,
        BatcherConfig { max_batch: BATCH, max_wait: Duration::from_millis(5) },
    )
    .flat_map("inference", Category::Ai, move |_seed| {
        let client = infer_client.clone();
        move |batch: Vec<(usize, Vec<i64>)>| {
            let logits = infer_batch(&client, model, is_chain, &batch)?;
            Ok(batch
                .into_iter()
                .zip(logits)
                .map(|((i, enc), l)| (i, enc, l))
                .collect())
        }
    })
    .sink("postprocess", Category::Post, move |payload: &Workload, _seed| {
        let (n_docs, labels) = match payload {
            Workload::Documents { docs, labels } => {
                anyhow::ensure!(
                    labels.is_empty() || labels.len() == docs.len(),
                    "dlsa: {} labels for {} documents",
                    labels.len(),
                    docs.len()
                );
                (docs.len(), labels.clone())
            }
            other => return Err(super::workload_mismatch("dlsa", "documents", other)),
        };
        let audit_client = audit_client.clone();
        Ok((
            Vec::new(),
            |acc: &mut Vec<(usize, Vec<i64>, [f32; 2])>, item: (usize, Vec<i64>, [f32; 2])| {
                acc.push(item);
                Ok(())
            },
            move |mut acc: Vec<(usize, Vec<i64>, [f32; 2])>| {
                acc.sort_by_key(|(i, _, _)| *i);
                // Offline quality audit (untimed, like the original
                // post-run audit): score the same encodings with the
                // FP32 fused reference and measure prediction agreement.
                let mut reference: Vec<[f32; 2]> = Vec::with_capacity(acc.len());
                let encs: Vec<(usize, Vec<i64>)> =
                    acc.iter().map(|(i, enc, _)| (*i, enc.clone())).collect();
                for chunk in encs.chunks(BATCH) {
                    reference
                        .extend(infer_batch(&audit_client, "bert_fused_b8", false, chunk)?);
                }
                let n = acc.len();
                let agree = acc
                    .iter()
                    .zip(&reference)
                    .filter(|((_, _, ours), fp32)| argmax2(ours) == argmax2(fp32))
                    .count();
                let mut m = BTreeMap::new();
                m.insert("agreement_vs_fp32".to_string(), agree as f64 / n.max(1) as f64);
                // Unlabeled external payloads skip the label audit.
                if !labels.is_empty() {
                    let label_match = acc
                        .iter()
                        .filter(|(i, _, logits)| {
                            labels.get(*i).is_some_and(|&l| argmax2(logits) as i64 == l)
                        })
                        .count();
                    m.insert("label_match".to_string(), label_match as f64 / n.max(1) as f64);
                }
                Ok(PlanOutput { metrics: m, items: n_docs })
            },
        ))
    })
    .declare_warm(&[model, "bert_fused_b8"]))
}

/// Run the DLSA pipeline under `cfg.exec`.
pub fn run(cfg: &RunConfig) -> anyhow::Result<PipelineResult> {
    super::run_entry(super::find("dlsa").expect("dlsa is registered"), cfg)
}

/// Typed projection of a DLSA run's metrics (`label_match` is `NaN` for
/// unlabeled payloads).
pub fn output(res: &PipelineResult) -> Output {
    Output::Sentiment {
        agreement_vs_fp32: res.metric_or_nan("agreement_vs_fp32"),
        label_match: res.metric_or_nan("label_match"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ExecMode;
    use crate::pipelines::Toggles;

    fn artifacts_ready() -> bool {
        crate::runtime::default_artifacts_dir().join("manifest.json").exists()
    }

    fn small(toggles: Toggles) -> PipelineResult {
        run(&RunConfig { toggles, scale: 0.25, seed: 9, ..Default::default() }).unwrap()
    }

    #[test]
    fn fused_runs_and_reports() {
        if !artifacts_ready() {
            return;
        }
        let res = small(Toggles::optimized());
        assert_eq!(res.items, 24);
        assert!(res.metric("agreement_vs_fp32").is_some());
    }

    #[test]
    fn int8_agrees_with_fp32() {
        if !artifacts_ready() {
            return;
        }
        let mut t = Toggles::optimized();
        t.quant = true; // opt in: int8 artifact
        let res = small(t);
        let agree = res.metric("agreement_vs_fp32").unwrap();
        assert!(agree >= 0.85, "int8 agreement {agree}");
    }

    #[test]
    fn unfused_chain_matches_fused_predictions() {
        if !artifacts_ready() {
            return;
        }
        let mut t = Toggles::optimized();
        t.dl = OptLevel::Baseline;
        t.quant = false;
        let res = small(t);
        // FP32 unfused vs FP32 fused must agree (numerically identical
        // graphs modulo fusion).
        let agree = res.metric("agreement_vs_fp32").unwrap();
        assert!(agree >= 0.99, "unfused agreement {agree}");
    }

    #[test]
    fn ai_share_is_substantial() {
        if !artifacts_ready() {
            return;
        }
        // Fig 1: DLSA is AI-dominated (~80% AI).
        let res = small(Toggles::optimized());
        let (_, ai) = res.report.fig1_split();
        assert!(ai > 40.0, "ai={ai}");
    }

    #[test]
    fn serving_stage_names() {
        if !artifacts_ready() {
            return;
        }
        let res = small(Toggles::optimized());
        let names: Vec<&str> = res.report.stages.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(
            names,
            vec!["load_data", "tokenize", "dynamic_batch", "inference", "postprocess"]
        );
    }

    #[test]
    fn streaming_batches_preserve_predictions() {
        if !artifacts_ready() {
            return;
        }
        // Batch boundaries differ between executors (timeout flushes);
        // per-document predictions must not.
        let cfg = RunConfig { toggles: Toggles::optimized(), scale: 0.25, seed: 9, ..Default::default() };
        let seq = run(&cfg).unwrap();
        let stream = run(&RunConfig { exec: ExecMode::Streaming, ..cfg }).unwrap();
        assert_eq!(seq.metrics, stream.metrics);
    }

    #[test]
    fn sharded_batches_preserve_predictions() {
        if !artifacts_ready() {
            return;
        }
        // Sharding cuts the document stream round-robin, so each shard
        // batches its own partition (different batch compositions than
        // sequential) and the sink's index-sort makes the merge order
        // irrelevant: per-document predictions — and therefore agreement
        // and label_match — must be identical. The docs split across
        // shards, pinning true data-parallel serving for the per-item
        // pipeline shape.
        let cfg = RunConfig { toggles: Toggles::optimized(), scale: 0.25, seed: 9, ..Default::default() };
        let seq = run(&cfg).unwrap();
        let sharded = run(&RunConfig { exec: ExecMode::Sharded(3), ..cfg }).unwrap();
        assert_eq!(seq.metrics, sharded.metrics);
        assert_eq!(seq.items, sharded.items);
        let sharding = sharded.sharding.unwrap();
        assert_eq!(sharding.total_owned(), seq.items, "every doc is owned by some shard");
        assert!(
            sharding.shards.iter().all(|s| s.owned > 0),
            "24 docs over 3 shards leaves no shard idle"
        );
    }
}
